//! Service-tier integration tests: the [`SolverPool`]'s pattern-keyed
//! symbolic cache, batched multi-RHS solves, and concurrent sessions.
//!
//! Tier layout: see `rust/tests/README.md`.

use std::time::Duration;

use glu3::coordinator::{pattern_key, Checkout, FaultPlan, ServeConfig, Server, SolverPool};
use glu3::glu::{ExecBackend, GluOptions, GluSolver, NumericEngine};
use glu3::numeric::residual;
use glu3::sparse::gen::{self, restamp_columns as restamp};
use glu3::sparse::Csc;
use glu3::util::Rng;

/// Pattern-cache accounting: misses only on first sight of a pattern, hits
/// on every repeat, entry count matches distinct patterns.
#[test]
fn cache_hit_miss_accounting() {
    let pool = SolverPool::new(GluOptions::default());
    let pats: Vec<Csc> = (0..3)
        .map(|s| gen::netlist(200, 5, 10, 0.05, 2, 0.2, 500 + s))
        .collect();
    let mut rng = Rng::new(1);
    let b = vec![1.0; 200];

    // 4 rounds over 3 patterns with fresh values each time.
    for round in 0..4 {
        for (pi, p) in pats.iter().enumerate() {
            let m = restamp(p, &mut rng);
            let x = pool.solve(&m, &b).unwrap();
            assert!(
                residual(&m, &x, &b) < 1e-7,
                "round {round} pattern {pi}: residual too large"
            );
        }
    }

    let st = pool.stats();
    assert_eq!(st.requests(), 12);
    assert_eq!(st.misses, 3, "one miss per distinct pattern");
    assert_eq!(st.hits, 9, "every repeat is a hit");
    assert_eq!(st.factors, 3);
    assert_eq!(st.refactors, 9);
    assert_eq!(st.entries, 3);
    assert_eq!(st.solves, 12);
    assert!((st.hit_rate() - 0.75).abs() < 1e-12);
    assert_eq!(st.latency.count(), 12);
    assert!(st.p99_ms() >= st.p50_ms());
}

/// The acceptance-criteria assertion: refactor-path solves skip ordering,
/// fill, and dependency detection — verified via the GluStats run counters
/// (symbolic pipeline ran exactly once while the numeric kernel ran once
/// per request).
#[test]
fn refactor_path_skips_symbolic_phases() {
    let pool = SolverPool::new(GluOptions::default());
    let base = gen::netlist(300, 5, 12, 0.05, 2, 0.2, 11);
    let mut rng = Rng::new(2);
    let b = vec![1.0; 300];

    let requests = 8;
    for _ in 0..requests {
        pool.solve(&restamp(&base, &mut rng), &b).unwrap();
    }

    let entries = pool.entry_stats();
    assert_eq!(entries.len(), 1);
    let (key, stats) = &entries[0];
    assert_eq!(*key, pattern_key(&base));
    assert_eq!(
        stats.symbolic_runs, 1,
        "ordering/fill/detection must run exactly once for a cached pattern"
    );
    assert_eq!(
        stats.numeric_runs, requests,
        "the numeric kernel runs once per request"
    );
    let st = pool.stats();
    assert_eq!(st.factors, 1);
    assert_eq!(st.refactors as usize, requests - 1);
}

/// Batched `solve_many` agrees with N independent `solve` calls — same
/// inner routine, so the answers are identical, not merely close.
#[test]
fn solve_many_agrees_with_independent_solves() {
    let a = gen::netlist(250, 6, 10, 0.05, 2, 0.2, 31);
    let batch: Vec<Vec<f64>> = (0..8)
        .map(|s| (0..250).map(|i| ((i * 3 + s) % 17) as f64 - 8.0).collect())
        .collect();

    // Batched through the pool.
    let pool = SolverPool::new(GluOptions::default());
    let xs_batch = pool.solve_many(&a, &batch).unwrap();
    let st = pool.stats();
    assert_eq!(st.requests(), 1, "one pattern lookup for the whole batch");
    assert_eq!(st.solves as usize, batch.len());

    // N independent solves on a fresh solver.
    let mut solver = GluSolver::factor(&a, &GluOptions::default()).unwrap();
    for (b, x_batch) in batch.iter().zip(&xs_batch) {
        let x_one = solver.solve(b).unwrap();
        assert_eq!(&x_one, x_batch, "batched result must match independent solve");
        assert!(residual(&a, x_batch, b) < 1e-7);
    }
}

/// Concurrent solves from 4 threads return exactly the answers serial
/// execution produces, and the cache accounting still adds up.
#[test]
fn concurrent_solves_match_serial() {
    let threads = 4;
    let per_thread = 6;
    let pats: Vec<Csc> = (0..3)
        .map(|s| gen::netlist(150, 5, 10, 0.08, 2, 0.2, 900 + s))
        .collect();

    // Build every request (thread, index) -> (matrix, rhs) up front so the
    // serial and concurrent runs see byte-identical inputs.
    let mut requests: Vec<Vec<(Csc, Vec<f64>)>> = Vec::new();
    for t in 0..threads {
        let mut rng = Rng::new(7_000 + t as u64);
        let mut reqs = Vec::new();
        for i in 0..per_thread {
            let m = restamp(&pats[(t + i) % pats.len()], &mut rng);
            let b: Vec<f64> = (0..150).map(|j| ((j + t + i) % 9) as f64 - 4.0).collect();
            reqs.push((m, b));
        }
        requests.push(reqs);
    }

    // Serial reference: a fresh factorization per request (no shared state).
    let serial: Vec<Vec<Vec<f64>>> = requests
        .iter()
        .map(|reqs| {
            reqs.iter()
                .map(|(m, b)| {
                    GluSolver::factor(m, &GluOptions::default())
                        .unwrap()
                        .solve(b)
                        .unwrap()
                })
                .collect()
        })
        .collect();

    // Concurrent: all threads share one pool.
    let pool = SolverPool::new(GluOptions::default());
    let mut concurrent: Vec<Vec<Vec<f64>>> = vec![Vec::new(); threads];
    std::thread::scope(|scope| {
        for (t, (reqs, out)) in requests.iter().zip(concurrent.iter_mut()).enumerate() {
            let pool = &pool;
            scope.spawn(move || {
                for (m, b) in reqs {
                    let x = pool.solve(m, b).unwrap_or_else(|e| {
                        panic!("thread {t}: solve failed: {e}");
                    });
                    out.push(x);
                }
            });
        }
    });

    for (t, (ser, con)) in serial.iter().zip(&concurrent).enumerate() {
        assert_eq!(ser.len(), con.len());
        for (i, (xs, xc)) in ser.iter().zip(con).enumerate() {
            for (p, q) in xs.iter().zip(xc) {
                assert!(
                    (p - q).abs() < 1e-9 * (1.0 + q.abs()),
                    "thread {t} request {i}: concurrent result diverged"
                );
            }
        }
    }

    let st = pool.stats();
    assert_eq!(st.requests() as usize, threads * per_thread);
    // The miss-path factorization runs outside the shard lock, so threads
    // racing on the same *cold* pattern may each factor it once; after
    // warmup every request hits. 3 patterns, 4 threads bounds the misses.
    assert!(
        (3..=3 * threads as u64).contains(&st.misses),
        "misses {} outside [3, {}]",
        st.misses,
        3 * threads
    );
    assert_eq!(st.factors, st.misses);
    assert_eq!(st.hits, st.requests() - st.misses);
    assert_eq!(st.entries, 3);
    assert_eq!(st.solves as usize, threads * per_thread);
    assert_eq!(st.latency.count(), threads * per_thread);
}

/// LRU eviction under capacity pressure keeps serving correct answers and
/// counts evictions.
#[test]
fn eviction_pressure_stays_correct() {
    // A deliberately tiny pool: 1 shard, 2 entries, 4 patterns.
    let pool = SolverPool::with_config(GluOptions::default(), 1, 2);
    let pats: Vec<Csc> = (0..4)
        .map(|s| gen::netlist(120, 5, 8, 0.1, 1, 0.2, 40 + s))
        .collect();
    let b = vec![1.0; 120];
    for round in 0..3 {
        for (pi, p) in pats.iter().enumerate() {
            let x = pool.solve(p, &b).unwrap();
            assert!(
                residual(p, &x, &b) < 1e-7,
                "round {round} pattern {pi} under eviction pressure"
            );
        }
    }
    let st = pool.stats();
    // Round-robin over 4 patterns with capacity 2 thrashes: every request
    // after the warmup misses, and each miss beyond capacity evicts.
    assert_eq!(st.requests(), 12);
    assert_eq!(st.misses, 12);
    assert_eq!(st.evictions, 10);
    assert_eq!(st.entries, 2);
}

/// Checkout outcomes are visible to callers (the NR driver keys off them).
#[test]
fn checkout_outcome_reporting() {
    let a = gen::grid2d(10, 10, 3);
    let pool = SolverPool::new(GluOptions::default());
    {
        let g = pool.checkout(&a).unwrap();
        assert_eq!(g.outcome(), Checkout::Factored);
    }
    {
        let g = pool.checkout(&a).unwrap();
        assert_eq!(g.outcome(), Checkout::Refactored);
        assert_eq!(g.stats().symbolic_runs, 1);
        assert_eq!(g.stats().numeric_runs, 2);
    }
}

/// Acceptance: checkout hits perform zero plan rebuilds — the cached
/// solver's mode-annotated FactorPlan is part of the pattern-keyed
/// symbolic state, so a hit reruns only the numeric kernel against it.
#[test]
fn checkout_hits_skip_plan_rebuilds() {
    let pool = SolverPool::new(GluOptions::default());
    let a = gen::netlist(180, 5, 10, 0.05, 2, 0.2, 909);
    let mut rng = Rng::new(77);
    let b = vec![1.0; 180];
    for _ in 0..5 {
        let m = restamp(&a, &mut rng);
        pool.solve(&m, &b).unwrap();
    }
    let st = pool.stats();
    assert_eq!((st.misses, st.hits), (1, 4));
    let es = pool.entry_stats();
    assert_eq!(es.len(), 1);
    // one plan build at factor time, never again across 4 refactor hits
    assert_eq!(es[0].1.plan_builds, 1);
    assert_eq!(es[0].1.numeric_runs, 5);
    assert_eq!(es[0].1.symbolic_runs, 1);
    // and the per-stage preprocessing timings were recorded once
    assert!(es[0].1.plan_ms >= 0.0);
    assert!(es[0].1.detect_ms >= 0.0 && es[0].1.levelize_ms >= 0.0);
}

/// Acceptance: the pattern-time ScatterMap is part of the cached symbolic
/// state — across repeated pool checkouts of the same pattern the indexed
/// engine builds it exactly once (`GluStats::scatter_builds == 1`), every
/// hit refactoring through the cached map.
#[test]
fn scatter_map_built_once_across_pool_checkouts() {
    let opts = GluOptions {
        engine: NumericEngine::ParallelRightLooking { threads: 2 },
        ..Default::default()
    };
    let pool = SolverPool::new(opts);
    let base = gen::grid2d(16, 16, 5);
    let mut rng = Rng::new(91);
    let b = vec![1.0; 256];
    for _ in 0..5 {
        let m = restamp(&base, &mut rng);
        let x = pool.solve(&m, &b).unwrap();
        assert!(residual(&m, &x, &b) < 1e-7);
    }
    let st = pool.stats();
    assert_eq!((st.misses, st.hits), (1, 4));
    let es = pool.entry_stats();
    assert_eq!(es.len(), 1);
    let stats = &es[0].1;
    assert_eq!(
        stats.scatter_builds, 1,
        "checkout hits must never rebuild the scatter map"
    );
    assert_eq!(stats.plan_builds, 1);
    assert_eq!(stats.numeric_runs, 5);
    assert!(
        stats.atomic_commits_avoided > 0,
        "AMD mesh must have ownership/chain levels"
    );
}

/// Acceptance: the lowered `LaunchSchedule` (and the executor's uploaded
/// device buffers) are part of the cached per-pattern state — across
/// repeated pool checkouts the schedule engine lowers the schedule and
/// uploads the pattern exactly once (`GluStats::schedule_builds == 1`),
/// every hit re-executing the cached launch sequence.
#[test]
fn launch_schedule_lowered_once_across_pool_checkouts() {
    let opts = GluOptions {
        engine: NumericEngine::Schedule {
            backend: ExecBackend::Virtual,
        },
        ..Default::default()
    };
    let pool = SolverPool::new(opts);
    let base = gen::grid2d(14, 14, 5);
    let mut rng = Rng::new(101);
    let b = vec![1.0; 196];
    for _ in 0..4 {
        let m = restamp(&base, &mut rng);
        let x = pool.solve(&m, &b).unwrap();
        assert!(residual(&m, &x, &b) < 1e-7);
    }
    let st = pool.stats();
    assert_eq!((st.misses, st.hits), (1, 3));
    let es = pool.entry_stats();
    assert_eq!(es.len(), 1);
    let stats = &es[0].1;
    assert_eq!(
        stats.schedule_builds, 1,
        "checkout hits must never re-lower the schedule"
    );
    assert_eq!(stats.scatter_builds, 1);
    assert_eq!(stats.plan_builds, 1);
    assert_eq!(stats.numeric_runs, 4);
    let exec = stats.exec.as_ref().expect("schedule engine must carry a per-launch report");
    assert_eq!(exec.per_launch.len(), stats.num_levels);
    assert!(exec.total_launches() >= stats.num_levels as u64);
}

/// Coalescing accounting on the serving loop: identical-stamp requests
/// ride one checkout, so the server answers all of them while running
/// far fewer refactors than requests (and exactly one symbolic run).
#[test]
fn coalescing_amortizes_identical_stamps() {
    let a = gen::netlist(120, 5, 8, 0.1, 1, 0.2, 77);
    // A slow single worker (forced 40ms per batch) backs the queue up so
    // the identical stamps are actually waiting together when popped.
    let plan = FaultPlan {
        delay: 1.0,
        delay_ms: 40,
        ..FaultPlan::disabled()
    };
    let cfg = ServeConfig {
        queue_capacity: 32,
        workers: 1,
        max_coalesce: 8,
        default_deadline: Duration::from_secs(30),
        fault_plan: plan,
        ..ServeConfig::default()
    };
    let server = Server::new(GluOptions::default(), cfg);
    let t0 = server.tenant("sim", 1);
    server.warm(&a).unwrap();
    let mut rng = Rng::new(7);
    let m = restamp(&a, &mut rng);
    let rhs = vec![vec![1.0; 120]];
    let tickets: Vec<_> = (0..12)
        .map(|_| server.submit(t0, m.clone(), rhs.clone()).unwrap())
        .collect();
    for t in tickets {
        let xs = t.wait().unwrap();
        assert_eq!(xs.len(), 1);
        assert!(residual(&m, &xs[0], &rhs[0]) < 1e-7);
    }
    let st = server.shutdown();
    assert_eq!(st.completed, 12);
    assert_eq!(st.in_flight(), 0);
    assert!(st.coalesced >= 4, "identical stamps must ride shared checkouts");
    assert!(st.numeric_runs < 12, "coalescing must amortize refactors");
    assert_eq!(st.symbolic_runs, 1, "one warm symbolic run serves everything");
    // A coalesced group issues exactly one blocked trisolve walk for the
    // whole batch, so walks = groups, not members: every coalesced member
    // rode a walk it did not pay for.
    assert!(
        st.batched_solve_walks >= 1,
        "the group solve must be counted"
    );
    assert_eq!(
        st.batched_solve_walks + st.coalesced,
        st.completed,
        "completed = one walk per group + the members that rode along"
    );
}
