//! Chaos tier: the fault-tolerant serving core under a deterministic,
//! seeded fault matrix — {injected delay, rung-2 repair stamp, rung-3
//! escalation stamp, singular exhaustion, rescuable singular burst
//! (rung-5 pivot rescue), poisoned checkout, queue-full burst} × {1, 4}
//! tenants.
//!
//! The invariants under test, for every scenario:
//!
//! - **zero lost requests**: every admitted request resolves
//!   (`ServeStats::in_flight() == 0` after a drained shutdown) and every
//!   ticket wait returns — solution or error, never a hang;
//! - **typed failures**: every service error downcasts to [`GluError`];
//! - **retry discipline**: transient faults retry with backoff, terminal
//!   [`GluError::NumericallySingular`] exhaustion never does;
//! - **the cached pattern survives faults**: the symbolic pipeline count
//!   stays at the warm-up's single run no matter what values arrive;
//! - **structural near-misses patch**: mixed traffic over one-entry
//!   pattern variants rides the incremental symbolic patch, keeping the
//!   service-level symbolic run count sub-linear in distinct patterns.
//!
//! Fault decisions are a pure function of `(seed, request id)`, so these
//! tests are reproducible regardless of worker interleaving.
//!
//! Tier layout: see `rust/tests/README.md`.

use std::time::Duration;

use glu3::coordinator::{FaultPlan, ServeConfig, ServeStats, Server};
use glu3::glu::GluOptions;
use glu3::numeric::GluError;
use glu3::sparse::gen::{self, restamp_columns};
use glu3::sparse::Csc;
use glu3::util::Rng;

type Outcome = anyhow::Result<Vec<Vec<f64>>>;

fn base_matrix(seed: u64) -> Csc {
    gen::netlist(120, 5, 8, 0.1, 1, 0.2, seed)
}

/// Drive `requests` submissions across `tenants` equal-priority tenants
/// (distinct values per request, so no coalescing muddies the counters),
/// wait out every ticket, and return the drained stats plus each outcome.
fn storm(a: &Csc, plan: FaultPlan, tenants: usize, requests: usize) -> (ServeStats, Vec<Outcome>) {
    let cfg = ServeConfig {
        queue_capacity: 64,
        workers: 2,
        default_deadline: Duration::from_secs(10),
        max_coalesce: 1,
        fault_plan: plan,
        ..ServeConfig::default()
    };
    let server = Server::new(GluOptions::default(), cfg);
    let ids: Vec<_> = (0..tenants).map(|i| server.tenant(&format!("t{i}"), 1)).collect();
    server.warm(a).unwrap();
    let mut rng = Rng::new(0xFA11);
    let tickets: Vec<_> = (0..requests)
        .map(|i| {
            let m = restamp_columns(a, &mut rng);
            let rhs = vec![vec![1.0; m.nrows()]; 2];
            server.submit(ids[i % ids.len()], m, rhs).unwrap()
        })
        .collect();
    let results: Vec<Outcome> = tickets.into_iter().map(|t| t.wait()).collect();
    (server.shutdown(), results)
}

fn assert_all_typed_or_ok(results: &[Outcome]) {
    for (i, r) in results.iter().enumerate() {
        if let Err(e) = r {
            assert!(
                e.downcast_ref::<GluError>().is_some(),
                "request {i}: untyped service error: {e:#}"
            );
        }
    }
}

/// Injected worker delays slow everything down but lose nothing.
#[test]
fn delay_storm_completes_everything() {
    let a = base_matrix(1);
    for tenants in [1usize, 4] {
        let plan = FaultPlan {
            delay: 1.0,
            delay_ms: 3,
            ..FaultPlan::disabled()
        };
        let (st, results) = storm(&a, plan, tenants, 10);
        assert!(
            results.iter().all(|r| r.is_ok()),
            "{tenants} tenants: delays must not fail requests"
        );
        assert_eq!(st.completed, 10);
        assert_eq!(st.in_flight(), 0);
        assert_eq!(st.injected_delays, 10);
        assert_eq!(st.retries, 0, "delays are not retried, just absorbed");
    }
}

/// Every request arrives with weakened diagonals (the rung-1/2 repair
/// stamp): the ladder repairs in place or fails typed — and the cached
/// pattern survives either way.
#[test]
fn rung2_weaken_stamps_resolve_without_symbolic_reruns() {
    let a = base_matrix(2);
    for tenants in [1usize, 4] {
        let plan = FaultPlan {
            weaken: 1.0,
            ..FaultPlan::disabled()
        };
        let (st, results) = storm(&a, plan, tenants, 8);
        assert_all_typed_or_ok(&results);
        assert_eq!(st.in_flight(), 0);
        assert_eq!(st.completed + st.failed, 8);
        assert_eq!(st.injected_repairs, 8);
        assert_eq!(st.retries, 0, "in-place ladder repairs are not retries");
        assert_eq!(
            st.symbolic_runs, 1,
            "{tenants} tenants: hostile values must never rerun the symbolic pipeline"
        );
    }
}

/// Every request arrives with 1e100-misscaled rows (the rung-2 Ruiz
/// escalation stamp): repair-or-typed-failure, no symbolic reruns.
#[test]
fn rung3_misscale_stamps_resolve_without_symbolic_reruns() {
    let a = base_matrix(3);
    for tenants in [1usize, 4] {
        let plan = FaultPlan {
            misscale: 1.0,
            ..FaultPlan::disabled()
        };
        let (st, results) = storm(&a, plan, tenants, 8);
        assert_all_typed_or_ok(&results);
        assert_eq!(st.in_flight(), 0);
        assert_eq!(st.completed + st.failed, 8);
        assert_eq!(st.injected_escalations, 8);
        assert_eq!(st.retries, 0);
        assert_eq!(st.symbolic_runs, 1);
    }
}

/// All-zero value stamps exhaust the robustness ladder: a terminal typed
/// [`GluError::NumericallySingular`] on every request, **zero** retries
/// (exhaustion is never transient), and the cached pattern survives.
#[test]
fn singular_exhaustion_is_terminal_typed_and_never_retried() {
    let a = base_matrix(4);
    for tenants in [1usize, 4] {
        let plan = FaultPlan {
            singular: 1.0,
            ..FaultPlan::disabled()
        };
        let (st, results) = storm(&a, plan, tenants, 6);
        assert_eq!(st.in_flight(), 0);
        assert_eq!(st.completed, 0, "all-zero stamps cannot solve");
        assert_eq!(st.failed, 6);
        assert_eq!(st.injected_singulars, 6);
        assert_eq!(st.retries, 0, "singular exhaustion must never be retried");
        assert_eq!(st.symbolic_runs, 1, "the cached pattern must survive");
        for (i, r) in results.iter().enumerate() {
            let e = r.as_ref().expect_err("zeroed stamp must fail");
            assert!(
                matches!(
                    e.downcast_ref::<GluError>(),
                    Some(GluError::NumericallySingular { .. })
                ),
                "request {i}: expected typed singular exhaustion, got {e:#}"
            );
        }
    }
}

/// A burst of rescuable-singular stamps — structurally zeroed diagonals
/// that defeat the fixed-order ladder outright — against a warm pattern:
/// the first request pays the rung-5 pivot rescue and hot-swaps the pool
/// entry, the rest ride the rescued order's refactor fast path. Zero lost
/// requests, zero terminal singular replies, and the whole burst shares
/// one rescue rebuild on top of the warm-up's single cold symbolic run.
#[test]
fn singular_burst_is_rescued_with_zero_lost_requests() {
    use glu3::order::FillOrdering;

    let a = gen::zero_diagonal_band(96, 48, 20260808);
    let twin = gen::dominant_restamp(&a, 7);
    let opts = GluOptions {
        ordering: FillOrdering::Natural,
        scale: false,
        ..Default::default()
    };
    let cfg = ServeConfig {
        queue_capacity: 64,
        workers: 2,
        default_deadline: Duration::from_secs(10),
        max_coalesce: 1,
        fault_plan: FaultPlan::disabled(),
        ..ServeConfig::default()
    };
    let server = Server::new(opts, cfg);
    let t0 = server.tenant("spice", 1);
    server.warm(&twin).unwrap();

    let b = vec![1.0; 96];
    let tickets: Vec<_> = (0..8)
        .map(|_| server.submit(t0, a.clone(), vec![b.clone()]).unwrap())
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let xs = t.wait().unwrap_or_else(|e| {
            panic!("request {i}: a rescuable singular burst must not fail: {e:#}")
        });
        let r = glu3::numeric::residual(&a, &xs[0], &b);
        assert!(r <= 1e-9, "request {i}: rescued residual {r}");
    }

    let st = server.shutdown();
    assert_eq!(st.in_flight(), 0, "nothing may be lost or hung");
    assert_eq!(st.completed, 8);
    assert_eq!(st.failed, 0, "no terminal singular replies");
    assert_eq!(st.retries, 0, "the rescue happens inside refactor, not via retry");
    assert_eq!(
        st.symbolic_runs, 2,
        "one warm-up cold run plus one rescue rebuild, shared by the burst"
    );
}

/// Poisoned checkouts (typed transient faults on the first attempt) are
/// retried with backoff and then succeed: no request fails, one retry per
/// request, and the retry discipline is visible in the counters.
#[test]
fn poisoned_checkouts_retry_and_recover() {
    let a = base_matrix(5);
    for tenants in [1usize, 4] {
        let plan = FaultPlan {
            poison: 1.0,
            ..FaultPlan::disabled()
        };
        let (st, results) = storm(&a, plan, tenants, 6);
        assert!(
            results.iter().all(|r| r.is_ok()),
            "{tenants} tenants: transient poisons must be retried away"
        );
        assert_eq!(st.completed, 6);
        assert_eq!(st.injected_poisons, 6);
        assert_eq!(st.retries, 6, "exactly one backoff retry per poisoned request");
        assert_eq!(st.in_flight(), 0);
    }
}

/// Tiny deadlines under injected delay: cooperative cancellation answers
/// every request with a typed [`GluError::DeadlineExceeded`] instead of
/// blocking the worker loop on doomed work.
#[test]
fn deadlines_cancel_cooperatively_with_typed_errors() {
    let a = base_matrix(6);
    let plan = FaultPlan {
        delay: 1.0,
        delay_ms: 30,
        ..FaultPlan::disabled()
    };
    let cfg = ServeConfig {
        queue_capacity: 16,
        workers: 1,
        max_coalesce: 1,
        fault_plan: plan,
        ..ServeConfig::default()
    };
    let server = Server::new(GluOptions::default(), cfg);
    let t0 = server.tenant("hurried", 1);
    server.warm(&a).unwrap();
    let mut rng = Rng::new(0xDEAD);
    let tickets: Vec<_> = (0..4)
        .map(|_| {
            let m = restamp_columns(&a, &mut rng);
            let rhs = vec![vec![1.0; m.nrows()]];
            server
                .submit_with_deadline(t0, m, rhs, Duration::from_millis(5))
                .unwrap()
        })
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let e = t.wait().expect_err("5ms budget under 30ms delay must miss");
        assert!(
            matches!(
                e.downcast_ref::<GluError>(),
                Some(GluError::DeadlineExceeded { .. })
            ),
            "request {i}: expected typed deadline error, got {e:#}"
        );
    }
    let st = server.shutdown();
    assert_eq!(st.deadline_missed, 4);
    assert_eq!(st.completed, 0);
    assert_eq!(st.in_flight(), 0);
}

/// `count` one-entry structural variants of `a` at distinct absent
/// coordinates — each a near-miss the pool's incremental patch absorbs.
fn one_entry_variants(a: &Csc, count: usize, seed: u64) -> Vec<Csc> {
    let mut rng = Rng::new(seed);
    let n = a.ncols();
    let mut used = std::collections::HashSet::new();
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let r = rng.below(n);
        let c = rng.below(n);
        if r != c && a.get(r, c) == 0.0 && used.insert((r, c)) {
            out.push(gen::with_entry(a, r, c, -1e-2));
        }
    }
    out
}

/// Mixed traffic over a base pattern plus five one-entry structural
/// variants, under injected worker delay: every request resolves, nothing
/// is lost, and the six distinct patterns cost ONE cold symbolic run —
/// the five variants ride the near-miss incremental patch, so the
/// service-level symbolic count stays sub-linear in distinct patterns.
#[test]
fn delta_pattern_traffic_patches_instead_of_recomputing() {
    let a = base_matrix(8);
    let variants = one_entry_variants(&a, 5, 0xDE17A);
    let plan = FaultPlan {
        delay: 1.0,
        delay_ms: 2,
        ..FaultPlan::disabled()
    };
    let cfg = ServeConfig {
        queue_capacity: 64,
        workers: 2,
        default_deadline: Duration::from_secs(10),
        max_coalesce: 1,
        fault_plan: plan,
        ..ServeConfig::default()
    };
    let server = Server::new(GluOptions::default(), cfg);
    let t0 = server.tenant("mixed", 1);
    server.warm(&a).unwrap();

    let mut rng = Rng::new(0xA5A5);
    let mut tickets = Vec::new();
    for _round in 0..3 {
        for m in std::iter::once(&a).chain(&variants) {
            let m = restamp_columns(m, &mut rng);
            let rhs = vec![vec![1.0; m.nrows()]];
            tickets.push(server.submit(t0, m, rhs).unwrap());
        }
    }
    for (i, t) in tickets.into_iter().enumerate() {
        t.wait().unwrap_or_else(|e| panic!("request {i} failed: {e:#}"));
    }

    let st = server.shutdown();
    assert_eq!(st.in_flight(), 0, "nothing may be lost");
    assert_eq!(st.completed, 18);
    assert!(
        st.symbolic_runs <= 2,
        "6 distinct patterns x 3 rounds must not cost per-pattern cold \
         symbolic runs (got {}): the near-miss patch path is not engaging",
        st.symbolic_runs
    );
}

/// A queue-full burst against a slow single worker: the bounded queue
/// rejects with typed [`GluError::Overloaded`], the lowest-priority tenant
/// is shed first (priority-scaled admission shares), and every *admitted*
/// request still resolves.
#[test]
fn queue_full_burst_rejects_typed_and_sheds_lowest_priority_first() {
    let a = base_matrix(7);
    let plan = FaultPlan {
        delay: 1.0,
        delay_ms: 25,
        ..FaultPlan::disabled()
    };
    let cfg = ServeConfig {
        queue_capacity: 4,
        workers: 1,
        max_coalesce: 1,
        default_deadline: Duration::from_secs(30),
        fault_plan: plan,
        ..ServeConfig::default()
    };
    let server = Server::new(GluOptions::default(), cfg);
    let low = server.tenant("batch", 0);
    let high = server.tenant("interactive", 3);
    server.warm(&a).unwrap();

    let mut rng = Rng::new(0xB00);
    let mut tickets = Vec::new();
    let mut typed_rejections = 0u64;
    // High-priority burst first: fills the queue to its real capacity.
    for _ in 0..8 {
        let m = restamp_columns(&a, &mut rng);
        match server.submit(high, m, vec![vec![1.0; 120]]) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                assert!(
                    matches!(e.downcast_ref::<GluError>(), Some(GluError::Overloaded { .. })),
                    "untyped admission error: {e:#}"
                );
                typed_rejections += 1;
            }
        }
    }
    // Low-priority burst into the pressure: share = cap * 1/4 = 1 slot, so
    // these shed while the high-priority tenant still saw the full queue.
    for _ in 0..8 {
        let m = restamp_columns(&a, &mut rng);
        match server.submit(low, m, vec![vec![1.0; 120]]) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                assert!(
                    matches!(e.downcast_ref::<GluError>(), Some(GluError::Overloaded { .. })),
                    "untyped shed error: {e:#}"
                );
                typed_rejections += 1;
            }
        }
    }
    assert!(typed_rejections > 0, "a 16-deep burst into capacity 4 must reject");

    // Every admitted request resolves; with a 30s deadline they complete.
    for t in tickets {
        t.wait().unwrap();
    }
    let st = server.shutdown();
    assert!(st.rejected + st.shed > 0);
    assert!(st.shed >= 1, "the priority-0 tenant must be shed under pressure");
    assert_eq!(st.in_flight(), 0);
    assert_eq!(st.submitted, st.completed);
    assert_eq!(st.depth.max_depth().min(4), st.depth.max_depth(), "depth bounded by capacity");
}
