//! Cross-module integration tests: the full pipeline on generated suites,
//! engine equivalences, paper-claim checks at integration scope, and the
//! PJRT runtime against the native solver.

use glu3::depend::levelize::validate_hazard_free;
use glu3::depend::{glu2, glu3 as g3, levelize};
use glu3::glu::{Detection, ExecBackend, GluOptions, GluSolver, NumericEngine};
use glu3::gpusim::{simulate_factorization, DeviceConfig, Policy};
use glu3::numeric::{leftlook, residual};
use glu3::order::{preprocess, FillOrdering};
use glu3::sparse::gen::{self, SuiteMatrix};
use glu3::symbolic::symbolic_fill;

/// The full pipeline solves every small suite matrix accurately.
#[test]
fn pipeline_small_suite() {
    for m in [SuiteMatrix::Rajat12, SuiteMatrix::Circuit2] {
        let a = gen::generate(&m.spec());
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let n = a.nrows();
        let b: Vec<f64> = (0..n).map(|i| ((i % 23) as f64) - 11.0).collect();
        let x = s.solve(&b).unwrap();
        let r = residual(&a, &x, &b);
        assert!(r < 1e-7, "{}: residual {r}", m.ufl_name());
    }
}

/// GPU-simulated factors == CPU oracle factors on a real suite matrix.
#[test]
fn simulator_matches_oracle_on_suite_matrix() {
    let a = gen::generate(&SuiteMatrix::Rajat12.spec());
    let pre = preprocess(&a, FillOrdering::Amd, true).unwrap();
    let sym = symbolic_fill(&pre.a).unwrap();
    let lv = levelize(&g3::detect(&sym.filled));
    validate_hazard_free(&sym.filled, &lv).unwrap();

    let (lu_sim, _) =
        simulate_factorization(&sym, &lv, &Policy::glu3(), &DeviceConfig::titan_x()).unwrap();
    let lu_ref = leftlook::factor(&sym).unwrap();
    for (p, q) in lu_sim.lu.values().iter().zip(lu_ref.lu.values()) {
        assert!((p - q).abs() < 1e-8 * (1.0 + q.abs()));
    }
}

/// Paper Table II claim at integration scope: relaxed detection is much
/// faster than the double-U search and costs at most a few extra levels.
#[test]
fn relaxed_detection_faster_and_equivalent() {
    let a = gen::generate(&SuiteMatrix::Circuit2.spec());
    let pre = preprocess(&a, FillOrdering::Amd, true).unwrap();
    let sym = symbolic_fill(&pre.a).unwrap();

    let t2 = std::time::Instant::now();
    let d2 = glu2::detect(&sym.filled);
    let time2 = t2.elapsed();
    let t3 = std::time::Instant::now();
    let d3 = g3::detect(&sym.filled);
    let time3 = t3.elapsed();

    let l2 = levelize(&d2).num_levels();
    let l3 = levelize(&d3).num_levels();
    assert!(l3 >= l2 && l3 <= l2 + 10, "levels {l2} vs {l3}");
    assert!(
        time3 < time2,
        "relaxed {time3:?} must beat double-U {time2:?}"
    );
}

/// All engines produce the same solution through the full pipeline.
#[test]
fn engines_agree_through_pipeline() {
    let a = gen::generate(&SuiteMatrix::Rajat12.spec());
    let n = a.nrows();
    let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
    let mut solutions = Vec::new();
    for engine in [
        NumericEngine::SimulatedGpu,
        NumericEngine::LeftLookingCpu,
        NumericEngine::RightLookingCpu,
        NumericEngine::ParallelCpu { threads: 2 },
        NumericEngine::ParallelRightLooking { threads: 4 },
        NumericEngine::Schedule {
            backend: ExecBackend::Virtual,
        },
    ] {
        let opts = GluOptions {
            engine,
            ..Default::default()
        };
        let mut s = GluSolver::factor(&a, &opts).unwrap();
        solutions.push(s.solve(&b).unwrap());
    }
    for x in &solutions[1..] {
        for (p, q) in x.iter().zip(&solutions[0]) {
            assert!((p - q).abs() < 1e-8 * (1.0 + q.abs()));
        }
    }
}

/// GLU2.0 exact detection also drives the simulator correctly.
#[test]
fn glu2_detection_full_pipeline() {
    let a = gen::generate(&SuiteMatrix::Rajat12.spec());
    let opts = GluOptions {
        detection: Detection::Glu2,
        ..Default::default()
    };
    let mut s = GluSolver::factor(&a, &opts).unwrap();
    let b = vec![1.0; a.nrows()];
    let x = s.solve(&b).unwrap();
    assert!(residual(&a, &x, &b) < 1e-7);
}

/// Matrix Market round-trip feeds the pipeline identically.
#[test]
fn matrix_market_roundtrip_pipeline() {
    let a = gen::generate(&SuiteMatrix::Rajat12.spec());
    let path = std::env::temp_dir().join("glu3_integration_rt.mtx");
    glu3::sparse::io::write_matrix_market(&path, &a).unwrap();
    let b = glu3::sparse::io::read_matrix_market(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(a, b);
    let mut s = GluSolver::factor(&b, &GluOptions::default()).unwrap();
    let rhs = vec![1.0; b.nrows()];
    let x = s.solve(&rhs).unwrap();
    assert!(residual(&a, &x, &rhs) < 1e-7);
}

/// PJRT runtime agrees with the native dense solver (skips without the
/// `pjrt` feature or without artifacts — `make artifacts` first).
#[test]
fn pjrt_dense_tail_vs_native() {
    if !glu3::runtime::PJRT_ENABLED {
        eprintln!("skipping: built without the xla runtime feature");
        return;
    }
    let dir = glu3::runtime::default_artifact_dir();
    if !dir.join("quickstart.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = glu3::runtime::Runtime::load(dir).unwrap();
    // take the trailing 48x48 dense block of a factored suite matrix as a
    // realistic tail system
    let a = gen::generate(&SuiteMatrix::Rajat12.spec());
    let pre = preprocess(&a, FillOrdering::Amd, true).unwrap();
    let sym = symbolic_fill(&pre.a).unwrap();
    let n = sym.filled.ncols();
    let t = 48;
    let mut tail = vec![0f32; t * t];
    for (ci, c) in (n - t..n).enumerate() {
        let (rows, vals) = sym.filled.col(c);
        for (&r, &v) in rows.iter().zip(vals) {
            if r >= n - t {
                tail[(r - (n - t)) * t + ci] = v as f32;
            }
        }
    }
    // make it solvable standalone (diagonal boost)
    for d in 0..t {
        let sum: f32 = (0..t).filter(|&r| r != d).map(|r| tail[r * t + d].abs()).sum();
        tail[d * t + d] += sum + 1.0;
    }
    let rhs: Vec<f32> = (0..t).map(|i| ((i % 5) as f32) - 2.0).collect();
    let (_, x) = rt.dense_tail_solve(&tail, &rhs, t).unwrap();
    let a64: Vec<f64> = tail.iter().map(|&v| v as f64).collect();
    let b64: Vec<f64> = rhs.iter().map(|&v| v as f64).collect();
    let want = glu3::numeric::dense::solve(&a64, t, &b64).unwrap();
    for (g, w) in x.iter().zip(&want) {
        assert!((*g as f64 - w).abs() < 1e-3 * (1.0 + w.abs()));
    }
}

/// Failure injection: structurally singular and numerically singular
/// matrices are rejected with errors, not bad answers.
#[test]
fn singular_inputs_rejected() {
    use glu3::sparse::Coo;
    // empty column
    let mut coo = Coo::new(3, 3);
    coo.push(0, 0, 1.0);
    coo.push(1, 1, 1.0);
    coo.push(2, 0, 1.0);
    assert!(GluSolver::factor(&coo.to_csc(), &GluOptions::default()).is_err());

    // exact cancellation pivot
    let mut coo = Coo::new(2, 2);
    coo.push(0, 0, 1.0);
    coo.push(0, 1, 1.0);
    coo.push(1, 0, 1.0);
    coo.push(1, 1, 1.0);
    let opts = GluOptions {
        scale: false,
        ordering: FillOrdering::Natural,
        ..Default::default()
    };
    assert!(GluSolver::factor(&coo.to_csc(), &opts).is_err());
}
