//! Property-based tests over randomized inputs, seeded through the crate's
//! deterministic [`glu3::util::Rng`] (no external property-test framework —
//! the offline crate set carries none). Every case logs its seed in the
//! assertion message so failures replay exactly.
//!
//! Tier layout: see `rust/tests/README.md`.

use glu3::glu::{GluOptions, GluSolver};
use glu3::numeric::residual;
use glu3::sparse::{gen, Coo, Csc};
use glu3::util::stats::rel_linf;
use glu3::util::Rng;

/// Random sparse matrix with unique coordinates and a full, column
/// diagonally dominant diagonal (the pivot-free GLU regime).
fn random_dd(n: usize, extra: usize, rng: &mut Rng) -> Csc {
    let mut coo = Coo::new(n, n);
    let mut colsum = vec![0.0f64; n];
    let mut used = std::collections::HashSet::new();
    let mut placed = 0usize;
    while placed < extra {
        let r = rng.below(n);
        let c = rng.below(n);
        if r == c || !used.insert((r, c)) {
            continue;
        }
        let v = rng.range_f64(-1.0, 1.0);
        coo.push(r, c, v);
        colsum[c] += v.abs();
        placed += 1;
    }
    for d in 0..n {
        coo.push(d, d, colsum[d] + rng.range_f64(0.5, 1.5));
    }
    coo.to_csc()
}

/// COO → CSC → COO round-trips preserve structure and values: every unique
/// triple survives, rows are sorted within columns, and nothing is
/// invented.
#[test]
fn coo_csc_roundtrip_preserves_structure() {
    let mut rng = Rng::new(0xC5C_0001);
    for trial in 0..20 {
        let nrows = rng.range(1, 40);
        let ncols = rng.range(1, 40);
        let want_entries = rng.range(0, (nrows * ncols).min(120) + 1);

        // unique coordinates, random insertion order
        let mut triples: Vec<(usize, usize, f64)> = Vec::new();
        let mut used = std::collections::HashSet::new();
        while triples.len() < want_entries {
            let r = rng.below(nrows);
            let c = rng.below(ncols);
            if used.insert((r, c)) {
                // nonzero values so "structure preserved" is unambiguous
                let mut v = rng.range_f64(-10.0, 10.0);
                if v == 0.0 {
                    v = 1.0;
                }
                triples.push((r, c, v));
            }
        }
        let mut coo = Coo::new(nrows, ncols);
        let mut order: Vec<usize> = (0..triples.len()).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            let (r, c, v) = triples[i];
            coo.push(r, c, v);
        }

        let csc = coo.to_csc();
        assert_eq!(csc.nnz(), triples.len(), "trial {trial}: nnz changed");

        // back to triples (the CSC → COO direction) and compare as sets
        let mut back: Vec<(usize, usize, f64)> = Vec::new();
        for c in 0..csc.ncols() {
            let (rows, vals) = csc.col(c);
            // rows strictly increasing within the column
            for w in rows.windows(2) {
                assert!(w[0] < w[1], "trial {trial}: unsorted rows in col {c}");
            }
            for (&r, &v) in rows.iter().zip(vals) {
                back.push((r, c, v));
            }
        }
        let key = |t: &(usize, usize, f64)| (t.1, t.0);
        let mut want = triples.clone();
        want.sort_by_key(key);
        back.sort_by_key(key);
        assert_eq!(back, want, "trial {trial}: triples changed");
    }
}

/// Duplicate COO entries are summed on conversion (MNA stamping semantics).
#[test]
fn coo_duplicates_sum_on_conversion() {
    let mut rng = Rng::new(0xC5C_0002);
    for trial in 0..10 {
        let n = rng.range(2, 20);
        let stamps = rng.range(1, 60);
        let mut coo = Coo::new(n, n);
        let mut dense = vec![0.0f64; n * n];
        for _ in 0..stamps {
            let r = rng.below(n);
            let c = rng.below(n);
            let v = rng.range_f64(-2.0, 2.0);
            coo.push(r, c, v);
            dense[r * n + c] += v;
        }
        let csc = coo.to_csc();
        for r in 0..n {
            for c in 0..n {
                let got = csc.get(r, c);
                let want = dense[r * n + c];
                assert!(
                    (got - want).abs() < 1e-12,
                    "trial {trial}: ({r},{c}) {got} vs {want}"
                );
            }
        }
    }
}

/// For random diagonally dominant matrices, the full pipeline solves with
/// residual < 1e-7.
#[test]
fn random_dd_factor_solve_residual() {
    let mut rng = Rng::new(0xDD_0001);
    for trial in 0..10 {
        let n = rng.range(30, 200);
        let extra = n * rng.range(2, 6);
        let a = random_dd(n, extra, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) - 6.0).collect();
        let mut s = GluSolver::factor(&a, &GluOptions::default())
            .unwrap_or_else(|e| panic!("trial {trial} (n={n}): factor failed: {e}"));
        let x = s.solve(&b).unwrap();
        let r = residual(&a, &x, &b);
        assert!(r < 1e-7, "trial {trial} (n={n}): residual {r}");
    }
}

/// `refactor` with perturbed values matches a fresh `factor` of the same
/// matrix to 1e-10 — both in the LU values and in the solutions.
#[test]
fn refactor_matches_fresh_factor() {
    let mut rng = Rng::new(0xDD_0002);
    for trial in 0..8 {
        let n = rng.range(30, 150);
        let extra = n * rng.range(2, 5);
        let a = random_dd(n, extra, &mut rng);

        // Perturb values (not structure): per-column positive scaling.
        let a2 = gen::restamp_columns(&a, &mut rng);

        // With scaling off, a fresh factor of `a2` reruns the whole
        // pipeline on identical inputs (matching is invariant under the
        // per-column scaling above), so even the LU value arrays must line
        // up entry-for-entry.
        let opts = GluOptions {
            scale: false,
            ..Default::default()
        };
        let mut via_refactor = GluSolver::factor(&a, &opts).unwrap();
        via_refactor.refactor(&a2).unwrap();
        let mut fresh = GluSolver::factor(&a2, &opts).unwrap();

        let lu_r = via_refactor.factors().lu.values();
        let lu_f = fresh.factors().lu.values();
        assert_eq!(lu_r.len(), lu_f.len(), "trial {trial}: fill changed");
        for (i, (p, q)) in lu_r.iter().zip(lu_f).enumerate() {
            assert!(
                (p - q).abs() <= 1e-10 * (1.0 + q.abs()),
                "trial {trial}: LU entry {i}: {p} vs {q}"
            );
        }

        let b: Vec<f64> = (0..n).map(|i| ((i * 5 % 11) as f64) - 5.0).collect();
        let xr = via_refactor.solve(&b).unwrap();
        let xf = fresh.solve(&b).unwrap();
        let d = rel_linf(&xr, &xf);
        assert!(d < 1e-10, "trial {trial}: solutions diverged by {d}");

        // Under the default options (equilibration on) the equilibration
        // factors of `a` and `a2` differ, so only the *solutions* are
        // comparable — still to 1e-10 on these well-conditioned systems.
        let mut vr = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        vr.refactor(&a2).unwrap();
        let mut fr = GluSolver::factor(&a2, &GluOptions::default()).unwrap();
        let d = rel_linf(&vr.solve(&b).unwrap(), &fr.solve(&b).unwrap());
        assert!(d < 1e-10, "trial {trial}: scaled solutions diverged by {d}");
    }
}
