//! Property-based tests over randomized inputs, seeded through the crate's
//! deterministic [`glu3::util::Rng`] (no external property-test framework —
//! the offline crate set carries none). Every case logs its seed in the
//! assertion message so failures replay exactly.
//!
//! Tier layout: see `rust/tests/README.md`.

use glu3::glu::{GluOptions, GluSolver};
use glu3::numeric::residual;
use glu3::sparse::{gen, Coo, Csc};
use glu3::util::stats::rel_linf;
use glu3::util::Rng;

/// Explicit RNG seeds, one per property — named so they appear in failure
/// messages and a failing trial replays exactly with `Rng::new(SEED)`.
const SEED_ROUNDTRIP: u64 = 0xC5C_0001;
const SEED_DUPLICATES: u64 = 0xC5C_0002;
const SEED_RANDOM_DD: u64 = 0xDD_0001;
const SEED_REFACTOR: u64 = 0xDD_0002;
const SEED_LADDER: u64 = 0xDD_0003;

/// Random sparse matrix with unique coordinates and a full, column
/// diagonally dominant diagonal (the pivot-free GLU regime).
fn random_dd(n: usize, extra: usize, rng: &mut Rng) -> Csc {
    let mut coo = Coo::new(n, n);
    let mut colsum = vec![0.0f64; n];
    let mut used = std::collections::HashSet::new();
    let mut placed = 0usize;
    while placed < extra {
        let r = rng.below(n);
        let c = rng.below(n);
        if r == c || !used.insert((r, c)) {
            continue;
        }
        let v = rng.range_f64(-1.0, 1.0);
        coo.push(r, c, v);
        colsum[c] += v.abs();
        placed += 1;
    }
    for d in 0..n {
        coo.push(d, d, colsum[d] + rng.range_f64(0.5, 1.5));
    }
    coo.to_csc()
}

/// COO → CSC → COO round-trips preserve structure and values: every unique
/// triple survives, rows are sorted within columns, and nothing is
/// invented.
#[test]
fn coo_csc_roundtrip_preserves_structure() {
    let mut rng = Rng::new(SEED_ROUNDTRIP);
    for trial in 0..20 {
        let nrows = rng.range(1, 40);
        let ncols = rng.range(1, 40);
        let want_entries = rng.range(0, (nrows * ncols).min(120) + 1);

        // unique coordinates, random insertion order
        let mut triples: Vec<(usize, usize, f64)> = Vec::new();
        let mut used = std::collections::HashSet::new();
        while triples.len() < want_entries {
            let r = rng.below(nrows);
            let c = rng.below(ncols);
            if used.insert((r, c)) {
                // nonzero values so "structure preserved" is unambiguous
                let mut v = rng.range_f64(-10.0, 10.0);
                if v == 0.0 {
                    v = 1.0;
                }
                triples.push((r, c, v));
            }
        }
        let mut coo = Coo::new(nrows, ncols);
        let mut order: Vec<usize> = (0..triples.len()).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            let (r, c, v) = triples[i];
            coo.push(r, c, v);
        }

        let csc = coo.to_csc();
        assert_eq!(csc.nnz(), triples.len(), "trial {trial}: nnz changed");

        // back to triples (the CSC → COO direction) and compare as sets
        let mut back: Vec<(usize, usize, f64)> = Vec::new();
        for c in 0..csc.ncols() {
            let (rows, vals) = csc.col(c);
            // rows strictly increasing within the column
            for w in rows.windows(2) {
                assert!(w[0] < w[1], "trial {trial}: unsorted rows in col {c}");
            }
            for (&r, &v) in rows.iter().zip(vals) {
                back.push((r, c, v));
            }
        }
        let key = |t: &(usize, usize, f64)| (t.1, t.0);
        let mut want = triples.clone();
        want.sort_by_key(key);
        back.sort_by_key(key);
        assert_eq!(back, want, "trial {trial}: triples changed");
    }
}

/// Duplicate COO entries are summed on conversion (MNA stamping semantics).
#[test]
fn coo_duplicates_sum_on_conversion() {
    let mut rng = Rng::new(SEED_DUPLICATES);
    for trial in 0..10 {
        let n = rng.range(2, 20);
        let stamps = rng.range(1, 60);
        let mut coo = Coo::new(n, n);
        let mut dense = vec![0.0f64; n * n];
        for _ in 0..stamps {
            let r = rng.below(n);
            let c = rng.below(n);
            let v = rng.range_f64(-2.0, 2.0);
            coo.push(r, c, v);
            dense[r * n + c] += v;
        }
        let csc = coo.to_csc();
        for r in 0..n {
            for c in 0..n {
                let got = csc.get(r, c);
                let want = dense[r * n + c];
                assert!(
                    (got - want).abs() < 1e-12,
                    "trial {trial}: ({r},{c}) {got} vs {want}"
                );
            }
        }
    }
}

/// For random diagonally dominant matrices, the full pipeline solves with
/// residual < 1e-7.
#[test]
fn random_dd_factor_solve_residual() {
    let mut rng = Rng::new(SEED_RANDOM_DD);
    for trial in 0..10 {
        let n = rng.range(30, 200);
        let extra = n * rng.range(2, 6);
        let a = random_dd(n, extra, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) - 6.0).collect();
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap_or_else(|e| {
            panic!("seed {SEED_RANDOM_DD:#x} trial {trial} (n={n}): factor failed: {e}")
        });
        let x = s.solve(&b).unwrap();
        let r = residual(&a, &x, &b);
        assert!(
            r < 1e-7,
            "seed {SEED_RANDOM_DD:#x} trial {trial} (n={n}): residual {r}"
        );
    }
}

/// `refactor` with perturbed values matches a fresh `factor` of the same
/// matrix to 1e-10 — both in the LU values and in the solutions.
#[test]
fn refactor_matches_fresh_factor() {
    let mut rng = Rng::new(SEED_REFACTOR);
    for trial in 0..8 {
        let n = rng.range(30, 150);
        let extra = n * rng.range(2, 5);
        let a = random_dd(n, extra, &mut rng);

        // Perturb values (not structure): per-column positive scaling.
        let a2 = gen::restamp_columns(&a, &mut rng);

        // With scaling off, a fresh factor of `a2` reruns the whole
        // pipeline on identical inputs (matching is invariant under the
        // per-column scaling above), so even the LU value arrays must line
        // up entry-for-entry.
        let opts = GluOptions {
            scale: false,
            ..Default::default()
        };
        let mut via_refactor = GluSolver::factor(&a, &opts).unwrap();
        via_refactor.refactor(&a2).unwrap();
        let mut fresh = GluSolver::factor(&a2, &opts).unwrap();

        let lu_r = via_refactor.factors().lu.values();
        let lu_f = fresh.factors().lu.values();
        assert_eq!(lu_r.len(), lu_f.len(), "trial {trial}: fill changed");
        for (i, (p, q)) in lu_r.iter().zip(lu_f).enumerate() {
            assert!(
                (p - q).abs() <= 1e-10 * (1.0 + q.abs()),
                "trial {trial}: LU entry {i}: {p} vs {q}"
            );
        }

        let b: Vec<f64> = (0..n).map(|i| ((i * 5 % 11) as f64) - 5.0).collect();
        let xr = via_refactor.solve(&b).unwrap();
        let xf = fresh.solve(&b).unwrap();
        let d = rel_linf(&xr, &xf);
        assert!(d < 1e-10, "trial {trial}: solutions diverged by {d}");

        // Under the default options (equilibration on) the equilibration
        // factors of `a` and `a2` differ, so only the *solutions* are
        // comparable — still to 1e-10 on these well-conditioned systems.
        let mut vr = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        vr.refactor(&a2).unwrap();
        let mut fr = GluSolver::factor(&a2, &GluOptions::default()).unwrap();
        let d = rel_linf(&vr.solve(&b).unwrap(), &fr.solve(&b).unwrap());
        assert!(d < 1e-10, "trial {trial}: scaled solutions diverged by {d}");
    }
}

/// The plan-driven parallel right-looking engine — both the scatter-mapped
/// indexed hot path and the search-based baseline — against the
/// simulator-ordered engine, on a fixture engineered (by calibrating the
/// stream threshold and device warp budget to the observed level widths)
/// to hit all three kernel modes and the interleaved / ownership /
/// chain-batch CPU strategies: bit-identical at 1 thread, within 1e-12
/// componentwise at 2/4 threads. (The dominant-destination CAS strategy
/// has its own engineered fixtures in the `plan` and `parrl` unit tests.)
#[test]
fn plan_driven_parrl_matches_simulator_across_all_modes() {
    use glu3::depend::{glu3 as det3, levelize};
    use glu3::gpusim::{simulate_factorization, DeviceConfig, Policy};
    use glu3::numeric::{parrl, WorkerPool};
    use glu3::plan::{CpuAssignment, FactorPlan};
    use glu3::symbolic::symbolic_fill;

    let g = gen::grid2d(24, 24, 11);
    let p = glu3::order::amd::amd_order(&g).unwrap();
    let a = g.permute(p.as_scatter(), p.as_scatter());
    let f = symbolic_fill(&a).unwrap();
    let lv = levelize(&det3::detect(&f.filled));

    // Calibrate: pick three distinct observed level widths s1 < s2 < s3 and
    // shape the policy/device so s1 -> stream, s2 -> large (32*s2 warps /
    // s2 columns = 32), s3 -> small (fewer than 32 warps per column).
    let mut sizes: Vec<usize> = lv.levels.iter().map(|l| l.len()).collect();
    sizes.sort_unstable();
    sizes.dedup();
    assert!(sizes.len() >= 3, "fixture must offer 3 distinct level widths");
    let (s1, s2, s3) = (sizes[0], sizes[sizes.len() / 2], sizes[sizes.len() - 1]);
    assert!(s1 < s2 && s2 < s3);
    let mut device = DeviceConfig::titan_x();
    device.num_sms = s2;
    device.max_warps_per_sm = 32;
    let policy = Policy::glu3_with_threshold(s1);

    let plan = FactorPlan::from_levels(&f, lv.clone(), &policy, &device);
    let (hs, hl, hc) = plan.mode_histogram();
    assert!(
        hs > 0 && hl > 0 && hc > 0,
        "fixture must hit all three modes, got A/B/C {hs}/{hl}/{hc}"
    );
    // ...and the CPU strategies are actually scheduled: interleaved wide
    // levels, ownership-grouped sliced levels, chain-batched tails
    for want in [
        CpuAssignment::InterleavedColumns,
        CpuAssignment::OwnedDestinations,
        CpuAssignment::ChainBatch,
    ] {
        assert!(
            plan.cpu_steps().iter().any(|s| s.assignment == want),
            "strategy {want:?} missing from the plan"
        );
    }

    let (sim, rep) = simulate_factorization(&f, &lv, &policy, &device).unwrap();
    assert_eq!(rep.level_distribution(), (hs, hl, hc));

    for threads in [1usize, 2, 4] {
        let pool = WorkerPool::new(threads);
        let indexed = parrl::factor_with(&f, &plan, &pool).unwrap();
        let search = parrl::factor_with_search(&f, &plan, &pool).unwrap();
        for (i, ((p, s), q)) in indexed
            .lu
            .values()
            .iter()
            .zip(search.lu.values())
            .zip(sim.lu.values())
            .enumerate()
        {
            if threads == 1 {
                assert!(
                    p == q,
                    "1 thread indexed must be bit-identical at entry {i}: {p} vs {q}"
                );
                assert!(
                    s == q,
                    "1 thread search must be bit-identical at entry {i}: {s} vs {q}"
                );
            } else {
                assert!(
                    (p - q).abs() <= 1e-12 * (1.0 + q.abs()),
                    "threads {threads} entry {i}: indexed {p} vs {q}"
                );
                assert!(
                    (s - q).abs() <= 1e-12 * (1.0 + q.abs()),
                    "threads {threads} entry {i}: search {s} vs {q}"
                );
            }
        }
        // and the engine's factors actually solve the system
        let b: Vec<f64> = (0..a.nrows()).map(|i| ((i % 7) as f64) - 3.0).collect();
        let mut x = b.clone();
        glu3::numeric::trisolve::lower_unit_solve(&indexed.lu, &mut x);
        glu3::numeric::trisolve::upper_solve(&indexed.lu, &mut x);
        assert!(residual(&a, &x, &b) < 1e-10, "threads {threads}");
    }
}

/// Tridiagonal DD fixture: MC64 matching and natural ordering are both the
/// identity on it, so a diagonal zeroed at refactor time is *guaranteed* to
/// land on a pivot — the deterministic trigger for the robustness ladder —
/// while the zeroed-corner matrix stays provably nonsingular (repairable).
fn tridiag(n: usize) -> Csc {
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 4.0);
        if i + 1 < n {
            coo.push(i, i + 1, -1.0);
            coo.push(i + 1, i, -1.0);
        }
    }
    coo.to_csc()
}

/// Every numeric engine × thread count the crate offers, for the ladder
/// matrix (engines that ignore the thread knob appear once).
fn all_engines() -> Vec<glu3::glu::NumericEngine> {
    use glu3::glu::{ExecBackend, NumericEngine};
    let mut engines = vec![
        NumericEngine::SimulatedGpu,
        NumericEngine::LeftLookingCpu,
        NumericEngine::RightLookingCpu,
        NumericEngine::Schedule {
            backend: ExecBackend::Virtual,
        },
    ];
    for threads in [1usize, 2, 4] {
        engines.push(NumericEngine::ParallelCpu { threads });
        engines.push(NumericEngine::ParallelRightLooking { threads });
        engines.push(NumericEngine::Auto { threads });
    }
    engines
}

/// The numeric robustness ladder repairs a zero pivot *in place* on every
/// engine at every thread count: good → singular → good on one solver,
/// zero extra symbolic runs, acceptance residual after the repair.
#[test]
fn ladder_repairs_zero_pivot_on_every_engine() {
    use glu3::order::FillOrdering;

    let a = tridiag(72);
    let bad = gen::weaken_diagonal(&a, 72, 0.0); // A(0,0) = 0
    let b = vec![1.0; 72];
    for engine in all_engines() {
        let opts = GluOptions {
            ordering: FillOrdering::Natural,
            scale: false,
            engine: engine.clone(),
            ..Default::default()
        };
        let mut s = GluSolver::factor(&a, &opts).unwrap();
        s.refactor(&bad)
            .unwrap_or_else(|e| panic!("{engine:?}: ladder failed to repair: {e}"));
        let st = s.stats();
        assert_eq!(st.symbolic_runs, 1, "{engine:?}: symbolic rerun");
        assert_eq!(st.plan_builds, 1, "{engine:?}: replan");
        assert!(st.robustness.repairs >= 1, "{engine:?}: no repair recorded");
        let x = s.solve(&b).unwrap();
        let r = residual(&bad, &x, &b);
        assert!(r <= 1e-8, "{engine:?}: repaired residual {r}");

        // healthy values again: clean run, same cached state
        s.refactor(&a).unwrap();
        let x = s.solve(&b).unwrap();
        assert!(residual(&a, &x, &b) <= 1e-8, "{engine:?}: recovery");
        assert_eq!(s.stats().symbolic_runs, 1);
    }
}

/// Randomized adversarial restamps (tiny pivots, mis-scaled rows, heavy
/// value unsymmetry) against every engine: the refactor must either repair
/// — with the solver-recorded probe residual meeting tolerance and a sane
/// solve — or fail with the *typed* numeric classification; it must never
/// panic, never return an untyped error, and the cached pattern must
/// survive for the next healthy restamp either way.
#[test]
fn ladder_adversarial_restamps_repair_or_fail_typed() {
    use glu3::numeric::GluError;

    let engines = all_engines();
    let mut rng = Rng::new(SEED_LADDER);
    for (trial, engine) in engines.into_iter().enumerate() {
        let n = rng.range(40, 120);
        let base = random_dd(n, n * 3, &mut rng);
        let bad = match trial % 3 {
            0 => gen::weaken_diagonal(&base, 7, 1e-13),
            1 => gen::misscale_rows(&base, 11, 1e100),
            _ => gen::skew_unsymmetric(&base, 8.0, SEED_LADDER ^ trial as u64),
        };
        let opts = GluOptions {
            engine,
            ..Default::default()
        };
        let mut s = GluSolver::factor(&base, &opts).unwrap_or_else(|e| {
            panic!("seed {SEED_LADDER:#x} trial {trial} (n={n}): base factor failed: {e}")
        });
        let b = vec![1.0; n];
        match s.refactor(&bad) {
            Ok(()) => {
                let (repairs, probe, growth) = {
                    let rb = &s.stats().robustness;
                    (rb.repairs, rb.last_residual, rb.pivot_growth)
                };
                if repairs > 0 {
                    assert!(
                        probe <= 1e-9,
                        "trial {trial}: accepted repair above probe tolerance: {probe}"
                    );
                }
                let x = s.solve(&b).unwrap();
                assert!(x.iter().all(|v| v.is_finite()), "trial {trial}: non-finite x");
                let r = residual(&bad, &x, &b);
                // backward-error-consistent bound: a clean rung-0 pass may
                // carry element growth up to the gate limit, which costs
                // digits legitimately; garbage factors cannot hide under it
                let bound = (growth.max(1.0) * 1e-13).max(1e-7);
                assert!(
                    r <= bound,
                    "seed {SEED_LADDER:#x} trial {trial}: residual {r} (growth {growth:.2e})"
                );
            }
            Err(e) => {
                assert!(
                    e.downcast_ref::<GluError>().is_some(),
                    "seed {SEED_LADDER:#x} trial {trial}: untyped numeric failure: {e:#}"
                );
            }
        }

        // Either way the cached symbolic state must serve the next healthy
        // stamp without rerunning the pattern phases (a rung-5 rescue is
        // the one legitimate extra symbolic pass: it rebuilds the pipeline
        // once, on the rescued row order).
        s.refactor(&base).unwrap_or_else(|e| {
            panic!("seed {SEED_LADDER:#x} trial {trial}: healthy restamp failed: {e}")
        });
        let expect_sym = 1 + s.stats().robustness.rescues as usize;
        assert_eq!(s.stats().symbolic_runs, expect_sym, "trial {trial}");
        let x = s.solve(&b).unwrap();
        assert!(x.iter().all(|v| v.is_finite()), "trial {trial}: recovery x");
        assert!(
            residual(&base, &x, &b) <= 1e-3,
            "seed {SEED_LADDER:#x} trial {trial}: recovery residual"
        );
    }
}

/// Rung 5 across the whole engine matrix: on the pivot-order-killer
/// generators the fixed-order ladder exhausts deterministically (their
/// zeroed diagonals survive perturbation and re-equilibration), so every
/// engine × thread count must take the threshold partial-pivoting rescue —
/// and the rescued factors must match the dense oracle, with the follow-up
/// refactor staying on the fast path (no second rescue, no symbolic rerun).
#[test]
fn pivot_rescue_succeeds_on_every_engine() {
    use glu3::order::FillOrdering;

    let cases = [
        ("zero-diagonal-band", gen::zero_diagonal_band(96, 48, 20260808)),
        ("shuffle-rows", gen::shuffle_rows(96, 48, 5)),
    ];
    for (label, a) in &cases {
        let n = a.nrows();
        // Healthy twin: same pattern, diagonally dominant values, so the
        // cold factor pins the matching/ordering the adversarial restamp
        // will then break.
        let twin = gen::dominant_restamp(a, 7);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let oracle =
            glu3::numeric::dense::solve(&a.to_dense(), n, &b).expect("dense oracle solve");
        for engine in all_engines() {
            let opts = GluOptions {
                ordering: FillOrdering::Natural,
                scale: false,
                engine: engine.clone(),
                ..Default::default()
            };
            let mut s = GluSolver::factor(&twin, &opts)
                .unwrap_or_else(|e| panic!("{label} {engine:?}: twin factor failed: {e}"));
            s.refactor(a)
                .unwrap_or_else(|e| panic!("{label} {engine:?}: rescue failed: {e:#}"));
            let st = s.stats();
            assert_eq!(st.robustness.rescues, 1, "{label} {engine:?}: rescue count");
            assert!(
                st.robustness.rescued_pivots >= 1,
                "{label} {engine:?}: no pivot swaps recorded"
            );
            assert!(st.robustness.rescue_ms >= 0.0, "{label} {engine:?}");
            assert_eq!(st.symbolic_runs, 2, "{label} {engine:?}: rescue rebuild");
            assert_eq!(st.plan_builds, 2, "{label} {engine:?}: rescue replan");
            let x = s.solve(&b).unwrap();
            let r = residual(a, &x, &b);
            assert!(r <= 1e-9, "{label} {engine:?}: rescued residual {r}");
            let d = rel_linf(&x, &oracle);
            assert!(d <= 1e-9, "{label} {engine:?}: oracle drift {d}");

            // Restamp the same adversarial values: the rescued row order is
            // now the installed order, so this must be a plain fast-path
            // refactor — no second rescue, no extra symbolic pass.
            s.refactor(a)
                .unwrap_or_else(|e| panic!("{label} {engine:?}: post-rescue refactor: {e:#}"));
            assert_eq!(s.stats().robustness.rescues, 1, "{label} {engine:?}: re-rescued");
            assert_eq!(s.stats().symbolic_runs, 2, "{label} {engine:?}: symbolic rerun");
            let x = s.solve(&b).unwrap();
            let r = residual(a, &x, &b);
            assert!(r <= 1e-9, "{label} {engine:?}: post-rescue residual {r}");
        }
    }
}

/// Adversarial: a corrupted ScatterMap — destinations rerouted, multiplier
/// indices shifted, runs truncated — is rejected by the debug-mode
/// validation pass before any indexed store could land on the wrong
/// element.
#[test]
fn corrupted_scatter_map_is_rejected() {
    use glu3::depend::{glu3 as det3, levelize};
    use glu3::gpusim::{DeviceConfig, Policy};
    use glu3::plan::FactorPlan;
    use glu3::symbolic::symbolic_fill;

    let a = gen::netlist(150, 5, 10, 0.08, 2, 0.2, 1234);
    let f = symbolic_fill(&a).unwrap();
    let lv = levelize(&det3::detect(&f.filled));
    let plan =
        FactorPlan::from_levels(&f, lv, &Policy::glu3(), &DeviceConfig::titan_x());
    let urow = plan.urow();
    let sm = plan.scatter(&f.filled);
    sm.validate(&f.filled, urow).expect("honest map validates");
    assert!(!sm.dst.is_empty(), "fixture must have MAC work");

    // Reroute one destination onto a neighbouring value slot: the row it
    // now addresses no longer matches the source's L row.
    let mut bad = sm.clone();
    bad.dst[bad.dst.len() / 2] = bad.diag_idx[0];
    assert!(bad.validate(&f.filled, urow).is_err());

    // Shift a multiplier index off its row.
    let mut bad = sm.clone();
    bad.mult_idx[0] = bad.mult_idx[0].wrapping_add(1);
    assert!(bad.validate(&f.filled, urow).is_err());

    // Truncate the destination runs.
    let mut bad = sm.clone();
    bad.dst.truncate(bad.dst.len() - 1);
    assert!(bad.validate(&f.filled, urow).is_err());

    // Lie about a column's L length (runs would overlap).
    let mut bad = sm.clone();
    let j = (0..bad.l_len.len())
        .find(|&j| bad.l_len[j] > 0)
        .expect("some column has L entries");
    bad.l_len[j] -= 1;
    assert!(bad.validate(&f.filled, urow).is_err());
}

// ---------------------------------------------------------------------------
// Symbolic tier: parallel ≡ serial and incremental-patch ≡ fresh.
// ---------------------------------------------------------------------------

const SEED_SYMBOLIC_DELTA: u64 = 0x5E11_0001;

/// The three pattern families of the symbolic bit-identity sweep: an
/// AMD-ordered mesh (the solver's own preprocessing), an RCM-ordered band
/// matrix, and an unstructured random diagonally dominant pattern.
fn symbolic_fixtures() -> Vec<(&'static str, Csc)> {
    let grid = gen::grid2d(14, 12, 3);
    let p = glu3::order::amd::amd_order(&grid).unwrap();
    let amd_grid = grid.permute(p.as_scatter(), p.as_scatter());

    let band = gen::netlist(180, 6, 10, 0.05, 2, 0.2, 21);
    let p = glu3::order::rcm::rcm_order(&band).unwrap();
    let rcm_band = band.permute(p.as_scatter(), p.as_scatter());

    let mut rng = Rng::new(SEED_RANDOM_DD ^ 0x51);
    let random = random_dd(160, 640, &mut rng);

    vec![("amd-grid", amd_grid), ("rcm-band", rcm_band), ("random-dd", random)]
}

/// Wave-parallel fill discovery is bit-identical to the serial
/// Gilbert–Peierls pass — filled pattern, values, fill count, dependency
/// graph, and level sets — at every thread count, on every fixture family.
#[test]
fn parallel_symbolic_is_bit_identical_to_serial() {
    use glu3::depend::{glu3 as det3, levelize};
    use glu3::numeric::WorkerPool;
    use glu3::symbolic::{parallel_symbolic, symbolic_fill, FillWorkspace};

    for (label, a) in symbolic_fixtures() {
        let sym = symbolic_fill(&a).unwrap();
        let deps = det3::detect(&sym.filled);
        let levels = levelize(&deps);
        let mut ws = FillWorkspace::new();
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let par = parallel_symbolic(&a, &pool, &mut ws).unwrap();
            assert_eq!(
                par.sym.filled, sym.filled,
                "{label} @{threads}t: filled pattern/values diverged"
            );
            assert_eq!(par.sym.fill_count, sym.fill_count, "{label} @{threads}t");
            assert_eq!(par.deps, deps, "{label} @{threads}t: dependency graph");
            assert_eq!(par.levels, levels, "{label} @{threads}t: level sets");
        }
    }
}

/// Patching a cached pattern against a randomized 1–2 column structural
/// delta is bit-identical to fresh symbolic analysis of the new matrix —
/// pattern, values, dependency graph, and levels.
#[test]
fn incremental_patch_is_bit_identical_to_fresh() {
    use glu3::depend::{glu3 as det3, levelize};
    use glu3::symbolic::{changed_columns, patch_symbolic, symbolic_fill, FillWorkspace};

    let mut rng = Rng::new(SEED_SYMBOLIC_DELTA);
    for (label, a) in symbolic_fixtures() {
        let n = a.ncols();
        let base = symbolic_fill(&a).unwrap();
        let mut ws = FillWorkspace::new();
        for trial in 0..6 {
            // 1 or 2 extra entries at random absent coordinates
            let mut a2 = a.clone();
            for _ in 0..1 + (trial % 2) {
                loop {
                    let r = rng.below(n);
                    let c = rng.below(n);
                    if r != c && a2.get(r, c) == 0.0 {
                        a2 = gen::with_entry(&a2, r, c, rng.range_f64(-0.01, 0.01));
                        break;
                    }
                }
            }
            let changed = changed_columns(a.colptr(), a.rowidx(), &a2, n)
                .expect("delta within budget");
            assert!(!changed.is_empty() && changed.len() <= 2, "{label} trial {trial}");
            let patch = patch_symbolic(&base, &a2, &changed, &mut ws).unwrap();

            let fresh = symbolic_fill(&a2).unwrap();
            let deps = det3::detect(&fresh.filled);
            let levels = levelize(&deps);
            assert_eq!(
                patch.sym.filled, fresh.filled,
                "{label} trial {trial} (seed {SEED_SYMBOLIC_DELTA:#x}): pattern"
            );
            assert_eq!(patch.sym.fill_count, fresh.fill_count, "{label} trial {trial}");
            assert_eq!(patch.deps, deps, "{label} trial {trial}: dependency graph");
            assert_eq!(patch.levels, levels, "{label} trial {trial}: levels");
            assert!(
                patch.recomputed >= changed.len(),
                "{label} trial {trial}: taint closure must cover the changed columns"
            );
        }
    }
}

/// Solver-level incremental factorization: `factor_delta` off a snapshot of
/// the base pattern solves the perturbed system to the same accuracy as a
/// cold `factor`, while reporting zero symbolic runs and one patch.
#[test]
fn factor_delta_matches_cold_factor() {
    use glu3::symbolic::FillWorkspace;

    let a = gen::grid2d(13, 11, 9);
    let n = a.nrows();
    let opts = GluOptions::default();
    let base = GluSolver::factor(&a, &opts).unwrap();
    let snap = base.symbolic_snapshot();

    // a one-entry structural delta (absent coordinate, modest value)
    assert_eq!(a.get(9, 2), 0.0, "fixture needs an absent coordinate");
    let a2 = gen::with_entry(&a, 9, 2, -1e-2);
    let changed = vec![2u32];

    let mut ws = FillWorkspace::new();
    let mut patched = GluSolver::factor_delta(&a2, &opts, &snap, &changed, &mut ws).unwrap();
    let mut cold = GluSolver::factor(&a2, &opts).unwrap();

    let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
    let xp = patched.solve(&b).unwrap();
    let xc = cold.solve(&b).unwrap();
    assert!(residual(&a2, &xp, &b) < 1e-8, "patched residual");
    assert!(residual(&a2, &xc, &b) < 1e-8, "cold residual");
    assert!(rel_linf(&xp, &xc) < 1e-8, "solutions must agree");

    let st = patched.stats();
    assert_eq!(st.symbolic_runs, 0, "patch must not rerun symbolic analysis");
    assert_eq!(st.incremental_patches, 1);
    assert_eq!(st.plan_builds, 1);
    assert_eq!(st.detect_ms, 0.0);
    assert_eq!((st.symbolic_ms - st.fillin_ms).abs(), 0.0);
}

// ---------------------------------------------------------------------------
// Batched value-plane refactor and blocked multi-RHS trisolve tiers
// ---------------------------------------------------------------------------

/// `k` independent tridiagonal chains of length `m`, block-diagonal: under
/// the natural order the triangular row schedules have exactly `m` levels
/// of width `k` — a dial for forcing each trisolve variant.
fn chains(k: usize, m: usize) -> Csc {
    let n = k * m;
    let mut coo = Coo::new(n, n);
    for c in 0..k {
        for i in 0..m {
            let r = c * m + i;
            coo.push(r, r, 4.0);
            if i + 1 < m {
                coo.push(r + 1, r, -1.0);
                coo.push(r, r + 1, -1.0);
            }
        }
    }
    coo.to_csc()
}

/// Batched `refactor_batch` ≡ `B` looped `refactor`s across every engine
/// with a batched kernel (plus the looped-fallback simulator), thread
/// counts {1, 2, 4}, and batch sizes {1, 4, 16}: bit-identical where the
/// kernel is deterministic (one worker thread, the schedule executor,
/// the fallback), ≤ 1e-12 relative under CAS-racing multi-thread parrl.
#[test]
fn batched_refactor_matches_looped_refactors() {
    use glu3::glu::{ExecBackend, NumericEngine};

    let a = gen::grid2d(20, 20, 11);
    let mut engines = vec![
        (NumericEngine::SimulatedGpu, true), // no batched kernel: loops
        (
            NumericEngine::Schedule {
                backend: ExecBackend::Virtual,
            },
            true, // plane-inner interpreter, ascending columns: exact
        ),
    ];
    for threads in [1usize, 2, 4] {
        engines.push((
            NumericEngine::ParallelRightLooking { threads },
            threads == 1,
        ));
    }
    for (engine, exact) in engines {
        for bsz in [1usize, 4, 16] {
            let mats: Vec<Csc> = (0..bsz)
                .map(|p| {
                    let mut m = a.clone();
                    for v in m.values_mut() {
                        *v *= 1.0 + 0.05 * (p as f64 + 1.0);
                    }
                    m
                })
                .collect();
            let refs: Vec<&Csc> = mats.iter().collect();
            let opts = GluOptions {
                engine: engine.clone(),
                ..Default::default()
            };
            let mut batched = GluSolver::factor(&a, &opts).unwrap();
            let planes = batched.refactor_batch(&refs).unwrap();
            assert_eq!(planes.planes(), bsz);

            let mut looped = GluSolver::factor(&a, &opts).unwrap();
            for (p, m) in mats.iter().enumerate() {
                looped.refactor(m).unwrap();
                let plane = planes.plane(p);
                let want = looped.factors().lu.values();
                if exact {
                    assert_eq!(
                        plane.as_slice(),
                        want,
                        "{engine:?} B={bsz} plane {p} must be bit-identical"
                    );
                } else {
                    for (x, y) in plane.iter().zip(want) {
                        assert!(
                            (x - y).abs() <= 1e-12 * (1.0 + y.abs()),
                            "{engine:?} B={bsz} plane {p}: {x} vs {y}"
                        );
                    }
                }
            }
            // The batch installs its last plane as the current factors.
            assert_eq!(planes.plane(bsz - 1), batched.factors().lu.values());
            // Run accounting matches the looped path exactly.
            assert_eq!(batched.stats().numeric_runs, looped.stats().numeric_runs);
            assert_eq!(batched.stats().symbolic_runs, 1);
            assert_eq!(batched.stats().plan_builds, 1);
        }
    }
}

/// The blocked multi-RHS solve agrees bit-for-bit with the sequential
/// engine across thread counts and batch sizes on patterns chosen to
/// force each trisolve variant: deep-and-wide chains (sync-free),
/// shallow-and-wide chains (level-set), a single narrow chain
/// (sequential). The variant actually run is pinned via
/// `GluStats::trisolve_variant`.
#[test]
fn solve_variants_agree_and_cover_all_three() {
    use glu3::glu::NumericEngine;
    use glu3::order::FillOrdering;

    let cases = vec![
        ("deep-wide", chains(16, 64), "sync-free"), // 64 levels ≥ 48, width 16
        ("shallow-wide", chains(24, 24), "level-set"), // 24 levels, width 24
        ("narrow", tridiag(120), "sequential"),     // width 1: not worthwhile
    ];
    for (name, a, expect) in cases {
        let n = a.nrows();
        let seq_opts = GluOptions {
            ordering: FillOrdering::Natural,
            scale: false,
            engine: NumericEngine::LeftLookingCpu,
            ..Default::default()
        };
        let mut seq = GluSolver::factor(&a, &seq_opts).unwrap();
        for threads in [1usize, 2, 4] {
            let opts = GluOptions {
                ordering: FillOrdering::Natural,
                scale: false,
                engine: NumericEngine::ParallelCpu { threads },
                ..Default::default()
            };
            let mut par = GluSolver::factor(&a, &opts).unwrap();
            for bsz in [1usize, 4, 16] {
                let rhs: Vec<Vec<f64>> = (0..bsz)
                    .map(|k| {
                        (0..n)
                            .map(|i| ((i * 13 + k * 7) % 17) as f64 - 8.0)
                            .collect()
                    })
                    .collect();
                let xs = seq.solve_many(&rhs).unwrap();
                let xp = par.solve_many(&rhs).unwrap();
                assert_eq!(xs, xp, "{name} @{threads}t B={bsz}");
                // the blocked walk replays the single-RHS op order exactly
                for (b, x) in rhs.iter().zip(&xp) {
                    assert_eq!(&par.solve(b).unwrap(), x, "{name} blocked vs single");
                }
            }
            let got = par.stats().trisolve_variant;
            if threads == 1 {
                assert_eq!(got, "sequential", "{name}: 1-thread pool stays sequential");
            } else {
                assert_eq!(got, expect, "{name} @{threads}t picked the wrong variant");
            }
        }
    }
}
