//! Smoke tests for the wall-clock numeric bench harness (`glu3 bench`):
//! the JSON report covers every engine and validates, and on the
//! acceptance fixture (100×100 AMD-ordered grid, 4 threads) the
//! persistent-pool `parlu` beats the seed's per-level-spawn baseline by
//! the required ≥ 2× wall-clock.

use std::sync::Mutex;

use glu3::bench_support::numeric::{
    batched_report, refactor_loop, run, spawn_vs_pool, symbolic_report, validate_json_schema,
    BenchSpec,
};

/// The tests in this binary all measure wall-clock while spawning thread
/// pools; run them serially so none perturbs the others' timing (the
/// harness otherwise runs same-binary tests in parallel).
static BENCH_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn bench_smoke_report_covers_all_engines_and_validates() {
    let _serial = BENCH_LOCK.lock().unwrap();
    let spec = BenchSpec::smoke();
    let report = run(&spec).expect("smoke bench");

    for engine in ["simulated-gpu", "leftlook", "rightlook", "schedule", "parlu", "parrl"] {
        let rows: Vec<_> = report.samples.iter().filter(|s| s.engine == engine).collect();
        assert!(!rows.is_empty(), "engine {engine} missing from the report");
        for r in rows {
            assert!(
                r.factor_ms.is_finite() && r.factor_ms >= 0.0,
                "{engine}: factor_ms"
            );
            assert!(
                r.refactor_ms.is_finite() && r.refactor_ms >= 0.0,
                "{engine}: refactor_ms"
            );
            assert!(
                r.solve_ms.is_finite() && r.solve_ms >= 0.0,
                "{engine}: solve_ms"
            );
        }
    }
    // parallel engines appear once per requested thread count
    for engine in ["parlu", "parrl"] {
        let threads: Vec<usize> = report
            .samples
            .iter()
            .filter(|s| s.engine == engine)
            .map(|s| s.threads)
            .collect();
        assert_eq!(threads, spec.thread_counts, "{engine} thread sweep");
    }

    // the v2 plan block: histogram covers every level, timings are sane
    let p = &report.plan;
    assert!(p.levels > 1, "smoke fixture must be multi-level");
    assert_eq!(
        p.modes_small + p.modes_large + p.modes_stream,
        p.levels,
        "mode histogram must cover every level"
    );
    for v in [p.build_ms, p.symbolic_ms, p.fillin_ms, p.detect_ms, p.levelize_ms] {
        assert!(v.is_finite() && v >= 0.0, "plan timing {v}");
    }
    // v6 semantics: symbolic_ms is the whole phase, fill a component of it
    assert!(
        (p.symbolic_ms - (p.fillin_ms + p.detect_ms + p.levelize_ms)).abs() < 1e-9,
        "symbolic_ms must equal fill + detect + levelize"
    );

    // the v3 refactor_loop block: per-iteration arrays the right length,
    // sane timings, the head-to-head medians present
    let rl = &report.refactor_loop;
    assert_eq!(rl.threads, *spec.thread_counts.iter().max().unwrap());
    assert!(rl.iterations >= 1);
    assert_eq!(rl.indexed_ms.len(), rl.iterations);
    assert_eq!(rl.search_ms.len(), rl.iterations);
    for v in rl
        .indexed_ms
        .iter()
        .chain(&rl.search_ms)
        .chain([rl.scatter_build_ms].iter())
    {
        assert!(v.is_finite() && *v >= 0.0, "refactor_loop timing {v}");
    }
    assert!(rl.indexed_median_ms() >= 0.0 && rl.search_median_ms() >= 0.0);

    // the v4 schedule block: one entry per level, cycle arrays aligned,
    // totals consistent — the executed-vs-simulated reconciliation the
    // executor feeds back per level
    let sc = &report.schedule;
    assert_eq!(sc.levels, p.levels, "schedule covers every plan level");
    assert_eq!(sc.executed_cycles.len(), sc.levels);
    assert_eq!(sc.simulated_cycles.len(), sc.levels);
    assert!(sc.total_launches >= sc.levels as u64);
    assert!(!sc.kernels.is_empty(), "schedule must name its artifacts");
    assert_eq!(sc.executed_total(), sc.executed_cycles.iter().sum::<u64>());
    assert_eq!(
        sc.cycle_delta(),
        sc.simulated_total() as i64 - sc.executed_total() as i64
    );
    assert!(sc.executed_total() > 0 && sc.simulated_total() > 0);

    // the v5 robustness block: the repair ladder fired on the deterministic
    // singular refactor and repaired it in place within probe tolerance
    let rb = &report.robustness;
    assert!(rb.repairs >= 1, "robustness fixture must record a repair");
    assert!(rb.perturbations >= 1, "rung 1 must fire on the zeroed pivot");
    assert_eq!(rb.escalations, 0, "the tridiagonal fixture must not escalate");
    assert!(
        rb.probe_residual.is_finite() && rb.probe_residual <= 1e-9,
        "repair accepted above probe tolerance: {}",
        rb.probe_residual
    );
    assert!(rb.pivot_growth.is_finite() && rb.pivot_growth > 0.0);
    assert!(rb.condition_estimate >= 1.0);

    // the v6 symbolic block: one parallel sample per thread count, the
    // delta fixture touched exactly one column, timings sane
    let sy = &report.symbolic;
    assert_eq!(sy.threads, spec.thread_counts, "symbolic thread sweep");
    assert_eq!(sy.parallel_ms.len(), sy.threads.len());
    for v in sy
        .parallel_ms
        .iter()
        .chain([sy.serial_ms, sy.cold_ms, sy.incremental_ms].iter())
    {
        assert!(v.is_finite() && *v > 0.0, "symbolic timing {v}");
    }
    assert_eq!(sy.changed_columns, 1, "fill-envelope delta touches one column");
    assert_eq!(sy.recomputed_columns, 1, "in-envelope delta must not cascade");

    // the v7 rescue block: the fixed-order ladder exhausted exactly once
    // into the rung-5 pivot rescue, and the rescued order refactors at
    // fast-path cost afterwards
    let rs = &report.rescue;
    assert_eq!(rs.rescues, 1, "rescue fixture must record one rescue");
    assert!(rs.swapped_pivots >= 1, "a rescue must swap pivots");
    assert!(rs.rescue_ms.is_finite() && rs.rescue_ms >= 0.0);
    assert!(rs.refactor_ms.is_finite() && rs.refactor_ms >= 0.0);
    assert!(
        rs.residual.is_finite() && rs.residual <= 1e-9,
        "rescued residual above probe tolerance: {}",
        rs.residual
    );

    // the v8 batched block: one looped/batched pair per batch size for
    // both the value-plane refactor and the blocked multi-RHS solve, plus
    // the trisolve-variant histogram the solvers reported
    let bt = &report.batched;
    assert_eq!(bt.threads, *spec.thread_counts.iter().max().unwrap());
    assert!(!bt.batch_sizes.is_empty(), "batched sweep must run");
    assert_eq!(bt.looped_refactor_ms.len(), bt.batch_sizes.len());
    assert_eq!(bt.batched_refactor_ms.len(), bt.batch_sizes.len());
    assert_eq!(bt.looped_solve_ms.len(), bt.batch_sizes.len());
    assert_eq!(bt.batched_solve_ms.len(), bt.batch_sizes.len());
    for v in bt
        .looped_refactor_ms
        .iter()
        .chain(&bt.batched_refactor_ms)
        .chain(&bt.looped_solve_ms)
        .chain(&bt.batched_solve_ms)
    {
        assert!(v.is_finite() && *v > 0.0, "batched timing {v}");
    }
    assert_eq!(bt.variant_labels.len(), bt.variant_counts.len());
    assert!(
        !bt.variant_labels.is_empty(),
        "at least one trisolve variant must be recorded"
    );

    let json = report.to_json();
    validate_json_schema(&json).expect("well-formed report");
    assert!(json.contains("\"plan\""), "plan block must be emitted");
    assert!(json.contains("\"mode_histogram\""));
    assert!(json.contains("\"refactor_loop\""), "v3 block must be emitted");
    assert!(json.contains("\"schedule\""), "v4 block must be emitted");
    assert!(json.contains("\"robustness\""), "v5 block must be emitted");
    assert!(json.contains("\"symbolic\""), "v6 block must be emitted");
    assert!(json.contains("\"rescue\""), "v7 block must be emitted");
    assert!(json.contains("\"batched\""), "v8 block must be emitted");
    assert!(json.contains("\"trisolve_variants\""));

    // and the file artifact round-trips
    let path = std::env::temp_dir().join("BENCH_numeric_smoke_test.json");
    let path = path.to_str().expect("utf-8 temp path");
    report.write_json(path).expect("write BENCH_numeric.json");
    let back = std::fs::read_to_string(path).expect("read back");
    assert_eq!(back, json);
    validate_json_schema(&back).unwrap();
    let _ = std::fs::remove_file(path);
}

#[test]
fn pool_parlu_beats_per_level_spawn_baseline_2x_on_acceptance_fixture() {
    // 100×100 AMD-ordered grid2d at 4 threads: same schedule, same column
    // kernel — the measured gap is the per-level spawn/join (plus its
    // per-level workspace allocation) the persistent pool eliminates.
    let _serial = BENCH_LOCK.lock().unwrap();
    let spec = BenchSpec::acceptance();
    assert_eq!(spec.thread_counts.iter().copied().max(), Some(4));
    let baseline = spawn_vs_pool(&spec).expect("head-to-head");
    assert_eq!(baseline.threads, 4);
    assert!(
        baseline.speedup() >= 2.0,
        "persistent pool must beat per-level spawn ≥ 2x: spawn {:.2} ms vs pool {:.2} ms ({:.2}x)",
        baseline.spawn_per_level_ms,
        baseline.pool_ms,
        baseline.speedup()
    );
}

/// The PR-4 acceptance bar: on the 100×100 AMD-ordered grid at 4 threads,
/// repeated refactorizations through the scatter-mapped indexed engine run
/// ≥ 1.5× faster than the search-based baseline — same plan, same pool,
/// same values; the gap is purely the removed per-refactor position
/// searching and the CAS traffic the ownership partitioning eliminates.
#[test]
fn indexed_refactor_beats_search_baseline_on_acceptance_fixture() {
    let _serial = BENCH_LOCK.lock().unwrap();
    let spec = BenchSpec::acceptance();
    let rl = refactor_loop(&spec).expect("refactor loop");
    assert_eq!(rl.threads, 4);
    assert!(
        rl.atomic_commits_avoided > 0,
        "the grid plan must schedule ownership/chain levels"
    );
    assert!(
        rl.speedup() >= 1.5,
        "indexed refactor must beat the search baseline ≥ 1.5x: \
         indexed {:.2} ms vs search {:.2} ms ({:.2}x)",
        rl.indexed_median_ms(),
        rl.search_median_ms(),
        rl.speedup()
    );
}

/// The v6 acceptance bars: on the 100×100 AMD-ordered grid, (1) the
/// wave-parallel symbolic phase at 4 threads is at least as fast as the
/// serial pass (no regression from parallelizing — the win grows with the
/// matrix), and (2) the incremental patch on a one-entry delta beats the
/// cold symbolic pipeline by ≥ 5× (it recomputes one column out of 10 000).
#[test]
fn symbolic_fast_paths_hold_on_acceptance_fixture() {
    let _serial = BENCH_LOCK.lock().unwrap();
    let spec = BenchSpec::acceptance();
    let sy = symbolic_report(&spec).expect("symbolic report");
    assert_eq!(sy.threads.iter().copied().max(), Some(4));
    assert!(
        sy.speedup_parallel() >= 1.0,
        "parallel symbolic @4t must not lose to serial: serial {:.2} ms vs \
         parallel {:.2} ms ({:.2}x)",
        sy.serial_ms,
        sy.parallel_ms.last().unwrap(),
        sy.speedup_parallel()
    );
    assert!(
        sy.speedup_incremental() >= 5.0,
        "incremental patch must beat cold symbolic ≥ 5x: cold {:.2} ms vs \
         patch {:.3} ms ({:.2}x)",
        sy.cold_ms,
        sy.incremental_ms,
        sy.speedup_incremental()
    );
    assert_eq!(sy.recomputed_columns, 1);
}

/// The v8 acceptance bar: on the 100×100 AMD-ordered grid at 4 threads,
/// refactoring a batch of 16 value planes through one schedule walk runs
/// ≥ 1.3× faster than 16 looped single-plane refactors — same pattern,
/// same plan, same pool; the gap is the amortized launch sequence and the
/// per-task gather/scatter paid once instead of B times.
#[test]
fn batched_refactor_beats_looped_on_acceptance_fixture() {
    let _serial = BENCH_LOCK.lock().unwrap();
    let spec = BenchSpec::acceptance();
    let bt = batched_report(&spec).expect("batched report");
    assert_eq!(bt.threads, 4);
    assert_eq!(bt.max_batch(), 16, "sweep must reach B=16");
    assert!(
        bt.refactor_speedup(16) >= 1.3,
        "batched refactor must beat the looped baseline ≥ 1.3x at B=16: \
         looped {:.2} ms vs batched {:.2} ms ({:.2}x)",
        bt.looped_refactor_ms.last().unwrap(),
        bt.batched_refactor_ms.last().unwrap(),
        bt.refactor_speedup(16)
    );
    // the blocked multi-RHS solve must at minimum not lose to the loop
    assert!(
        bt.solve_speedup(16) >= 1.0,
        "blocked solve_many must not lose to looped solves at B=16: \
         looped {:.2} ms vs blocked {:.2} ms ({:.2}x)",
        bt.looped_solve_ms.last().unwrap(),
        bt.batched_solve_ms.last().unwrap(),
        bt.solve_speedup(16)
    );
}
