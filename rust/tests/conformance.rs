//! Conformance tier: the three-way differential matrix that keeps every
//! backend of the execution layer honest, plus golden-pattern regression
//! fixtures pinning the symbolic pipeline.
//!
//! The three paths that must agree on L/U values:
//!
//! 1. [`glu3::gpusim::simulate_factorization`] — the cycle simulator's
//!    level-ordered numerics (the reference serialization);
//! 2. [`glu3::runtime::VirtualDevice`] — the schedule executor
//!    interpreting the lowered [`glu3::runtime::LaunchSchedule`] from the
//!    uploaded scatter index buffers (**bit-identical** to 1, always);
//! 3. [`glu3::numeric::parrl`] — the indexed worker-pool engine
//!    (bit-identical at 1 thread, ≤ 1e-12 componentwise at 2/4 threads).
//!
//! The matrix runs across {AMD-ordered grid with a policy/device
//! calibrated to hit all three kernel modes, RCM-ordered band,
//! random diagonally dominant} × {1, 2, 4} threads, and also asserts the
//! per-level mode histogram is identical across all three paths.
//!
//! A fourth, batched row covers the value-plane kernels: the
//! `VirtualDevice`'s one-walk [`DeviceExecutor::execute_planes`] and
//! `parrl`'s [`glu3::numeric::parrl::refactor_planes`] against per-plane
//! looped execution on the same fixtures (bit-identical for the
//! executor and 1-thread parrl, ≤ 1e-12 at 2/4 threads).
//!
//! Tier layout: see `rust/tests/README.md`.

use std::collections::BTreeMap;

use glu3::depend::{glu3 as det3, levelize};
use glu3::gpusim::{simulate_factorization, DeviceConfig, Policy};
use glu3::numeric::{parrl, residual, PivotMonitor, WorkerPool};
use glu3::plan::FactorPlan;
use glu3::runtime::{lower_plan, DeviceExecutor, VirtualDevice};
use glu3::sparse::{Coo, Csc};
use glu3::symbolic::symbolic_fill;
use glu3::util::Rng;

/// Explicit RNG seed for the random-DD fixture — appears in assertion
/// messages via the fixture name so failures replay exactly.
const RANDOM_DD_SEED: u64 = 0xC0DE_0001;

/// Random sparse matrix with unique coordinates and a column diagonally
/// dominant diagonal (the pivot-free GLU regime).
fn random_dd(n: usize, extra: usize, rng: &mut Rng) -> Csc {
    let mut coo = Coo::new(n, n);
    let mut colsum = vec![0.0f64; n];
    let mut used = std::collections::HashSet::new();
    let mut placed = 0usize;
    while placed < extra {
        let r = rng.below(n);
        let c = rng.below(n);
        if r == c || !used.insert((r, c)) {
            continue;
        }
        let v = rng.range_f64(-1.0, 1.0);
        coo.push(r, c, v);
        colsum[c] += v.abs();
        placed += 1;
    }
    for d in 0..n {
        coo.push(d, d, colsum[d] + rng.range_f64(0.5, 1.5));
    }
    coo.to_csc()
}

struct Fixture {
    name: &'static str,
    a: Csc,
    policy: Policy,
    device: DeviceConfig,
    /// The calibrated fixture must exercise all three kernel modes.
    require_all_modes: bool,
}

fn fixtures() -> Vec<Fixture> {
    let mut out = Vec::new();

    // AMD-ordered mesh with the policy/device calibrated to the observed
    // level widths so the plan hits all three kernel modes (the same
    // calibration trick as tests/property.rs): the smallest width becomes
    // the stream threshold, the median width gets exactly 32 warps per
    // column (large), wider levels get fewer (small).
    {
        let g = glu3::sparse::gen::grid2d(24, 24, 11);
        let p = glu3::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&det3::detect(&f.filled));
        let mut sizes: Vec<usize> = lv.levels.iter().map(|l| l.len()).collect();
        sizes.sort_unstable();
        sizes.dedup();
        assert!(sizes.len() >= 3, "mesh must offer 3 distinct level widths");
        let (s1, s2) = (sizes[0], sizes[sizes.len() / 2]);
        let mut device = DeviceConfig::titan_x();
        device.num_sms = s2;
        device.max_warps_per_sm = 32;
        out.push(Fixture {
            name: "amd-grid-24x24",
            a,
            policy: Policy::glu3_with_threshold(s1),
            device,
            require_all_modes: true,
        });
    }

    // RCM-ordered band: a long, narrow profile — deep schedules, heavy
    // stream/chain tails under the default policy.
    {
        let g = glu3::sparse::gen::grid2d(18, 18, 7);
        let p = glu3::order::rcm::rcm_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        out.push(Fixture {
            name: "rcm-band-18x18",
            a,
            policy: Policy::glu3(),
            device: DeviceConfig::titan_x(),
            require_all_modes: false,
        });
    }

    // Random diagonally dominant: irregular structure, no ordering.
    {
        let mut rng = Rng::new(RANDOM_DD_SEED);
        let a = random_dd(160, 640, &mut rng);
        out.push(Fixture {
            name: "random-dd-160",
            a,
            policy: Policy::glu3(),
            device: DeviceConfig::titan_x(),
            require_all_modes: false,
        });
    }

    out
}

/// The differential matrix: VirtualDevice executor vs `parrl` indexed vs
/// the cycle simulator, on every fixture × {1, 2, 4} threads.
#[test]
fn three_way_matrix_executor_vs_parrl_vs_simulator() {
    for fx in fixtures() {
        let f = symbolic_fill(&fx.a).unwrap();
        let lv = levelize(&det3::detect(&f.filled));
        let plan = FactorPlan::from_levels(&f, lv.clone(), &fx.policy, &fx.device);
        if fx.require_all_modes {
            let (hs, hl, hc) = plan.mode_histogram();
            assert!(
                hs > 0 && hl > 0 && hc > 0,
                "{}: fixture must hit all three modes, got A/B/C {hs}/{hl}/{hc}",
                fx.name
            );
        }

        // Path 1: the cycle simulator (the reference serialization).
        let (sim, simrep) = simulate_factorization(&f, &lv, &fx.policy, &fx.device).unwrap();

        // Path 2: the schedule executor on the VirtualDevice backend.
        let mut dev = VirtualDevice::new();
        dev.upload_pattern(&plan, plan.scatter(&f.filled)).unwrap();
        let mut exec_lu = f.filled.clone();
        let exec_rep = dev
            .execute(plan.launch_schedule(), exec_lu.values_mut(), &mut PivotMonitor::new())
            .unwrap();
        assert_eq!(
            exec_lu.values(),
            sim.lu.values(),
            "{}: executor must be bit-identical to the simulator",
            fx.name
        );

        // The per-level mode histogram is identical across all three
        // paths (parrl executes the same plan, so its histogram is the
        // plan's by construction).
        assert_eq!(
            plan.mode_histogram(),
            simrep.level_distribution(),
            "{}: plan vs simulator histogram",
            fx.name
        );
        assert_eq!(
            plan.mode_histogram(),
            exec_rep.mode_histogram(),
            "{}: plan vs executor histogram",
            fx.name
        );
        // and the executor's full-model cycle side reconciles exactly
        assert_eq!(exec_rep.simulated_cycles(), simrep.kernel_cycles, "{}", fx.name);

        // Path 3: the indexed worker-pool engine across thread counts.
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let par = parrl::factor_with(&f, &plan, &pool).unwrap();
            for (i, (p, q)) in par.lu.values().iter().zip(exec_lu.values()).enumerate() {
                if threads == 1 {
                    assert!(
                        p == q,
                        "{} (seed {RANDOM_DD_SEED:#x}) threads 1 entry {i}: \
                         parrl {p} vs executor {q} must be bit-identical",
                        fx.name
                    );
                } else {
                    assert!(
                        (p - q).abs() <= 1e-12 * (1.0 + q.abs()),
                        "{} (seed {RANDOM_DD_SEED:#x}) threads {threads} entry {i}: \
                         parrl {p} vs executor {q}",
                        fx.name
                    );
                }
            }
        }

        // The executed factors genuinely solve the fixture's system.
        let n = fx.a.nrows();
        let b: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) - 3.0).collect();
        let mut x = b.clone();
        glu3::numeric::trisolve::lower_unit_solve(&exec_lu, &mut x);
        glu3::numeric::trisolve::upper_solve(&exec_lu, &mut x);
        assert!(residual(&fx.a, &x, &b) < 1e-7, "{}", fx.name);
    }
}

/// The batched row of the matrix: on every fixture, stamp `B` scaled
/// value planes of the filled pattern and factor them (a) plane-by-plane
/// through `VirtualDevice::execute` (the reference), (b) in one
/// `execute_planes` schedule walk, and (c) through `parrl`'s batched
/// `refactor_planes` at {1, 2, 4} threads. The one-walk executor must be
/// bit-identical to its own looped execution; parrl follows the usual
/// thread-count contract.
#[test]
fn batched_planes_matrix_executor_vs_parrl() {
    use glu3::numeric::ValuePlanes;

    const B: usize = 4;
    for fx in fixtures() {
        let f = symbolic_fill(&fx.a).unwrap();
        let lv = levelize(&det3::detect(&f.filled));
        let plan = FactorPlan::from_levels(&f, lv, &fx.policy, &fx.device);
        let nnz = f.filled.nnz();

        // Reference: per-plane looped execution on the VirtualDevice.
        let mut dev = VirtualDevice::new();
        dev.upload_pattern(&plan, plan.scatter(&f.filled)).unwrap();
        let mut looped = Vec::with_capacity(B);
        for p in 0..B {
            let mut lu = f.filled.clone();
            for v in lu.values_mut() {
                *v *= 1.0 + 0.05 * (p as f64 + 1.0);
            }
            dev.execute(plan.launch_schedule(), lu.values_mut(), &mut PivotMonitor::new())
                .unwrap();
            looped.push(lu);
        }

        // One batched schedule walk over the same planes.
        let mut planes = ValuePlanes::new(B, nnz);
        for p in 0..B {
            let mut vals = f.filled.values().to_vec();
            for v in &mut vals {
                *v *= 1.0 + 0.05 * (p as f64 + 1.0);
            }
            planes.set_plane(p, &vals);
        }
        dev.execute_planes(plan.launch_schedule(), &mut planes, &mut PivotMonitor::new())
            .unwrap();
        for p in 0..B {
            assert_eq!(
                planes.plane(p).as_slice(),
                looped[p].values(),
                "{}: batched executor plane {p} must be bit-identical",
                fx.name
            );
        }

        // parrl's batched kernel across thread counts.
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let mut pplanes = ValuePlanes::new(B, nnz);
            for p in 0..B {
                let mut vals = f.filled.values().to_vec();
                for v in &mut vals {
                    *v *= 1.0 + 0.05 * (p as f64 + 1.0);
                }
                pplanes.set_plane(p, &vals);
            }
            parrl::refactor_planes(&f.filled, &mut pplanes, &plan, &pool, &mut PivotMonitor::new())
                .unwrap();
            for p in 0..B {
                let plane = pplanes.plane(p);
                for (i, (x, y)) in plane.iter().zip(looped[p].values()).enumerate() {
                    if threads == 1 {
                        assert!(
                            x == y,
                            "{} threads 1 plane {p} entry {i}: parrl {x} vs executor {y} \
                             must be bit-identical",
                            fx.name
                        );
                    } else {
                        assert!(
                            (x - y).abs() <= 1e-12 * (1.0 + y.abs()),
                            "{} threads {threads} plane {p} entry {i}: parrl {x} vs {y}",
                            fx.name
                        );
                    }
                }
            }
        }
    }
}

/// Integration-level adversarial check (the executor's own unit tests
/// cover more shapes): a corrupted schedule — launches out of level
/// order — is rejected whole, with the value buffer untouched.
#[test]
fn corrupted_schedule_rejected_before_values_change() {
    let a = glu3::sparse::io::read_matrix_market(fixture_dir().join("tridiag_8.mtx")).unwrap();
    let f = symbolic_fill(&a).unwrap();
    let lv = levelize(&det3::detect(&f.filled));
    let plan = FactorPlan::from_levels(&f, lv, &Policy::glu3(), &DeviceConfig::titan_x());
    let mut dev = VirtualDevice::new();
    dev.upload_pattern(&plan, plan.scatter(&f.filled)).unwrap();

    let mut bad = plan.launch_schedule().clone();
    assert!(bad.launches.len() >= 2);
    bad.launches.swap(0, 1);
    let mut lu = f.filled.clone();
    let before = lu.values().to_vec();
    let err = dev
        .execute(&bad, lu.values_mut(), &mut PivotMonitor::new())
        .unwrap_err();
    assert!(err.to_string().contains("order"), "{err}");
    assert_eq!(lu.values(), &before[..], "values must be untouched");

    // the honest schedule still runs afterwards
    dev.execute(plan.launch_schedule(), lu.values_mut(), &mut PivotMonitor::new())
        .unwrap();
}

fn fixture_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn parse_golden(text: &str) -> BTreeMap<String, u64> {
    let mut map = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line.split_once('=').expect("golden line must be `key = value`");
        map.insert(
            k.trim().to_string(),
            v.trim().parse::<u64>().expect("golden value must be an integer"),
        );
    }
    map
}

/// Golden-pattern regression fixtures: three checked-in matrices with the
/// expected L/U nnz, level count, mode histogram, and launch count of the
/// natural-ordering pattern pipeline (symbolic fill → glu3 detect →
/// levelize → plan under `Policy::glu3` on the TITAN X model →
/// `lower_plan`). Any drift in fill, levelization, mode selection, or
/// lowering fails with a field-by-field diff.
#[test]
fn golden_pattern_fixtures_pin_lowering_and_levelization() {
    for name in ["tridiag_8", "diag_20", "grid_3x3"] {
        let dir = fixture_dir();
        let a = glu3::sparse::io::read_matrix_market(dir.join(format!("{name}.mtx")))
            .unwrap_or_else(|e| panic!("{name}: reading fixture: {e}"));
        let golden_text = std::fs::read_to_string(dir.join(format!("{name}.golden")))
            .unwrap_or_else(|e| panic!("{name}: reading golden file: {e}"));
        let golden = parse_golden(&golden_text);

        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&det3::detect(&f.filled));
        let plan = FactorPlan::from_levels(&f, lv, &Policy::glu3(), &DeviceConfig::titan_x());
        let sched = lower_plan(&plan);
        let (hs, hl, hc) = plan.mode_histogram();
        let l_nnz: u64 = (0..f.filled.ncols())
            .map(|c| {
                let (rows, _) = f.filled.col(c);
                rows.iter().filter(|&&r| r > c).count() as u64
            })
            .sum();

        let got: Vec<(&str, u64)> = vec![
            ("n", a.nrows() as u64),
            ("nnz_filled", f.filled.nnz() as u64),
            ("l_nnz", l_nnz),
            ("u_nnz", f.filled.nnz() as u64 - l_nnz),
            ("levels", plan.num_levels() as u64),
            ("modes_small", hs as u64),
            ("modes_large", hl as u64),
            ("modes_stream", hc as u64),
            ("total_launches", sched.total_launches()),
        ];
        let mut diffs = Vec::new();
        for (k, g) in &got {
            match golden.get(*k) {
                Some(w) if w == g => {}
                Some(w) => diffs.push(format!("  {k}: got {g}, golden expects {w}")),
                None => diffs.push(format!("  {k}: got {g}, missing from golden file")),
            }
        }
        for k in golden.keys() {
            if !got.iter().any(|(gk, _)| gk == k) {
                diffs.push(format!("  {k}: in golden file but not measured"));
            }
        }
        assert!(
            diffs.is_empty(),
            "{name}: pattern pipeline drifted from the golden fixture:\n{}\n\
             (regenerate {name}.golden only for an intentional fill/\
             levelization/lowering change)",
            diffs.join("\n")
        );

        // the fixture also factors and solves through the executor
        let mut dev = VirtualDevice::new();
        dev.upload_pattern(&plan, plan.scatter(&f.filled)).unwrap();
        let mut lu = f.filled.clone();
        dev.execute(plan.launch_schedule(), lu.values_mut(), &mut PivotMonitor::new())
        .unwrap();
        let n = a.nrows();
        let b = vec![1.0; n];
        let mut x = b.clone();
        glu3::numeric::trisolve::lower_unit_solve(&lu, &mut x);
        glu3::numeric::trisolve::upper_solve(&lu, &mut x);
        assert!(residual(&a, &x, &b) < 1e-10, "{name}: factors must solve");
    }
}

/// Golden pivot-rescue fixture: a checked-in `zero_diagonal_band` instance
/// whose fixed-order ladder exhausts deterministically (the 48-column dead
/// band overflows every perturbed rerun), pinned through the full rescue
/// flow — factor the diagonally-dominant twin, refactor with the hostile
/// values, and compare the rescue invariants (rescue count, swapped pivot
/// count, rebuild counters) field-by-field against the golden file. The
/// rescued factors must then solve to dense-partial-pivoting-oracle
/// accuracy and refactor again *without* re-rescuing.
#[test]
fn golden_rescue_fixture_pins_the_pivot_rescue() {
    let dir = fixture_dir();
    let a = glu3::sparse::io::read_matrix_market(dir.join("rescue_zdb_96.mtx"))
        .expect("reading rescue fixture");
    let golden_text = std::fs::read_to_string(dir.join("rescue_zdb_96.golden"))
        .expect("reading rescue golden file");
    let golden = parse_golden(&golden_text);

    let twin = glu3::sparse::gen::dominant_restamp(&a, 7);
    let opts = glu3::glu::GluOptions {
        ordering: glu3::order::FillOrdering::Natural,
        scale: false,
        ..Default::default()
    };
    let mut s = glu3::glu::GluSolver::factor(&twin, &opts).expect("twin must factor cleanly");
    assert_eq!(s.stats().robustness.rescues, 0);
    s.refactor(&a)
        .unwrap_or_else(|e| panic!("rung 5 must rescue the fixture: {e:#}"));

    let st = s.stats();
    let got: Vec<(&str, u64)> = vec![
        ("n", a.nrows() as u64),
        ("nnz", a.nnz() as u64),
        ("rescues", st.robustness.rescues),
        ("rescued_pivots", st.robustness.rescued_pivots),
        ("symbolic_runs", st.symbolic_runs as u64),
        ("plan_builds", st.plan_builds as u64),
    ];
    let mut diffs = Vec::new();
    for (k, g) in &got {
        match golden.get(*k) {
            Some(w) if w == g => {}
            Some(w) => diffs.push(format!("  {k}: got {g}, golden expects {w}")),
            None => diffs.push(format!("  {k}: got {g}, missing from golden file")),
        }
    }
    for k in golden.keys() {
        if !got.iter().any(|(gk, _)| gk == k) {
            diffs.push(format!("  {k}: in golden file but not measured"));
        }
    }
    assert!(
        diffs.is_empty(),
        "pivot rescue drifted from the golden fixture:\n{}\n\
         (regenerate rescue_zdb_96.golden only for an intentional ladder \
         or pivoting-policy change)",
        diffs.join("\n")
    );
    assert!(st.robustness.rescue_ms >= 0.0);

    // The rescued factors solve to dense partial-pivoting oracle accuracy.
    let n = a.nrows();
    let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
    let x = s.solve(&b).expect("rescued solver must solve");
    let want = glu3::numeric::dense::solve(&a.to_dense(), n, &b).expect("oracle must factor");
    let drift = x
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f64, f64::max);
    assert!(drift <= 1e-9, "rescued solve drifts {drift:.3e} from the dense oracle");
    assert!(residual(&a, &x, &b) <= 1e-9, "rescued residual too large");

    // Subsequent refactor on the rescued ordering: fast path, no re-rescue,
    // no second symbolic rebuild.
    s.refactor(&a).expect("refactor on the rescued ordering must succeed");
    assert_eq!(s.stats().robustness.rescues, 1, "must not re-rescue");
    assert_eq!(s.stats().symbolic_runs, 2, "no extra symbolic pass");
    let x2 = s.solve(&b).unwrap();
    assert!(residual(&a, &x2, &b) <= 1e-9, "post-rescue refactor residual");
}
