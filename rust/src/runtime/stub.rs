//! Default-build stand-in for the PJRT runtime (`xla` bindings absent).
//!
//! Same public surface as the real implementation; [`Runtime::load`] always
//! errors, so every caller that guards on artifacts being built (the bench
//! and the integration test do) skips before touching the other methods.
//! This stub is what the `pjrt`-feature *stub path* builds against too:
//! the [`super::executor::PjrtDevice`] dispatch code compiles, and its
//! construction fails here, at runtime load.

use std::path::Path;

/// Stub runtime: carries the API, never loads.
#[derive(Debug)]
pub struct Runtime {}

impl Runtime {
    /// Always fails: the `xla` FFI bindings (vendored, plus
    /// `--features xla`) are required for artifact execution.
    pub fn load(_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        anyhow::bail!(
            "glu3 was built without the `xla` bindings; vendor the `xla` \
             crate and rebuild with `--features xla` to load PJRT artifacts"
        )
    }

    /// Artifact names available (none in the stub).
    pub fn names(&self) -> Vec<&str> {
        Vec::new()
    }

    /// Stubbed `level_update` (see the `pjrt` module when enabled).
    pub fn level_update(
        &self,
        _x: &[f32],
        _u: &[f32],
        _s: &[f32],
        _b: usize,
        _n: usize,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::bail!("xla feature disabled")
    }

    /// Stubbed `dense_tail_solve` (see the `pjrt` module when enabled).
    pub fn dense_tail_solve(
        &self,
        _a: &[f32],
        _rhs: &[f32],
        _t: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::bail!("xla feature disabled")
    }

    /// Stubbed `quickstart` (see the `pjrt` module when enabled).
    pub fn quickstart(&self, _x: [f32; 4], _y: [f32; 4]) -> anyhow::Result<[f32; 4]> {
        anyhow::bail!("xla feature disabled")
    }

    /// Stubbed plan lowering. The pure walk is available without a runtime
    /// as [`super::lower_plan`]; this method (which would additionally
    /// verify the named artifacts are compiled) needs the `xla` feature.
    pub fn lower_plan(
        &self,
        _plan: &crate::plan::FactorPlan,
    ) -> anyhow::Result<super::LaunchSchedule> {
        anyhow::bail!("xla feature disabled")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_reports_missing_feature() {
        let err = Runtime::load(super::super::default_artifact_dir()).unwrap_err();
        assert!(format!("{err}").contains("xla"));
    }
}
