//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts and execute them
//! from the Rust hot path. Python never runs at request time.
//!
//! Artifacts are HLO *text* (`artifacts/*.hlo.txt`, produced by
//! `python/compile/aot.py` — see that file for why text, not serialized
//! protos). Each artifact is parsed and compiled once at [`Runtime::load`];
//! execution is a buffer round-trip on the PJRT CPU client.
//!
//! The artifact ladder has static shapes; [`Runtime::level_update`] and
//! [`Runtime::dense_tail_solve`] pick the smallest fitting variant and
//! zero-pad (padding rows/columns flow through the MAC/LU harmlessly:
//! padded `s`/`u` entries are zero, and the dense-tail pad is an identity
//! block).
//!
//! ## Feature gating
//!
//! The real implementation (`pjrt` module) needs the `xla` FFI bindings,
//! which the offline vendored crate set does not carry. The default build
//! ships a stub with the identical public API whose [`Runtime::load`]
//! returns an error; callers (the `pjrt_kernels` bench, the PJRT
//! integration test) guard on [`PJRT_ENABLED`] *and* the artifact
//! directory existing, so they skip cleanly either way. Enabling the real
//! path means vendoring `xla`, adding it to `[dependencies]` in
//! `rust/Cargo.toml`, and building with `--features pjrt`.

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

/// Whether this build carries the real PJRT runtime. Callers that gate on
/// artifacts existing must gate on this too — with the stub, `load` errors
/// even when artifacts are present.
pub const PJRT_ENABLED: bool = cfg!(feature = "pjrt");

/// Shape ladder for `level_update_{B}x{N}` (must match `aot.py`).
pub const LEVEL_SIZES: [(usize, usize); 2] = [(64, 256), (256, 2048)];
/// Shape ladder for `dense_tail_{T}` (must match `aot.py`).
pub const TAIL_SIZES: [usize; 2] = [64, 256];

/// Default artifact directory: `$GLU3_ARTIFACTS` or `artifacts/` relative to
/// the workspace root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("GLU3_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
