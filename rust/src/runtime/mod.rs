//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts and execute them
//! from the Rust hot path. Python never runs at request time.
//!
//! Artifacts are HLO *text* (`artifacts/*.hlo.txt`, produced by
//! `python/compile/aot.py` — see that file for why text, not serialized
//! protos). Each artifact is parsed and compiled once at [`Runtime::load`];
//! execution is a buffer round-trip on the PJRT CPU client.
//!
//! The artifact ladder has static shapes; [`Runtime::level_update`] and
//! [`Runtime::dense_tail_solve`] pick the smallest fitting variant and
//! zero-pad (padding rows/columns flow through the MAC/LU harmlessly:
//! padded `s`/`u` entries are zero, and the dense-tail pad is an identity
//! block).
//!
//! [`lower_plan`] maps a whole [`crate::plan::FactorPlan`] onto that
//! ladder: each level becomes a [`PlannedLaunch`] (kernel variant, block
//! geometry from the plan's resource binding, launch count with tiling),
//! giving the GPU-offload work a concrete launch sequence to execute and
//! the cycle simulator a measured counterpart to reconcile against.
//!
//! [`executor`] then *runs* a lowered schedule: the
//! [`executor::DeviceExecutor`] trait dispatches either the default-build
//! [`executor::VirtualDevice`] interpreter or the `pjrt`-feature
//! [`executor::PjrtDevice`] artifact path, with per-launch cycle
//! accounting reconciled against the gpusim model.
//!
//! ## Feature gating
//!
//! Two features split the stack:
//!
//! - `pjrt` — the executor backend plumbing ([`executor::PjrtDevice`] and
//!   friends). Compiles offline; CI keeps it green with
//!   `cargo test -q --features pjrt` (the *stub path*: runtime loads fail
//!   gracefully, artifact-dependent tests self-skip).
//! - `xla` (implies `pjrt`) — the real PJRT FFI. The offline vendored
//!   crate set does not carry the `xla` bindings, so the default build
//!   (and the `pjrt`-only build) ships a stub with the identical public
//!   API whose [`Runtime::load`] returns an error; callers (the
//!   `pjrt_kernels` bench, the PJRT integration test) guard on
//!   [`PJRT_ENABLED`] *and* the artifact directory existing, so they skip
//!   cleanly either way. Enabling the real path means vendoring `xla`,
//!   adding it to `[dependencies]` in `rust/Cargo.toml`, and building
//!   with `--features xla`.

use std::path::PathBuf;

use crate::plan::{FactorPlan, KernelMode, ResourceBinding};

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::Runtime;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::Runtime;

pub mod executor;

#[cfg(feature = "pjrt")]
pub use executor::PjrtDevice;
pub use executor::{DeviceExecutor, ExecBackend, ExecReport, LaunchExec, UploadInfo, VirtualDevice};

/// Whether this build carries the real PJRT runtime (the `xla` FFI
/// bindings). Callers that gate on artifacts existing must gate on this
/// too — with the stub, `load` errors even when artifacts are present.
pub const PJRT_ENABLED: bool = cfg!(feature = "xla");

/// Shape ladder for `level_update_{B}x{N}` (must match `aot.py`).
pub const LEVEL_SIZES: [(usize, usize); 2] = [(64, 256), (256, 2048)];
/// Shape ladder for `dense_tail_{T}` (must match `aot.py`).
pub const TAIL_SIZES: [usize; 2] = [64, 256];

/// Default artifact directory: `$GLU3_ARTIFACTS` or `artifacts/` relative to
/// the workspace root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("GLU3_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// One planned kernel launch of the lowered factorization — a level of the
/// [`FactorPlan`] mapped onto the AOT artifact ladder.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlannedLaunch {
    /// Source level index in the plan.
    pub level: usize,
    /// Artifact name (`level_update_{B}x{N}` — must exist in the loaded
    /// runtime to execute).
    pub kernel: String,
    /// Kernel invocations this level costs: one per `(column-tile,
    /// width-tile)` pair for block modes, one per column per tile pair in
    /// stream mode (dispatched over the plan's CUDA streams).
    pub launches: u64,
    /// Thread blocks per launch.
    pub blocks: usize,
    /// Threads per block (warps × warp size from the plan's binding).
    pub threads_per_block: usize,
    /// Columns factorized by the level.
    pub columns: usize,
}

/// The kernel-launch sequence a [`FactorPlan`] lowers to — the bridge
/// between the ROADMAP's "real GPU offload" item and the scheduling IR:
/// walking the plan's levels in order yields exactly the launches the
/// future device path will enqueue, so the cycle simulator and a measured
/// kernel ladder can be reconciled level by level.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaunchSchedule {
    /// Launches in level order (one entry per level).
    pub launches: Vec<PlannedLaunch>,
}

impl LaunchSchedule {
    /// Total kernel invocations across all levels.
    pub fn total_launches(&self) -> u64 {
        self.launches.iter().map(|l| l.launches).sum()
    }

    /// Distinct artifact names the schedule needs, sorted and deduplicated
    /// — consecutive levels routinely share a ladder variant, so the raw
    /// launch list repeats names; this never does.
    pub fn kernels_used(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.launches.iter().map(|l| l.kernel.as_str()).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Lower a [`FactorPlan`] into its kernel-launch sequence against the
/// static artifact ladder. Pure plan walk — needs no loaded runtime, so
/// the default (stub) build can already answer "what would the GPU path
/// launch"; `Runtime::lower_plan` additionally verifies the named
/// artifacts are compiled.
///
/// Each level picks the smallest `level_update_{B}x{N}` variant that fits
/// its `(columns, max L length)` batch geometry; oversize levels tile over
/// both dimensions (columns in chunks of `B`, subcolumn length in chunks
/// of `N`), so lowering never fails — it just costs more launches.
pub fn lower_plan(plan: &FactorPlan) -> LaunchSchedule {
    let warp = plan.device().warp_size;
    let launches = plan
        .level_plans()
        .iter()
        .map(|lp| {
            let cols = lp.columns.max(1);
            let width = lp.max_l_len.max(1);
            // Stream-mode kernels handle exactly one column each, so only
            // the width participates in variant selection and tiling; the
            // block modes batch `cols` columns and tile over both axes.
            let (lb, ln) = LEVEL_SIZES
                .iter()
                .copied()
                .find(|&(b, n)| {
                    width <= n && (matches!(lp.mode, KernelMode::Stream) || cols <= b)
                })
                .unwrap_or(LEVEL_SIZES[LEVEL_SIZES.len() - 1]);
            let width_tiles = width.div_ceil(ln) as u64;
            let (blocks, threads_per_block, launches) = match lp.binding {
                ResourceBinding::Blocks {
                    blocks,
                    warps_per_block,
                } => (
                    blocks,
                    warps_per_block * warp,
                    cols.div_ceil(lb) as u64 * width_tiles,
                ),
                // Stream mode: one kernel per column (× width tiles), one
                // max-occupancy block per subcolumn.
                ResourceBinding::Streams { kernels, .. } => (
                    lp.max_subcols.max(1),
                    plan.device().max_threads_per_block,
                    kernels as u64 * width_tiles,
                ),
            };
            debug_assert!(matches!(
                (lp.mode, lp.binding),
                (KernelMode::Stream, ResourceBinding::Streams { .. })
                    | (KernelMode::SmallBlock { .. }, ResourceBinding::Blocks { .. })
                    | (KernelMode::LargeBlock, ResourceBinding::Blocks { .. })
            ));
            PlannedLaunch {
                level: lp.index,
                kernel: format!("level_update_{lb}x{ln}"),
                launches,
                blocks,
                threads_per_block,
                columns: lp.columns,
            }
        })
        .collect();
    LaunchSchedule { launches }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::glu3;
    use crate::gpusim::{DeviceConfig, Policy};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;

    fn mesh_plan() -> FactorPlan {
        let g = gen::grid2d(20, 20, 3);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let sym = symbolic_fill(&a).unwrap();
        let deps = glu3::detect(&sym.filled);
        FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x())
    }

    #[test]
    fn lowering_walks_every_level_in_order() {
        let plan = mesh_plan();
        let sched = lower_plan(&plan);
        assert_eq!(sched.launches.len(), plan.num_levels());
        for (i, l) in sched.launches.iter().enumerate() {
            assert_eq!(l.level, i);
            assert!(l.launches >= 1);
            assert!(l.threads_per_block >= 1);
            assert_eq!(l.columns, plan.level_plan(i).columns);
            // every kernel name resolves against the artifact ladder
            assert!(
                LEVEL_SIZES
                    .iter()
                    .any(|(b, n)| l.kernel == format!("level_update_{b}x{n}")),
                "unknown kernel {}",
                l.kernel
            );
        }
        assert!(sched.total_launches() >= plan.num_levels() as u64);
        assert!(!sched.kernels_used().is_empty());
    }

    /// Consecutive levels sharing an artifact must not repeat in
    /// `kernels_used`: strictly sorted, no duplicates, and never more
    /// names than the ladder has variants.
    #[test]
    fn kernels_used_dedups_shared_artifacts() {
        let plan = mesh_plan();
        let sched = lower_plan(&plan);
        assert!(
            sched.launches.len() > LEVEL_SIZES.len(),
            "mesh must have more levels than ladder variants"
        );
        let used = sched.kernels_used();
        assert!(!used.is_empty());
        assert!(used.len() <= LEVEL_SIZES.len());
        assert!(
            used.windows(2).all(|w| w[0] < w[1]),
            "kernels_used must be strictly sorted (duplicate-free): {used:?}"
        );
    }

    #[test]
    fn stream_levels_launch_per_column_and_wide_levels_tile() {
        let plan = mesh_plan();
        let sched = lower_plan(&plan);
        for (lp, l) in plan.level_plans().iter().zip(&sched.launches) {
            match lp.mode {
                crate::plan::KernelMode::Stream => {
                    // one kernel per column (× width tiles)
                    assert!(l.launches >= lp.columns as u64, "{l:?}");
                    assert_eq!(l.threads_per_block, 1024);
                }
                crate::plan::KernelMode::SmallBlock { warps_per_block } => {
                    assert_eq!(l.threads_per_block, warps_per_block * 32);
                    // a level wider than the biggest batch variant must tile
                    let max_b = LEVEL_SIZES.iter().map(|&(b, _)| b).max().unwrap();
                    if lp.columns > max_b {
                        assert!(l.launches > 1, "{l:?}");
                    }
                }
                crate::plan::KernelMode::LargeBlock => {
                    assert_eq!(l.threads_per_block, 1024);
                }
            }
        }
    }
}
