//! Executing a [`LaunchSchedule`]: the backend-dispatch layer between the
//! scheduling IR and a device.
//!
//! [`super::lower_plan`] turns a [`FactorPlan`] into the kernel-launch
//! sequence a device would enqueue, and the pattern-time [`ScatterMap`] is
//! exactly the gather/scatter index-buffer pair that device would hold
//! resident. This module adds the missing piece — something that *runs*
//! the schedule — behind one trait:
//!
//! ```text
//!                 upload_pattern(plan, scatter)      execute(schedule, vals)
//! DeviceExecutor ──────────────────────────────► device state ───────────► L/U
//!        │
//!        ├── VirtualDevice (default build): interprets every launch with
//!        │   the real launch geometry — blocks × warps / stream batches
//!        │   from the plan's ResourceBinding, the indexed inner loop
//!        │   straight off the uploaded u32 scatter buffers — and accounts
//!        │   per-launch cycles through the gpusim cost model so the
//!        │   simulator's prediction can be reconciled level by level.
//!        └── PjrtDevice (`pjrt` feature): binds the scatter map as
//!            device-resident u32 buffers and dispatches the AOT
//!            `level_update` artifact ladder through [`super::Runtime`].
//! ```
//!
//! ## Conformance contract
//!
//! The [`VirtualDevice`] serializes each level's columns in ascending
//! order (divide phase, then the column's MAC tasks in task order) — the
//! same serialization [`crate::gpusim::executor::simulate_refactorization`]
//! and the 1-thread [`crate::numeric::parrl`] engine use — so its L/U
//! values are **bit-identical** to both. `rust/tests/conformance.rs` holds
//! that three-way matrix across kernel modes, thread counts, and fixtures.
//!
//! ## Validation before execution
//!
//! Both backends refuse to touch the value buffer until the inputs prove
//! coherent, mirroring [`ScatterMap::validate`]'s adversarial posture:
//!
//! - [`DeviceExecutor::upload_pattern`] bounds-checks every scatter index
//!   against the pattern (an out-of-range value index can never reach the
//!   indexed stores);
//! - [`DeviceExecutor::execute`] validates the whole schedule first —
//!   level order, per-launch column counts against the uploaded plan,
//!   kernel names against the artifact ladder, and the value-buffer
//!   length — and rejects a corrupted or foreign schedule with `vals`
//!   untouched. (A zero pivot *during* execution still errors midway, the
//!   same partial-update semantics every in-place engine has.)
//!
//! ## Cycle reconciliation
//!
//! Each executed launch reports two cycle counts derived from the same
//! [`crate::gpusim::cost`] model: `simulated_cycles` — the full latency
//! model, exactly what [`crate::gpusim::SimReport`] charges the level —
//! and `executed_cycles` — the same geometry costed on an
//! [`crate::gpusim::DeviceConfig::issue_only`] device (memory-latency and
//! launch-overhead terms zeroed), i.e. the pure issue makespan the
//! interpreter actually walked. The per-level delta is the model's
//! latency/overhead prediction, surfaced through `GluStats`, `glu3
//! factor`/`glu3 bench`, and the `schedule` block of `BENCH_numeric.json`.

use crate::gpusim::exec::simulate_level;
use crate::numeric::{PivotMonitor, ValuePlanes};
use crate::plan::{ColumnWork, FactorPlan, KernelMode, ScatterMap};

use super::{LaunchSchedule, PlannedLaunch, LEVEL_SIZES};

/// Which executor backend runs the schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// The default-build interpreter ([`VirtualDevice`]).
    #[default]
    Virtual,
    /// The AOT artifact ladder through the PJRT runtime ([`PjrtDevice`];
    /// requires `--features pjrt`, and the vendored `xla` bindings for
    /// real execution).
    Pjrt,
}

impl ExecBackend {
    /// Short label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            ExecBackend::Virtual => "virtual",
            ExecBackend::Pjrt => "pjrt",
        }
    }
}

/// What [`DeviceExecutor::upload_pattern`] bound on the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UploadInfo {
    /// Device buffers bound (the scatter map's index arrays).
    pub buffers: usize,
    /// Total bytes of device-resident `u32` index data.
    pub index_bytes: usize,
    /// MAC tasks the uploaded map describes.
    pub tasks: usize,
    /// Value-array length the indices address.
    pub nnz: usize,
}

/// One executed launch of the schedule walk.
#[derive(Debug, Clone)]
pub struct LaunchExec {
    /// Level index the launch factorized.
    pub level: usize,
    /// Artifact the launch dispatched.
    pub kernel: String,
    /// Kernel mode of the level (from the uploaded plan).
    pub mode: KernelMode,
    /// Columns factorized.
    pub columns: usize,
    /// Kernel invocations charged (tiling included).
    pub launches: u64,
    /// Divide-phase elements actually processed.
    pub div_elems: u64,
    /// MAC elements the backend processed. The virtual interpreter skips
    /// zero-multiplier tasks (the kernel's early-out); the pjrt ladder
    /// dispatches every task tiled, zeros included — so the two backends
    /// may legitimately report different counts for the same values.
    pub mac_elems: u64,
    /// Issue-only makespan of the launch geometry (the
    /// [`crate::gpusim::DeviceConfig::issue_only`] costing).
    pub executed_cycles: u64,
    /// Full gpusim latency-model cycles — identical to what
    /// [`crate::gpusim::simulate_refactorization`] charges the level.
    pub simulated_cycles: u64,
}

impl LaunchExec {
    /// Simulated minus executed: the latency/launch-overhead cycles the
    /// model predicts beyond pure issue work.
    pub fn cycle_delta(&self) -> i64 {
        self.simulated_cycles as i64 - self.executed_cycles as i64
    }
}

/// Per-launch execution report of one schedule walk.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Backend that executed ([`DeviceExecutor::name`]).
    pub backend: &'static str,
    /// One row per planned launch, in level order.
    pub per_launch: Vec<LaunchExec>,
}

impl ExecReport {
    /// Total kernel invocations across all launches.
    pub fn total_launches(&self) -> u64 {
        self.per_launch.iter().map(|l| l.launches).sum()
    }

    /// Total issue-only cycles.
    pub fn executed_cycles(&self) -> u64 {
        self.per_launch.iter().map(|l| l.executed_cycles).sum()
    }

    /// Total full-model cycles (reconciles with
    /// [`crate::gpusim::SimReport`]'s `kernel_cycles`).
    pub fn simulated_cycles(&self) -> u64 {
        self.per_launch.iter().map(|l| l.simulated_cycles).sum()
    }

    /// Total simulated-minus-executed cycle delta.
    pub fn cycle_delta(&self) -> i64 {
        self.simulated_cycles() as i64 - self.executed_cycles() as i64
    }

    /// Count of executed levels by mode family `(small, large, stream)` —
    /// must equal [`FactorPlan::mode_histogram`] for the uploaded plan.
    pub fn mode_histogram(&self) -> (usize, usize, usize) {
        let mut dist = (0, 0, 0);
        for l in &self.per_launch {
            match l.mode.level_type() {
                'A' => dist.0 += 1,
                'B' => dist.1 += 1,
                _ => dist.2 += 1,
            }
        }
        dist
    }
}

/// A backend that holds an uploaded pattern and executes lowered
/// schedules against value buffers.
pub trait DeviceExecutor: std::fmt::Debug + Send {
    /// Backend label for reports.
    fn name(&self) -> &'static str;

    /// Bind the pattern-time state (plan views + scatter index buffers) on
    /// the device. Validates every index before binding; a later upload
    /// replaces the previous pattern.
    fn upload_pattern(&mut self, plan: &FactorPlan, sm: &ScatterMap) -> anyhow::Result<UploadInfo>;

    /// Execute a lowered schedule against `vals` (the filled pattern's
    /// value array, `A`'s values stamped in) in place, walking the
    /// launches level by level. The whole schedule is validated against
    /// the uploaded pattern before the first store; on a validation error
    /// `vals` is untouched. `mon` records the pivot extrema the robustness
    /// ladder consumes (the divide phase observes each pivot).
    fn execute(
        &mut self,
        sched: &LaunchSchedule,
        vals: &mut [f64],
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<ExecReport>;

    /// Execute a lowered schedule against a whole batch of value planes.
    /// The default implementation loops [`DeviceExecutor::execute`] over
    /// the planes through a scratch buffer (correct for any backend — the
    /// PJRT ladder inherits it); backends that can amortize the launch
    /// walk override it ([`VirtualDevice`] interprets each launch once
    /// with the plane loop innermost). The returned report describes one
    /// schedule walk; a batching override accounts the *total* per-plane
    /// trip counts in `div_elems`/`mac_elems`, while the looped default
    /// returns the last plane's report.
    fn execute_planes(
        &mut self,
        sched: &LaunchSchedule,
        planes: &mut ValuePlanes,
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<ExecReport> {
        let mut scratch = vec![0.0; planes.nnz()];
        let mut last = None;
        for p in 0..planes.planes() {
            planes.copy_plane(p, &mut scratch);
            let rep = self.execute(sched, &mut scratch, mon)?;
            planes.set_plane(p, &scratch);
            last = Some(rep);
        }
        last.ok_or_else(|| anyhow::anyhow!("empty plane batch"))
    }
}

/// Construct the executor for a backend choice. `ExecBackend::Pjrt` needs
/// the `pjrt` feature (and errors at runtime load without the `xla`
/// bindings or compiled artifacts).
pub fn create_backend(backend: ExecBackend) -> anyhow::Result<Box<dyn DeviceExecutor>> {
    match backend {
        ExecBackend::Virtual => Ok(Box::new(VirtualDevice::new())),
        #[cfg(feature = "pjrt")]
        ExecBackend::Pjrt => Ok(Box::new(PjrtDevice::new(super::default_artifact_dir())?)),
        #[cfg(not(feature = "pjrt"))]
        ExecBackend::Pjrt => anyhow::bail!(
            "the pjrt executor backend requires building with `--features pjrt`"
        ),
    }
}

/// Bounds-check a scatter map against the plan's pattern geometry before
/// any backend binds it: array lengths, the per-column task layout, and
/// every value index in `0..nnz`. Cheaper than [`ScatterMap::validate`]
/// (no address re-derivation) but sufficient to guarantee the indexed
/// kernel can never load or store out of bounds.
fn check_upload(plan: &FactorPlan, sm: &ScatterMap) -> anyhow::Result<()> {
    let n = plan.n();
    let nnz = sm.nnz;
    anyhow::ensure!(
        sm.diag_idx.len() == n && sm.l_len.len() == n && sm.task_ptr.len() == n + 1,
        "scatter map per-column arrays do not match the plan dimension"
    );
    anyhow::ensure!(sm.task_ptr[0] == 0, "scatter map task_ptr must start at 0");
    let ntasks = sm.mult_idx.len();
    anyhow::ensure!(
        sm.dst_off.len() == ntasks && sm.task_ptr[n] as usize == ntasks,
        "scatter map task arrays disagree"
    );
    let urow = plan.urow();
    for j in 0..n {
        let d = sm.diag_idx[j] as usize;
        let ll = sm.l_len[j] as usize;
        anyhow::ensure!(
            d + ll < nnz,
            "column {j}: diagonal/L run exceeds the value array"
        );
        let (t0, t1) = (sm.task_ptr[j] as usize, sm.task_ptr[j + 1] as usize);
        anyhow::ensure!(
            t0 <= t1 && t1 <= ntasks && t1 - t0 == urow[j].len(),
            "column {j}: task range disagrees with the plan's subcolumn view"
        );
        for t in t0..t1 {
            anyhow::ensure!(
                (sm.mult_idx[t] as usize) < nnz,
                "task {t}: multiplier value index out of range"
            );
            let off = sm.dst_off[t] as usize;
            anyhow::ensure!(
                off + ll <= sm.dst.len(),
                "task {t}: destination run out of bounds"
            );
            for &dv in &sm.dst[off..off + ll] {
                anyhow::ensure!(
                    (dv as usize) < nnz,
                    "task {t}: destination value index out of range"
                );
            }
        }
    }
    Ok(())
}

/// Validate a lowered schedule against the uploaded pattern — rejected
/// whole, before any value is touched.
fn check_schedule(
    plan: &FactorPlan,
    sched: &LaunchSchedule,
    vals_len: usize,
    nnz: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        vals_len == nnz,
        "value buffer length {vals_len} does not match the uploaded pattern \
         ({nnz} nonzeros) — schedule and pattern mismatch"
    );
    anyhow::ensure!(
        sched.launches.len() == plan.num_levels(),
        "schedule has {} launches for {} uploaded levels — schedule and \
         pattern mismatch",
        sched.launches.len(),
        plan.num_levels()
    );
    for (i, l) in sched.launches.iter().enumerate() {
        anyhow::ensure!(
            l.level == i,
            "launch {i} targets level {} — levels must execute in order",
            l.level
        );
        let lp = plan.level_plan(i);
        anyhow::ensure!(
            l.columns == lp.columns,
            "launch {i} covers {} columns but the uploaded level has {} — \
             schedule and pattern mismatch",
            l.columns,
            lp.columns
        );
        anyhow::ensure!(
            LEVEL_SIZES
                .iter()
                .any(|(b, n)| l.kernel == format!("level_update_{b}x{n}")),
            "launch {i} names unknown kernel {}",
            l.kernel
        );
        anyhow::ensure!(
            l.launches >= 1 && l.blocks >= 1 && l.threads_per_block >= 1,
            "launch {i} has empty geometry"
        );
    }
    Ok(())
}

/// Cost one level through the gpusim model: `(executed, simulated)`
/// cycles — the issue-only makespan of the real launch geometry next to
/// the full latency model (the exact per-level figure
/// [`crate::gpusim::simulate_refactorization`] charges). Pure
/// pattern-time data, so [`bind_buffers`] precomputes it once per upload
/// and the execute hot path just reads it back.
fn account_level(plan: &FactorPlan, level: usize, work: &mut Vec<ColumnWork>) -> (u64, u64) {
    let lp = plan.level_plan(level);
    work.clear();
    work.extend(
        plan.levels().levels[level]
            .iter()
            .map(|&j| plan.col_work()[j as usize]),
    );
    let device = plan.device();
    let policy = plan.policy();
    let launch_scale = policy.launch_scale_for(lp.columns);
    let simulated = simulate_level(
        work.as_slice(),
        lp.mode,
        plan.n(),
        device,
        launch_scale,
        policy.compute_scale,
        true,
    )
    .cycles;
    let executed = simulate_level(
        work.as_slice(),
        lp.mode,
        plan.n(),
        &device.issue_only(),
        launch_scale,
        policy.compute_scale,
        true,
    )
    .cycles;
    (executed, simulated)
}

/// Device-resident state of the [`VirtualDevice`]: the uploaded plan plus
/// `u32` copies of the scatter map's index buffers — exactly what a real
/// device would keep in global memory for the indexed kernel.
#[derive(Debug)]
struct VirtualState {
    plan: FactorPlan,
    nnz: usize,
    diag_idx: Vec<u32>,
    l_len: Vec<u32>,
    task_ptr: Vec<u32>,
    mult_idx: Vec<u32>,
    dst_off: Vec<u32>,
    dst: Vec<u32>,
    /// Per-level `(executed, simulated)` cycle accounts — pattern-time
    /// data, computed once at upload so the re-execute hot path never
    /// reruns the cost model.
    cycles: Vec<(u64, u64)>,
}

/// The default-build executor: interprets each planned launch with its
/// real geometry and the uploaded index buffers. Serializes every level's
/// columns in ascending order, so results are bit-identical to the cycle
/// simulator and the 1-thread parallel engine (see module docs).
#[derive(Debug, Default)]
pub struct VirtualDevice {
    state: Option<VirtualState>,
}

impl VirtualDevice {
    /// A device with no pattern uploaded.
    pub fn new() -> Self {
        VirtualDevice { state: None }
    }
}

/// Shared upload: validate, then copy the index buffers (the "host →
/// device" transfer both backends perform identically).
fn bind_buffers(plan: &FactorPlan, sm: &ScatterMap) -> anyhow::Result<(VirtualState, UploadInfo)> {
    check_upload(plan, sm)?;
    let words = sm.diag_idx.len()
        + sm.l_len.len()
        + sm.task_ptr.len()
        + sm.mult_idx.len()
        + sm.dst_off.len()
        + sm.dst.len();
    let info = UploadInfo {
        buffers: 6,
        index_bytes: 4 * words,
        tasks: sm.num_tasks(),
        nnz: sm.nnz,
    };
    let mut work: Vec<ColumnWork> = Vec::new();
    let cycles = (0..plan.num_levels())
        .map(|level| account_level(plan, level, &mut work))
        .collect();
    let state = VirtualState {
        plan: plan.clone(),
        nnz: sm.nnz,
        diag_idx: sm.diag_idx.clone(),
        l_len: sm.l_len.clone(),
        task_ptr: sm.task_ptr.clone(),
        mult_idx: sm.mult_idx.clone(),
        dst_off: sm.dst_off.clone(),
        dst: sm.dst.clone(),
        cycles,
    };
    Ok((state, info))
}

impl VirtualState {
    /// Divide phase of one column off the uploaded buffers — pivot check
    /// plus in-place L normalization, shared by both backends so their
    /// serialization can never diverge. Returns the column's L length.
    fn divide_column(
        &self,
        j: usize,
        vals: &mut [f64],
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<usize> {
        let d = self.diag_idx[j] as usize;
        let ll = self.l_len[j] as usize;
        let pivot = vals[d];
        if pivot == 0.0 || !pivot.is_finite() {
            return Err(crate::numeric::singular_pivot(j));
        }
        mon.observe(pivot);
        for v in &mut vals[d + 1..=d + ll] {
            *v /= pivot;
        }
        Ok(ll)
    }

    /// Assemble one report row from a planned launch and the interpreted
    /// trip counts, reading back the upload-time cycle accounts.
    fn launch_row(&self, launch: &PlannedLaunch, div_elems: u64, mac_elems: u64) -> LaunchExec {
        let (executed_cycles, simulated_cycles) = self.cycles[launch.level];
        LaunchExec {
            level: launch.level,
            kernel: launch.kernel.clone(),
            mode: self.plan.level_plan(launch.level).mode,
            columns: launch.columns,
            launches: launch.launches,
            div_elems,
            mac_elems,
            executed_cycles,
            simulated_cycles,
        }
    }

    /// Interpret one launch: the indexed kernel body over the level's
    /// columns, ascending — divide phase, then the column's MAC tasks in
    /// task order — exactly the simulator's serialization. Returns
    /// `(div_elems, mac_elems)` actually processed.
    fn run_launch(
        &self,
        level: usize,
        vals: &mut [f64],
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<(u64, u64)> {
        let (mut div_elems, mut mac_elems) = (0u64, 0u64);
        for &j in &self.plan.levels().levels[level] {
            let j = j as usize;
            let ll = self.divide_column(j, vals, mon)?;
            div_elems += ll as u64;
            let ls = self.diag_idx[j] as usize + 1;
            for t in self.task_ptr[j] as usize..self.task_ptr[j + 1] as usize {
                let mult = vals[self.mult_idx[t] as usize];
                if mult == 0.0 {
                    continue;
                }
                let off = self.dst_off[t] as usize;
                for i in 0..ll {
                    let lij = vals[ls + i];
                    vals[self.dst[off + i] as usize] -= lij * mult;
                }
                mac_elems += ll as u64;
            }
        }
        Ok((div_elems, mac_elems))
    }

    /// Batched divide phase: per plane the pivot check and L normalization
    /// of [`VirtualState::divide_column`], plane dimension innermost over
    /// the interleaved layout (`vals[idx * b + p]`).
    fn divide_column_planes(
        &self,
        j: usize,
        vals: &mut [f64],
        b: usize,
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<usize> {
        let d = self.diag_idx[j] as usize;
        let ll = self.l_len[j] as usize;
        for p in 0..b {
            let pivot = vals[d * b + p];
            if pivot == 0.0 || !pivot.is_finite() {
                return Err(crate::numeric::singular_pivot(j));
            }
            mon.observe(pivot);
        }
        for idx in d + 1..=d + ll {
            for p in 0..b {
                vals[idx * b + p] /= vals[d * b + p];
            }
        }
        Ok(ll)
    }

    /// Batched launch interpretation: one walk of the level's columns
    /// serves every plane — the uploaded index buffers are read once per
    /// element, the inner loop runs over the contiguous plane dimension.
    /// Per plane the operation order is exactly [`VirtualState::run_launch`]'s,
    /// so each plane's values are bit-identical to a single-plane execute.
    /// Trip counts are totals across planes (the zero-multiplier skip is
    /// per plane, as in the single-plane kernel's early-out).
    fn run_launch_planes(
        &self,
        level: usize,
        vals: &mut [f64],
        b: usize,
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<(u64, u64)> {
        let (mut div_elems, mut mac_elems) = (0u64, 0u64);
        for &j in &self.plan.levels().levels[level] {
            let j = j as usize;
            let ll = self.divide_column_planes(j, vals, b, mon)?;
            div_elems += (ll * b) as u64;
            let ls = self.diag_idx[j] as usize + 1;
            for t in self.task_ptr[j] as usize..self.task_ptr[j + 1] as usize {
                let mbase = self.mult_idx[t] as usize * b;
                let mut live = 0u64;
                for p in 0..b {
                    if vals[mbase + p] != 0.0 {
                        live += 1;
                    }
                }
                if live == 0 {
                    continue;
                }
                let off = self.dst_off[t] as usize;
                for i in 0..ll {
                    let lbase = (ls + i) * b;
                    let dbase = self.dst[off + i] as usize * b;
                    for p in 0..b {
                        // The multiplier element is never a destination of
                        // its own task (destinations sit strictly below
                        // the pivot row), so the per-plane re-read sees
                        // one stable value for the whole task.
                        let mult = vals[mbase + p];
                        if mult != 0.0 {
                            vals[dbase + p] -= vals[lbase + p] * mult;
                        }
                    }
                }
                mac_elems += live * ll as u64;
            }
        }
        Ok((div_elems, mac_elems))
    }
}

impl DeviceExecutor for VirtualDevice {
    fn name(&self) -> &'static str {
        "virtual"
    }

    fn upload_pattern(&mut self, plan: &FactorPlan, sm: &ScatterMap) -> anyhow::Result<UploadInfo> {
        let (state, info) = bind_buffers(plan, sm)?;
        self.state = Some(state);
        Ok(info)
    }

    fn execute(
        &mut self,
        sched: &LaunchSchedule,
        vals: &mut [f64],
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<ExecReport> {
        let st = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no pattern uploaded to the virtual device"))?;
        check_schedule(&st.plan, sched, vals.len(), st.nnz)?;
        let mut per_launch = Vec::with_capacity(sched.launches.len());
        for launch in &sched.launches {
            let (div_elems, mac_elems) = st.run_launch(launch.level, vals, mon)?;
            per_launch.push(st.launch_row(launch, div_elems, mac_elems));
        }
        Ok(ExecReport {
            backend: self.name(),
            per_launch,
        })
    }

    fn execute_planes(
        &mut self,
        sched: &LaunchSchedule,
        planes: &mut ValuePlanes,
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<ExecReport> {
        let st = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no pattern uploaded to the virtual device"))?;
        check_schedule(&st.plan, sched, planes.nnz(), st.nnz)?;
        let b = planes.planes();
        let vals = planes.data_mut();
        let mut per_launch = Vec::with_capacity(sched.launches.len());
        for launch in &sched.launches {
            let (div_elems, mac_elems) = st.run_launch_planes(launch.level, vals, b, mon)?;
            per_launch.push(st.launch_row(launch, div_elems, mac_elems));
        }
        Ok(ExecReport {
            backend: self.name(),
            per_launch,
        })
    }
}

/// The PJRT executor backend: binds the scatter map as device-resident
/// `u32` buffers and dispatches the AOT `level_update_{B}x{N}` artifact
/// ladder through [`super::Runtime`] — one batched rank-1 update per
/// `(column, task-tile, width-tile)`, tiled into the ladder's static
/// shapes. The divide phase runs on the host in f64 (the ladder carries
/// no divide kernel; a real offload would fuse it into the launch), and
/// dense tails keep their separate entry point
/// (`Runtime::dense_tail_solve`). Artifact execution is f32, so values
/// match the f64 engines to single precision — the conformance contract
/// (bit-identity) binds the [`VirtualDevice`], not this backend.
///
/// Without the vendored `xla` bindings ([`super::PJRT_ENABLED`] false),
/// [`PjrtDevice::new`] fails at runtime load — before any pattern is
/// touched — which is the CI "stub path": the dispatch code compiles and
/// the tests self-skip.
#[cfg(feature = "pjrt")]
#[derive(Debug)]
pub struct PjrtDevice {
    rt: super::Runtime,
    state: Option<VirtualState>,
}

#[cfg(feature = "pjrt")]
impl PjrtDevice {
    /// Create a CPU PJRT client and compile the artifact ladder from
    /// `dir`. Errors without the `xla` bindings or compiled artifacts.
    pub fn new(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let rt = super::Runtime::load(dir)?;
        Ok(PjrtDevice { rt, state: None })
    }
}

#[cfg(feature = "pjrt")]
impl DeviceExecutor for PjrtDevice {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn upload_pattern(&mut self, plan: &FactorPlan, sm: &ScatterMap) -> anyhow::Result<UploadInfo> {
        let (state, info) = bind_buffers(plan, sm)?;
        self.state = Some(state);
        Ok(info)
    }

    fn execute(
        &mut self,
        sched: &LaunchSchedule,
        vals: &mut [f64],
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<ExecReport> {
        let st = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no pattern uploaded to the pjrt device"))?;
        check_schedule(&st.plan, sched, vals.len(), st.nnz)?;
        for launch in &sched.launches {
            anyhow::ensure!(
                self.rt.names().contains(&launch.kernel.as_str()),
                "schedule needs artifact {}, not loaded (have {:?})",
                launch.kernel,
                self.rt.names()
            );
        }
        let (max_b, max_n) = LEVEL_SIZES[LEVEL_SIZES.len() - 1];
        let mut per_launch = Vec::with_capacity(sched.launches.len());
        for launch in &sched.launches {
            let (mut div_elems, mut mac_elems) = (0u64, 0u64);
            for &j in &st.plan.levels().levels[launch.level] {
                let j = j as usize;
                let d = st.diag_idx[j] as usize;
                let ll = st.divide_column(j, vals, mon)?;
                div_elems += ll as u64;
                let (t0, t1) = (st.task_ptr[j] as usize, st.task_ptr[j + 1] as usize);
                if ll == 0 || t0 == t1 {
                    continue;
                }
                let lvals32: Vec<f32> = vals[d + 1..=d + ll].iter().map(|&v| v as f32).collect();
                // Tile the column's task batch into the ladder's static
                // shapes: tasks over rows, the L run over columns.
                let mut tb = t0;
                while tb < t1 {
                    let b = (t1 - tb).min(max_b);
                    let mut c0 = 0usize;
                    while c0 < ll {
                        let nw = (ll - c0).min(max_n);
                        let mut x = vec![0f32; b * nw];
                        let mut s = vec![0f32; b];
                        for r in 0..b {
                            let t = tb + r;
                            s[r] = vals[st.mult_idx[t] as usize] as f32;
                            let off = st.dst_off[t] as usize + c0;
                            for (c, xv) in x[r * nw..(r + 1) * nw].iter_mut().enumerate() {
                                *xv = vals[st.dst[off + c] as usize] as f32;
                            }
                        }
                        let out = self.rt.level_update(&x, &lvals32[c0..c0 + nw], &s, b, nw)?;
                        for r in 0..b {
                            let t = tb + r;
                            let off = st.dst_off[t] as usize + c0;
                            for (c, &ov) in out[r * nw..(r + 1) * nw].iter().enumerate() {
                                vals[st.dst[off + c] as usize] = ov as f64;
                            }
                        }
                        mac_elems += (b * nw) as u64;
                        c0 += nw;
                    }
                    tb += b;
                }
            }
            per_launch.push(st.launch_row(launch, div_elems, mac_elems));
        }
        Ok(ExecReport {
            backend: self.name(),
            per_launch,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::glu3;
    use crate::gpusim::{simulate_factorization, DeviceConfig, Policy};
    use crate::sparse::gen;
    use crate::symbolic::{symbolic_fill, SymbolicFill};

    fn setup() -> (SymbolicFill, FactorPlan) {
        let g = gen::grid2d(14, 14, 5);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let sym = symbolic_fill(&a).unwrap();
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());
        (sym, plan)
    }

    #[test]
    fn virtual_device_matches_simulator_bit_for_bit() {
        let (sym, plan) = setup();
        let sched = plan.launch_schedule().clone();
        let mut dev = VirtualDevice::new();
        let info = dev.upload_pattern(&plan, plan.scatter(&sym.filled)).unwrap();
        assert_eq!(info.nnz, sym.filled.nnz());
        assert!(info.index_bytes > 0 && info.buffers == 6);

        let mut lu = sym.filled.clone();
        let report = dev.execute(&sched, lu.values_mut(), &mut PivotMonitor::new()).unwrap();

        let (simf, simrep) = simulate_factorization(
            &sym,
            plan.levels(),
            &Policy::glu3(),
            &DeviceConfig::titan_x(),
        )
        .unwrap();
        assert_eq!(lu.values(), simf.lu.values(), "executor must be bit-identical");

        // accounting: one row per level, the full-model side reconciles
        // exactly with the simulator's per-level charges
        assert_eq!(report.per_launch.len(), plan.num_levels());
        assert_eq!(report.backend, "virtual");
        assert_eq!(report.mode_histogram(), plan.mode_histogram());
        assert_eq!(report.simulated_cycles(), simrep.kernel_cycles);
        assert_eq!(report.total_launches(), sched.total_launches());
        for (row, timing) in report.per_launch.iter().zip(&simrep.per_level) {
            assert_eq!(row.simulated_cycles, timing.cycles);
            assert_eq!(row.mode, timing.mode);
            assert!(row.executed_cycles > 0);
        }
        assert!(report.executed_cycles() > 0);
        // a second execution on restamped values reuses the same upload
        let mut lu2 = sym.filled.clone();
        for v in lu2.values_mut() {
            *v *= 1.5;
        }
        dev.execute(&sched, lu2.values_mut(), &mut PivotMonitor::new()).unwrap();
    }

    #[test]
    fn batched_execute_planes_is_bit_identical_to_looped_execute() {
        let (sym, plan) = setup();
        let sched = plan.launch_schedule().clone();
        let mut dev = VirtualDevice::new();
        dev.upload_pattern(&plan, plan.scatter(&sym.filled)).unwrap();

        for b in [1usize, 4, 16] {
            let mut planes = ValuePlanes::new(b, sym.filled.nnz());
            let mut looped = Vec::with_capacity(b);
            for p in 0..b {
                let mut lu = sym.filled.clone();
                for v in lu.values_mut() {
                    *v *= 1.0 + 0.01 * p as f64;
                }
                planes.set_plane(p, lu.values());
                dev.execute(&sched, lu.values_mut(), &mut PivotMonitor::new()).unwrap();
                looped.push(lu);
            }
            let rep = dev
                .execute_planes(&sched, &mut planes, &mut PivotMonitor::new())
                .unwrap();
            assert_eq!(rep.backend, "virtual");
            assert_eq!(rep.per_launch.len(), plan.num_levels());
            for (p, lu) in looped.iter().enumerate() {
                assert_eq!(
                    planes.plane(p),
                    lu.values(),
                    "plane {p} of batch {b} must be bit-identical to its looped run"
                );
            }
        }

        // a singular plane in the middle of the batch surfaces the typed error
        let mut planes = ValuePlanes::new(3, sym.filled.nnz());
        planes.set_plane(0, sym.filled.values());
        planes.set_plane(2, sym.filled.values());
        let err = dev
            .execute_planes(&sched, &mut planes, &mut PivotMonitor::new())
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<crate::numeric::GluError>(),
                Some(crate::numeric::GluError::NumericallySingular { .. })
            ),
            "{err}"
        );
    }

    #[test]
    fn executor_rejects_corrupted_schedules_before_touching_values() {
        let (sym, plan) = setup();
        assert!(plan.num_levels() >= 2, "fixture must be multi-level");
        let mut dev = VirtualDevice::new();
        dev.upload_pattern(&plan, plan.scatter(&sym.filled)).unwrap();
        let good = plan.launch_schedule().clone();
        let mut lu = sym.filled.clone();
        let before = lu.values().to_vec();

        // wrong level order
        let mut bad = good.clone();
        bad.launches.swap(0, 1);
        let err = dev.execute(&bad, lu.values_mut(), &mut PivotMonitor::new()).unwrap_err();
        assert!(err.to_string().contains("order"), "{err}");
        assert_eq!(lu.values(), &before[..], "values must be untouched");

        // truncated schedule
        let mut bad = good.clone();
        bad.launches.pop();
        assert!(dev.execute(&bad, lu.values_mut(), &mut PivotMonitor::new()).is_err());
        assert_eq!(lu.values(), &before[..]);

        // a launch claiming the wrong column count (foreign pattern)
        let mut bad = good.clone();
        bad.launches[0].columns += 1;
        let err = dev.execute(&bad, lu.values_mut(), &mut PivotMonitor::new()).unwrap_err();
        assert!(err.to_string().contains("mismatch"), "{err}");
        assert_eq!(lu.values(), &before[..]);

        // an unknown kernel name
        let mut bad = good.clone();
        bad.launches[0].kernel = "level_update_1x1".into();
        assert!(dev.execute(&bad, lu.values_mut(), &mut PivotMonitor::new()).is_err());
        assert_eq!(lu.values(), &before[..]);

        // a value buffer of the wrong length (mismatched pattern)
        let mut short = vec![1.0; sym.filled.nnz() - 1];
        assert!(dev.execute(&good, &mut short, &mut PivotMonitor::new()).is_err());

        // the untouched schedule still executes fine afterwards
        dev.execute(&good, lu.values_mut(), &mut PivotMonitor::new()).unwrap();
    }

    #[test]
    fn upload_rejects_out_of_range_scatter_indices() {
        let (sym, plan) = setup();
        let sm = plan.scatter(&sym.filled);
        assert!(!sm.dst.is_empty(), "fixture must have MAC work");
        let mut dev = VirtualDevice::new();

        // multiplier value index beyond the pattern
        let mut bad = sm.clone();
        bad.mult_idx[0] = bad.nnz as u32;
        assert!(dev.upload_pattern(&plan, &bad).is_err());

        // destination value index beyond the pattern
        let mut bad = sm.clone();
        let last = bad.dst.len() - 1;
        bad.dst[last] = bad.nnz as u32;
        assert!(dev.upload_pattern(&plan, &bad).is_err());

        // truncated task arrays
        let mut bad = sm.clone();
        bad.mult_idx.pop();
        assert!(dev.upload_pattern(&plan, &bad).is_err());

        // the honest map binds
        assert!(dev.upload_pattern(&plan, sm).is_ok());
    }

    #[test]
    fn execute_requires_an_uploaded_pattern() {
        let (sym, plan) = setup();
        let mut dev = VirtualDevice::new();
        let sched = plan.launch_schedule().clone();
        let mut lu = sym.filled.clone();
        let err = dev.execute(&sched, lu.values_mut(), &mut PivotMonitor::new()).unwrap_err();
        assert!(err.to_string().contains("uploaded"), "{err}");
    }

    #[test]
    fn schedule_from_a_different_pattern_is_rejected() {
        let (sym, plan) = setup();
        let other = {
            let a = gen::netlist(120, 5, 8, 0.1, 2, 0.2, 31);
            let f = symbolic_fill(&a).unwrap();
            let deps = glu3::detect(&f.filled);
            FactorPlan::build(&f, &deps, &Policy::glu3(), &DeviceConfig::titan_x())
        };
        let mut dev = VirtualDevice::new();
        dev.upload_pattern(&plan, plan.scatter(&sym.filled)).unwrap();
        let foreign = other.launch_schedule().clone();
        let mut lu = sym.filled.clone();
        let before = lu.values().to_vec();
        assert!(dev.execute(&foreign, lu.values_mut(), &mut PivotMonitor::new()).is_err());
        assert_eq!(lu.values(), &before[..]);
    }

    #[test]
    fn zero_pivot_surfaces_as_an_error() {
        let (sym, plan) = setup();
        let mut dev = VirtualDevice::new();
        dev.upload_pattern(&plan, plan.scatter(&sym.filled)).unwrap();
        let mut lu = sym.filled.clone();
        for v in lu.values_mut() {
            *v = 0.0;
        }
        let err = dev.execute(plan.launch_schedule(), lu.values_mut(), &mut PivotMonitor::new()).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<crate::numeric::GluError>(),
                Some(crate::numeric::GluError::NumericallySingular { .. })
            ),
            "{err}"
        );
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_requires_the_feature() {
        let err = create_backend(ExecBackend::Pjrt).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[cfg(all(feature = "pjrt", not(feature = "xla")))]
    #[test]
    fn pjrt_backend_surfaces_runtime_load_failure() {
        // The stub path: the dispatch code compiles, construction fails at
        // runtime load with a diagnostic instead of a panic.
        let err = PjrtDevice::new(std::env::temp_dir().join("glu3_no_artifacts_here"))
            .err()
            .expect("stub runtime must refuse to load");
        assert!(err.to_string().contains("xla"), "{err}");
    }

    #[test]
    fn backend_labels() {
        assert_eq!(ExecBackend::Virtual.label(), "virtual");
        assert_eq!(ExecBackend::Pjrt.label(), "pjrt");
        assert_eq!(ExecBackend::default(), ExecBackend::Virtual);
        assert!(create_backend(ExecBackend::Virtual).is_ok());
    }
}
