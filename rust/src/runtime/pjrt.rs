//! Real PJRT runtime (requires the `pjrt` feature and the `xla` bindings).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

use super::{LEVEL_SIZES, TAIL_SIZES};

/// A loaded PJRT runtime with compiled executables.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("executables", &self.executables.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl Runtime {
    /// Create a CPU PJRT client and compile every artifact in `dir`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for entry in std::fs::read_dir(&dir)
            .with_context(|| format!("reading artifact dir {}", dir.display()))?
        {
            let path = entry?.path();
            let Some(fname) = path.file_name().and_then(|s| s.to_str()) else {
                continue;
            };
            let Some(name) = fname.strip_suffix(".hlo.txt") else {
                continue;
            };
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            executables.insert(name.to_string(), exe);
        }
        anyhow::ensure!(
            !executables.is_empty(),
            "no *.hlo.txt artifacts in {} — run `make artifacts`",
            dir.display()
        );
        Ok(Runtime {
            client,
            executables,
            dir,
        })
    }

    /// Artifact names available.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    fn exe(&self, name: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not loaded (have {:?})", self.names()))
    }

    /// Execute an artifact on literal inputs, returning the tuple elements.
    fn run(&self, name: &str, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self.exe(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} result"))?;
        // aot.py lowers with return_tuple=True.
        Ok(lit.to_tuple()?)
    }

    /// The Eq. 3 batched MAC on the PJRT path: `x (b×n) − s ⊗ u`.
    ///
    /// Pads into the smallest `level_update_{B}x{N}` variant that fits;
    /// errors if `b`/`n` exceed the largest.
    pub fn level_update(
        &self,
        x: &[f32],
        u: &[f32],
        s: &[f32],
        b: usize,
        n: usize,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(x.len() == b * n && u.len() == n && s.len() == b, "shape mismatch");
        let (pb, pn) = LEVEL_SIZES
            .iter()
            .copied()
            .find(|&(lb, ln)| b <= lb && n <= ln)
            .ok_or_else(|| anyhow::anyhow!("batch {b}x{n} exceeds artifact ladder"))?;
        let name = format!("level_update_{pb}x{pn}");

        let mut xp = vec![0f32; pb * pn];
        for r in 0..b {
            xp[r * pn..r * pn + n].copy_from_slice(&x[r * n..(r + 1) * n]);
        }
        let mut up = vec![0f32; pn];
        up[..n].copy_from_slice(u);
        let mut sp = vec![0f32; pb];
        sp[..b].copy_from_slice(s);

        let lx = xla::Literal::vec1(&xp).reshape(&[pb as i64, pn as i64])?;
        let lu = xla::Literal::vec1(&up);
        let ls = xla::Literal::vec1(&sp);
        let out = self.run(&name, &[lx, lu, ls])?;
        let full = out[0].to_vec::<f32>()?;
        let mut result = vec![0f32; b * n];
        for r in 0..b {
            result[r * n..(r + 1) * n].copy_from_slice(&full[r * pn..r * pn + n]);
        }
        Ok(result)
    }

    /// Dense-tail factor+solve on the PJRT path: returns `(lu, x)` for the
    /// `t×t` system, padding into the artifact ladder with an identity
    /// bottom-right block (so the padded pivots are 1 and the pad solves to
    /// the padded RHS zeros).
    pub fn dense_tail_solve(
        &self,
        a: &[f32],
        rhs: &[f32],
        t: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(a.len() == t * t && rhs.len() == t, "shape mismatch");
        let pt = TAIL_SIZES
            .iter()
            .copied()
            .find(|&s| t <= s)
            .ok_or_else(|| anyhow::anyhow!("tail {t} exceeds artifact ladder"))?;
        let name = format!("dense_tail_{pt}");

        let mut ap = vec![0f32; pt * pt];
        for r in 0..t {
            ap[r * pt..r * pt + t].copy_from_slice(&a[r * t..(r + 1) * t]);
        }
        for d in t..pt {
            ap[d * pt + d] = 1.0; // identity pad
        }
        let mut bp = vec![0f32; pt];
        bp[..t].copy_from_slice(rhs);

        let la = xla::Literal::vec1(&ap).reshape(&[pt as i64, pt as i64])?;
        let lb = xla::Literal::vec1(&bp);
        let out = self.run(&name, &[la, lb])?;
        let lu_full = out[0].to_vec::<f32>()?;
        let x_full = out[1].to_vec::<f32>()?;
        let mut lu = vec![0f32; t * t];
        for r in 0..t {
            lu[r * t..(r + 1) * t].copy_from_slice(&lu_full[r * pt..r * pt + t]);
        }
        Ok((lu, x_full[..t].to_vec()))
    }

    /// Lower a [`crate::plan::FactorPlan`] to its kernel-launch sequence
    /// and verify every kernel it names is compiled in this runtime — the
    /// executable half of the ROADMAP's GPU-offload path: the returned
    /// schedule walks the plan's levels exactly as the device loop will.
    pub fn lower_plan(
        &self,
        plan: &crate::plan::FactorPlan,
    ) -> anyhow::Result<super::LaunchSchedule> {
        let sched = super::lower_plan(plan);
        for name in sched.kernels_used() {
            anyhow::ensure!(
                self.executables.contains_key(name),
                "plan needs artifact {name}, not loaded (have {:?})",
                self.names()
            );
        }
        Ok(sched)
    }

    /// The 2×2 quickstart smoke graph: `matmul(x, y) + 2`.
    pub fn quickstart(&self, x: [f32; 4], y: [f32; 4]) -> anyhow::Result<[f32; 4]> {
        let lx = xla::Literal::vec1(&x).reshape(&[2, 2])?;
        let ly = xla::Literal::vec1(&y).reshape(&[2, 2])?;
        let out = self.run("quickstart", &[lx, ly])?;
        let v = out[0].to_vec::<f32>()?;
        Ok([v[0], v[1], v[2], v[3]])
    }
}

#[cfg(test)]
mod tests {
    use super::super::default_artifact_dir;
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = default_artifact_dir();
        if !dir.join("quickstart.hlo.txt").exists() {
            eprintln!("skipping runtime tests: artifacts not built (make artifacts)");
            return None;
        }
        Some(Runtime::load(dir).expect("runtime load"))
    }

    #[test]
    fn quickstart_numbers() {
        let Some(rt) = runtime() else { return };
        let out = rt
            .quickstart([1.0, 2.0, 3.0, 4.0], [1.0, 1.0, 1.0, 1.0])
            .unwrap();
        assert_eq!(out, [5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn level_update_matches_native() {
        let Some(rt) = runtime() else { return };
        for (b, n) in [(1usize, 1usize), (5, 40), (64, 256), (100, 1000)] {
            let x: Vec<f32> = (0..b * n).map(|i| (i % 17) as f32 - 8.0).collect();
            let u: Vec<f32> = (0..n).map(|i| (i % 5) as f32 * 0.25).collect();
            let s: Vec<f32> = (0..b).map(|i| (i % 3) as f32 - 1.0).collect();
            let got = rt.level_update(&x, &u, &s, b, n).unwrap();
            for r in 0..b {
                for c in 0..n {
                    let want = x[r * n + c] - s[r] * u[c];
                    let g = got[r * n + c];
                    assert!((g - want).abs() < 1e-5, "({r},{c}): {g} vs {want}");
                }
            }
        }
    }

    #[test]
    fn level_update_rejects_oversize() {
        let Some(rt) = runtime() else { return };
        let b = 300usize;
        let x = vec![0f32; b];
        let u = vec![0f32; 1];
        let s = vec![0f32; b];
        assert!(rt.level_update(&x, &u, &s, b, 1).is_err());
    }

    #[test]
    fn dense_tail_solves_against_rust_oracle() {
        let Some(rt) = runtime() else { return };
        for t in [3usize, 17, 64, 100] {
            // column diagonally dominant system
            let mut rng = crate::util::Rng::new(t as u64);
            let mut a = vec![0f64; t * t];
            for r in 0..t {
                for c in 0..t {
                    if r != c {
                        a[r * t + c] = rng.range_f64(-1.0, 1.0);
                    }
                }
            }
            for d in 0..t {
                let col_sum: f64 = (0..t).filter(|&r| r != d).map(|r| a[r * t + d].abs()).sum();
                a[d * t + d] = col_sum + 1.0;
            }
            let rhs: Vec<f64> = (0..t).map(|i| ((i % 7) as f64) - 3.0).collect();

            let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let rhs32: Vec<f32> = rhs.iter().map(|&v| v as f32).collect();
            let (_, x) = rt.dense_tail_solve(&a32, &rhs32, t).unwrap();

            let want = crate::numeric::dense::solve(&a, t, &rhs).unwrap();
            for (g, w) in x.iter().zip(&want) {
                assert!(
                    (*g as f64 - w).abs() < 1e-3 * (1.0 + w.abs()),
                    "t={t}: {g} vs {w}"
                );
            }
        }
    }
}
