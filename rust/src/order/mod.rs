//! Preprocessing orderings: the CPU-side front half of the GLU2.0/3.0 flow
//! (Fig. 5 of the paper): *"MC64 and AMD (Approximate minimum degree)
//! algorithms in order to reduce the number of final nonzero elements, as is
//! done in NICSLU"*.
//!
//! - [`mc64`] — maximum-transversal permutation plus row/column equilibration
//!   scaling: a faithful stand-in for HSL MC64's role (a zero-free, large
//!   diagonal so factorization needs no numerical pivoting).
//! - [`amd`] — approximate minimum degree fill-reducing ordering on the
//!   pattern of `A + Aᵀ` (quotient-graph implementation).
//! - [`rcm`] — reverse Cuthill–McKee bandwidth reducer (extra baseline used
//!   by the ablation benches).

pub mod amd;
pub mod mc64;
pub mod rcm;

use crate::sparse::{Csc, Permutation};

/// The combined preprocessing result applied to a matrix before symbolic
/// analysis: `A' = Pfill · Prow · Dr · A · Dc · Pfillᵀ`.
#[derive(Debug, Clone)]
pub struct Preprocessed {
    /// Preprocessed matrix ready for symbolic analysis.
    pub a: Csc,
    /// Row permutation (matching ∘ fill-reducing), scatter form.
    pub row_perm: Permutation,
    /// Column permutation (fill-reducing), scatter form.
    pub col_perm: Permutation,
    /// Row scaling applied (1.0s when scaling disabled).
    pub row_scale: Vec<f64>,
    /// Column scaling applied.
    pub col_scale: Vec<f64>,
}

/// Which fill-reducing ordering to run after the matching step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FillOrdering {
    #[default]
    Amd,
    Rcm,
    Natural,
}

/// Full preprocessing pipeline: matching + scaling, then fill ordering.
pub fn preprocess(a: &Csc, ordering: FillOrdering, scale: bool) -> anyhow::Result<Preprocessed> {
    anyhow::ensure!(a.nrows() == a.ncols(), "matrix must be square");
    let n = a.nrows();

    // 1. MC64-style step: permute rows to put large entries on the diagonal,
    //    optionally equilibrate.
    let m = mc64::match_and_scale(a, scale)?;
    let matched = a.permute_scale(
        m.row_perm.as_scatter(),
        Permutation::identity(n).as_scatter(),
        if scale { Some(&m.row_scale) } else { None },
        if scale { Some(&m.col_scale) } else { None },
    );

    // 2. Fill-reducing symmetric ordering on A + A^T of the matched matrix.
    let fill = match ordering {
        FillOrdering::Amd => amd::amd_order(&matched)?,
        FillOrdering::Rcm => rcm::rcm_order(&matched)?,
        FillOrdering::Natural => Permutation::identity(n),
    };
    let a2 = matched.permute(fill.as_scatter(), fill.as_scatter());

    Ok(Preprocessed {
        a: a2,
        row_perm: m.row_perm.then(&fill),
        col_perm: fill,
        row_scale: m.row_scale,
        col_scale: m.col_scale,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn preprocess_preserves_solvability() {
        let a = gen::netlist(200, 6, 12, 0.05, 2, 0.2, 42);
        for ord in [FillOrdering::Amd, FillOrdering::Rcm, FillOrdering::Natural] {
            let p = preprocess(&a, ord, true).unwrap();
            assert_eq!(p.a.nrows(), 200);
            assert!(p.a.has_full_diagonal(), "{ord:?} lost the diagonal");
            // Permutations must be consistent: A'(pr[i], pc[j]) = r[i]*A(i,j)*c[j]
            let pr = p.row_perm.as_scatter();
            let pc = p.col_perm.as_scatter();
            for (r, c, want) in [(0usize, 0usize, a.get(0, 0)), (5, 3, a.get(5, 3))] {
                let got = p.a.get(pr[r], pc[c]);
                let scaled = want * p.row_scale[r] * p.col_scale[c];
                assert!(
                    (got - scaled).abs() <= 1e-12 * (1.0 + scaled.abs()),
                    "{ord:?}: ({r},{c}) {got} vs {scaled}"
                );
            }
        }
    }
}
