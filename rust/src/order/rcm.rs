//! Reverse Cuthill–McKee bandwidth-reducing ordering.
//!
//! Used as an ablation baseline against AMD (DESIGN.md §4): RCM minimizes
//! bandwidth rather than fill, which on circuit matrices yields deeper
//! dependency chains — the benches use it to show how ordering interacts
//! with GLU levelization.

use std::collections::VecDeque;

use crate::sparse::{Csc, Permutation};

/// Compute an RCM ordering of `a`'s symmetrized pattern.
pub fn rcm_order(a: &Csc) -> anyhow::Result<Permutation> {
    anyhow::ensure!(a.nrows() == a.ncols(), "matrix must be square");
    let n = a.nrows();
    let sym = a.plus_transpose_pattern();
    let deg = |v: usize| sym.col(v).0.len();

    let mut visited = vec![false; n];
    let mut order: Vec<usize> = Vec::with_capacity(n);

    // Process each connected component from a pseudo-peripheral start node.
    for start in 0..n {
        if visited[start] {
            continue;
        }
        let root = pseudo_peripheral(&sym, start);
        let mut q = VecDeque::new();
        visited[root] = true;
        q.push_back(root);
        while let Some(v) = q.pop_front() {
            order.push(v);
            let (rows, _) = sym.col(v);
            let mut nbrs: Vec<usize> = rows.iter().copied().filter(|&u| !visited[u]).collect();
            nbrs.sort_unstable_by_key(|&u| deg(u));
            for u in nbrs {
                visited[u] = true;
                q.push_back(u);
            }
        }
    }
    order.reverse(); // the "reverse" in RCM
    Permutation::from_order(&order)
}

/// Find a pseudo-peripheral node by repeated BFS to the farthest level.
fn pseudo_peripheral(sym: &Csc, start: usize) -> usize {
    let n = sym.nrows();
    let mut node = start;
    let mut last_ecc = 0usize;
    for _ in 0..8 {
        let mut dist = vec![usize::MAX; n];
        let mut q = VecDeque::new();
        dist[node] = 0;
        q.push_back(node);
        let mut far = node;
        while let Some(v) = q.pop_front() {
            let (rows, _) = sym.col(v);
            for &u in rows {
                if u != v && dist[u] == usize::MAX {
                    dist[u] = dist[v] + 1;
                    if dist[u] > dist[far] {
                        far = u;
                    }
                    q.push_back(u);
                }
            }
        }
        if dist[far] <= last_ecc {
            break;
        }
        last_ecc = dist[far];
        node = far;
    }
    node
}

/// Bandwidth of a matrix (max |i - j| over stored entries) — test metric.
pub fn bandwidth(a: &Csc) -> usize {
    let mut bw = 0usize;
    for c in 0..a.ncols() {
        let (rows, _) = a.col(c);
        for &r in rows {
            bw = bw.max(r.abs_diff(c));
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::Rng;

    #[test]
    fn rcm_reduces_bandwidth_on_shuffled_grid() {
        let a = gen::grid2d(12, 12, 4);
        // Shuffle to destroy natural banding.
        let mut rng = Rng::new(99);
        let mut p: Vec<usize> = (0..144).collect();
        rng.shuffle(&mut p);
        let shuffled = a.permute(&p, &p);
        let before = bandwidth(&shuffled);
        let r = rcm_order(&shuffled).unwrap();
        let after = bandwidth(&shuffled.permute(r.as_scatter(), r.as_scatter()));
        assert!(after < before / 2, "bandwidth {before} -> {after}");
    }

    #[test]
    fn handles_disconnected_components() {
        // Two disjoint 2-cliques.
        let mut coo = crate::sparse::Coo::new(4, 4);
        for i in 0..4 {
            coo.push(i, i, 1.0);
        }
        coo.push(0, 1, -1.0);
        coo.push(1, 0, -1.0);
        coo.push(2, 3, -1.0);
        coo.push(3, 2, -1.0);
        let a = coo.to_csc();
        let p = rcm_order(&a).unwrap();
        assert_eq!(p.len(), 4);
    }
}
