//! Approximate minimum degree (AMD) fill-reducing ordering.
//!
//! Quotient-graph minimum-degree in the style of Amestoy–Davis–Duff:
//! eliminated pivots become *elements* whose variable lists stand in for the
//! clique their elimination created; degrees are maintained as the standard
//! AMD upper bound (|direct neighbors| + Σ |element lists|) instead of the
//! exact union size. Elements adjacent to the pivot are absorbed, keeping
//! element lists shallow. A dense-tail shortcut finishes the ordering once
//! the minimum degree reaches the number of remaining variables (the
//! remaining graph is a clique — its internal order is irrelevant to fill).
//!
//! Applied to the pattern of `A + Aᵀ` (GLU, like KLU/NICSLU, orders
//! unsymmetric circuit matrices through their symmetrized pattern).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sparse::{Csc, Permutation};

/// Compute an AMD ordering of `a`'s symmetrized pattern.
///
/// Returns a [`Permutation`] in scatter form (`perm[old] = new`), i.e. the
/// pivot eliminated first maps to position 0.
pub fn amd_order(a: &Csc) -> anyhow::Result<Permutation> {
    anyhow::ensure!(a.nrows() == a.ncols(), "matrix must be square");
    let n = a.nrows();
    if n == 0 {
        return Ok(Permutation::identity(0));
    }

    // Symmetrized adjacency without the diagonal.
    let sym = a.plus_transpose_pattern();
    let mut adj_var: Vec<Vec<u32>> = (0..n)
        .map(|c| {
            let (rows, _) = sym.col(c);
            rows.iter()
                .filter(|&&r| r != c)
                .map(|&r| r as u32)
                .collect()
        })
        .collect();
    let mut adj_el: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut elem_vars: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut elem_alive = vec![false; n];
    let mut eliminated = vec![false; n];
    let mut degree: Vec<usize> = adj_var.iter().map(|v| v.len()).collect();

    let mut heap: BinaryHeap<Reverse<(usize, u32)>> = (0..n)
        .map(|v| Reverse((degree[v], v as u32)))
        .collect();

    // Stamp marker for set operations.
    let mut mark = vec![0u32; n];
    let mut stamp = 0u32;
    // w-trick scratch: per-element |L_e \ L_p| counters.
    let mut w = vec![0u32; n];
    let mut wstamp = vec![0u32; n];

    let mut order: Vec<usize> = Vec::with_capacity(n); // order[k] = old index
    let mut remaining = n;

    while remaining > 0 {
        // Pop the minimum-degree live variable (lazy heap deletion).
        let p = loop {
            let Reverse((d, v)) = heap.pop().expect("heap exhausted with vars remaining");
            let v = v as usize;
            if !eliminated[v] && d == degree[v] {
                break v;
            }
        };

        // Dense-tail shortcut: remaining graph is (near-)complete.
        if degree[p] + 1 >= remaining {
            let mut rest: Vec<usize> = (0..n).filter(|&v| !eliminated[v]).collect();
            rest.sort_unstable_by_key(|&v| degree[v]);
            for v in rest {
                order.push(v);
                eliminated[v] = true;
            }
            remaining = 0;
            continue;
        }

        // --- Eliminate p: build L_p = exact neighbor variable set. ---
        stamp += 1;
        mark[p] = stamp;
        let mut lp: Vec<u32> = Vec::with_capacity(degree[p]);
        for &u in &adj_var[p] {
            let u_ = u as usize;
            if !eliminated[u_] && mark[u_] != stamp {
                mark[u_] = stamp;
                lp.push(u);
            }
        }
        for &e in &adj_el[p] {
            let e_ = e as usize;
            if !elem_alive[e_] {
                continue;
            }
            for &u in &elem_vars[e_] {
                let u_ = u as usize;
                if !eliminated[u_] && u_ != p && mark[u_] != stamp {
                    mark[u_] = stamp;
                    lp.push(u);
                }
            }
            // Absorb: element e's clique is now covered by element p.
            elem_alive[e_] = false;
            elem_vars[e_] = Vec::new();
        }
        adj_var[p] = Vec::new();
        adj_el[p] = Vec::new();

        // p becomes element p.
        elem_vars[p] = lp.clone();
        elem_alive[p] = true;
        eliminated[p] = true;
        order.push(p);
        remaining -= 1;

        // --- Amestoy–Davis–Duff w-trick: for every element e adjacent to a
        // variable of L_p, compute |L_e \ L_p| exactly in aggregate time
        // O(Σ |adj_el|): initialize w[e] = |L_e| on first touch, then
        // decrement once per member of L_e ∩ L_p. ---
        for &vu in &lp {
            let v = vu as usize;
            for &e in &adj_el[v] {
                let e_ = e as usize;
                if !elem_alive[e_] || e_ == p {
                    continue;
                }
                if wstamp[e_] != stamp {
                    wstamp[e_] = stamp;
                    w[e_] = elem_vars[e_].len() as u32;
                }
                w[e_] -= 1;
            }
        }

        // --- Update every variable in L_p. ---
        for &vu in &lp {
            let v = vu as usize;
            // Prune direct neighbors now covered by element p (marked) or dead.
            adj_var[v].retain(|&u| {
                let u_ = u as usize;
                !eliminated[u_] && mark[u_] != stamp
            });
            // Drop dead + fully-absorbed elements; adopt p. An element whose
            // remaining variables are all inside L_p (w == 0) is covered by
            // element p — aggressive absorption.
            adj_el[v].retain(|&e| {
                let e_ = e as usize;
                if !elem_alive[e_] {
                    return false;
                }
                if wstamp[e_] == stamp && w[e_] == 0 {
                    elem_alive[e_] = false;
                    elem_vars[e_] = Vec::new();
                    return false;
                }
                true
            });
            adj_el[v].push(p as u32);

            // AMD approximate external degree:
            //   d = |A_v| + |L_p \ {v}| + Σ_{e ∈ E_v, e≠p} |L_e \ L_p|
            let mut d = adj_var[v].len() + (lp.len() - 1);
            for &e in &adj_el[v] {
                let e_ = e as usize;
                if e_ != p && elem_alive[e_] {
                    d += if wstamp[e_] == stamp {
                        w[e_] as usize
                    } else {
                        elem_vars[e_].len().saturating_sub(1)
                    };
                }
            }
            let d = d.min(remaining.saturating_sub(1));
            degree[v] = d;
            heap.push(Reverse((d, vu)));
        }
    }

    Permutation::from_order(&order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic::fillin::symbolic_fill;

    #[test]
    fn orders_are_valid_permutations() {
        for seed in 0..5 {
            let a = gen::netlist(150, 6, 10, 0.08, 2, 0.2, seed);
            let p = amd_order(&a).unwrap();
            assert_eq!(p.len(), 150);
            // from_order already validates; double-check scatter coverage.
            let mut seen = vec![false; 150];
            for &s in p.as_scatter() {
                assert!(!seen[s]);
                seen[s] = true;
            }
        }
    }

    #[test]
    fn path_graph_orders_like_nested_dissection() {
        // A path graph has a perfect elimination ordering with zero fill;
        // AMD must find *a* zero-fill order (leaves first).
        let a = gen::ladder(64, 64, 0, 1); // pure chain
        let p = amd_order(&a).unwrap();
        let pa = a.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&pa).unwrap();
        assert_eq!(
            f.filled.nnz(),
            a.nnz(),
            "chain graph must factor with zero fill under AMD"
        );
    }

    #[test]
    fn amd_beats_natural_on_grid() {
        let a = gen::grid2d(20, 20, 2);
        let natural_fill = symbolic_fill(&a).unwrap().filled.nnz();
        let p = amd_order(&a).unwrap();
        let pa = a.permute(p.as_scatter(), p.as_scatter());
        let amd_fill = symbolic_fill(&pa).unwrap().filled.nnz();
        assert!(
            (amd_fill as f64) < 0.8 * natural_fill as f64,
            "AMD fill {amd_fill} vs natural {natural_fill}"
        );
    }

    #[test]
    fn empty_and_tiny() {
        let a = Csc::identity(1);
        assert_eq!(amd_order(&a).unwrap().len(), 1);
        let a = Csc::identity(3);
        let p = amd_order(&a).unwrap();
        assert_eq!(p.len(), 3);
    }

    #[test]
    fn star_graph_center_last() {
        // Star: center node 0 connected to all others. Eliminating leaves
        // first is optimal; the center must come last.
        let mut coo = crate::sparse::Coo::new(10, 10);
        for i in 0..10 {
            coo.push(i, i, 4.0);
        }
        for i in 1..10 {
            coo.push(0, i, -1.0);
            coo.push(i, 0, -1.0);
        }
        let a = coo.to_csc();
        let p = amd_order(&a).unwrap();
        // The hub must survive until the final clique (last two nodes);
        // within that clique the order is fill-irrelevant.
        assert!(
            p.as_scatter()[0] >= 8,
            "hub eliminated too early: position {}",
            p.as_scatter()[0]
        );
    }
}
