//! MC64-style static pivoting: maximum transversal + equilibration scaling.
//!
//! HSL's MC64 computes a row permutation maximizing the product of diagonal
//! magnitudes (plus optimal scalings) via a weighted bipartite assignment.
//! This stand-in keeps the two properties the GLU flow actually relies on:
//!
//! 1. a **zero-free diagonal** — maximum-cardinality matching (MC21-style
//!    augmenting paths) biased greedily toward large-magnitude entries;
//! 2. **bounded entry magnitudes** — iterative row/column infinity-norm
//!    equilibration (Ruiz scaling), so no-pivoting LU stays stable on the
//!    matched matrix.
//!
//! On circuit matrices (diagonally dominant after stamping) the matching is
//! usually the identity; on shuffled or zero-diagonal inputs it restores a
//! usable diagonal — tested below.

use crate::sparse::{Csc, Permutation};

/// Result of the matching + scaling step.
#[derive(Debug, Clone)]
pub struct MatchScale {
    /// Row permutation (scatter form: `perm[old_row] = new_row`) such that
    /// the permuted matrix has a zero-free diagonal.
    pub row_perm: Permutation,
    /// Row scaling factors (all 1.0 when `scale == false`).
    pub row_scale: Vec<f64>,
    /// Column scaling factors.
    pub col_scale: Vec<f64>,
}

/// Compute the matching and (optionally) equilibration scalings.
pub fn match_and_scale(a: &Csc, scale: bool) -> anyhow::Result<MatchScale> {
    anyhow::ensure!(a.nrows() == a.ncols(), "matrix must be square");
    let n = a.nrows();

    let (row_scale, col_scale) = if scale {
        ruiz_scale(a, 5)
    } else {
        (vec![1.0; n], vec![1.0; n])
    };

    let row_of_col = max_transversal(a)?;
    // row_of_col[c] = r means entry (r, c) sits on the diagonal after the
    // permutation, i.e. new row index of old row r is c.
    let mut scatter = vec![usize::MAX; n];
    for (c, &r) in row_of_col.iter().enumerate() {
        scatter[r] = c;
    }
    Ok(MatchScale {
        row_perm: Permutation::from_scatter(scatter)?,
        row_scale,
        col_scale,
    })
}

/// Maximum-cardinality bipartite matching column -> row with greedy
/// large-magnitude seeding and DFS augmentation (MC21 with a value bias).
/// Returns `row_of_col`, or an error if the matrix is structurally singular.
fn max_transversal(a: &Csc) -> anyhow::Result<Vec<usize>> {
    let n = a.ncols();
    let mut row_of_col = vec![usize::MAX; n];
    let mut col_of_row = vec![usize::MAX; n];

    // Greedy pass: each column claims its largest-magnitude unclaimed row.
    // This biases the final matching toward a large diagonal (the property
    // MC64's weighted variants optimize exactly).
    for c in 0..n {
        let (rows, vals) = a.col(c);
        let mut best: Option<(usize, f64)> = None;
        for (&r, &v) in rows.iter().zip(vals) {
            if col_of_row[r] == usize::MAX {
                let m = v.abs();
                if best.map_or(true, |(_, bm)| m > bm) {
                    best = Some((r, m));
                }
            }
        }
        if let Some((r, _)) = best {
            row_of_col[c] = r;
            col_of_row[r] = c;
        }
    }

    // Augmenting-path pass for unmatched columns (iterative DFS).
    let mut visited = vec![usize::MAX; n]; // visited[row] = current column stamp
    for c in 0..n {
        if row_of_col[c] != usize::MAX {
            continue;
        }
        if !augment(a, c, c, &mut row_of_col, &mut col_of_row, &mut visited) {
            anyhow::bail!("structurally singular: no transversal covers column {c}");
        }
    }
    Ok(row_of_col)
}

/// Iterative DFS augmentation from `c0`; `stamp` identifies this search.
fn augment(
    a: &Csc,
    c0: usize,
    stamp: usize,
    row_of_col: &mut [usize],
    col_of_row: &mut [usize],
    visited: &mut [usize],
) -> bool {
    // Explicit stack of (col, next candidate index into its row list).
    let mut stack: Vec<(usize, usize)> = vec![(c0, 0)];
    // path[i] = (col, row) chosen at depth i.
    let mut path: Vec<(usize, usize)> = Vec::new();
    while let Some(&mut (c, ref mut idx)) = stack.last_mut() {
        let (rows, _) = a.col(c);
        let mut advanced = false;
        while *idx < rows.len() {
            let r = rows[*idx];
            *idx += 1;
            if visited[r] == stamp {
                continue;
            }
            visited[r] = stamp;
            if col_of_row[r] == usize::MAX {
                // Free row found: apply augmenting path.
                path.push((c, r));
                for &(pc, pr) in path.iter().rev() {
                    row_of_col[pc] = pr;
                    col_of_row[pr] = pc;
                }
                return true;
            }
            // Row matched elsewhere: recurse into that column.
            path.push((c, r));
            stack.push((col_of_row[r], 0));
            advanced = true;
            break;
        }
        if !advanced {
            stack.pop();
            path.pop();
        }
    }
    false
}

/// Ruiz iterative equilibration: after convergence every row and column has
/// infinity-norm ≈ 1. Returns `(row_scale, col_scale)`. Exposed to the
/// crate so the solver's robustness ladder can re-equilibrate a drifted
/// Newton iterate on the *fixed* permutations (escalation rung) without
/// redoing the transversal.
pub(crate) fn ruiz_scale(a: &Csc, iters: usize) -> (Vec<f64>, Vec<f64>) {
    let n = a.nrows();
    let mut r = vec![1.0f64; n];
    let mut c = vec![1.0f64; n];
    for _ in 0..iters {
        let mut rmax = vec![0.0f64; n];
        let mut cmax = vec![0.0f64; n];
        for j in 0..n {
            let (rows, vals) = a.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                let w = (v * r[i] * c[j]).abs();
                if w > rmax[i] {
                    rmax[i] = w;
                }
                if w > cmax[j] {
                    cmax[j] = w;
                }
            }
        }
        for i in 0..n {
            if rmax[i] > 0.0 {
                r[i] /= rmax[i].sqrt();
            }
            if cmax[i] > 0.0 {
                c[i] /= cmax[i].sqrt();
            }
        }
    }
    (r, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::sparse::Coo;

    #[test]
    fn identity_like_on_dominant_matrix() {
        let a = gen::grid2d(6, 6, 1);
        let m = match_and_scale(&a, false).unwrap();
        // Diagonally dominant: matching should keep the diagonal.
        assert_eq!(m.row_perm, Permutation::identity(36));
    }

    #[test]
    fn restores_zero_free_diagonal() {
        // Permuted diagonal: A = [[0,1],[2,0]] — needs a row swap.
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 2.0);
        let a = coo.to_csc();
        let m = match_and_scale(&a, false).unwrap();
        let id: Vec<usize> = (0..2).collect();
        let b = a.permute(m.row_perm.as_scatter(), &id);
        assert!(b.has_full_diagonal());
    }

    #[test]
    fn augmenting_path_needed() {
        // Greedy can trap itself; augmentation must recover.
        // A = [[1,1,0],[1,0,0],[0,0,1]] : col0 grabbing row0 blocks col1
        // unless the path augments col0 -> row1.
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(2, 2, 1.0);
        let a = coo.to_csc();
        let m = match_and_scale(&a, false).unwrap();
        let id: Vec<usize> = (0..3).collect();
        let b = a.permute(m.row_perm.as_scatter(), &id);
        assert!(b.has_full_diagonal());
    }

    #[test]
    fn detects_structural_singularity() {
        // Column 1 empty.
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 0, 1.0);
        let a = coo.to_csc();
        assert!(match_and_scale(&a, false).is_err());
    }

    #[test]
    fn ruiz_normalizes_norms() {
        let a = gen::netlist(128, 6, 10, 0.1, 2, 0.2, 3);
        let (r, c) = ruiz_scale(&a, 8);
        // After scaling, every column inf-norm should be close to 1.
        for j in 0..a.ncols() {
            let (rows, vals) = a.col(j);
            let m = rows
                .iter()
                .zip(vals)
                .map(|(&i, &v)| (v * r[i] * c[j]).abs())
                .fold(0.0, f64::max);
            assert!((0.5..=2.0).contains(&m), "col {j} norm {m}");
        }
    }

    #[test]
    fn matching_prefers_large_entries() {
        // [[1e-8, 5],[3, 1e-9]]: both diagonals possible; the biased greedy
        // should pick the off-diagonal (large) transversal.
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1e-8);
        coo.push(0, 1, 5.0);
        coo.push(1, 0, 3.0);
        coo.push(1, 1, 1e-9);
        let a = coo.to_csc();
        let m = match_and_scale(&a, false).unwrap();
        let id: Vec<usize> = (0..2).collect();
        let b = a.permute(m.row_perm.as_scatter(), &id);
        assert!(b.get(0, 0).abs() > 1.0 && b.get(1, 1).abs() > 1.0);
    }
}
