//! Parallel Gilbert–Peierls fill discovery on the spawn-once worker pool —
//! the cold-start half of the symbolic overhaul.
//!
//! The serial fill pass ([`super::fillin`]) is inherently sequential in
//! appearance: column `j`'s DFS reads the L patterns of columns `< j`. But it
//! never reads *all* of them — Gilbert–Ng shows every column a fill DFS of
//! `j` visits is a proper descendant of `j` in the **column elimination
//! tree** of `A` ([`super::etree::col_etree`]), a structure computable in
//! near-linear time *before any fill exists*. Height-based level sets of that
//! tree therefore partition the columns into waves whose DFSs only read
//! columns finished in strictly earlier waves (GSoFa's schedule, here on
//! CPU threads instead of GPU blocks):
//!
//! - **wide waves** are chunked contiguously across the pool's workers, each
//!   worker discovering its columns into a private [`FillScratch`] and
//!   publishing the finished pattern into a per-column slot
//!   ([`SharedSlots`] — disjoint writes, reads ordered by the wave barrier);
//! - **runs of narrow waves** (the top of the tree) are merged into one
//!   serial segment run by worker 0, so the barrier count is proportional to
//!   the number of *wide* waves, not the tree height.
//!
//! Each column's pattern is sorted before publication, so the assembled
//! filled matrix is **bit-identical** to the serial pipeline at any thread
//! count — the reach set of a column is schedule-independent; only the
//! discovery order varies. The assembly walk feeds every finished column
//! straight into [`StreamingDetect`], fusing GLU3.0 dependency detection and
//! levelization into the same sweep.

use std::time::Instant;

use super::etree::{col_etree, tree_heights};
use super::fillin::{ensure_factorable, FillScratch, FillWorkspace, SymbolicFill};
use crate::depend::glu3::StreamingDetect;
use crate::depend::{DepGraph, Levels};
use crate::numeric::pool::{SharedSlots, WorkerPool};
use crate::sparse::Csc;

/// A finished column's sorted pattern and the offset of its first L row
/// (`pat[lstart..]` = rows strictly below the diagonal), published for the
/// DFSs of later waves.
#[derive(Debug, Default)]
struct ColPat {
    pat: Vec<u32>,
    lstart: u32,
}

/// One barrier-delimited slice of the wave schedule.
#[derive(Debug)]
struct Segment {
    /// Chunked across all workers (`true`) or run whole by worker 0.
    parallel: bool,
    /// Columns in ascending coletree-height order, ascending index within a
    /// height (serial segments may span several consecutive heights).
    cols: Vec<u32>,
}

/// A parallel wave must amortize its barrier: anything narrower is cheaper
/// run serially and merged with its neighbors into one barrier.
fn wide_threshold(threads: usize) -> usize {
    (threads * 4).max(16)
}

/// Partition the columns into barrier-delimited segments by coletree height.
fn build_segments(a: &Csc, threads: usize) -> Vec<Segment> {
    let n = a.ncols();
    let parent = col_etree(a);
    let heights = tree_heights(&parent);
    let nh = heights.iter().map(|&h| h as usize + 1).max().unwrap_or(0);
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); nh];
    for j in 0..n {
        buckets[heights[j] as usize].push(j as u32);
    }
    let wide = wide_threshold(threads);
    let mut segments: Vec<Segment> = Vec::new();
    for b in buckets {
        if b.len() >= wide {
            segments.push(Segment {
                parallel: true,
                cols: b,
            });
        } else if let Some(last) = segments.last_mut().filter(|s| !s.parallel) {
            last.cols.extend_from_slice(&b);
        } else {
            segments.push(Segment {
                parallel: false,
                cols: b,
            });
        }
    }
    segments
}

/// Discover the full reach pattern of column `j` and publish it into its
/// slot. Reads only slots of strictly smaller coletree height — finalized by
/// an earlier barrier or earlier in this worker's serial run.
fn discover(a: &Csc, j: usize, scratch: &mut FillScratch, slots: &SharedSlots<ColPat>) {
    let ju = j as u32;
    scratch.pat.clear();
    let (arows, _) = a.col(j);
    for &r in arows {
        if scratch.marked[r] == ju {
            continue;
        }
        scratch.stack.clear();
        scratch.marked[r] = ju;
        scratch.stack.push((r as u32, 0));
        while let Some(&mut (v, ref mut ci)) = scratch.stack.last_mut() {
            let v_ = v as usize;
            if v_ >= j {
                scratch.pat.push(v);
                scratch.stack.pop();
                continue;
            }
            // SAFETY: v < j is reachable in column j's fill DFS, so it is a
            // proper coletree descendant of j (Gilbert–Ng) and its slot was
            // published before this segment started (or earlier in this
            // worker's serial run). No worker writes it now.
            let cp = unsafe { slots.get(v_) };
            let kids = &cp.pat[cp.lstart as usize..];
            let mut pushed = false;
            while (*ci as usize) < kids.len() {
                let t = kids[*ci as usize];
                *ci += 1;
                if scratch.marked[t as usize] != ju {
                    scratch.marked[t as usize] = ju;
                    scratch.stack.push((t, 0));
                    pushed = true;
                    break;
                }
            }
            if !pushed {
                scratch.pat.push(v);
                scratch.stack.pop();
            }
        }
    }
    scratch.pat.sort_unstable();
    let lstart = scratch.pat.partition_point(|&r| r <= ju) as u32;
    // SAFETY: column j belongs to exactly one worker's chunk of exactly one
    // segment — no concurrent access to this slot.
    let out = unsafe { slots.get_mut(j) };
    out.pat.extend_from_slice(&scratch.pat);
    out.lstart = lstart;
}

/// Run the wave schedule on the pool, leaving every column's sorted pattern
/// in `pats`.
fn discover_all(a: &Csc, pool: &WorkerPool, ws: &mut FillWorkspace, pats: &mut [ColPat]) {
    let threads = pool.threads();
    let segments = build_segments(a, threads);
    ws.reset_scratches(threads, a.ncols());
    let slots = SharedSlots::new(pats);
    let scratch = SharedSlots::new(&mut ws.scratches);
    let segs = &segments;
    pool.run(&move |ctx| {
        // SAFETY: one scratch per worker id, ids are distinct.
        let my = unsafe { scratch.get_mut(ctx.id) };
        for seg in segs {
            if seg.parallel {
                let len = seg.cols.len();
                let lo = len * ctx.id / ctx.threads;
                let hi = len * (ctx.id + 1) / ctx.threads;
                for &j in &seg.cols[lo..hi] {
                    discover(a, j as usize, my, &slots);
                }
            } else if ctx.id == 0 {
                for &j in &seg.cols {
                    discover(a, j as usize, my, &slots);
                }
            }
            if !ctx.sync() {
                return;
            }
        }
    });
}

/// Output of the fused parallel symbolic phase: the filled pattern plus the
/// GLU3.0 dependency graph and level schedule it streams out, with per-stage
/// timings for [`crate::glu::GluStats`].
#[derive(Debug)]
pub struct ParSymbolic {
    pub sym: SymbolicFill,
    pub deps: DepGraph,
    pub levels: Levels,
    /// Wave-parallel reach discovery.
    pub fillin_ms: f64,
    /// Serial assembly of the filled CSC + streamed Algorithm 4.
    pub detect_ms: f64,
    /// Grouping the streamed level assignment.
    pub levelize_ms: f64,
}

/// Parallel fill + fused streaming detection/levelization — the Glu3 cold
/// path. Bit-identical to `symbolic_fill` → `glu3::detect` → `levelize` at
/// any thread count.
pub fn parallel_symbolic(
    a: &Csc,
    pool: &WorkerPool,
    ws: &mut FillWorkspace,
) -> anyhow::Result<ParSymbolic> {
    ensure_factorable(a)?;
    let n = a.ncols();
    let t0 = Instant::now();
    let mut pats: Vec<ColPat> = Vec::new();
    pats.resize_with(n, ColPat::default);
    discover_all(a, pool, ws, &mut pats);
    let fillin_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t1 = Instant::now();
    let mut sd = StreamingDetect::new(n);
    let (sym, _) = assemble(a, &pats, Some(&mut sd))?;
    let detect_ms = t1.elapsed().as_secs_f64() * 1e3;

    let t2 = Instant::now();
    let (deps, levels) = sd.finish();
    let levelize_ms = t2.elapsed().as_secs_f64() * 1e3;

    Ok(ParSymbolic {
        sym,
        deps,
        levels,
        fillin_ms,
        detect_ms,
        levelize_ms,
    })
}

/// Parallel fill alone (no fused detection) — the cold path for detection
/// modes that batch-process the filled pattern afterwards. Returns the
/// filled pattern and the discovery time (assembly included, matching the
/// serial `symbolic_fill` accounting).
pub fn parallel_fill(
    a: &Csc,
    pool: &WorkerPool,
    ws: &mut FillWorkspace,
) -> anyhow::Result<(SymbolicFill, f64)> {
    ensure_factorable(a)?;
    let t0 = Instant::now();
    let mut pats: Vec<ColPat> = Vec::new();
    pats.resize_with(a.ncols(), ColPat::default);
    discover_all(a, pool, ws, &mut pats);
    let (sym, _) = assemble(a, &pats, None)?;
    Ok((sym, t0.elapsed().as_secs_f64() * 1e3))
}

/// Serial assembly of the discovered per-column patterns into the filled
/// CSC, optionally streaming each finished column into `sd`.
fn assemble(
    a: &Csc,
    pats: &[ColPat],
    mut sd: Option<&mut StreamingDetect>,
) -> anyhow::Result<(SymbolicFill, usize)> {
    let n = a.ncols();
    let total: usize = pats.iter().map(|p| p.pat.len()).sum();
    let mut colptr = Vec::with_capacity(n + 1);
    colptr.push(0usize);
    let mut rowidx: Vec<usize> = Vec::with_capacity(total);
    let mut values: Vec<f64> = Vec::with_capacity(total);
    for (j, p) in pats.iter().enumerate() {
        let (arows, avals) = a.col(j);
        let mut ai = 0usize;
        let start = rowidx.len();
        for &r in &p.pat {
            let r_ = r as usize;
            rowidx.push(r_);
            if ai < arows.len() && arows[ai] == r_ {
                values.push(avals[ai]);
                ai += 1;
            } else {
                values.push(0.0);
            }
        }
        debug_assert_eq!(ai, arows.len(), "structural entry missing from pattern");
        colptr.push(rowidx.len());
        if let Some(sd) = sd.as_deref_mut() {
            sd.consume(j, &rowidx[start..]);
        }
    }
    let fill_count = rowidx.len() - a.nnz();
    let filled = Csc::from_raw_parts(n, n, colptr, rowidx, values)?;
    Ok((SymbolicFill { filled, fill_count }, total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::{glu3, levelize};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;

    fn check_identical(a: &Csc, threads: usize) {
        let serial = symbolic_fill(a).unwrap();
        let sdeps = glu3::detect(&serial.filled);
        let slevels = levelize(&sdeps);
        let pool = WorkerPool::new(threads);
        let mut ws = FillWorkspace::new();
        let par = parallel_symbolic(a, &pool, &mut ws).unwrap();
        assert_eq!(par.sym.filled, serial.filled);
        assert_eq!(par.sym.fill_count, serial.fill_count);
        assert_eq!(par.deps, sdeps);
        assert_eq!(par.levels, slevels);
        // Reused workspace, second run — same answer.
        let again = parallel_symbolic(a, &pool, &mut ws).unwrap();
        assert_eq!(again.sym.filled, serial.filled);
    }

    #[test]
    fn matches_serial_on_grid_all_thread_counts() {
        let a = gen::grid2d(13, 11, 7);
        for threads in [1, 2, 4] {
            check_identical(&a, threads);
        }
    }

    #[test]
    fn matches_serial_on_netlists() {
        for (seed, threads) in [(11u64, 2usize), (12, 4), (13, 3)] {
            let a = gen::netlist(150, 6, 8, 0.1, 2, 0.25, seed);
            check_identical(&a, threads);
        }
    }

    #[test]
    fn matches_serial_on_chain_tree_degenerate() {
        // Tridiagonal chain: coletree is a path, every wave is narrow — the
        // whole run collapses into one serial segment on worker 0.
        let a = gen::ladder(64, 16, 32, 5);
        check_identical(&a, 4);
    }

    #[test]
    fn parallel_fill_matches_serial() {
        let a = gen::grid2d(10, 10, 3);
        let pool = WorkerPool::new(4);
        let mut ws = FillWorkspace::new();
        let (sym, ms) = parallel_fill(&a, &pool, &mut ws).unwrap();
        let serial = symbolic_fill(&a).unwrap();
        assert_eq!(sym.filled, serial.filled);
        assert_eq!(sym.fill_count, serial.fill_count);
        assert!(ms >= 0.0);
    }

    #[test]
    fn segments_cover_every_column_once() {
        let a = gen::netlist(200, 6, 8, 0.1, 2, 0.25, 77);
        let segs = build_segments(&a, 4);
        let mut seen = vec![false; a.ncols()];
        for s in &segs {
            for &c in &s.cols {
                assert!(!seen[c as usize], "column {c} scheduled twice");
                seen[c as usize] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }
}
