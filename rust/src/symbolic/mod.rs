//! Symbolic analysis: fill-in computation and elimination-tree utilities.
//!
//! GLU (like KLU/NICSLU) performs all symbolic work once on the CPU; the
//! numeric GPU kernel then runs on a *static* filled pattern `As = L + U`.
//! This module computes that pattern with the Gilbert–Peierls reachability
//! argument, and derives the column elimination tree used by tests and the
//! multithreaded CPU baseline.
//!
//! Two fast paths take the cold-start tax off that once-per-pattern work:
//! [`parfill`] runs fill discovery wave-parallel on the numeric worker pool
//! (coletree height level sets; bit-identical to the serial pass), and
//! [`delta`] patches a cached pattern against a structural near-miss instead
//! of recomputing it from scratch.

pub mod delta;
pub mod etree;
pub mod fillin;
pub mod parfill;

pub use delta::{changed_columns, patch_symbolic, SymbolicPatch};
pub use fillin::{symbolic_fill, symbolic_fill_with, FillWorkspace, SymbolicFill};
pub use parfill::{parallel_fill, parallel_symbolic, ParSymbolic};
