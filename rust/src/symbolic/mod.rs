//! Symbolic analysis: fill-in computation and elimination-tree utilities.
//!
//! GLU (like KLU/NICSLU) performs all symbolic work once on the CPU; the
//! numeric GPU kernel then runs on a *static* filled pattern `As = L + U`.
//! This module computes that pattern with the Gilbert–Peierls reachability
//! argument, and derives the column elimination tree used by tests and the
//! multithreaded CPU baseline.

pub mod etree;
pub mod fillin;

pub use fillin::{symbolic_fill, SymbolicFill};
