//! Incremental symbolic patching — the near-miss half of the symbolic
//! overhaul.
//!
//! Circuit simulators re-factor long sequences of matrices whose *structure*
//! drifts slowly (a device model switching on, a coupling element added)
//! while values change every step. A structural near-miss in the
//! [`crate::coordinator::SolverPool`] used to pay the full cold pipeline;
//! here it pays a **structural diff** plus a patch proportional to the part
//! of the pattern the diff actually perturbs.
//!
//! The taint rule is exact, not heuristic. Column `j`'s fill DFS starts from
//! `struct(A(:,j))` and traverses the L patterns of exactly the columns that
//! appear as U-rows (`< j`) of its *filled* column. Ascending over `j`:
//!
//! - if `struct(A(:,j))` is unchanged **and** no old U-row `v` of `j` has a
//!   changed L pattern (`l_changed[v]`), the DFS replays move-for-move —
//!   column `j`'s pattern is copied from the base (values re-merged from the
//!   new `A`);
//! - otherwise column `j` is recomputed with the serial DFS against the
//!   *new* lower patterns, and `l_changed[j]` records whether its L part
//!   differs from the base, propagating the taint exactly as far as it
//!   reaches and no further.
//!
//! Every finalized column streams through [`StreamingDetect`], so the
//! dependency graph and level schedule come out of the same sweep —
//! bit-identical to a from-scratch `symbolic_fill` + `detect` + `levelize`
//! on the new matrix (property-tested in `tests/property.rs`).

use super::fillin::{ensure_factorable, FillWorkspace, SymbolicFill};
use crate::depend::glu3::StreamingDetect;
use crate::depend::{DepGraph, Levels};
use crate::sparse::Csc;

/// Columns of `a` whose structure differs from the cached base pattern
/// (`base_colptr` / `base_rowidx`), ascending. `None` when the matrices are
/// not comparable (different shape) or the diff exceeds `max_changed` —
/// the caller should fall back to the cold path.
pub fn changed_columns(
    base_colptr: &[usize],
    base_rowidx: &[usize],
    a: &Csc,
    max_changed: usize,
) -> Option<Vec<u32>> {
    let n = a.ncols();
    if base_colptr.len() != n + 1 {
        return None;
    }
    let mut changed = Vec::new();
    for j in 0..n {
        let base = &base_rowidx[base_colptr[j]..base_colptr[j + 1]];
        if base != a.col(j).0 {
            if changed.len() == max_changed {
                return None;
            }
            changed.push(j as u32);
        }
    }
    Some(changed)
}

/// A patched symbolic phase: the new triple plus how much work the patch
/// actually did.
#[derive(Debug)]
pub struct SymbolicPatch {
    pub sym: SymbolicFill,
    pub deps: DepGraph,
    pub levels: Levels,
    /// Columns whose fill DFS was re-run (taint closure of `changed`).
    pub recomputed: usize,
}

/// Patch `base`'s filled pattern onto the new matrix `a`, recomputing only
/// the taint closure of `changed` (ascending column indices from
/// [`changed_columns`], in the same index space as `a` and `base`).
pub fn patch_symbolic(
    base: &SymbolicFill,
    a: &Csc,
    changed: &[u32],
    ws: &mut FillWorkspace,
) -> anyhow::Result<SymbolicPatch> {
    ensure_factorable(a)?;
    let n = a.ncols();
    anyhow::ensure!(
        base.filled.ncols() == n && base.filled.nrows() == n,
        "base pattern shape mismatch"
    );
    ws.reset(n);

    let mut changed_set = vec![false; n];
    for &c in changed {
        changed_set[c as usize] = true;
    }
    let mut l_changed = vec![false; n];

    let mut colptr = Vec::with_capacity(n + 1);
    colptr.push(0usize);
    let mut rowidx: Vec<usize> = Vec::with_capacity(base.filled.nnz());
    let mut values: Vec<f64> = Vec::with_capacity(base.filled.nnz());
    // L pattern of each finalized new column, as a range into `rowidx`
    // (stable: the vec only grows).
    let mut lrange: Vec<(usize, usize)> = Vec::with_capacity(n);
    let mut sd = StreamingDetect::new(n);
    let mut recomputed = 0usize;

    for j in 0..n {
        let (old_rows, _) = base.filled.col(j);
        let tainted = changed_set[j]
            || old_rows
                .iter()
                .take_while(|&&v| v < j)
                .any(|&v| l_changed[v]);
        let start = rowidx.len();
        if tainted {
            recomputed += 1;
            // Serial Gilbert–Peierls DFS against the *new* lower patterns.
            ws.pattern.clear();
            let ju = j as u32;
            let (arows, avals) = a.col(j);
            for &r in arows {
                if ws.marked[r] == ju {
                    continue;
                }
                ws.dfs_stack.clear();
                ws.marked[r] = ju;
                ws.dfs_stack.push((r as u32, 0));
                while let Some(&mut (v, ref mut ci)) = ws.dfs_stack.last_mut() {
                    let v_ = v as usize;
                    if v_ >= j {
                        ws.pattern.push(v);
                        ws.dfs_stack.pop();
                        continue;
                    }
                    let (klo, khi) = lrange[v_];
                    let kids = &rowidx[klo..khi];
                    let mut pushed = false;
                    while (*ci as usize) < kids.len() {
                        let t = kids[*ci as usize];
                        *ci += 1;
                        if ws.marked[t] != ju {
                            ws.marked[t] = ju;
                            ws.dfs_stack.push((t as u32, 0));
                            pushed = true;
                            break;
                        }
                    }
                    if !pushed {
                        ws.pattern.push(v);
                        ws.dfs_stack.pop();
                    }
                }
            }
            ws.pattern.sort_unstable();
            let mut ai = 0usize;
            for &r in &ws.pattern {
                let r_ = r as usize;
                rowidx.push(r_);
                if ai < arows.len() && arows[ai] == r_ {
                    values.push(avals[ai]);
                    ai += 1;
                } else {
                    values.push(0.0);
                }
            }
            debug_assert_eq!(ai, arows.len(), "structural entry missing from pattern");
            // Did the L part move? Compare against the base column.
            let lpos = rowidx[start..].partition_point(|&r| r <= j);
            let old_lpos = old_rows.partition_point(|&r| r <= j);
            l_changed[j] = rowidx[start + lpos..] != old_rows[old_lpos..];
        } else {
            // Untainted: the base pattern replays identically; copy it and
            // re-merge the (possibly restamped) values from the new matrix.
            let (arows, avals) = a.col(j);
            let mut ai = 0usize;
            for &r in old_rows {
                rowidx.push(r);
                if ai < arows.len() && arows[ai] == r {
                    values.push(avals[ai]);
                    ai += 1;
                } else {
                    values.push(0.0);
                }
            }
            debug_assert_eq!(ai, arows.len(), "unchanged column disagrees with base");
        }
        colptr.push(rowidx.len());
        let lpos = start + rowidx[start..].partition_point(|&r| r <= j);
        lrange.push((lpos, rowidx.len()));
        sd.consume(j, &rowidx[start..]);
    }

    let fill_count = rowidx.len() - a.nnz();
    let filled = Csc::from_raw_parts(n, n, colptr, rowidx, values)?;
    let (deps, levels) = sd.finish();
    Ok(SymbolicPatch {
        sym: SymbolicFill { filled, fill_count },
        deps,
        levels,
        recomputed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::{glu3, levelize};
    use crate::sparse::{gen, Coo};
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    fn raw_pattern(a: &Csc) -> (Vec<usize>, Vec<usize>) {
        let mut colptr = vec![0usize];
        let mut rowidx = Vec::new();
        for j in 0..a.ncols() {
            rowidx.extend_from_slice(a.col(j).0);
            colptr.push(rowidx.len());
        }
        (colptr, rowidx)
    }

    /// Rebuild `a` with one extra structural entry at `(r, c)`.
    fn with_extra(a: &Csc, r: usize, c: usize, v: f64) -> Csc {
        let mut coo = Coo::new(a.nrows(), a.ncols());
        for j in 0..a.ncols() {
            let (rows, vals) = a.col(j);
            for (&i, &x) in rows.iter().zip(vals) {
                coo.push(i, j, x);
            }
        }
        coo.push(r, c, v);
        coo.to_csc()
    }

    #[test]
    fn changed_columns_finds_the_diff() {
        let a = gen::grid2d(8, 8, 1);
        let (cp, ri) = raw_pattern(&a);
        assert_eq!(changed_columns(&cp, &ri, &a, 4).unwrap(), Vec::<u32>::new());
        let b = with_extra(&a, 40, 3, -0.5);
        let ch = changed_columns(&cp, &ri, &b, 4).unwrap();
        assert_eq!(ch, vec![3]);
        // Budget exhaustion falls back.
        let mut c = a.clone();
        for col in 0..6 {
            c = with_extra(&c, 50, col, -0.1);
        }
        assert!(changed_columns(&cp, &ri, &c, 4).is_none());
    }

    fn check_patch_matches_fresh(base_a: &Csc, new_a: &Csc) {
        let base = symbolic_fill(base_a).unwrap();
        let (cp, ri) = raw_pattern(base_a);
        let changed = changed_columns(&cp, &ri, new_a, new_a.ncols())
            .expect("same shape, diff within budget");
        let mut ws = FillWorkspace::new();
        let patch = patch_symbolic(&base, new_a, &changed, &mut ws).unwrap();
        let fresh = symbolic_fill(new_a).unwrap();
        assert_eq!(patch.sym.filled, fresh.filled);
        assert_eq!(patch.sym.fill_count, fresh.fill_count);
        assert_eq!(patch.deps, glu3::detect(&fresh.filled));
        assert_eq!(patch.levels, levelize(&glu3::detect(&fresh.filled)));
        assert!(patch.recomputed >= changed.len());
    }

    #[test]
    fn identity_delta_recomputes_nothing() {
        let a = gen::grid2d(9, 9, 5);
        let base = symbolic_fill(&a).unwrap();
        let mut ws = FillWorkspace::new();
        let patch = patch_symbolic(&base, &a, &[], &mut ws).unwrap();
        assert_eq!(patch.recomputed, 0);
        assert_eq!(patch.sym.filled, base.filled);
    }

    #[test]
    fn single_entry_deltas_match_fresh() {
        let mut rng = Rng::new(0xDE17A);
        for trial in 0..12 {
            let n = rng.range(30, 90);
            let a = gen::netlist(n, 6, 8, 0.1, 2, 0.25, 4000 + trial);
            let r = rng.below(n);
            let c = rng.below(n);
            let b = with_extra(&a, r, c, -0.3);
            check_patch_matches_fresh(&a, &b);
        }
    }

    #[test]
    fn two_column_deltas_match_fresh() {
        let mut rng = Rng::new(0xDE17B);
        for trial in 0..8 {
            let n = rng.range(40, 100);
            let a = gen::netlist(n, 6, 8, 0.1, 2, 0.25, 5000 + trial);
            let b = with_extra(&a, rng.below(n), rng.below(n), 0.2);
            let c = with_extra(&b, rng.below(n), rng.below(n), -0.7);
            check_patch_matches_fresh(&a, &c);
        }
    }

    #[test]
    fn entry_removal_delta_matches_fresh() {
        // Shrinking structure: drop one off-diagonal entry.
        let a = gen::grid2d(10, 7, 3);
        let mut coo = Coo::new(a.nrows(), a.ncols());
        let mut dropped = false;
        for j in 0..a.ncols() {
            let (rows, vals) = a.col(j);
            for (&i, &x) in rows.iter().zip(vals) {
                if !dropped && i != j && i > 20 {
                    dropped = true;
                    continue;
                }
                coo.push(i, j, x);
            }
        }
        assert!(dropped);
        check_patch_matches_fresh(&a, &coo.to_csc());
    }

    #[test]
    fn fill_envelope_delta_recomputes_one_column() {
        // An entry already inside the filled pattern but absent from A:
        // the patched column's reach cannot grow, so the taint stops there.
        let a = gen::grid2d(10, 10, 2);
        let base = symbolic_fill(&a).unwrap();
        let mut pick = None;
        'outer: for j in 0..a.ncols() {
            let (rows, _) = base.filled.col(j);
            for &r in rows {
                if !a.has_entry(r, j) && r > j {
                    pick = Some((r, j));
                    break 'outer;
                }
            }
        }
        let (r, c) = pick.expect("grids always fill in");
        let b = with_extra(&a, r, c, 1e-3);
        let (cp, ri) = raw_pattern(&a);
        let changed = changed_columns(&cp, &ri, &b, 8).unwrap();
        assert_eq!(changed, vec![c as u32]);
        let mut ws = FillWorkspace::new();
        let patch = patch_symbolic(&base, &b, &changed, &mut ws).unwrap();
        assert_eq!(patch.recomputed, 1);
        let fresh = symbolic_fill(&b).unwrap();
        assert_eq!(patch.sym.filled, fresh.filled);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let a = gen::grid2d(6, 6, 1);
        let b = gen::grid2d(7, 7, 1);
        let base = symbolic_fill(&a).unwrap();
        let mut ws = FillWorkspace::new();
        assert!(patch_symbolic(&base, &b, &[], &mut ws).is_err());
        let (cp, ri) = raw_pattern(&a);
        assert!(changed_columns(&cp, &ri, &b, 99).is_none());
    }
}
