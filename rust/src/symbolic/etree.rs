//! Column elimination tree over a filled pattern.
//!
//! `parent[j] = min { i > j : L(i, j) ≠ 0 }` (or `NONE` for roots). SuperLU
//! and NICSLU schedule column tasks with this tree; here it feeds the
//! multithreaded CPU baseline and provides an independent check of the
//! levelization (a column's level must be ≥ its tree depth over U-pattern
//! dependencies).

use crate::sparse::Csc;

/// Sentinel for "no parent" (tree root).
pub const NONE: usize = usize::MAX;

/// Compute the elimination tree from a *filled* pattern `As = L + U`.
pub fn etree_from_filled(filled: &Csc) -> Vec<usize> {
    let n = filled.ncols();
    let mut parent = vec![NONE; n];
    for j in 0..n {
        let (rows, _) = filled.col(j);
        // first L entry strictly below the diagonal
        if let Some(&r) = rows.iter().find(|&&r| r > j) {
            parent[j] = r;
        }
    }
    parent
}

/// Depth of each node in the tree (roots have depth 0).
pub fn tree_depths(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    let mut depth = vec![usize::MAX; n];
    for mut v in 0..n {
        // walk up until a known depth, collecting the path
        let mut path = Vec::new();
        while depth[v] == usize::MAX {
            path.push(v);
            if parent[v] == NONE {
                depth[v] = 0;
                break;
            }
            v = parent[v];
        }
        let mut d = depth[v];
        for &u in path.iter().rev() {
            if depth[u] == usize::MAX {
                d += 1;
                depth[u] = d;
            }
        }
    }
    depth
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;

    #[test]
    fn chain_gives_path_tree() {
        let a = gen::ladder(16, 16, 0, 1); // tridiagonal chain
        let f = symbolic_fill(&a).unwrap();
        let p = etree_from_filled(&f.filled);
        for j in 0..15 {
            assert_eq!(p[j], j + 1);
        }
        assert_eq!(p[15], NONE);
        let d = tree_depths(&p);
        assert_eq!(d[0], 15);
        assert_eq!(d[15], 0);
    }

    #[test]
    fn diagonal_matrix_all_roots() {
        let a = crate::sparse::Csc::identity(5);
        let f = symbolic_fill(&a).unwrap();
        let p = etree_from_filled(&f.filled);
        assert!(p.iter().all(|&x| x == NONE));
        assert!(tree_depths(&p).iter().all(|&d| d == 0));
    }

    #[test]
    fn parents_strictly_increase() {
        let a = gen::netlist(120, 6, 10, 0.05, 2, 0.2, 8);
        let f = symbolic_fill(&a).unwrap();
        let p = etree_from_filled(&f.filled);
        for (j, &pj) in p.iter().enumerate() {
            if pj != NONE {
                assert!(pj > j);
            }
        }
    }
}
