//! Column elimination tree over a filled pattern.
//!
//! `parent[j] = min { i > j : L(i, j) ≠ 0 }` (or `NONE` for roots). SuperLU
//! and NICSLU schedule column tasks with this tree; here it feeds the
//! multithreaded CPU baseline and provides an independent check of the
//! levelization (a column's level must be ≥ its tree depth over U-pattern
//! dependencies).

use crate::sparse::Csc;

/// Sentinel for "no parent" (tree root).
pub const NONE: usize = usize::MAX;

/// Compute the elimination tree from a *filled* pattern `As = L + U`.
pub fn etree_from_filled(filled: &Csc) -> Vec<usize> {
    let n = filled.ncols();
    let mut parent = vec![NONE; n];
    for j in 0..n {
        let (rows, _) = filled.col(j);
        // first L entry strictly below the diagonal
        if let Some(&r) = rows.iter().find(|&&r| r > j) {
            parent[j] = r;
        }
    }
    parent
}

/// Column elimination tree of `A` **before** fill — the elimination tree of
/// `AᵀA` computed without forming it (SuperLU's `sp_coletree` union-find
/// trick over each column's row set, keyed by the first column touching
/// each row).
///
/// Gilbert–Ng: for any matrix with a zero-free diagonal, every column `i`
/// consulted by Gilbert–Peierls fill discovery of column `j` (`i < j`,
/// `Us(i,j) ≠ 0`) is a proper descendant of `j` in this tree. Height-based
/// level sets over it therefore partition the columns so a level's fill
/// DFSs only read columns finished in strictly earlier levels — the safe
/// parallel schedule [`super::parfill`] runs on, known before any fill is
/// computed.
pub fn col_etree(a: &Csc) -> Vec<usize> {
    let n = a.ncols();
    // firstcol[r] = smallest column with a structural entry in row r.
    let mut firstcol = vec![NONE; a.nrows()];
    for j in 0..n {
        for &r in a.col(j).0 {
            if firstcol[r] == NONE {
                firstcol[r] = j;
            }
        }
    }
    // Union-find with path halving; root[find(x)] = highest-numbered column
    // of x's current subtree.
    let mut pp: Vec<usize> = (0..n).collect();
    let mut root: Vec<usize> = (0..n).collect();
    let mut parent = vec![NONE; n];
    let mut find = |pp: &mut Vec<usize>, mut x: usize| {
        while pp[x] != x {
            pp[x] = pp[pp[x]];
            x = pp[x];
        }
        x
    };
    for col in 0..n {
        let mut cset = col;
        root[cset] = col;
        for &r in a.col(col).0 {
            let k = firstcol[r];
            if k >= col {
                continue;
            }
            let rset = find(&mut pp, k);
            let rroot = root[rset];
            if rroot != col {
                parent[rroot] = col;
                // link rset into cset
                pp[rset] = cset;
                cset = rset;
                root[cset] = col;
            }
        }
    }
    parent
}

/// Height of each node from the leaves (`leaf = 0`,
/// `height[parent] ≥ height[child] + 1`). Valid for trees whose parents
/// strictly increase (both the coletree and the post-fill etree), so a
/// single ascending pass settles every node.
pub fn tree_heights(parent: &[usize]) -> Vec<u32> {
    let n = parent.len();
    let mut height = vec![0u32; n];
    for j in 0..n {
        let p = parent[j];
        if p != NONE {
            debug_assert!(p > j, "etree parents must increase");
            height[p] = height[p].max(height[j] + 1);
        }
    }
    height
}

/// Depth of each node in the tree (roots have depth 0).
pub fn tree_depths(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    let mut depth = vec![usize::MAX; n];
    for mut v in 0..n {
        // walk up until a known depth, collecting the path
        let mut path = Vec::new();
        while depth[v] == usize::MAX {
            path.push(v);
            if parent[v] == NONE {
                depth[v] = 0;
                break;
            }
            v = parent[v];
        }
        let mut d = depth[v];
        for &u in path.iter().rev() {
            if depth[u] == usize::MAX {
                d += 1;
                depth[u] = d;
            }
        }
    }
    depth
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;

    #[test]
    fn chain_gives_path_tree() {
        let a = gen::ladder(16, 16, 0, 1); // tridiagonal chain
        let f = symbolic_fill(&a).unwrap();
        let p = etree_from_filled(&f.filled);
        for j in 0..15 {
            assert_eq!(p[j], j + 1);
        }
        assert_eq!(p[15], NONE);
        let d = tree_depths(&p);
        assert_eq!(d[0], 15);
        assert_eq!(d[15], 0);
    }

    #[test]
    fn diagonal_matrix_all_roots() {
        let a = crate::sparse::Csc::identity(5);
        let f = symbolic_fill(&a).unwrap();
        let p = etree_from_filled(&f.filled);
        assert!(p.iter().all(|&x| x == NONE));
        assert!(tree_depths(&p).iter().all(|&d| d == 0));
    }

    #[test]
    fn parents_strictly_increase() {
        let a = gen::netlist(120, 6, 10, 0.05, 2, 0.2, 8);
        let f = symbolic_fill(&a).unwrap();
        let p = etree_from_filled(&f.filled);
        for (j, &pj) in p.iter().enumerate() {
            if pj != NONE {
                assert!(pj > j);
            }
        }
    }

    #[test]
    fn coletree_of_chain_is_path() {
        let a = gen::ladder(12, 12, 0, 1); // tridiagonal chain
        let p = col_etree(&a);
        for j in 0..11 {
            assert_eq!(p[j], j + 1);
        }
        assert_eq!(p[11], NONE);
        let h = tree_heights(&p);
        assert_eq!(h[11], 11);
        assert_eq!(h[0], 0);
    }

    /// The Gilbert–Ng safety property the parallel symbolic engine rests
    /// on: every U-row of every filled column is a proper coletree
    /// descendant of that column (so its fill DFS only reads columns of
    /// strictly smaller coletree height).
    #[test]
    fn coletree_bounds_fill_dfs_reads() {
        use crate::util::Rng;
        let mut rng = Rng::new(0xC01E);
        let mut mats = vec![gen::grid2d(9, 9, 4), gen::ladder(60, 12, 24, 2)];
        for t in 0..8 {
            let n = rng.range(20, 80);
            mats.push(gen::netlist(n, 6, 8, 0.1, 2, 0.25, 600 + t));
        }
        for a in &mats {
            let parent = col_etree(a);
            let heights = tree_heights(&parent);
            let is_descendant = |mut v: usize, j: usize| -> bool {
                while v < j {
                    v = parent[v];
                    if v == NONE {
                        return false;
                    }
                }
                v == j
            };
            let f = symbolic_fill(a).unwrap();
            for j in 0..a.ncols() {
                let (rows, _) = f.filled.col(j);
                for &i in rows.iter().take_while(|&&i| i < j) {
                    assert!(
                        is_descendant(i, j),
                        "U-row {i} of column {j} is not a coletree descendant"
                    );
                    assert!(heights[i] < heights[j]);
                }
            }
        }
    }
}
