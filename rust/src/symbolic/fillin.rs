//! Gilbert–Peierls symbolic fill-in.
//!
//! For each column `j`, the nonzero pattern of column `j` of `L+U` is the set
//! of nodes reachable from the pattern of `A(:,j)` in the DAG of already-
//! factorized `L` columns (edge `i → t` when `L(t,i) ≠ 0`, `t > i`,
//! propagating only through `i < j`). This is exactly the pattern the
//! numeric triangular solve of Algorithm 1 touches, so the numeric kernels
//! can run data-oblivious on the filled pattern.
//!
//! The DFS scratch (marker array, explicit stack, pattern buffer, per-column
//! L lists) lives in a [`FillWorkspace`] so repeated symbolic runs — the
//! [`crate::coordinator::SolverPool`] miss path, the parallel engine in
//! [`super::parfill`], and the incremental patcher in [`super::delta`] —
//! reuse one allocation instead of paying `O(n)` fresh buffers per call.

use crate::sparse::Csc;

/// Result of symbolic analysis.
#[derive(Debug, Clone)]
pub struct SymbolicFill {
    /// `As`: the filled matrix. Structural union of `A` and all fill;
    /// values are copied from `A` (0.0 at fill positions).
    pub filled: Csc,
    /// Number of entries of `filled` that are fill (not structural in `A`).
    pub fill_count: usize,
}

impl SymbolicFill {
    /// nnz of `A` before fill (`filled.nnz() - fill_count`).
    pub fn nz_original(&self) -> usize {
        self.filled.nnz() - self.fill_count
    }
}

/// Per-worker DFS scratch of the parallel fill engine: one marker array and
/// one explicit stack per pool thread, so workers discover disjoint columns
/// without sharing (or locking) any mutable state.
#[derive(Debug, Default)]
pub(crate) struct FillScratch {
    pub(crate) marked: Vec<u32>,
    pub(crate) stack: Vec<(u32, u32)>,
    pub(crate) pat: Vec<u32>,
}

impl FillScratch {
    fn reset(&mut self, n: usize) {
        self.marked.clear();
        self.marked.resize(n, u32::MAX);
        self.stack.clear();
        self.pat.clear();
    }
}

/// Reusable symbolic scratch: the reach/marker buffers the serial fill DFS
/// allocated per call, plus per-worker scratches for the parallel engine.
/// Owned by long-lived callers (the solver pool keeps one per pool and lends
/// it to every miss) so back-to-back symbolic runs are allocation-light.
#[derive(Debug, Default)]
pub struct FillWorkspace {
    /// `marked[i] == j` means row `i` was visited while computing column `j`.
    pub(crate) marked: Vec<u32>,
    /// Explicit DFS stack of `(node, next child index)` frames.
    pub(crate) dfs_stack: Vec<(u32, u32)>,
    /// Pattern accumulator for the column in flight.
    pub(crate) pattern: Vec<u32>,
    /// L patterns discovered so far: `lower[c]` = sorted rows `> c` of
    /// column `c`. The outer vec and the inner allocations are both reused
    /// across calls (cleared, not dropped).
    pub(crate) lower: Vec<Vec<u32>>,
    /// Per-worker scratches for [`super::parfill`]; sized on demand.
    pub(crate) scratches: Vec<FillScratch>,
}

impl FillWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset the serial-DFS buffers for an `n`-column run, keeping capacity.
    pub(crate) fn reset(&mut self, n: usize) {
        self.marked.clear();
        self.marked.resize(n, u32::MAX);
        self.dfs_stack.clear();
        self.pattern.clear();
        self.lower.truncate(n);
        for l in &mut self.lower {
            l.clear();
        }
        let have = self.lower.len();
        self.lower.resize_with(n, Vec::new);
        debug_assert!(have <= n);
    }

    /// Reset `threads` per-worker scratches for an `n`-column parallel run.
    pub(crate) fn reset_scratches(&mut self, threads: usize, n: usize) {
        self.scratches.resize_with(threads, FillScratch::default);
        self.scratches.truncate(threads);
        for s in &mut self.scratches {
            s.reset(n);
        }
    }
}

/// Shared validation for every symbolic entry point: square with a
/// structurally full diagonal (the pivot-free GLU regime MC64 establishes).
pub(crate) fn ensure_factorable(a: &Csc) -> anyhow::Result<()> {
    anyhow::ensure!(a.nrows() == a.ncols(), "matrix must be square");
    anyhow::ensure!(
        a.has_full_diagonal(),
        "diagonal must be structurally full (run MC64 matching first)"
    );
    Ok(())
}

/// Compute the filled pattern `As = L + U` of `a` (no pivoting — GLU's
/// regime: the diagonal must be structurally present and numerically usable,
/// which MC64-style preprocessing establishes).
pub fn symbolic_fill(a: &Csc) -> anyhow::Result<SymbolicFill> {
    symbolic_fill_with(a, &mut FillWorkspace::new())
}

/// [`symbolic_fill`] with caller-owned scratch: the reach/marker buffers in
/// `ws` are reused instead of reallocated, the win the solver pool's
/// miss path depends on when distinct patterns arrive back-to-back.
pub fn symbolic_fill_with(a: &Csc, ws: &mut FillWorkspace) -> anyhow::Result<SymbolicFill> {
    ensure_factorable(a)?;
    let n = a.nrows();
    ws.reset(n);

    let mut colptr = Vec::with_capacity(n + 1);
    colptr.push(0usize);
    let mut rowidx: Vec<usize> = Vec::new();
    let mut values: Vec<f64> = Vec::new();

    let mut fill_count = 0usize;

    for j in 0..n {
        ws.pattern.clear();
        let ju = j as u32;
        let (arows, _) = a.col(j);
        for &r in arows {
            // DFS from r through the L DAG (only nodes < j propagate).
            if ws.marked[r] == ju {
                continue;
            }
            ws.dfs_stack.clear();
            ws.marked[r] = ju;
            ws.dfs_stack.push((r as u32, 0));
            while let Some(&mut (v, ref mut ci)) = ws.dfs_stack.last_mut() {
                let v_ = v as usize;
                if v_ >= j {
                    // L part of the current column: no outgoing edges yet.
                    ws.pattern.push(v);
                    ws.dfs_stack.pop();
                    continue;
                }
                let kids = &ws.lower[v_];
                let mut pushed = false;
                while (*ci as usize) < kids.len() {
                    let t = kids[*ci as usize];
                    *ci += 1;
                    if ws.marked[t as usize] != ju {
                        ws.marked[t as usize] = ju;
                        ws.dfs_stack.push((t, 0));
                        pushed = true;
                        break;
                    }
                }
                if !pushed {
                    ws.pattern.push(v);
                    ws.dfs_stack.pop();
                }
            }
        }
        ws.pattern.sort_unstable();

        // Record column j of the filled matrix and its L pattern. `A(:,j)`
        // is a sorted subset of the (sorted) reachable pattern — every
        // structural row seeds a DFS — so a single merged scan replaces
        // the former per-entry `get` + `has_entry` pair (two binary
        // searches per output nonzero).
        let (arows, avals) = a.col(j);
        let mut ai = 0usize;
        let lcol = &mut ws.lower[j];
        for &r in &ws.pattern {
            let r_ = r as usize;
            rowidx.push(r_);
            if ai < arows.len() && arows[ai] == r_ {
                values.push(avals[ai]);
                ai += 1;
            } else {
                values.push(0.0);
                fill_count += 1;
            }
            if r > ju {
                lcol.push(r);
            }
        }
        debug_assert_eq!(ai, arows.len(), "structural entry missing from pattern");
        colptr.push(rowidx.len());
    }

    let filled = Csc::from_raw_parts(n, n, colptr, rowidx, values)?;
    Ok(SymbolicFill { filled, fill_count })
}

/// Dense-oracle symbolic factorization for tests: simulate right-looking
/// Gaussian elimination on a boolean dense matrix, return the filled pattern.
#[cfg(test)]
pub fn dense_symbolic_oracle(a: &Csc) -> Vec<bool> {
    let n = a.nrows();
    let mut p = vec![false; n * n];
    for c in 0..n {
        let (rows, _) = a.col(c);
        for &r in rows {
            p[r * n + c] = true;
        }
    }
    for k in 0..n {
        assert!(p[k * n + k], "zero diagonal in oracle");
        for i in k + 1..n {
            if p[i * n + k] {
                for j in k + 1..n {
                    if p[k * n + j] {
                        p[i * n + j] = true;
                    }
                }
            }
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, Coo};
    use crate::util::Rng;

    use crate::bench_support::paper_example;

    #[test]
    fn matches_dense_oracle_small_random() {
        let mut rng = Rng::new(17);
        for trial in 0..30 {
            let n = rng.range(4, 24);
            // random sparse pattern with full diagonal
            let mut coo = Coo::new(n, n);
            for i in 0..n {
                coo.push(i, i, 10.0);
            }
            let extras = rng.range(n, 3 * n);
            for _ in 0..extras {
                let r = rng.below(n);
                let c = rng.below(n);
                if r != c {
                    coo.push(r, c, -1.0);
                }
            }
            let a = coo.to_csc();
            let f = symbolic_fill(&a).unwrap();
            let oracle = dense_symbolic_oracle(&a);
            for c in 0..n {
                for r in 0..n {
                    assert_eq!(
                        f.filled.has_entry(r, c),
                        oracle[r * n + c],
                        "trial {trial}: mismatch at ({r},{c})"
                    );
                }
            }
        }
    }

    #[test]
    fn no_fill_on_tridiagonal() {
        let a = gen::ladder(32, 32, 0, 1);
        let f = symbolic_fill(&a).unwrap();
        assert_eq!(f.fill_count, 0);
        assert_eq!(f.filled.nnz(), a.nnz());
    }

    #[test]
    fn fill_values_copied_from_a() {
        let a = gen::grid2d(5, 5, 3);
        let f = symbolic_fill(&a).unwrap();
        for c in 0..a.ncols() {
            let (rows, vals) = f.filled.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                assert_eq!(v, a.get(r, c));
            }
        }
        assert_eq!(f.nz_original(), a.nnz());
    }

    #[test]
    fn grid_fill_is_positive() {
        let a = gen::grid2d(8, 8, 5);
        let f = symbolic_fill(&a).unwrap();
        assert!(f.fill_count > 0, "2-D grids always fill in");
    }

    #[test]
    fn paper_example_column7_updates() {
        // Fig. 2: factorizing column 7 (0-based 6) uses columns 4 and 6
        // (0-based 3 and 5): U entries A(3,6) and A(5,6) must be present.
        let a = paper_example();
        let f = symbolic_fill(&a).unwrap();
        assert!(f.filled.has_entry(3, 6));
        assert!(f.filled.has_entry(5, 6));
        // Fig. 2(a): col 4's L pattern includes rows 6 and 8 (0-based 5, 7).
        assert!(f.filled.has_entry(5, 3));
        assert!(f.filled.has_entry(7, 3));
    }

    #[test]
    fn rejects_missing_diagonal() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        assert!(symbolic_fill(&coo.to_csc()).is_err());
    }

    /// A reused workspace produces the same answer as fresh scratch on a
    /// sequence of distinct patterns — the pool-miss reuse contract.
    #[test]
    fn workspace_reuse_matches_fresh_scratch() {
        let mut ws = FillWorkspace::new();
        let mats = [
            gen::grid2d(9, 9, 2),
            gen::netlist(64, 5, 8, 0.1, 1, 0.2, 9),
            gen::grid2d(6, 11, 4),
            gen::ladder(48, 12, 24, 3),
        ];
        for a in &mats {
            let fresh = symbolic_fill(a).unwrap();
            let reused = symbolic_fill_with(a, &mut ws).unwrap();
            assert_eq!(reused.filled, fresh.filled);
            assert_eq!(reused.fill_count, fresh.fill_count);
        }
    }
}
