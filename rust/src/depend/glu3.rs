//! GLU3.0 *relaxed* dependency detection (Algorithm 4) — the paper's first
//! contribution.
//!
//! Observation (§III-A): a nonzero `As(t, i)` with `i < t` — an entry to the
//! *left* of the diagonal in row `t` of `L` — is a necessary condition for
//! any double-U hazard between columns `i` and `t`: it is exactly the entry
//! through which column `i`'s submatrix update writes into row `t`.
//! So instead of searching for the full double-U witness (`O(n³)`), GLU3.0
//! simply:
//!
//! - **looks up** column `k` of `U` (the GLU1.0 edges — kept only when
//!   column `i` of `L` is non-empty, since an empty `L(:,i)` produces no
//!   submatrix update at all), and
//! - **looks left** along row `k` of `L`, adding an edge for every nonzero.
//!
//! Two loops over the stored pattern: `O(nnz(As))`. The result is a
//! *superset* of the exact GLU2.0 set (possibly with redundant edges — the
//! red edges of Fig. 9c); levelization on the superset is at worst a few
//! levels deeper (Table II) while detection is 2–3 orders of magnitude
//! faster.

use super::{DepGraph, Levels};
use crate::sparse::Csc;

/// Streaming Algorithm 4: consume filled columns *as they land* instead of
/// after a full serial fill pass, producing the dependency graph **and** the
/// level assignment in the same ascending sweep.
///
/// Both the parallel symbolic engine ([`crate::symbolic::parfill`]) and the
/// incremental patcher ([`crate::symbolic::delta`]) assemble the filled
/// pattern column by column; feeding each column here the moment it is final
/// fuses detection + levelization into the assembly walk, removing the two
/// extra `O(nnz)` pattern passes the batch [`detect`] + `levelize` pair
/// costs. The output is bit-identical to `detect(filled)` followed by
/// `levelize`: the look-up test reads only finalized earlier columns, the
/// look-left buckets accumulate sources in the same ascending order, and
/// [`DepGraph::new`] / [`Levels::from_level_of`] normalize identically.
#[derive(Debug)]
pub struct StreamingDetect {
    l_nonempty: Vec<bool>,
    lrow: Vec<Vec<u32>>,
    deps: Vec<Vec<u32>>,
    level_of: Vec<u32>,
}

impl StreamingDetect {
    pub fn new(n: usize) -> Self {
        StreamingDetect {
            l_nonempty: vec![false; n],
            lrow: vec![Vec::new(); n],
            deps: Vec::with_capacity(n),
            level_of: vec![0u32; n],
        }
    }

    /// Consume the final sorted row pattern of filled column `k`. Columns
    /// must arrive in ascending order, exactly once each.
    pub fn consume(&mut self, k: usize, rows: &[usize]) {
        debug_assert_eq!(self.deps.len(), k, "columns must stream in order");
        let mut d: Vec<u32> = Vec::new();
        // Look up: U(i, k) != 0, i < k, and column i of L non-empty.
        for &i in rows.iter().take_while(|&&i| i < k) {
            if self.l_nonempty[i] {
                d.push(i as u32);
            }
        }
        // Look left: L-row entries As(k, i) != 0, i < k — accumulated from
        // the earlier columns' L parts as they streamed through.
        d.extend_from_slice(&self.lrow[k]);
        let mut lvl = 0u32;
        for &i in &d {
            lvl = lvl.max(self.level_of[i as usize] + 1);
        }
        self.level_of[k] = lvl;
        self.deps.push(d);
        // Publish column k's L part for the look-left of later columns.
        for &t in rows.iter().filter(|&&t| t > k) {
            self.lrow[t].push(k as u32);
        }
        self.l_nonempty[k] = rows.last().is_some_and(|&r| r > k);
    }

    /// Finish the sweep: the dependency graph and the level schedule.
    pub fn finish(self) -> (DepGraph, Levels) {
        debug_assert_eq!(self.deps.len(), self.level_of.len());
        (DepGraph::new(self.deps), Levels::from_level_of(self.level_of))
    }
}

/// Relaxed dependencies (Algorithm 4 verbatim: "look up" + "look left").
pub fn detect(filled: &Csc) -> DepGraph {
    let n = filled.ncols();

    // Column i of L is non-empty iff it has an entry strictly below the
    // diagonal. Precompute in one pass over columns.
    let mut l_nonempty = vec![false; n];
    for i in 0..n {
        let (rows, _) = filled.col(i);
        l_nonempty[i] = rows.last().is_some_and(|&r| r > i);
    }

    // "Look left": row-wise access to the strictly-lower triangle. Build a
    // row-bucketed list of L entries in one pass (cheaper than a full CSR
    // transpose — values are not needed).
    let mut lrow: Vec<Vec<u32>> = vec![Vec::new(); n];
    for i in 0..n {
        let (rows, _) = filled.col(i);
        for &t in rows.iter().filter(|&&t| t > i) {
            lrow[t].push(i as u32);
        }
    }

    let mut deps: Vec<Vec<u32>> = Vec::with_capacity(n);
    for k in 0..n {
        let (rows, _) = filled.col(k);
        let mut d: Vec<u32> = Vec::new();
        // Look up: U(i, k) != 0, i < k, and column i of L non-empty.
        for &i in rows.iter().take_while(|&&i| i < k) {
            if l_nonempty[i] {
                d.push(i as u32);
            }
        }
        // Look left: L-row entries As(k, i) != 0, i < k.
        d.extend_from_slice(&lrow[k]);
        deps.push(d);
    }
    DepGraph::new(deps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::{glu1, glu2};
    use crate::sparse::gen;
    use crate::bench_support::paper_example;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    #[test]
    fn fig8_look_left_finds_double_u() {
        // Paper Fig. 8: looking up from (6,6) finds nothing; looking left
        // finds the nonzero in column 4 -> the 6-on-4 dependency (0-based
        // 5 -> 3).
        let f = symbolic_fill(&paper_example()).unwrap();
        let g3 = detect(&f.filled);
        assert!(g3.has_edge(5, 3));
    }

    /// The safety property behind the "relaxed" claim: every *true*
    /// dependency is found. True deps = U-pattern-with-nonempty-L ∪ exact
    /// double-U (what GLU2.0 computes, minus U-edges from empty L columns
    /// which generate no work at all).
    fn relaxed_covers_required(filled: &Csc) {
        let g3 = detect(filled);
        let du = glu2::detect_double_u(filled);
        assert!(
            g3.contains(&du),
            "relaxed detection missed a double-U edge"
        );
        // U-pattern edges from columns whose L part is non-empty:
        let g1 = glu1::detect(filled);
        for k in 0..filled.ncols() {
            for &i in g1.deps_of(k) {
                let (rows, _) = filled.col(i as usize);
                let nonempty = rows.last().is_some_and(|&r| r > i as usize);
                if nonempty {
                    assert!(
                        g3.has_edge(k, i as usize),
                        "relaxed detection missed U edge {k} -> {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn covers_required_on_paper_example() {
        let f = symbolic_fill(&paper_example()).unwrap();
        relaxed_covers_required(&f.filled);
    }

    #[test]
    fn property_covers_required_on_random_circuits() {
        let mut rng = Rng::new(0xA14);
        for trial in 0..20 {
            let n = rng.range(30, 120);
            let a = gen::netlist(n, 6, 8, 0.1, 2, 0.25, 1000 + trial);
            let f = symbolic_fill(&a).unwrap();
            relaxed_covers_required(&f.filled);
        }
    }

    #[test]
    fn property_covers_required_on_meshes() {
        for (nx, ny, seed) in [(6, 6, 1u64), (9, 5, 2), (12, 12, 3)] {
            let a = gen::grid2d(nx, ny, seed);
            let f = symbolic_fill(&a).unwrap();
            relaxed_covers_required(&f.filled);
        }
    }

    /// The streaming consumer is bit-identical to the batch pair
    /// `detect` + `levelize` on the same filled pattern.
    #[test]
    fn streaming_matches_batch_detect_and_levelize() {
        let mut rng = Rng::new(0x57E4);
        let mut fixtures = vec![
            symbolic_fill(&paper_example()).unwrap().filled,
            symbolic_fill(&gen::grid2d(12, 9, 4)).unwrap().filled,
        ];
        for trial in 0..6 {
            let n = rng.range(20, 90);
            let a = gen::netlist(n, 6, 8, 0.1, 2, 0.25, 3000 + trial);
            fixtures.push(symbolic_fill(&a).unwrap().filled);
        }
        for filled in &fixtures {
            let batch_deps = detect(filled);
            let batch_levels = crate::depend::levelize(&batch_deps);
            let mut sd = StreamingDetect::new(filled.ncols());
            for k in 0..filled.ncols() {
                sd.consume(k, filled.col(k).0);
            }
            let (deps, levels) = sd.finish();
            assert_eq!(deps, batch_deps);
            assert_eq!(levels, batch_levels);
        }
    }

    #[test]
    fn relaxed_may_add_redundant_edges() {
        // Fig. 9(c): the relaxed set is allowed to be strictly larger.
        // On the paper example it is.
        let f = symbolic_fill(&paper_example()).unwrap();
        let g2 = glu2::detect(&f.filled);
        let g3 = detect(&f.filled);
        assert!(g3.num_edges() >= g2.num_edges());
    }
}
