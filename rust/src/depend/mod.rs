//! Column dependency detection and levelization — the first contribution of
//! GLU3.0 (paper §II-C, §III-A).
//!
//! Three detection algorithms are implemented, matching the paper's Fig. 9:
//!
//! - [`glu1`] — the classic U-pattern method used by left-looking codes and
//!   GLU1.0. **Incorrect** for the hybrid right-looking algorithm: it misses
//!   the *double-U* read/write hazard, which can corrupt results when two
//!   columns in one level race on a shared subcolumn element.
//! - [`glu2`] — GLU2.0's explicit double-U search (Algorithm 3), a
//!   triple-nested O(n³)-class scan. Exact, but dominates preprocessing time
//!   (Table II's left column).
//! - [`glu3`] — GLU3.0's *relaxed* detection (Algorithm 4): "look up" the U
//!   column plus "look left" along the L row. Two loops over the pattern
//!   (O(nnz)), finding a **superset** of the exact dependencies; the paper
//!   shows (and our benches confirm) the few redundant edges cost at most a
//!   handful of extra levels.
//!
//! [`levelize`] turns any dependency set into levels: groups of columns with
//! no mutual dependencies that the numeric kernel may factorize in parallel.

pub mod glu1;
pub mod glu2;
pub mod glu3;
pub mod levelize;

pub use levelize::{levelize, Levels};

/// A column dependency graph: `deps[k]` lists columns that must be
/// factorized before column `k` (all entries `< k`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DepGraph {
    deps: Vec<Vec<u32>>,
}

impl DepGraph {
    /// Build from per-column dependency lists, deduplicating and sorting.
    pub fn new(mut deps: Vec<Vec<u32>>) -> Self {
        for (k, d) in deps.iter_mut().enumerate() {
            d.sort_unstable();
            d.dedup();
            debug_assert!(d.iter().all(|&i| (i as usize) < k), "dep must precede");
        }
        DepGraph { deps }
    }

    /// Number of columns.
    pub fn n(&self) -> usize {
        self.deps.len()
    }

    /// Dependencies of column `k` (sorted, unique, all `< k`).
    pub fn deps_of(&self, k: usize) -> &[u32] {
        &self.deps[k]
    }

    /// Total number of dependency edges.
    pub fn num_edges(&self) -> usize {
        self.deps.iter().map(|d| d.len()).sum()
    }

    /// Whether `k` depends on `i`.
    pub fn has_edge(&self, k: usize, i: usize) -> bool {
        self.deps[k].binary_search(&(i as u32)).is_ok()
    }

    /// Whether every edge of `other` is present in `self` (superset check —
    /// the paper's "relaxed ⊇ exact" property).
    pub fn contains(&self, other: &DepGraph) -> bool {
        self.deps.len() == other.deps.len()
            && other
                .deps
                .iter()
                .enumerate()
                .all(|(k, d)| d.iter().all(|&i| self.has_edge(k, i as usize)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_superset() {
        let g = DepGraph::new(vec![vec![], vec![0, 0], vec![1]]);
        assert_eq!(g.deps_of(1), &[0]);
        assert_eq!(g.num_edges(), 2);
        let h = DepGraph::new(vec![vec![], vec![0], vec![0, 1]]);
        assert!(h.contains(&g));
        assert!(!g.contains(&h));
    }
}
