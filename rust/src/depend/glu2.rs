//! GLU2.0 dependency detection: the explicit double-U search (Algorithm 3)
//! plus the U-pattern edges.
//!
//! The double-U hazard (paper Fig. 4): while column `i` is being factorized
//! it *writes* `As(t, k)` for every `t ∈ L(:,i)`, `k ∈ U(i,:)`; if column `t`
//! is factorized concurrently it *reads* `As(t, k)` to update `As(j, k)` for
//! `j ∈ L(:,t)`. The write must land first, so `t` depends on `i` whenever
//! such a `k > t` exists — Algorithm 3 searches for it directly:
//!
//! ```text
//! for i = 1..n:                      (row i of U = I_i)
//!   for t where As(t,i) != 0, t > i:   (L entries of column i)
//!     for j where As(j,t) != 0, j > t: (L entries of column t)
//!       if ∃ k ∈ I_i ∩ I_j, k > t:  add edge t -> i
//! ```
//!
//! The triple nest over sparse patterns is the O(n³)-class cost Table II
//! measures; this implementation is faithful to the algorithm (with the one
//! obvious short-circuit: stop scanning `j` once the edge is found).

use super::{glu1, DepGraph};
use crate::sparse::Csc;

/// Exact GLU2.0 dependencies: U-pattern ∪ double-U (Algorithm 3).
pub fn detect(filled: &Csc) -> DepGraph {
    let upattern = glu1::detect(filled);
    let doubleu = detect_double_u(filled);
    let n = filled.ncols();
    let mut deps: Vec<Vec<u32>> = Vec::with_capacity(n);
    for k in 0..n {
        let mut d: Vec<u32> = upattern.deps_of(k).to_vec();
        d.extend_from_slice(doubleu.deps_of(k));
        deps.push(d);
    }
    DepGraph::new(deps)
}

/// Only the double-U edges (Algorithm 3 verbatim).
pub fn detect_double_u(filled: &Csc) -> DepGraph {
    let n = filled.ncols();
    let csr = filled.to_csr();
    let mut deps: Vec<Vec<u32>> = vec![Vec::new(); n];

    for i in 0..n {
        // I_i: row i's nonzero column indices (sorted by CSR invariant).
        let (row_i, _) = csr.row(i);
        if row_i.last().is_none_or(|&last| last <= i) {
            continue; // no U entries to the right of the diagonal
        }
        let (lrows, _) = filled.col(i);
        for &t in lrows.iter().filter(|&&t| t > i) {
            if deps[t].contains(&(i as u32)) {
                continue;
            }
            let (lt_rows, _) = filled.col(t);
            'js: for &j in lt_rows.iter().filter(|&&j| j > t) {
                let (row_j, _) = csr.row(j);
                // ∃ k > t with k ∈ I_i ∩ I_j : sorted two-pointer scan.
                if sorted_intersect_after(row_i, row_j, t) {
                    deps[t].push(i as u32);
                    break 'js;
                }
            }
        }
    }
    DepGraph::new(deps)
}

/// Algorithm 3 **verbatim** — the implementation Table II times.
///
/// Faithful to the paper's pseudocode (and its O(n³) class): `I_j` is
/// *stored* (materialized) afresh for every `(t, j)` pair, the existence
/// check `∃k ∈ I_i ∩ I_j, k > t` is a plain nested scan over the two index
/// lists, and the `j` loop runs to completion. [`detect_double_u`] above is
/// this crate's *optimized* variant (sorted two-pointer intersection +
/// early exit) used on the solver path; benchmarking the optimized variant
/// would understate the speedup the paper reports, benchmarking this one
/// reproduces it.
pub fn detect_verbatim(filled: &Csc) -> DepGraph {
    let n = filled.ncols();
    let csr = filled.to_csr();
    let mut deps: Vec<Vec<u32>> = vec![Vec::new(); n];

    for i in 0..n {
        // "Store all non-zero indices of row i in I_i"
        let i_i: Vec<usize> = csr.row(i).0.to_vec();
        let (lrows, _) = filled.col(i);
        for &t in lrows.iter().filter(|&&t| t > i) {
            let (lt_rows, _) = filled.col(t);
            for &j in lt_rows.iter().filter(|&&j| j > t) {
                // "Store all non-zero indices of row j in I_j"
                let i_j: Vec<usize> = csr.row(j).0.to_vec();
                // "if ∃k, k ∈ I_i, k ∈ I_j, k > t"
                let mut found = false;
                for &k in &i_i {
                    if k > t {
                        for &k2 in &i_j {
                            if k2 == k {
                                found = true;
                                break;
                            }
                        }
                    }
                    if found {
                        break;
                    }
                }
                if found && !deps[t].contains(&(i as u32)) {
                    // "Add i to t's dependency list"
                    deps[t].push(i as u32);
                }
            }
        }
    }
    // Combine with the U-pattern edges as GLU2.0's full detection does.
    let upattern = glu1::detect(filled);
    for (k, d) in deps.iter_mut().enumerate() {
        d.extend_from_slice(upattern.deps_of(k));
    }
    DepGraph::new(deps)
}

/// Crate-visible alias used by the independent hazard validator in
/// [`super::levelize`] (it re-derives hazards with the same primitive).
pub(crate) fn sorted_intersect_after_pub(a: &[usize], b: &[usize], t: usize) -> bool {
    sorted_intersect_after(a, b, t)
}

/// Whether sorted slices `a` and `b` share an element strictly greater
/// than `t`.
fn sorted_intersect_after(a: &[usize], b: &[usize], t: usize) -> bool {
    let mut ia = a.partition_point(|&x| x <= t);
    let mut ib = b.partition_point(|&x| x <= t);
    while ia < a.len() && ib < b.len() {
        match a[ia].cmp(&b[ib]) {
            std::cmp::Ordering::Equal => return true,
            std::cmp::Ordering::Less => ia += 1,
            std::cmp::Ordering::Greater => ib += 1,
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::paper_example;
    use crate::symbolic::symbolic_fill;

    #[test]
    fn sorted_intersect_basic() {
        assert!(sorted_intersect_after(&[1, 4, 7], &[2, 7], 4));
        assert!(!sorted_intersect_after(&[1, 4, 7], &[2, 7], 7));
        assert!(!sorted_intersect_after(&[1, 4], &[2, 5], 0));
        assert!(sorted_intersect_after(&[3], &[3], 2));
    }

    #[test]
    fn paper_fig4_double_u_between_cols_4_and_6() {
        // Paper Fig. 4 (1-based): i=4, t=6, j=8, k=7. 0-based: col 5 must
        // gain a double-U dependency on col 3.
        let f = symbolic_fill(&paper_example()).unwrap();
        let du = detect_double_u(&f.filled);
        assert!(
            du.has_edge(5, 3),
            "missing the Fig. 4 double-U edge 6 -> 4 (0-based 5 -> 3); edges: {:?}",
            (0..8).map(|k| du.deps_of(k).to_vec()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn glu2_contains_glu1() {
        let f = symbolic_fill(&paper_example()).unwrap();
        let g1 = glu1::detect(&f.filled);
        let g2 = detect(&f.filled);
        assert!(g2.contains(&g1));
        assert!(g2.num_edges() > g1.num_edges(), "double-U must add edges");
    }

    #[test]
    fn verbatim_matches_optimized() {
        use crate::sparse::gen;
        use crate::util::Rng;
        let mut rng = Rng::new(0x5E);
        for trial in 0..8 {
            let n = rng.range(20, 80);
            let a = gen::netlist(n.max(8), 6, 8, 0.1, 2, 0.25, 7000 + trial);
            let f = symbolic_fill(&a).unwrap();
            let fast = detect(&f.filled);
            let slow = detect_verbatim(&f.filled);
            assert_eq!(fast, slow, "trial {trial}");
        }
    }

    #[test]
    fn no_double_u_on_tridiagonal() {
        // Chain: L(:,i) = {i+1}, U(i,:) = {i+1}; double-U needs k > t = i+1
        // in row i — absent in a tridiagonal pattern.
        let a = crate::sparse::gen::ladder(12, 12, 0, 1);
        let f = symbolic_fill(&a).unwrap();
        assert_eq!(detect_double_u(&f.filled).num_edges(), 0);
    }
}
