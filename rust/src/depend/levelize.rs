//! Levelization: grouping columns into parallel levels.
//!
//! `level(k) = 0` if column `k` has no dependencies, else
//! `1 + max(level(dep))` — longest-path layering of the dependency DAG (the
//! paper's analogue of an elimination-tree schedule). All columns in one
//! level are mutually independent and are factorized in parallel by the GPU
//! kernel; *the number of levels is the most decisive parameter of the GPU
//! kernel runtime* (paper §IV).

use super::DepGraph;
use crate::sparse::Csc;

/// A level schedule for the numeric kernel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Levels {
    /// `level_of[k]` = level index of column `k`.
    pub level_of: Vec<u32>,
    /// `levels[l]` = columns in level `l`, ascending.
    pub levels: Vec<Vec<u32>>,
}

impl Levels {
    /// Number of levels (the paper's "most decisive parameter").
    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    /// Size of the largest level.
    pub fn max_level_size(&self) -> usize {
        self.levels.iter().map(|l| l.len()).max().unwrap_or(0)
    }

    /// Group a per-column level assignment into the level lists — the
    /// shared back half of [`levelize`], the streaming detector
    /// ([`crate::depend::glu3::StreamingDetect`]), and the incremental
    /// symbolic patcher. Ascending iteration keeps every level's column
    /// list sorted, so the result is bit-identical no matter which front
    /// end produced `level_of`.
    pub fn from_level_of(level_of: Vec<u32>) -> Levels {
        let nlevels = level_of.iter().map(|&l| l + 1).max().unwrap_or(0);
        let mut levels: Vec<Vec<u32>> = vec![Vec::new(); nlevels as usize];
        for (k, &l) in level_of.iter().enumerate() {
            levels[l as usize].push(k as u32);
        }
        Levels { level_of, levels }
    }
}

/// Compute levels from a dependency graph. Single forward pass: every
/// dependency references a smaller column index, so levels are final by the
/// time they are read.
pub fn levelize(deps: &DepGraph) -> Levels {
    let n = deps.n();
    let mut level_of = vec![0u32; n];
    for k in 0..n {
        let mut lvl = 0u32;
        for &d in deps.deps_of(k) {
            lvl = lvl.max(level_of[d as usize] + 1);
        }
        level_of[k] = lvl;
    }
    Levels::from_level_of(level_of)
}

/// Validate that a level schedule is *hazard-free* for the hybrid
/// right-looking kernel: no two columns in the same level may have (a) a
/// direct U dependency with work attached, or (b) a double-U read/write
/// hazard. This is the ground-truth safety check used by the property tests
/// (it re-derives the hazards from the pattern, independently of whichever
/// detection algorithm produced the schedule).
pub fn validate_hazard_free(filled: &Csc, levels: &Levels) -> Result<(), String> {
    let n = filled.ncols();
    let csr = filled.to_csr();
    let l_nonempty: Vec<bool> = (0..n)
        .map(|i| filled.col(i).0.last().is_some_and(|&r| r > i))
        .collect();

    // (a) direct U edges with work: As(i,k) != 0, i < k, L(:,i) non-empty.
    for k in 0..n {
        let (rows, _) = filled.col(k);
        for &i in rows.iter().take_while(|&&i| i < k) {
            if l_nonempty[i] && levels.level_of[i] >= levels.level_of[k] {
                return Err(format!(
                    "columns {i} and {k}: U dependency within/across level order \
                     (lvl {} vs {})",
                    levels.level_of[i], levels.level_of[k]
                ));
            }
        }
    }

    // (b) double-U hazards: reuse the Algorithm 3 condition.
    for i in 0..n {
        let (row_i, _) = csr.row(i);
        if row_i.last().is_none_or(|&last| last <= i) {
            continue;
        }
        let (lrows, _) = filled.col(i);
        for &t in lrows.iter().filter(|&&t| t > i) {
            if levels.level_of[t] > levels.level_of[i] {
                continue; // already ordered
            }
            let (lt_rows, _) = filled.col(t);
            for &j in lt_rows.iter().filter(|&&j| j > t) {
                let (row_j, _) = csr.row(j);
                if super::glu2::sorted_intersect_after_pub(row_i, row_j, t) {
                    return Err(format!(
                        "columns {i} and {t}: double-U hazard not ordered \
                         (lvl {} vs {})",
                        levels.level_of[i], levels.level_of[t]
                    ));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::paper_example;
    use crate::depend::{glu1, glu2, glu3};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    #[test]
    fn chain_levels_are_sequential() {
        let a = gen::ladder(8, 8, 0, 1);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        assert_eq!(lv.num_levels(), 8);
        for k in 0..8 {
            assert_eq!(lv.level_of[k], k as u32);
        }
    }

    #[test]
    fn diagonal_matrix_single_level() {
        let a = crate::sparse::Csc::identity(10);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        assert_eq!(lv.num_levels(), 1);
        assert_eq!(lv.levels[0].len(), 10);
    }

    #[test]
    fn levels_partition_columns() {
        let a = gen::netlist(200, 6, 12, 0.05, 3, 0.2, 5);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let total: usize = lv.levels.iter().map(|l| l.len()).sum();
        assert_eq!(total, 200);
        for (l, cols) in lv.levels.iter().enumerate() {
            assert!(!cols.is_empty(), "level {l} empty");
            for &c in cols {
                assert_eq!(lv.level_of[c as usize], l as u32);
            }
        }
    }

    #[test]
    fn glu2_and_glu3_schedules_are_hazard_free() {
        let mut rng = Rng::new(0xBEEF);
        for trial in 0..15 {
            let n = rng.range(30, 100);
            let a = gen::netlist(n, 6, 8, 0.1, 2, 0.25, 2000 + trial);
            let f = symbolic_fill(&a).unwrap();
            for (name, g) in [
                ("glu2", glu2::detect(&f.filled)),
                ("glu3", glu3::detect(&f.filled)),
            ] {
                let lv = levelize(&g);
                validate_hazard_free(&f.filled, &lv)
                    .unwrap_or_else(|e| panic!("trial {trial} {name}: {e}"));
            }
        }
    }

    #[test]
    fn glu1_schedule_has_hazard_on_paper_example() {
        // Fig. 9(a) is *incorrect*: the GLU1.0 schedule must fail the
        // hazard validator on the example matrix (that is the whole point
        // of GLU2.0/3.0).
        let f = symbolic_fill(&paper_example()).unwrap();
        let lv = levelize(&glu1::detect(&f.filled));
        assert!(validate_hazard_free(&f.filled, &lv).is_err());
    }

    #[test]
    fn relaxed_levelization_close_to_exact() {
        // Table II: "the number of additional levels resulting from the new
        // dependency detection method are just a few or even zero".
        let mut rng = Rng::new(0xFACE);
        for trial in 0..10 {
            let n = rng.range(50, 150);
            let a = gen::netlist(n, 6, 10, 0.08, 2, 0.2, 3000 + trial);
            let f = symbolic_fill(&a).unwrap();
            let exact = levelize(&glu2::detect(&f.filled)).num_levels();
            let relaxed = levelize(&glu3::detect(&f.filled)).num_levels();
            assert!(relaxed >= exact);
            assert!(
                relaxed <= exact + exact / 2 + 8,
                "trial {trial}: relaxed {relaxed} vs exact {exact}"
            );
        }
    }

    #[test]
    fn paper_example_levelization_matches_between_glu2_and_glu3() {
        // Fig. 9: "Despite the redundant dependencies, the result of
        // levelization is exactly the same".
        let f = symbolic_fill(&paper_example()).unwrap();
        let exact = levelize(&glu2::detect(&f.filled));
        let relaxed = levelize(&glu3::detect(&f.filled));
        assert_eq!(exact.num_levels(), relaxed.num_levels());
    }

    /// Tridiagonal chain: every column depends on its predecessor, so the
    /// only hazard-free schedule is fully sequential. The validator must
    /// accept it and reject any flattened variant.
    #[test]
    fn validator_on_adversarial_chain() {
        let n = 12;
        let mut dense = vec![0.0; n * n];
        for i in 0..n {
            dense[i * n + i] = 4.0;
            if i + 1 < n {
                dense[i * n + i + 1] = 1.0; // U entry (i, i+1)
                dense[(i + 1) * n + i] = 1.0; // L entry (i+1, i)
            }
        }
        let a = crate::sparse::Csc::from_dense(n, n, &dense);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        assert_eq!(lv.num_levels(), n);
        validate_hazard_free(&f.filled, &lv).unwrap();

        // flat schedule: everything "parallel" — must be rejected
        let flat = Levels {
            level_of: vec![0; n],
            levels: vec![(0..n as u32).collect()],
        };
        assert!(validate_hazard_free(&f.filled, &flat).is_err());

        // off-by-one schedule: columns paired two-per-level — also unsafe
        let paired = Levels {
            level_of: (0..n).map(|k| (k / 2) as u32).collect(),
            levels: Vec::new(), // validator only reads level_of
        };
        assert!(validate_hazard_free(&f.filled, &paired).is_err());
    }

    /// Star: column 0 feeds every other column (dense U row 0 + L work in
    /// column 0). A 2-deep schedule is the exact answer; putting any
    /// dependent column next to its hub must be rejected.
    #[test]
    fn validator_on_adversarial_star() {
        let n = 8;
        let mut dense = vec![0.0; n * n];
        for i in 0..n {
            dense[i * n + i] = 8.0;
        }
        for j in 1..n {
            dense[j] = 1.0; // U row 0: (0, j)
        }
        dense[(n - 1) * n] = 1.0; // L work in column 0: (n-1, 0)
        let a = crate::sparse::Csc::from_dense(n, n, &dense);
        let f = symbolic_fill(&a).unwrap();

        let exact = levelize(&glu2::detect(&f.filled));
        validate_hazard_free(&f.filled, &exact).unwrap();
        assert!(exact.level_of[0] == 0);
        for k in 1..n {
            assert!(exact.level_of[k] >= 1, "column {k} must wait for the hub");
        }

        // the relaxed schedule is also safe (supersets only add ordering)
        let relaxed = levelize(&glu3::detect(&f.filled));
        validate_hazard_free(&f.filled, &relaxed).unwrap();
        assert!(relaxed.num_levels() >= exact.num_levels());

        // hoisting a spoke into the hub's level races on U(0, k)
        let mut bad = exact.clone();
        bad.level_of[3] = 0;
        assert!(validate_hazard_free(&f.filled, &bad).is_err());
    }

    /// The *true hazard graph*: exact double-U edges plus U-pattern edges
    /// whose source column carries L work. These — and only these — are the
    /// orderings [`validate_hazard_free`] enforces (a no-work U edge
    /// produces no submatrix update, hence no hazard).
    fn true_hazard_graph(filled: &crate::sparse::Csc) -> crate::depend::DepGraph {
        let n = filled.ncols();
        let l_nonempty: Vec<bool> = (0..n)
            .map(|i| filled.col(i).0.last().is_some_and(|&r| r > i))
            .collect();
        let g1 = glu1::detect(filled);
        let du = glu2::detect_double_u(filled);
        let mut deps: Vec<Vec<u32>> = Vec::with_capacity(n);
        for k in 0..n {
            let mut d: Vec<u32> = g1
                .deps_of(k)
                .iter()
                .copied()
                .filter(|&i| l_nonempty[i as usize])
                .collect();
            d.extend_from_slice(du.deps_of(k));
            deps.push(d);
        }
        crate::depend::DepGraph::new(deps)
    }

    /// Randomly generated DAGs (via random circuit matrices): the true,
    /// exact, and relaxed schedules always validate, and demoting any
    /// column whose true-hazard level is positive must trip the validator —
    /// that level was forced by a real read/write hazard.
    #[test]
    fn validator_on_random_dags() {
        let mut rng = Rng::new(0xDA6);
        for trial in 0..10 {
            let n = rng.range(25, 90);
            let a = gen::netlist(n, 6, 8, 0.1, 2, 0.25, 7000 + trial);
            let f = symbolic_fill(&a).unwrap();
            let truth = levelize(&true_hazard_graph(&f.filled));
            validate_hazard_free(&f.filled, &truth)
                .unwrap_or_else(|e| panic!("trial {trial} true graph: {e}"));
            let exact = levelize(&glu2::detect(&f.filled));
            validate_hazard_free(&f.filled, &exact)
                .unwrap_or_else(|e| panic!("trial {trial} exact: {e}"));
            let relaxed = levelize(&glu3::detect(&f.filled));
            validate_hazard_free(&f.filled, &relaxed)
                .unwrap_or_else(|e| panic!("trial {trial} relaxed: {e}"));

            // corrupt: demote one hazard-constrained column to level 0
            let candidates: Vec<usize> = (0..n).filter(|&k| truth.level_of[k] > 0).collect();
            if candidates.is_empty() {
                continue;
            }
            let victim = candidates[rng.below(candidates.len())];
            let mut bad = truth.clone();
            bad.level_of[victim] = 0;
            assert!(
                validate_hazard_free(&f.filled, &bad).is_err(),
                "trial {trial}: demoting column {victim} must be caught"
            );
        }
    }

    /// GLU3.0's relaxed detection covers every true dependency, so its
    /// schedule can never be shallower than the true dependency depth (the
    /// longest path through the real hazard graph).
    #[test]
    fn relaxed_never_fewer_levels_than_true_depth() {
        // the paper's 8x8 example first
        let f = symbolic_fill(&paper_example()).unwrap();
        let true_depth = levelize(&true_hazard_graph(&f.filled)).num_levels();
        assert!(levelize(&glu3::detect(&f.filled)).num_levels() >= true_depth);

        let mut rng = Rng::new(0xDEB7);
        for trial in 0..12 {
            let n = rng.range(20, 120);
            let a = gen::netlist(n, 5, 9, 0.08, 2, 0.2, 8000 + trial);
            let f = symbolic_fill(&a).unwrap();
            let truth = true_hazard_graph(&f.filled);
            let relaxed_graph = glu3::detect(&f.filled);
            // the superset property is what guarantees the depth bound
            assert!(
                relaxed_graph.contains(&truth),
                "trial {trial}: relaxed must cover every true dependency"
            );
            let true_depth = levelize(&truth).num_levels();
            let relaxed_depth = levelize(&relaxed_graph).num_levels();
            assert!(
                relaxed_depth >= true_depth,
                "trial {trial}: relaxed {relaxed_depth} < true depth {true_depth}"
            );
        }
    }
}
