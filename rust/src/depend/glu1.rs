//! GLU1.0 dependency detection: the U-pattern ("look up") method.
//!
//! `U(i, k) ≠ 0` for `i < k` makes column `k` depend on column `i` — the
//! dependency structure of the *left-looking* triangular solve. GLU1.0
//! reused it unchanged for the hybrid right-looking kernel, which is why
//! GLU1.0 can produce wrong numbers: the right-looking submatrix update adds
//! the double-U read/write hazard this method cannot see (paper Fig. 4,
//! Fig. 9a).
//!
//! Kept as (a) the baseline for Table II, (b) a correctness foil for the
//! hazard-checking property tests, and (c) the correct detector for the
//! *left-looking* CPU baseline where it is sufficient.

use super::DepGraph;
use crate::sparse::Csc;

/// U-pattern dependencies on a filled matrix `As = L + U`.
pub fn detect(filled: &Csc) -> DepGraph {
    let n = filled.ncols();
    let mut deps: Vec<Vec<u32>> = Vec::with_capacity(n);
    for k in 0..n {
        let (rows, _) = filled.col(k);
        // all entries strictly above the diagonal: U(i, k) with i < k
        let d: Vec<u32> = rows
            .iter()
            .take_while(|&&i| i < k)
            .map(|&i| i as u32)
            .collect();
        deps.push(d);
    }
    DepGraph::new(deps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;

    #[test]
    fn u_entries_become_edges() {
        // Tridiagonal chain: U(k-1, k) != 0 for every k -> chain deps.
        let a = gen::ladder(8, 8, 0, 1);
        let f = symbolic_fill(&a).unwrap();
        let g = detect(&f.filled);
        for k in 1..8 {
            assert_eq!(g.deps_of(k), &[(k - 1) as u32]);
        }
        assert!(g.deps_of(0).is_empty());
    }

    #[test]
    fn diagonal_matrix_no_edges() {
        let a = crate::sparse::Csc::identity(6);
        let f = symbolic_fill(&a).unwrap();
        assert_eq!(detect(&f.filled).num_edges(), 0);
    }
}
