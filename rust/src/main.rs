//! `glu3` — CLI for the GLU3.0 sparse LU reproduction.
//!
//! ```text
//! glu3 factor  --matrix <suite-name|file.mtx> [--policy P] [--detect D] [--ordering O] [--engine E]
//! glu3 solve   --matrix <...> [--rhs ones|ramp] [options]
//! glu3 suite   [--set small|all] [--policy P]
//! glu3 profile --matrix <...>        # Fig. 10 per-level parallelism dump
//! glu3 info    --matrix <...>        # structural stats only
//! ```
//!
//! Matrix names resolve against the synthetic suite
//! ([`glu3::sparse::gen::SuiteMatrix`]); anything ending in `.mtx` is read
//! as a Matrix Market file. (Offline build: argument parsing is hand-rolled —
//! no clap in the vendored crate set.)

use std::collections::HashMap;
use std::process::ExitCode;

use glu3::bench_support::table::{ms, ratio, Table};
use glu3::glu::{
    parallelism_profile, Detection, ExecBackend, GluOptions, GluSolver, NumericEngine,
};
use glu3::gpusim::Policy;
use glu3::numeric::residual;
use glu3::order::FillOrdering;
use glu3::sparse::gen::{self, SuiteMatrix};
use glu3::sparse::{io, Csc};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "factor" => cmd_factor(&flags, false),
        "solve" => cmd_factor(&flags, true),
        "suite" => cmd_suite(&flags),
        "profile" => cmd_profile(&flags),
        "serve" => cmd_serve(&flags),
        "bench" => cmd_bench(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command {other}; try `glu3 help`"),
    }
}

fn print_usage() {
    println!(
        "glu3 — GLU3.0 sparse LU factorization (paper reproduction)\n\n\
         commands:\n\
         \x20 factor  --matrix <name|file.mtx> [--policy glu3|glu2|lee|nosmall|nostream]\n\
         \x20         [--detect glu1|glu2|glu3] [--ordering amd|rcm|natural]\n\
         \x20         [--engine auto|gpu|left|right|parcpu|parrl|sched|sched-pjrt] [--threads T]\n\
         \x20         (default: auto — per-pattern engine selection from the plan)\n\
         \x20 solve   same options, also solves (--rhs ones|ramp)\n\
         \x20 suite   [--set small|all] [--policy ...]   run the whole suite\n\
         \x20 profile --matrix <...>   per-level parallelism profile (Fig. 10)\n\
         \x20 serve   --matrix <...> [--requests N] [--tenants T] [--workers W] [--queue Q]\n\
         \x20         [--patterns P] [--deadline-ms D] [--fault-seed S] [--rate RPS]\n\
         \x20         [--sweep] [--out BENCH_service.json]\n\
         \x20         drive the fault-tolerant serving core (admission control, deadlines,\n\
         \x20         coalescing, seeded chaos) and emit the service bench report\n\
         \x20 bench   [--matrix <...>] [--threads 1,2,4] [--iters N] [--warmup N]\n\
         \x20         [--out BENCH_numeric.json] [--smoke]\n\
         \x20         wall-clock factor/refactor/solve across engines -> JSON\n\
         \x20 info    --matrix <...>   structural stats\n\n\
         suite names: {}",
        SuiteMatrix::ALL
            .iter()
            .map(|m| m.ufl_name())
            .collect::<Vec<_>>()
            .join(", ")
    );
}

/// Flags that take no value (presence == "true").
const BOOL_FLAGS: &[&str] = &["smoke", "sweep"];

fn parse_flags(args: &[String]) -> anyhow::Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let Some(key) = a.strip_prefix("--") else {
            anyhow::bail!("unexpected argument {a}");
        };
        if BOOL_FLAGS.contains(&key) {
            map.insert(key.to_string(), "true".to_string());
            continue;
        }
        let val = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
        map.insert(key.to_string(), val.clone());
    }
    Ok(map)
}

fn load_matrix(flags: &HashMap<String, String>) -> anyhow::Result<(String, Csc)> {
    let spec = flags
        .get("matrix")
        .ok_or_else(|| anyhow::anyhow!("--matrix is required"))?;
    if spec.ends_with(".mtx") {
        return Ok((spec.clone(), io::read_matrix_market(spec)?));
    }
    for m in SuiteMatrix::ALL {
        if m.ufl_name().eq_ignore_ascii_case(spec) {
            return Ok((m.ufl_name().to_string(), gen::generate(&m.spec())));
        }
    }
    anyhow::bail!("unknown matrix {spec} (suite name or .mtx path)")
}

fn options_from(flags: &HashMap<String, String>) -> anyhow::Result<GluOptions> {
    let mut opts = GluOptions::default();
    if let Some(p) = flags.get("policy") {
        opts.policy = match p.as_str() {
            "glu3" => Policy::glu3(),
            "glu2" => Policy::glu2_fixed(),
            "lee" => Policy::lee_enhanced(),
            "nosmall" => Policy::glu3_no_small(),
            "nostream" => Policy::glu3_no_stream(),
            other => anyhow::bail!("unknown policy {other}"),
        };
    }
    if let Some(d) = flags.get("detect") {
        opts.detection = match d.as_str() {
            "glu1" => Detection::Glu1,
            "glu2" => Detection::Glu2,
            "glu3" => Detection::Glu3,
            other => anyhow::bail!("unknown detection {other}"),
        };
    }
    if let Some(o) = flags.get("ordering") {
        opts.ordering = match o.as_str() {
            "amd" => FillOrdering::Amd,
            "rcm" => FillOrdering::Rcm,
            "natural" => FillOrdering::Natural,
            other => anyhow::bail!("unknown ordering {other}"),
        };
    }
    // --threads overrides the default (host parallelism) for the
    // pool-backed and auto-resolved engines.
    let threads = match flags.get("threads") {
        Some(t) => t
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--threads must be a single integer with --engine"))?,
        None => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    };
    // The CLI defaults to the auto engine — CKTSO-style per-pattern
    // selection from the factor plan's level statistics. (The library
    // default `GluOptions::default()` stays the simulated GPU engine.)
    opts.engine = match flags.get("engine").map(|s| s.as_str()) {
        None | Some("auto") => NumericEngine::Auto { threads },
        Some("gpu") => NumericEngine::SimulatedGpu,
        Some("left") => NumericEngine::LeftLookingCpu,
        Some("right") => NumericEngine::RightLookingCpu,
        Some("parcpu") => NumericEngine::ParallelCpu { threads },
        Some("parrl") => NumericEngine::ParallelRightLooking { threads },
        Some("sched") => NumericEngine::Schedule {
            backend: ExecBackend::Virtual,
        },
        Some("sched-pjrt") => NumericEngine::Schedule {
            backend: ExecBackend::Pjrt,
        },
        Some(other) => anyhow::bail!("unknown engine {other}"),
    };
    Ok(opts)
}

fn cmd_factor(flags: &HashMap<String, String>, also_solve: bool) -> anyhow::Result<()> {
    let (name, a) = load_matrix(flags)?;
    let opts = options_from(flags)?;
    println!(
        "factoring {name}: n={} nz={} (policy {}, {:?}, {:?})",
        a.nrows(),
        a.nnz(),
        opts.policy.name,
        opts.detection,
        opts.ordering
    );
    let mut solver = GluSolver::factor(&a, &opts)?;
    let st = solver.stats();
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["engine".to_string(), st.resolved_engine.clone()]);
    t.row(vec!["rows".to_string(), st.n.to_string()]);
    t.row(vec!["nz (before fill)".to_string(), st.nz.to_string()]);
    t.row(vec!["nnz (after fill)".to_string(), st.nnz.to_string()]);
    t.row(vec!["levels".to_string(), st.num_levels.to_string()]);
    t.row(vec![
        "max level size".to_string(),
        st.max_level_size.to_string(),
    ]);
    t.row(vec!["preprocess (ms)".to_string(), ms(st.preprocess_ms)]);
    // The symbolic stage table: total plus its three components (fill
    // discovery, dependency detection, levelization) and how it ran.
    t.row(vec!["symbolic total (ms)".to_string(), ms(st.symbolic_ms)]);
    t.row(vec!["  fill-in (ms)".to_string(), ms(st.fillin_ms)]);
    t.row(vec!["  detect (ms)".to_string(), ms(st.detect_ms)]);
    t.row(vec!["  levelize (ms)".to_string(), ms(st.levelize_ms)]);
    t.row(vec![
        "symbolic path".to_string(),
        if st.incremental_patches > 0 {
            "incremental patch".to_string()
        } else if st.symbolic_parallel_runs > 0 {
            "wave-parallel".to_string()
        } else {
            "serial".to_string()
        },
    ]);
    t.row(vec!["plan build (ms)".to_string(), ms(st.plan_ms)]);
    t.row(vec!["numeric (ms)".to_string(), ms(st.numeric_ms)]);
    t.row(vec![
        "scatter builds".to_string(),
        st.scatter_builds.to_string(),
    ]);
    t.row(vec![
        "atomic commits avoided".to_string(),
        st.atomic_commits_avoided.to_string(),
    ]);
    // The schedule engine's per-launch execution report: launch counts
    // plus the simulated-vs-executed cycle reconciliation.
    if let Some(exec) = &st.exec {
        t.row(vec![
            "schedule launches".to_string(),
            exec.total_launches().to_string(),
        ]);
        t.row(vec![
            "executed cycles".to_string(),
            exec.executed_cycles().to_string(),
        ]);
        t.row(vec![
            "simulated cycles".to_string(),
            exec.simulated_cycles().to_string(),
        ]);
        t.row(vec![
            "sim - exec cycle delta".to_string(),
            exec.cycle_delta().to_string(),
        ]);
    }
    // Mode distribution comes from the plan (every engine has one), not
    // from the simulator report.
    let (da, db, dc) = solver.plan().mode_histogram();
    t.row(vec![
        "level types A/B/C".to_string(),
        format!("{da}/{db}/{dc}"),
    ]);
    if let Some(sim) = &st.sim {
        t.row(vec![
            "mean warp occupancy".to_string(),
            format!("{:.2}", sim.mean_occupancy()),
        ]);
    }
    // Robustness-ladder health of the numeric run: growth and condition
    // proxies from the pivot monitor, plus the repair counters (all zero
    // on a clean factorization).
    let rb = &st.robustness;
    t.row(vec![
        "pivot growth".to_string(),
        format!("{:.3e}", rb.pivot_growth),
    ]);
    t.row(vec![
        "condition estimate".to_string(),
        format!("{:.3e}", rb.condition_estimate),
    ]);
    t.row(vec![
        "min |pivot|".to_string(),
        format!("{:.3e}", rb.min_abs_pivot),
    ]);
    t.row(vec![
        "ladder perturb/refine/escalate/repair".to_string(),
        format!(
            "{}/{}/{}/{}",
            rb.perturbations, rb.refine_iters, rb.escalations, rb.repairs
        ),
    ]);
    print!("{}", t.render());

    if also_solve {
        let n = a.nrows();
        let b: Vec<f64> = match flags.get("rhs").map(|s| s.as_str()).unwrap_or("ones") {
            "ones" => vec![1.0; n],
            "ramp" => (0..n).map(|i| 1.0 + (i % 100) as f64 / 100.0).collect(),
            other => anyhow::bail!("unknown rhs {other}"),
        };
        let x = solver.solve(&b)?;
        println!(
            "solve: relative residual = {:.3e} (trisolve variant: {})",
            residual(&a, &x, &b),
            solver.stats().trisolve_variant
        );
    }
    Ok(())
}

fn cmd_suite(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let set = flags.get("set").map(|s| s.as_str()).unwrap_or("small");
    let matrices: Vec<SuiteMatrix> = match set {
        "small" => SuiteMatrix::SMALL.to_vec(),
        "all" => SuiteMatrix::ALL.to_vec(),
        other => anyhow::bail!("unknown set {other} (small|all)"),
    };
    let opts = options_from(flags)?;
    let mut t = Table::new(vec![
        "matrix", "rows", "nnz", "levels", "cpu(ms)", "kernel(ms)",
    ]);
    for m in matrices {
        let a = gen::generate(&m.spec());
        let solver = GluSolver::factor(&a, &opts)?;
        let st = solver.stats();
        t.row(vec![
            m.ufl_name().to_string(),
            st.n.to_string(),
            st.nnz.to_string(),
            st.num_levels.to_string(),
            ms(st.cpu_ms()),
            ms(st.numeric_ms),
        ]);
        println!("done {}", m.ufl_name());
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_profile(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let (name, a) = load_matrix(flags)?;
    let opts = options_from(flags)?;
    let solver = GluSolver::factor(&a, &opts)?;
    let prof = parallelism_profile(solver.symbolic(), solver.levels());
    println!("# {name}: level size vs max subcolumns (Fig. 10 data)");
    let mut t = Table::new(vec!["level", "size", "max_subcols", "mean_L_len"]);
    for p in &prof {
        t.row(vec![
            p.level.to_string(),
            p.size.to_string(),
            p.max_subcols.to_string(),
            format!("{:.1}", p.mean_l_len),
        ]);
    }
    print!("{}", t.render());
    let corr = glu3::glu::profile::size_subcol_correlation(&prof);
    println!("size/subcol correlation: {}", ratio(corr));
    Ok(())
}

fn flag_usize(
    flags: &HashMap<String, String>,
    key: &str,
    default: usize,
) -> anyhow::Result<usize> {
    match flags.get(key) {
        Some(s) => Ok(s.parse()?),
        None => Ok(default),
    }
}

fn flag_u64(flags: &HashMap<String, String>, key: &str, default: u64) -> anyhow::Result<u64> {
    match flags.get(key) {
        Some(s) => Ok(s.parse()?),
        None => Ok(default),
    }
}

fn flag_f64_opt(flags: &HashMap<String, String>, key: &str) -> anyhow::Result<Option<f64>> {
    match flags.get(key) {
        Some(s) => Ok(Some(s.parse()?)),
        None => Ok(None),
    }
}

/// Drive the fault-tolerant serving core ([`glu3::coordinator::Server`])
/// with a multi-tenant, seeded-chaos workload and emit the schema-validated
/// `BENCH_service.json` (throughput, tail latency, queue depth, shed/retry/
/// coalesce counters, saturation sweep).
fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use glu3::bench_support::service::{
        run_service_bench, validate_service_schema, ServiceBenchSpec,
    };
    use glu3::coordinator::FaultPlan;

    let (name, a) = load_matrix(flags)?;
    let opts = options_from(flags)?;
    let patterns = flag_usize(flags, "patterns", 3)?.max(1);
    let fault_seed = flag_u64(flags, "fault-seed", 0x5EED)?;

    // Distinct sparsity patterns: the base matrix plus symmetric random
    // permutations of it (structure changes, solvability is preserved).
    let mut rng = glu3::util::Rng::new(fault_seed);
    let mut variants = vec![a.clone()];
    for _ in 1..patterns {
        let mut p: Vec<usize> = (0..a.nrows()).collect();
        rng.shuffle(&mut p);
        variants.push(a.permute(&p, &p));
    }

    let spec = ServiceBenchSpec {
        label: name.clone(),
        tenants: flag_usize(flags, "tenants", 4)?.max(1),
        requests: flag_usize(flags, "requests", 64)?.max(1),
        rhs_per_request: flag_usize(flags, "rhs", 2)?.max(1),
        queue_capacity: flag_usize(flags, "queue", 32)?.max(1),
        workers: flag_usize(flags, "workers", 2)?.max(1),
        deadline_ms: flag_u64(flags, "deadline-ms", 5_000)?,
        fault_plan: FaultPlan::chaos(fault_seed),
        rate_rps: flag_f64_opt(flags, "rate")?,
        sweep: flags.contains_key("sweep"),
        opts,
    };
    let out = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_service.json".to_string());

    println!(
        "serving {name}: n={} nz={}, {} tenants x {} requests on {} workers \
         (queue {}, deadline {} ms, fault seed {:#x}, {} patterns)",
        a.nrows(),
        a.nnz(),
        spec.tenants,
        spec.requests,
        spec.workers,
        spec.queue_capacity,
        spec.deadline_ms,
        fault_seed,
        patterns
    );
    let report = run_service_bench(&spec, &variants)?;
    let st = &report.stats;

    let mut t = Table::new(vec!["counter", "value"]);
    t.row(vec!["submitted".to_string(), st.submitted.to_string()]);
    t.row(vec!["completed".to_string(), st.completed.to_string()]);
    t.row(vec!["rejected (queue full)".to_string(), st.rejected.to_string()]);
    t.row(vec!["shed (priority)".to_string(), st.shed.to_string()]);
    t.row(vec![
        "deadline missed".to_string(),
        st.deadline_missed.to_string(),
    ]);
    t.row(vec!["failed (terminal)".to_string(), st.failed.to_string()]);
    t.row(vec!["retries".to_string(), st.retries.to_string()]);
    t.row(vec!["coalesced".to_string(), st.coalesced.to_string()]);
    t.row(vec![
        "degraded checkouts".to_string(),
        st.degraded_checkouts.to_string(),
    ]);
    t.row(vec![
        "injected faults".to_string(),
        st.injected_faults().to_string(),
    ]);
    t.row(vec!["in flight (lost)".to_string(), st.in_flight().to_string()]);
    t.row(vec![
        "symbolic runs".to_string(),
        st.symbolic_runs.to_string(),
    ]);
    t.row(vec!["numeric runs".to_string(), st.numeric_runs.to_string()]);
    t.row(vec!["queue max depth".to_string(), st.depth.max_depth().to_string()]);
    t.row(vec!["p50 latency (ms)".to_string(), ms(st.p50_ms())]);
    t.row(vec!["p99 latency (ms)".to_string(), ms(st.p99_ms())]);
    t.row(vec!["p999 latency (ms)".to_string(), ms(st.p999_ms())]);
    t.row(vec![
        "throughput (req/s)".to_string(),
        format!("{:.0}", report.rps()),
    ]);
    print!("{}", t.render());

    anyhow::ensure!(st.in_flight() == 0, "lost requests: {}", st.in_flight());

    if !report.sweep.is_empty() {
        println!("\n# saturation sweep (fault-free, paced offered load)");
        let mut t = Table::new(vec![
            "offered r/s",
            "achieved r/s",
            "p50(ms)",
            "p99(ms)",
            "p999(ms)",
            "rej",
            "shed",
            "depth",
        ]);
        for p in &report.sweep {
            t.row(vec![
                format!("{:.0}", p.offered_rps),
                format!("{:.0}", p.achieved_rps),
                ms(p.p50_ms),
                ms(p.p99_ms),
                ms(p.p999_ms),
                p.rejected.to_string(),
                p.shed.to_string(),
                p.max_depth.to_string(),
            ]);
        }
        print!("{}", t.render());
    }

    let json = report.to_json();
    validate_service_schema(&json)?;
    report.write_json(&out)?;
    println!("wrote {out}");
    Ok(())
}

/// Run the wall-clock numeric bench harness and emit `BENCH_numeric.json`:
/// factor/refactor/solve medians per engine and thread count, plus the
/// persistent-pool vs per-level-spawn head-to-head. `--smoke` selects the
/// small CI fixture; the default is the 100×100 AMD-ordered grid
/// acceptance fixture.
fn cmd_bench(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use glu3::bench_support::numeric::{run, validate_json_schema, BenchSpec};

    let smoke = flags.get("smoke").is_some();
    let mut spec = if smoke {
        BenchSpec::smoke()
    } else {
        BenchSpec::acceptance()
    };
    if flags.contains_key("matrix") {
        let (name, a) = load_matrix(flags)?;
        spec.label = name;
        spec.a = a;
    }
    if let Some(t) = flags.get("threads") {
        let counts: Result<Vec<usize>, _> = t.split(',').map(|s| s.trim().parse()).collect();
        spec.thread_counts = counts
            .map_err(|_| anyhow::anyhow!("--threads expects a comma list, e.g. 1,2,4"))?;
        anyhow::ensure!(!spec.thread_counts.is_empty(), "--threads list is empty");
    }
    spec.iters = flag_usize(flags, "iters", spec.iters)?.max(1);
    spec.warmup = flag_usize(flags, "warmup", spec.warmup)?;
    let out = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_numeric.json".to_string());

    println!(
        "bench {}: n={} nnz={}, threads {:?}, {} iters (+{} warmup)",
        spec.label,
        spec.a.nrows(),
        spec.a.nnz(),
        spec.thread_counts,
        spec.iters,
        spec.warmup
    );
    let report = run(&spec)?;

    let mut t = Table::new(vec![
        "engine",
        "threads",
        "factor(ms)",
        "refactor(ms)",
        "solve(ms)",
    ]);
    for s in &report.samples {
        t.row(vec![
            s.engine.clone(),
            s.threads.to_string(),
            ms(s.factor_ms),
            ms(s.refactor_ms),
            ms(s.solve_ms),
        ]);
    }
    print!("{}", t.render());
    println!(
        "pool vs per-level-spawn @{} threads: {} ms vs {} ms ({} speedup)",
        report.baseline.threads,
        ms(report.baseline.pool_ms),
        ms(report.baseline.spawn_per_level_ms),
        ratio(report.baseline.speedup())
    );
    let p = &report.plan;
    println!(
        "plan: {} levels (A/B/C {}/{}/{}), build {} ms; symbolic {} ms \
         (fill {} + detect {} + levelize {})",
        p.levels,
        p.modes_small,
        p.modes_large,
        p.modes_stream,
        ms(p.build_ms),
        ms(p.symbolic_ms),
        ms(p.fillin_ms),
        ms(p.detect_ms),
        ms(p.levelize_ms)
    );
    let sy = &report.symbolic;
    let par_list: Vec<String> = sy
        .threads
        .iter()
        .zip(&sy.parallel_ms)
        .map(|(t, &v)| format!("{} ms @{}t", ms(v), t))
        .collect();
    println!(
        "symbolic cold-start: serial {} ms vs parallel {} ({} speedup); \
         incremental patch {} ms vs cold {} ms ({} speedup, \
         {} changed / {} recomputed column(s))",
        ms(sy.serial_ms),
        par_list.join(", "),
        ratio(sy.speedup_parallel()),
        ms(sy.incremental_ms),
        ms(sy.cold_ms),
        ratio(sy.speedup_incremental()),
        sy.changed_columns,
        sy.recomputed_columns
    );
    let rl = &report.refactor_loop;
    println!(
        "refactor loop @{} threads x{}: indexed {} ms vs search {} ms ({} speedup); \
         scatter build {} ms (once per pattern), {} atomic commits avoided per refactor",
        rl.threads,
        rl.iterations,
        ms(rl.indexed_median_ms()),
        ms(rl.search_median_ms()),
        ratio(rl.speedup()),
        ms(rl.scatter_build_ms),
        rl.atomic_commits_avoided
    );
    let sc = &report.schedule;
    let max_delta = sc
        .simulated_cycles
        .iter()
        .zip(&sc.executed_cycles)
        .map(|(&s, &e)| s as i64 - e as i64)
        .max()
        .unwrap_or(0);
    println!(
        "schedule: {} launches over {} levels via {:?}; executed {} vs simulated {} cycles \
         (delta {} total, {} max per level)",
        sc.total_launches,
        sc.levels,
        sc.kernels,
        sc.executed_total(),
        sc.simulated_total(),
        sc.cycle_delta(),
        max_delta
    );
    let rb = &report.robustness;
    println!(
        "robustness ladder: {} repair(s) via {} perturbation(s), {} refinement step(s), \
         {} escalation(s); probe residual {:.2e} (growth {:.2e}, cond est {:.2e})",
        rb.repairs,
        rb.perturbations,
        rb.refine_iters,
        rb.escalations,
        rb.probe_residual,
        rb.pivot_growth,
        rb.condition_estimate
    );
    let bt = &report.batched;
    let maxb = bt.max_batch();
    let variants: Vec<String> = bt
        .variant_labels
        .iter()
        .zip(&bt.variant_counts)
        .map(|(l, c)| format!("{l}: {c}"))
        .collect();
    println!(
        "batched @{} threads, B={}: refactor {} ms batched vs {} ms looped ({}); \
         solve {} ms blocked vs {} ms looped ({}); trisolve variants {{{}}}",
        bt.threads,
        maxb,
        ms(bt.batched_refactor_ms.last().copied().unwrap_or(f64::NAN)),
        ms(bt.looped_refactor_ms.last().copied().unwrap_or(f64::NAN)),
        ratio(bt.refactor_speedup(maxb)),
        ms(bt.batched_solve_ms.last().copied().unwrap_or(f64::NAN)),
        ms(bt.looped_solve_ms.last().copied().unwrap_or(f64::NAN)),
        ratio(bt.solve_speedup(maxb)),
        variants.join(", ")
    );

    let json = report.to_json();
    validate_json_schema(&json)?;
    report.write_json(&out)?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let (name, a) = load_matrix(flags)?;
    println!(
        "{name}: {}x{}, nnz {}, full diagonal: {}",
        a.nrows(),
        a.ncols(),
        a.nnz(),
        a.has_full_diagonal()
    );
    Ok(())
}
