//! `glu3` — CLI for the GLU3.0 sparse LU reproduction.
//!
//! ```text
//! glu3 factor  --matrix <suite-name|file.mtx> [--policy P] [--detect D] [--ordering O] [--engine E]
//! glu3 solve   --matrix <...> [--rhs ones|ramp] [options]
//! glu3 suite   [--set small|all] [--policy P]
//! glu3 profile --matrix <...>        # Fig. 10 per-level parallelism dump
//! glu3 info    --matrix <...>        # structural stats only
//! ```
//!
//! Matrix names resolve against the synthetic suite
//! ([`glu3::sparse::gen::SuiteMatrix`]); anything ending in `.mtx` is read
//! as a Matrix Market file. (Offline build: argument parsing is hand-rolled —
//! no clap in the vendored crate set.)

use std::collections::HashMap;
use std::process::ExitCode;

use glu3::bench_support::table::{ms, ratio, Table};
use glu3::glu::{parallelism_profile, Detection, GluOptions, GluSolver, NumericEngine};
use glu3::gpusim::Policy;
use glu3::numeric::residual;
use glu3::order::FillOrdering;
use glu3::sparse::gen::{self, SuiteMatrix};
use glu3::sparse::{io, Csc};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "factor" => cmd_factor(&flags, false),
        "solve" => cmd_factor(&flags, true),
        "suite" => cmd_suite(&flags),
        "profile" => cmd_profile(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command {other}; try `glu3 help`"),
    }
}

fn print_usage() {
    println!(
        "glu3 — GLU3.0 sparse LU factorization (paper reproduction)\n\n\
         commands:\n\
         \x20 factor  --matrix <name|file.mtx> [--policy glu3|glu2|lee|nosmall|nostream]\n\
         \x20         [--detect glu1|glu2|glu3] [--ordering amd|rcm|natural]\n\
         \x20         [--engine gpu|left|right|parcpu]\n\
         \x20 solve   same options, also solves (--rhs ones|ramp)\n\
         \x20 suite   [--set small|all] [--policy ...]   run the whole suite\n\
         \x20 profile --matrix <...>   per-level parallelism profile (Fig. 10)\n\
         \x20 info    --matrix <...>   structural stats\n\n\
         suite names: {}",
        SuiteMatrix::ALL
            .iter()
            .map(|m| m.ufl_name())
            .collect::<Vec<_>>()
            .join(", ")
    );
}

fn parse_flags(args: &[String]) -> anyhow::Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let Some(key) = a.strip_prefix("--") else {
            anyhow::bail!("unexpected argument {a}");
        };
        let val = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
        map.insert(key.to_string(), val.clone());
    }
    Ok(map)
}

fn load_matrix(flags: &HashMap<String, String>) -> anyhow::Result<(String, Csc)> {
    let spec = flags
        .get("matrix")
        .ok_or_else(|| anyhow::anyhow!("--matrix is required"))?;
    if spec.ends_with(".mtx") {
        return Ok((spec.clone(), io::read_matrix_market(spec)?));
    }
    for m in SuiteMatrix::ALL {
        if m.ufl_name().eq_ignore_ascii_case(spec) {
            return Ok((m.ufl_name().to_string(), gen::generate(&m.spec())));
        }
    }
    anyhow::bail!("unknown matrix {spec} (suite name or .mtx path)")
}

fn options_from(flags: &HashMap<String, String>) -> anyhow::Result<GluOptions> {
    let mut opts = GluOptions::default();
    if let Some(p) = flags.get("policy") {
        opts.policy = match p.as_str() {
            "glu3" => Policy::glu3(),
            "glu2" => Policy::glu2_fixed(),
            "lee" => Policy::lee_enhanced(),
            "nosmall" => Policy::glu3_no_small(),
            "nostream" => Policy::glu3_no_stream(),
            other => anyhow::bail!("unknown policy {other}"),
        };
    }
    if let Some(d) = flags.get("detect") {
        opts.detection = match d.as_str() {
            "glu1" => Detection::Glu1,
            "glu2" => Detection::Glu2,
            "glu3" => Detection::Glu3,
            other => anyhow::bail!("unknown detection {other}"),
        };
    }
    if let Some(o) = flags.get("ordering") {
        opts.ordering = match o.as_str() {
            "amd" => FillOrdering::Amd,
            "rcm" => FillOrdering::Rcm,
            "natural" => FillOrdering::Natural,
            other => anyhow::bail!("unknown ordering {other}"),
        };
    }
    if let Some(e) = flags.get("engine") {
        opts.engine = match e.as_str() {
            "gpu" => NumericEngine::SimulatedGpu,
            "left" => NumericEngine::LeftLookingCpu,
            "right" => NumericEngine::RightLookingCpu,
            "parcpu" => NumericEngine::ParallelCpu {
                threads: std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1),
            },
            other => anyhow::bail!("unknown engine {other}"),
        };
    }
    Ok(opts)
}

fn cmd_factor(flags: &HashMap<String, String>, also_solve: bool) -> anyhow::Result<()> {
    let (name, a) = load_matrix(flags)?;
    let opts = options_from(flags)?;
    println!(
        "factoring {name}: n={} nz={} (policy {}, {:?}, {:?})",
        a.nrows(),
        a.nnz(),
        opts.policy.name,
        opts.detection,
        opts.ordering
    );
    let mut solver = GluSolver::factor(&a, &opts)?;
    let st = solver.stats();
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["rows".to_string(), st.n.to_string()]);
    t.row(vec!["nz (before fill)".to_string(), st.nz.to_string()]);
    t.row(vec!["nnz (after fill)".to_string(), st.nnz.to_string()]);
    t.row(vec!["levels".to_string(), st.num_levels.to_string()]);
    t.row(vec![
        "max level size".to_string(),
        st.max_level_size.to_string(),
    ]);
    t.row(vec!["preprocess (ms)".to_string(), ms(st.preprocess_ms)]);
    t.row(vec!["symbolic (ms)".to_string(), ms(st.symbolic_ms)]);
    t.row(vec![
        "levelization (ms)".to_string(),
        ms(st.levelization_ms),
    ]);
    t.row(vec!["numeric (ms)".to_string(), ms(st.numeric_ms)]);
    if let Some(sim) = &st.sim {
        let (da, db, dc) = sim.level_distribution();
        t.row(vec![
            "level types A/B/C".to_string(),
            format!("{da}/{db}/{dc}"),
        ]);
        t.row(vec![
            "mean warp occupancy".to_string(),
            format!("{:.2}", sim.mean_occupancy()),
        ]);
    }
    print!("{}", t.render());

    if also_solve {
        let n = a.nrows();
        let b: Vec<f64> = match flags.get("rhs").map(|s| s.as_str()).unwrap_or("ones") {
            "ones" => vec![1.0; n],
            "ramp" => (0..n).map(|i| 1.0 + (i % 100) as f64 / 100.0).collect(),
            other => anyhow::bail!("unknown rhs {other}"),
        };
        let x = solver.solve(&b)?;
        println!("solve: relative residual = {:.3e}", residual(&a, &x, &b));
    }
    Ok(())
}

fn cmd_suite(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let set = flags.get("set").map(|s| s.as_str()).unwrap_or("small");
    let matrices: Vec<SuiteMatrix> = match set {
        "small" => SuiteMatrix::SMALL.to_vec(),
        "all" => SuiteMatrix::ALL.to_vec(),
        other => anyhow::bail!("unknown set {other} (small|all)"),
    };
    let opts = options_from(flags)?;
    let mut t = Table::new(vec![
        "matrix", "rows", "nnz", "levels", "cpu(ms)", "kernel(ms)",
    ]);
    for m in matrices {
        let a = gen::generate(&m.spec());
        let solver = GluSolver::factor(&a, &opts)?;
        let st = solver.stats();
        t.row(vec![
            m.ufl_name().to_string(),
            st.n.to_string(),
            st.nnz.to_string(),
            st.num_levels.to_string(),
            ms(st.cpu_ms()),
            ms(st.numeric_ms),
        ]);
        println!("done {}", m.ufl_name());
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_profile(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let (name, a) = load_matrix(flags)?;
    let opts = options_from(flags)?;
    let solver = GluSolver::factor(&a, &opts)?;
    let prof = parallelism_profile(solver.symbolic(), solver.levels());
    println!("# {name}: level size vs max subcolumns (Fig. 10 data)");
    let mut t = Table::new(vec!["level", "size", "max_subcols", "mean_L_len"]);
    for p in &prof {
        t.row(vec![
            p.level.to_string(),
            p.size.to_string(),
            p.max_subcols.to_string(),
            format!("{:.1}", p.mean_l_len),
        ]);
    }
    print!("{}", t.render());
    let corr = glu3::glu::profile::size_subcol_correlation(&prof);
    println!("size/subcol correlation: {}", ratio(corr));
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let (name, a) = load_matrix(flags)?;
    println!(
        "{name}: {}x{}, nnz {}, full diagonal: {}",
        a.nrows(),
        a.ncols(),
        a.nnz(),
        a.has_full_diagonal()
    );
    Ok(())
}
