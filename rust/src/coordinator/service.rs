//! The solver service: one worker thread per factored system, channel-based
//! job submission, RHS batching.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::glu::{GluOptions, GluSolver, GluStats};
use crate::numeric::{service_error, GluError};
use crate::sparse::Csc;

/// A dead worker thread, as a typed error: callers can downcast to
/// [`GluError::WorkerPanicked`] instead of string-matching `"worker gone"`.
fn worker_gone() -> anyhow::Error {
    service_error(GluError::WorkerPanicked)
}

enum Job {
    /// Solve a batch of right-hand sides.
    Solve {
        rhs: Vec<Vec<f64>>,
        reply: mpsc::Sender<anyhow::Result<Vec<Vec<f64>>>>,
    },
    /// Refactor with new values on the same pattern.
    Refactor {
        a: Box<Csc>,
        reply: mpsc::Sender<anyhow::Result<()>>,
    },
    /// Fetch current stats.
    Stats {
        reply: mpsc::Sender<GluStats>,
    },
    Shutdown,
}

/// Handle to one factored system living on its worker thread.
pub struct SolverHandle {
    tx: mpsc::Sender<Job>,
    join: Option<JoinHandle<()>>,
}

impl SolverHandle {
    /// Factor `a` on a fresh worker thread.
    pub fn spawn(a: Csc, opts: GluOptions) -> anyhow::Result<SolverHandle> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let join = std::thread::spawn(move || {
            let mut solver = match GluSolver::factor(&a, &opts) {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(job) = rx.recv() {
                match job {
                    Job::Solve { rhs, reply } => {
                        let out: anyhow::Result<Vec<Vec<f64>>> =
                            rhs.iter().map(|b| solver.solve(b)).collect();
                        let _ = reply.send(out);
                    }
                    Job::Refactor { a, reply } => {
                        let _ = reply.send(solver.refactor(&a));
                    }
                    Job::Stats { reply } => {
                        let _ = reply.send(solver.stats().clone());
                    }
                    Job::Shutdown => break,
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during factorization"))??;
        Ok(SolverHandle {
            tx,
            join: Some(join),
        })
    }

    /// Solve one RHS.
    pub fn solve(&self, b: Vec<f64>) -> anyhow::Result<Vec<f64>> {
        Ok(self.solve_batch(vec![b])?.pop().unwrap())
    }

    /// Solve a batch of RHS against the same factors (amortizes dispatch).
    pub fn solve_batch(&self, rhs: Vec<Vec<f64>>) -> anyhow::Result<Vec<Vec<f64>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job::Solve { rhs, reply })
            .map_err(|_| worker_gone())?;
        rx.recv().map_err(|_| worker_gone())?
    }

    /// Refactor with new values (same pattern).
    pub fn refactor(&self, a: Csc) -> anyhow::Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job::Refactor {
                a: Box::new(a),
                reply,
            })
            .map_err(|_| worker_gone())?;
        rx.recv().map_err(|_| worker_gone())?
    }

    /// Current stats snapshot.
    pub fn stats(&self) -> anyhow::Result<GluStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job::Stats { reply })
            .map_err(|_| worker_gone())?;
        rx.recv().map_err(|_| worker_gone())
    }

    /// Graceful shutdown: drain the job channel (every already-submitted
    /// job is answered), then join the worker. Reports — rather than
    /// swallows — a worker that died by panic, as a typed
    /// [`GluError::WorkerPanicked`]. `Drop` does the same minus the
    /// report; call this when you care about the outcome.
    pub fn shutdown(mut self) -> anyhow::Result<()> {
        let _ = self.tx.send(Job::Shutdown);
        let panicked = self.join.take().is_some_and(|j| j.join().is_err());
        if panicked {
            return Err(worker_gone().context("worker panicked before shutdown"));
        }
        Ok(())
    }
}

impl Drop for SolverHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// A registry of named solver instances (the long-running service a circuit
/// simulator or batch workload talks to).
#[derive(Default)]
pub struct SolverService {
    solvers: HashMap<String, SolverHandle>,
}

impl SolverService {
    pub fn new() -> Self {
        Self::default()
    }

    /// Factor and register a system under `name` (replaces any previous).
    pub fn load(&mut self, name: &str, a: Csc, opts: GluOptions) -> anyhow::Result<()> {
        let h = SolverHandle::spawn(a, opts)?;
        self.solvers.insert(name.to_string(), h);
        Ok(())
    }

    /// Get a handle by name.
    pub fn get(&self, name: &str) -> Option<&SolverHandle> {
        self.solvers.get(name)
    }

    /// Drop a system.
    pub fn unload(&mut self, name: &str) -> bool {
        self.solvers.remove(name).is_some()
    }

    /// Registered system names.
    pub fn names(&self) -> Vec<&str> {
        self.solvers.keys().map(|s| s.as_str()).collect()
    }

    /// Shut every solver down (drain-then-join), reporting the first
    /// worker that died by panic instead of silently dropping it.
    pub fn shutdown_all(&mut self) -> anyhow::Result<()> {
        let mut first_err = None;
        for (name, h) in self.solvers.drain() {
            if let Err(e) = h.shutdown() {
                first_err.get_or_insert(e.context(format!("solver '{name}'")));
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::residual;
    use crate::sparse::gen;

    #[test]
    fn service_solves_and_refactors() {
        let a = gen::netlist(200, 5, 10, 0.05, 2, 0.2, 31);
        let mut svc = SolverService::new();
        svc.load("sys", a.clone(), GluOptions::default()).unwrap();
        let h = svc.get("sys").unwrap();

        let b = vec![1.0; 200];
        let x = h.solve(b.clone()).unwrap();
        assert!(residual(&a, &x, &b) < 1e-10);

        // batch of RHS
        let batch: Vec<Vec<f64>> = (0..4)
            .map(|s| (0..200).map(|i| ((i + s) % 7) as f64).collect())
            .collect();
        let xs = h.solve_batch(batch.clone()).unwrap();
        for (x, b) in xs.iter().zip(&batch) {
            assert!(residual(&a, x, b) < 1e-10);
        }

        // refactor with scaled values
        let mut a2 = a.clone();
        for v in a2.values_mut() {
            *v *= 2.0;
        }
        h.refactor(a2.clone()).unwrap();
        let x2 = h.solve(b.clone()).unwrap();
        assert!(residual(&a2, &x2, &b) < 1e-10);

        let st = h.stats().unwrap();
        assert_eq!(st.n, 200);
        assert!(svc.unload("sys"));
        assert!(!svc.unload("sys"));
    }

    #[test]
    fn factor_error_propagates() {
        use crate::sparse::Coo;
        // structurally singular
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 0, 1.0);
        let mut svc = SolverService::new();
        assert!(svc
            .load("bad", coo.to_csc(), GluOptions::default())
            .is_err());
    }

    #[test]
    fn shutdown_drains_and_joins() {
        let a = gen::netlist(100, 5, 8, 0.1, 1, 0.2, 5);
        let h = SolverHandle::spawn(a, GluOptions::default()).unwrap();
        h.shutdown().unwrap();

        let mut svc = SolverService::new();
        let a = gen::netlist(100, 5, 8, 0.1, 1, 0.2, 6);
        svc.load("sys", a, GluOptions::default()).unwrap();
        svc.shutdown_all().unwrap();
        assert!(svc.names().is_empty());
    }

    #[test]
    fn dead_worker_surfaces_as_typed_error() {
        use crate::numeric::GluError;
        let a = gen::netlist(100, 5, 8, 0.1, 1, 0.2, 7);
        let h = SolverHandle::spawn(a, GluOptions::default()).unwrap();
        // Kill the worker out from under the handle; whether or not it has
        // exited by the time solve() runs, the caller must get a typed
        // error, never a hang.
        h.tx.send(Job::Shutdown).unwrap();
        let err = h.solve(vec![1.0; 100]).unwrap_err();
        let typed = err.downcast_ref::<GluError>();
        assert_eq!(typed, Some(&GluError::WorkerPanicked));
    }

    #[test]
    fn multiple_systems_coexist() {
        let mut svc = SolverService::new();
        for (i, n) in [100usize, 150].iter().enumerate() {
            let a = gen::netlist(*n, 5, 8, 0.1, 1, 0.2, i as u64);
            svc.load(&format!("m{i}"), a, GluOptions::default()).unwrap();
        }
        assert_eq!(svc.names().len(), 2);
        let x = svc.get("m0").unwrap().solve(vec![1.0; 100]).unwrap();
        assert_eq!(x.len(), 100);
    }
}
