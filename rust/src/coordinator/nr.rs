//! Newton–Raphson driver over the GLU solver — the loop the paper's §III
//! motivates ("the numeric factorization ... might be repeated many times
//! when solving a nonlinear equation with Newton-Raphson method in circuit
//! simulation").
//!
//! The symbolic work (MC64, AMD, fill-in, levelization) is done once for
//! the Jacobian *pattern*; each iteration only restamps values and reruns
//! the numeric kernel. The driver routes every Jacobian through a
//! [`SolverPool`] ([`newton_raphson_in`]): the first iteration misses the
//! pattern cache and factors, every later iteration hits it and takes the
//! refactor fast path — and when the caller shares a pool across NR runs
//! (the transient loop does), even the *first* iteration of subsequent
//! solves is a refactor.

use crate::coordinator::pool::SolverPool;
use crate::glu::GluOptions;
use crate::sparse::Csc;

/// A nonlinear system `F(x) = 0` with a fixed Jacobian sparsity pattern.
pub trait NonlinearSystem {
    /// Dimension of `x`.
    fn dim(&self) -> usize;
    /// Evaluate the residual `F(x)`.
    fn residual(&self, x: &[f64]) -> Vec<f64>;
    /// Evaluate the Jacobian `J(x)`; must have the same sparsity pattern on
    /// every call (standard MNA stamping guarantees this).
    fn jacobian(&self, x: &[f64]) -> Csc;
}

/// NR options.
#[derive(Debug, Clone)]
pub struct NrOptions {
    pub max_iters: usize,
    /// Convergence: `‖F(x)‖∞ < abstol`.
    pub abstol: f64,
    /// Damping factor on the Newton step (1.0 = full steps).
    pub damping: f64,
    /// Solver configuration.
    pub glu: GluOptions,
}

impl Default for NrOptions {
    fn default() -> Self {
        NrOptions {
            max_iters: 50,
            abstol: 1e-9,
            damping: 1.0,
            glu: GluOptions::default(),
        }
    }
}

/// NR outcome.
#[derive(Debug, Clone)]
pub struct NrResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    /// `‖F(x)‖∞` per iteration (the convergence log).
    pub residual_norms: Vec<f64>,
    /// Numeric kernel time of each executed NR solve, ms (the first entry
    /// is a full factor on a cold pool, a refactor on a warm one).
    pub refactor_ms: Vec<f64>,
}

/// Run Newton–Raphson from `x0` with a private, single-pattern pool.
///
/// Convenience wrapper over [`newton_raphson_in`]; callers that run many NR
/// solves over the same Jacobian pattern (transient analysis, parameter
/// sweeps, concurrent sessions) should share a [`SolverPool`] instead so the
/// symbolic state survives between calls.
pub fn newton_raphson(
    sys: &dyn NonlinearSystem,
    x0: &[f64],
    opts: &NrOptions,
) -> anyhow::Result<NrResult> {
    let pool = SolverPool::with_config(opts.glu.clone(), 1, 1);
    newton_raphson_in(sys, x0, opts, &pool)
}

/// Run Newton–Raphson from `x0`, solving every linearized step through
/// `pool`. One checkout per iteration: a full factorization the first time
/// the Jacobian pattern is seen (by this pool), the numeric-only refactor
/// fast path after that.
pub fn newton_raphson_in(
    sys: &dyn NonlinearSystem,
    x0: &[f64],
    opts: &NrOptions,
    pool: &SolverPool,
) -> anyhow::Result<NrResult> {
    anyhow::ensure!(x0.len() == sys.dim(), "x0 dimension mismatch");
    let mut x = x0.to_vec();
    let mut norms = Vec::new();
    let mut refactor_ms = Vec::new();

    for it in 0..opts.max_iters {
        let f = sys.residual(&x);
        let norm = f.iter().map(|v| v.abs()).fold(0.0, f64::max);
        norms.push(norm);
        if norm < opts.abstol {
            return Ok(NrResult {
                x,
                iterations: it,
                converged: true,
                residual_norms: norms,
                refactor_ms,
            });
        }
        let j = sys.jacobian(&x);
        let mut guard = pool.checkout(&j)?;
        refactor_ms.push(guard.stats().numeric_ms);
        let dx = guard.solve(&f)?;
        drop(guard);
        for (xi, di) in x.iter_mut().zip(&dx) {
            *xi -= opts.damping * di;
        }
    }
    let f = sys.residual(&x);
    let norm = f.iter().map(|v| v.abs()).fold(0.0, f64::max);
    norms.push(norm);
    Ok(NrResult {
        x,
        iterations: opts.max_iters,
        converged: norm < opts.abstol,
        residual_norms: norms,
        refactor_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, Coo};

    /// Toy nonlinear system: A x + 0.1 * x³ = b elementwise cubic on a
    /// circuit-like linear core (a resistive grid with cubic "diodes").
    struct CubicGrid {
        a: Csc,
        b: Vec<f64>,
    }

    impl NonlinearSystem for CubicGrid {
        fn dim(&self) -> usize {
            self.a.nrows()
        }
        fn residual(&self, x: &[f64]) -> Vec<f64> {
            let mut r = self.a.matvec(x);
            for (ri, (xi, bi)) in r.iter_mut().zip(x.iter().zip(&self.b)) {
                *ri += 0.1 * xi.powi(3) - bi;
            }
            r
        }
        fn jacobian(&self, x: &[f64]) -> Csc {
            // J = A + diag(0.3 x²); same pattern (diagonal present in A).
            let mut coo = Coo::new(self.dim(), self.dim());
            for c in 0..self.a.ncols() {
                let (rows, vals) = self.a.col(c);
                for (&r, &v) in rows.iter().zip(vals) {
                    let add = if r == c { 0.3 * x[c] * x[c] } else { 0.0 };
                    coo.push(r, c, v + add);
                }
            }
            coo.to_csc()
        }
    }

    #[test]
    fn converges_quadratically_on_cubic_grid() {
        let a = gen::grid2d(10, 10, 4);
        let b: Vec<f64> = (0..100).map(|i| ((i % 5) as f64) - 2.0).collect();
        let sys = CubicGrid { a, b };
        let res = newton_raphson(&sys, &vec![0.0; 100], &NrOptions::default()).unwrap();
        assert!(res.converged, "norms: {:?}", res.residual_norms);
        assert!(res.iterations <= 10);
        // Each iteration reuses the symbolic state — one refactor per iter.
        assert_eq!(res.refactor_ms.len(), res.iterations.max(1));
        // Final residual actually small.
        let f = sys.residual(&res.x);
        assert!(f.iter().all(|v| v.abs() < 1e-8));
    }

    #[test]
    fn linear_system_converges_in_one_step() {
        let a = gen::netlist(80, 5, 8, 0.1, 1, 0.2, 2);
        struct Lin {
            a: Csc,
            b: Vec<f64>,
        }
        impl NonlinearSystem for Lin {
            fn dim(&self) -> usize {
                self.a.nrows()
            }
            fn residual(&self, x: &[f64]) -> Vec<f64> {
                self.a
                    .matvec(x)
                    .into_iter()
                    .zip(&self.b)
                    .map(|(p, q)| p - q)
                    .collect()
            }
            fn jacobian(&self, _x: &[f64]) -> Csc {
                self.a.clone()
            }
        }
        let sys = Lin {
            a,
            b: vec![1.0; 80],
        };
        let res = newton_raphson(&sys, &vec![0.0; 80], &NrOptions::default()).unwrap();
        assert!(res.converged);
        assert!(res.iterations <= 2);
    }

    #[test]
    fn parallel_engines_through_the_pool_match_default() {
        use crate::glu::{GluOptions, NumericEngine};

        let a = gen::grid2d(10, 10, 4);
        let b: Vec<f64> = (0..100).map(|i| ((i % 5) as f64) - 2.0).collect();
        let sys = CubicGrid { a, b };
        let base = newton_raphson(&sys, &vec![0.0; 100], &NrOptions::default()).unwrap();
        assert!(base.converged);

        // Thread plumbing: NrOptions -> GluOptions -> SolverPool -> the
        // pool-backed engines (factorization *and* the parallel trisolve).
        for engine in [
            NumericEngine::ParallelCpu { threads: 2 },
            NumericEngine::ParallelRightLooking { threads: 2 },
        ] {
            let opts = NrOptions {
                glu: GluOptions {
                    engine: engine.clone(),
                    ..Default::default()
                },
                ..Default::default()
            };
            let res = newton_raphson(&sys, &vec![0.0; 100], &opts).unwrap();
            assert!(res.converged, "{engine:?}");
            assert!(res.iterations.abs_diff(base.iterations) <= 1, "{engine:?}");
            for (p, q) in res.x.iter().zip(&base.x) {
                assert!((p - q).abs() < 1e-8 * (1.0 + q.abs()), "{engine:?}");
            }
        }
    }

    #[test]
    fn shared_pool_hits_refactor_path_across_nr_runs() {
        use crate::coordinator::pool::SolverPool;

        let a = gen::grid2d(9, 9, 6);
        let b: Vec<f64> = (0..81).map(|i| ((i % 7) as f64) - 3.0).collect();
        let sys = CubicGrid { a, b };
        let opts = NrOptions::default();
        let pool = SolverPool::new(opts.glu.clone());

        let r1 = newton_raphson_in(&sys, &vec![0.0; 81], &opts, &pool).unwrap();
        assert!(r1.converged);
        let st = pool.stats();
        // first NR solve factored, the rest refactored
        assert_eq!(st.factors, 1);
        assert_eq!(st.refactors as usize, r1.iterations - 1);

        // a second run over the same pattern never factors again
        let r2 = newton_raphson_in(&sys, &vec![0.0; 81], &opts, &pool).unwrap();
        assert!(r2.converged);
        let st = pool.stats();
        assert_eq!(st.factors, 1);
        assert_eq!(st.misses, 1);
        assert_eq!(st.hits as usize, r1.iterations + r2.iterations - 1);
        for (p, q) in r1.x.iter().zip(&r2.x) {
            assert!((p - q).abs() < 1e-8);
        }
    }
}
