//! The [`SolverPool`]: a pattern-keyed symbolic cache serving batched,
//! concurrent solves.
//!
//! GLU3.0's whole value proposition is amortization: a SPICE-class workload
//! refactors the *same sparsity pattern* thousands of times across
//! Newton–Raphson iterations and transient steps, so the expensive CPU
//! phases (MC64 matching, AMD ordering, symbolic fill, dependency detection,
//! levelization — Fig. 5's front half) should run **once per pattern** and
//! be reused hot. The pool makes that policy a service-level guarantee:
//!
//! - requests are keyed by a [`PatternKey`] (an FNV-1a hash of the CSC
//!   structure, verified against the stored pattern on every hit, so a hash
//!   collision can never route values onto the wrong symbolic state);
//! - a hit takes the [`GluSolver::refactor`] fast path (numeric kernel
//!   only); a miss pays one full [`GluSolver::factor`] — run *outside* the
//!   shard lock, so a slow first factorization never stalls other patterns
//!   — and caches it (two threads racing on the same cold pattern may both
//!   factor; the later insert wins, so counters can report a few extra
//!   misses under contention but never a stale answer);
//! - a miss whose pattern is a structural *near-miss* of an already-cached
//!   entry (same `n`, nnz within 1/8, only a handful of columns differ)
//!   skips the cold pipeline entirely: the cached symbolic state is
//!   snapshotted and patched incrementally ([`GluSolver::factor_delta`]
//!   over [`crate::symbolic::delta`]), counted in [`PoolStats::patched`];
//!   any patch failure falls back to the cold pipeline, so near-miss
//!   detection can only save work, never lose a request;
//! - cold misses borrow one pool-owned [`FillWorkspace`], so back-to-back
//!   misses reuse the symbolic reach/marker buffers instead of
//!   reallocating them per pattern;
//! - the cache is sharded (`Mutex` per shard, share the pool itself behind
//!   an `Arc` or scoped-thread borrow) so concurrent sessions with
//!   different patterns proceed in parallel, with per-shard LRU eviction;
//! - every checkout records its latency (lock wait + factor/refactor +
//!   whatever the caller does before releasing the guard) into a
//!   [`LatencyRecorder`], surfaced as p50/p99 through [`PoolStats`].
//!
//! Thread plumbing: the [`GluOptions`] the pool is built with select the
//! numeric engine, including the pool-backed parallel ones
//! ([`crate::glu::NumericEngine::ParallelCpu`] /
//! [`crate::glu::NumericEngine::ParallelRightLooking`]). Each cached
//! [`GluSolver`] owns its persistent worker pool and its mode-annotated
//! [`crate::plan::FactorPlan`] (the levelized schedule with per-level
//! kernel modes, CPU assignment strategies, destination-ownership groups,
//! the pattern-time [`crate::plan::ScatterMap`] of the indexed MAC loop,
//! and triangular-solve row schedules), so refactors and batched solves
//! on a warm entry run level-parallel with no thread spawn — and **zero
//! plan, scatter-map, or launch-schedule rebuilds** (`GluStats::plan_builds`,
//! `GluStats::scatter_builds`, and `GluStats::schedule_builds` stay at 1;
//! the schedule engine's executor likewise keeps its uploaded device
//! buffers across checkouts) — on the hot path. Worker threads are parked (not spinning) between
//! checkouts; a cache with many parallel-engine entries therefore costs
//! idle threads, not idle cycles — size `shards × capacity × threads`
//! accordingly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::glu::{Detection, GluOptions, GluSolver, GluStats, SymbolicSnapshot};
use crate::sparse::Csc;
use crate::symbolic::{changed_columns, FillWorkspace};
use crate::util::stats::LatencyRecorder;

/// Identity of a sparsity pattern: dimensions, nnz, and a structural hash.
///
/// Two matrices with equal keys *almost certainly* share a pattern; the pool
/// still verifies the stored `colptr`/`rowidx` before reusing symbolic
/// state, so the key is a router, not a proof.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PatternKey {
    /// Matrix dimension.
    pub n: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// FNV-1a hash of `colptr` and `rowidx`.
    pub hash: u64,
}

/// Compute the [`PatternKey`] of a CSC matrix (values are ignored — only
/// the structure participates).
pub fn pattern_key(a: &Csc) -> PatternKey {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    #[inline]
    fn eat(mut h: u64, x: u64) -> u64 {
        for b in x.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        h
    }
    let mut h = eat(FNV_OFFSET, a.nrows() as u64);
    h = eat(h, a.ncols() as u64);
    for &p in a.colptr() {
        h = eat(h, p as u64);
    }
    for &r in a.rowidx() {
        h = eat(h, r as u64);
    }
    PatternKey {
        n: a.nrows(),
        nnz: a.nnz(),
        hash: h,
    }
}

/// What a [`SolverPool::checkout`] did to satisfy the request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Checkout {
    /// Cache miss: the full pipeline ran (preprocess + symbolic + numeric).
    Factored,
    /// Cache hit: only the numeric kernel reran on the cached symbolic state.
    Refactored,
}

/// One cached factored system.
struct Entry {
    key: PatternKey,
    /// Stored structure for exact verification on hash hits.
    colptr: Vec<usize>,
    rowidx: Vec<usize>,
    solver: GluSolver,
    last_used: u64,
}

/// One cache shard: a small LRU set plus that shard's latency samples.
#[derive(Default)]
struct Shard {
    entries: Vec<Entry>,
    latency: LatencyRecorder,
}

/// Aggregate pool counters (see [`SolverPool::stats`]).
#[derive(Debug, Clone)]
pub struct PoolStats {
    /// Requests that reused cached symbolic state (refactor fast path).
    pub hits: u64,
    /// Requests that paid a full factorization.
    pub misses: u64,
    /// Entries dropped by LRU pressure.
    pub evictions: u64,
    /// Cold full factorizations performed (misses that found no usable
    /// structural near-miss; `misses == factors + patched` absent errors).
    pub factors: u64,
    /// Misses served by incrementally patching a cached near-miss pattern
    /// ([`GluSolver::factor_delta`]) instead of the cold pipeline.
    pub patched: u64,
    /// Value-only refactorizations performed.
    pub refactors: u64,
    /// Right-hand sides solved.
    pub solves: u64,
    /// Patterns currently cached.
    pub entries: usize,
    /// Per-checkout request latencies (ms; lock wait + factor/refactor +
    /// caller's solves until the guard drops), merged across shards over a
    /// bounded recent window.
    pub latency: LatencyRecorder,
}

impl PoolStats {
    /// Total pattern lookups.
    pub fn requests(&self) -> u64 {
        self.hits + self.misses
    }

    /// Symbolic-cache hit rate in `[0, 1]`.
    pub fn hit_rate(&self) -> f64 {
        if self.requests() == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests() as f64
        }
    }

    /// Median request latency, ms.
    pub fn p50_ms(&self) -> f64 {
        self.latency.p50_ms()
    }

    /// 99th-percentile request latency, ms.
    pub fn p99_ms(&self) -> f64 {
        self.latency.p99_ms()
    }

    /// 99.9th-percentile request latency, ms.
    pub fn p999_ms(&self) -> f64 {
        self.latency.p999_ms()
    }
}

/// A sharded, pattern-keyed pool of factored systems.
pub struct SolverPool {
    opts: GluOptions,
    shards: Vec<Mutex<Shard>>,
    capacity_per_shard: usize,
    /// Symbolic scratch lent to every cold miss (taken out of the mutex for
    /// the factorization itself, so concurrent misses never serialize on it
    /// — a racing miss simply allocates fresh buffers).
    fill_ws: Mutex<FillWorkspace>,
    clock: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    factors: AtomicU64,
    patched: AtomicU64,
    refactors: AtomicU64,
    solves: AtomicU64,
}

/// Exclusive access to one cached solver, obtained from
/// [`SolverPool::checkout`]. Holds the shard lock: concurrent requests for
/// patterns on the same shard wait until the guard drops. Dropping the
/// guard records the checkout-to-release latency into the shard's
/// [`LatencyRecorder`].
pub struct PoolGuard<'a> {
    pool: &'a SolverPool,
    shard: MutexGuard<'a, Shard>,
    idx: usize,
    outcome: Checkout,
    start: Instant,
}

impl PoolGuard<'_> {
    /// Whether this checkout factored or refactored.
    pub fn outcome(&self) -> Checkout {
        self.outcome
    }

    /// Statistics of the underlying solver (n, timings, run counters).
    pub fn stats(&self) -> &GluStats {
        self.shard.entries[self.idx].solver.stats()
    }

    /// Mutable access to the checked-out solver.
    pub fn solver_mut(&mut self) -> &mut GluSolver {
        &mut self.shard.entries[self.idx].solver
    }

    /// Solve one right-hand side against the checked-out factors.
    pub fn solve(&mut self, b: &[f64]) -> anyhow::Result<Vec<f64>> {
        let x = self.shard.entries[self.idx].solver.solve(b)?;
        self.pool.solves.fetch_add(1, Ordering::Relaxed);
        Ok(x)
    }

    /// Solve a batch of right-hand sides against the checked-out factors.
    pub fn solve_many(&mut self, rhs: &[Vec<f64>]) -> anyhow::Result<Vec<Vec<f64>>> {
        let mut out = vec![vec![0.0; self.stats().n]; rhs.len()];
        self.solve_many_into(rhs, &mut out)?;
        Ok(out)
    }

    /// Blocked batch solve over caller-provided storage
    /// ([`GluSolver::solve_many_into`]): one trisolve walk for the whole
    /// batch, zero solve-path allocations in steady state — the serve
    /// loop's coalesced groups ride this.
    pub fn solve_many_into(
        &mut self,
        rhs: &[Vec<f64>],
        out: &mut [Vec<f64>],
    ) -> anyhow::Result<()> {
        self.shard.entries[self.idx].solver.solve_many_into(rhs, out)?;
        self.pool.solves.fetch_add(rhs.len() as u64, Ordering::Relaxed);
        Ok(())
    }
}

impl Drop for PoolGuard<'_> {
    fn drop(&mut self) {
        let ms = self.start.elapsed().as_secs_f64() * 1e3;
        self.shard.latency.record(ms);
    }
}

fn lock_shard<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl SolverPool {
    /// A pool with the default layout: 8 shards × 4 entries.
    pub fn new(opts: GluOptions) -> Self {
        Self::with_config(opts, 8, 4)
    }

    /// A pool with `shards` mutex shards and `capacity_per_shard` cached
    /// patterns per shard (LRU-evicted beyond that).
    pub fn with_config(opts: GluOptions, shards: usize, capacity_per_shard: usize) -> Self {
        assert!(shards >= 1 && capacity_per_shard >= 1);
        SolverPool {
            opts,
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
            capacity_per_shard,
            fill_ws: Mutex::new(FillWorkspace::new()),
            clock: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            factors: AtomicU64::new(0),
            patched: AtomicU64::new(0),
            refactors: AtomicU64::new(0),
            solves: AtomicU64::new(0),
        }
    }

    /// The options every cached solver is built with.
    pub fn options(&self) -> &GluOptions {
        &self.opts
    }

    /// Index of the entry matching `a`'s exact pattern, if cached.
    fn find(shard: &Shard, key: &PatternKey, a: &Csc) -> Option<usize> {
        shard.entries.iter().position(|e| {
            e.key == *key
                && e.colptr.as_slice() == a.colptr()
                && e.rowidx.as_slice() == a.rowidx()
        })
    }

    /// Check out the solver for `a`'s sparsity pattern, factoring on a miss
    /// and refactoring (numeric kernel only) on a hit. The returned guard
    /// pins the shard until dropped.
    ///
    /// The miss-path factorization runs with the shard lock *released*, so
    /// a large cold pattern never stalls requests for other patterns that
    /// happen to share its shard. Two threads racing on the same cold
    /// pattern may therefore both factor; whichever inserts second replaces
    /// the first entry (its values are the fresher stamp), costing a
    /// duplicated factorization but never a wrong answer.
    pub fn checkout(&self, a: &Csc) -> anyhow::Result<PoolGuard<'_>> {
        let start = Instant::now();
        let key = pattern_key(a);
        let si = (key.hash as usize) % self.shards.len();

        {
            let mut shard = lock_shard(&self.shards[si]);
            if let Some(i) = Self::find(&shard, &key, a) {
                // Hit (counted before the refactor attempt, so hits + misses
                // always equals checkout calls).
                self.hits.fetch_add(1, Ordering::Relaxed);
                if let Err(e) = shard.entries[i].solver.refactor(a) {
                    if e.downcast_ref::<crate::numeric::GluError>().is_some() {
                        // Numerically singular *values* — the symbolic
                        // pattern, plan, scatter map and schedule are all
                        // still valid, and the next Newton iterate will
                        // usually stamp healthy values. Keep the entry (its
                        // solver is poisoned until a refactor succeeds) so
                        // the cached symbolic state survives the bad stamp.
                        shard.entries[i].last_used = self.tick();
                        return Err(e);
                    }
                    // Structural failure: the entry's cached state itself is
                    // suspect — drop it rather than serve it again.
                    shard.entries.swap_remove(i);
                    return Err(e);
                }
                self.refactors.fetch_add(1, Ordering::Relaxed);
                shard.entries[i].last_used = self.tick();
                return Ok(PoolGuard {
                    pool: self,
                    shard,
                    idx: i,
                    outcome: Checkout::Refactored,
                    start,
                });
            }
        } // release the shard lock for the expensive factorization

        // Miss: pay the full pipeline (or a near-miss patch) outside the
        // lock, then cache.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let solver = self.factor_miss(&key, a)?;

        let mut shard = lock_shard(&self.shards[si]);
        let idx = if let Some(i) = Self::find(&shard, &key, a) {
            // Another thread inserted this pattern while we factored. Its
            // guard is gone (we hold the shard lock), so replacing the
            // solver with ours — stamped with *our* request's values — is
            // safe and serves this checkout correctly.
            shard.entries[i].solver = solver;
            i
        } else {
            if shard.entries.len() >= self.capacity_per_shard {
                let lru = shard
                    .entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(i, _)| i)
                    .expect("non-empty shard");
                shard.entries.swap_remove(lru);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
            shard.entries.push(Entry {
                key,
                colptr: a.colptr().to_vec(),
                rowidx: a.rowidx().to_vec(),
                solver,
                last_used: 0,
            });
            shard.entries.len() - 1
        };
        shard.entries[idx].last_used = self.tick();
        Ok(PoolGuard {
            pool: self,
            shard,
            idx,
            outcome: Checkout::Factored,
            start,
        })
    }

    /// Scan the cache for a structural near-miss of `a`: an entry with the
    /// same dimension, nnz within 1/8, and at most `max(n/4, 4)` columns
    /// whose raw structure differs. Returns the cached symbolic snapshot
    /// plus the changed original-column list. Holds one shard lock at a
    /// time; the snapshot clone is the only work done under it.
    fn find_near_miss(&self, key: &PatternKey, a: &Csc) -> Option<(SymbolicSnapshot, Vec<u32>)> {
        if self.opts.detection != Detection::Glu3 {
            return None; // the patch path streams GLU3.0 detection only
        }
        let budget = (key.n / 4).max(4);
        for m in &self.shards {
            let shard = lock_shard(m);
            for e in &shard.entries {
                // Same hash means same pattern (or a collision) — either way
                // the exact-match path already had its chance; and a pattern
                // of a different dimension can never be a delta of ours.
                if e.key.hash == key.hash || e.key.n != key.n {
                    continue;
                }
                // A poisoned entry's plan annotations describe a numeric run
                // that never completed, and a rescue-swapped entry's symbolic
                // state lives on a re-permuted row order the delta patcher
                // knows nothing about — either way its snapshot is not a
                // sound delta base.
                if e.solver.is_poisoned() || e.solver.is_rescued() {
                    continue;
                }
                if e.key.nnz.abs_diff(key.nnz) * 8 > key.nnz.max(1) {
                    continue;
                }
                if let Some(changed) = changed_columns(&e.colptr, &e.rowidx, a, budget) {
                    if !changed.is_empty() {
                        return Some((e.solver.symbolic_snapshot(), changed));
                    }
                }
            }
        }
        None
    }

    /// Produce a solver for a missed pattern, with no shard lock held:
    /// incremental patch off a cached structural near-miss when one fits
    /// the budget, cold pipeline otherwise. Cold runs borrow the pool's
    /// [`FillWorkspace`]; a patch failure (e.g. the delta broke the matched
    /// diagonal) silently falls back to cold.
    fn factor_miss(&self, key: &PatternKey, a: &Csc) -> anyhow::Result<GluSolver> {
        if let Some((snap, changed)) = self.find_near_miss(key, a) {
            let mut fws = std::mem::take(&mut *lock_shard(&self.fill_ws));
            let patched = GluSolver::factor_delta(a, &self.opts, &snap, &changed, &mut fws);
            *lock_shard(&self.fill_ws) = fws;
            if let Ok(solver) = patched {
                self.patched.fetch_add(1, Ordering::Relaxed);
                return Ok(solver);
            }
        }
        let mut fws = std::mem::take(&mut *lock_shard(&self.fill_ws));
        let solver = GluSolver::factor_with_workspace(a, &self.opts, &mut fws);
        *lock_shard(&self.fill_ws) = fws;
        let solver = solver?;
        self.factors.fetch_add(1, Ordering::Relaxed);
        Ok(solver)
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Solve `A x = b`, reusing cached symbolic state when `A`'s pattern is
    /// known. One checkout: latency and solve counters are recorded by the
    /// guard.
    pub fn solve(&self, a: &Csc, b: &[f64]) -> anyhow::Result<Vec<f64>> {
        self.checkout(a)?.solve(b)
    }

    /// Solve a batch of right-hand sides against one matrix: one pattern
    /// lookup, one factor-or-refactor, then the batched trisolve path
    /// ([`GluSolver::solve_many`]). Counted as one request, `rhs.len()`
    /// solves.
    pub fn solve_many(&self, a: &Csc, rhs: &[Vec<f64>]) -> anyhow::Result<Vec<Vec<f64>>> {
        self.checkout(a)?.solve_many(rhs)
    }

    /// Number of cached patterns across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_shard(s).entries.len()).sum()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached entry (counters and latency samples are kept).
    pub fn clear(&self) {
        for s in &self.shards {
            lock_shard(s).entries.clear();
        }
    }

    /// Snapshot of per-entry solver statistics (one per cached pattern),
    /// most-recently-used first.
    pub fn entry_stats(&self) -> Vec<(PatternKey, GluStats)> {
        let mut out: Vec<(u64, PatternKey, GluStats)> = Vec::new();
        for s in &self.shards {
            let shard = lock_shard(s);
            for e in &shard.entries {
                out.push((e.last_used, e.key, e.solver.stats().clone()));
            }
        }
        out.sort_by(|a, b| b.0.cmp(&a.0));
        out.into_iter().map(|(_, k, st)| (k, st)).collect()
    }

    /// `(symbolic_runs, numeric_runs)` summed over the live entries — the
    /// serving layer's "did coalescing/caching actually avoid work" signal
    /// (evicted entries' runs are not counted).
    pub fn run_totals(&self) -> (usize, usize) {
        let mut sym = 0usize;
        let mut num = 0usize;
        for s in &self.shards {
            let shard = lock_shard(s);
            for e in &shard.entries {
                sym += e.solver.stats().symbolic_runs;
                num += e.solver.stats().numeric_runs;
            }
        }
        (sym, num)
    }

    /// Aggregate counters and merged latency samples.
    pub fn stats(&self) -> PoolStats {
        // Size the merged window to hold every shard's current window, so
        // no shard's samples overwrite another's and the p50/p99 reflect
        // the whole pool rather than whichever shard merged last.
        let shards: Vec<_> = self.shards.iter().map(lock_shard).collect();
        let window: usize = shards.iter().map(|s| s.latency.samples().len()).sum();
        let mut latency = LatencyRecorder::with_window(window.max(1));
        let mut entries = 0usize;
        for shard in &shards {
            latency.merge(&shard.latency);
            entries += shard.entries.len();
        }
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            factors: self.factors.load(Ordering::Relaxed),
            patched: self.patched.load(Ordering::Relaxed),
            refactors: self.refactors.load(Ordering::Relaxed),
            solves: self.solves.load(Ordering::Relaxed),
            entries,
            latency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::residual;
    use crate::sparse::gen;

    #[test]
    fn pattern_key_structure_only() {
        let a = gen::netlist(120, 5, 8, 0.1, 1, 0.2, 3);
        let mut b = a.clone();
        for v in b.values_mut() {
            *v *= 3.25;
        }
        // same structure, different values -> same key
        assert_eq!(pattern_key(&a), pattern_key(&b));
        // different structure -> different key
        let c = gen::netlist(120, 5, 8, 0.1, 1, 0.2, 4);
        assert_ne!(pattern_key(&a), pattern_key(&c));
    }

    #[test]
    fn hit_refactors_miss_factors() {
        let a = gen::netlist(150, 5, 10, 0.05, 2, 0.2, 9);
        let pool = SolverPool::new(GluOptions::default());
        let b = vec![1.0; 150];

        let x0 = pool.solve(&a, &b).unwrap();
        assert!(residual(&a, &x0, &b) < 1e-7);
        let st = pool.stats();
        assert_eq!((st.hits, st.misses, st.factors, st.refactors), (0, 1, 1, 0));

        let mut a2 = a.clone();
        for v in a2.values_mut() {
            *v *= 1.5;
        }
        let x1 = pool.solve(&a2, &b).unwrap();
        assert!(residual(&a2, &x1, &b) < 1e-7);
        let st = pool.stats();
        assert_eq!((st.hits, st.misses, st.factors, st.refactors), (1, 1, 1, 1));
        assert_eq!(st.solves, 2);
        assert_eq!(st.entries, 1);
        assert_eq!(st.latency.count(), 2);

        // the cached entry never reran its symbolic phases
        let es = pool.entry_stats();
        assert_eq!(es.len(), 1);
        assert_eq!(es[0].1.symbolic_runs, 1);
        assert_eq!(es[0].1.numeric_runs, 2);
    }

    #[test]
    fn lru_eviction_under_capacity_pressure() {
        // 1 shard x 2 entries; three patterns force an eviction.
        let pool = SolverPool::with_config(GluOptions::default(), 1, 2);
        let mats: Vec<_> = (0..3)
            .map(|s| gen::netlist(80, 5, 8, 0.1, 1, 0.2, 100 + s))
            .collect();
        let b = vec![1.0; 80];
        for m in &mats {
            pool.solve(m, &b).unwrap();
        }
        let st = pool.stats();
        assert_eq!(st.misses, 3);
        assert_eq!(st.evictions, 1);
        assert_eq!(st.entries, 2);
        // the evicted (least recently used) pattern is mats[0]: solving it
        // again is a miss, while mats[2] stays hot
        pool.solve(&mats[2], &b).unwrap();
        assert_eq!(pool.stats().hits, 1);
        pool.solve(&mats[0], &b).unwrap();
        assert_eq!(pool.stats().misses, 4);
    }

    #[test]
    fn checkout_outcomes_and_clear() {
        let a = gen::grid2d(8, 8, 7);
        let pool = SolverPool::new(GluOptions::default());
        let g = pool.checkout(&a).unwrap();
        assert_eq!(g.outcome(), Checkout::Factored);
        drop(g);
        let g = pool.checkout(&a).unwrap();
        assert_eq!(g.outcome(), Checkout::Refactored);
        assert_eq!(g.stats().numeric_runs, 2);
        drop(g);
        assert_eq!(pool.len(), 1);
        pool.clear();
        assert!(pool.is_empty());
        let g = pool.checkout(&a).unwrap();
        assert_eq!(g.outcome(), Checkout::Factored);
    }

    #[test]
    fn numeric_failure_retains_cached_pattern() {
        // good -> singular -> good on one pattern: the singular stamp must
        // not evict the entry, so the third checkout reuses the cached
        // symbolic state (symbolic_runs stays 1) and refactors in place.
        let a = gen::netlist(120, 5, 8, 0.1, 1, 0.2, 42);
        let pool = SolverPool::new(GluOptions::default());

        let g = pool.checkout(&a).unwrap();
        assert_eq!(g.outcome(), Checkout::Factored);
        drop(g);

        // Same pattern, all-zero values: numerically singular beyond what
        // the robustness ladder can repair (every rung sees zero pivots and
        // a zero residual denominator), but structurally fine.
        let mut zeroed = a.clone();
        for v in zeroed.values_mut() {
            *v = 0.0;
        }
        let err = pool.checkout(&zeroed).unwrap_err();
        assert!(
            err.downcast_ref::<crate::numeric::GluError>().is_some(),
            "expected a typed numeric error, got: {err:#}"
        );
        assert_eq!(pool.len(), 1, "numeric failure must not evict the entry");

        // Healthy values again: hit + refactor, zero extra symbolic runs.
        let g = pool.checkout(&a).unwrap();
        assert_eq!(g.outcome(), Checkout::Refactored);
        assert_eq!(g.stats().symbolic_runs, 1);
        drop(g);

        let st = pool.stats();
        assert_eq!(st.misses, 1);
        assert_eq!(st.hits, 2);
        assert_eq!(st.factors, 1);
        // only the successful repair counts as a refactor
        assert_eq!(st.refactors, 1);

        // and the repaired solver actually solves
        let b = vec![1.0; 120];
        let x = pool.solve(&a, &b).unwrap();
        assert!(residual(&a, &x, &b) < 1e-7);
    }

    #[test]
    fn near_miss_takes_the_incremental_patch() {
        let a = gen::grid2d(12, 12, 5);
        let n = a.nrows();
        let pool = SolverPool::new(GluOptions::default());
        let b = vec![1.0; n];

        let x = pool.solve(&a, &b).unwrap();
        assert!(residual(&a, &x, &b) < 1e-7);

        // One extra entry: a structural near-miss of the cached pattern.
        // It misses the exact-match lookup but fits the patch budget.
        let a2 = gen::with_entry(&a, 7, 2, -1e-3);
        assert!(a2.nnz() == a.nnz() + 1);
        let x2 = pool.solve(&a2, &b).unwrap();
        assert!(residual(&a2, &x2, &b) < 1e-7);

        let st = pool.stats();
        assert_eq!(st.misses, 2);
        assert_eq!(st.patched, 1, "second pattern must patch, not factor");
        assert_eq!(st.factors, 1);
        assert_eq!(st.entries, 2);

        // the patched entry reports zero symbolic runs and one patch
        let es = pool.entry_stats();
        let patched = es
            .iter()
            .find(|(k, _)| k.nnz == a2.nnz())
            .expect("patched entry cached");
        assert_eq!(patched.1.symbolic_runs, 0);
        assert_eq!(patched.1.incremental_patches, 1);

        // and it is a first-class cache entry: exact re-requests hit it
        let x3 = pool.solve(&a2, &b).unwrap();
        assert!(residual(&a2, &x3, &b) < 1e-7);
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn unrelated_patterns_stay_on_the_cold_path() {
        // Different-seed netlists share n and a similar nnz but differ in
        // far more columns than the patch budget: the near-miss scan must
        // reject them and the cold path must serve both.
        let a = gen::netlist(96, 5, 8, 0.1, 1, 0.2, 11);
        let c = gen::netlist(96, 5, 8, 0.1, 1, 0.2, 12);
        let pool = SolverPool::new(GluOptions::default());
        let b = vec![1.0; 96];
        pool.solve(&a, &b).unwrap();
        pool.solve(&c, &b).unwrap();
        let st = pool.stats();
        assert_eq!(st.misses, 2);
        assert_eq!(st.factors, 2);
        assert_eq!(st.patched, 0);
    }

    #[test]
    fn near_miss_scan_skips_poisoned_bases() {
        // A cached entry whose last refactor failed partway is poisoned:
        // its plan annotations describe a numeric run that never
        // completed, so it must not serve as a delta base even though its
        // pattern fits the near-miss budget.
        let a = gen::grid2d(12, 12, 5);
        let n = a.nrows();
        let pool = SolverPool::new(GluOptions::default());
        let b = vec![1.0; n];
        pool.solve(&a, &b).unwrap();

        let mut zeroed = a.clone();
        for v in zeroed.values_mut() {
            *v = 0.0;
        }
        let err = pool.checkout(&zeroed).unwrap_err();
        assert!(err.downcast_ref::<crate::numeric::GluError>().is_some());
        assert_eq!(pool.len(), 1, "numeric failure must retain the entry");

        // The same near-miss that near_miss_takes_the_incremental_patch
        // patches must now go cold: the only candidate base is poisoned.
        let a2 = gen::with_entry(&a, 7, 2, -1e-3);
        let x2 = pool.solve(&a2, &b).unwrap();
        assert!(residual(&a2, &x2, &b) < 1e-7);
        let st = pool.stats();
        assert_eq!(st.patched, 0, "poisoned entry must not be a delta base");
        assert_eq!(st.factors, 2);
    }

    #[test]
    fn near_miss_scan_skips_rescue_swapped_bases() {
        // A rung-5 pivot rescue re-permutes a cached solver's rows, so its
        // symbolic state no longer matches what the cold pipeline would
        // build for that pattern: the delta patcher must not extend it.
        // The rescued entry itself keeps serving exact hits hot.
        let opts = GluOptions {
            ordering: crate::order::FillOrdering::Natural,
            scale: false,
            ..Default::default()
        };
        let pool = SolverPool::new(opts);
        let a = gen::zero_diagonal_band(96, 48, 20260808);
        let twin = gen::dominant_restamp(&a, 7);
        let b = vec![1.0; 96];

        let x = pool.solve(&twin, &b).unwrap();
        assert!(residual(&twin, &x, &b) < 1e-7);

        // Same pattern, adversarial values: the fixed-order ladder
        // exhausts and the rescue hot-swaps the cached entry in place,
        // under the shard lock, keyed exactly as before.
        let mut g = pool.checkout(&a).unwrap();
        assert_eq!(g.outcome(), Checkout::Refactored);
        assert_eq!(g.stats().robustness.rescues, 1);
        let xr = g.solve(&b).unwrap();
        assert!(residual(&a, &xr, &b) < 1e-9);
        drop(g);

        // A structural near-miss of the twin (row 5 of column 60 is
        // structurally empty in this generator) must factor cold rather
        // than patch off the rescued entry.
        let near = gen::with_entry(&twin, 5, 60, 1e-3);
        assert_eq!(near.nnz(), twin.nnz() + 1);
        let xn = pool.solve(&near, &b).unwrap();
        assert!(residual(&near, &xn, &b) < 1e-7);
        let st = pool.stats();
        assert_eq!(st.patched, 0, "rescued entry must not be a delta base");
        assert_eq!(st.factors, 2);

        // The rescued entry still serves exact hits without re-rescuing:
        // one cold symbolic run plus the one rescue rebuild, ever.
        let g = pool.checkout(&a).unwrap();
        assert_eq!(g.outcome(), Checkout::Refactored);
        assert_eq!(g.stats().robustness.rescues, 1, "no re-rescue");
        assert_eq!(g.stats().symbolic_runs, 2);
        drop(g);
    }

    #[test]
    fn factor_error_is_not_cached() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 0, 1.0); // structurally singular
        let bad = coo.to_csc();
        let pool = SolverPool::new(GluOptions::default());
        assert!(pool.checkout(&bad).is_err());
        assert!(pool.is_empty());
        assert_eq!(pool.stats().factors, 0);
        assert_eq!(pool.stats().misses, 1);
    }
}
