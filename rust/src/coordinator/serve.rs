//! The fault-tolerant serving core: a multi-tenant request loop over the
//! [`SolverPool`].
//!
//! [`SolverPool`] amortizes symbolic work across requests, but nothing in
//! it survives a slow, failing, or overloaded *caller population*. The
//! [`Server`] adds the service discipline a production solver front-end
//! needs, as one pipeline every request flows through:
//!
//! ```text
//! submit ──► admission ──► fairness ──► coalesce ──► checkout ──► solve
//!            bounded       round-robin  by pattern   retry w/     one blocked
//!            queue,        over per-    key: one     backoff on   trisolve walk
//!            priority      tenant sub-  refactor     transient    per group
//!            shedding      queues       feeds all    faults only
//!            │                          waiters      │
//!            ▼ GluError::Overloaded                  ▼ GluError::
//!                                                    DeadlineExceeded /
//!                                                    NumericallySingular
//! ```
//!
//! - **Admission control & back-pressure** — the queue is bounded
//!   ([`ServeConfig::queue_capacity`]); a full queue rejects with a typed
//!   [`GluError::Overloaded`] instead of buffering unboundedly, and every
//!   depth transition is recorded in a ring-buffered
//!   [`crate::util::stats::DepthGauge`]. Under pressure, tenants are shed
//!   lowest-priority first: a tenant with priority `p` may only occupy
//!   `capacity * (p+1) / (max_priority+1)` slots, so low-priority traffic
//!   hits back-pressure while high-priority traffic still flows.
//! - **Fairness** — each tenant has its own sub-queue; workers pop
//!   round-robin across tenants, so one chatty tenant cannot starve the
//!   rest no matter how deep its backlog.
//! - **Deadlines** — every request carries a budget; cancellation is
//!   cooperative, checked at the dequeue, checkout, and group-solve
//!   boundaries, and a missed deadline replies with a typed
//!   [`GluError::DeadlineExceeded`].
//! - **Retry** — checkout failures classified transient by
//!   [`crate::numeric::is_transient`] are retried with exponential
//!   backoff inside the remaining deadline budget, each sleep jittered
//!   deterministically from the [`FaultPlan`] seed so coalesced tenants
//!   never retry in lock-step. The robustness ladder's in-place repairs
//!   (perturbed/escalated refactors, the rung-5 pivot rescue) return `Ok`
//!   and need no retry; a [`GluError::NumericallySingular`] that escaped
//!   *without* exhausting the ladder (cold-path factor, fallback race) is
//!   recoverable-once, while ladder exhaustion — the matrix is singular
//!   under every row order — is terminal and is **never** retried.
//! - **Coalescing** — when a popped request has same-pattern, same-values
//!   peers waiting anywhere in the queue, they ride the same checkout:
//!   one refactor feeds every waiting solve for that stamp, and the whole
//!   group's right-hand sides are stacked into **one** blocked trisolve
//!   walk ([`crate::glu::GluSolver::solve_many_into`], counted by
//!   [`ServeStats::batched_solve_walks`]).
//! - **Degradation** — sustained pressure (the backlog holding above ¾
//!   of capacity) flips the loop to a fallback pool whose engine is the
//!   cheapest viable one (the sequential left-looking oracle), trading
//!   per-request speed for service-wide liveness; easing below ¼
//!   capacity flips it back.
//! - **Shutdown** — [`Server::shutdown`] (and `Drop`) stops admission,
//!   lets the workers drain the backlog, joins them, and replies a typed
//!   [`GluError::WorkerPanicked`] to anything a dead worker stranded —
//!   no caller ever hangs.
//!
//! Driving all of it: the deterministic, seedable [`FaultPlan`] — the
//! chaos-injection layer. Decisions are a pure function of `(seed,
//! request id)`, so a chaos run is reproducible in CI regardless of
//! thread interleaving. Injected matrix faults reuse the adversarial
//! restamps of [`crate::sparse::gen`] (pattern-preserving, so they are
//! legal refactor inputs that exercise specific robustness-ladder rungs).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::pool::{pattern_key, PatternKey, PoolGuard, PoolStats, SolverPool};
use crate::glu::{GluOptions, NumericEngine};
use crate::numeric::{is_transient, service_error, GluError};
use crate::sparse::{gen, Csc};
use crate::util::stats::{DepthGauge, LatencyRecorder};
use crate::util::Rng;

/// Serving-loop knobs. The defaults suit tests and demos; a real
/// deployment sizes `queue_capacity`/`workers` to its traffic.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bounded admission-queue capacity (across all tenants).
    pub queue_capacity: usize,
    /// Worker threads draining the queue.
    pub workers: usize,
    /// Deadline for [`Server::submit`] (use
    /// [`Server::submit_with_deadline`] for per-request budgets).
    pub default_deadline: Duration,
    /// Retry budget for transient checkout failures.
    pub max_retries: u32,
    /// First backoff sleep; doubles per retry, capped by the deadline.
    pub backoff_base: Duration,
    /// Largest coalesced batch (1 disables coalescing).
    pub max_coalesce: usize,
    /// Consecutive over-watermark admissions before the loop degrades to
    /// the fallback engine.
    pub degrade_after: u32,
    /// Deterministic chaos injection (disabled by default).
    pub fault_plan: FaultPlan,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_capacity: 64,
            workers: 2,
            default_deadline: Duration::from_secs(5),
            max_retries: 3,
            backoff_base: Duration::from_millis(1),
            max_coalesce: 8,
            degrade_after: 16,
            fault_plan: FaultPlan::disabled(),
        }
    }
}

/// What the [`FaultPlan`] injects into one request's processing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// No injection.
    None,
    /// Stall the worker for the given milliseconds before the checkout
    /// (models a slow device or a GC-style hiccup).
    Delay(u64),
    /// Weaken every 7th diagonal to `1e-13` of its value
    /// ([`gen::weaken_diagonal`]): forces the ladder's rung-1/2
    /// perturb+refine repair.
    WeakenDiagonal,
    /// Mis-scale every 9th row by `1e100` ([`gen::misscale_rows`]):
    /// forces a rung-2 re-equilibration escalation.
    MisscaleRows,
    /// Zero every stored value: exhausts the ladder into a terminal typed
    /// [`GluError::NumericallySingular`] (the cached pattern survives).
    ZeroValues,
    /// Fail the first checkout attempt with a typed
    /// [`GluError::TransientFault`]: exercises the backoff-retry path.
    Poison,
}

/// A deterministic, seedable chaos plan. Every decision is a pure
/// function of `(seed, request id)` — independent of thread timing — so
/// a seeded chaos run is bit-reproducible in CI.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed recorded in reports; same seed ⇒ same per-request decisions.
    pub seed: u64,
    /// Probability of [`FaultAction::Delay`].
    pub delay: f64,
    /// Injected delay length, ms.
    pub delay_ms: u64,
    /// Probability of [`FaultAction::WeakenDiagonal`].
    pub weaken: f64,
    /// Probability of [`FaultAction::MisscaleRows`].
    pub misscale: f64,
    /// Probability of [`FaultAction::ZeroValues`].
    pub singular: f64,
    /// Probability of [`FaultAction::Poison`].
    pub poison: f64,
    /// Probability that a driver duplicates a request into a burst
    /// (consumed by the harnesses via [`FaultPlan::burst_at`], not by the
    /// serving loop itself).
    pub burst: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::disabled()
    }
}

impl FaultPlan {
    /// No injection at all (the production configuration).
    pub fn disabled() -> Self {
        FaultPlan {
            seed: 0,
            delay: 0.0,
            delay_ms: 0,
            weaken: 0.0,
            misscale: 0.0,
            singular: 0.0,
            poison: 0.0,
            burst: 0.0,
        }
    }

    /// The CI/demo chaos mix: ≥10% injected faults spanning every action,
    /// plus occasional submission bursts.
    pub fn chaos(seed: u64) -> Self {
        FaultPlan {
            seed,
            delay: 0.05,
            delay_ms: 2,
            weaken: 0.04,
            misscale: 0.02,
            singular: 0.02,
            poison: 0.04,
            burst: 0.03,
        }
    }

    /// Total injected-fault probability (bursts excluded — they add
    /// load, not faults).
    pub fn fault_rate(&self) -> f64 {
        self.delay + self.weaken + self.misscale + self.singular + self.poison
    }

    /// The (deterministic) action for one request id.
    pub fn decide(&self, request_id: u64) -> FaultAction {
        let mix = request_id.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
        let mut rng = Rng::new(self.seed ^ mix);
        let x = rng.f64();
        let mut acc = self.delay;
        if x < acc {
            return FaultAction::Delay(self.delay_ms);
        }
        acc += self.weaken;
        if x < acc {
            return FaultAction::WeakenDiagonal;
        }
        acc += self.misscale;
        if x < acc {
            return FaultAction::MisscaleRows;
        }
        acc += self.singular;
        if x < acc {
            return FaultAction::ZeroValues;
        }
        acc += self.poison;
        if x < acc {
            return FaultAction::Poison;
        }
        FaultAction::None
    }

    /// Whether a driver should duplicate request `request_id` into a
    /// burst (deterministic, like [`FaultPlan::decide`]).
    pub fn burst_at(&self, request_id: u64) -> bool {
        let mut rng = Rng::new(self.seed ^ request_id.rotate_left(17).wrapping_add(0xB0B));
        rng.chance(self.burst)
    }
}

/// Handle to a registered tenant (index into the per-tenant sub-queues).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantId(usize);

/// One admitted request waiting in (or popped from) the queue.
struct Request {
    id: u64,
    key: PatternKey,
    a: Csc,
    rhs: Vec<Vec<f64>>,
    deadline: Instant,
    budget_ms: u64,
    enqueued: Instant,
    reply: mpsc::Sender<anyhow::Result<Vec<Vec<f64>>>>,
}

struct TenantState {
    name: String,
    priority: u8,
    submitted: u64,
    queue: VecDeque<Request>,
}

struct QueueState {
    tenants: Vec<TenantState>,
    /// Round-robin cursor over tenants.
    rr: usize,
    /// Total queued requests across tenants.
    depth: usize,
    /// Consecutive admissions observed above the degrade watermark.
    over_streak: u32,
    /// Set by shutdown: reject new work, drain the backlog, exit workers.
    stopping: bool,
}

/// The pending reply to one submitted request. [`Ticket::wait`] blocks
/// until the serving loop answers; every admitted request is answered —
/// with a solution, a typed rejection, or a typed deadline error — even
/// across worker death and shutdown.
pub struct Ticket {
    id: u64,
    rx: mpsc::Receiver<anyhow::Result<Vec<Vec<f64>>>>,
}

impl Ticket {
    /// The request id (the [`FaultPlan`] key for this request).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request resolves. A dead worker surfaces as a
    /// typed [`GluError::WorkerPanicked`] rather than a hang.
    pub fn wait(self) -> anyhow::Result<Vec<Vec<f64>>> {
        let Ok(r) = self.rx.recv() else {
            let e = service_error(GluError::WorkerPanicked);
            return Err(e.context("request dropped: its worker thread died"));
        };
        r
    }
}

/// Aggregate serving counters (see [`Server::stats`]). The zero-lost
/// invariant after a drained shutdown is
/// `submitted == completed + deadline_missed + failed`
/// ([`ServeStats::in_flight`] returns 0); rejections and sheds are
/// counted separately because those requests were never admitted.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests admitted to the queue.
    pub submitted: u64,
    /// Requests answered with solutions.
    pub completed: u64,
    /// Submissions rejected by the full queue (typed
    /// [`GluError::Overloaded`]).
    pub rejected: u64,
    /// Submissions shed by priority-scaled admission under pressure.
    pub shed: u64,
    /// Admitted requests that missed their deadline (typed
    /// [`GluError::DeadlineExceeded`]).
    pub deadline_missed: u64,
    /// Admitted requests that failed terminally (typed
    /// [`GluError::NumericallySingular`], structural errors, or a
    /// shutdown flush).
    pub failed: u64,
    /// Backoff retries of transient checkout failures.
    pub retries: u64,
    /// Requests that rode another request's checkout (batch members
    /// beyond each leader).
    pub coalesced: u64,
    /// Blocked multi-RHS trisolve walks issued by the serving loop — one
    /// per processed group with at least one right-hand side, no matter
    /// how many coalesced requests (or RHS per request) rode it. A
    /// coalesced group costs exactly one walk: the acceptance invariant is
    /// `batched_solve_walks + deadline_missed + failed >= submitted -
    /// coalesced` with equality under clean traffic.
    pub batched_solve_walks: u64,
    /// Checkouts served by the degraded fallback engine.
    pub degraded_checkouts: u64,
    /// Worker threads that died (panicked) over the server's lifetime.
    pub worker_panics: u64,
    /// Injected [`FaultAction::Delay`] count.
    pub injected_delays: u64,
    /// Injected [`FaultAction::WeakenDiagonal`] count.
    pub injected_repairs: u64,
    /// Injected [`FaultAction::MisscaleRows`] count.
    pub injected_escalations: u64,
    /// Injected [`FaultAction::ZeroValues`] count.
    pub injected_singulars: u64,
    /// Injected [`FaultAction::Poison`] count.
    pub injected_poisons: u64,
    /// Configured admission-queue capacity.
    pub queue_capacity: usize,
    /// Queue-depth gauge (current / high-water / windowed summaries).
    pub depth: DepthGauge,
    /// End-to-end request latency (admission to reply), completed
    /// requests only.
    pub latency: LatencyRecorder,
    /// Primary pool counters (hits/misses/evictions/...).
    pub pool: PoolStats,
    /// Symbolic pipeline runs across both pools' live entries — the
    /// coalescing acceptance reads `symbolic_runs < submitted`.
    pub symbolic_runs: usize,
    /// Numeric kernel runs across both pools' live entries.
    pub numeric_runs: usize,
}

impl ServeStats {
    /// Admitted requests that have received a reply.
    pub fn resolved(&self) -> u64 {
        self.completed + self.deadline_missed + self.failed
    }

    /// Admitted requests not yet replied to (0 after a drained shutdown —
    /// the zero-lost invariant).
    pub fn in_flight(&self) -> u64 {
        self.submitted.saturating_sub(self.resolved())
    }

    /// Total injected faults.
    pub fn injected_faults(&self) -> u64 {
        self.injected_delays
            + self.injected_repairs
            + self.injected_escalations
            + self.injected_singulars
            + self.injected_poisons
    }

    /// Median end-to-end latency, ms.
    pub fn p50_ms(&self) -> f64 {
        self.latency.p50_ms()
    }

    /// 99th-percentile end-to-end latency, ms.
    pub fn p99_ms(&self) -> f64 {
        self.latency.p99_ms()
    }

    /// 99.9th-percentile end-to-end latency, ms.
    pub fn p999_ms(&self) -> f64 {
        self.latency.p999_ms()
    }
}

enum CheckoutErr {
    Deadline,
    Failed(anyhow::Error),
}

struct Inner {
    cfg: ServeConfig,
    pool: SolverPool,
    /// Cheapest-viable-engine pool the loop degrades to under sustained
    /// pressure (sequential left-looking: no worker threads to feed).
    fallback: SolverPool,
    queue: Mutex<QueueState>,
    cond: Condvar,
    gauge: Mutex<DepthGauge>,
    latency: Mutex<LatencyRecorder>,
    degraded: AtomicBool,
    next_id: AtomicU64,
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    deadline_missed: AtomicU64,
    failed: AtomicU64,
    retries: AtomicU64,
    coalesced: AtomicU64,
    batched_solve_walks: AtomicU64,
    degraded_checkouts: AtomicU64,
    worker_panics: AtomicU64,
    injected_delays: AtomicU64,
    injected_repairs: AtomicU64,
    injected_escalations: AtomicU64,
    injected_singulars: AtomicU64,
    injected_poisons: AtomicU64,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `anyhow::Error` is not `Clone`, but every member of a coalesced batch
/// needs its own copy of a shared failure: typed payloads are
/// reconstructed exactly, untyped chains are flattened to their rendered
/// form.
fn clone_error(e: &anyhow::Error) -> anyhow::Error {
    match e.downcast_ref::<GluError>() {
        Some(g) => service_error(*g),
        None => anyhow::anyhow!("{e:#}"),
    }
}

impl Inner {
    fn pop_locked(&self, q: &mut QueueState) -> Option<Vec<Request>> {
        if q.depth == 0 || q.tenants.is_empty() {
            return None;
        }
        // Round-robin fairness across tenant sub-queues.
        let nt = q.tenants.len();
        let mut lead: Option<Request> = None;
        for step in 0..nt {
            let ti = (q.rr + step) % nt;
            if let Some(r) = q.tenants[ti].queue.pop_front() {
                q.rr = (ti + 1) % nt;
                lead = Some(r);
                break;
            }
        }
        let lead = lead?;
        q.depth -= 1;

        // Coalesce: same pattern, same values, anywhere in the queue —
        // they all ride this checkout.
        let mut extras: Vec<Request> = Vec::new();
        let limit = self.cfg.max_coalesce;
        if limit > 1 {
            let lead_vals = lead.a.values();
            'scan: for t in q.tenants.iter_mut() {
                let mut i = 0;
                while i < t.queue.len() {
                    if extras.len() + 1 >= limit {
                        break 'scan;
                    }
                    if t.queue[i].key == lead.key && t.queue[i].a.values() == lead_vals {
                        if let Some(r) = t.queue.remove(i) {
                            extras.push(r);
                        }
                    } else {
                        i += 1;
                    }
                }
            }
        }
        q.depth -= extras.len();

        // Pressure easing: leave degraded mode once the backlog falls to
        // a quarter of capacity.
        if q.depth * 4 <= self.cfg.queue_capacity {
            q.over_streak = 0;
            self.degraded.store(false, Ordering::Relaxed);
        }
        lock(&self.gauge).record(q.depth);

        let mut batch = Vec::with_capacity(1 + extras.len());
        batch.push(lead);
        batch.extend(extras);
        Some(batch)
    }

    fn next_batch(&self) -> Option<Vec<Request>> {
        let mut q = lock(&self.queue);
        loop {
            if let Some(batch) = self.pop_locked(&mut q) {
                return Some(batch);
            }
            if q.stopping {
                return None;
            }
            q = self.cond.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }

    fn finish_deadline(&self, r: Request) {
        self.deadline_missed.fetch_add(1, Ordering::Relaxed);
        let e = GluError::DeadlineExceeded {
            budget_ms: r.budget_ms,
        };
        let _ = r.reply.send(Err(service_error(e)));
    }

    /// Apply the matrix-transforming fault actions (pattern-preserving
    /// adversarial restamps), counting each injection.
    fn apply_matrix_fault(&self, action: FaultAction, a: &Csc) -> Option<Csc> {
        match action {
            FaultAction::WeakenDiagonal => {
                self.injected_repairs.fetch_add(1, Ordering::Relaxed);
                Some(gen::weaken_diagonal(a, 7, 1e-13))
            }
            FaultAction::MisscaleRows => {
                self.injected_escalations.fetch_add(1, Ordering::Relaxed);
                Some(gen::misscale_rows(a, 9, 1e100))
            }
            FaultAction::ZeroValues => {
                self.injected_singulars.fetch_add(1, Ordering::Relaxed);
                let mut z = a.clone();
                for v in z.values_mut() {
                    *v = 0.0;
                }
                Some(z)
            }
            _ => None,
        }
    }

    /// Checkout with deadline-capped exponential-backoff retry of
    /// *transient* failures (injected poisons, overload). A numerically
    /// singular result is retried **once** unless the solver's ladder
    /// already exhausted — a rescuable matrix is repaired inside
    /// [`GluSolver::refactor`]'s rung-5 pivot rescue, so a singular error
    /// *without* the ladder-exhausted marker means the rescue never got to
    /// run (cold-path factor, fallback-pool race) and one more attempt may
    /// land on the rescued entry. Terminal failures — ladder exhaustion,
    /// structural errors — return immediately.
    ///
    /// Backoff sleeps carry deterministic seeded jitter (a pure function
    /// of the [`FaultPlan`] seed, the leader's request id, and the attempt
    /// number): coalesced tenants released by one rescue fan out instead
    /// of retrying in lock-step, and a seeded chaos run stays
    /// bit-reproducible.
    ///
    /// [`GluSolver::refactor`]: crate::glu::GluSolver::refactor
    fn checkout_with_retry(
        &self,
        a: &Csc,
        id: u64,
        poisoned: bool,
        deadline: Instant,
    ) -> Result<PoolGuard<'_>, CheckoutErr> {
        let mut attempt: u32 = 0;
        let mut backoff = self.cfg.backoff_base;
        let mut singular_retried = false;
        let mix = id.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
        let mut jitter = Rng::new(self.cfg.fault_plan.seed ^ mix.rotate_left(29));
        let mut sleep_with_jitter = |backoff: &mut Duration| {
            self.retries.fetch_add(1, Ordering::Relaxed);
            let remaining = deadline.saturating_duration_since(Instant::now());
            let jittered = backoff.mul_f64(0.5 + jitter.f64());
            std::thread::sleep(jittered.min(remaining));
            *backoff = backoff.saturating_mul(2);
        };
        loop {
            if Instant::now() >= deadline {
                return Err(CheckoutErr::Deadline);
            }
            let res = if poisoned && attempt == 0 {
                self.injected_poisons.fetch_add(1, Ordering::Relaxed);
                Err(service_error(GluError::TransientFault).context("injected poisoned checkout"))
            } else if self.degraded.load(Ordering::Relaxed) {
                self.degraded_checkouts.fetch_add(1, Ordering::Relaxed);
                self.fallback.checkout(a)
            } else {
                self.pool.checkout(a)
            };
            match res {
                Ok(g) => return Ok(g),
                Err(e) if is_transient(&e) && attempt < self.cfg.max_retries => {
                    sleep_with_jitter(&mut backoff);
                    attempt += 1;
                }
                Err(e)
                    if !singular_retried
                        && attempt < self.cfg.max_retries
                        && matches!(
                            e.downcast_ref::<GluError>(),
                            Some(GluError::NumericallySingular { .. })
                        )
                        && !format!("{e:#}").contains("ladder exhausted") =>
                {
                    singular_retried = true;
                    sleep_with_jitter(&mut backoff);
                    attempt += 1;
                }
                Err(e) => return Err(CheckoutErr::Failed(e)),
            }
        }
    }

    /// Solve a coalesced group against a held checkout with **one**
    /// blocked trisolve walk: every live member's right-hand sides are
    /// stacked into a single [`PoolGuard::solve_many_into`] call (the
    /// RHS vectors are moved, not copied, through worker-owned scratch),
    /// then the solution block is split back per request. Deadlines are
    /// re-checked per member at the solve boundary; a shared failure is
    /// cloned to every member's reply.
    fn solve_group(
        &self,
        guard: &mut PoolGuard<'_>,
        live: Vec<Request>,
        scratch: &mut SolveScratch,
    ) {
        let now = Instant::now();
        let (mut ready, expired): (Vec<Request>, Vec<Request>) =
            live.into_iter().partition(|r| now < r.deadline);
        for r in expired {
            self.finish_deadline(r);
        }
        if ready.is_empty() {
            return;
        }
        scratch.rhs.clear();
        scratch.counts.clear();
        for r in ready.iter_mut() {
            scratch.counts.push(r.rhs.len());
            scratch.rhs.append(&mut r.rhs);
        }
        let total = scratch.rhs.len();
        scratch.out.resize_with(total, Vec::new);
        match guard.solve_many_into(&scratch.rhs, &mut scratch.out) {
            Ok(()) => {
                if total > 0 {
                    self.batched_solve_walks.fetch_add(1, Ordering::Relaxed);
                }
                let mut off = 0usize;
                for (r, &cnt) in ready.into_iter().zip(scratch.counts.iter()) {
                    let xs: Vec<Vec<f64>> = scratch.out[off..off + cnt]
                        .iter_mut()
                        .map(std::mem::take)
                        .collect();
                    off += cnt;
                    self.completed.fetch_add(1, Ordering::Relaxed);
                    let ms = r.enqueued.elapsed().as_secs_f64() * 1e3;
                    lock(&self.latency).record(ms);
                    let _ = r.reply.send(Ok(xs));
                }
            }
            Err(e) => {
                for r in ready {
                    self.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = r.reply.send(Err(clone_error(&e).context("solve failed")));
                }
            }
        }
        scratch.rhs.clear();
    }

    fn process(&self, batch: Vec<Request>, scratch: &mut SolveScratch) {
        let extra = batch.len() - 1;
        if extra > 0 {
            self.coalesced.fetch_add(extra as u64, Ordering::Relaxed);
        }
        // Dequeue boundary: requests that expired while queued get their
        // typed reply without costing a checkout.
        let now = Instant::now();
        let (live, expired): (Vec<Request>, Vec<Request>) =
            batch.into_iter().partition(|r| now < r.deadline);
        for r in expired {
            self.finish_deadline(r);
        }
        let Some(lead) = live.first() else { return };

        // One deterministic fault decision per batch, keyed by the leader.
        let action = self.cfg.fault_plan.decide(lead.id);
        if let FaultAction::Delay(ms) = action {
            self.injected_delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(ms));
        }
        let adversarial = self.apply_matrix_fault(action, &lead.a);
        let served = adversarial.as_ref().unwrap_or(&lead.a);

        // The shared checkout runs under the batch's latest deadline;
        // members are re-checked individually before their solves.
        let latest = live.iter().map(|r| r.deadline).max().expect("batch");
        let poisoned = matches!(action, FaultAction::Poison);
        match self.checkout_with_retry(served, lead.id, poisoned, latest) {
            Ok(mut guard) => self.solve_group(&mut guard, live, scratch),
            Err(CheckoutErr::Deadline) => {
                for r in live {
                    self.finish_deadline(r);
                }
            }
            Err(CheckoutErr::Failed(e)) => {
                for r in live {
                    self.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = r.reply.send(Err(clone_error(&e).context("checkout failed")));
                }
            }
        }
    }
}

/// Worker-owned scratch for the batched group solve: the flat RHS block,
/// per-request counts, and the output slots are reused across batches so
/// the steady-state serving loop's internal solve path allocates nothing
/// (the reply payloads themselves are owned by the callers).
struct SolveScratch {
    rhs: Vec<Vec<f64>>,
    out: Vec<Vec<f64>>,
    counts: Vec<usize>,
}

fn worker_loop(inner: &Inner) {
    let mut scratch = SolveScratch {
        rhs: Vec::new(),
        out: Vec::new(),
        counts: Vec::new(),
    };
    while let Some(batch) = inner.next_batch() {
        inner.process(batch, &mut scratch);
    }
}

/// The multi-tenant serving loop (see the module docs for the pipeline).
pub struct Server {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Spawn a server: a [`SolverPool`] built from `opts`, a fallback
    /// pool on the cheapest viable engine, and `cfg.workers` drainers.
    pub fn new(opts: GluOptions, cfg: ServeConfig) -> Server {
        assert!(cfg.queue_capacity >= 1, "queue capacity must be >= 1");
        assert!(cfg.workers >= 1, "at least one worker");
        assert!(cfg.max_coalesce >= 1, "max_coalesce must be >= 1");
        let fallback_opts = GluOptions {
            engine: NumericEngine::LeftLookingCpu,
            ..opts.clone()
        };
        let nworkers = cfg.workers;
        let inner = Arc::new(Inner {
            cfg,
            pool: SolverPool::new(opts),
            fallback: SolverPool::with_config(fallback_opts, 2, 2),
            queue: Mutex::new(QueueState {
                tenants: Vec::new(),
                rr: 0,
                depth: 0,
                over_streak: 0,
                stopping: false,
            }),
            cond: Condvar::new(),
            gauge: Mutex::new(DepthGauge::new()),
            latency: Mutex::new(LatencyRecorder::new()),
            degraded: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            deadline_missed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            batched_solve_walks: AtomicU64::new(0),
            degraded_checkouts: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
            injected_repairs: AtomicU64::new(0),
            injected_escalations: AtomicU64::new(0),
            injected_singulars: AtomicU64::new(0),
            injected_poisons: AtomicU64::new(0),
        });
        let workers = (0..nworkers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("glu3-serve-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn serve worker")
            })
            .collect();
        Server { inner, workers }
    }

    /// Register a tenant. Higher `priority` keeps flowing longer under
    /// pressure; the lowest-priority tenants are shed first.
    pub fn tenant(&self, name: &str, priority: u8) -> TenantId {
        let mut q = lock(&self.inner.queue);
        q.tenants.push(TenantState {
            name: name.to_string(),
            priority,
            submitted: 0,
            queue: VecDeque::new(),
        });
        TenantId(q.tenants.len() - 1)
    }

    /// `(name, priority, admitted submissions)` per registered tenant.
    pub fn tenant_info(&self) -> Vec<(String, u8, u64)> {
        let q = lock(&self.inner.queue);
        q.tenants
            .iter()
            .map(|t| (t.name.clone(), t.priority, t.submitted))
            .collect()
    }

    /// Pre-factor a pattern directly into the primary pool, bypassing
    /// the queue and the fault plan — harnesses warm their patterns so
    /// injected singular stamps always land on *cached* symbolic state
    /// (the scenario the pool's retention policy is about).
    pub fn warm(&self, a: &Csc) -> anyhow::Result<()> {
        self.inner.pool.checkout(a).map(|_| ())
    }

    /// The primary pool (counters and entry stats for tests/reports).
    pub fn pool(&self) -> &SolverPool {
        &self.inner.pool
    }

    /// Whether the loop is currently degraded to the fallback engine.
    pub fn is_degraded(&self) -> bool {
        self.inner.degraded.load(Ordering::Relaxed)
    }

    /// Submit with the configured default deadline.
    pub fn submit(&self, tenant: TenantId, a: Csc, rhs: Vec<Vec<f64>>) -> anyhow::Result<Ticket> {
        let budget = self.inner.cfg.default_deadline;
        self.submit_with_deadline(tenant, a, rhs, budget)
    }

    /// Submit a request: admission control runs synchronously (typed
    /// [`GluError::Overloaded`] on rejection), everything after is
    /// asynchronous behind the returned [`Ticket`].
    pub fn submit_with_deadline(
        &self,
        tenant: TenantId,
        a: Csc,
        rhs: Vec<Vec<f64>>,
        budget: Duration,
    ) -> anyhow::Result<Ticket> {
        let inner = &self.inner;
        let cap = inner.cfg.queue_capacity;
        let key = pattern_key(&a);
        let (tx, rx) = mpsc::channel();
        let id = inner.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut q = lock(&inner.queue);
            anyhow::ensure!(tenant.0 < q.tenants.len(), "unregistered tenant");
            if q.stopping {
                inner.rejected.fetch_add(1, Ordering::Relaxed);
                let e = GluError::Overloaded {
                    depth: q.depth,
                    capacity: cap,
                };
                return Err(service_error(e).context("server is shutting down"));
            }
            if q.depth >= cap {
                inner.rejected.fetch_add(1, Ordering::Relaxed);
                let e = GluError::Overloaded {
                    depth: q.depth,
                    capacity: cap,
                };
                return Err(service_error(e));
            }
            // Priority-scaled shares: a tenant with priority p may occupy
            // cap*(p+1)/(maxp+1) slots, so under pressure the lowest
            // priorities are shed first while the top priority still sees
            // the full queue.
            let maxp = q.tenants.iter().map(|t| t.priority).max().unwrap_or(0) as usize;
            let p = q.tenants[tenant.0].priority as usize;
            let share = (cap * (p + 1)) / (maxp + 1);
            if q.depth >= share {
                inner.shed.fetch_add(1, Ordering::Relaxed);
                let e = GluError::Overloaded {
                    depth: q.depth,
                    capacity: cap,
                };
                let msg = format!("shed: priority {p} share is {share} slots");
                return Err(service_error(e).context(msg));
            }
            let now = Instant::now();
            q.tenants[tenant.0].queue.push_back(Request {
                id,
                key,
                a,
                rhs,
                deadline: now + budget,
                budget_ms: budget.as_millis() as u64,
                enqueued: now,
                reply: tx,
            });
            q.tenants[tenant.0].submitted += 1;
            q.depth += 1;
            // Sustained-pressure tracking for engine degradation.
            if q.depth * 4 >= cap * 3 {
                q.over_streak += 1;
                if q.over_streak >= inner.cfg.degrade_after {
                    inner.degraded.store(true, Ordering::Relaxed);
                }
            } else {
                q.over_streak = 0;
            }
            lock(&inner.gauge).record(q.depth);
            inner.submitted.fetch_add(1, Ordering::Relaxed);
        }
        inner.cond.notify_one();
        Ok(Ticket { id, rx })
    }

    /// Counter snapshot (live — callable while serving).
    pub fn stats(&self) -> ServeStats {
        let inner = &self.inner;
        let (sym_p, num_p) = inner.pool.run_totals();
        let (sym_f, num_f) = inner.fallback.run_totals();
        ServeStats {
            submitted: inner.submitted.load(Ordering::Relaxed),
            completed: inner.completed.load(Ordering::Relaxed),
            rejected: inner.rejected.load(Ordering::Relaxed),
            shed: inner.shed.load(Ordering::Relaxed),
            deadline_missed: inner.deadline_missed.load(Ordering::Relaxed),
            failed: inner.failed.load(Ordering::Relaxed),
            retries: inner.retries.load(Ordering::Relaxed),
            coalesced: inner.coalesced.load(Ordering::Relaxed),
            batched_solve_walks: inner.batched_solve_walks.load(Ordering::Relaxed),
            degraded_checkouts: inner.degraded_checkouts.load(Ordering::Relaxed),
            worker_panics: inner.worker_panics.load(Ordering::Relaxed),
            injected_delays: inner.injected_delays.load(Ordering::Relaxed),
            injected_repairs: inner.injected_repairs.load(Ordering::Relaxed),
            injected_escalations: inner.injected_escalations.load(Ordering::Relaxed),
            injected_singulars: inner.injected_singulars.load(Ordering::Relaxed),
            injected_poisons: inner.injected_poisons.load(Ordering::Relaxed),
            queue_capacity: inner.cfg.queue_capacity,
            depth: lock(&inner.gauge).clone(),
            latency: lock(&inner.latency).clone(),
            pool: inner.pool.stats(),
            symbolic_runs: sym_p + sym_f,
            numeric_runs: num_p + num_f,
        }
    }

    /// Graceful shutdown: stop admission, let the workers drain the
    /// backlog, join them, and flush anything a dead worker stranded.
    /// Returns the final counters (with `in_flight() == 0`).
    pub fn shutdown(mut self) -> ServeStats {
        self.shutdown_impl();
        self.stats()
    }

    fn shutdown_impl(&mut self) {
        {
            let mut q = lock(&self.inner.queue);
            q.stopping = true;
        }
        self.inner.cond.notify_all();
        for j in self.workers.drain(..) {
            if j.join().is_err() {
                self.inner.worker_panics.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Workers drain before exiting, so leftovers only exist if a
        // worker died: give every stranded request a typed reply so no
        // ticket can hang.
        let mut q = lock(&self.inner.queue);
        for t in q.tenants.iter_mut() {
            while let Some(r) = t.queue.pop_front() {
                self.inner.failed.fetch_add(1, Ordering::Relaxed);
                let e = service_error(GluError::WorkerPanicked)
                    .context("server shut down before the request ran");
                let _ = r.reply.send(Err(e));
            }
        }
        q.depth = 0;
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::residual;

    #[test]
    fn fault_plan_is_deterministic_and_seed_sensitive() {
        let a = FaultPlan::chaos(42);
        let b = FaultPlan::chaos(42);
        let c = FaultPlan::chaos(43);
        let da: Vec<FaultAction> = (0..256).map(|i| a.decide(i)).collect();
        let db: Vec<FaultAction> = (0..256).map(|i| b.decide(i)).collect();
        let dc: Vec<FaultAction> = (0..256).map(|i| c.decide(i)).collect();
        assert_eq!(da, db, "same seed must replay identically");
        assert_ne!(da, dc, "different seeds must differ");
        assert!(a.fault_rate() >= 0.1, "chaos mix is >= 10% faults");
        assert!(
            da.iter().any(|&x| x != FaultAction::None),
            "chaos plan must actually inject"
        );
        let quiet = FaultPlan::disabled();
        assert!((0..256).all(|i| quiet.decide(i) == FaultAction::None));
    }

    #[test]
    fn clean_round_trip_completes_everything() {
        let a = gen::netlist(96, 5, 8, 0.1, 1, 0.2, 11);
        let server = Server::new(GluOptions::default(), ServeConfig::default());
        let t0 = server.tenant("sim-a", 1);
        server.warm(&a).unwrap();
        let b = vec![1.0; 96];
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| server.submit(t0, a.clone(), vec![b.clone()]).unwrap())
            .collect();
        for t in tickets {
            let xs = t.wait().unwrap();
            assert_eq!(xs.len(), 1);
            assert!(residual(&a, &xs[0], &b) < 1e-7);
        }
        let st = server.shutdown();
        assert_eq!(st.completed, 8);
        assert_eq!(st.in_flight(), 0);
        assert_eq!(st.rejected + st.shed + st.failed + st.deadline_missed, 0);
    }

    #[test]
    fn unregistered_tenant_is_refused() {
        let server = Server::new(GluOptions::default(), ServeConfig::default());
        let err = server
            .submit(TenantId(5), gen::grid2d(4, 4, 1), vec![vec![1.0; 16]])
            .unwrap_err();
        assert!(format!("{err}").contains("unregistered"));
    }
}
