//! Layer-3 coordinator: the batched solver service and the Newton–Raphson
//! refactorization driver.
//!
//! The paper's system is a *solver*, so L3 is a thin-but-real runtime with
//! two serving layers:
//!
//! - [`pool`] — the [`SolverPool`]: a sharded, pattern-keyed symbolic cache.
//!   Requests carrying a matrix whose sparsity pattern has been seen before
//!   take the refactor fast path (numeric kernel only); new patterns pay one
//!   full factorization and are cached with LRU eviction. Batched multi-RHS
//!   solves amortize the permute/trisolve setup, and per-request latency is
//!   tracked for p50/p99 reporting. This is the layer the NR driver
//!   ([`nr`]) and the transient simulator route through.
//! - [`service`] — the named-handle worker-thread service: one thread owns
//!   each factored system, clients submit solve/refactor jobs over channels.
//!   Useful when systems are long-lived and callers want isolation rather
//!   than a shared cache.
//! - [`serve`] — the fault-tolerant serving core over the pool: bounded
//!   admission with priority shedding, per-tenant fairness, deadlines with
//!   cooperative cancellation, transient-failure retry, same-stamp request
//!   coalescing, engine degradation under pressure, drain-then-join
//!   shutdown — all testable under a deterministic seeded [`FaultPlan`].

pub mod nr;
pub mod pool;
pub mod serve;
pub mod service;

pub use nr::{newton_raphson, newton_raphson_in, NonlinearSystem, NrOptions, NrResult};
pub use pool::{pattern_key, Checkout, PatternKey, PoolGuard, PoolStats, SolverPool};
pub use serve::{FaultAction, FaultPlan, ServeConfig, ServeStats, Server, TenantId, Ticket};
pub use service::{SolverHandle, SolverService};
