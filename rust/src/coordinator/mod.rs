//! Layer-3 coordinator: a threaded solver service and the Newton–Raphson
//! refactorization driver.
//!
//! The paper's system is a *solver*, so L3 is a thin-but-real runtime: a
//! worker thread owns each factored system (symbolic state is large and
//! reusable), clients submit solve/refactor jobs over channels, and the
//! service batches multiple right-hand sides against one set of factors —
//! the access pattern of a SPICE transient loop, where one Jacobian pattern
//! is refactored per Newton step and solved against one or more RHS.

pub mod nr;
pub mod service;

pub use nr::{newton_raphson, NonlinearSystem, NrOptions, NrResult};
pub use service::{SolverHandle, SolverService};
