//! The [`ScatterMap`]: pattern-time position resolution for the
//! refactorization hot loop.
//!
//! GLU's amortization argument says anything computable from the sparsity
//! pattern should be paid **once per pattern**, not once per refactor — yet
//! the numeric MAC loop used to re-derive every position on every Newton
//! restamp: a `binary_search` per multiplier, a `partition_point` plus a
//! linear row-match scan per destination element. This module moves all of
//! that into the symbolic phase (CKTSO and HYLU make the same trade): for
//! every `(source column j, destination column k)` MAC task the map stores
//! the multiplier's value index and a flat run of destination value
//! indices aligned one-to-one with column `j`'s L rows, so the numeric
//! inner loop degenerates to `vals[dst[i]] -= l[i] * mult` with **zero
//! searching**. The same index runs are exactly the gather/scatter buffers
//! a real GPU offload would upload once per pattern.
//!
//! Layout (all indices point into the filled pattern's value array):
//!
//! ```text
//! column j:  diag_idx[j]                 value index of U(j,j)
//!            l_len[j]                    L entries (contiguous after diag)
//!            tasks task_ptr[j]..task_ptr[j+1]   one per subcolumn k of j
//! task t:    mult_idx[t]                 value index of As(j,k)
//!            dst[dst_off[t] .. dst_off[t] + l_len[j]]
//!                                        value index of As(i,k) per L row i
//! ```
//!
//! Task `t` of column `j` corresponds to `urow[j][t - task_ptr[j]]` — the
//! same enumeration [`crate::plan::FactorPlan`] uses for its
//! destination-ownership groups, so a group's stored task ids index
//! straight into this map.
//!
//! The fields are public for inspection and for adversarial tests; they
//! must be treated as read-only. The numeric engines only ever consume a
//! map built (and, in debug builds, [`ScatterMap::validate`]d) internally
//! by [`crate::plan::FactorPlan::scatter`], never a caller-supplied one —
//! the unchecked indexed stores in the hot loop rely on that provenance.

use crate::sparse::Csc;

/// Precomputed value-index map for the right-looking MAC loop — see the
/// module docs for the layout.
#[derive(Debug, Clone)]
pub struct ScatterMap {
    /// `nnz` of the filled pattern the indices point into.
    pub nnz: usize,
    /// Per column: value index of the diagonal (the L run follows it).
    pub diag_idx: Vec<u32>,
    /// Per column: number of L entries (= length of every MAC task run).
    pub l_len: Vec<u32>,
    /// Per column: task range `task_ptr[j]..task_ptr[j+1]` (len `n + 1`).
    pub task_ptr: Vec<u32>,
    /// Per task: value index of the multiplier `As(j,k)`.
    pub mult_idx: Vec<u32>,
    /// Per task: start of its destination run in [`ScatterMap::dst`].
    pub dst_off: Vec<u32>,
    /// Flat destination value indices, `l_len[j]` per task of column `j`.
    pub dst: Vec<u32>,
}

impl ScatterMap {
    /// Build the map from a filled pattern and its subcolumn view (`urow`
    /// as produced by [`crate::numeric::rightlook::upper_rows`]). Pure
    /// pattern work — `O(total MAC elements)`, the cost of roughly one
    /// numeric refactorization, paid once per pattern.
    ///
    /// Panics if the pattern misses a diagonal entry (symbolic fill
    /// guarantees it) or exceeds `u32` indexing (≥ 4G nonzeros).
    pub fn build(filled: &Csc, urow: &[Vec<u32>]) -> ScatterMap {
        let n = filled.ncols();
        assert_eq!(urow.len(), n, "subcolumn view dimension mismatch");
        let nnz = filled.nnz();
        assert!(nnz <= u32::MAX as usize, "pattern exceeds u32 indexing");
        let (colptr, rowidx) = (filled.colptr(), filled.rowidx());

        let mut diag_idx = Vec::with_capacity(n);
        let mut l_len = Vec::with_capacity(n);
        for j in 0..n {
            let rows = &rowidx[colptr[j]..colptr[j + 1]];
            let d = rows.binary_search(&j).expect("full diagonal");
            diag_idx.push((colptr[j] + d) as u32);
            l_len.push((rows.len() - d - 1) as u32);
        }

        let total_tasks: usize = urow.iter().map(|u| u.len()).sum();
        let total_dst: usize = (0..n)
            .map(|j| l_len[j] as usize * urow[j].len())
            .sum();
        assert!(total_dst <= u32::MAX as usize, "MAC volume exceeds u32 indexing");
        let mut task_ptr = Vec::with_capacity(n + 1);
        task_ptr.push(0u32);
        let mut mult_idx = Vec::with_capacity(total_tasks);
        let mut dst_off = Vec::with_capacity(total_tasks);
        let mut dst: Vec<u32> = Vec::with_capacity(total_dst);

        for j in 0..n {
            let ls = diag_idx[j] as usize + 1;
            let lrows = &rowidx[ls..ls + l_len[j] as usize];
            for &k in &urow[j] {
                let k = k as usize;
                let (s_k, e_k) = (colptr[k], colptr[k + 1]);
                let rows_k = &rowidx[s_k..e_k];
                // Merged scan: j and every L row are present in column k
                // (the fill closure guarantees containment), in order.
                // Real asserts (release too): if the caller's pattern does
                // not match the subcolumn view — same n and nnz but a
                // different structure — these trip at build time with a
                // diagnostic instead of caching a silently wrong map.
                let mut pos = rows_k.partition_point(|&r| r < j);
                assert!(
                    pos < rows_k.len() && rows_k[pos] == j,
                    "pattern mismatch: column {k} has no multiplier entry at row {j}"
                );
                mult_idx.push((s_k + pos) as u32);
                dst_off.push(dst.len() as u32);
                pos += 1;
                for &i in lrows {
                    while pos < rows_k.len() && rows_k[pos] != i {
                        pos += 1;
                    }
                    assert!(
                        pos < rows_k.len(),
                        "pattern mismatch: column {k} is missing update target row {i}"
                    );
                    dst.push((s_k + pos) as u32);
                    pos += 1;
                }
            }
            task_ptr.push(mult_idx.len() as u32);
        }

        ScatterMap {
            nnz,
            diag_idx,
            l_len,
            task_ptr,
            mult_idx,
            dst_off,
            dst,
        }
    }

    /// Total MAC tasks across all columns.
    pub fn num_tasks(&self) -> usize {
        self.mult_idx.len()
    }

    /// Full structural coherence check against the pattern the map claims
    /// to index: every run boundary, multiplier position, and destination
    /// index is re-derived from `filled`/`urow` and compared. `O(total MAC
    /// elements)` — debug builds run it once per map build
    /// ([`crate::plan::FactorPlan::scatter`]); a corrupted or mismatched
    /// map is rejected here before any indexed store can go wrong.
    pub fn validate(&self, filled: &Csc, urow: &[Vec<u32>]) -> anyhow::Result<()> {
        let n = filled.ncols();
        let (colptr, rowidx) = (filled.colptr(), filled.rowidx());
        anyhow::ensure!(self.nnz == filled.nnz(), "nnz mismatch");
        anyhow::ensure!(urow.len() == n, "subcolumn view dimension mismatch");
        anyhow::ensure!(
            self.diag_idx.len() == n && self.l_len.len() == n && self.task_ptr.len() == n + 1,
            "per-column array length mismatch"
        );
        anyhow::ensure!(self.task_ptr[0] == 0, "task_ptr must start at 0");
        let ntasks = self.mult_idx.len();
        anyhow::ensure!(
            self.dst_off.len() == ntasks && self.task_ptr[n] as usize == ntasks,
            "task array length mismatch"
        );
        let mut expect_dst = 0usize;
        for j in 0..n {
            let rows = &rowidx[colptr[j]..colptr[j + 1]];
            let d = rows
                .binary_search(&j)
                .map_err(|_| anyhow::anyhow!("column {j} has no diagonal"))?;
            anyhow::ensure!(
                self.diag_idx[j] as usize == colptr[j] + d,
                "column {j}: diag_idx corrupt"
            );
            let ll = rows.len() - d - 1;
            anyhow::ensure!(self.l_len[j] as usize == ll, "column {j}: l_len corrupt");
            let (t0, t1) = (self.task_ptr[j] as usize, self.task_ptr[j + 1] as usize);
            anyhow::ensure!(
                t1 >= t0 && t1 - t0 == urow[j].len(),
                "column {j}: task count disagrees with the subcolumn view"
            );
            let lrows = &rows[d + 1..];
            for (s, &k) in urow[j].iter().enumerate() {
                let t = t0 + s;
                let k = k as usize;
                anyhow::ensure!(k < n, "task {t}: destination out of range");
                let (s_k, e_k) = (colptr[k], colptr[k + 1]);
                let m = self.mult_idx[t] as usize;
                anyhow::ensure!(
                    (s_k..e_k).contains(&m) && rowidx[m] == j,
                    "task {t}: multiplier index does not address As({j},{k})"
                );
                let off = self.dst_off[t] as usize;
                anyhow::ensure!(
                    off == expect_dst,
                    "task {t}: destination run is not contiguous"
                );
                anyhow::ensure!(
                    off + ll <= self.dst.len(),
                    "task {t}: destination run out of bounds"
                );
                for (i, &row) in lrows.iter().enumerate() {
                    let d_idx = self.dst[off + i] as usize;
                    anyhow::ensure!(
                        (s_k..e_k).contains(&d_idx) && rowidx[d_idx] == row,
                        "task {t}: destination {i} does not address As({row},{k})"
                    );
                }
                expect_dst += ll;
            }
        }
        anyhow::ensure!(
            expect_dst == self.dst.len(),
            "trailing destination entries beyond the last task"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::rightlook::upper_rows;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    #[test]
    fn build_validates_on_random_patterns() {
        let mut rng = Rng::new(0x5CA7);
        for trial in 0..6 {
            let n = rng.range(20, 150);
            let a = gen::netlist(n, 6, 10, 0.08, 2, 0.2, 7100 + trial);
            let f = symbolic_fill(&a).unwrap();
            let urow = upper_rows(&f);
            let sm = ScatterMap::build(&f.filled, &urow);
            sm.validate(&f.filled, &urow)
                .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
            assert_eq!(sm.num_tasks(), urow.iter().map(|u| u.len()).sum::<usize>());
            // every destination run length matches its source's L length
            let total: usize = (0..n)
                .map(|j| sm.l_len[j] as usize * urow[j].len())
                .sum();
            assert_eq!(sm.dst.len(), total);
        }
    }

    #[test]
    fn map_addresses_match_binary_search() {
        let a = gen::grid2d(12, 12, 3);
        let f = symbolic_fill(&a).unwrap();
        let urow = upper_rows(&f);
        let sm = ScatterMap::build(&f.filled, &urow);
        for j in 0..f.filled.ncols() {
            assert_eq!(
                sm.diag_idx[j] as usize,
                f.filled.entry_index(j, j).unwrap(),
                "column {j} diagonal"
            );
            for (s, &k) in urow[j].iter().enumerate() {
                let t = sm.task_ptr[j] as usize + s;
                assert_eq!(
                    sm.mult_idx[t] as usize,
                    f.filled.entry_index(j, k as usize).unwrap()
                );
            }
        }
    }

    #[test]
    fn validate_rejects_corruption() {
        let a = gen::netlist(80, 5, 8, 0.1, 2, 0.2, 99);
        let f = symbolic_fill(&a).unwrap();
        let urow = upper_rows(&f);
        let sm = ScatterMap::build(&f.filled, &urow);
        assert!(!sm.dst.is_empty(), "fixture must have MAC work");

        // a destination pointing at the wrong element
        let mut bad = sm.clone();
        let last = bad.dst.len() - 1;
        bad.dst[last] = bad.diag_idx[0];
        assert!(bad.validate(&f.filled, &urow).is_err());

        // a multiplier pointing at the wrong row
        let mut bad = sm.clone();
        bad.mult_idx[0] += 1;
        assert!(bad.validate(&f.filled, &urow).is_err());

        // truncated destination array
        let mut bad = sm.clone();
        bad.dst.pop();
        assert!(bad.validate(&f.filled, &urow).is_err());

        // and a mismatched pattern (different structure, honest map)
        let other = symbolic_fill(&gen::netlist(80, 5, 8, 0.1, 2, 0.2, 100)).unwrap();
        if other.filled.nnz() != f.filled.nnz() {
            assert!(sm.validate(&other.filled, &upper_rows(&other)).is_err());
        }
    }
}
