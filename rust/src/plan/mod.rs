//! The [`FactorPlan`]: a mode-annotated schedule IR shared by every backend.
//!
//! GLU3.0's second contribution — the three adaptive kernel modes selected
//! per level as the available parallelism changes (paper §III-B, Fig. 11) —
//! used to live inside the cycle simulator only: `gpusim::policy` picked a
//! mode per level while the real CPU engines executed every level the same
//! way and the PJRT runtime had no lowering target. This module makes the
//! adaptive schedule a first-class artifact instead:
//!
//! ```text
//! SymbolicFill + DepGraph + Policy + DeviceConfig
//!         │ levelize + annotate (once, at factor time)
//!         ▼
//!     FactorPlan ──► gpusim::executor   (costs the plan's levels)
//!         │      ──► numeric::parrl     (mode-adaptive worker-pool steps)
//!         │      ──► GluSolver::solve   (cached trisolve row schedules)
//!         └──────► runtime::lower_plan  (kernel-launch sequence, cached
//!                  on the plan and run by runtime::executor backends)
//! ```
//!
//! Per level the plan records the [`KernelMode`] (the paper's Eq. 4 +
//! stream-threshold decision, **the single source of truth** — both the
//! simulator's former `select_mode` call site and `Policy::mode_for` now
//! delegate here), the GPU [`ResourceBinding`] (blocks × warps or
//! stream-dispatch geometry), the CPU [`CpuAssignment`] the worker-pool
//! engine executes, and column work estimates. Sliced levels additionally
//! carry their MAC tasks grouped by destination column
//! ([`FactorPlan::dest_groups`]) so the ownership-aware engine can hand
//! whole destination groups to single owners and commit with plain
//! stores. The plan also carries the pattern-derived views every numeric
//! backend shares (subcolumn map, per-column work, the lazily built
//! [`ScatterMap`] that resolves every MAC position at pattern time, and —
//! lazily, on first multi-threaded solve — the triangular-solve row
//! schedules), so
//! [`crate::glu::GluSolver::refactor`] and the solves reuse it
//! allocation-free and [`crate::coordinator::SolverPool`] caches it with
//! the pattern-keyed symbolic state — a checkout hit never replans.
//!
//! [`FactorPlan`] is immutable after construction and cheap to clone (the
//! heavy state sits behind one `Arc`).

pub mod scatter;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::depend::{levelize, DepGraph, Levels};
use crate::gpusim::device::DeviceConfig;
use crate::gpusim::policy::Policy;
use crate::numeric::rightlook::upper_rows;
use crate::numeric::trisolve::TriangularSchedule;
use crate::symbolic::SymbolicFill;

pub use scatter::ScatterMap;

/// The three GPU kernel modes of GLU3.0 (paper Fig. 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Type A levels: one block per column, few warps per block
    /// (Eq. 4), one warp per subcolumn task.
    SmallBlock {
        /// Warps per block ∈ {2, 4, 8, 16}.
        warps_per_block: usize,
    },
    /// Type B levels: one block per column, 32 warps (1024 threads),
    /// one warp per subcolumn — the GLU1.0/2.0 kernel.
    LargeBlock,
    /// Type C levels: one kernel per column over 16 CUDA streams, one
    /// *block* (1024 threads) per subcolumn.
    Stream,
}

impl KernelMode {
    /// Short label for reports.
    pub fn label(&self) -> String {
        match self {
            KernelMode::SmallBlock { warps_per_block } => format!("small({warps_per_block}w)"),
            KernelMode::LargeBlock => "large".to_string(),
            KernelMode::Stream => "stream".to_string(),
        }
    }

    /// Level-type letter for Table III's distribution columns.
    pub fn level_type(&self) -> char {
        match self {
            KernelMode::SmallBlock { .. } => 'A',
            KernelMode::LargeBlock => 'B',
            KernelMode::Stream => 'C',
        }
    }
}

/// Select the raw GLU3.0 mode for a level (Eq. 4 + the stream threshold),
/// before any policy ablation gates.
pub fn select_mode(level_size: usize, stream_threshold: usize, device: &DeviceConfig) -> KernelMode {
    if level_size <= stream_threshold {
        return KernelMode::Stream;
    }
    let w = device.total_warps() / level_size.max(1);
    if w >= 32 {
        KernelMode::LargeBlock
    } else {
        // Round down to a power of two in {2, 4, 8, 16} (paper §III-B.1:
        // "grows from 2 to 4, 8, and eventually to 32").
        let w = w.max(2);
        let w = 1usize << (usize::BITS - 1 - w.leading_zeros());
        KernelMode::SmallBlock {
            warps_per_block: w.clamp(2, 16),
        }
    }
}

/// Kernel mode for a level of `level_size` columns under `policy` — the
/// deduplicated decision the simulator's `select_mode` call site and
/// `Policy::mode_for` both used to make independently.
pub fn mode_for(policy: &Policy, level_size: usize, device: &DeviceConfig) -> KernelMode {
    if !policy.adaptive {
        return KernelMode::LargeBlock;
    }
    let mode = select_mode(level_size, policy.stream_threshold, device);
    match mode {
        KernelMode::SmallBlock { .. } if !policy.enable_small => KernelMode::LargeBlock,
        KernelMode::Stream if !policy.enable_stream => KernelMode::LargeBlock,
        m => m,
    }
}

/// Static work description of one column: `l_len` L entries (= length of
/// every subcolumn update task) and `n_subcols` subcolumn tasks.
#[derive(Debug, Clone, Copy)]
pub struct ColumnWork {
    pub l_len: usize,
    pub n_subcols: usize,
}

impl ColumnWork {
    /// Flop estimate: the divide pass plus one fused multiply-subtract per
    /// L entry per subcolumn (Eq. 3).
    pub fn flops(&self) -> u64 {
        (self.l_len + 2 * self.l_len * self.n_subcols) as u64
    }
}

/// GPU resource binding of one level, derived from its mode and the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResourceBinding {
    /// Small/large-block modes: one block per column.
    Blocks {
        blocks: usize,
        warps_per_block: usize,
    },
    /// Stream mode: one kernel per column dispatched over CUDA streams,
    /// one max-occupancy block per subcolumn.
    Streams { streams: usize, kernels: usize },
}

/// How the CPU worker-pool engine executes one plan step — the
/// thread-chunk analogue of the GPU geometry. Decided *here*, never in the
/// engine: `numeric::parrl` only dispatches on what the plan says.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuAssignment {
    /// Column-parallel: deal the level's columns round-robin across
    /// workers (wide small-mode levels — many independent columns).
    InterleavedColumns,
    /// Task-parallel in two sub-phases: all divide phases (columns dealt
    /// round-robin), one barrier, then the flat `(column, subcolumn)` MAC
    /// task list dealt round-robin **source-major** (narrow large-mode
    /// levels — too few columns to feed every worker, but plenty of
    /// subcolumn tasks). Two workers may target the same destination
    /// column, so commits must be atomic (CAS). Kept only for sliced
    /// levels where one destination group dominates the level's MAC work
    /// and ownership would serialize it — see
    /// [`CpuAssignment::OwnedDestinations`].
    SubcolumnSlices,
    /// Task-parallel in two sub-phases like
    /// [`CpuAssignment::SubcolumnSlices`], but the MAC task list is
    /// grouped **by destination column** ([`FactorPlan::dest_groups`]) and
    /// whole groups are dealt to workers: one owner per destination column
    /// per level means plain (non-atomic) writes, and — because tasks
    /// within a group stay in ascending source order — results that are
    /// bit-identical to the simulator's serialization at *every* thread
    /// count, not just one. The default for sliced levels whenever no
    /// single destination group carries more than half the MAC work.
    OwnedDestinations,
    /// A run of consecutive singleton stream-mode levels executed as one
    /// sequential chain by a single worker with a single end-of-run
    /// rendezvous — batching the deep narrow tail's barriers away (plain
    /// writes: nothing else runs during the chain).
    ChainBatch,
}

/// One MAC task of a destination-ownership group: the source column and
/// the task's global id in the pattern's task enumeration — the same
/// enumeration [`ScatterMap`] uses, so `task` indexes straight into the
/// map's `mult_idx`/`dst_off` arrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacTaskRef {
    /// Source column `j`.
    pub src: u32,
    /// Global task id (`task_base[j] + position of k in urow[j]`).
    pub task: u32,
}

/// A sliced level's MAC tasks grouped by destination column, for
/// [`CpuAssignment::OwnedDestinations`]: group `g` spans
/// `tasks[group_ptr[g]..group_ptr[g+1]]`, every task in a group shares one
/// destination column, and tasks within a group are in ascending source
/// order (the simulator's serialization — per-element accumulation order
/// is therefore identical no matter which worker owns the group). Groups
/// are stored in descending estimated-work order so round-robin dealing
/// approximates longest-processing-time balance.
#[derive(Debug, Clone, Default)]
pub struct DestGroups {
    /// Flat task refs, grouped by destination.
    pub tasks: Vec<MacTaskRef>,
    /// Group boundaries into `tasks` (len `num_groups + 1`).
    pub group_ptr: Vec<u32>,
}

impl DestGroups {
    /// Number of destination groups.
    pub fn num_groups(&self) -> usize {
        self.group_ptr.len().saturating_sub(1)
    }

    /// The tasks of group `g`.
    pub fn group(&self, g: usize) -> &[MacTaskRef] {
        &self.tasks[self.group_ptr[g] as usize..self.group_ptr[g + 1] as usize]
    }
}

/// The ownership decision for a sliced level: destination grouping wins
/// unless a single destination group carries more than half the level's
/// MAC work — a dominant group would serialize on its one owner, while
/// source-major CAS slicing spreads even one destination's tasks across
/// the pool.
fn ownership_wins(max_group_flops: u64, total_flops: u64) -> bool {
    max_group_flops * 2 <= total_flops || total_flops == 0
}

/// Sort one level's MAC tasks by `(destination, source)` and compute the
/// per-destination group boundaries with their flop estimates — the data
/// the ownership decision needs, without materializing the groups.
/// Returns the sorted pairs, the `(flops, start, end)` boundaries, and the
/// largest-group / level-total MAC flop estimates.
#[allow(clippy::type_complexity)]
fn dest_task_bounds(
    cols: &[u32],
    urow: &[Vec<u32>],
    task_base: &[u32],
    col_work: &[ColumnWork],
) -> (Vec<(u32, MacTaskRef)>, Vec<(u64, usize, usize)>, u64, u64) {
    let mut pairs: Vec<(u32, MacTaskRef)> = Vec::new();
    for &j in cols {
        let ju = j as usize;
        for (s, &k) in urow[ju].iter().enumerate() {
            pairs.push((
                k,
                MacTaskRef {
                    src: j,
                    task: task_base[ju] + s as u32,
                },
            ));
        }
    }
    pairs.sort_unstable_by_key(|&(k, r)| (k, r.src));

    let mut bounds: Vec<(u64, usize, usize)> = Vec::new();
    let mut start = 0usize;
    let mut total = 0u64;
    let mut max = 0u64;
    while start < pairs.len() {
        let k = pairs[start].0;
        let mut end = start;
        let mut flops = 0u64;
        while end < pairs.len() && pairs[end].0 == k {
            flops += col_work[pairs[end].1.src as usize].l_len as u64;
            end += 1;
        }
        total += flops;
        max = max.max(flops);
        bounds.push((flops, start, end));
        start = end;
    }
    (pairs, bounds, max, total)
}

/// Materialize the destination-ownership groups (descending work, ascending
/// source within each group) — only called once ownership has won, so
/// losing levels never pay for the copy or the second sort.
fn build_dest_groups(
    pairs: &[(u32, MacTaskRef)],
    mut bounds: Vec<(u64, usize, usize)>,
) -> DestGroups {
    bounds.sort_unstable_by_key(|&(flops, start, _)| (std::cmp::Reverse(flops), start));
    let mut groups = DestGroups {
        tasks: Vec::with_capacity(pairs.len()),
        group_ptr: Vec::with_capacity(bounds.len() + 1),
    };
    groups.group_ptr.push(0);
    for &(_, s, e) in &bounds {
        groups.tasks.extend(pairs[s..e].iter().map(|&(_, r)| r));
        groups.group_ptr.push(groups.tasks.len() as u32);
    }
    groups
}

/// One step of the CPU execution schedule: a contiguous range of levels
/// sharing one assignment strategy (`level_count > 1` only for chain
/// batches).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuStep {
    pub first_level: usize,
    pub level_count: usize,
    pub assignment: CpuAssignment,
}

/// Per-level annotations of the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelPlan {
    /// Level index in schedule order.
    pub index: usize,
    /// Columns in the level.
    pub columns: usize,
    /// Kernel mode ([`mode_for`] — the single source of truth).
    pub mode: KernelMode,
    /// GPU launch geometry.
    pub binding: ResourceBinding,
    /// CPU worker-pool strategy.
    pub assignment: CpuAssignment,
    /// Max subcolumn tasks over the level's columns.
    pub max_subcols: usize,
    /// Total subcolumn tasks in the level.
    pub total_subcols: usize,
    /// Max L length over the level's columns (subcolumn task length).
    pub max_l_len: usize,
    /// Work estimate (sum of [`ColumnWork::flops`]).
    pub work_flops: u64,
}

#[derive(Debug)]
struct PlanInner {
    n: usize,
    policy: Policy,
    device: DeviceConfig,
    levels: Levels,
    level_plans: Vec<LevelPlan>,
    cpu_steps: Vec<CpuStep>,
    col_work: Vec<ColumnWork>,
    urow: Vec<Vec<u32>>,
    /// Per level: the destination-ownership groups (empty unless the
    /// level's assignment is [`CpuAssignment::OwnedDestinations`]).
    dest_groups: Vec<DestGroups>,
    /// MAC element commits per factorization that the ownership/chain
    /// strategies perform with plain stores instead of CAS loops.
    atomic_commits_avoided: u64,
    /// Levels whose ownership analysis was transferred from a base plan
    /// ([`FactorPlan::from_levels_delta`]; 0 for cold builds).
    reused_levels: usize,
    /// The pattern-time [`ScatterMap`], built lazily on first numeric use
    /// (only the indexed right-looking engines consume it) and cached with
    /// the plan — a pooled solver therefore never rebuilds it on a
    /// checkout hit.
    scatter: OnceLock<ScatterMap>,
    /// How many times the scatter map has been built (0 or 1 — exposed so
    /// the service layer can assert hits never rebuild).
    scatter_builds: AtomicUsize,
    /// The lowered [`crate::runtime::LaunchSchedule`], built lazily on the
    /// schedule engine's first run and cached with the plan — like the
    /// scatter map, a pooled solver's checkout hit never re-lowers.
    schedule: OnceLock<crate::runtime::LaunchSchedule>,
    /// How many times the schedule has been lowered (0 or 1).
    schedule_builds: AtomicUsize,
    /// Row-oriented L/U level schedules, built lazily on first use: the
    /// `O(nnz)` row views would be dead weight in solvers that only ever
    /// take the sequential solve path (single-threaded engines, narrow
    /// schedules), so the plan stays immutable but pays for them only when
    /// a parallel solve actually asks.
    trisolve: OnceLock<TriangularSchedule>,
    /// Cached [`TriangularSchedule::parallel_worthwhile`] verdict. Kept
    /// separately so a *narrow* pattern's probe retains only this bool —
    /// the transient schedule built to answer it is dropped, not parked in
    /// every cached solver.
    trisolve_worthwhile: OnceLock<bool>,
    /// Cached per-pattern trisolve variant choice (see
    /// [`TriangularSchedule::choose_variant`]). Pattern-only, so one
    /// verdict serves every solve against this plan.
    trisolve_variant: OnceLock<crate::numeric::trisolve::TrisolveVariant>,
}

/// The mode-annotated factorization schedule — see the module docs.
#[derive(Debug, Clone)]
pub struct FactorPlan {
    inner: Arc<PlanInner>,
}

impl FactorPlan {
    /// Build the plan from a dependency graph (levelizes internally).
    pub fn build(
        sym: &SymbolicFill,
        deps: &DepGraph,
        policy: &Policy,
        device: &DeviceConfig,
    ) -> FactorPlan {
        FactorPlan::from_levels(sym, levelize(deps), policy, device)
    }

    /// Build the plan from an already-levelized schedule (the solver path,
    /// where levelization is timed as its own preprocessing stage).
    pub fn from_levels(
        sym: &SymbolicFill,
        levels: Levels,
        policy: &Policy,
        device: &DeviceConfig,
    ) -> FactorPlan {
        FactorPlan::build_plan_impl(sym, levels, policy, device, None)
    }

    /// [`FactorPlan::from_levels`] against a cached base plan (the
    /// incremental-patch path): a level whose column list and per-column
    /// pattern data (`urow`, work, global task ids) are unchanged from the
    /// base reuses the base's ownership decision and cloned destination
    /// groups instead of re-sorting its MAC tasks. The result is identical
    /// to `from_levels` on the same inputs (the reuse conditions pin every
    /// input of the per-level computation); [`FactorPlan::reused_levels`]
    /// reports how much was skipped.
    pub fn from_levels_delta(
        sym: &SymbolicFill,
        levels: Levels,
        policy: &Policy,
        device: &DeviceConfig,
        base: &FactorPlan,
    ) -> FactorPlan {
        FactorPlan::build_plan_impl(sym, levels, policy, device, Some(base))
    }

    /// Shared construction; `base` enables the per-level reuse fast path.
    fn build_plan_impl(
        sym: &SymbolicFill,
        levels: Levels,
        policy: &Policy,
        device: &DeviceConfig,
        base: Option<&FactorPlan>,
    ) -> FactorPlan {
        let n = sym.filled.ncols();
        let urow = upper_rows(sym);
        let col_work: Vec<ColumnWork> = (0..n)
            .map(|j| {
                let (rows, _) = sym.filled.col(j);
                ColumnWork {
                    l_len: rows.len() - rows.partition_point(|&r| r <= j),
                    n_subcols: urow[j].len(),
                }
            })
            .collect();

        let mut level_plans = Vec::with_capacity(levels.num_levels());
        for (index, cols) in levels.levels.iter().enumerate() {
            let mode = mode_for(policy, cols.len(), device);
            let mut max_subcols = 0usize;
            let mut total_subcols = 0usize;
            let mut max_l_len = 0usize;
            let mut work_flops = 0u64;
            for &j in cols {
                let cw = col_work[j as usize];
                max_subcols = max_subcols.max(cw.n_subcols);
                total_subcols += cw.n_subcols;
                max_l_len = max_l_len.max(cw.l_len);
                work_flops += cw.flops();
            }
            let binding = match mode {
                KernelMode::SmallBlock { warps_per_block } => ResourceBinding::Blocks {
                    blocks: cols.len(),
                    warps_per_block,
                },
                KernelMode::LargeBlock => ResourceBinding::Blocks {
                    blocks: cols.len(),
                    warps_per_block: device.max_threads_per_block / device.warp_size,
                },
                KernelMode::Stream => ResourceBinding::Streams {
                    streams: device.num_streams,
                    kernels: cols.len(),
                },
            };
            // CPU strategy: wide levels are column-parallel; narrow levels
            // slice their subcolumn tasks; singleton stream tails are
            // chain-batched below.
            let assignment = match mode {
                KernelMode::SmallBlock { .. } => CpuAssignment::InterleavedColumns,
                KernelMode::LargeBlock | KernelMode::Stream => CpuAssignment::SubcolumnSlices,
            };
            level_plans.push(LevelPlan {
                index,
                columns: cols.len(),
                mode,
                binding,
                assignment,
                max_subcols,
                total_subcols,
                max_l_len,
                work_flops,
            });
        }

        // Fold maximal runs of singleton stream levels into chain batches
        // (one rendezvous per run instead of one per level).
        let mut li = 0usize;
        while li < level_plans.len() {
            let chainable = |lp: &LevelPlan| lp.mode == KernelMode::Stream && lp.columns == 1;
            if chainable(&level_plans[li]) {
                let mut end = li + 1;
                while end < level_plans.len() && chainable(&level_plans[end]) {
                    end += 1;
                }
                for lp in &mut level_plans[li..end] {
                    lp.assignment = CpuAssignment::ChainBatch;
                }
                li = end;
            } else {
                li += 1;
            }
        }

        // Ownership pass: for every remaining sliced level, group its MAC
        // tasks by destination column and hand the level to the atomic-free
        // ownership strategy unless one destination group dominates (see
        // `ownership_wins`). Chain batches run single-worker, so their
        // commits are plain stores too — both count toward the
        // atomic-commits-avoided estimate.
        let task_base: Vec<u32> = {
            let mut base = Vec::with_capacity(n + 1);
            let mut acc = 0u32;
            for u in &urow {
                base.push(acc);
                acc += u.len() as u32;
            }
            base
        };
        let mac_elems = |cols: &[u32]| -> u64 {
            cols.iter()
                .map(|&j| {
                    let cw = col_work[j as usize];
                    (cw.l_len * cw.n_subcols) as u64
                })
                .sum()
        };
        // Incremental reuse: a level transfers the base plan's ownership
        // decision (and its materialized groups) verbatim when its column
        // list and every member column's `urow` slice, work description,
        // and *global* task id are unchanged — exactly the inputs of
        // `dest_task_bounds` + `ownership_wins`. Task ids are prefix sums
        // over all earlier columns, so a structural change shifts them for
        // every later column and reuse stops there.
        let base_inner = base.map(|b| b.inner.as_ref());
        let base_task_base: Vec<u32> = base_inner.map_or_else(Vec::new, |b| {
            let mut acc = 0u32;
            b.urow
                .iter()
                .map(|u| {
                    let t = acc;
                    acc += u.len() as u32;
                    t
                })
                .collect()
        });
        let level_reusable = |index: usize, cols: &[u32]| -> Option<&LevelPlan> {
            let b = base_inner?;
            let base_lp = b.level_plans.get(index)?;
            if !matches!(
                base_lp.assignment,
                CpuAssignment::SubcolumnSlices | CpuAssignment::OwnedDestinations
            ) || b.levels.levels.get(index).map(Vec::as_slice) != Some(cols)
            {
                return None;
            }
            cols.iter()
                .all(|&j| {
                    let ju = j as usize;
                    urow[ju] == b.urow[ju]
                        && col_work[ju].l_len == b.col_work[ju].l_len
                        && col_work[ju].n_subcols == b.col_work[ju].n_subcols
                        && task_base[ju] == base_task_base[ju]
                })
                .then_some(base_lp)
        };
        let mut reused_levels = 0usize;
        let mut dest_groups: Vec<DestGroups> = vec![DestGroups::default(); level_plans.len()];
        let mut atomic_commits_avoided = 0u64;
        for lp in &mut level_plans {
            let cols = &levels.levels[lp.index];
            match lp.assignment {
                CpuAssignment::SubcolumnSlices => {
                    if let Some(base_lp) = level_reusable(lp.index, cols) {
                        lp.assignment = base_lp.assignment;
                        if base_lp.assignment == CpuAssignment::OwnedDestinations {
                            atomic_commits_avoided += mac_elems(cols);
                            dest_groups[lp.index] =
                                base_inner.expect("reusable implies base").dest_groups[lp.index]
                                    .clone();
                        }
                        reused_levels += 1;
                    } else {
                        let (pairs, bounds, max_flops, total_flops) =
                            dest_task_bounds(cols, &urow, &task_base, &col_work);
                        if ownership_wins(max_flops, total_flops) {
                            lp.assignment = CpuAssignment::OwnedDestinations;
                            atomic_commits_avoided += mac_elems(cols);
                            dest_groups[lp.index] = build_dest_groups(&pairs, bounds);
                        }
                    }
                }
                CpuAssignment::ChainBatch => atomic_commits_avoided += mac_elems(cols),
                _ => {}
            }
        }

        // Group the final assignments into execution steps: one step per
        // level, except chain runs which fold into one multi-level step.
        let mut cpu_steps = Vec::new();
        let mut li = 0usize;
        while li < level_plans.len() {
            let assignment = level_plans[li].assignment;
            let mut end = li + 1;
            if assignment == CpuAssignment::ChainBatch {
                while end < level_plans.len()
                    && level_plans[end].assignment == CpuAssignment::ChainBatch
                {
                    end += 1;
                }
            }
            cpu_steps.push(CpuStep {
                first_level: li,
                level_count: end - li,
                assignment,
            });
            li = end;
        }

        FactorPlan {
            inner: Arc::new(PlanInner {
                n,
                policy: policy.clone(),
                device: device.clone(),
                levels,
                level_plans,
                cpu_steps,
                col_work,
                urow,
                dest_groups,
                atomic_commits_avoided,
                reused_levels,
                scatter: OnceLock::new(),
                scatter_builds: AtomicUsize::new(0),
                schedule: OnceLock::new(),
                schedule_builds: AtomicUsize::new(0),
                trisolve: OnceLock::new(),
                trisolve_worthwhile: OnceLock::new(),
                trisolve_variant: OnceLock::new(),
            }),
        }
    }

    /// Matrix dimension the plan was built for.
    pub fn n(&self) -> usize {
        self.inner.n
    }

    /// Number of levels.
    pub fn num_levels(&self) -> usize {
        self.inner.levels.num_levels()
    }

    /// The level schedule the plan annotates.
    pub fn levels(&self) -> &Levels {
        &self.inner.levels
    }

    /// Per-level annotations, in schedule order.
    pub fn level_plans(&self) -> &[LevelPlan] {
        &self.inner.level_plans
    }

    /// One level's annotations.
    pub fn level_plan(&self, level: usize) -> &LevelPlan {
        &self.inner.level_plans[level]
    }

    /// The CPU execution steps (levels grouped by assignment strategy).
    pub fn cpu_steps(&self) -> &[CpuStep] {
        &self.inner.cpu_steps
    }

    /// Per-column work descriptions, indexed by column.
    pub fn col_work(&self) -> &[ColumnWork] {
        &self.inner.col_work
    }

    /// Subcolumn map: for each row `j`, the columns `k > j` with
    /// `As(j,k) ≠ 0` (shared by every right-looking backend).
    pub fn urow(&self) -> &[Vec<u32>] {
        &self.inner.urow
    }

    /// The destination-ownership groups of one level — non-empty exactly
    /// when the level's assignment is
    /// [`CpuAssignment::OwnedDestinations`].
    pub fn dest_groups(&self, level: usize) -> &DestGroups {
        &self.inner.dest_groups[level]
    }

    /// The pattern-time [`ScatterMap`] for this pattern, built on first
    /// use and cached in the plan (a pooled solver's checkout hits never
    /// rebuild it — [`FactorPlan::scatter_builds`] proves it). `filled`
    /// must carry the filled pattern the plan was built from; debug builds
    /// validate the freshly built map against it once
    /// ([`ScatterMap::validate`]).
    pub fn scatter(&self, filled: &crate::sparse::Csc) -> &ScatterMap {
        debug_assert_eq!(filled.ncols(), self.inner.n, "pattern mismatch");
        self.inner.scatter.get_or_init(|| {
            self.inner.scatter_builds.fetch_add(1, Ordering::Relaxed);
            let sm = ScatterMap::build(filled, &self.inner.urow);
            #[cfg(debug_assertions)]
            sm.validate(filled, &self.inner.urow)
                .expect("freshly built scatter map must validate");
            sm
        })
    }

    /// How many times the scatter map has been built for this plan (0
    /// until a scatter-consuming engine runs, 1 ever after).
    pub fn scatter_builds(&self) -> usize {
        self.inner.scatter_builds.load(Ordering::Relaxed)
    }

    /// The lowered kernel-launch schedule for this plan
    /// ([`crate::runtime::lower_plan`]), built on first use and cached —
    /// the schedule engine re-executes the cached sequence on every
    /// refactor, and a pooled solver's checkout hits never re-lower
    /// ([`FactorPlan::schedule_builds`] proves it).
    pub fn launch_schedule(&self) -> &crate::runtime::LaunchSchedule {
        self.inner.schedule.get_or_init(|| {
            self.inner.schedule_builds.fetch_add(1, Ordering::Relaxed);
            crate::runtime::lower_plan(self)
        })
    }

    /// How many times the launch schedule has been lowered for this plan
    /// (0 until the schedule engine runs, 1 ever after).
    pub fn schedule_builds(&self) -> usize {
        self.inner.schedule_builds.load(Ordering::Relaxed)
    }

    /// MAC element commits per factorization executed with plain stores
    /// instead of CAS loops, thanks to destination ownership and chain
    /// batching.
    pub fn atomic_commits_avoided(&self) -> u64 {
        self.inner.atomic_commits_avoided
    }

    /// Levels whose ownership analysis was transferred from a base plan by
    /// [`FactorPlan::from_levels_delta`] — 0 for cold builds.
    pub fn reused_levels(&self) -> usize {
        self.inner.reused_levels
    }

    /// The triangular-solve row schedules for this pattern, built on first
    /// use and cached in the plan. `filled` must be the filled pattern the
    /// plan was built from (the caller keeps it — storing a pattern copy
    /// here would cost the same `O(nnz)` the lazy build avoids).
    pub fn trisolve(&self, filled: &crate::sparse::Csc) -> &TriangularSchedule {
        debug_assert_eq!(filled.ncols(), self.inner.n, "pattern mismatch");
        self.inner
            .trisolve
            .get_or_init(|| TriangularSchedule::build(filled))
    }

    /// Whether the level-parallel triangular solves are worth running on
    /// this pattern (see [`TriangularSchedule::parallel_worthwhile`]).
    /// The first probe builds the schedules; they are retained only on a
    /// `true` verdict — a narrow pattern keeps the cached bool and drops
    /// the `O(nnz)` row views (the pre-plan behavior).
    pub fn parallel_trisolve(&self, filled: &crate::sparse::Csc) -> bool {
        *self.inner.trisolve_worthwhile.get_or_init(|| {
            if let Some(ts) = self.inner.trisolve.get() {
                return ts.parallel_worthwhile();
            }
            let ts = TriangularSchedule::build(filled);
            let worthwhile = ts.parallel_worthwhile();
            if worthwhile {
                // Another racing forced build may have set it first; either
                // value is equivalent (pattern-only, deterministic).
                let _ = self.inner.trisolve.set(ts);
            }
            worthwhile
        })
    }

    /// The trisolve execution variant for this pattern, chosen once from
    /// the level-width statistics (see
    /// [`TriangularSchedule::choose_variant`]): `Sequential` when the
    /// parallel walks are not worthwhile, `SyncFree` for deep narrow
    /// level structures where barrier overhead dominates, `LevelSet`
    /// otherwise. Probing forces the schedule build; the schedule is
    /// retained only for non-sequential verdicts (mirroring
    /// [`FactorPlan::parallel_trisolve`]'s retention rule).
    pub fn trisolve_variant(
        &self,
        filled: &crate::sparse::Csc,
    ) -> crate::numeric::trisolve::TrisolveVariant {
        use crate::numeric::trisolve::TrisolveVariant;
        *self.inner.trisolve_variant.get_or_init(|| {
            if let Some(ts) = self.inner.trisolve.get() {
                return ts.choose_variant();
            }
            let ts = TriangularSchedule::build(filled);
            let variant = ts.choose_variant();
            if variant != TrisolveVariant::Sequential {
                let _ = self.inner.trisolve.set(ts);
            }
            variant
        })
    }

    /// The policy the plan was annotated under.
    pub fn policy(&self) -> &Policy {
        &self.inner.policy
    }

    /// The device model the plan was annotated under.
    pub fn device(&self) -> &DeviceConfig {
        &self.inner.device
    }

    /// Count of levels by mode family `(small, large, stream)` — the
    /// Table III A/B/C distribution, now answerable without running the
    /// simulator.
    pub fn mode_histogram(&self) -> (usize, usize, usize) {
        let mut dist = (0, 0, 0);
        for lp in &self.inner.level_plans {
            match lp.mode.level_type() {
                'A' => dist.0 += 1,
                'B' => dist.1 += 1,
                _ => dist.2 += 1,
            }
        }
        dist
    }

    /// Total estimated factorization flops across all levels.
    pub fn total_work(&self) -> u64 {
        self.inner.level_plans.iter().map(|lp| lp.work_flops).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::glu3;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    fn amd_grid(nx: usize, ny: usize, seed: u64) -> SymbolicFill {
        let g = gen::grid2d(nx, ny, seed);
        let p = crate::order::amd::amd_order(&g).unwrap();
        symbolic_fill(&g.permute(p.as_scatter(), p.as_scatter())).unwrap()
    }

    #[test]
    fn mode_selection_follows_eq4() {
        let d = DeviceConfig::titan_x();
        // level size <= 16 -> stream
        assert_eq!(select_mode(1, 16, &d), KernelMode::Stream);
        assert_eq!(select_mode(16, 16, &d), KernelMode::Stream);
        // 1536 total warps: level 48 -> W = 32 -> large
        assert_eq!(select_mode(48, 16, &d), KernelMode::LargeBlock);
        assert_eq!(select_mode(17, 16, &d), KernelMode::LargeBlock);
        // level 100 -> W = 15 -> small(8); level 1000 -> W = 1 -> small(2)
        assert_eq!(
            select_mode(100, 16, &d),
            KernelMode::SmallBlock { warps_per_block: 8 }
        );
        assert_eq!(
            select_mode(1000, 16, &d),
            KernelMode::SmallBlock { warps_per_block: 2 }
        );
    }

    /// The dedupe regression test: the plan's per-level mode agrees with
    /// both former call sites — `Policy::mode_for` (the policy layer) and
    /// the raw `select_mode` the simulator used to call inline — on random
    /// AMD-ordered grids under every policy.
    #[test]
    fn plan_mode_agrees_with_former_call_sites() {
        let d = DeviceConfig::titan_x();
        let mut rng = Rng::new(0x91A7);
        for trial in 0..4 {
            let nx = rng.range(10, 24);
            let ny = rng.range(10, 24);
            let sym = amd_grid(nx, ny, 40 + trial);
            let deps = glu3::detect(&sym.filled);
            for policy in [
                Policy::glu3(),
                Policy::glu2_fixed(),
                Policy::glu3_no_small(),
                Policy::glu3_no_stream(),
                Policy::glu3_with_threshold(4),
                Policy::lee_enhanced(),
            ] {
                let plan = FactorPlan::build(&sym, &deps, &policy, &d);
                assert_eq!(plan.num_levels(), plan.level_plans().len());
                for lp in plan.level_plans() {
                    let size = plan.levels().levels[lp.index].len();
                    assert_eq!(size, lp.columns);
                    // former call site 1: the policy layer
                    assert_eq!(
                        lp.mode,
                        policy.mode_for(size, &d),
                        "trial {trial} policy {} level {}",
                        policy.name,
                        lp.index
                    );
                    // former call site 2: the simulator's raw Eq. 4 call
                    // (only comparable when no ablation gate intervenes)
                    if policy == Policy::glu3() {
                        assert_eq!(lp.mode, select_mode(size, 16, &d));
                    }
                }
            }
        }
    }

    #[test]
    fn plan_annotations_are_consistent() {
        let sym = amd_grid(20, 20, 7);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());

        // levels partition the columns, and the per-level aggregates match
        // a direct recomputation from the column work table
        let total_cols: usize = plan.level_plans().iter().map(|lp| lp.columns).sum();
        assert_eq!(total_cols, plan.n());
        for lp in plan.level_plans() {
            let cols = &plan.levels().levels[lp.index];
            let max_sub = cols
                .iter()
                .map(|&j| plan.col_work()[j as usize].n_subcols)
                .max()
                .unwrap_or(0);
            assert_eq!(max_sub, lp.max_subcols);
            let flops: u64 = cols
                .iter()
                .map(|&j| plan.col_work()[j as usize].flops())
                .sum();
            assert_eq!(flops, lp.work_flops);
            // binding geometry mirrors the mode
            match (lp.mode, lp.binding) {
                (KernelMode::SmallBlock { warps_per_block }, ResourceBinding::Blocks { blocks, warps_per_block: w }) => {
                    assert_eq!(blocks, lp.columns);
                    assert_eq!(w, warps_per_block);
                }
                (KernelMode::LargeBlock, ResourceBinding::Blocks { blocks, warps_per_block }) => {
                    assert_eq!(blocks, lp.columns);
                    assert_eq!(warps_per_block, 32);
                }
                (KernelMode::Stream, ResourceBinding::Streams { streams, kernels }) => {
                    assert_eq!(streams, 16);
                    assert_eq!(kernels, lp.columns);
                }
                (m, b) => panic!("mode {m:?} bound to {b:?}"),
            }
        }
        let (a, b, c) = plan.mode_histogram();
        assert_eq!(a + b + c, plan.num_levels());
        assert!(plan.total_work() > 0);
    }

    #[test]
    fn cpu_steps_cover_levels_and_batch_singleton_tails() {
        let sym = amd_grid(24, 24, 3);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());

        // steps tile the level range exactly, in order
        let mut next = 0usize;
        for step in plan.cpu_steps() {
            assert_eq!(step.first_level, next);
            assert!(step.level_count >= 1);
            if step.assignment != CpuAssignment::ChainBatch {
                assert_eq!(step.level_count, 1);
            }
            for lp in &plan.level_plans()[step.first_level..step.first_level + step.level_count] {
                assert_eq!(lp.assignment, step.assignment);
                if step.assignment == CpuAssignment::ChainBatch {
                    assert_eq!(lp.columns, 1);
                    assert_eq!(lp.mode, KernelMode::Stream);
                }
            }
            next = step.first_level + step.level_count;
        }
        assert_eq!(next, plan.num_levels());

        // an AMD mesh tail ends in consecutive singleton stream levels —
        // they must fold into a multi-level chain batch
        let batched = plan
            .cpu_steps()
            .iter()
            .any(|s| s.assignment == CpuAssignment::ChainBatch && s.level_count > 1);
        assert!(batched, "singleton stream tail must be chain-batched");

        // wide early levels are column-parallel
        assert_eq!(
            plan.level_plans()[0].assignment,
            CpuAssignment::InterleavedColumns
        );
    }

    #[test]
    fn ownership_decision_rule() {
        // balanced groups -> ownership; a dominant group -> CAS slicing
        assert!(ownership_wins(5, 10));
        assert!(ownership_wins(1, 100));
        assert!(!ownership_wins(6, 10));
        assert!(!ownership_wins(10, 10));
        // a level with no MAC work needs no atomics either way
        assert!(ownership_wins(0, 0));
    }

    /// Sliced levels on an AMD mesh get destination-ownership groups that
    /// exactly partition the level's MAC tasks: one destination per group,
    /// ascending source within a group, task ids matching the pattern's
    /// global task enumeration, groups in descending work order.
    #[test]
    fn ownership_groups_partition_sliced_levels() {
        let sym = amd_grid(24, 24, 3);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());
        let urow = plan.urow();
        let task_base: Vec<u32> = {
            let mut base = Vec::new();
            let mut acc = 0u32;
            for u in urow {
                base.push(acc);
                acc += u.len() as u32;
            }
            base
        };

        let mut owned_levels = 0usize;
        for lp in plan.level_plans() {
            let groups = plan.dest_groups(lp.index);
            if lp.assignment != CpuAssignment::OwnedDestinations {
                assert_eq!(groups.num_groups(), 0, "level {}", lp.index);
                continue;
            }
            owned_levels += 1;
            let cols = &plan.levels().levels[lp.index];
            let expected_tasks: usize = cols.iter().map(|&j| urow[j as usize].len()).sum();
            assert_eq!(groups.tasks.len(), expected_tasks, "level {}", lp.index);

            let level_cols: std::collections::HashSet<u32> = cols.iter().copied().collect();
            let mut seen = std::collections::HashSet::new();
            let mut prev_flops = u64::MAX;
            for g in 0..groups.num_groups() {
                let tasks = groups.group(g);
                assert!(!tasks.is_empty());
                // one destination per group, never a same-level column
                let s = (tasks[0].task - task_base[tasks[0].src as usize]) as usize;
                let dest = urow[tasks[0].src as usize][s];
                assert!(!level_cols.contains(&dest), "MAC target inside its own level");
                let mut flops = 0u64;
                for w in tasks.windows(2) {
                    assert!(w[0].src < w[1].src, "group not in ascending source order");
                }
                for t in tasks {
                    assert!(level_cols.contains(&t.src), "task source outside the level");
                    let s = (t.task - task_base[t.src as usize]) as usize;
                    assert_eq!(urow[t.src as usize][s], dest, "mixed destinations in a group");
                    assert!(seen.insert(t.task), "task dealt twice");
                    flops += plan.col_work()[t.src as usize].l_len as u64;
                }
                assert!(flops <= prev_flops, "groups not in descending work order");
                prev_flops = flops;
            }
        }
        assert!(owned_levels > 0, "mesh must produce ownership levels");
    }

    /// A level whose MAC tasks all target one destination column keeps the
    /// source-major CAS slicing — handing the single group to one owner
    /// would serialize the level.
    #[test]
    fn dominant_destination_keeps_cas_slicing() {
        use crate::sparse::Coo;
        // Arrow matrix: columns 0..m are independent (level 0), each with
        // one L entry in row m and one subcolumn m — a single dominant
        // destination.
        let m = 8usize;
        let mut coo = Coo::new(m + 1, m + 1);
        for j in 0..=m {
            coo.push(j, j, 4.0);
        }
        for j in 0..m {
            coo.push(m, j, -1.0);
            coo.push(j, m, -1.0);
        }
        let sym = crate::symbolic::symbolic_fill(&coo.to_csc()).unwrap();
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());
        let lp0 = &plan.level_plans()[0];
        assert_eq!(lp0.columns, m);
        assert_eq!(
            lp0.assignment,
            CpuAssignment::SubcolumnSlices,
            "dominant single destination must keep the CAS path"
        );
        assert_eq!(plan.dest_groups(0).num_groups(), 0);
    }

    /// The atomic-commits-avoided estimate equals a direct recomputation
    /// over the ownership/chain levels.
    #[test]
    fn atomic_commits_avoided_matches_recomputation() {
        let sym = amd_grid(20, 20, 5);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());
        let want: u64 = plan
            .level_plans()
            .iter()
            .filter(|lp| {
                matches!(
                    lp.assignment,
                    CpuAssignment::OwnedDestinations | CpuAssignment::ChainBatch
                )
            })
            .map(|lp| {
                plan.levels().levels[lp.index]
                    .iter()
                    .map(|&j| {
                        let cw = plan.col_work()[j as usize];
                        (cw.l_len * cw.n_subcols) as u64
                    })
                    .sum::<u64>()
            })
            .sum();
        assert_eq!(plan.atomic_commits_avoided(), want);
        assert!(want > 0, "mesh must avoid some atomic commits");
    }

    /// The scatter map is built lazily, exactly once, and cached in the
    /// plan (clones share it).
    #[test]
    fn scatter_map_builds_once_and_is_shared() {
        let sym = amd_grid(12, 12, 9);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());
        assert_eq!(plan.scatter_builds(), 0, "lazy: no build until first use");
        let clone = plan.clone();
        let a = plan.scatter(&sym.filled) as *const ScatterMap;
        let b = clone.scatter(&sym.filled) as *const ScatterMap;
        assert_eq!(a, b, "clones share one cached map");
        assert_eq!(plan.scatter_builds(), 1);
        assert_eq!(clone.scatter_builds(), 1);
    }

    /// The launch schedule is lowered lazily, exactly once, and cached in
    /// the plan (clones share it) — the same contract as the scatter map.
    #[test]
    fn launch_schedule_lowers_once_and_is_shared() {
        let sym = amd_grid(12, 12, 4);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());
        assert_eq!(plan.schedule_builds(), 0, "lazy: no lowering until first use");
        let clone = plan.clone();
        let a = plan.launch_schedule() as *const crate::runtime::LaunchSchedule;
        let b = clone.launch_schedule() as *const crate::runtime::LaunchSchedule;
        assert_eq!(a, b, "clones share one cached schedule");
        assert_eq!(plan.schedule_builds(), 1);
        assert_eq!(clone.schedule_builds(), 1);
        assert_eq!(plan.launch_schedule().launches.len(), plan.num_levels());
    }

    #[test]
    fn plan_clone_is_shallow() {
        let sym = amd_grid(12, 12, 1);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());
        let clone = plan.clone();
        // same backing allocation — cloning a cached plan is free
        assert!(std::ptr::eq(plan.urow(), clone.urow()));
        assert!(std::ptr::eq(plan.levels(), clone.levels()));
    }

    fn assert_plans_equal(a: &FactorPlan, b: &FactorPlan) {
        assert_eq!(a.level_plans(), b.level_plans());
        assert_eq!(a.cpu_steps(), b.cpu_steps());
        assert_eq!(a.atomic_commits_avoided(), b.atomic_commits_avoided());
        assert_eq!(a.num_levels(), b.num_levels());
        for lvl in 0..a.num_levels() {
            assert_eq!(a.dest_groups(lvl).tasks, b.dest_groups(lvl).tasks);
            assert_eq!(a.dest_groups(lvl).group_ptr, b.dest_groups(lvl).group_ptr);
        }
    }

    /// `from_levels_delta` is identical to a cold `from_levels` build no
    /// matter the base — full reuse against an identical base, zero reuse
    /// against an unrelated one, bit-identical annotations either way.
    #[test]
    fn delta_build_matches_cold_build() {
        let policy = Policy::glu3();
        let d = DeviceConfig::titan_x();
        let sym = amd_grid(12, 12, 3);
        let deps = glu3::detect(&sym.filled);
        let levels = crate::depend::levelize(&deps);
        let cold = FactorPlan::from_levels(&sym, levels.clone(), &policy, &d);
        assert_eq!(cold.reused_levels(), 0);

        let patched = FactorPlan::from_levels_delta(&sym, levels.clone(), &policy, &d, &cold);
        assert_plans_equal(&cold, &patched);
        assert!(patched.reused_levels() > 0, "identical base must reuse");

        let other_sym = amd_grid(9, 7, 1);
        let odeps = glu3::detect(&other_sym.filled);
        let obase = FactorPlan::from_levels(
            &other_sym,
            crate::depend::levelize(&odeps),
            &policy,
            &d,
        );
        let cross = FactorPlan::from_levels_delta(&sym, levels, &policy, &d, &obase);
        assert_plans_equal(&cold, &cross);
    }
}
