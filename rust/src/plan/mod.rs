//! The [`FactorPlan`]: a mode-annotated schedule IR shared by every backend.
//!
//! GLU3.0's second contribution — the three adaptive kernel modes selected
//! per level as the available parallelism changes (paper §III-B, Fig. 11) —
//! used to live inside the cycle simulator only: `gpusim::policy` picked a
//! mode per level while the real CPU engines executed every level the same
//! way and the PJRT runtime had no lowering target. This module makes the
//! adaptive schedule a first-class artifact instead:
//!
//! ```text
//! SymbolicFill + DepGraph + Policy + DeviceConfig
//!         │ levelize + annotate (once, at factor time)
//!         ▼
//!     FactorPlan ──► gpusim::executor   (costs the plan's levels)
//!         │      ──► numeric::parrl     (mode-adaptive worker-pool steps)
//!         │      ──► GluSolver::solve   (cached trisolve row schedules)
//!         └──────► runtime::lower_plan  (future kernel-launch sequence)
//! ```
//!
//! Per level the plan records the [`KernelMode`] (the paper's Eq. 4 +
//! stream-threshold decision, **the single source of truth** — both the
//! simulator's former `select_mode` call site and `Policy::mode_for` now
//! delegate here), the GPU [`ResourceBinding`] (blocks × warps or
//! stream-dispatch geometry), the CPU [`CpuAssignment`] the worker-pool
//! engine executes, and column work estimates. The plan also carries the
//! pattern-derived views every numeric backend shares (subcolumn map,
//! per-column work, and — lazily, on first multi-threaded solve — the
//! triangular-solve row schedules), so
//! [`crate::glu::GluSolver::refactor`] and the solves reuse it
//! allocation-free and [`crate::coordinator::SolverPool`] caches it with
//! the pattern-keyed symbolic state — a checkout hit never replans.
//!
//! [`FactorPlan`] is immutable after construction and cheap to clone (the
//! heavy state sits behind one `Arc`).

use std::sync::{Arc, OnceLock};

use crate::depend::{levelize, DepGraph, Levels};
use crate::gpusim::device::DeviceConfig;
use crate::gpusim::policy::Policy;
use crate::numeric::rightlook::upper_rows;
use crate::numeric::trisolve::TriangularSchedule;
use crate::symbolic::SymbolicFill;

/// The three GPU kernel modes of GLU3.0 (paper Fig. 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Type A levels: one block per column, few warps per block
    /// (Eq. 4), one warp per subcolumn task.
    SmallBlock {
        /// Warps per block ∈ {2, 4, 8, 16}.
        warps_per_block: usize,
    },
    /// Type B levels: one block per column, 32 warps (1024 threads),
    /// one warp per subcolumn — the GLU1.0/2.0 kernel.
    LargeBlock,
    /// Type C levels: one kernel per column over 16 CUDA streams, one
    /// *block* (1024 threads) per subcolumn.
    Stream,
}

impl KernelMode {
    /// Short label for reports.
    pub fn label(&self) -> String {
        match self {
            KernelMode::SmallBlock { warps_per_block } => format!("small({warps_per_block}w)"),
            KernelMode::LargeBlock => "large".to_string(),
            KernelMode::Stream => "stream".to_string(),
        }
    }

    /// Level-type letter for Table III's distribution columns.
    pub fn level_type(&self) -> char {
        match self {
            KernelMode::SmallBlock { .. } => 'A',
            KernelMode::LargeBlock => 'B',
            KernelMode::Stream => 'C',
        }
    }
}

/// Select the raw GLU3.0 mode for a level (Eq. 4 + the stream threshold),
/// before any policy ablation gates.
pub fn select_mode(level_size: usize, stream_threshold: usize, device: &DeviceConfig) -> KernelMode {
    if level_size <= stream_threshold {
        return KernelMode::Stream;
    }
    let w = device.total_warps() / level_size.max(1);
    if w >= 32 {
        KernelMode::LargeBlock
    } else {
        // Round down to a power of two in {2, 4, 8, 16} (paper §III-B.1:
        // "grows from 2 to 4, 8, and eventually to 32").
        let w = w.max(2);
        let w = 1usize << (usize::BITS - 1 - w.leading_zeros());
        KernelMode::SmallBlock {
            warps_per_block: w.clamp(2, 16),
        }
    }
}

/// Kernel mode for a level of `level_size` columns under `policy` — the
/// deduplicated decision the simulator's `select_mode` call site and
/// `Policy::mode_for` both used to make independently.
pub fn mode_for(policy: &Policy, level_size: usize, device: &DeviceConfig) -> KernelMode {
    if !policy.adaptive {
        return KernelMode::LargeBlock;
    }
    let mode = select_mode(level_size, policy.stream_threshold, device);
    match mode {
        KernelMode::SmallBlock { .. } if !policy.enable_small => KernelMode::LargeBlock,
        KernelMode::Stream if !policy.enable_stream => KernelMode::LargeBlock,
        m => m,
    }
}

/// Static work description of one column: `l_len` L entries (= length of
/// every subcolumn update task) and `n_subcols` subcolumn tasks.
#[derive(Debug, Clone, Copy)]
pub struct ColumnWork {
    pub l_len: usize,
    pub n_subcols: usize,
}

impl ColumnWork {
    /// Flop estimate: the divide pass plus one fused multiply-subtract per
    /// L entry per subcolumn (Eq. 3).
    pub fn flops(&self) -> u64 {
        (self.l_len + 2 * self.l_len * self.n_subcols) as u64
    }
}

/// GPU resource binding of one level, derived from its mode and the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResourceBinding {
    /// Small/large-block modes: one block per column.
    Blocks {
        blocks: usize,
        warps_per_block: usize,
    },
    /// Stream mode: one kernel per column dispatched over CUDA streams,
    /// one max-occupancy block per subcolumn.
    Streams { streams: usize, kernels: usize },
}

/// How the CPU worker-pool engine executes one plan step — the
/// thread-chunk analogue of the GPU geometry. Decided *here*, never in the
/// engine: `numeric::parrl` only dispatches on what the plan says.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuAssignment {
    /// Column-parallel: deal the level's columns round-robin across
    /// workers (wide small-mode levels — many independent columns).
    InterleavedColumns,
    /// Task-parallel in two sub-phases: all divide phases (columns dealt
    /// round-robin), one barrier, then the flat `(column, subcolumn)` MAC
    /// task list dealt round-robin (narrow large-mode levels — too few
    /// columns to feed every worker, but plenty of subcolumn tasks).
    SubcolumnSlices,
    /// A run of consecutive singleton stream-mode levels executed as one
    /// sequential chain by a single worker with a single end-of-run
    /// rendezvous — batching the deep narrow tail's barriers away.
    ChainBatch,
}

/// One step of the CPU execution schedule: a contiguous range of levels
/// sharing one assignment strategy (`level_count > 1` only for chain
/// batches).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuStep {
    pub first_level: usize,
    pub level_count: usize,
    pub assignment: CpuAssignment,
}

/// Per-level annotations of the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelPlan {
    /// Level index in schedule order.
    pub index: usize,
    /// Columns in the level.
    pub columns: usize,
    /// Kernel mode ([`mode_for`] — the single source of truth).
    pub mode: KernelMode,
    /// GPU launch geometry.
    pub binding: ResourceBinding,
    /// CPU worker-pool strategy.
    pub assignment: CpuAssignment,
    /// Max subcolumn tasks over the level's columns.
    pub max_subcols: usize,
    /// Total subcolumn tasks in the level.
    pub total_subcols: usize,
    /// Max L length over the level's columns (subcolumn task length).
    pub max_l_len: usize,
    /// Work estimate (sum of [`ColumnWork::flops`]).
    pub work_flops: u64,
}

#[derive(Debug)]
struct PlanInner {
    n: usize,
    policy: Policy,
    device: DeviceConfig,
    levels: Levels,
    level_plans: Vec<LevelPlan>,
    cpu_steps: Vec<CpuStep>,
    col_work: Vec<ColumnWork>,
    urow: Vec<Vec<u32>>,
    /// Row-oriented L/U level schedules, built lazily on first use: the
    /// `O(nnz)` row views would be dead weight in solvers that only ever
    /// take the sequential solve path (single-threaded engines, narrow
    /// schedules), so the plan stays immutable but pays for them only when
    /// a parallel solve actually asks.
    trisolve: OnceLock<TriangularSchedule>,
    /// Cached [`TriangularSchedule::parallel_worthwhile`] verdict. Kept
    /// separately so a *narrow* pattern's probe retains only this bool —
    /// the transient schedule built to answer it is dropped, not parked in
    /// every cached solver.
    trisolve_worthwhile: OnceLock<bool>,
}

/// The mode-annotated factorization schedule — see the module docs.
#[derive(Debug, Clone)]
pub struct FactorPlan {
    inner: Arc<PlanInner>,
}

impl FactorPlan {
    /// Build the plan from a dependency graph (levelizes internally).
    pub fn build(
        sym: &SymbolicFill,
        deps: &DepGraph,
        policy: &Policy,
        device: &DeviceConfig,
    ) -> FactorPlan {
        FactorPlan::from_levels(sym, levelize(deps), policy, device)
    }

    /// Build the plan from an already-levelized schedule (the solver path,
    /// where levelization is timed as its own preprocessing stage).
    pub fn from_levels(
        sym: &SymbolicFill,
        levels: Levels,
        policy: &Policy,
        device: &DeviceConfig,
    ) -> FactorPlan {
        let n = sym.filled.ncols();
        let urow = upper_rows(sym);
        let col_work: Vec<ColumnWork> = (0..n)
            .map(|j| {
                let (rows, _) = sym.filled.col(j);
                ColumnWork {
                    l_len: rows.len() - rows.partition_point(|&r| r <= j),
                    n_subcols: urow[j].len(),
                }
            })
            .collect();

        let mut level_plans = Vec::with_capacity(levels.num_levels());
        for (index, cols) in levels.levels.iter().enumerate() {
            let mode = mode_for(policy, cols.len(), device);
            let mut max_subcols = 0usize;
            let mut total_subcols = 0usize;
            let mut max_l_len = 0usize;
            let mut work_flops = 0u64;
            for &j in cols {
                let cw = col_work[j as usize];
                max_subcols = max_subcols.max(cw.n_subcols);
                total_subcols += cw.n_subcols;
                max_l_len = max_l_len.max(cw.l_len);
                work_flops += cw.flops();
            }
            let binding = match mode {
                KernelMode::SmallBlock { warps_per_block } => ResourceBinding::Blocks {
                    blocks: cols.len(),
                    warps_per_block,
                },
                KernelMode::LargeBlock => ResourceBinding::Blocks {
                    blocks: cols.len(),
                    warps_per_block: device.max_threads_per_block / device.warp_size,
                },
                KernelMode::Stream => ResourceBinding::Streams {
                    streams: device.num_streams,
                    kernels: cols.len(),
                },
            };
            // CPU strategy: wide levels are column-parallel; narrow levels
            // slice their subcolumn tasks; singleton stream tails are
            // chain-batched below.
            let assignment = match mode {
                KernelMode::SmallBlock { .. } => CpuAssignment::InterleavedColumns,
                KernelMode::LargeBlock | KernelMode::Stream => CpuAssignment::SubcolumnSlices,
            };
            level_plans.push(LevelPlan {
                index,
                columns: cols.len(),
                mode,
                binding,
                assignment,
                max_subcols,
                total_subcols,
                max_l_len,
                work_flops,
            });
        }

        // Fold maximal runs of singleton stream levels into chain batches
        // (one rendezvous per run instead of one per level) and group the
        // remaining levels into single-level steps.
        let mut cpu_steps = Vec::new();
        let mut li = 0usize;
        while li < level_plans.len() {
            let chainable = |lp: &LevelPlan| lp.mode == KernelMode::Stream && lp.columns == 1;
            if chainable(&level_plans[li]) {
                let mut end = li + 1;
                while end < level_plans.len() && chainable(&level_plans[end]) {
                    end += 1;
                }
                for lp in &mut level_plans[li..end] {
                    lp.assignment = CpuAssignment::ChainBatch;
                }
                cpu_steps.push(CpuStep {
                    first_level: li,
                    level_count: end - li,
                    assignment: CpuAssignment::ChainBatch,
                });
                li = end;
            } else {
                cpu_steps.push(CpuStep {
                    first_level: li,
                    level_count: 1,
                    assignment: level_plans[li].assignment,
                });
                li += 1;
            }
        }

        FactorPlan {
            inner: Arc::new(PlanInner {
                n,
                policy: policy.clone(),
                device: device.clone(),
                levels,
                level_plans,
                cpu_steps,
                col_work,
                urow,
                trisolve: OnceLock::new(),
                trisolve_worthwhile: OnceLock::new(),
            }),
        }
    }

    /// Matrix dimension the plan was built for.
    pub fn n(&self) -> usize {
        self.inner.n
    }

    /// Number of levels.
    pub fn num_levels(&self) -> usize {
        self.inner.levels.num_levels()
    }

    /// The level schedule the plan annotates.
    pub fn levels(&self) -> &Levels {
        &self.inner.levels
    }

    /// Per-level annotations, in schedule order.
    pub fn level_plans(&self) -> &[LevelPlan] {
        &self.inner.level_plans
    }

    /// One level's annotations.
    pub fn level_plan(&self, level: usize) -> &LevelPlan {
        &self.inner.level_plans[level]
    }

    /// The CPU execution steps (levels grouped by assignment strategy).
    pub fn cpu_steps(&self) -> &[CpuStep] {
        &self.inner.cpu_steps
    }

    /// Per-column work descriptions, indexed by column.
    pub fn col_work(&self) -> &[ColumnWork] {
        &self.inner.col_work
    }

    /// Subcolumn map: for each row `j`, the columns `k > j` with
    /// `As(j,k) ≠ 0` (shared by every right-looking backend).
    pub fn urow(&self) -> &[Vec<u32>] {
        &self.inner.urow
    }

    /// The triangular-solve row schedules for this pattern, built on first
    /// use and cached in the plan. `filled` must be the filled pattern the
    /// plan was built from (the caller keeps it — storing a pattern copy
    /// here would cost the same `O(nnz)` the lazy build avoids).
    pub fn trisolve(&self, filled: &crate::sparse::Csc) -> &TriangularSchedule {
        debug_assert_eq!(filled.ncols(), self.inner.n, "pattern mismatch");
        self.inner
            .trisolve
            .get_or_init(|| TriangularSchedule::build(filled))
    }

    /// Whether the level-parallel triangular solves are worth running on
    /// this pattern (see [`TriangularSchedule::parallel_worthwhile`]).
    /// The first probe builds the schedules; they are retained only on a
    /// `true` verdict — a narrow pattern keeps the cached bool and drops
    /// the `O(nnz)` row views (the pre-plan behavior).
    pub fn parallel_trisolve(&self, filled: &crate::sparse::Csc) -> bool {
        *self.inner.trisolve_worthwhile.get_or_init(|| {
            if let Some(ts) = self.inner.trisolve.get() {
                return ts.parallel_worthwhile();
            }
            let ts = TriangularSchedule::build(filled);
            let worthwhile = ts.parallel_worthwhile();
            if worthwhile {
                // Another racing forced build may have set it first; either
                // value is equivalent (pattern-only, deterministic).
                let _ = self.inner.trisolve.set(ts);
            }
            worthwhile
        })
    }

    /// The policy the plan was annotated under.
    pub fn policy(&self) -> &Policy {
        &self.inner.policy
    }

    /// The device model the plan was annotated under.
    pub fn device(&self) -> &DeviceConfig {
        &self.inner.device
    }

    /// Count of levels by mode family `(small, large, stream)` — the
    /// Table III A/B/C distribution, now answerable without running the
    /// simulator.
    pub fn mode_histogram(&self) -> (usize, usize, usize) {
        let mut dist = (0, 0, 0);
        for lp in &self.inner.level_plans {
            match lp.mode.level_type() {
                'A' => dist.0 += 1,
                'B' => dist.1 += 1,
                _ => dist.2 += 1,
            }
        }
        dist
    }

    /// Total estimated factorization flops across all levels.
    pub fn total_work(&self) -> u64 {
        self.inner.level_plans.iter().map(|lp| lp.work_flops).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::glu3;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    fn amd_grid(nx: usize, ny: usize, seed: u64) -> SymbolicFill {
        let g = gen::grid2d(nx, ny, seed);
        let p = crate::order::amd::amd_order(&g).unwrap();
        symbolic_fill(&g.permute(p.as_scatter(), p.as_scatter())).unwrap()
    }

    #[test]
    fn mode_selection_follows_eq4() {
        let d = DeviceConfig::titan_x();
        // level size <= 16 -> stream
        assert_eq!(select_mode(1, 16, &d), KernelMode::Stream);
        assert_eq!(select_mode(16, 16, &d), KernelMode::Stream);
        // 1536 total warps: level 48 -> W = 32 -> large
        assert_eq!(select_mode(48, 16, &d), KernelMode::LargeBlock);
        assert_eq!(select_mode(17, 16, &d), KernelMode::LargeBlock);
        // level 100 -> W = 15 -> small(8); level 1000 -> W = 1 -> small(2)
        assert_eq!(
            select_mode(100, 16, &d),
            KernelMode::SmallBlock { warps_per_block: 8 }
        );
        assert_eq!(
            select_mode(1000, 16, &d),
            KernelMode::SmallBlock { warps_per_block: 2 }
        );
    }

    /// The dedupe regression test: the plan's per-level mode agrees with
    /// both former call sites — `Policy::mode_for` (the policy layer) and
    /// the raw `select_mode` the simulator used to call inline — on random
    /// AMD-ordered grids under every policy.
    #[test]
    fn plan_mode_agrees_with_former_call_sites() {
        let d = DeviceConfig::titan_x();
        let mut rng = Rng::new(0x91A7);
        for trial in 0..4 {
            let nx = rng.range(10, 24);
            let ny = rng.range(10, 24);
            let sym = amd_grid(nx, ny, 40 + trial);
            let deps = glu3::detect(&sym.filled);
            for policy in [
                Policy::glu3(),
                Policy::glu2_fixed(),
                Policy::glu3_no_small(),
                Policy::glu3_no_stream(),
                Policy::glu3_with_threshold(4),
                Policy::lee_enhanced(),
            ] {
                let plan = FactorPlan::build(&sym, &deps, &policy, &d);
                assert_eq!(plan.num_levels(), plan.level_plans().len());
                for lp in plan.level_plans() {
                    let size = plan.levels().levels[lp.index].len();
                    assert_eq!(size, lp.columns);
                    // former call site 1: the policy layer
                    assert_eq!(
                        lp.mode,
                        policy.mode_for(size, &d),
                        "trial {trial} policy {} level {}",
                        policy.name,
                        lp.index
                    );
                    // former call site 2: the simulator's raw Eq. 4 call
                    // (only comparable when no ablation gate intervenes)
                    if policy == Policy::glu3() {
                        assert_eq!(lp.mode, select_mode(size, 16, &d));
                    }
                }
            }
        }
    }

    #[test]
    fn plan_annotations_are_consistent() {
        let sym = amd_grid(20, 20, 7);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());

        // levels partition the columns, and the per-level aggregates match
        // a direct recomputation from the column work table
        let total_cols: usize = plan.level_plans().iter().map(|lp| lp.columns).sum();
        assert_eq!(total_cols, plan.n());
        for lp in plan.level_plans() {
            let cols = &plan.levels().levels[lp.index];
            let max_sub = cols
                .iter()
                .map(|&j| plan.col_work()[j as usize].n_subcols)
                .max()
                .unwrap_or(0);
            assert_eq!(max_sub, lp.max_subcols);
            let flops: u64 = cols
                .iter()
                .map(|&j| plan.col_work()[j as usize].flops())
                .sum();
            assert_eq!(flops, lp.work_flops);
            // binding geometry mirrors the mode
            match (lp.mode, lp.binding) {
                (KernelMode::SmallBlock { warps_per_block }, ResourceBinding::Blocks { blocks, warps_per_block: w }) => {
                    assert_eq!(blocks, lp.columns);
                    assert_eq!(w, warps_per_block);
                }
                (KernelMode::LargeBlock, ResourceBinding::Blocks { blocks, warps_per_block }) => {
                    assert_eq!(blocks, lp.columns);
                    assert_eq!(warps_per_block, 32);
                }
                (KernelMode::Stream, ResourceBinding::Streams { streams, kernels }) => {
                    assert_eq!(streams, 16);
                    assert_eq!(kernels, lp.columns);
                }
                (m, b) => panic!("mode {m:?} bound to {b:?}"),
            }
        }
        let (a, b, c) = plan.mode_histogram();
        assert_eq!(a + b + c, plan.num_levels());
        assert!(plan.total_work() > 0);
    }

    #[test]
    fn cpu_steps_cover_levels_and_batch_singleton_tails() {
        let sym = amd_grid(24, 24, 3);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());

        // steps tile the level range exactly, in order
        let mut next = 0usize;
        for step in plan.cpu_steps() {
            assert_eq!(step.first_level, next);
            assert!(step.level_count >= 1);
            if step.assignment != CpuAssignment::ChainBatch {
                assert_eq!(step.level_count, 1);
            }
            for lp in &plan.level_plans()[step.first_level..step.first_level + step.level_count] {
                assert_eq!(lp.assignment, step.assignment);
                if step.assignment == CpuAssignment::ChainBatch {
                    assert_eq!(lp.columns, 1);
                    assert_eq!(lp.mode, KernelMode::Stream);
                }
            }
            next = step.first_level + step.level_count;
        }
        assert_eq!(next, plan.num_levels());

        // an AMD mesh tail ends in consecutive singleton stream levels —
        // they must fold into a multi-level chain batch
        let batched = plan
            .cpu_steps()
            .iter()
            .any(|s| s.assignment == CpuAssignment::ChainBatch && s.level_count > 1);
        assert!(batched, "singleton stream tail must be chain-batched");

        // wide early levels are column-parallel
        assert_eq!(
            plan.level_plans()[0].assignment,
            CpuAssignment::InterleavedColumns
        );
    }

    #[test]
    fn plan_clone_is_shallow() {
        let sym = amd_grid(12, 12, 1);
        let deps = glu3::detect(&sym.filled);
        let plan = FactorPlan::build(&sym, &deps, &Policy::glu3(), &DeviceConfig::titan_x());
        let clone = plan.clone();
        // same backing allocation — cloning a cached plan is free
        assert!(std::ptr::eq(plan.urow(), clone.urow()));
        assert!(std::ptr::eq(plan.levels(), clone.levels()));
    }
}
