//! # glu3 — GPU-style parallel sparse LU factorization for circuit simulation
//!
//! A from-scratch reproduction of **GLU3.0** (Peng & Tan, 2019): a sparse LU
//! solver built around the hybrid column right-looking factorization of
//! GLU1.0/2.0, with the paper's two contributions implemented as first-class
//! features:
//!
//! 1. **Relaxed column dependency detection** ([`depend::glu3`], Algorithm 4)
//!    replacing the O(n³) double-U search of GLU2.0 ([`depend::glu2`],
//!    Algorithm 3).
//! 2. **Adaptive three-mode numeric kernel** — small-block / large-block /
//!    stream — computed once per pattern as a mode-annotated
//!    [`plan::FactorPlan`] and consumed by every backend: the warp-based
//!    cycle simulator ([`gpusim`]), the worker-pool CPU engines
//!    ([`numeric`]), and the PJRT lowering path ([`runtime`]).
//!
//! The pipeline every solve flows through — the `execute` stage now
//! dispatches to a backend:
//!
//! ```text
//!                 ┌── wave-parallel & streamed ([`symbolic::parfill`]);
//!                 │   near-miss patterns patch instead ([`symbolic::delta`])
//! order → scale → symbolic → detect → levelize → plan ──► execute
//!                                                  │
//!                              ┌───────────────────┼──────────────────┐
//!                       gpusim (costed)   numeric engines (CPU)   lower_plan
//!                                                                    │
//!                                                              LaunchSchedule
//!                                                                    │
//!                                                     DeviceExecutor backend:
//!                                                     VirtualDevice | PjrtDevice
//! ```
//!
//! The crate also contains every substrate the paper depends on: sparse
//! formats and Matrix Market I/O ([`sparse`]), MC64-style matching/scaling and
//! AMD ordering ([`order`]), symbolic Gilbert–Peierls fill-in ([`symbolic`]),
//! sequential and multithreaded baselines ([`numeric`]), a cycle-approximate
//! GPU timing simulator ([`gpusim`]), a SPICE-lite circuit simulator
//! ([`circuit`]) as the end-to-end workload, a threaded solver-service
//! coordinator ([`coordinator`]), and a PJRT runtime ([`runtime`]) that loads
//! AOT-compiled JAX/Pallas kernels for the dense-batch update and dense-tail
//! paths.
//!
//! ## Quickstart
//!
//! ```no_run
//! use glu3::glu::{GluOptions, GluSolver};
//! use glu3::sparse::gen::{self, SuiteMatrix};
//!
//! let a = gen::generate(&SuiteMatrix::Circuit2.spec());
//! let mut solver = GluSolver::factor(&a, &GluOptions::default()).unwrap();
//! let b = vec![1.0; a.nrows()];
//! let x = solver.solve(&b).unwrap();
//! ```
//!
//! ## Serving many solves
//!
//! One-shot factorization is the wrong shape for circuit simulation: a
//! SPICE transient loop restamps the *same* Jacobian pattern thousands of
//! times, and only the values change. The serving tier —
//! [`coordinator::SolverPool`] — makes the factor-once/refactor-many split
//! an API guarantee. The pool caches each pattern's symbolic state
//! (ordering + fill + dependency graph + levels) under a structural hash:
//! the first request for a pattern pays [`glu::GluSolver::factor`], every
//! later request (same structure, any values) takes the numeric-only
//! [`glu::GluSolver::refactor`] fast path. Batched right-hand sides take
//! one *blocked* triangular-solve walk ([`glu::GluSolver::solve_many`]
//! permutes, scales, and level-walks the whole RHS block once, not once
//! per vector), the allocation-free [`coordinator::PoolGuard::solve_many_into`]
//! variant solves into caller-provided storage, the cache is sharded for
//! concurrent sessions, and hit/miss/latency counters (p50/p99) come back
//! through [`coordinator::SolverPool::stats`]. The serve loop
//! ([`coordinator::serve`]) builds on the same primitive: requests that
//! coalesce on an identical value stamp are stacked into one RHS block
//! and retired by exactly one blocked walk —
//! [`coordinator::serve::ServeStats::batched_solve_walks`] counts those
//! walks, so `batched_solve_walks + coalesced == completed` under clean
//! traffic.
//!
//! ```no_run
//! use glu3::coordinator::SolverPool;
//! use glu3::glu::GluOptions;
//! use glu3::sparse::gen::{self, SuiteMatrix};
//!
//! let pool = SolverPool::new(GluOptions::default());
//! let a = gen::generate(&SuiteMatrix::Circuit2.spec());
//! let rhs: Vec<Vec<f64>> = vec![vec![1.0; a.nrows()]; 4];
//!
//! let _xs = pool.solve_many(&a, &rhs).unwrap(); // miss: full factor
//! let mut a2 = a.clone();
//! for v in a2.values_mut() {
//!     *v *= 1.5; // Newton restamp: same pattern, new values
//! }
//! let _xs = pool.solve_many(&a2, &rhs).unwrap(); // hit: refactor only
//! assert_eq!(pool.stats().hits, 1);
//! ```
//!
//! The Newton–Raphson driver ([`coordinator::nr::newton_raphson_in`]) and
//! the transient simulator ([`circuit::transient::transient_in`]) route
//! every linear solve through a pool, so a warm pool carries symbolic
//! state across whole simulations (e.g. Monte-Carlo corners of one
//! circuit).
//!
//! ## Cold starts and pattern deltas
//!
//! The hit path above amortizes *numeric* work; this section is about the
//! miss path — the serial symbolic pipeline a cold pattern pays before
//! the first refactor can ever run. Two mechanisms attack it:
//!
//! **Wave-parallel symbolic** ([`symbolic::parallel_symbolic`]): the
//! column elimination tree is computed first (cheap,
//! [`symbolic::etree::col_etree`]), its node heights partition columns into
//! *waves* of provably independent reach computations, and each wave's
//! fill discovery fans out across the same spawn-once
//! [`numeric::pool::WorkerPool`] the numeric engines park between runs.
//! Finished columns stream straight into the fused relaxed-detection +
//! levelization pass ([`depend::glu3::StreamingDetect`]), so dependency
//! analysis overlaps fill discovery instead of waiting for it. The
//! result — fill pattern, dependency graph, levels — is **bit-identical
//! to the serial pass at any thread count** (the symbolic tier of
//! `rust/tests/property.rs` holds that matrix), so every downstream
//! consumer is oblivious to how the pattern was produced.
//!
//! **Incremental patching** ([`symbolic::patch_symbolic`]): a transient
//! step that fires a switch, or a Monte-Carlo corner that adds one
//! device, hands the pool a pattern that is *almost* a cached one.
//! [`symbolic::changed_columns`] diffs the new matrix against a cached
//! pattern under a changed-column budget; if the delta is small, the
//! exact taint set (changed columns plus everything their new fill can
//! reach) is recomputed against the frozen prefix and the rest of the
//! symbolic state — fill, dependency edges, levels, and the
//! [`plan::FactorPlan`]'s per-level annotations
//! ([`plan::FactorPlan::from_levels_delta`]) — is patched in place. The
//! patched state is bit-identical to a fresh cold run on the new matrix.
//! [`coordinator::SolverPool`] wires this in on every miss: a near-miss
//! scan over cached entries (same `n`, nnz within ~12%, budget
//! `(n/4).max(4)`) routes small deltas through
//! [`glu::GluSolver::factor_delta`], falling back to the cold path —
//! with a pool-owned reusable fill workspace — when no candidate
//! qualifies. [`coordinator::PoolStats::patched`] counts the saved cold
//! starts, [`glu::GluStats`] reports `fillin_ms` and the
//! incremental/parallel run counters, and `glu3 bench` records cold vs
//! incremental symbolic wall-clock in the `symbolic` block of
//! `BENCH_numeric.json`.
//!
//! ## Choosing a numeric engine
//!
//! [`glu::NumericEngine`] selects what executes the numeric kernel; the
//! engines split into two families:
//!
//! **Simulated** — [`glu::NumericEngine::SimulatedGpu`] (the default)
//! runs the paper's hybrid right-looking kernel under a cycle-approximate
//! TITAN X timing model. Its `numeric_ms` is *simulated kernel time*: use
//! it to reproduce the paper's tables and to study policy/levelization
//! trade-offs, never to measure this host. Numerics are real (checked
//! against the oracles); only the clock is modeled.
//!
//! **Real-parallel** — the pool-backed engines report *wall-clock* and
//! actually use your cores, spawning their worker pool once at factor
//! time and parking it between runs ([`numeric::pool::WorkerPool`]):
//!
//! - [`glu::NumericEngine::ParallelRightLooking`] executes the GLU3.0
//!   hazard-free schedule (relaxed detection + levelization) with real
//!   threads — the engine where the paper's extra parallelism shows up in
//!   wall-clock. Requires a hazard-free schedule, so it refuses
//!   [`glu::Detection::Glu1`]. Same-level columns commit MAC updates with
//!   atomic compare-and-swap, so results match the simulator to rounding
//!   (bit-identical at one thread).
//! - [`glu::NumericEngine::ParallelCpu`] is the NICSLU-style level-parallel
//!   *left*-looking baseline (Table I's CPU column): bit-identical to the
//!   sequential oracle at any thread count, scheduled on the U-pattern
//!   dependency graph.
//!
//! The sequential engines — [`glu::NumericEngine::LeftLookingCpu`]
//! (Gilbert–Peierls oracle) and [`glu::NumericEngine::RightLookingCpu`]
//! (Algorithm 2 reference, bit-identical to the simulator's arithmetic) —
//! are the correctness anchors the test pyramid compares everything
//! against.
//!
//! If you don't want to choose at all, [`glu::NumericEngine::Auto`]
//! resolves an engine *per pattern* from the factored plan's own level
//! statistics (CKTSO-style adaptivity): deep, narrow schedules — chains,
//! `Glu1` detection, stream-dominated plans — take the sequential
//! left-looking oracle; wide level schedules with a thread budget take
//! the pool-backed right-looking engine; everything else runs the lowered
//! `LaunchSchedule` on the virtual device. The resolved choice is
//! recorded in [`glu::GluStats::resolved_engine`], and the `glu3` CLI
//! defaults to `--engine auto`.
//!
//! Any multi-threaded engine also switches `solve`/`solve_many` to the
//! parallel triangular solves (the
//! [`numeric::trisolve::TriangularSchedule`] carried by the plan), which
//! are bit-identical to the sequential substitutions at every thread
//! count. **Choosing a trisolve variant:** the plan picks one of three
//! kernels per pattern from its own level-width statistics
//! ([`numeric::trisolve::TriangularSchedule::choose_variant`], cached on
//! the [`plan::FactorPlan`]): schedules too narrow for any barrier to pay for
//! itself (mean level width below ~8 rows) keep the *sequential*
//! substitution; wide, shallow schedules take the *level-set* kernel (one
//! barrier per level, all rows in a level in parallel); and deep
//! schedules — where per-level barriers would dominate — take the
//! *sync-free* self-scheduling kernel (per-row ready counters in the
//! style of Li's GPU trisolve: each worker spins only on its own rows'
//! inputs, no inter-level barrier at all). The resolved label is recorded
//! in [`glu::GluStats::trisolve_variant`]. The `glu3 bench` subcommand
//! measures factor/refactor/solve
//! wall-clock for every engine and writes `BENCH_numeric.json` — the
//! recorded perf trajectory, including a `plan` block (per-level mode
//! histogram + preprocessing stage timings).
//!
//! ## The refactorization hot path
//!
//! Circuit simulation refactors the *same pattern* thousands of times, so
//! the engineering rule the whole crate follows is: **anything computable
//! from the pattern is paid once at pattern time; the numeric hot loop
//! only streams values.** Pattern time (per [`glu::GluSolver::factor`] /
//! pool miss) produces the ordering, the fill, the dependency levels, the
//! mode-annotated [`plan::FactorPlan`] — and, for the indexed engine, two
//! further artifacts:
//!
//! - the [`plan::ScatterMap`]: for every `(source, destination)` MAC task,
//!   the multiplier's value index plus a flat run of destination value
//!   indices aligned with the source column's L rows. The numeric inner
//!   loop is then pure `vals[dst[i]] -= l[i] * mult` — the per-refactor
//!   `binary_search`/`partition_point`/row-match scans are gone. (A real
//!   GPU offload would upload the same runs once as its gather/scatter
//!   index buffers; the cycle simulator already costs that kernel.)
//! - the destination-ownership groups ([`plan::FactorPlan::dest_groups`]):
//!   each sliced level's tasks grouped by destination column, so one
//!   worker owns each destination and commits with **plain stores** — no
//!   CAS — falling back to source-major CAS slicing only where a dominant
//!   destination would serialize
//!   ([`plan::CpuAssignment::OwnedDestinations`] vs
//!   [`plan::CpuAssignment::SubcolumnSlices`]).
//!
//! Numeric time ([`glu::GluSolver::refactor`], the Newton/transient inner
//! loop) then allocates nothing, searches nothing, and atomically commits
//! only where two same-level sources can actually collide.
//! [`glu::GluStats::scatter_builds`] proves the map is built once per
//! pattern (pool checkout hits never rebuild it) and
//! [`glu::GluStats::atomic_commits_avoided`] counts the CAS traffic the
//! ownership partitioning removes; `glu3 bench` measures the win as the
//! `refactor_loop` block of `BENCH_numeric.json` (indexed vs search-based
//! head-to-head on the same plan and pool).
//!
//! When the workload restamps the pattern *many times at once* — Monte-
//! Carlo corners, periodic-steady-state shooting, parameter sweeps — even
//! the per-refactor schedule walk repeats work: B refactors replay the
//! same launch sequence, re-read the same index buffers, and re-gather
//! the same multipliers B times. [`glu::GluSolver::refactor_batch`] fixes
//! the shape: the B value sets are laid out as a [`numeric::ValuePlanes`]
//! structure-of-arrays (plane-major interleaved over the shared nnz
//! layout), and **one** schedule walk pushes all B planes through the
//! factorization — the ScatterMap indices are read once per task and the
//! inner MAC loop runs over the contiguous plane dimension, in both the
//! pool-backed right-looking engine and the lowered `LaunchSchedule` on
//! the virtual device. Results are bit-identical to B looped refactors at
//! one thread (and within 1e-12 relative at more); any plane that trips
//! the pivot monitor drops the whole batch back to the looped repair
//! ladder, so robustness is unchanged. The `batched` block of
//! `BENCH_numeric.json` records the looped-vs-batched head-to-head (the
//! tier-1 bar is ≥ 1.3× at B = 16 on the acceptance grid), alongside the
//! blocked multi-RHS solve sweep and the trisolve-variant histogram.
//!
//! ## Surviving ugly matrices
//!
//! A Newton/transient loop occasionally hands the solver a restamp whose
//! values are numerically hostile — a pivot driven to zero through a
//! region of the operating curve, a device model that mis-scales a row by
//! decades. Because GLU-style factorization pivots *statically* (the
//! order is fixed at pattern time), the numeric phase cannot swap rows to
//! save itself; the classic response is to throw away the cached symbolic
//! state and refactor from scratch, which is exactly the cost the whole
//! crate exists to amortize. Instead, [`glu::GluSolver::refactor`] climbs
//! a **repair ladder** on the fixed pattern:
//!
//! 1. Every numeric kernel threads a [`numeric::PivotMonitor`] through
//!    the factorization, so each run yields an element-growth proxy and a
//!    max/min pivot condition estimate for free. A clean run inside the
//!    gates is accepted as-is — the hot path pays two comparisons.
//! 2. On a zero/non-finite pivot (or a gate trip), the ladder retries
//!    with a small static **diagonal perturbation** (scaled to the
//!    stamped magnitudes) and runs **iterative refinement** against the
//!    true values; the repair is accepted only if the scaled probe
//!    residual meets tolerance. Subsequent `solve` calls keep refining
//!    against the unperturbed matrix, so answers converge to the true
//!    system, not the perturbed one.
//! 3. If refinement stalls — values so mis-scaled the perturbation
//!    swamps healthy rows — the ladder **escalates**: a fresh Ruiz
//!    equilibration of the new values on the *same* permutations, then
//!    the perturbed retry again. Ordering, fill, dependency levels, plan,
//!    scatter map, and launch schedule are all reused at every rung.
//! 4. When the fixed order itself is unsalvageable, the last resort is
//!    the **pivot rescue** ([`numeric::pivlu`]): a Gilbert–Peierls
//!    left-looking factorization with *threshold partial pivoting* —
//!    keep the static pivot when it is within a relative tolerance of
//!    the best candidate, otherwise swap toward the largest (ties broken
//!    toward sparser rows, Markowitz-style) — discovers the fill of the
//!    new row order on the fly, and the entire static pipeline (filled
//!    pattern, dependency levels, [`plan::FactorPlan`], scatter map,
//!    launch schedule, workspace) is rebuilt and **hot-swapped in
//!    place** on the rescued order. Subsequent refactors run the normal
//!    fast path on that order — one rescue, not one per restamp.
//! 5. Only when even the rescue finds no admissible pivot does
//!    `refactor` return an error — a typed
//!    [`numeric::GluError::NumericallySingular`] carried in the `anyhow`
//!    chain — with the stats scrubbed so stale timings can't be mistaken
//!    for a successful run.
//!
//! One consequence worth naming: a rescue makes the solver's internal
//! row order *drift* from what the cold pipeline would build for the
//! same pattern. Solutions are unaffected (the permutation is applied
//! and undone inside `solve`), but raw LU values are no longer
//! comparable entry-for-entry against a fresh `factor`, and cached
//! symbolic state on the rescued order is not a valid delta base for
//! structural near-miss patching — [`glu::GluSolver::is_rescued`] flags
//! this, and the pool's near-miss scan skips such entries.
//!
//! [`glu::RobustnessStats`] (on [`glu::GluStats`]) counts perturbations,
//! refinement steps, escalations, repairs, and rescues (with swapped
//! pivot counts and the rescue wall-clock), and records the growth /
//! condition proxies and the accepted probe residual; `glu3 factor`
//! prints them and `glu3 bench` emits them as the `robustness` and
//! `rescue` blocks of `BENCH_numeric.json`. The serving tier leans on
//! the same split: [`coordinator::SolverPool`] keeps a cached pattern
//! when a checkout's refactor fails *numerically* (the next restamp will
//! likely repair), hot-swaps it under the same pattern key when a rescue
//! re-permutes it, and evicts only on structural failure.
//!
//! ## Serving under failure
//!
//! The ladder repairs hostile *values*; [`coordinator::Server`] survives
//! hostile *callers*. It wraps the [`coordinator::SolverPool`] in the
//! service discipline a simulation farm's shared solver front-end needs,
//! as one pipeline every request flows through:
//!
//! ```text
//! submit ─► admission ─► fairness ─► coalesce ─► checkout ─► solve
//!           bounded      round-robin by pattern  retry w/    per-RHS
//!           queue,       over tenant key + equal backoff on  deadline
//!           priority     sub-queues  values     transients   checks
//!           shedding
//! ```
//!
//! Admission is bounded and priority-aware: a full queue answers with a
//! typed [`numeric::GluError::Overloaded`] (back-pressure, not an
//! unbounded buffer), and under pressure low-priority tenants are shed
//! first. Every request carries a deadline, checked cooperatively at the
//! dequeue, checkout, and per-RHS boundaries — a miss replies with a
//! typed [`numeric::GluError::DeadlineExceeded`], never a hang. Transient
//! checkout failures retry with exponential backoff inside the remaining
//! budget; ladder exhaustion
//! ([`numeric::GluError::NumericallySingular`]) is terminal and is never
//! retried. Same-pattern same-values requests coalesce onto one checkout,
//! so a submission burst costs one refactor; sustained pressure degrades
//! the loop to the cheapest viable engine until the backlog eases; and
//! shutdown drains the backlog, joins the workers, and gives anything
//! stranded a typed reply.
//!
//! All of it is testable under a deterministic, seedable
//! [`coordinator::FaultPlan`]: injected delays, adversarial restamps that
//! force specific ladder rungs, poisoned checkouts, and submission bursts
//! are a pure function of `(seed, request id)`, so a chaos run
//! (`tests/chaos.rs`, `glu3 serve`, the `solver_service` example) is
//! reproducible in CI regardless of thread interleaving. `glu3 serve`
//! emits the serving counters — throughput, p50/p99/p999 latency, queue
//! depth, shed/retry/coalesce counts, and a saturation sweep — as
//! `BENCH_service.json`.
//!
//! ## Choosing a kernel mode
//!
//! You don't: the [`plan::FactorPlan`] does, per level, at plan-build
//! time — this is the paper's second contribution and the knob-free core
//! of GLU3.0. What you choose is the [`gpusim::Policy`] (and, for the
//! simulator, a [`gpusim::DeviceConfig`]); the plan then annotates each
//! level with the mode the policy's Eq. 4 arithmetic selects
//! ([`plan::mode_for`] — the single source of mode decisions):
//!
//! - **Small-block** ([`plan::KernelMode::SmallBlock`], type A): wide
//!   levels with more columns than the device has 32-warp slots. One
//!   block per column with 2–16 warps, so more columns are resident at
//!   once. CPU analogue: columns dealt round-robin across the worker pool
//!   ([`plan::CpuAssignment::InterleavedColumns`]).
//! - **Large-block** ([`plan::KernelMode::LargeBlock`], type B): mid-width
//!   levels where every column can hold a full 32-warp block — the
//!   GLU1.0/2.0 kernel shape. CPU analogue: too few columns to feed every
//!   worker, so the level's MAC tasks are dealt across the pool — whole
//!   destination-column groups per worker with plain stores
//!   ([`plan::CpuAssignment::OwnedDestinations`]), or source-major with
//!   CAS commits when one destination dominates
//!   ([`plan::CpuAssignment::SubcolumnSlices`]).
//! - **Stream** ([`plan::KernelMode::Stream`], type C): tail levels of at
//!   most `stream_threshold` (default 16) columns, launched one kernel
//!   per column over CUDA streams with a block per subcolumn. CPU
//!   analogue: runs of singleton levels execute as one sequential chain
//!   with a single rendezvous ([`plan::CpuAssignment::ChainBatch`]).
//!
//! Policies tune the decision, not the mechanism: [`gpusim::Policy::glu3`]
//! is the adaptive default, [`gpusim::Policy::glu3_with_threshold`] sweeps
//! the stream cutoff (Fig. 12), [`gpusim::Policy::glu3_no_small`] /
//! [`gpusim::Policy::glu3_no_stream`] are Table III's ablations, and
//! [`gpusim::Policy::glu2_fixed`] pins every level to the fixed
//! large-block kernel. [`runtime::lower_plan`] maps the same per-level
//! annotations onto the AOT kernel ladder — the launch sequence the
//! execution layer runs.
//!
//! ## Executing a plan
//!
//! The execution layer closes the loop from scheduling IR to device:
//! [`runtime::lower_plan`] lowers the plan to a
//! [`runtime::LaunchSchedule`] (cached on the plan, like the scatter
//! map), and a [`runtime::executor::DeviceExecutor`] backend runs it —
//! `upload_pattern` binds the [`plan::ScatterMap`] as device-resident
//! `u32` index buffers once per pattern, `execute` walks the
//! `PlannedLaunch`es level by level against the value buffer. Two
//! backends exist: the default-build [`runtime::VirtualDevice`]
//! interprets each launch with its real geometry (bit-identical L/U
//! values to the cycle simulator and the 1-thread parallel engine — the
//! conformance tier, `rust/tests/conformance.rs`, holds that three-way
//! matrix), and the `pjrt`-feature [`runtime::executor::PjrtDevice`]
//! dispatches the AOT artifact ladder. Select it with
//! [`glu::NumericEngine::Schedule`]; per-launch counts and the
//! executed-vs-simulated cycle reconciliation (the gpusim latency model
//! against the issue-only cost of the same geometry,
//! [`gpusim::DeviceConfig::issue_only`]) surface in [`glu::GluStats`],
//! `glu3 factor`, and the `schedule` block of `BENCH_numeric.json`.
//! Both backends validate the schedule against the uploaded pattern —
//! level order, column counts, kernel names, buffer lengths, every
//! scatter index — before touching a single value, so a corrupted or
//! foreign schedule is rejected whole.

pub mod bench_support;
pub mod circuit;
pub mod coordinator;
pub mod depend;
pub mod glu;
pub mod gpusim;
pub mod numeric;
pub mod order;
pub mod plan;
pub mod runtime;
pub mod sparse;
pub mod symbolic;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
