//! # glu3 — GPU-style parallel sparse LU factorization for circuit simulation
//!
//! A from-scratch reproduction of **GLU3.0** (Peng & Tan, 2019): a sparse LU
//! solver built around the hybrid column right-looking factorization of
//! GLU1.0/2.0, with the paper's two contributions implemented as first-class
//! features:
//!
//! 1. **Relaxed column dependency detection** ([`depend::glu3`], Algorithm 4)
//!    replacing the O(n³) double-U search of GLU2.0 ([`depend::glu2`],
//!    Algorithm 3).
//! 2. **Adaptive three-mode numeric kernel** ([`glu::modes`]) — small-block /
//!    large-block / stream — scheduling level-parallel column factorization
//!    onto a warp-based execution substrate ([`gpusim`]).
//!
//! The crate also contains every substrate the paper depends on: sparse
//! formats and Matrix Market I/O ([`sparse`]), MC64-style matching/scaling and
//! AMD ordering ([`order`]), symbolic Gilbert–Peierls fill-in ([`symbolic`]),
//! sequential and multithreaded baselines ([`numeric`]), a cycle-approximate
//! GPU timing simulator ([`gpusim`]), a SPICE-lite circuit simulator
//! ([`circuit`]) as the end-to-end workload, a threaded solver-service
//! coordinator ([`coordinator`]), and a PJRT runtime ([`runtime`]) that loads
//! AOT-compiled JAX/Pallas kernels for the dense-batch update and dense-tail
//! paths.
//!
//! ## Quickstart
//!
//! ```no_run
//! use glu3::glu::{GluOptions, GluSolver};
//! use glu3::sparse::gen::{self, SuiteMatrix};
//!
//! let a = gen::generate(&SuiteMatrix::Circuit2.spec());
//! let mut solver = GluSolver::factor(&a, &GluOptions::default()).unwrap();
//! let b = vec![1.0; a.nrows()];
//! let x = solver.solve(&b).unwrap();
//! ```

pub mod bench_support;
pub mod circuit;
pub mod coordinator;
pub mod depend;
pub mod glu;
pub mod gpusim;
pub mod numeric;
pub mod order;
pub mod runtime;
pub mod sparse;
pub mod symbolic;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
