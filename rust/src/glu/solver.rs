//! The [`GluSolver`]: preprocess → symbolic → levelize → numeric → solve.

use crate::depend::{glu1, glu2, glu3, levelize, DepGraph, Levels};
use crate::gpusim::{simulate_refactorization, DeviceConfig, Policy, SimReport};
use crate::numeric::pool::WorkerPool;
use crate::numeric::trisolve::{ReadyFlags, TriangularSchedule, TrisolveVariant};
use crate::numeric::{
    leftlook, parlu, parrl, pivlu, rightlook, GluError, LuFactors, PivotMonitor, ValuePlanes,
};
use crate::order::{preprocess, FillOrdering, Preprocessed};
use crate::plan::FactorPlan;
use crate::runtime::executor::{create_backend, DeviceExecutor, ExecReport};
use crate::symbolic::{
    parallel_fill, parallel_symbolic, patch_symbolic, symbolic_fill_with, FillWorkspace,
    SymbolicFill,
};
use crate::util::Stopwatch;

pub use crate::runtime::executor::ExecBackend;

/// Which dependency detection algorithm to run (paper Fig. 9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Detection {
    /// GLU1.0 U-pattern (unsafe for the right-looking kernel; only valid
    /// together with [`NumericEngine::LeftLookingCpu`]).
    Glu1,
    /// GLU2.0 exact double-U search (Algorithm 3) — O(n³)-class.
    Glu2,
    /// GLU3.0 relaxed detection (Algorithm 4) — the default.
    #[default]
    Glu3,
}

/// Which numeric engine executes the factorization.
///
/// See the crate docs ("Choosing a numeric engine") for guidance; in
/// short: [`NumericEngine::SimulatedGpu`] reproduces the paper's *timing
/// model*, the two pool-backed parallel engines produce real wall-clock
/// speedups on host CPUs, and the sequential engines are oracles.
#[derive(Debug, Clone, Default)]
pub enum NumericEngine {
    /// Simulated-GPU hybrid right-looking kernel under a [`Policy`]
    /// (the paper's system; default: GLU3.0 adaptive on a TITAN X model).
    #[default]
    SimulatedGpu,
    /// Sequential Gilbert–Peierls left-looking (oracle).
    LeftLookingCpu,
    /// Multithreaded left-looking (NICSLU-like baseline) on a persistent
    /// worker pool.
    ParallelCpu {
        threads: usize,
    },
    /// Sequential right-looking (Algorithm 2 reference).
    RightLookingCpu,
    /// Pool-backed parallel hybrid right-looking executing the hazard-free
    /// GLU2.0/GLU3.0 schedule with real CPU threads — the first engine
    /// where the relaxed detection's extra parallelism is wall-clock, not
    /// simulated cycles. Incompatible with [`Detection::Glu1`] (that
    /// schedule has read/write hazards; [`GluSolver::factor`] refuses it).
    ParallelRightLooking {
        threads: usize,
    },
    /// Execute the lowered kernel-launch schedule
    /// ([`crate::runtime::LaunchSchedule`], cached on the plan) through a
    /// [`crate::runtime::executor::DeviceExecutor`] backend:
    /// [`ExecBackend::Virtual`] interprets every launch with the real
    /// launch geometry and the uploaded scatter index buffers
    /// (bit-identical to [`NumericEngine::SimulatedGpu`]'s numerics),
    /// [`ExecBackend::Pjrt`] dispatches the AOT artifact ladder
    /// (`--features pjrt`; real execution additionally needs the vendored
    /// `xla` bindings). Per-launch counts and simulated-vs-executed cycle
    /// deltas land in [`GluStats::exec`]. Like the parallel right-looking
    /// engine, refuses [`Detection::Glu1`]'s hazardous schedule.
    Schedule {
        backend: ExecBackend,
    },
    /// CKTSO-style adaptive choice: pick the engine *per pattern* from the
    /// [`FactorPlan`]'s statistics (level depth, mode histogram, average
    /// level width) once the symbolic analysis is done. Deep, narrow,
    /// stream-dominated schedules route to the sequential left-looking
    /// oracle (per-level launches are pure overhead there); wide schedules
    /// with multiple threads route to the pool-backed parallel
    /// right-looking engine; everything in between executes the lowered
    /// launch schedule on the virtual device. The resolved choice is
    /// recorded in [`GluStats::resolved_engine`] and queryable via
    /// [`GluSolver::engine`]. With [`Detection::Glu1`] the only safe
    /// engine — the left-looking oracle — is chosen.
    Auto {
        threads: usize,
    },
}

impl NumericEngine {
    /// Worker threads this engine runs with (1 for sequential engines).
    pub fn threads(&self) -> usize {
        match self {
            NumericEngine::ParallelCpu { threads }
            | NumericEngine::ParallelRightLooking { threads }
            | NumericEngine::Auto { threads } => (*threads).max(1),
            _ => 1,
        }
    }
}

/// Resolve [`NumericEngine::Auto`] against the pattern's plan statistics;
/// every concrete engine resolves to itself. Average level width (columns
/// per barrier) is the dominant signal — it decides whether per-level
/// orchestration amortizes — with the plan's stream-mode share breaking
/// near-sequential schedules toward the oracle.
fn resolve_engine(
    requested: &NumericEngine,
    detection: Detection,
    plan: &FactorPlan,
) -> NumericEngine {
    let NumericEngine::Auto { threads } = requested else {
        return requested.clone();
    };
    let threads = (*threads).max(1);
    if detection == Detection::Glu1 {
        // The U-pattern schedule has double-U hazards: only the
        // left-looking engine may consume it.
        return NumericEngine::LeftLookingCpu;
    }
    let levels = plan.num_levels().max(1);
    let avg_width = plan.n() as f64 / levels as f64;
    let (_, _, stream) = plan.mode_histogram();
    if avg_width < 2.0 || stream * 2 >= levels {
        return NumericEngine::LeftLookingCpu;
    }
    if threads > 1 && avg_width >= 16.0 {
        return NumericEngine::ParallelRightLooking { threads };
    }
    NumericEngine::Schedule {
        backend: ExecBackend::Virtual,
    }
}

/// Options for [`GluSolver::factor`].
#[derive(Debug, Clone)]
pub struct GluOptions {
    /// Fill-reducing ordering (default AMD, as the paper).
    pub ordering: FillOrdering,
    /// Apply MC64-style equilibration scaling.
    pub scale: bool,
    /// Dependency detection algorithm.
    pub detection: Detection,
    /// Numeric engine.
    pub engine: NumericEngine,
    /// Kernel policy for the simulated GPU engine.
    pub policy: Policy,
    /// Device model for the simulated GPU engine.
    pub device: DeviceConfig,
}

impl Default for GluOptions {
    fn default() -> Self {
        GluOptions {
            ordering: FillOrdering::Amd,
            scale: true,
            detection: Detection::Glu3,
            engine: NumericEngine::SimulatedGpu,
            policy: Policy::glu3(),
            device: DeviceConfig::titan_x(),
        }
    }
}

/// Numeric-health estimates and robustness-ladder counters, updated by
/// every [`GluSolver::factor`] / [`GluSolver::refactor`] run. The estimates
/// are the cheap kernel-threaded kind (pivot extrema — two compares per
/// column, never on the MAC hot loop), not true condition numbers.
#[derive(Debug, Clone, Default)]
pub struct RobustnessStats {
    /// Element growth proxy of the last successful run:
    /// `max |pivot| / max |stamped value|`.
    pub pivot_growth: f64,
    /// Condition proxy of the last successful run:
    /// `max |pivot| / min |pivot|`.
    pub condition_estimate: f64,
    /// Smallest pivot magnitude seen in the last successful run.
    pub min_abs_pivot: f64,
    /// Scaled probe residual of the last *repaired* run (0.0 while the
    /// factors are exact and no repair was needed).
    pub last_residual: f64,
    /// Diagonal-perturbation attempts (ladder rung 1) over this solver's
    /// lifetime.
    pub perturbations: u64,
    /// Iterative-refinement correction steps applied (probe + solve).
    pub refine_iters: u64,
    /// Escalations to a fresh re-equilibration on the fixed pattern
    /// (ladder rung 2).
    pub escalations: u64,
    /// Refactor calls that would have failed outright but were repaired in
    /// place by the ladder.
    pub repairs: u64,
    /// Rung-5 rescues: refactor calls whose fixed pivot order was
    /// numerically unsalvageable and that were saved by the threshold
    /// partial-pivoting factorization ([`crate::numeric::pivlu`]) — each
    /// one rebuilt the solver's symbolic state on a new row order.
    pub rescues: u64,
    /// Columns whose rescued pivot row differs from the static one,
    /// summed over all rescues (the pivot-order drift).
    pub rescued_pivots: u64,
    /// Wall-clock of the last rescue, ms (pivoting factorization plus the
    /// full symbolic/plan/workspace rebuild). 0.0 while no rescue ran.
    pub rescue_ms: f64,
}

/// Phase timings and structural statistics of one factorization.
#[derive(Debug, Clone)]
pub struct GluStats {
    pub n: usize,
    /// nnz before fill.
    pub nz: usize,
    /// nnz after fill.
    pub nnz: usize,
    pub num_levels: usize,
    pub max_level_size: usize,
    /// CPU preprocessing time (matching + ordering + permute), ms.
    pub preprocess_ms: f64,
    /// Total symbolic-phase time (fill + detection + levelization), ms —
    /// the whole cold-start tax a pattern pays before any numeric work.
    pub symbolic_ms: f64,
    /// Fill-in discovery time alone, ms (wave-parallel on the worker pool
    /// when the engine is multi-threaded; the taint-patch time on the
    /// incremental path, where detection/levelization are fused in).
    pub fillin_ms: f64,
    /// Dependency detection time alone, ms — the stage Algorithm 4's
    /// detection-speedup claim (Table II) is about.
    pub detect_ms: f64,
    /// Levelization time alone, ms.
    pub levelize_ms: f64,
    /// Dependency detection + levelization time, ms (Table II's metric).
    pub levelization_ms: f64,
    /// [`FactorPlan`] build time, ms (mode annotation + CPU step layout +
    /// subcolumn/work views; the trisolve row schedules build lazily on
    /// the first multi-threaded solve and are not counted here).
    pub plan_ms: f64,
    /// Numeric factorization time, ms: simulated-GPU kernel time for the
    /// GPU engine, wall-clock for CPU engines.
    pub numeric_ms: f64,
    /// Simulated-GPU report (None for CPU engines).
    pub sim: Option<SimReport>,
    /// How many times the symbolic pipeline (ordering + fill + dependency
    /// detection + levelization) has run for this solver — 1 unless a
    /// rung-5 pivot rescue rebuilt the pattern on a new row order (then
    /// 1 + rescues): the whole point of [`GluSolver::refactor`] is that it
    /// never reruns on the fast path. Exposed so the service layer can
    /// *assert* the refactor fast path skipped the CPU phases.
    pub symbolic_runs: usize,
    /// How many times the numeric kernel has run (1 for the initial factor
    /// plus one per [`GluSolver::refactor`]).
    pub numeric_runs: usize,
    /// How many times a [`FactorPlan`] has been built for this solver —
    /// 1 outside of rung-5 rescues (which replan once per rescue):
    /// refactors and solves reuse it, and the service layer asserts cache
    /// hits never replan.
    pub plan_builds: usize,
    /// Whether this solver's fill discovery ran wave-parallel on the
    /// worker pool (1) or serially (0).
    pub symbolic_parallel_runs: u64,
    /// Whether this solver's symbolic state was produced by patching a
    /// cached near-miss pattern ([`GluSolver::factor_delta`]) instead of
    /// the cold pipeline (then `symbolic_runs` stays 0).
    pub incremental_patches: u64,
    /// How many times the pattern-time [`crate::plan::ScatterMap`] has
    /// been built for this solver — 0 until a scatter-consuming engine
    /// (the indexed parallel right-looking path) first runs, 1 ever after:
    /// refactors and pool checkout hits reuse the cached map, and the
    /// service layer asserts it.
    pub scatter_builds: usize,
    /// MAC element commits per numeric run executed with plain stores
    /// instead of CAS loops (destination-ownership and chain-batch levels
    /// of the plan) — the atomic traffic the ownership-aware partitioning
    /// removes from the hot loop.
    pub atomic_commits_avoided: u64,
    /// How many times the [`crate::runtime::LaunchSchedule`] has been
    /// lowered for this solver — 0 until the schedule engine first runs,
    /// 1 ever after: refactors and pool checkout hits execute the cached
    /// schedule, and the service layer asserts it.
    pub schedule_builds: usize,
    /// Per-launch execution report of the schedule engine's last run
    /// (`None` for every other engine): launch counts plus
    /// executed-vs-simulated cycles per level.
    pub exec: Option<ExecReport>,
    /// Numeric-health estimates and robustness-ladder counters.
    pub robustness: RobustnessStats,
    /// Debug label of the engine actually running the kernels — equals the
    /// configured engine unless [`NumericEngine::Auto`] resolved it.
    pub resolved_engine: String,
    /// Label of the trisolve variant the solves run ("sequential" /
    /// "level-set" / "sync-free"; empty until the first solve) — the
    /// per-pattern choice [`FactorPlan::trisolve_variant`] makes from the
    /// level-width statistics, downgraded to sequential when the engine
    /// has no multi-thread pool.
    pub trisolve_variant: &'static str,
}

impl GluStats {
    /// Total CPU-side time (the paper's "CPU time" column, plus the plan
    /// build — all of it paid once per pattern and amortized by refactors).
    /// `symbolic_ms` already includes detection + levelization.
    pub fn cpu_ms(&self) -> f64 {
        self.preprocess_ms + self.symbolic_ms + self.plan_ms
    }
}

/// Solver-owned numeric scratch: everything the refactor/solve hot paths
/// need, allocated once at factor time so Newton iterations allocate
/// **nothing** `O(nnz)` — the amortization the paper's Fig. 5 split is
/// about, extended to host memory traffic.
#[derive(Debug)]
struct NumericWorkspace {
    /// `O(nnz)` scatter buffer for value restamping in
    /// [`GluSolver::refactor`].
    fresh: Vec<f64>,
    /// Per-worker dense column workspaces (left-looking engines; one entry
    /// for the sequential oracle, one per pool thread for `ParallelCpu`).
    works: Vec<Vec<f64>>,
    /// Divide-phase scratch (right-looking engines).
    lvals: Vec<f64>,
    /// U-pattern level schedule — the parallel *left*-looking engine
    /// (distinct from the solver's hazard-free right-looking plan).
    ll_levels: Option<Levels>,
    /// Persistent worker pool (spawned once; parks between runs) for the
    /// parallel engines and the parallel triangular solves.
    pool: Option<WorkerPool>,
    /// Schedule-executor backend (the [`NumericEngine::Schedule`] engine),
    /// created at factor time; holds the uploaded pattern (device-resident
    /// index buffers) after the first run, so refactors re-execute the
    /// cached schedule with zero re-uploads.
    executor: Option<Box<dyn DeviceExecutor>>,
    /// Scattered-rhs scratch for the refined solve path (ladder rung 1) —
    /// solver-owned so a repaired solver's solves stay allocation-free.
    b0: Vec<f64>,
    /// Residual scratch for iterative refinement.
    resid: Vec<f64>,
    /// Permuted-domain solution scratch for [`GluSolver::solve`] and the
    /// per-RHS refinement sweep of [`GluSolver::solve_many_into`].
    pb: Vec<f64>,
    /// Interleaved multi-RHS block (`n × nrhs`) for
    /// [`GluSolver::solve_many_into`], grown to the largest batch seen.
    block: Vec<f64>,
    /// Per-row ready flags for the sync-free trisolves.
    ready: ReadyFlags,
}

impl NumericWorkspace {
    /// Engine-specific scratch only: every *pattern-derived* view the
    /// right-looking engines used to cache here (subcolumn map, per-column
    /// work, trisolve row schedules) now lives in the shared
    /// [`FactorPlan`].
    ///
    /// `pool` is the worker pool the symbolic phase already spawned (when
    /// the engine is multi-threaded); it is adopted by the pool-backed
    /// engines and dropped (threads joined) by everything else, preserving
    /// the parallel-trisolve gating on `ws.pool`.
    fn new(
        engine: &NumericEngine,
        sym: &SymbolicFill,
        pool: Option<WorkerPool>,
    ) -> anyhow::Result<Self> {
        let n = sym.filled.ncols();
        let threads = engine.threads();
        let pool = match engine {
            NumericEngine::ParallelCpu { .. } | NumericEngine::ParallelRightLooking { .. } => {
                Some(pool.unwrap_or_else(|| WorkerPool::new(threads)))
            }
            _ => None,
        };
        let works = match engine {
            NumericEngine::ParallelCpu { .. } => vec![vec![0.0f64; n]; threads],
            NumericEngine::LeftLookingCpu => vec![vec![0.0f64; n]; 1],
            _ => Vec::new(),
        };
        let ll_levels = match engine {
            NumericEngine::ParallelCpu { .. } => Some(parlu::leftlook_levels(sym)),
            _ => None,
        };
        let executor = match engine {
            NumericEngine::Schedule { backend } => Some(create_backend(*backend)?),
            _ => None,
        };
        Ok(NumericWorkspace {
            fresh: vec![0.0f64; sym.filled.nnz()],
            works,
            lvals: Vec::new(),
            ll_levels,
            pool,
            executor,
            b0: Vec::new(),
            resid: Vec::new(),
            pb: Vec::new(),
            block: Vec::new(),
            ready: ReadyFlags::new(),
        })
    }
}

/// Cached symbolic state of a factored pattern — preprocessing transform,
/// filled pattern, factor plan — cloned out of a [`GluSolver`] by
/// [`GluSolver::symbolic_snapshot`] so a structural near-miss can be
/// patched incrementally ([`GluSolver::factor_delta`]) instead of paying
/// the cold pipeline.
#[derive(Debug, Clone)]
pub struct SymbolicSnapshot {
    pre: Preprocessed,
    sym: SymbolicFill,
    plan: FactorPlan,
}

/// A factored system ready to solve and refactor.
#[derive(Debug)]
pub struct GluSolver {
    opts: GluOptions,
    pre: Preprocessed,
    sym: SymbolicFill,
    /// The mode-annotated schedule every backend consumes — built once at
    /// factor time, reused allocation-free by `refactor`/`solve`, cached
    /// with this solver by the [`crate::coordinator::SolverPool`].
    plan: FactorPlan,
    factors: LuFactors,
    stats: GluStats,
    ws: NumericWorkspace,
    /// The engine actually running the kernels: `opts.engine` unless
    /// [`NumericEngine::Auto`] was requested, in which case the per-pattern
    /// resolution made at factor time.
    engine: NumericEngine,
    /// Set when an in-place refactorization failed partway: the factors
    /// are garbage until a refactor succeeds, and solves are refused.
    poisoned: bool,
    /// Map: position in the *original* matrix's CSC value array → position
    /// in the filled pattern's value array (for fast refactorization).
    value_map: Vec<usize>,
    /// Filled-pattern value index of each diagonal entry (`usize::MAX` if
    /// structurally absent — a case the symbolic phase rejects anyway).
    /// Precomputed so the ladder's diagonal perturbation is a flat sweep.
    diag_map: Vec<usize>,
    /// Whether stamping applies `pre.row_scale`/`pre.col_scale`. Starts as
    /// `opts.scale`; the escalation rung forces it on after installing
    /// fresh Ruiz scales.
    apply_scales: bool,
    /// Magnitude of the diagonal perturbation baked into the current
    /// factors (0.0 = factors are exact). While nonzero, every solve runs
    /// iterative refinement against the true values held in `ws.fresh`.
    perturb_eps: f64,
}

impl GluSolver {
    /// Run the full pipeline on `a`.
    pub fn factor(a: &crate::sparse::Csc, opts: &GluOptions) -> anyhow::Result<Self> {
        Self::factor_with_workspace(a, opts, &mut FillWorkspace::new())
    }

    /// [`GluSolver::factor`] with caller-owned symbolic scratch — the
    /// [`crate::coordinator::SolverPool`] lends its workspace here so
    /// back-to-back cache misses reuse one set of reach/marker buffers.
    pub fn factor_with_workspace(
        a: &crate::sparse::Csc,
        opts: &GluOptions,
        fws: &mut FillWorkspace,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(a.nrows() == a.ncols(), "matrix must be square");
        if matches!(
            opts.engine,
            NumericEngine::ParallelRightLooking { .. } | NumericEngine::Schedule { .. }
        ) {
            anyhow::ensure!(
                opts.detection != Detection::Glu1,
                "this engine requires a hazard-free schedule: GLU1.0's \
                 U-pattern detection misses double-U read/write hazards (paper \
                 Fig. 9) — use Detection::Glu2 or Detection::Glu3"
            );
        }
        let mut sw = Stopwatch::new();

        let pre = sw.time("preprocess", || preprocess(a, opts.ordering, opts.scale))?;
        // Spawn the worker pool *before* the symbolic phase when the engine
        // is multi-threaded: fill discovery runs wave-parallel on it, and
        // the pool-backed numeric engines adopt it afterwards.
        let pool = (opts.engine.threads() > 1).then(|| WorkerPool::new(opts.engine.threads()));
        let (sym, levels, [fillin_ms, detect_ms, levelize_ms], par_run) =
            run_symbolic(&pre.a, opts.detection, pool.as_ref(), fws)?;
        let plan = sw.time("plan", || {
            FactorPlan::from_levels(&sym, levels, &opts.policy, &opts.device)
        });

        let engine = resolve_engine(&opts.engine, opts.detection, &plan);
        let mut ws = NumericWorkspace::new(&engine, &sym, pool)?;
        let mut mon = PivotMonitor::new();
        let (factors, sim, numeric_ms, exec) = run_engine(&engine, &plan, &sym, &mut ws, &mut mon)?;

        // Keep the true stamped values around: the robustness ladder's
        // iterative refinement corrects against them, and refactors reuse
        // the buffer as scatter scratch.
        ws.fresh.copy_from_slice(sym.filled.values());
        let max_stamp = max_abs(&ws.fresh);
        let diag_map = (0..sym.filled.ncols())
            .map(|j| sym.filled.entry_index(j, j).unwrap_or(usize::MAX))
            .collect();

        let value_map = build_value_map(a, &pre, &sym);

        let ms = |name: &str| sw.get(name).unwrap().as_secs_f64() * 1e3;
        let stats = GluStats {
            n: a.nrows(),
            nz: a.nnz(),
            nnz: sym.filled.nnz(),
            num_levels: plan.num_levels(),
            max_level_size: plan.levels().max_level_size(),
            preprocess_ms: ms("preprocess"),
            symbolic_ms: fillin_ms + detect_ms + levelize_ms,
            fillin_ms,
            detect_ms,
            levelize_ms,
            levelization_ms: detect_ms + levelize_ms,
            plan_ms: ms("plan"),
            numeric_ms,
            sim,
            symbolic_runs: 1,
            numeric_runs: 1,
            plan_builds: 1,
            symbolic_parallel_runs: par_run as u64,
            incremental_patches: 0,
            scatter_builds: plan.scatter_builds(),
            atomic_commits_avoided: plan.atomic_commits_avoided(),
            schedule_builds: plan.schedule_builds(),
            exec,
            robustness: RobustnessStats {
                pivot_growth: mon.growth(max_stamp),
                condition_estimate: mon.condition_estimate(),
                min_abs_pivot: if mon.min_abs_pivot.is_finite() {
                    mon.min_abs_pivot
                } else {
                    0.0
                },
                ..Default::default()
            },
            resolved_engine: format!("{engine:?}"),
            trisolve_variant: "",
        };

        let apply_scales = opts.scale;
        Ok(GluSolver {
            opts: opts.clone(),
            pre,
            sym,
            plan,
            factors,
            stats,
            ws,
            engine,
            poisoned: false,
            value_map,
            diag_map,
            apply_scales,
            perturb_eps: 0.0,
        })
    }

    /// Snapshot the symbolic state — preprocessing transform, filled
    /// pattern, factor plan — for later incremental patching via
    /// [`GluSolver::factor_delta`]. The plan share is `Arc`-backed (cheap);
    /// the preprocessing and pattern are deep copies taken once here.
    pub fn symbolic_snapshot(&self) -> SymbolicSnapshot {
        SymbolicSnapshot {
            pre: self.pre.clone(),
            sym: self.sym.clone(),
            plan: self.plan.clone(),
        }
    }

    /// CKTSO-style incremental factorization: reuse a cached pattern's
    /// preprocessing verbatim and patch its symbolic state against a
    /// structural near-miss instead of running the cold pipeline.
    ///
    /// `changed_orig` lists the columns of `a` (original index space)
    /// whose structure differs from the snapshot's matrix — what
    /// [`crate::symbolic::changed_columns`] returns from the cached raw
    /// pattern. Re-applying the cached permutations and scales in one
    /// [`crate::sparse::Csc::permute_scale`] reproduces the preprocessing
    /// two-step exactly (scales apply at original indices); a delta that
    /// breaks the matched diagonal fails here and the caller falls back to
    /// the cold path. The patched solver reports `symbolic_runs == 0` and
    /// `incremental_patches == 1`.
    pub fn factor_delta(
        a: &crate::sparse::Csc,
        opts: &GluOptions,
        snap: &SymbolicSnapshot,
        changed_orig: &[u32],
        fws: &mut FillWorkspace,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(a.nrows() == a.ncols(), "matrix must be square");
        anyhow::ensure!(
            opts.detection == Detection::Glu3,
            "incremental patching streams GLU3.0 detection; other modes go cold"
        );
        let n = a.nrows();
        anyhow::ensure!(snap.sym.filled.ncols() == n, "snapshot shape mismatch");
        let t_pre = std::time::Instant::now();
        let a2 = a.permute_scale(
            snap.pre.row_perm.as_scatter(),
            snap.pre.col_perm.as_scatter(),
            opts.scale.then_some(snap.pre.row_scale.as_slice()),
            opts.scale.then_some(snap.pre.col_scale.as_slice()),
        );
        anyhow::ensure!(
            a2.has_full_diagonal(),
            "structural delta breaks the matched diagonal — refactor cold"
        );
        let preprocess_ms = wall_ms(t_pre);

        // Column permutation is a bijection on columns: the changed set
        // maps 1:1 into the permuted space the cached pattern lives in.
        let t_sym = std::time::Instant::now();
        let pc = snap.pre.col_perm.as_scatter();
        let mut changed: Vec<u32> = changed_orig
            .iter()
            .map(|&c| pc[c as usize] as u32)
            .collect();
        changed.sort_unstable();
        let patch = patch_symbolic(&snap.sym, &a2, &changed, fws)?;
        // Detection + levelization are fused into the patch sweep; the
        // whole symbolic cost lands in `fillin_ms`.
        let fillin_ms = wall_ms(t_sym);
        let sym = patch.sym;

        let t_plan = std::time::Instant::now();
        let plan = FactorPlan::from_levels_delta(
            &sym,
            patch.levels,
            &opts.policy,
            &opts.device,
            &snap.plan,
        );
        let plan_ms = wall_ms(t_plan);

        let engine = resolve_engine(&opts.engine, opts.detection, &plan);
        let mut ws = NumericWorkspace::new(&engine, &sym, None)?;
        let mut mon = PivotMonitor::new();
        let (factors, sim, numeric_ms, exec) = run_engine(&engine, &plan, &sym, &mut ws, &mut mon)?;

        ws.fresh.copy_from_slice(sym.filled.values());
        let max_stamp = max_abs(&ws.fresh);
        let diag_map = (0..sym.filled.ncols())
            .map(|j| sym.filled.entry_index(j, j).unwrap_or(usize::MAX))
            .collect();
        let pre = snap.pre.clone();
        let value_map = build_value_map(a, &pre, &sym);

        let stats = GluStats {
            n,
            nz: a.nnz(),
            nnz: sym.filled.nnz(),
            num_levels: plan.num_levels(),
            max_level_size: plan.levels().max_level_size(),
            preprocess_ms,
            symbolic_ms: fillin_ms,
            fillin_ms,
            detect_ms: 0.0,
            levelize_ms: 0.0,
            levelization_ms: 0.0,
            plan_ms,
            numeric_ms,
            sim,
            symbolic_runs: 0,
            numeric_runs: 1,
            plan_builds: 1,
            symbolic_parallel_runs: 0,
            incremental_patches: 1,
            scatter_builds: plan.scatter_builds(),
            atomic_commits_avoided: plan.atomic_commits_avoided(),
            schedule_builds: plan.schedule_builds(),
            exec,
            robustness: RobustnessStats {
                pivot_growth: mon.growth(max_stamp),
                condition_estimate: mon.condition_estimate(),
                min_abs_pivot: if mon.min_abs_pivot.is_finite() {
                    mon.min_abs_pivot
                } else {
                    0.0
                },
                ..Default::default()
            },
            resolved_engine: format!("{engine:?}"),
            trisolve_variant: "",
        };

        Ok(GluSolver {
            opts: opts.clone(),
            pre,
            sym,
            plan,
            factors,
            stats,
            ws,
            engine,
            poisoned: false,
            value_map,
            diag_map,
            apply_scales: opts.scale,
            perturb_eps: 0.0,
        })
    }

    /// The engine actually running the kernels (the Auto resolution when
    /// [`NumericEngine::Auto`] was requested).
    pub fn engine(&self) -> &NumericEngine {
        &self.engine
    }

    /// Solve `A x = b` using the current factors. The permuted-domain
    /// scratch lives in the solver workspace; only the returned solution
    /// vector is allocated.
    pub fn solve(&mut self, b: &[f64]) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(b.len() == self.stats.n, "rhs dimension mismatch");
        self.ensure_factors_valid()?;
        let mut pb = std::mem::take(&mut self.ws.pb);
        pb.resize(b.len(), 0.0);
        let mut x = vec![0.0; b.len()];
        self.solve_into(b, &mut pb, &mut x);
        self.ws.pb = pb;
        Ok(x)
    }

    /// Solve a batch of right-hand sides against the same factors.
    /// Allocates the output block and delegates to
    /// [`GluSolver::solve_many_into`].
    pub fn solve_many(&mut self, rhs: &[Vec<f64>]) -> anyhow::Result<Vec<Vec<f64>>> {
        let mut out = vec![vec![0.0; self.stats.n]; rhs.len()];
        self.solve_many_into(rhs, &mut out)?;
        Ok(out)
    }

    /// Blocked multi-RHS solve over caller-provided storage — zero
    /// solve-path heap allocation in steady state (the interleaved block
    /// scratch is solver-owned and grown to the largest batch seen).
    ///
    /// The whole batch rides **one** permute/scale sweep, one blocked
    /// triangular level walk (sequential, level-set, or sync-free — the
    /// plan's per-pattern [`FactorPlan::trisolve_variant`] choice), and one
    /// gather, instead of `nrhs` independent passes. Each solution is
    /// bit-identical to the corresponding [`GluSolver::solve`] call: per
    /// RHS the blocked kernels replay the single-vector operation order
    /// exactly. Each `out[k]` is resized to `n`.
    pub fn solve_many_into(
        &mut self,
        rhs: &[Vec<f64>],
        out: &mut [Vec<f64>],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(rhs.len() == out.len(), "rhs/out batch size mismatch");
        let n = self.stats.n;
        for b in rhs {
            anyhow::ensure!(b.len() == n, "rhs dimension mismatch");
        }
        self.ensure_factors_valid()?;
        let nrhs = rhs.len();
        if nrhs == 0 {
            return Ok(());
        }
        for x in out.iter_mut() {
            x.resize(n, 0.0);
        }
        let mut xb = std::mem::take(&mut self.ws.block);
        xb.resize(n * nrhs, 0.0);
        // b' = Dr * b permuted by the row permutation, all RHS at once.
        let pr = self.pre.row_perm.as_scatter();
        for (old, &new) in pr.iter().enumerate() {
            let scale = self.pre.row_scale[old];
            let base = new * nrhs;
            for (k, b) in rhs.iter().enumerate() {
                xb[base + k] = b[old] * scale;
            }
        }
        let variant = self.effective_trisolve_variant();
        self.stats.trisolve_variant = variant.label();
        match variant {
            TrisolveVariant::Sequential => {
                crate::numeric::trisolve::lower_unit_solve_block(&self.factors.lu, &mut xb, nrhs);
                crate::numeric::trisolve::upper_solve_block(&self.factors.lu, &mut xb, nrhs);
            }
            TrisolveVariant::LevelSet => {
                let pool = self.ws.pool.as_ref().expect("pool gated by variant");
                let ts = self.plan.trisolve(&self.sym.filled);
                crate::numeric::trisolve::lower_unit_solve_par_block(
                    &self.factors.lu,
                    &ts.lower,
                    pool,
                    &mut xb,
                    nrhs,
                );
                crate::numeric::trisolve::upper_solve_par_block(
                    &self.factors.lu,
                    &ts.upper,
                    pool,
                    &mut xb,
                    nrhs,
                );
            }
            TrisolveVariant::SyncFree => {
                let pool = self.ws.pool.as_ref().expect("pool gated by variant");
                let ts = self.plan.trisolve(&self.sym.filled);
                crate::numeric::trisolve::lower_unit_solve_syncfree_block(
                    &self.factors.lu,
                    &ts.lower,
                    pool,
                    &mut self.ws.ready,
                    &mut xb,
                    nrhs,
                );
                crate::numeric::trisolve::upper_solve_syncfree_block(
                    &self.factors.lu,
                    &ts.upper,
                    pool,
                    &mut self.ws.ready,
                    &mut xb,
                    nrhs,
                );
            }
        }
        // Perturbed factors are a preconditioner, not an inverse: refine
        // each solution against the true stamped values, exactly as the
        // single-RHS path does.
        if self.perturb_eps > 0.0 {
            let mut y = std::mem::take(&mut self.ws.pb);
            y.resize(n, 0.0);
            let mut b0 = std::mem::take(&mut self.ws.b0);
            b0.resize(n, 0.0);
            for (k, b) in rhs.iter().enumerate() {
                for i in 0..n {
                    y[i] = xb[i * nrhs + k];
                }
                let pr = self.pre.row_perm.as_scatter();
                for (old, &new) in pr.iter().enumerate() {
                    b0[new] = b[old] * self.pre.row_scale[old];
                }
                self.refine_in_place(&b0, &mut y, REFINE_MAX_SOLVE);
                for i in 0..n {
                    xb[i * nrhs + k] = y[i];
                }
            }
            self.ws.pb = y;
            self.ws.b0 = b0;
        }
        // x = Dc * (P_colᵀ x'), all RHS at once.
        let pc = self.pre.col_perm.as_scatter();
        for (old, &new) in pc.iter().enumerate() {
            let scale = self.pre.col_scale[old];
            let base = new * nrhs;
            for (k, x) in out.iter_mut().enumerate() {
                x[old] = xb[base + k] * scale;
            }
        }
        self.ws.block = xb;
        Ok(())
    }

    /// The trisolve variant this solver's solves actually run: the plan's
    /// per-pattern choice when a multi-thread pool is available, sequential
    /// otherwise.
    fn effective_trisolve_variant(&self) -> TrisolveVariant {
        match &self.ws.pool {
            Some(pool) if pool.threads() > 1 => self.plan.trisolve_variant(&self.sym.filled),
            _ => TrisolveVariant::Sequential,
        }
    }

    fn ensure_factors_valid(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.poisoned,
            "factors are stale: the last refactor failed partway; refactor \
             with numerically valid values before solving"
        );
        Ok(())
    }

    /// Shared inner solve: scatter `b` through row scaling/permutation into
    /// `pb`, run the triangular solves in place, gather into `x` through the
    /// column permutation/scaling. `pb` and `x` must have length `n`.
    ///
    /// With a multi-thread engine configured, the triangular solves run
    /// level-parallel on the persistent worker pool over the cached
    /// [`TriangularSchedule`]; results are bit-identical to the sequential
    /// path at any thread count. While the factors carry a diagonal
    /// perturbation (ladder rung 1), the solution is polished by iterative
    /// refinement against the true values in `ws.fresh` before the gather.
    fn solve_into(&mut self, b: &[f64], pb: &mut [f64], x: &mut [f64]) {
        // b' = Dr * b permuted by the row permutation.
        let pr = self.pre.row_perm.as_scatter();
        for (old, &new) in pr.iter().enumerate() {
            pb[new] = b[old] * self.pre.row_scale[old];
        }
        // The plan carries the row schedules (built lazily on the first
        // multi-threaded solve) and the per-pattern variant choice; every
        // variant is bit-identical to the sequential walk by construction.
        let variant = self.effective_trisolve_variant();
        self.stats.trisolve_variant = variant.label();
        match variant {
            TrisolveVariant::Sequential => {
                crate::numeric::trisolve::lower_unit_solve(&self.factors.lu, pb);
                crate::numeric::trisolve::upper_solve(&self.factors.lu, pb);
            }
            TrisolveVariant::LevelSet => {
                let pool = self.ws.pool.as_ref().expect("pool gated by variant");
                let ts = self.plan.trisolve(&self.sym.filled);
                crate::numeric::trisolve::lower_unit_solve_par(
                    &self.factors.lu,
                    &ts.lower,
                    pool,
                    pb,
                );
                crate::numeric::trisolve::upper_solve_par(&self.factors.lu, &ts.upper, pool, pb);
            }
            TrisolveVariant::SyncFree => {
                let pool = self.ws.pool.as_ref().expect("pool gated by variant");
                let ts = self.plan.trisolve(&self.sym.filled);
                crate::numeric::trisolve::lower_unit_solve_syncfree(
                    &self.factors.lu,
                    &ts.lower,
                    pool,
                    &mut self.ws.ready,
                    pb,
                );
                crate::numeric::trisolve::upper_solve_syncfree(
                    &self.factors.lu,
                    &ts.upper,
                    pool,
                    &mut self.ws.ready,
                    pb,
                );
            }
        }
        // Perturbed factors are a preconditioner, not an inverse: refine
        // the permuted-domain solution against the true stamped values.
        if self.perturb_eps > 0.0 {
            // re-derive the scattered rhs (pb was overwritten in place)
            // through workspace scratch — the refined solve path performs
            // no heap allocation.
            let mut b0 = std::mem::take(&mut self.ws.b0);
            b0.resize(pb.len(), 0.0);
            for (old, &new) in pr.iter().enumerate() {
                b0[new] = b[old] * self.pre.row_scale[old];
            }
            self.refine_in_place(&b0, pb, REFINE_MAX_SOLVE);
            self.ws.b0 = b0;
        }
        // x = Dc * (P_colᵀ x').
        let pc = self.pre.col_perm.as_scatter();
        for (old, &new) in pc.iter().enumerate() {
            x[old] = pb[new] * self.pre.col_scale[old];
        }
    }

    /// `out[r] = (As · y)[r]` over the filled pattern with the *true*
    /// stamped values (`ws.fresh`) — the matvec iterative refinement needs.
    fn matvec_fresh(&self, y: &[f64], out: &mut [f64]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        let filled = &self.sym.filled;
        let mut pos = 0usize;
        for c in 0..filled.ncols() {
            let (rows, _) = filled.col(c);
            let yc = y[c];
            for &r in rows {
                out[r] += self.ws.fresh[pos] * yc;
                pos += 1;
            }
        }
    }

    /// Iterative refinement in the permuted/scaled domain: polish `y`
    /// (current solution of `As y = b0`) with up to `max_iters` correction
    /// solves through the (possibly perturbed) factors. Returns the final
    /// scaled residual `‖b0 − As·y‖∞ / (‖As‖_F ‖y‖∞ + ‖b0‖∞)`.
    fn refine_in_place(&mut self, b0: &[f64], y: &mut [f64], max_iters: usize) -> f64 {
        let n = b0.len();
        let mut r = std::mem::take(&mut self.ws.resid);
        r.resize(n, 0.0);
        let fro = self.ws.fresh.iter().map(|v| v * v).sum::<f64>().sqrt();
        let bnorm = b0.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let mut rel = f64::INFINITY;
        for iter in 0..=max_iters {
            self.matvec_fresh(y, &mut r);
            for (ri, &bi) in r.iter_mut().zip(b0) {
                *ri = bi - *ri;
            }
            let rnorm = r.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            let ynorm = y.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            let denom = fro * ynorm + bnorm;
            rel = if denom > 0.0 { rnorm / denom } else { rnorm };
            if rel <= PROBE_TOL || iter == max_iters || !rel.is_finite() {
                break;
            }
            crate::numeric::trisolve::lower_unit_solve(&self.factors.lu, &mut r);
            crate::numeric::trisolve::upper_solve(&self.factors.lu, &mut r);
            for (yi, &di) in y.iter_mut().zip(r.iter()) {
                *yi += di;
            }
            self.stats.robustness.refine_iters += 1;
        }
        self.ws.resid = r;
        rel
    }

    /// Repair probe: factor-quality check used by the ladder. Solves a
    /// fixed all-ones rhs through the current factors and refines it
    /// against the true stamped values; the returned scaled residual says
    /// whether the (perturbed/re-equilibrated) factors reproduce the
    /// actual matrix to acceptance.
    fn probe_residual(&mut self) -> f64 {
        let n = self.stats.n;
        let b0 = vec![1.0; n];
        let mut y = b0.clone();
        crate::numeric::trisolve::lower_unit_solve(&self.factors.lu, &mut y);
        crate::numeric::trisolve::upper_solve(&self.factors.lu, &mut y);
        self.refine_in_place(&b0, &mut y, REFINE_MAX_PROBE)
    }

    /// Refactor with new values on the *same sparsity pattern* (the
    /// Newton–Raphson iteration pattern). Preprocessing, symbolic analysis
    /// and levelization are all reused; only the numeric kernel reruns —
    /// **in place** over the existing factor storage, through solver-owned
    /// scratch, so the hot loop performs no `O(nnz)` allocation.
    ///
    /// Singular or badly-grown values do **not** discard the solver: the
    /// numeric robustness ladder repairs them in place, keeping every
    /// piece of symbolic state (plan, scatter map, launch schedule):
    ///
    /// 1. plain refactorization with pivot-growth monitoring;
    /// 2. on a zero/tiny pivot or excessive growth, a small diagonal
    ///    perturbation plus an iterative-refinement probe against the true
    ///    values (refinement then stays active for subsequent solves);
    /// 3. if refinement stalls, escalation: fresh Ruiz equilibration of
    ///    the new values on the *fixed* permutations, then one more
    ///    attempt (plain, then perturbed);
    /// 4. when the fixed order itself is unsalvageable, the rung-5
    ///    **pivot rescue**: a threshold partial-pivoting factorization
    ///    ([`crate::numeric::pivlu`]) re-permutes the rows, and the whole
    ///    static pipeline — filled pattern, dependency levels, plan,
    ///    scatter map, launch schedule — is rebuilt in place on the
    ///    rescued ordering (recorded in [`RobustnessStats::rescues`];
    ///    subsequent refactors run the normal fast path, no re-rescue);
    /// 5. only then a typed [`GluError::NumericallySingular`] — the matrix
    ///    is singular under *every* row order; the solver stays poisoned
    ///    until a later refactor succeeds, but its symbolic state remains
    ///    reusable.
    pub fn refactor(&mut self, a: &crate::sparse::Csc) -> anyhow::Result<()> {
        anyhow::ensure!(
            a.nnz() == self.value_map.len() && a.nrows() == self.stats.n,
            "refactor requires the original sparsity pattern"
        );
        self.stamp_fresh(a);
        let mut max_stamp = max_abs(&self.ws.fresh);
        let mut bad_col = 0usize;

        // Rung 0: plain refactorization, growth-monitored.
        let mut mon = PivotMonitor::new();
        match self.run_numeric(0.0, &mut mon) {
            Ok(run) => {
                if mon.growth(max_stamp) <= GROWTH_LIMIT
                    && mon.condition_estimate() <= COND_LIMIT
                {
                    self.perturb_eps = 0.0; // clean factors: refinement off
                    self.finish_run(run, &mon, max_stamp, 0.0);
                    return Ok(());
                }
                // Factored, but the monitor flagged the run — repair.
            }
            Err(e) => match e.downcast_ref::<GluError>() {
                Some(GluError::NumericallySingular { col }) => bad_col = *col,
                // Structural failure (not values): the ladder cannot help.
                _ => return Err(self.fail_numeric(e)),
            },
        }

        // Rung 1: diagonal perturbation + iterative-refinement probe.
        if let Some((run, rel)) = self.try_perturbed(max_stamp, &mut mon, &mut bad_col) {
            self.finish_run(run, &mon, max_stamp, rel);
            return Ok(());
        }

        // Rung 2: escalation — re-equilibrate the new values on the fixed
        // permutations (the pattern, plan and schedules stay untouched).
        self.stats.robustness.escalations += 1;
        let (rs, cs) = crate::order::mc64::ruiz_scale(a, 5);
        self.pre.row_scale = sanitize_scales(rs);
        self.pre.col_scale = sanitize_scales(cs);
        self.apply_scales = true;
        self.stamp_fresh(a);
        max_stamp = max_abs(&self.ws.fresh);

        mon = PivotMonitor::new();
        match self.run_numeric(0.0, &mut mon) {
            Ok(run) => {
                let rel = self.probe_residual();
                if rel <= PROBE_TOL {
                    self.perturb_eps = 0.0;
                    self.stats.robustness.repairs += 1;
                    self.finish_run(run, &mon, max_stamp, rel);
                    return Ok(());
                }
            }
            Err(e) => match e.downcast_ref::<GluError>() {
                Some(GluError::NumericallySingular { col }) => bad_col = *col,
                _ => return Err(self.fail_numeric(e)),
            },
        }
        if let Some((run, rel)) = self.try_perturbed(max_stamp, &mut mon, &mut bad_col) {
            self.finish_run(run, &mon, max_stamp, rel);
            return Ok(());
        }

        // Rung 5: the fixed-order ladder is exhausted — threshold partial
        // pivoting as a last resort. On success the solver's symbolic
        // state has been hot-swapped onto the rescued row order; on
        // failure the error is terminal and typed, so callers (the pool)
        // can tell repairable-numeric from structural and keep the cached
        // symbolic state for the next refactor.
        match self.try_rescue(a, bad_col) {
            Ok(()) => Ok(()),
            Err(e) => Err(self.fail_numeric(e)),
        }
    }

    /// Refactor a batch of matrices sharing the *same sparsity pattern* —
    /// the transient-analysis shape, where one levelized schedule serves B
    /// Newton-step Jacobians. Returns one factored value plane per input
    /// matrix; the last plane is also installed as the solver's current
    /// factors (so `refactor_batch(&[a])` ends in the same state as
    /// `refactor(a)`).
    ///
    /// On the batched engines — parallel right-looking and the schedule
    /// executor — the whole batch rides **one** schedule walk over a
    /// [`ValuePlanes`] block: the scatter-map indices are read once per
    /// task and the inner MAC loop runs over the contiguous plane
    /// dimension. Per plane the operation order replays the single-plane
    /// kernel exactly, so each plane is bit-identical to its looped
    /// [`GluSolver::refactor`] at one worker thread (rounding-level at
    /// more). Engines without a batched kernel, and any batch the batched
    /// rung-0 attempt cannot factor cleanly, fall back to looping
    /// [`GluSolver::refactor`] per plane — full robustness ladder
    /// included.
    pub fn refactor_batch(
        &mut self,
        mats: &[&crate::sparse::Csc],
    ) -> anyhow::Result<ValuePlanes> {
        anyhow::ensure!(!mats.is_empty(), "empty refactor batch");
        for a in mats {
            anyhow::ensure!(
                a.nnz() == self.value_map.len() && a.nrows() == self.stats.n,
                "refactor_batch requires the original sparsity pattern"
            );
        }
        let nnz = self.sym.filled.nnz();
        let b = mats.len();

        // Batched rung 0: stamp every plane, one schedule walk. The
        // growth/condition gates run on the merged monitor — any flagged
        // plane (or singular pivot) drops the whole batch to the looped
        // ladder below, which repairs plane by plane.
        if b > 1 && self.batched_kernel_available() {
            let mut planes = ValuePlanes::new(b, nnz);
            let mut max_stamp = 0.0f64;
            for (p, a) in mats.iter().enumerate() {
                self.stamp_fresh(a);
                max_stamp = max_stamp.max(max_abs(&self.ws.fresh));
                planes.set_plane(p, &self.ws.fresh);
            }
            // ws.fresh now holds the last plane's stamp — the refinement /
            // probe baseline for the installed factors.
            let mut mon = PivotMonitor::new();
            if let Ok(run) = self.run_numeric_planes(&mut planes, &mut mon) {
                if mon.growth(max_stamp) <= GROWTH_LIMIT && mon.condition_estimate() <= COND_LIMIT
                {
                    planes.copy_plane(b - 1, self.factors.lu.values_mut());
                    self.perturb_eps = 0.0;
                    self.finish_run(run, &mon, max_stamp, 0.0);
                    // one kernel run per plane, matching the looped path's
                    // accounting (finish_run counted the first).
                    self.stats.numeric_runs += b - 1;
                    return Ok(planes);
                }
            }
        }

        // Looped fallback: the full ladder per plane. A terminal failure
        // propagates (and poisons the solver) exactly as `refactor` does.
        let mut planes = ValuePlanes::new(b, nnz);
        for (p, a) in mats.iter().enumerate() {
            self.refactor(a)?;
            planes.set_plane(p, self.factors.lu.values());
        }
        Ok(planes)
    }

    /// Whether the resolved engine has a batched value-plane kernel.
    fn batched_kernel_available(&self) -> bool {
        match &self.engine {
            NumericEngine::ParallelRightLooking { .. } => self.ws.pool.is_some(),
            NumericEngine::Schedule { .. } => self.ws.executor.is_some(),
            _ => false,
        }
    }

    /// One batched kernel run over `planes` (already stamped), in the
    /// shape of [`rerun_engine`]. Only called for engines
    /// [`GluSolver::batched_kernel_available`] approves.
    fn run_numeric_planes(
        &mut self,
        planes: &mut ValuePlanes,
        mon: &mut PivotMonitor,
    ) -> anyhow::Result<EngineRun> {
        let t0 = std::time::Instant::now();
        match &self.engine {
            NumericEngine::ParallelRightLooking { .. } => {
                parrl::refactor_planes(
                    &self.sym.filled,
                    planes,
                    &self.plan,
                    self.ws.pool.as_ref().expect("pool spawned for parallel engine"),
                    mon,
                )?;
                Ok((None, wall_ms(t0), None))
            }
            NumericEngine::Schedule { .. } => {
                let executor = self
                    .ws
                    .executor
                    .as_mut()
                    .expect("executor created for schedule engine");
                let report = executor.execute_planes(self.plan.launch_schedule(), planes, mon)?;
                Ok((None, wall_ms(t0), Some(report)))
            }
            _ => unreachable!("batched kernel availability checked by the caller"),
        }
    }

    /// Ladder rung 5 — the last resort, reached only after perturbation
    /// and re-equilibration both failed. Factor the matrix in the solver's
    /// current permuted/scaled domain with threshold partial pivoting,
    /// then rebuild the entire static pipeline — filled pattern,
    /// dependency levels, [`FactorPlan`], workspace, value/diag maps — on
    /// the rescued row order and hot-swap it into `self`. Nothing in the
    /// solver is mutated until the rescue factorization and the rebuilt
    /// engine run have both succeeded, so a failed rescue leaves the old
    /// (still-consistent) symbolic state in place for the next refactor.
    fn try_rescue(&mut self, a: &crate::sparse::Csc, bad_col: usize) -> anyhow::Result<()> {
        let t0 = std::time::Instant::now();
        // The matrix whose static order just failed, in the solver's
        // permuted/scaled domain: pivoting *within* this domain preserves
        // the fill-reducing column order and whatever equilibration the
        // escalation rung installed.
        let cur = a.permute_scale(
            self.pre.row_perm.as_scatter(),
            self.pre.col_perm.as_scatter(),
            self.apply_scales.then(|| self.pre.row_scale.as_slice()),
            self.apply_scales.then(|| self.pre.col_scale.as_slice()),
        );
        let mut mon = PivotMonitor::new();
        let rescued = match pivlu::factor(&cur, pivlu::DEFAULT_PIVOT_TOL, &mut mon) {
            Ok(r) => r,
            Err(e) => {
                // Singular under every row order: terminal for real.
                let col = match e.downcast_ref::<GluError>() {
                    Some(GluError::NumericallySingular { col }) => *col,
                    _ => bad_col,
                };
                return Err(anyhow::Error::with_payload(
                    format!(
                        "numeric robustness ladder exhausted: zero/non-finite \
                         pivot at column {bad_col} persisted through diagonal \
                         perturbation and re-equilibration, and the threshold \
                         partial-pivoting rescue found no admissible pivot at \
                         column {col}"
                    ),
                    GluError::NumericallySingular { col },
                ));
            }
        };

        // The discovered pattern *is* the no-pivot symbolic fill of the
        // rescued row order (the Gilbert–Peierls reach argument), so the
        // symbolic phase here is a pattern transplant: zero the factor
        // values and restamp the matrix entries through the new order.
        let n = self.stats.n;
        let perm = rescued.row_perm.as_scatter();
        let mut filled = rescued.lu.clone();
        for v in filled.values_mut() {
            *v = 0.0;
        }
        for c in 0..n {
            let (rows, vals) = cur.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                let idx = filled
                    .entry_index(perm[r], c)
                    .expect("input entry missing from the rescued pattern");
                filled.values_mut()[idx] += v;
            }
        }
        let sym = SymbolicFill {
            filled,
            fill_count: rescued.fill_count,
        };
        let deps = detect(self.opts.detection, &sym);
        let levels = levelize(&deps);
        let plan = FactorPlan::from_levels(&sym, levels, &self.opts.policy, &self.opts.device);
        let engine = resolve_engine(&self.opts.engine, self.opts.detection, &plan);
        // A fresh workspace (and pool, for the multi-threaded engines):
        // the old one stays untouched until the rescue commits.
        let mut ws = NumericWorkspace::new(&engine, &sym, None)?;
        let mut run_mon = PivotMonitor::new();
        let (factors, sim, numeric_ms, exec) =
            match run_engine(&engine, &plan, &sym, &mut ws, &mut run_mon) {
                Ok(run) => run,
                Err(e) => {
                    return Err(anyhow::Error::with_payload(
                        format!(
                            "numeric robustness ladder exhausted: the threshold \
                             partial-pivoting rescue factored the matrix but the \
                             rebuilt static pipeline could not reproduce it: {e:#}"
                        ),
                        GluError::NumericallySingular { col: bad_col },
                    ));
                }
            };

        // Commit: compose the rescued row order into the preprocessing
        // transform and install the rebuilt state. The original structural
        // identity of the pattern is unchanged — the pool's cache key and
        // near-miss scans still see the same matrix structure.
        self.pre.row_perm = self.pre.row_perm.then(&rescued.row_perm);
        let ident: Vec<usize> = (0..n).collect();
        self.pre.a = cur.permute(perm, &ident);
        self.sym = sym;
        self.plan = plan;
        self.factors = factors;
        self.ws = ws;
        self.engine = engine;
        self.ws.fresh.copy_from_slice(self.sym.filled.values());
        let max_stamp = max_abs(&self.ws.fresh);
        self.diag_map = (0..n)
            .map(|j| self.sym.filled.entry_index(j, j).unwrap_or(usize::MAX))
            .collect();
        self.value_map = build_value_map(a, &self.pre, &self.sym);
        self.perturb_eps = 0.0;

        self.stats.nnz = self.sym.filled.nnz();
        self.stats.num_levels = self.plan.num_levels();
        self.stats.max_level_size = self.plan.levels().max_level_size();
        self.stats.symbolic_runs += 1;
        self.stats.plan_builds += 1;
        self.stats.resolved_engine = format!("{:?}", self.engine);
        self.stats.robustness.rescues += 1;
        self.stats.robustness.rescued_pivots += rescued.swapped_pivots as u64;
        self.stats.robustness.rescue_ms = wall_ms(t0);

        // Acceptance probe, exactly like the lower rungs: the rebuilt
        // factors must reproduce the true stamped values. On failure the
        // caller poisons the solver; the rescued symbolic state stays
        // installed and consistent, so the next refactor retries on it.
        let rel = self.probe_residual();
        if rel > PROBE_TOL {
            return Err(anyhow::Error::with_payload(
                format!(
                    "numeric robustness ladder exhausted: the partial-pivoting \
                     rescue probe residual {rel:.3e} exceeds {PROBE_TOL:.0e}"
                ),
                GluError::NumericallySingular { col: bad_col },
            ));
        }
        let mut full_mon = run_mon;
        full_mon.merge(&mon);
        self.finish_run((sim, numeric_ms, exec), &full_mon, max_stamp, rel);
        Ok(())
    }

    /// Ladder rung 1 (shared with rung 2's second attempt): refactor with a
    /// relative diagonal perturbation, probe with iterative refinement, and
    /// accept only when the probe residual meets [`PROBE_TOL`]. On success
    /// the perturbation magnitude is recorded so solves keep refining.
    fn try_perturbed(
        &mut self,
        max_stamp: f64,
        mon: &mut PivotMonitor,
        bad_col: &mut usize,
    ) -> Option<(EngineRun, f64)> {
        self.stats.robustness.perturbations += 1;
        let eps = PERTURB_REL * max_stamp.max(f64::MIN_POSITIVE);
        *mon = PivotMonitor::new();
        match self.run_numeric(eps, mon) {
            Ok(run) => {
                let rel = self.probe_residual();
                if rel <= PROBE_TOL {
                    self.perturb_eps = eps;
                    self.stats.robustness.repairs += 1;
                    return Some((run, rel));
                }
                None
            }
            Err(e) => {
                if let Some(GluError::NumericallySingular { col }) = e.downcast_ref::<GluError>()
                {
                    *bad_col = *col;
                }
                None
            }
        }
    }

    /// Zero the solver-owned scatter buffer and restamp `a`'s (optionally
    /// scaled) values through the precomputed map — fill positions stay
    /// zero.
    fn stamp_fresh(&mut self, a: &crate::sparse::Csc) {
        for v in self.ws.fresh.iter_mut() {
            *v = 0.0;
        }
        let fresh = &mut self.ws.fresh;
        let rs = &self.pre.row_scale;
        let cs = &self.pre.col_scale;
        let apply = self.apply_scales;
        let mut pos = 0usize;
        for c in 0..a.ncols() {
            let (rows, vals) = a.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                let scaled = if apply { v * rs[r] * cs[c] } else { v };
                fresh[self.value_map[pos]] += scaled;
                pos += 1;
            }
        }
    }

    /// Stamp the factor storage from `ws.fresh` (plus an optional diagonal
    /// perturbation of magnitude `eps`, signed to reinforce the stamped
    /// diagonal) and rerun the engine in place.
    fn run_numeric(&mut self, eps: f64, mon: &mut PivotMonitor) -> anyhow::Result<EngineRun> {
        self.factors.lu.values_mut().copy_from_slice(&self.ws.fresh);
        if eps > 0.0 {
            let vals = self.factors.lu.values_mut();
            for &idx in &self.diag_map {
                if idx != usize::MAX {
                    let d = vals[idx];
                    vals[idx] = if d >= 0.0 { d + eps } else { d - eps };
                }
            }
        }
        rerun_engine(
            &self.engine,
            &self.plan,
            &mut self.factors.lu,
            &mut self.ws,
            mon,
        )
    }

    /// Commit a successful numeric run into the stats block.
    fn finish_run(&mut self, run: EngineRun, mon: &PivotMonitor, max_stamp: f64, rel: f64) {
        let (sim, numeric_ms, exec) = run;
        self.poisoned = false;
        self.stats.numeric_ms = numeric_ms;
        self.stats.sim = sim;
        self.stats.exec = exec;
        self.stats.numeric_runs += 1;
        // Stay 1 forever after the first consuming run — the refactor fast
        // path rebuilds neither the scatter map nor the lowered schedule.
        self.stats.scatter_builds = self.plan.scatter_builds();
        self.stats.schedule_builds = self.plan.schedule_builds();
        self.stats.robustness.pivot_growth = mon.growth(max_stamp);
        self.stats.robustness.condition_estimate = mon.condition_estimate();
        self.stats.robustness.min_abs_pivot = if mon.min_abs_pivot.is_finite() {
            mon.min_abs_pivot
        } else {
            0.0
        };
        self.stats.robustness.last_residual = rel;
    }

    /// Terminal numeric failure: the in-place kernel may have left the
    /// factors partially updated — refuse solves until a refactor succeeds,
    /// and scrub the run-scoped stats so a poisoned solver never reports
    /// stale kernel timings as if they described the current factors.
    fn fail_numeric(&mut self, e: anyhow::Error) -> anyhow::Error {
        self.poisoned = true;
        self.stats.numeric_ms = f64::NAN;
        self.stats.sim = None;
        self.stats.exec = None;
        e
    }

    /// Factorization statistics.
    pub fn stats(&self) -> &GluStats {
        &self.stats
    }

    /// Whether the last refactor failed partway (factors are garbage and
    /// solves are refused until a refactor succeeds). The pool's near-miss
    /// scan must not patch from a poisoned solver's symbolic state.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// Whether a rung-5 pivot rescue has rebuilt this solver's symbolic
    /// state on a new row order. The cached pattern key is unchanged (the
    /// input structure is the same), but the internal plan/permutation no
    /// longer match what the cold pipeline would build — so the near-miss
    /// delta patcher must not use it as a base.
    pub fn is_rescued(&self) -> bool {
        self.stats.robustness.rescues > 0
    }

    /// The level schedule (Fig. 10 / Table III analysis).
    pub fn levels(&self) -> &Levels {
        self.plan.levels()
    }

    /// The mode-annotated [`FactorPlan`] — the schedule IR every backend
    /// (simulator, CPU engines, trisolves, PJRT lowering) consumes. Built
    /// once at factor time; cloning it is cheap (shared `Arc`).
    pub fn plan(&self) -> &FactorPlan {
        &self.plan
    }

    /// The symbolic fill result.
    pub fn symbolic(&self) -> &SymbolicFill {
        &self.sym
    }

    /// The LU factors (permuted/scaled domain).
    pub fn factors(&self) -> &LuFactors {
        &self.factors
    }

    /// The L/U row-level schedules the parallel triangular solves run on —
    /// `Some` when a multi-thread engine is configured *and* the schedules
    /// are wide enough for the parallel path to win (narrow schedules keep
    /// the sequential solves). The schedules live on the plan
    /// ([`FactorPlan::trisolve`], built lazily); this accessor reports
    /// whether the parallel path is active.
    pub fn triangular_schedule(&self) -> Option<&TriangularSchedule> {
        match &self.ws.pool {
            Some(pool) if pool.threads() > 1 && self.plan.parallel_trisolve(&self.sym.filled) => {
                Some(self.plan.trisolve(&self.sym.filled))
            }
            _ => None,
        }
    }
}

/// Dispatch the configured detection algorithm.
pub fn detect(detection: Detection, sym: &SymbolicFill) -> DepGraph {
    match detection {
        Detection::Glu1 => glu1::detect(&sym.filled),
        Detection::Glu2 => glu2::detect(&sym.filled),
        Detection::Glu3 => glu3::detect(&sym.filled),
    }
}

/// One cold symbolic pass — fill, detection, levelization — wave-parallel
/// on `pool` when present. With GLU3.0 detection the parallel engine fuses
/// detection + levelization into the assembly sweep; other detection modes
/// parallelize the fill and batch-process the pattern afterwards. Returns
/// the filled pattern, the level schedule, `[fillin_ms, detect_ms,
/// levelize_ms]`, and whether the parallel engine ran. The triple is
/// bit-identical across every variant and thread count.
fn run_symbolic(
    a: &crate::sparse::Csc,
    detection: Detection,
    pool: Option<&WorkerPool>,
    fws: &mut FillWorkspace,
) -> anyhow::Result<(SymbolicFill, Levels, [f64; 3], bool)> {
    if let Some(pool) = pool {
        if detection == Detection::Glu3 {
            let par = parallel_symbolic(a, pool, fws)?;
            return Ok((
                par.sym,
                par.levels,
                [par.fillin_ms, par.detect_ms, par.levelize_ms],
                true,
            ));
        }
        let (sym, fillin_ms) = parallel_fill(a, pool, fws)?;
        let t1 = std::time::Instant::now();
        let deps = detect(detection, &sym);
        let detect_ms = wall_ms(t1);
        let t2 = std::time::Instant::now();
        let levels = levelize(&deps);
        return Ok((sym, levels, [fillin_ms, detect_ms, wall_ms(t2)], true));
    }
    let t0 = std::time::Instant::now();
    let sym = symbolic_fill_with(a, fws)?;
    let fillin_ms = wall_ms(t0);
    let t1 = std::time::Instant::now();
    let deps = detect(detection, &sym);
    let detect_ms = wall_ms(t1);
    let t2 = std::time::Instant::now();
    let levels = levelize(&deps);
    Ok((sym, levels, [fillin_ms, detect_ms, wall_ms(t2)], false))
}

fn wall_ms(t0: std::time::Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3
}

/// Ladder thresholds. Growth and condition limits are deliberately loose —
/// they exist to catch runs that are numerically *doomed* (exact or
/// near-exact cancellation), not to second-guess moderately conditioned
/// circuit matrices the no-pivot regime handles fine.
const GROWTH_LIMIT: f64 = 1e12;
const COND_LIMIT: f64 = 1e14;
/// Relative diagonal perturbation (× max |stamped value|) — SuperLU's
/// `√ε·‖A‖` neighborhood: big enough that perturbed pivots divide safely,
/// small enough that refinement converges when the matrix itself is fine.
const PERTURB_REL: f64 = 1e-8;
/// Probe acceptance: scaled residual the repaired factors must reach.
const PROBE_TOL: f64 = 1e-9;
/// Refinement iteration caps (probe at repair time / every solve after).
const REFINE_MAX_PROBE: usize = 10;
const REFINE_MAX_SOLVE: usize = 6;

/// What one engine run returns beyond the factors themselves.
type EngineRun = (Option<SimReport>, f64, Option<ExecReport>);

/// Largest magnitude in a value buffer (0.0 for all-zero input).
fn max_abs(vals: &[f64]) -> f64 {
    vals.iter().fold(0.0f64, |m, v| m.max(v.abs()))
}

/// Replace non-finite or non-positive equilibration factors with 1.0 —
/// Ruiz on degenerate values (zero rows/columns) must never install a
/// scale that poisons every later stamp.
fn sanitize_scales(mut s: Vec<f64>) -> Vec<f64> {
    for v in s.iter_mut() {
        if !v.is_finite() || *v <= 0.0 {
            *v = 1.0;
        }
    }
    s
}

/// The left-looking engines check pivots inline but never see them twice;
/// feed the factored diagonal through the monitor after the fact so the
/// ladder's growth/condition gates work identically on every engine.
fn observe_diagonal(lu: &crate::sparse::Csc, mon: &mut PivotMonitor) {
    for j in 0..lu.ncols() {
        let (rows, vals) = lu.col(j);
        if let Ok(p) = rows.binary_search(&j) {
            mon.observe(vals[p]);
        }
    }
}

/// Initial factorization through the engine, using (and warming) the
/// solver workspace. Every schedule-consuming engine reads the shared
/// [`FactorPlan`]; only the U-pattern left-looking baseline keeps its own
/// (different) schedule in the workspace. `mon` collects the pivot extrema
/// for the robustness ladder on every path.
fn run_engine(
    engine: &NumericEngine,
    plan: &FactorPlan,
    sym: &SymbolicFill,
    ws: &mut NumericWorkspace,
    mon: &mut PivotMonitor,
) -> anyhow::Result<(LuFactors, Option<SimReport>, f64, Option<ExecReport>)> {
    let t0 = std::time::Instant::now();
    match engine {
        NumericEngine::SimulatedGpu => {
            let mut lu = sym.filled.clone();
            let report = simulate_refactorization(&mut lu, plan, &mut ws.lvals, mon)?;
            let ms = report.kernel_ms();
            Ok((LuFactors { lu }, Some(report), ms, None))
        }
        NumericEngine::LeftLookingCpu => {
            let mut lu = sym.filled.clone();
            leftlook::factor_in_place(&mut lu, &mut ws.works[0], mon)?;
            Ok((LuFactors { lu }, None, wall_ms(t0), None))
        }
        NumericEngine::RightLookingCpu => {
            let mut lu = sym.filled.clone();
            rightlook::factor_in_place(&mut lu, plan.urow(), &mut ws.lvals, mon)?;
            Ok((LuFactors { lu }, None, wall_ms(t0), None))
        }
        NumericEngine::ParallelCpu { .. } => {
            let factors = parlu::factor_with(
                sym,
                ws.ll_levels.as_ref().expect("U-pattern schedule cached"),
                ws.pool.as_ref().expect("pool spawned for parallel engine"),
                &mut ws.works,
            )?;
            observe_diagonal(&factors.lu, mon);
            Ok((factors, None, wall_ms(t0), None))
        }
        NumericEngine::ParallelRightLooking { .. } => {
            let factors = parrl::factor_with(
                sym,
                plan,
                ws.pool.as_ref().expect("pool spawned for parallel engine"),
            )?;
            observe_diagonal(&factors.lu, mon);
            Ok((factors, None, wall_ms(t0), None))
        }
        NumericEngine::Schedule { .. } => {
            let executor = ws.executor.as_mut().expect("executor created for schedule engine");
            // Pattern time, paid once: build/fetch the scatter map, bind
            // it on the device, lower the schedule (cached on the plan).
            executor.upload_pattern(plan, plan.scatter(&sym.filled))?;
            let sched = plan.launch_schedule();
            let mut lu = sym.filled.clone();
            let report = executor.execute(sched, lu.values_mut(), mon)?;
            Ok((LuFactors { lu }, None, wall_ms(t0), Some(report)))
        }
        NumericEngine::Auto { .. } => unreachable!("Auto is resolved before the workspace exists"),
    }
}

/// Refactorization through the engine, **in place** over `lu` (already
/// stamped with the new values). No `O(nnz)` allocation on any path — the
/// plan is reused as-is. `mon` collects pivot extrema for the ladder.
fn rerun_engine(
    engine: &NumericEngine,
    plan: &FactorPlan,
    lu: &mut crate::sparse::Csc,
    ws: &mut NumericWorkspace,
    mon: &mut PivotMonitor,
) -> anyhow::Result<EngineRun> {
    let t0 = std::time::Instant::now();
    match engine {
        NumericEngine::SimulatedGpu => {
            let report = simulate_refactorization(lu, plan, &mut ws.lvals, mon)?;
            let ms = report.kernel_ms();
            Ok((Some(report), ms, None))
        }
        NumericEngine::LeftLookingCpu => {
            leftlook::factor_in_place(lu, &mut ws.works[0], mon)?;
            Ok((None, wall_ms(t0), None))
        }
        NumericEngine::RightLookingCpu => {
            rightlook::factor_in_place(lu, plan.urow(), &mut ws.lvals, mon)?;
            Ok((None, wall_ms(t0), None))
        }
        NumericEngine::ParallelCpu { .. } => {
            parlu::refactor_in_place(
                lu,
                ws.ll_levels.as_ref().expect("U-pattern schedule cached"),
                ws.pool.as_ref().expect("pool spawned for parallel engine"),
                &mut ws.works,
            )?;
            observe_diagonal(lu, mon);
            Ok((None, wall_ms(t0), None))
        }
        NumericEngine::ParallelRightLooking { .. } => {
            parrl::refactor_in_place(
                lu,
                plan,
                ws.pool.as_ref().expect("pool spawned for parallel engine"),
                mon,
            )?;
            Ok((None, wall_ms(t0), None))
        }
        NumericEngine::Schedule { .. } => {
            let executor = ws.executor.as_mut().expect("executor created for schedule engine");
            // The pattern is already device-resident and the schedule
            // cached — the refactor hot path is a pure re-execution.
            let report = executor.execute(plan.launch_schedule(), lu.values_mut(), mon)?;
            Ok((None, wall_ms(t0), Some(report)))
        }
        NumericEngine::Auto { .. } => unreachable!("Auto is resolved before the workspace exists"),
    }
}

/// For each stored entry of `a` (CSC order), the index of its destination
/// in the filled pattern's value array after row/col permutation.
fn build_value_map(
    a: &crate::sparse::Csc,
    pre: &Preprocessed,
    sym: &SymbolicFill,
) -> Vec<usize> {
    let pr = pre.row_perm.as_scatter();
    let pc = pre.col_perm.as_scatter();
    let mut map = Vec::with_capacity(a.nnz());
    for c in 0..a.ncols() {
        let (rows, _) = a.col(c);
        for &r in rows {
            let (nr, nc) = (pr[r], pc[c]);
            let idx = sym
                .filled
                .entry_index(nr, nc)
                .expect("original entry missing from filled pattern");
            map.push(idx);
        }
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::residual;
    use crate::sparse::gen;

    #[test]
    fn full_pipeline_solves() {
        let a = gen::netlist(500, 6, 16, 0.05, 4, 0.2, 42);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let b: Vec<f64> = (0..500).map(|i| ((i % 13) as f64) - 6.0).collect();
        let x = s.solve(&b).unwrap();
        // n=500 hub netlist: condition ~1e5; 1e-7 relative is the right
        // acceptance here (oracle-equality is asserted elsewhere).
        assert!(residual(&a, &x, &b) < 1e-7, "residual {}", residual(&a, &x, &b));
        let st = s.stats();
        assert!(st.nnz >= st.nz);
        assert!(st.num_levels > 1);
        assert!(st.sim.is_some());
    }

    #[test]
    fn all_engines_agree() {
        let a = gen::grid2d(15, 15, 3);
        let b: Vec<f64> = (0..225).map(|i| (i as f64).sin()).collect();
        let mut xs = Vec::new();
        for engine in [
            NumericEngine::SimulatedGpu,
            NumericEngine::LeftLookingCpu,
            NumericEngine::RightLookingCpu,
            NumericEngine::ParallelCpu { threads: 3 },
            NumericEngine::ParallelRightLooking { threads: 3 },
            NumericEngine::Schedule {
                backend: ExecBackend::Virtual,
            },
        ] {
            let opts = GluOptions {
                engine,
                ..Default::default()
            };
            let mut s = GluSolver::factor(&a, &opts).unwrap();
            xs.push(s.solve(&b).unwrap());
        }
        for x in &xs[1..] {
            for (p, q) in x.iter().zip(&xs[0]) {
                assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
            }
        }
    }

    #[test]
    fn parallel_right_looking_matches_simulated_gpu_values() {
        let a = gen::netlist(300, 6, 12, 0.06, 3, 0.2, 901);
        let mut sim = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        for threads in [1, 2, 4] {
            let opts = GluOptions {
                engine: NumericEngine::ParallelRightLooking { threads },
                ..Default::default()
            };
            let mut par = GluSolver::factor(&a, &opts).unwrap();
            for (p, q) in par
                .factors()
                .lu
                .values()
                .iter()
                .zip(sim.factors().lu.values())
            {
                assert!(
                    (p - q).abs() < 1e-9 * (1.0 + q.abs()),
                    "threads {threads}: {p} vs {q}"
                );
            }
            // and the solve paths (parallel trisolve for threads > 1)
            let b = vec![1.0; 300];
            let xp = par.solve(&b).unwrap();
            let xs = sim.solve(&b).unwrap();
            for (p, q) in xp.iter().zip(&xs) {
                assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
            }
        }
    }

    #[test]
    fn parallel_right_looking_rejects_glu1_schedule() {
        let a = gen::netlist(100, 5, 8, 0.1, 1, 0.2, 7);
        let opts = GluOptions {
            detection: Detection::Glu1,
            engine: NumericEngine::ParallelRightLooking { threads: 2 },
            ..Default::default()
        };
        let err = GluSolver::factor(&a, &opts).unwrap_err();
        assert!(err.to_string().contains("hazard"), "{err}");
    }

    #[test]
    fn refactor_newton_raphson_pattern() {
        let a = gen::netlist(300, 5, 12, 0.05, 2, 0.2, 11);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let b = vec![1.0; 300];
        let x0 = s.solve(&b).unwrap();
        assert!(residual(&a, &x0, &b) < 1e-10);

        // Same pattern, perturbed values (a Newton step's new Jacobian).
        let mut a2 = a.clone();
        for v in a2.values_mut() {
            *v *= 1.25;
        }
        s.refactor(&a2).unwrap();
        let x1 = s.solve(&b).unwrap();
        assert!(residual(&a2, &x1, &b) < 1e-10);
        // And x1 should differ from x0 (values changed).
        assert!(x1.iter().zip(&x0).any(|(p, q)| (p - q).abs() > 1e-9));

        // Refactor back to the original values reproduces x0.
        s.refactor(&a).unwrap();
        let x2 = s.solve(&b).unwrap();
        for (p, q) in x2.iter().zip(&x0) {
            assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
        }
    }

    #[test]
    fn refactor_matches_fresh_factor_on_every_engine() {
        let a = gen::netlist(220, 6, 10, 0.06, 2, 0.2, 23);
        let mut a2 = a.clone();
        for v in a2.values_mut() {
            *v *= 1.1;
        }
        for engine in [
            NumericEngine::SimulatedGpu,
            NumericEngine::LeftLookingCpu,
            NumericEngine::RightLookingCpu,
            NumericEngine::ParallelCpu { threads: 4 },
            NumericEngine::ParallelRightLooking { threads: 4 },
            NumericEngine::Schedule {
                backend: ExecBackend::Virtual,
            },
        ] {
            let opts = GluOptions {
                engine: engine.clone(),
                ..Default::default()
            };
            let mut s = GluSolver::factor(&a, &opts).unwrap();
            s.refactor(&a2).unwrap();
            let fresh = GluSolver::factor(&a2, &opts).unwrap();
            for (p, q) in s
                .factors()
                .lu
                .values()
                .iter()
                .zip(fresh.factors().lu.values())
            {
                // identical for deterministic engines, rounding-level for
                // the CAS-accumulating parallel right-looking engine
                assert!(
                    (p - q).abs() < 1e-9 * (1.0 + q.abs()),
                    "{engine:?}: {p} vs {q}"
                );
            }
            assert_eq!(s.stats().numeric_runs, 2);
            assert_eq!(s.stats().symbolic_runs, 1);
            // the refactor reused the plan — no rebuild on any engine
            assert_eq!(s.stats().plan_builds, 1);
        }
    }

    /// The solver's plan is the single source of mode decisions: the
    /// simulated report's histogram equals the plan's, and the per-stage
    /// preprocessing timings decompose consistently.
    #[test]
    fn plan_and_stage_timings_consistent() {
        let a = gen::netlist(400, 6, 12, 0.05, 3, 0.2, 71);
        let s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let st = s.stats();
        let sim = st.sim.as_ref().expect("simulated engine");
        assert_eq!(sim.level_distribution(), s.plan().mode_histogram());
        assert_eq!(s.plan().num_levels(), st.num_levels);
        assert!((st.levelization_ms - (st.detect_ms + st.levelize_ms)).abs() < 1e-9);
        assert!(
            (st.symbolic_ms - (st.fillin_ms + st.detect_ms + st.levelize_ms)).abs() < 1e-9,
            "symbolic_ms must decompose into its stages"
        );
        assert!(st.plan_ms >= 0.0);
        assert!(st.cpu_ms() >= st.preprocess_ms + st.symbolic_ms);
        assert_eq!(st.plan_builds, 1);
    }

    /// The pattern-time scatter map is built exactly once per solver by
    /// the indexed engine and reused by every refactor; engines that never
    /// consume it never pay for it.
    #[test]
    fn scatter_map_built_once_for_indexed_engine() {
        let a = gen::grid2d(20, 20, 7);
        let opts = GluOptions {
            engine: NumericEngine::ParallelRightLooking { threads: 2 },
            ..Default::default()
        };
        let mut s = GluSolver::factor(&a, &opts).unwrap();
        assert_eq!(s.stats().scatter_builds, 1);
        assert!(
            s.stats().atomic_commits_avoided > 0,
            "AMD mesh must have ownership/chain levels"
        );
        let mut a2 = a.clone();
        for v in a2.values_mut() {
            *v *= 1.2;
        }
        s.refactor(&a2).unwrap();
        s.refactor(&a).unwrap();
        assert_eq!(s.stats().numeric_runs, 3);
        assert_eq!(s.stats().scatter_builds, 1, "refactors must reuse the map");

        // the simulated engine never consumes the map — stays lazy
        let sim = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        assert_eq!(sim.stats().scatter_builds, 0);
    }

    /// The schedule engine through the VirtualDevice backend reproduces
    /// the simulated-GPU engine's factors bit for bit, its per-launch
    /// report reconciles with the simulator's cycle charges, and refactors
    /// re-execute the cached schedule (no re-lowering, no re-upload).
    #[test]
    fn schedule_engine_is_bit_identical_to_simulated_gpu() {
        let a = gen::grid2d(16, 16, 3);
        let mut sim = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let opts = GluOptions {
            engine: NumericEngine::Schedule {
                backend: ExecBackend::Virtual,
            },
            ..Default::default()
        };
        let mut sched = GluSolver::factor(&a, &opts).unwrap();
        assert_eq!(sched.factors().lu.values(), sim.factors().lu.values());
        {
            let st = sched.stats();
            assert_eq!(st.schedule_builds, 1);
            assert_eq!(st.scatter_builds, 1);
            let exec = st.exec.as_ref().expect("schedule engine must report");
            assert_eq!(exec.backend, "virtual");
            assert_eq!(exec.per_launch.len(), st.num_levels);
            assert!(exec.total_launches() >= st.num_levels as u64);
            let simrep = sim.stats().sim.as_ref().unwrap();
            assert_eq!(exec.simulated_cycles(), simrep.kernel_cycles);
            assert_eq!(exec.mode_histogram(), sim.plan().mode_histogram());
        }

        let mut a2 = a.clone();
        for v in a2.values_mut() {
            *v *= 1.3;
        }
        sched.refactor(&a2).unwrap();
        sim.refactor(&a2).unwrap();
        assert_eq!(sched.factors().lu.values(), sim.factors().lu.values());
        assert_eq!(sched.stats().schedule_builds, 1, "refactor must not re-lower");
        assert_eq!(sched.stats().scatter_builds, 1);
        assert_eq!(sched.stats().numeric_runs, 2);
        assert!(sched.stats().exec.is_some());

        let b = vec![1.0; 256];
        let x = sched.solve(&b).unwrap();
        assert!(residual(&a2, &x, &b) < 1e-10);
    }

    #[test]
    fn schedule_engine_rejects_glu1_schedule() {
        let a = gen::netlist(100, 5, 8, 0.1, 1, 0.2, 7);
        let opts = GluOptions {
            detection: Detection::Glu1,
            engine: NumericEngine::Schedule {
                backend: ExecBackend::Virtual,
            },
            ..Default::default()
        };
        let err = GluSolver::factor(&a, &opts).unwrap_err();
        assert!(err.to_string().contains("hazard"), "{err}");
    }

    /// Without the `xla` runtime the PJRT backend fails at factor time —
    /// cleanly, before any numeric work.
    #[cfg(not(feature = "xla"))]
    #[test]
    fn schedule_pjrt_backend_fails_cleanly_without_runtime() {
        let a = gen::grid2d(8, 8, 1);
        let opts = GluOptions {
            engine: NumericEngine::Schedule {
                backend: ExecBackend::Pjrt,
            },
            ..Default::default()
        };
        assert!(GluSolver::factor(&a, &opts).is_err());
    }

    #[test]
    fn failed_refactor_poisons_solver_until_repaired() {
        let a = gen::netlist(120, 5, 8, 0.1, 1, 0.2, 19);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let b = vec![1.0; 120];
        s.solve(&b).unwrap();

        // All-zero values: every pivot is zero — the refactor must fail...
        let mut bad = a.clone();
        for v in bad.values_mut() {
            *v = 0.0;
        }
        assert!(s.refactor(&bad).is_err());
        // ...and the solver refuses to serve the garbage factors.
        let err = s.solve(&b).unwrap_err();
        assert!(err.to_string().contains("stale"), "{err}");
        assert!(s.solve_many(&[b.clone()]).is_err());

        // A successful refactor repairs it.
        s.refactor(&a).unwrap();
        let x = s.solve(&b).unwrap();
        assert!(residual(&a, &x, &b) < 1e-10);
    }

    /// Tridiagonal DD fixture for the ladder tests: MC64's greedy matching
    /// and `FillOrdering::Natural` both resolve to the identity on it, so a
    /// value zeroed at `A(0,0)` at *refactor* time is guaranteed to land on
    /// the pivot of column 0 — no permutation can route around it. The
    /// matrix with the zeroed corner stays nonsingular (its determinant is
    /// minus the trailing block's), which is exactly the repairable case.
    fn tridiag(n: usize) -> crate::sparse::Csc {
        let mut coo = crate::sparse::Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, 4.0);
            if i + 1 < n {
                coo.push(i, i + 1, -1.0);
                coo.push(i + 1, i, -1.0);
            }
        }
        coo.to_csc()
    }

    fn ladder_opts() -> GluOptions {
        GluOptions {
            ordering: FillOrdering::Natural,
            scale: false,
            ..Default::default()
        }
    }

    /// The tentpole end-to-end: good → singular → good on one cached
    /// pattern. The zero pivot is repaired *in place* by rung 1 (diagonal
    /// perturbation + refinement probe) — zero extra symbolic runs, zero
    /// plan rebuilds, and the solve on the repaired factors meets the
    /// acceptance residual.
    #[test]
    fn ladder_repairs_zero_pivot_in_place() {
        let a = tridiag(64);
        let mut s = GluSolver::factor(&a, &ladder_opts()).unwrap();
        let b = vec![1.0; 64];
        let x_good = s.solve(&b).unwrap();
        assert!(residual(&a, &x_good, &b) < 1e-10);
        assert_eq!(s.stats().robustness.perturbations, 0);

        // Newton hands back the same pattern with A(0,0) == 0.
        let bad = gen::weaken_diagonal(&a, 64, 0.0);
        s.refactor(&bad).unwrap();
        let st = s.stats();
        assert_eq!(st.symbolic_runs, 1, "repair must not rerun symbolic");
        assert_eq!(st.plan_builds, 1, "repair must not replan");
        assert_eq!(st.numeric_runs, 2);
        assert_eq!(st.robustness.perturbations, 1);
        assert_eq!(st.robustness.repairs, 1);
        assert_eq!(st.robustness.escalations, 0);
        assert!(
            st.robustness.last_residual <= 1e-9,
            "probe residual {}",
            st.robustness.last_residual
        );

        // The repaired solve refines through the perturbed factors and
        // meets the acceptance bar against the *bad* matrix.
        let x_bad = s.solve(&b).unwrap();
        assert!(
            residual(&bad, &x_bad, &b) <= 1e-8,
            "repaired residual {}",
            residual(&bad, &x_bad, &b)
        );

        // Healthy values again: clean rung-0 run, refinement off.
        s.refactor(&a).unwrap();
        let st = s.stats();
        assert_eq!(st.numeric_runs, 3);
        assert_eq!(st.symbolic_runs, 1);
        assert_eq!(st.robustness.last_residual, 0.0);
        // lifetime counters persist
        assert_eq!(st.robustness.perturbations, 1);
        let x_again = s.solve(&b).unwrap();
        for (p, q) in x_again.iter().zip(&x_good) {
            assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
        }
    }

    /// Rung-2 coverage: one row mis-scaled by 1e100 trips the condition
    /// gate, the relative diagonal perturbation (~1e92) drowns the healthy
    /// rows so the refinement probe stalls, and the ladder escalates to a
    /// fresh Ruiz equilibration on the fixed permutations — which fixes
    /// the stamp outright.
    #[test]
    fn ladder_escalates_to_reequilibration() {
        let a = tridiag(64);
        let mut s = GluSolver::factor(&a, &ladder_opts()).unwrap();

        let bad = gen::misscale_rows(&a, 64, 1e100);
        s.refactor(&bad).unwrap();
        let st = s.stats();
        assert_eq!(st.robustness.escalations, 1, "must reach rung 2");
        assert_eq!(st.robustness.perturbations, 1, "rung 1 tried and failed");
        assert_eq!(st.robustness.repairs, 1);
        assert_eq!(st.symbolic_runs, 1);
        assert_eq!(st.plan_builds, 1);

        let b = vec![1.0; 64];
        let x = s.solve(&b).unwrap();
        assert!(
            residual(&bad, &x, &b) <= 1e-8,
            "escalated residual {}",
            residual(&bad, &x, &b)
        );
    }

    /// Rung-3 coverage: an all-zero stamp exhausts every rung. The error
    /// must be the *typed* numeric classification (so the pool keeps the
    /// entry), and the failure path must scrub the run-scoped stats — a
    /// poisoned solver never reports stale kernel timings.
    #[test]
    fn ladder_exhaustion_is_typed_and_scrubs_stats() {
        let a = tridiag(48);
        let mut s = GluSolver::factor(&a, &ladder_opts()).unwrap();
        assert!(s.stats().numeric_ms.is_finite());

        let bad = gen::weaken_diagonal(&a, 1, 0.0); // every diagonal zeroed
        let err = s.refactor(&gen::misscale_rows(&bad, 1, 0.0)).unwrap_err();
        let glu = err
            .downcast_ref::<GluError>()
            .expect("ladder exhaustion must carry the typed payload");
        assert!(matches!(glu, GluError::NumericallySingular { .. }));
        assert!(err.to_string().contains("ladder exhausted"), "{err}");

        // satellite: failed refactor resets the run-scoped stats
        let st = s.stats();
        assert!(st.numeric_ms.is_nan(), "stale numeric_ms survived failure");
        assert!(st.sim.is_none());
        assert!(st.exec.is_none());
        // the ladder tried everything before giving up
        assert!(st.robustness.perturbations >= 2);
        assert!(st.robustness.escalations >= 1);
        assert_eq!(st.robustness.repairs, 0);

        // the cached symbolic state is still viable: repair with values
        let _ = s.solve(&vec![1.0; 48]).unwrap_err(); // poisoned
        s.refactor(&a).unwrap();
        assert!(s.stats().numeric_ms.is_finite());
        assert_eq!(s.stats().symbolic_runs, 1);
        let x = s.solve(&vec![1.0; 48]).unwrap();
        assert!(residual(&a, &x, &vec![1.0; 48]) < 1e-8);
    }

    /// `NumericEngine::Auto` picks a concrete engine per pattern from the
    /// plan statistics and records it. The chain fixture is analytically
    /// pinned (width-1 schedule → the sequential oracle); the mesh and
    /// band fixtures are pinned against the documented decision rule
    /// evaluated on their own (deterministic) plans.
    #[test]
    fn auto_engine_resolves_per_pattern() {
        // A pure chain schedule: average level width 1 — per-level
        // launches are pure overhead, Auto must pick the oracle.
        let chain = tridiag(96);
        let opts = GluOptions {
            ordering: FillOrdering::Natural,
            scale: false,
            engine: NumericEngine::Auto { threads: 4 },
            ..Default::default()
        };
        let mut s = GluSolver::factor(&chain, &opts).unwrap();
        assert!(
            matches!(s.engine(), NumericEngine::LeftLookingCpu),
            "chain must resolve to the oracle, got {:?}",
            s.engine()
        );
        assert_eq!(s.stats().resolved_engine, "LeftLookingCpu");

        // Glu1 detection: the only hazard-safe engine is the oracle.
        let g1 = GluOptions {
            detection: Detection::Glu1,
            engine: NumericEngine::Auto { threads: 4 },
            ..Default::default()
        };
        let s1 = GluSolver::factor(&gen::netlist(100, 5, 8, 0.1, 1, 0.2, 7), &g1).unwrap();
        assert!(matches!(s1.engine(), NumericEngine::LeftLookingCpu));

        // Mesh and band fixtures: the resolution must match the documented
        // rule applied to the pattern's own plan, must never be Auto
        // itself, and must respect the thread budget.
        for (label, a, threads) in [
            ("amd-mesh", gen::grid2d(32, 32, 7), 4usize),
            ("amd-mesh-1t", gen::grid2d(32, 32, 7), 1usize),
            ("band", gen::ladder(256, 16, 32, 5), 2usize),
            ("random-dd", gen::netlist(400, 6, 12, 0.05, 3, 0.2, 71), 4usize),
        ] {
            let opts = GluOptions {
                engine: NumericEngine::Auto { threads },
                ..Default::default()
            };
            let mut s = GluSolver::factor(&a, &opts).unwrap();
            let plan = s.plan();
            let levels = plan.num_levels().max(1);
            let avg_width = plan.n() as f64 / levels as f64;
            let (_, _, stream) = plan.mode_histogram();
            let expect = if avg_width < 2.0 || stream * 2 >= levels {
                "LeftLookingCpu".to_string()
            } else if threads > 1 && avg_width >= 16.0 {
                format!("ParallelRightLooking {{ threads: {threads} }}")
            } else {
                "Schedule { backend: Virtual }".to_string()
            };
            assert_eq!(
                s.stats().resolved_engine, expect,
                "{label}: avg_width {avg_width:.1}, {stream}/{levels} stream"
            );
            assert!(!matches!(s.engine(), NumericEngine::Auto { .. }));
            assert!(s.engine().threads() <= threads.max(1));

            // the resolved engine is a fully working solver, refactor
            // included
            let n = a.nrows();
            let b = vec![1.0; n];
            let x = s.solve(&b).unwrap();
            assert!(residual(&a, &x, &b) < 1e-7, "{label}");
            let mut a2 = a.clone();
            for v in a2.values_mut() {
                *v *= 1.2;
            }
            s.refactor(&a2).unwrap();
            assert_eq!(s.stats().symbolic_runs, 1);
            let x2 = s.solve(&b).unwrap();
            assert!(residual(&a2, &x2, &b) < 1e-7, "{label} refactor");
        }
    }

    #[test]
    fn detection_options_all_work_with_safe_engines() {
        let a = gen::netlist(200, 6, 10, 0.08, 2, 0.2, 5);
        let b = vec![1.0; 200];
        for det in [Detection::Glu2, Detection::Glu3] {
            let opts = GluOptions {
                detection: det,
                ..Default::default()
            };
            let mut s = GluSolver::factor(&a, &opts).unwrap();
            let x = s.solve(&b).unwrap();
            assert!(residual(&a, &x, &b) < 1e-7, "{det:?}");
        }
        // GLU1 detection is only safe with the left-looking engine.
        let opts = GluOptions {
            detection: Detection::Glu1,
            engine: NumericEngine::LeftLookingCpu,
            ..Default::default()
        };
        let mut s = GluSolver::factor(&a, &opts).unwrap();
        let x = s.solve(&b).unwrap();
        assert!(residual(&a, &x, &b) < 1e-7);
    }

    #[test]
    fn solve_many_matches_individual_solves() {
        let a = gen::netlist(250, 5, 10, 0.06, 2, 0.2, 77);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let batch: Vec<Vec<f64>> = (0..6)
            .map(|k| (0..250).map(|i| ((i * 7 + k) % 13) as f64 - 6.0).collect())
            .collect();
        let many = s.solve_many(&batch).unwrap();
        assert_eq!(many.len(), batch.len());
        for (b, x_batch) in batch.iter().zip(&many) {
            let x_one = s.solve(b).unwrap();
            // same inner routine — results are identical, not just close
            assert_eq!(x_one, *x_batch);
            assert!(residual(&a, x_batch, b) < 1e-7);
        }
        // counters: one symbolic + one numeric run, no matter how many solves
        assert_eq!(s.stats().symbolic_runs, 1);
        assert_eq!(s.stats().numeric_runs, 1);

        // dimension mismatch anywhere in the batch is rejected
        assert!(s.solve_many(&[vec![1.0; 249]]).is_err());
    }

    #[test]
    fn solve_many_parallel_engine_bit_identical_to_sequential_engine() {
        // The parallel trisolve is bit-identical to the sequential one
        // (and the width gate may route narrow schedules to the sequential
        // path anyway), so a ParallelCpu solver must reproduce the
        // LeftLookingCpu solver's solutions *exactly* — the factors are
        // bit-identical between those engines too.
        let a = gen::netlist(300, 6, 12, 0.05, 2, 0.2, 83);
        let batch: Vec<Vec<f64>> = (0..4)
            .map(|k| (0..300).map(|i| ((i * 11 + k) % 19) as f64 - 9.0).collect())
            .collect();
        let mut seq = GluSolver::factor(
            &a,
            &GluOptions {
                engine: NumericEngine::LeftLookingCpu,
                ..Default::default()
            },
        )
        .unwrap();
        let mut par = GluSolver::factor(
            &a,
            &GluOptions {
                engine: NumericEngine::ParallelCpu { threads: 4 },
                ..Default::default()
            },
        )
        .unwrap();
        let xs = seq.solve_many(&batch).unwrap();
        let xp = par.solve_many(&batch).unwrap();
        assert_eq!(xs, xp);
    }

    /// `refactor_batch` returns one plane per input matching the looped
    /// per-matrix `refactor` (bit-identical on the deterministic schedule
    /// executor, rounding-level on the CAS-committing parallel engine),
    /// installs the last plane as the current factors, and keeps the
    /// looped path's run accounting — on engines with a batched kernel
    /// (parrl, schedule) and on the looped fallback (simulator) alike.
    #[test]
    fn refactor_batch_agrees_with_looped_refactor_and_installs_last_plane() {
        let a = gen::grid2d(16, 16, 5);
        let b = 4usize;
        let mats: Vec<crate::sparse::Csc> = (0..b)
            .map(|p| {
                let mut m = a.clone();
                for v in m.values_mut() {
                    *v *= 1.0 + 0.1 * p as f64;
                }
                m
            })
            .collect();
        let refs: Vec<&crate::sparse::Csc> = mats.iter().collect();
        for engine in [
            NumericEngine::SimulatedGpu, // no batched kernel: looped fallback
            NumericEngine::ParallelRightLooking { threads: 2 },
            NumericEngine::Schedule {
                backend: ExecBackend::Virtual,
            },
        ] {
            let opts = GluOptions {
                engine: engine.clone(),
                ..Default::default()
            };
            let mut s = GluSolver::factor(&a, &opts).unwrap();
            let planes = s.refactor_batch(&refs).unwrap();
            assert_eq!(planes.planes(), b);

            // Each plane matches a looped refactor of the same matrix.
            let mut looped = GluSolver::factor(&a, &opts).unwrap();
            for (p, m) in mats.iter().enumerate() {
                looped.refactor(m).unwrap();
                let plane = planes.plane(p);
                for (x, y) in plane.iter().zip(looped.factors().lu.values()) {
                    assert!(
                        (x - y).abs() <= 1e-12 * (1.0 + y.abs()),
                        "{engine:?} plane {p}: {x} vs {y}"
                    );
                }
            }

            // The last plane is the solver's current factors — exactly.
            assert_eq!(planes.plane(b - 1), s.factors().lu.values());
            // Run accounting matches the looped path: factor + one per plane.
            assert_eq!(s.stats().numeric_runs as usize, 1 + b);
            assert_eq!(s.stats().symbolic_runs, 1);
            assert_eq!(s.stats().plan_builds, 1);

            // And the solver is immediately usable on the last matrix.
            let rhs = vec![1.0; a.nrows()];
            let x = s.solve(&rhs).unwrap();
            assert!(residual(&mats[b - 1], &x, &rhs) < 1e-9);
        }
    }

    /// A singleton batch ends in exactly the state `refactor` leaves.
    #[test]
    fn refactor_batch_of_one_equals_refactor() {
        let a = gen::grid2d(12, 12, 9);
        let mut a2 = a.clone();
        for v in a2.values_mut() {
            *v *= 1.3;
        }
        let opts = GluOptions {
            engine: NumericEngine::ParallelRightLooking { threads: 2 },
            ..Default::default()
        };
        let mut s = GluSolver::factor(&a, &opts).unwrap();
        let planes = s.refactor_batch(&[&a2]).unwrap();
        let mut r = GluSolver::factor(&a, &opts).unwrap();
        r.refactor(&a2).unwrap();
        assert_eq!(planes.plane(0), r.factors().lu.values());
        assert_eq!(s.factors().lu.values(), r.factors().lu.values());
        assert_eq!(s.stats().numeric_runs, r.stats().numeric_runs);
    }

    #[test]
    fn rejects_nonsquare_and_bad_rhs() {
        let a = gen::netlist(100, 5, 8, 0.1, 1, 0.2, 1);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        assert!(s.solve(&vec![1.0; 99]).is_err());
    }
}
