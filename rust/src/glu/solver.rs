//! The [`GluSolver`]: preprocess → symbolic → levelize → numeric → solve.

use crate::depend::{glu1, glu2, glu3, levelize, DepGraph, Levels};
use crate::gpusim::{simulate_factorization, DeviceConfig, Policy, SimReport};
use crate::numeric::{leftlook, parlu, rightlook, LuFactors};
use crate::order::{preprocess, FillOrdering, Preprocessed};
use crate::symbolic::{symbolic_fill, SymbolicFill};
use crate::util::Stopwatch;

/// Which dependency detection algorithm to run (paper Fig. 9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Detection {
    /// GLU1.0 U-pattern (unsafe for the right-looking kernel; only valid
    /// together with [`NumericEngine::LeftLookingCpu`]).
    Glu1,
    /// GLU2.0 exact double-U search (Algorithm 3) — O(n³)-class.
    Glu2,
    /// GLU3.0 relaxed detection (Algorithm 4) — the default.
    #[default]
    Glu3,
}

/// Which numeric engine executes the factorization.
#[derive(Debug, Clone, Default)]
pub enum NumericEngine {
    /// Simulated-GPU hybrid right-looking kernel under a [`Policy`]
    /// (the paper's system; default: GLU3.0 adaptive on a TITAN X model).
    #[default]
    SimulatedGpu,
    /// Sequential Gilbert–Peierls left-looking (oracle).
    LeftLookingCpu,
    /// Multithreaded left-looking (NICSLU-like baseline).
    ParallelCpu {
        threads: usize,
    },
    /// Sequential right-looking (Algorithm 2 reference).
    RightLookingCpu,
}

/// Options for [`GluSolver::factor`].
#[derive(Debug, Clone)]
pub struct GluOptions {
    /// Fill-reducing ordering (default AMD, as the paper).
    pub ordering: FillOrdering,
    /// Apply MC64-style equilibration scaling.
    pub scale: bool,
    /// Dependency detection algorithm.
    pub detection: Detection,
    /// Numeric engine.
    pub engine: NumericEngine,
    /// Kernel policy for the simulated GPU engine.
    pub policy: Policy,
    /// Device model for the simulated GPU engine.
    pub device: DeviceConfig,
}

impl Default for GluOptions {
    fn default() -> Self {
        GluOptions {
            ordering: FillOrdering::Amd,
            scale: true,
            detection: Detection::Glu3,
            engine: NumericEngine::SimulatedGpu,
            policy: Policy::glu3(),
            device: DeviceConfig::titan_x(),
        }
    }
}

/// Phase timings and structural statistics of one factorization.
#[derive(Debug, Clone)]
pub struct GluStats {
    pub n: usize,
    /// nnz before fill.
    pub nz: usize,
    /// nnz after fill.
    pub nnz: usize,
    pub num_levels: usize,
    pub max_level_size: usize,
    /// CPU preprocessing time (matching + ordering + permute), ms.
    pub preprocess_ms: f64,
    /// Symbolic fill time, ms.
    pub symbolic_ms: f64,
    /// Dependency detection + levelization time, ms (Table II's metric).
    pub levelization_ms: f64,
    /// Numeric factorization time, ms: simulated-GPU kernel time for the
    /// GPU engine, wall-clock for CPU engines.
    pub numeric_ms: f64,
    /// Simulated-GPU report (None for CPU engines).
    pub sim: Option<SimReport>,
    /// How many times the symbolic pipeline (ordering + fill + dependency
    /// detection + levelization) has run for this solver — always 1: the
    /// whole point of [`GluSolver::refactor`] is that it never reruns.
    /// Exposed so the service layer can *assert* the refactor fast path
    /// skipped the CPU phases.
    pub symbolic_runs: usize,
    /// How many times the numeric kernel has run (1 for the initial factor
    /// plus one per [`GluSolver::refactor`]).
    pub numeric_runs: usize,
}

impl GluStats {
    /// Total CPU-side time (the paper's "CPU time" column).
    pub fn cpu_ms(&self) -> f64 {
        self.preprocess_ms + self.symbolic_ms + self.levelization_ms
    }
}

/// A factored system ready to solve and refactor.
#[derive(Debug)]
pub struct GluSolver {
    opts: GluOptions,
    pre: Preprocessed,
    sym: SymbolicFill,
    levels: Levels,
    factors: LuFactors,
    stats: GluStats,
    /// Map: position in the *original* matrix's CSC value array → position
    /// in the filled pattern's value array (for fast refactorization).
    value_map: Vec<usize>,
}

impl GluSolver {
    /// Run the full pipeline on `a`.
    pub fn factor(a: &crate::sparse::Csc, opts: &GluOptions) -> anyhow::Result<Self> {
        anyhow::ensure!(a.nrows() == a.ncols(), "matrix must be square");
        let mut sw = Stopwatch::new();

        let pre = sw.time("preprocess", || preprocess(a, opts.ordering, opts.scale))?;
        let sym = sw.time("symbolic", || symbolic_fill(&pre.a))?;
        let (deps, levels) = sw.time("levelize", || {
            let deps = detect(opts.detection, &sym);
            let levels = levelize(&deps);
            (deps, levels)
        });
        drop(deps);

        let (factors, sim, numeric_ms) = run_engine(&opts.engine, &opts.policy, &opts.device, &sym, &levels, &mut sw)?;

        let value_map = build_value_map(a, &pre, &sym);

        let stats = GluStats {
            n: a.nrows(),
            nz: a.nnz(),
            nnz: sym.filled.nnz(),
            num_levels: levels.num_levels(),
            max_level_size: levels.max_level_size(),
            preprocess_ms: sw.get("preprocess").unwrap().as_secs_f64() * 1e3,
            symbolic_ms: sw.get("symbolic").unwrap().as_secs_f64() * 1e3,
            levelization_ms: sw.get("levelize").unwrap().as_secs_f64() * 1e3,
            numeric_ms,
            sim,
            symbolic_runs: 1,
            numeric_runs: 1,
        };

        Ok(GluSolver {
            opts: opts.clone(),
            pre,
            sym,
            levels,
            factors,
            stats,
            value_map,
        })
    }

    /// Solve `A x = b` using the current factors.
    pub fn solve(&mut self, b: &[f64]) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(b.len() == self.stats.n, "rhs dimension mismatch");
        let mut pb = vec![0.0; b.len()];
        let mut x = vec![0.0; b.len()];
        self.solve_into(b, &mut pb, &mut x);
        Ok(x)
    }

    /// Solve a batch of right-hand sides against the same factors.
    ///
    /// The permute/scale scratch buffer is allocated once and the triangular
    /// solves run back-to-back over the cached level structure — the batched
    /// fast path the [`crate::coordinator::SolverPool`] feeds. Each solution
    /// is bit-identical to the corresponding [`GluSolver::solve`] call (same
    /// inner routine, same operation order).
    pub fn solve_many(&mut self, rhs: &[Vec<f64>]) -> anyhow::Result<Vec<Vec<f64>>> {
        for b in rhs {
            anyhow::ensure!(b.len() == self.stats.n, "rhs dimension mismatch");
        }
        let mut pb = vec![0.0; self.stats.n];
        let mut out = Vec::with_capacity(rhs.len());
        for b in rhs {
            let mut x = vec![0.0; self.stats.n];
            self.solve_into(b, &mut pb, &mut x);
            out.push(x);
        }
        Ok(out)
    }

    /// Shared inner solve: scatter `b` through row scaling/permutation into
    /// `pb`, run the triangular solves in place, gather into `x` through the
    /// column permutation/scaling. `pb` and `x` must have length `n`.
    fn solve_into(&self, b: &[f64], pb: &mut [f64], x: &mut [f64]) {
        // b' = Dr * b permuted by the row permutation.
        let pr = self.pre.row_perm.as_scatter();
        for (old, &new) in pr.iter().enumerate() {
            pb[new] = b[old] * self.pre.row_scale[old];
        }
        crate::numeric::trisolve::lower_unit_solve(&self.factors.lu, pb);
        crate::numeric::trisolve::upper_solve(&self.factors.lu, pb);
        // x = Dc * (P_colᵀ x').
        let pc = self.pre.col_perm.as_scatter();
        for (old, &new) in pc.iter().enumerate() {
            x[old] = pb[new] * self.pre.col_scale[old];
        }
    }

    /// Refactor with new values on the *same sparsity pattern* (the
    /// Newton–Raphson iteration pattern). Preprocessing, symbolic analysis
    /// and levelization are all reused; only the numeric kernel reruns.
    pub fn refactor(&mut self, a: &crate::sparse::Csc) -> anyhow::Result<()> {
        anyhow::ensure!(
            a.nnz() == self.value_map.len() && a.nrows() == self.stats.n,
            "refactor requires the original sparsity pattern"
        );
        // Reset filled values: zero everywhere (fill positions stay zero),
        // then scatter A's scaled values through the precomputed map.
        let mut fresh = vec![0.0f64; self.sym.filled.nnz()];
        let rs = &self.pre.row_scale;
        let cs = &self.pre.col_scale;
        let mut pos = 0usize;
        for c in 0..a.ncols() {
            let (rows, vals) = a.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                let scaled = if self.opts.scale {
                    v * rs[r] * cs[c]
                } else {
                    v
                };
                fresh[self.value_map[pos]] += scaled;
                pos += 1;
            }
        }
        self.sym.filled.values_mut().copy_from_slice(&fresh);

        let mut sw = Stopwatch::new();
        let (factors, sim, numeric_ms) = run_engine(
            &self.opts.engine,
            &self.opts.policy,
            &self.opts.device,
            &self.sym,
            &self.levels,
            &mut sw,
        )?;
        self.factors = factors;
        self.stats.numeric_ms = numeric_ms;
        self.stats.sim = sim;
        self.stats.numeric_runs += 1;
        Ok(())
    }

    /// Factorization statistics.
    pub fn stats(&self) -> &GluStats {
        &self.stats
    }

    /// The level schedule (Fig. 10 / Table III analysis).
    pub fn levels(&self) -> &Levels {
        &self.levels
    }

    /// The symbolic fill result.
    pub fn symbolic(&self) -> &SymbolicFill {
        &self.sym
    }

    /// The LU factors (permuted/scaled domain).
    pub fn factors(&self) -> &LuFactors {
        &self.factors
    }
}

/// Dispatch the configured detection algorithm.
pub fn detect(detection: Detection, sym: &SymbolicFill) -> DepGraph {
    match detection {
        Detection::Glu1 => glu1::detect(&sym.filled),
        Detection::Glu2 => glu2::detect(&sym.filled),
        Detection::Glu3 => glu3::detect(&sym.filled),
    }
}

fn run_engine(
    engine: &NumericEngine,
    policy: &Policy,
    device: &DeviceConfig,
    sym: &SymbolicFill,
    levels: &Levels,
    sw: &mut Stopwatch,
) -> anyhow::Result<(LuFactors, Option<SimReport>, f64)> {
    match engine {
        NumericEngine::SimulatedGpu => {
            let (factors, report) =
                sw.time("numeric", || simulate_factorization(sym, levels, policy, device))?;
            let ms = report.kernel_ms();
            Ok((factors, Some(report), ms))
        }
        NumericEngine::LeftLookingCpu => {
            let factors = sw.time("numeric", || leftlook::factor(sym))?;
            Ok((factors, None, sw.get("numeric").unwrap().as_secs_f64() * 1e3))
        }
        NumericEngine::RightLookingCpu => {
            let factors = sw.time("numeric", || rightlook::factor(sym))?;
            Ok((factors, None, sw.get("numeric").unwrap().as_secs_f64() * 1e3))
        }
        NumericEngine::ParallelCpu { threads } => {
            let factors = sw.time("numeric", || parlu::factor(sym, *threads))?;
            Ok((factors, None, sw.get("numeric").unwrap().as_secs_f64() * 1e3))
        }
    }
}

/// For each stored entry of `a` (CSC order), the index of its destination
/// in the filled pattern's value array after row/col permutation.
fn build_value_map(
    a: &crate::sparse::Csc,
    pre: &Preprocessed,
    sym: &SymbolicFill,
) -> Vec<usize> {
    let pr = pre.row_perm.as_scatter();
    let pc = pre.col_perm.as_scatter();
    let mut map = Vec::with_capacity(a.nnz());
    for c in 0..a.ncols() {
        let (rows, _) = a.col(c);
        for &r in rows {
            let (nr, nc) = (pr[r], pc[c]);
            let idx = sym
                .filled
                .entry_index(nr, nc)
                .expect("original entry missing from filled pattern");
            map.push(idx);
        }
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::residual;
    use crate::sparse::gen;

    #[test]
    fn full_pipeline_solves() {
        let a = gen::netlist(500, 6, 16, 0.05, 4, 0.2, 42);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let b: Vec<f64> = (0..500).map(|i| ((i % 13) as f64) - 6.0).collect();
        let x = s.solve(&b).unwrap();
        // n=500 hub netlist: condition ~1e5; 1e-7 relative is the right
        // acceptance here (oracle-equality is asserted elsewhere).
        assert!(residual(&a, &x, &b) < 1e-7, "residual {}", residual(&a, &x, &b));
        let st = s.stats();
        assert!(st.nnz >= st.nz);
        assert!(st.num_levels > 1);
        assert!(st.sim.is_some());
    }

    #[test]
    fn all_engines_agree() {
        let a = gen::grid2d(15, 15, 3);
        let b: Vec<f64> = (0..225).map(|i| (i as f64).sin()).collect();
        let mut xs = Vec::new();
        for engine in [
            NumericEngine::SimulatedGpu,
            NumericEngine::LeftLookingCpu,
            NumericEngine::RightLookingCpu,
            NumericEngine::ParallelCpu { threads: 3 },
        ] {
            let opts = GluOptions {
                engine,
                ..Default::default()
            };
            let mut s = GluSolver::factor(&a, &opts).unwrap();
            xs.push(s.solve(&b).unwrap());
        }
        for x in &xs[1..] {
            for (p, q) in x.iter().zip(&xs[0]) {
                assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
            }
        }
    }

    #[test]
    fn refactor_newton_raphson_pattern() {
        let a = gen::netlist(300, 5, 12, 0.05, 2, 0.2, 11);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let b = vec![1.0; 300];
        let x0 = s.solve(&b).unwrap();
        assert!(residual(&a, &x0, &b) < 1e-10);

        // Same pattern, perturbed values (a Newton step's new Jacobian).
        let mut a2 = a.clone();
        for v in a2.values_mut() {
            *v *= 1.25;
        }
        s.refactor(&a2).unwrap();
        let x1 = s.solve(&b).unwrap();
        assert!(residual(&a2, &x1, &b) < 1e-10);
        // And x1 should differ from x0 (values changed).
        assert!(x1.iter().zip(&x0).any(|(p, q)| (p - q).abs() > 1e-9));

        // Refactor back to the original values reproduces x0.
        s.refactor(&a).unwrap();
        let x2 = s.solve(&b).unwrap();
        for (p, q) in x2.iter().zip(&x0) {
            assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
        }
    }

    #[test]
    fn detection_options_all_work_with_safe_engines() {
        let a = gen::netlist(200, 6, 10, 0.08, 2, 0.2, 5);
        let b = vec![1.0; 200];
        for det in [Detection::Glu2, Detection::Glu3] {
            let opts = GluOptions {
                detection: det,
                ..Default::default()
            };
            let mut s = GluSolver::factor(&a, &opts).unwrap();
            let x = s.solve(&b).unwrap();
            assert!(residual(&a, &x, &b) < 1e-7, "{det:?}");
        }
        // GLU1 detection is only safe with the left-looking engine.
        let opts = GluOptions {
            detection: Detection::Glu1,
            engine: NumericEngine::LeftLookingCpu,
            ..Default::default()
        };
        let mut s = GluSolver::factor(&a, &opts).unwrap();
        let x = s.solve(&b).unwrap();
        assert!(residual(&a, &x, &b) < 1e-7);
    }

    #[test]
    fn solve_many_matches_individual_solves() {
        let a = gen::netlist(250, 5, 10, 0.06, 2, 0.2, 77);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let batch: Vec<Vec<f64>> = (0..6)
            .map(|k| (0..250).map(|i| ((i * 7 + k) % 13) as f64 - 6.0).collect())
            .collect();
        let many = s.solve_many(&batch).unwrap();
        assert_eq!(many.len(), batch.len());
        for (b, x_batch) in batch.iter().zip(&many) {
            let x_one = s.solve(b).unwrap();
            // same inner routine — results are identical, not just close
            assert_eq!(x_one, *x_batch);
            assert!(residual(&a, x_batch, b) < 1e-7);
        }
        // counters: one symbolic + one numeric run, no matter how many solves
        assert_eq!(s.stats().symbolic_runs, 1);
        assert_eq!(s.stats().numeric_runs, 1);

        // dimension mismatch anywhere in the batch is rejected
        assert!(s.solve_many(&[vec![1.0; 249]]).is_err());
    }

    #[test]
    fn rejects_nonsquare_and_bad_rhs() {
        let a = gen::netlist(100, 5, 8, 0.1, 1, 0.2, 1);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        assert!(s.solve(&vec![1.0; 99]).is_err());
    }
}
