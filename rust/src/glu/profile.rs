//! Per-level parallelism profile — the data behind the paper's Fig. 10
//! ("Number of columns and subcolumns of different levels") and the A/B/C
//! level taxonomy that motivates the three kernel modes — plus the
//! [`AmortizationProfile`] that quantifies the factor-once/refactor-many
//! economics the solver service is built on.

use super::solver::GluStats;
use crate::depend::Levels;
use crate::numeric::rightlook::upper_rows;
use crate::symbolic::SymbolicFill;

/// One level's parallelism metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelProfile {
    /// Level index (x-axis of Fig. 10).
    pub level: usize,
    /// Level size = number of parallelizable columns.
    pub size: usize,
    /// Maximum number of subcolumns over the level's columns (Fig. 10
    /// uses the max per level).
    pub max_subcols: usize,
    /// Mean L length of the level's columns (subcolumn task length).
    pub mean_l_len: f64,
}

/// Compute the Fig. 10 profile for a schedule.
pub fn parallelism_profile(sym: &SymbolicFill, levels: &Levels) -> Vec<LevelProfile> {
    let urow = upper_rows(sym);
    let filled = &sym.filled;
    levels
        .levels
        .iter()
        .enumerate()
        .map(|(li, cols)| {
            let mut max_subcols = 0usize;
            let mut l_sum = 0usize;
            for &j in cols {
                let j = j as usize;
                max_subcols = max_subcols.max(urow[j].len());
                let (rows, _) = filled.col(j);
                l_sum += rows.len() - rows.partition_point(|&r| r <= j);
            }
            LevelProfile {
                level: li,
                size: cols.len(),
                max_subcols,
                mean_l_len: l_sum as f64 / cols.len().max(1) as f64,
            }
        })
        .collect()
}

/// Pearson correlation between level size and max subcolumns — the paper's
/// §III-B observation that the two are *inversely correlated* (used as an
/// assertion in tests and printed by the fig10 bench).
pub fn size_subcol_correlation(profile: &[LevelProfile]) -> f64 {
    let n = profile.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let (mx, my) = (
        profile.iter().map(|p| p.size as f64).sum::<f64>() / n,
        profile.iter().map(|p| p.max_subcols as f64).sum::<f64>() / n,
    );
    let (mut sxy, mut sxx, mut syy) = (0.0f64, 0.0f64, 0.0f64);
    for p in profile {
        let dx = p.size as f64 - mx;
        let dy = p.max_subcols as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Amortization economics of one cached solver: how much CPU-side symbolic
/// work the refactor fast path has saved so far (paper §III — the numeric
/// kernel "might be repeated many times" per symbolic analysis).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AmortizationProfile {
    /// Times the symbolic pipeline ran (1 per cached pattern, by design).
    pub symbolic_runs: usize,
    /// Times the numeric kernel ran (factor + refactors).
    pub numeric_runs: usize,
    /// One-time CPU cost actually paid, ms.
    pub cpu_ms_paid: f64,
    /// CPU cost that *would* have been paid had every numeric run
    /// re-preprocessed (the no-cache counterfactual), ms.
    pub cpu_ms_counterfactual: f64,
}

impl AmortizationProfile {
    /// CPU milliseconds saved by reusing symbolic state.
    pub fn cpu_ms_saved(&self) -> f64 {
        self.cpu_ms_counterfactual - self.cpu_ms_paid
    }

    /// Reuse factor: numeric runs per symbolic run.
    pub fn reuse(&self) -> f64 {
        self.numeric_runs as f64 / self.symbolic_runs.max(1) as f64
    }
}

/// Derive the [`AmortizationProfile`] from a solver's run counters.
pub fn amortization_profile(stats: &GluStats) -> AmortizationProfile {
    let per_run_cpu = stats.cpu_ms();
    AmortizationProfile {
        symbolic_runs: stats.symbolic_runs,
        numeric_runs: stats.numeric_runs,
        cpu_ms_paid: per_run_cpu * stats.symbolic_runs as f64,
        cpu_ms_counterfactual: per_run_cpu * stats.numeric_runs as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::{glu3, levelize};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;

    fn amd_mesh(nx: usize, ny: usize) -> SymbolicFill {
        let g = gen::grid2d(nx, ny, 1);
        let p = crate::order::amd::amd_order(&g).unwrap();
        symbolic_fill(&g.permute(p.as_scatter(), p.as_scatter())).unwrap()
    }

    #[test]
    fn profile_covers_all_levels() {
        let sym = amd_mesh(20, 20);
        let lv = levelize(&glu3::detect(&sym.filled));
        let prof = parallelism_profile(&sym, &lv);
        assert_eq!(prof.len(), lv.num_levels());
        let total: usize = prof.iter().map(|p| p.size).sum();
        assert_eq!(total, 400);
    }

    #[test]
    fn fig10_shape_on_amd_mesh() {
        // Paper Fig. 10: early levels have many columns with few
        // subcolumns; late levels few columns with many subcolumns, and
        // size vs max-subcolumns is inversely correlated overall.
        let sym = amd_mesh(40, 40);
        let lv = levelize(&glu3::detect(&sym.filled));
        let prof = parallelism_profile(&sym, &lv);
        assert!(prof[0].size > prof.last().unwrap().size * 10);
        let early_sub = prof[0].max_subcols;
        let late_max = prof[prof.len() / 2..]
            .iter()
            .map(|p| p.max_subcols)
            .max()
            .unwrap();
        assert!(late_max > early_sub, "late {late_max} vs early {early_sub}");
        let corr = size_subcol_correlation(&prof);
        assert!(corr < 0.1, "expected inverse/no correlation, got {corr}");
    }

    #[test]
    fn amortization_tracks_refactors() {
        use crate::glu::{GluOptions, GluSolver};

        let a = gen::netlist(150, 5, 10, 0.05, 2, 0.2, 23);
        let mut s = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let p0 = amortization_profile(s.stats());
        assert_eq!((p0.symbolic_runs, p0.numeric_runs), (1, 1));
        assert_eq!(p0.cpu_ms_saved(), 0.0);
        assert_eq!(p0.reuse(), 1.0);

        for _ in 0..4 {
            s.refactor(&a).unwrap();
        }
        let p = amortization_profile(s.stats());
        assert_eq!((p.symbolic_runs, p.numeric_runs), (1, 5));
        assert_eq!(p.reuse(), 5.0);
        assert!(p.cpu_ms_saved() >= 0.0);
        assert!(p.cpu_ms_counterfactual >= p.cpu_ms_paid);
    }
}
