//! The GLU3.0 solver pipeline — the crate's primary public API.
//!
//! Mirrors the paper's Fig. 5 flow, with the mode-annotated
//! [`crate::plan::FactorPlan`] between schedule and execution:
//!
//! ```text
//! A ──MC64 match+scale──► A₁ ──AMD──► A₂ ──symbolic fill──► As
//!    ──dependency detection (GLU3.0 relaxed / GLU2.0 / GLU1.0)──► deps
//!    ──levelization──► levels ──plan (per-level kernel mode + resource
//!      binding + work estimates + trisolve schedules)──► FactorPlan
//!    ──numeric kernel (3-mode, simulated GPU, worker-pool CPU, or the
//!      lowered LaunchSchedule through a DeviceExecutor backend)──►
//!      L, U ──tri-solve──► x
//! ```
//!
//! Preprocessing and symbolic analysis run once on the CPU; the numeric
//! factorization can be repeated for new values on the same pattern
//! ([`GluSolver::refactor`]) — the Newton–Raphson pattern of SPICE-class
//! circuit simulation, where the GPU kernel "might be repeated many times"
//! (paper §III).
//!
//! The once-per-pattern symbolic cost itself has two fast paths: on a
//! multi-threaded engine the fill discovery runs wave-parallel on the
//! worker pool with detection + levelization fused into the assembly
//! sweep ([`crate::symbolic::parfill`]), and a structural *near-miss* of
//! an already-analyzed pattern can be patched incrementally
//! ([`GluSolver::factor_delta`] over [`crate::symbolic::delta`]) instead
//! of recomputed.

pub mod profile;
pub mod solver;

pub use profile::{amortization_profile, parallelism_profile, AmortizationProfile, LevelProfile};
pub use solver::{
    Detection, ExecBackend, GluOptions, GluSolver, GluStats, NumericEngine, RobustnessStats,
    SymbolicSnapshot,
};
