//! Persistent worker pool for the level-scheduled numeric hot paths.
//!
//! The paper's CPU baselines (NICSLU's cluster/pipeline modes) and Li's
//! GPU trisolve work both rest on the same execution shape: a fixed set of
//! workers that *stay alive* across levels and meet at a cheap rendezvous
//! between them. The seed implementation instead respawned OS threads at
//! every level via `std::thread::scope` — on circuit matrices with
//! thousands of shallow levels the spawn/join cost dwarfs the arithmetic.
//!
//! [`WorkerPool`] spawns its threads **once**; each [`WorkerPool::run`]
//! dispatch wakes them with a condvar, executes one job on every thread
//! (the caller participates as worker 0, so a 1-thread pool runs inline
//! with zero synchronization), and waits on a completion counter until
//! every worker has left the job body. Inside a job, per-level rendezvous
//! goes through [`PoolCtx::sync`] — a
//! sense-reversing [`SpinBarrier`] that spins briefly and then yields, so a
//! level boundary costs microseconds instead of a spawn/join round trip.
//!
//! Safety model: jobs receive a [`PoolCtx`] and share data through the
//! caller's captures. The pool erases the job's lifetime to hand it to the
//! parked threads, which is sound because `run` does not return until every
//! worker has bumped the completion counter — the borrow outlives all use.
//! A panicking job poisons the pool (the barrier aborts so no thread
//! deadlocks waiting on the panicked one) and `run` re-panics on the
//! caller's thread; a poisoned pool refuses further jobs.
//!
//! With the off-by-default `affinity` feature (Linux only), each spawned
//! worker `i` pins itself to core `i` at startup via a raw
//! `sched_setaffinity` shim — see [`pin_to_core`] — so NUMA hosts stop
//! bouncing the level-sliced column writes across nodes.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

/// Best-effort core pinning for spawned workers (`affinity` feature,
/// Linux only): pin the calling thread to the `worker`-th CPU of the
/// process's *allowed* affinity mask through raw `sched_{get,set}affinity`
/// shims declared against the libc the Rust std already links — the
/// offline build gains no dependency, and cgroup/cpuset-restricted hosts
/// (whose allowed CPUs rarely start at 0) pin correctly instead of
/// silently no-opping. On multi-socket hosts this keeps worker *i* on one
/// core so the level-sliced column writes stop bouncing cache lines
/// across NUMA nodes. Failures are ignored: pinning is an optimization,
/// never a correctness requirement. Worker 0 is the dispatching caller
/// and is deliberately left unpinned — pinning it would constrain the
/// application thread beyond the pool's lifetime. Caveat: pools don't
/// coordinate, so several concurrently live pools pin onto the same
/// leading CPUs of the mask — intended for the one-pool-per-active-solver
/// topology, not for stacks of simultaneously hot pools.
#[cfg(all(feature = "affinity", target_os = "linux"))]
fn pin_to_core(worker: usize) {
    // glibc's cpu_set_t is 1024 bits wide.
    const CPU_SET_BYTES: usize = 128;
    extern "C" {
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u8) -> i32;
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u8) -> i32;
    }
    let mut current = [0u8; CPU_SET_BYTES];
    // SAFETY: the buffers outlive the calls; pid 0 targets this thread.
    if unsafe { sched_getaffinity(0, CPU_SET_BYTES, current.as_mut_ptr()) } != 0 {
        return;
    }
    let allowed: Vec<usize> = (0..CPU_SET_BYTES * 8)
        .filter(|&c| current[c / 8] & (1u8 << (c % 8)) != 0)
        .collect();
    if allowed.is_empty() {
        return;
    }
    let cpu = allowed[worker % allowed.len()];
    let mut mask = [0u8; CPU_SET_BYTES];
    mask[cpu / 8] |= 1u8 << (cpu % 8);
    let _ = unsafe { sched_setaffinity(0, CPU_SET_BYTES, mask.as_ptr()) };
}

/// No-op shim: the `affinity` feature is off (the default) or the target
/// is not Linux — thread placement stays with the OS.
#[cfg(not(all(feature = "affinity", target_os = "linux")))]
fn pin_to_core(_worker: usize) {}

/// Shared raw pointer into an `f64` buffer, for level-sliced writes where
/// the schedule (not the borrow checker) proves disjointness. Used by the
/// parallel factorization engines and the parallel triangular solves; see
/// each call site's safety comment for its aliasing discipline.
pub(crate) struct SharedPtr(pub *mut f64);
unsafe impl Send for SharedPtr {}
unsafe impl Sync for SharedPtr {}

/// Shared raw view over a slice of `T` slots, for pool jobs where each slot
/// is written by exactly one worker and read only after a barrier published
/// the write — the generic analogue of [`SharedPtr`] the *symbolic* jobs
/// need (per-column pattern slots, per-worker scratch slots; see
/// [`crate::symbolic::parfill`]).
pub(crate) struct SharedSlots<T>(*mut T, usize);
unsafe impl<T: Send> Send for SharedSlots<T> {}
unsafe impl<T: Send> Sync for SharedSlots<T> {}

impl<T> SharedSlots<T> {
    pub(crate) fn new(slots: &mut [T]) -> Self {
        SharedSlots(slots.as_mut_ptr(), slots.len())
    }

    /// Shared read of slot `i`.
    ///
    /// # Safety
    /// The caller's schedule must guarantee slot `i` is not being written
    /// concurrently and that any prior write was published by a barrier.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn get(&self, i: usize) -> &T {
        debug_assert!(i < self.1);
        unsafe { &*self.0.add(i) }
    }

    /// Exclusive write access to slot `i`.
    ///
    /// # Safety
    /// The caller's schedule must guarantee this worker is the only one
    /// touching slot `i` until the next barrier.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.1);
        unsafe { &mut *self.0.add(i) }
    }
}

/// Sense-reversing spin-then-yield barrier for `total` participants.
///
/// `wait` returns `true` on a normal rendezvous and `false` once the
/// barrier has been aborted (a job panicked); after an abort the barrier
/// releases every waiter immediately and permanently.
pub struct SpinBarrier {
    total: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
    aborted: AtomicBool,
}

impl SpinBarrier {
    pub fn new(total: usize) -> Self {
        assert!(total >= 1);
        SpinBarrier {
            total,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            aborted: AtomicBool::new(false),
        }
    }

    /// Block until all `total` participants arrive. The AcqRel/Release
    /// orderings publish every pre-barrier write to every post-barrier
    /// reader (the level-schedule safety argument relies on this).
    pub fn wait(&self) -> bool {
        if self.aborted.load(Ordering::Acquire) {
            return false;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
            self.count.store(0, Ordering::Relaxed);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
            true
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                if self.aborted.load(Ordering::Acquire) {
                    return false;
                }
                spins = spins.saturating_add(1);
                if spins < 256 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
            true
        }
    }

    /// Permanently release all current and future waiters (panic path).
    pub fn abort(&self) {
        self.aborted.store(true, Ordering::Release);
    }
}

/// Per-thread view of a running job: worker id, pool width, and the
/// inter-level rendezvous.
pub struct PoolCtx<'p> {
    /// This thread's index in `0..threads` (0 is the dispatching caller).
    pub id: usize,
    /// Total participating threads.
    pub threads: usize,
    barrier: &'p SpinBarrier,
}

impl PoolCtx<'_> {
    /// Rendezvous with every other worker (end-of-level barrier). Returns
    /// `false` if the pool aborted (another worker panicked) — the job
    /// should return immediately.
    pub fn sync(&self) -> bool {
        self.barrier.wait()
    }
}

type Job = dyn Fn(&PoolCtx<'_>) + Sync;

/// Lifetime-erased job pointer handed to the parked workers.
#[derive(Clone, Copy)]
struct JobPtr(*const Job);
unsafe impl Send for JobPtr {}

struct JobSlot {
    epoch: u64,
    job: Option<JobPtr>,
    shutdown: bool,
}

struct Shared {
    nworkers: usize,
    barrier: SpinBarrier,
    state: Mutex<JobSlot>,
    start: Condvar,
    poisoned: AtomicBool,
    /// Workers finished with the current job body. Unlike the (abortable)
    /// barrier, this is the completion signal `run` must always wait on —
    /// even on the panic path — before releasing the borrowed job.
    done: AtomicUsize,
}

fn lock_state(shared: &Shared) -> MutexGuard<'_, JobSlot> {
    shared.state.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A pool of `threads - 1` parked OS threads plus the dispatching caller.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes concurrent [`WorkerPool::run`] callers (the pool is
    /// `Sync`, e.g. behind an `Arc`): the epoch/done protocol supports one
    /// dispatcher at a time, so a second caller queues here instead of
    /// corrupting the rendezvous.
    dispatch: Mutex<()>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads())
            .field("poisoned", &self.shared.poisoned.load(Ordering::Relaxed))
            .finish()
    }
}

impl WorkerPool {
    /// Spawn a pool of `threads` workers (clamped to at least 1). The
    /// calling thread is worker 0, so `threads - 1` OS threads are created
    /// — `WorkerPool::new(1)` spawns nothing and `run` executes inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            nworkers: threads - 1,
            barrier: SpinBarrier::new(threads),
            state: Mutex::new(JobSlot {
                epoch: 0,
                job: None,
                shutdown: false,
            }),
            start: Condvar::new(),
            poisoned: AtomicBool::new(false),
            done: AtomicUsize::new(0),
        });
        let handles = (1..threads)
            .map(|id| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("glu3-worker-{id}"))
                    .spawn(move || {
                        pin_to_core(id);
                        worker_loop(&sh, id)
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            shared,
            handles,
            dispatch: Mutex::new(()),
        }
    }

    /// Total participating threads (parked workers + the caller).
    pub fn threads(&self) -> usize {
        self.shared.nworkers + 1
    }

    /// Execute `job` on every thread of the pool (the caller runs it as
    /// worker 0) and return once all of them have finished. Concurrent
    /// callers on a shared pool are serialized. Panics if the pool is
    /// poisoned or if `job` panics on any thread.
    pub fn run(&self, job: &Job) {
        let _dispatch = self
            .dispatch
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        assert!(
            !self.shared.poisoned.load(Ordering::Acquire),
            "worker pool poisoned by an earlier job panic"
        );
        if self.shared.nworkers == 0 {
            // Inline fast path: no synchronization at all.
            let ctx = PoolCtx {
                id: 0,
                threads: 1,
                barrier: &self.shared.barrier,
            };
            job(&ctx);
            return;
        }
        // Lifetime erasure: the pointer is only dereferenced by workers
        // between the epoch bump below and the completion barrier, and we
        // do not return until that barrier passes.
        let ptr = JobPtr(job as *const Job);
        {
            let mut st = lock_state(&self.shared);
            st.job = Some(ptr);
            st.epoch += 1;
        }
        self.shared.start.notify_all();

        let ctx = PoolCtx {
            id: 0,
            threads: self.threads(),
            barrier: &self.shared.barrier,
        };
        let result = catch_unwind(AssertUnwindSafe(|| job(&ctx)));
        if result.is_err() {
            // Poison + release any worker parked at a level barrier; they
            // observe the abort at their next sync and exit the job body.
            self.shared.poisoned.store(true, Ordering::Release);
            self.shared.barrier.abort();
        }
        // Completion: wait until every worker left the job body — on the
        // panic path too, since returning would drop the borrows the job
        // captures while workers still hold the erased reference.
        let mut spins = 0u32;
        while self.shared.done.load(Ordering::Acquire) < self.shared.nworkers {
            spins = spins.saturating_add(1);
            if spins < 256 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        self.shared.done.store(0, Ordering::Relaxed);
        match result {
            Err(payload) => std::panic::resume_unwind(payload),
            Ok(()) => assert!(
                !self.shared.poisoned.load(Ordering::Acquire),
                "worker pool job panicked on a worker thread"
            ),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_state(&self.shared);
            st.shutdown = true;
        }
        self.shared.start.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, id: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock_state(shared);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > seen {
                    seen = st.epoch;
                    break st.job.expect("job set whenever the epoch advances");
                }
                st = shared
                    .start
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        };
        let ctx = PoolCtx {
            id,
            threads: shared.nworkers + 1,
            barrier: &shared.barrier,
        };
        // SAFETY: `run` keeps the job alive until every worker has bumped
        // `done` below.
        let job_ref: &Job = unsafe { &*job.0 };
        if catch_unwind(AssertUnwindSafe(|| job_ref(&ctx))).is_err() {
            shared.poisoned.store(true, Ordering::Release);
            shared.barrier.abort();
        }
        shared.done.fetch_add(1, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_job_on_every_thread() {
        for threads in [1, 2, 4] {
            let pool = WorkerPool::new(threads);
            assert_eq!(pool.threads(), threads);
            let hits = AtomicU64::new(0);
            pool.run(&|ctx: &PoolCtx<'_>| {
                assert!(ctx.id < ctx.threads);
                hits.fetch_add(1 << (8 * ctx.id), Ordering::Relaxed);
            });
            let h = hits.load(Ordering::Relaxed);
            for t in 0..threads {
                assert_eq!((h >> (8 * t)) & 0xff, 1, "worker {t} ran once");
            }
        }
    }

    #[test]
    fn level_barriers_order_writes() {
        // Each "level" doubles a shared counter after every worker added 1:
        // with L levels and T threads the result is ((0+T)*2+T)*2... —
        // deterministic only if sync() really is a barrier.
        let threads = 4;
        let levels = 50;
        let pool = WorkerPool::new(threads);
        let value = AtomicU64::new(0);
        pool.run(&|ctx: &PoolCtx<'_>| {
            for _ in 0..levels {
                value.fetch_add(1, Ordering::Relaxed);
                ctx.sync();
                if ctx.id == 0 {
                    let v = value.load(Ordering::Relaxed);
                    value.store(v * 2, Ordering::Relaxed);
                }
                ctx.sync();
            }
        });
        let mut want = 0u64;
        for _ in 0..levels {
            want = (want + threads as u64) * 2;
        }
        assert_eq!(value.load(Ordering::Relaxed), want);
    }

    #[test]
    fn pool_is_reusable_across_runs() {
        let pool = WorkerPool::new(3);
        let total = AtomicU64::new(0);
        for _ in 0..20 {
            pool.run(&|_ctx: &PoolCtx<'_>| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 60);
    }

    #[test]
    fn concurrent_run_callers_are_serialized() {
        // The pool is Sync; racing dispatchers must queue, not deadlock
        // or corrupt the epoch/done rendezvous.
        let pool = WorkerPool::new(3);
        let total = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let pool = &pool;
                let total = &total;
                scope.spawn(move || {
                    for _ in 0..10 {
                        pool.run(&|_ctx: &PoolCtx<'_>| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        // 3 callers x 10 runs x 3 pool threads
        assert_eq!(total.load(Ordering::Relaxed), 90);
    }

    #[test]
    fn shared_slice_levelwise_writes_are_visible() {
        // Level k: worker t writes slot t from the slot values of level
        // k-1; the barrier must publish all writes between levels.
        let threads = 4;
        let rounds = 32;
        let pool = WorkerPool::new(threads);
        let mut data = vec![1.0f64; threads];
        let shared = SharedPtr(data.as_mut_ptr());
        pool.run(&|ctx: &PoolCtx<'_>| {
            for _ in 0..rounds {
                // read everyone's value (from the previous level)
                let sum: f64 = (0..ctx.threads)
                    .map(|t| unsafe { *shared.0.add(t) })
                    .sum();
                ctx.sync();
                unsafe { *shared.0.add(ctx.id) = sum / ctx.threads as f64 };
                ctx.sync();
            }
        });
        drop(pool);
        for &v in &data {
            assert_eq!(v, 1.0, "mean-of-ones must stay 1.0");
        }
    }

    /// With the affinity feature on, pinned workers still rendezvous and
    /// compute correctly (pinning is best-effort and purely a placement
    /// hint — this exercises the shim end to end).
    #[cfg(feature = "affinity")]
    #[test]
    fn pinned_pool_still_computes() {
        let pool = WorkerPool::new(4);
        let total = AtomicU64::new(0);
        for _ in 0..8 {
            pool.run(&|ctx: &PoolCtx<'_>| {
                total.fetch_add(1 + ctx.id as u64, Ordering::Relaxed);
                ctx.sync();
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 8 * (1 + 2 + 3 + 4));
    }

    #[test]
    fn panicked_job_poisons_pool_without_deadlock() {
        let pool = WorkerPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&|ctx: &PoolCtx<'_>| {
                if ctx.id == 1 {
                    panic!("boom");
                }
                // other workers park on the barrier; the abort releases them
                ctx.sync();
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        let r2 = catch_unwind(AssertUnwindSafe(|| pool.run(&|_: &PoolCtx<'_>| {})));
        assert!(r2.is_err(), "poisoned pool must refuse further jobs");
    }
}
