//! Sparse triangular solves over compact LU factors.
//!
//! Completes `Ax = b` after factorization (the `L y = b`, `U x = y` halves
//! of the paper's SPICE use-case); also exercised standalone by the
//! coordinator's repeated-solve path (same factors, many right-hand sides —
//! the Newton–Raphson pattern).
//!
//! Three execution modes:
//!
//! - the sequential column-oriented ("push") solves below,
//! - level-scheduled parallel row-oriented ("pull") solves
//!   ([`lower_unit_solve_par`] / [`upper_solve_par`]) over a
//!   [`TriangularSchedule`], following Li's GPU trisolve construction
//!   (arXiv:1710.04985): rows are grouped into dependency levels, each
//!   level's rows are dealt round-robin across a persistent
//!   [`WorkerPool`], and a spin barrier separates levels, and
//! - self-scheduling **sync-free** solves
//!   ([`lower_unit_solve_syncfree`] / [`upper_solve_syncfree`]) after the
//!   same paper's barrier-free construction: workers claim rows from a
//!   shared counter in dependency-safe order (ascending for `L`,
//!   descending for `U`) and spin on per-row ready flags instead of
//!   paying one barrier per level — the win on deep, narrow schedules
//!   where the level-set form is all barrier and no concurrency.
//!
//! Every parallel form accumulates row `i`'s terms in exactly the order
//! the push form applies them (ascending column for `L`, descending for
//! `U`, including the skip of zero multiplicands), so the parallel solves
//! are **bit-identical** to the sequential ones at any thread count — the
//! property the test pyramid pins down. The `_block` variants solve `nrhs`
//! interleaved right-hand sides (`xb[row * nrhs + p]`) in one factor walk,
//! plane-for-plane bit-identical to `nrhs` single solves.

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

use crate::numeric::pool::{PoolCtx, SharedPtr, WorkerPool};
use crate::sparse::Csc;

/// Which trisolve implementation a pattern should use — chosen once per
/// [`TriangularSchedule`] from its level-width statistics (see
/// [`TriangularSchedule::choose_variant`]) and recorded in
/// `GluStats::trisolve_variant`. All three produce bit-identical results;
/// the choice is purely a latency heuristic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrisolveVariant {
    /// Sequential push-form solve: the right call when the schedule is too
    /// narrow for any parallel form to amortize its coordination cost.
    Sequential,
    /// Level-set pull-form solve with one barrier per dependency level.
    LevelSet,
    /// Self-scheduling solve with per-row ready flags and no barrier.
    SyncFree,
}

impl TrisolveVariant {
    /// Stable label for stats and bench reports.
    pub fn label(&self) -> &'static str {
        match self {
            TrisolveVariant::Sequential => "sequential",
            TrisolveVariant::LevelSet => "level-set",
            TrisolveVariant::SyncFree => "sync-free",
        }
    }
}

/// Reusable per-row ready flags for the sync-free solves. Owned by the
/// caller (the solver keeps one in its `NumericWorkspace`) so the
/// steady-state solve path performs no heap allocation; `prepare` only
/// grows the buffer on first use per size class.
#[derive(Debug, Default)]
pub struct ReadyFlags {
    flags: Vec<AtomicU32>,
}

impl ReadyFlags {
    pub fn new() -> Self {
        ReadyFlags { flags: Vec::new() }
    }

    /// Ensure capacity for `n` rows and reset all flags to "not ready".
    /// The relaxed stores are published to the workers by the pool's
    /// dispatch handshake.
    fn prepare(&mut self, n: usize) -> &[AtomicU32] {
        if self.flags.len() < n {
            self.flags.resize_with(n, || AtomicU32::new(0));
        }
        for f in &self.flags[..n] {
            f.store(0, Ordering::Relaxed);
        }
        &self.flags[..n]
    }
}

/// In-place forward substitution with the unit-lower factor stored in the
/// strictly-lower triangle of `lu`: `x ← L⁻¹ x`.
pub fn lower_unit_solve(lu: &Csc, x: &mut [f64]) {
    let n = lu.ncols();
    assert_eq!(x.len(), n);
    for j in 0..n {
        let xj = x[j];
        if xj == 0.0 {
            continue;
        }
        let (rows, vals) = lu.col(j);
        let start = rows.partition_point(|&r| r <= j);
        for (&i, &lij) in rows[start..].iter().zip(&vals[start..]) {
            x[i] -= lij * xj;
        }
    }
}

/// In-place backward substitution with the upper factor (diagonal included):
/// `x ← U⁻¹ x`.
pub fn upper_solve(lu: &Csc, x: &mut [f64]) {
    let n = lu.ncols();
    assert_eq!(x.len(), n);
    for j in (0..n).rev() {
        let (rows, vals) = lu.col(j);
        let dpos = rows.partition_point(|&r| r < j);
        debug_assert!(rows[dpos] == j, "missing diagonal");
        let xj = x[j] / vals[dpos];
        x[j] = xj;
        if xj == 0.0 {
            continue;
        }
        for (&i, &uij) in rows[..dpos].iter().zip(&vals[..dpos]) {
            x[i] -= uij * xj;
        }
    }
}

/// Blocked forward substitution over `nrhs` interleaved right-hand sides:
/// `xb[i * nrhs + p] ← (L⁻¹ x_p)[i]` for every plane `p`. One walk over
/// the factor serves the whole block; per plane the operation order (and
/// the zero-multiplicand skip) is exactly [`lower_unit_solve`]'s, so each
/// plane's result is bit-identical to a single solve.
pub fn lower_unit_solve_block(lu: &Csc, xb: &mut [f64], nrhs: usize) {
    let n = lu.ncols();
    assert_eq!(xb.len(), n * nrhs);
    for j in 0..n {
        let (rows, vals) = lu.col(j);
        let start = rows.partition_point(|&r| r <= j);
        if start == rows.len() {
            continue;
        }
        let jbase = j * nrhs;
        for (&i, &lij) in rows[start..].iter().zip(&vals[start..]) {
            let ibase = i * nrhs;
            for p in 0..nrhs {
                let xj = xb[jbase + p];
                if xj != 0.0 {
                    xb[ibase + p] -= lij * xj;
                }
            }
        }
    }
}

/// Blocked backward substitution over `nrhs` interleaved right-hand sides:
/// `xb ← U⁻¹ xb` plane-wise, each plane bit-identical to [`upper_solve`].
pub fn upper_solve_block(lu: &Csc, xb: &mut [f64], nrhs: usize) {
    let n = lu.ncols();
    assert_eq!(xb.len(), n * nrhs);
    for j in (0..n).rev() {
        let (rows, vals) = lu.col(j);
        let dpos = rows.partition_point(|&r| r < j);
        debug_assert!(rows[dpos] == j, "missing diagonal");
        let dj = vals[dpos];
        let jbase = j * nrhs;
        for p in 0..nrhs {
            xb[jbase + p] /= dj;
        }
        for (&i, &uij) in rows[..dpos].iter().zip(&vals[..dpos]) {
            let ibase = i * nrhs;
            for p in 0..nrhs {
                let xj = xb[jbase + p];
                if xj != 0.0 {
                    xb[ibase + p] -= uij * xj;
                }
            }
        }
    }
}

/// Transpose solve `Aᵀ x = b` over the same factors (`Uᵀ y = b`, `Lᵀ x = y`)
/// — used by adjoint/sensitivity analysis in circuit simulators.
pub fn transpose_solve(lu: &Csc, b: &[f64]) -> Vec<f64> {
    let n = lu.ncols();
    let mut x = b.to_vec();
    // U^T is lower triangular (non-unit): forward substitution by columns.
    for j in 0..n {
        let (rows, vals) = lu.col(j);
        let dpos = rows.partition_point(|&r| r < j);
        let mut acc = x[j];
        for (&i, &uij) in rows[..dpos].iter().zip(&vals[..dpos]) {
            acc -= uij * x[i];
        }
        x[j] = acc / vals[dpos];
    }
    // L^T is unit upper: backward substitution.
    for j in (0..n).rev() {
        let (rows, vals) = lu.col(j);
        let start = rows.partition_point(|&r| r <= j);
        let mut acc = x[j];
        for (&i, &lij) in rows[start..].iter().zip(&vals[start..]) {
            acc -= lij * x[i];
        }
        x[j] = acc;
    }
    x
}

/// Row-oriented, level-scheduled view of one triangular factor: for each
/// row, its off-diagonal entries (column + index into the CSC value array)
/// in ascending column order, plus the rows grouped by dependency level.
#[derive(Debug, Clone)]
pub struct RowSched {
    /// Row pointer into `cols`/`vidx` (length `n + 1`).
    ptr: Vec<usize>,
    /// Column index of each row entry, ascending within a row.
    cols: Vec<u32>,
    /// Index of each row entry in the CSC value array.
    vidx: Vec<usize>,
    /// Value index of the diagonal per row (upper factor only; empty for
    /// the unit-lower factor).
    diag: Vec<usize>,
    /// Rows grouped by level: every row only reads solution entries
    /// produced in strictly earlier levels.
    levels: Vec<Vec<u32>>,
}

impl RowSched {
    /// Number of dependency levels (the solve's critical-path length).
    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    /// Mean rows per level — the available parallelism. Deep/narrow
    /// schedules (circuit matrices often levelize to width ~1–3) pay a
    /// barrier per level for almost no concurrent work, so callers should
    /// fall back to the sequential solve below a width threshold (see
    /// [`TriangularSchedule::parallel_worthwhile`]).
    pub fn mean_level_width(&self) -> f64 {
        if self.levels.is_empty() {
            return 0.0;
        }
        (self.ptr.len() - 1) as f64 / self.levels.len() as f64
    }
}

/// L and U row schedules for one factored pattern — cached by
/// [`crate::glu::GluSolver`] and reused across every solve on the same
/// symbolic state (pattern-only: value restamps don't invalidate it).
#[derive(Debug, Clone)]
pub struct TriangularSchedule {
    pub lower: RowSched,
    pub upper: RowSched,
}

impl TriangularSchedule {
    /// Whether the level-parallel solves are expected to beat the
    /// sequential ones: both schedules need enough rows per level to
    /// amortize the per-level barrier (a few microseconds) over real
    /// concurrent work. Results are bit-identical either way — this is a
    /// pure latency heuristic.
    pub fn parallel_worthwhile(&self) -> bool {
        const MIN_MEAN_LEVEL_WIDTH: f64 = 8.0;
        self.lower.mean_level_width() >= MIN_MEAN_LEVEL_WIDTH
            && self.upper.mean_level_width() >= MIN_MEAN_LEVEL_WIDTH
    }

    /// Pick the trisolve implementation for this pattern from its
    /// level-width statistics. Narrow schedules (below the
    /// `parallel_worthwhile` width floor) stay sequential; among the
    /// parallel-worthy ones, deep schedules prefer the sync-free form
    /// (which pays per-row flag spins instead of one barrier per level,
    /// and the barrier count is the depth), shallow-and-wide ones the
    /// level-set form (few barriers, no spinning at all).
    pub fn choose_variant(&self) -> TrisolveVariant {
        const DEEP_LEVELS: usize = 48;
        if !self.parallel_worthwhile() {
            TrisolveVariant::Sequential
        } else if self.lower.num_levels().max(self.upper.num_levels()) >= DEEP_LEVELS {
            TrisolveVariant::SyncFree
        } else {
            TrisolveVariant::LevelSet
        }
    }

    /// Build both row schedules from a factored (or just filled) pattern.
    pub fn build(lu: &Csc) -> Self {
        let n = lu.ncols();
        let colptr = lu.colptr();
        let rowidx = lu.rowidx();

        // Count strictly-lower and strictly-upper entries per row.
        let mut lcnt = vec![0usize; n];
        let mut ucnt = vec![0usize; n];
        for c in 0..n {
            for &r in &rowidx[colptr[c]..colptr[c + 1]] {
                match r.cmp(&c) {
                    std::cmp::Ordering::Greater => lcnt[r] += 1,
                    std::cmp::Ordering::Less => ucnt[r] += 1,
                    std::cmp::Ordering::Equal => {}
                }
            }
        }
        let prefix = |cnt: &[usize]| {
            let mut ptr = vec![0usize; n + 1];
            for i in 0..n {
                ptr[i + 1] = ptr[i] + cnt[i];
            }
            ptr
        };
        let lptr = prefix(&lcnt);
        let uptr = prefix(&ucnt);

        let mut lcols = vec![0u32; lptr[n]];
        let mut lvidx = vec![0usize; lptr[n]];
        let mut ucols = vec![0u32; uptr[n]];
        let mut uvidx = vec![0usize; uptr[n]];
        let mut diag = vec![usize::MAX; n];
        let mut lcur = lptr.clone();
        let mut ucur = uptr.clone();
        // Column-ascending fill keeps each row's entries sorted by column.
        for c in 0..n {
            for (off, &r) in rowidx[colptr[c]..colptr[c + 1]].iter().enumerate() {
                let v = colptr[c] + off;
                match r.cmp(&c) {
                    std::cmp::Ordering::Greater => {
                        lcols[lcur[r]] = c as u32;
                        lvidx[lcur[r]] = v;
                        lcur[r] += 1;
                    }
                    std::cmp::Ordering::Less => {
                        ucols[ucur[r]] = c as u32;
                        uvidx[ucur[r]] = v;
                        ucur[r] += 1;
                    }
                    std::cmp::Ordering::Equal => diag[r] = v,
                }
            }
        }
        debug_assert!(diag.iter().all(|&d| d != usize::MAX), "missing diagonal");

        // Levelize. Lower: row i waits on rows j < i it reads (ascending
        // pass). Upper: row i waits on rows j > i (descending pass).
        let mut llev = vec![0u32; n];
        for i in 0..n {
            let mut lvl = 0u32;
            for &j in &lcols[lptr[i]..lptr[i + 1]] {
                lvl = lvl.max(llev[j as usize] + 1);
            }
            llev[i] = lvl;
        }
        let mut ulev = vec![0u32; n];
        for i in (0..n).rev() {
            let mut lvl = 0u32;
            for &j in &ucols[uptr[i]..uptr[i + 1]] {
                lvl = lvl.max(ulev[j as usize] + 1);
            }
            ulev[i] = lvl;
        }
        let group = |lev: &[u32]| {
            let nlev = lev.iter().map(|&l| l + 1).max().unwrap_or(1) as usize;
            let mut levels: Vec<Vec<u32>> = vec![Vec::new(); nlev];
            for (i, &l) in lev.iter().enumerate() {
                levels[l as usize].push(i as u32);
            }
            levels
        };

        TriangularSchedule {
            lower: RowSched {
                ptr: lptr,
                cols: lcols,
                vidx: lvidx,
                diag: Vec::new(),
                levels: group(&llev),
            },
            upper: RowSched {
                ptr: uptr,
                cols: ucols,
                vidx: uvidx,
                diag,
                levels: group(&ulev),
            },
        }
    }
}

/// Level-parallel forward substitution: `x ← L⁻¹ x` on `pool`, bit-identical
/// to [`lower_unit_solve`]. `sched` must be the lower schedule built from
/// this `lu`'s pattern.
pub fn lower_unit_solve_par(lu: &Csc, sched: &RowSched, pool: &WorkerPool, x: &mut [f64]) {
    let n = lu.ncols();
    assert_eq!(x.len(), n);
    assert_eq!(sched.ptr.len(), n + 1);
    let vals = lu.values();
    let xp = SharedPtr(x.as_mut_ptr());
    pool.run(&|ctx: &PoolCtx<'_>| {
        for level in &sched.levels {
            let mut idx = ctx.id;
            while idx < level.len() {
                let i = level[idx] as usize;
                // SAFETY: row i is owned by this worker for this level;
                // entries read belong to earlier levels (published by the
                // barrier) or to the initial right-hand side.
                let mut acc = unsafe { *xp.0.add(i) };
                for e in sched.ptr[i]..sched.ptr[i + 1] {
                    let xj = unsafe { *xp.0.add(sched.cols[e] as usize) };
                    if xj != 0.0 {
                        acc -= vals[sched.vidx[e]] * xj;
                    }
                }
                unsafe { *xp.0.add(i) = acc };
                idx += ctx.threads;
            }
            if !ctx.sync() {
                return;
            }
        }
    });
}

/// Level-parallel backward substitution: `x ← U⁻¹ x` on `pool`,
/// bit-identical to [`upper_solve`]. `sched` must be the upper schedule
/// built from this `lu`'s pattern.
pub fn upper_solve_par(lu: &Csc, sched: &RowSched, pool: &WorkerPool, x: &mut [f64]) {
    let n = lu.ncols();
    assert_eq!(x.len(), n);
    assert_eq!(sched.ptr.len(), n + 1);
    assert_eq!(sched.diag.len(), n, "upper schedule required");
    let vals = lu.values();
    let xp = SharedPtr(x.as_mut_ptr());
    pool.run(&|ctx: &PoolCtx<'_>| {
        for level in &sched.levels {
            let mut idx = ctx.id;
            while idx < level.len() {
                let i = level[idx] as usize;
                // SAFETY: as in the lower solve.
                let mut acc = unsafe { *xp.0.add(i) };
                // Descending column order mirrors the sequential backward
                // substitution's term order exactly.
                for e in (sched.ptr[i]..sched.ptr[i + 1]).rev() {
                    let xj = unsafe { *xp.0.add(sched.cols[e] as usize) };
                    if xj != 0.0 {
                        acc -= vals[sched.vidx[e]] * xj;
                    }
                }
                unsafe { *xp.0.add(i) = acc / vals[sched.diag[i]] };
                idx += ctx.threads;
            }
            if !ctx.sync() {
                return;
            }
        }
    });
}

/// Blocked level-parallel forward substitution: `nrhs` interleaved planes
/// through one level walk, each plane bit-identical to
/// [`lower_unit_solve`] / [`lower_unit_solve_block`].
pub fn lower_unit_solve_par_block(
    lu: &Csc,
    sched: &RowSched,
    pool: &WorkerPool,
    xb: &mut [f64],
    nrhs: usize,
) {
    let n = lu.ncols();
    assert_eq!(xb.len(), n * nrhs);
    assert_eq!(sched.ptr.len(), n + 1);
    let vals = lu.values();
    let xp = SharedPtr(xb.as_mut_ptr());
    pool.run(&|ctx: &PoolCtx<'_>| {
        for level in &sched.levels {
            let mut idx = ctx.id;
            while idx < level.len() {
                let i = level[idx] as usize;
                let ibase = i * nrhs;
                // SAFETY: rows are dealt disjointly within a level and
                // dependencies live in earlier levels (published by the
                // barrier); plane columns of row i are exclusive to this
                // worker for the duration of the level.
                for e in sched.ptr[i]..sched.ptr[i + 1] {
                    let jbase = sched.cols[e] as usize * nrhs;
                    let lij = vals[sched.vidx[e]];
                    for p in 0..nrhs {
                        let xj = unsafe { *xp.0.add(jbase + p) };
                        if xj != 0.0 {
                            unsafe { *xp.0.add(ibase + p) -= lij * xj };
                        }
                    }
                }
                idx += ctx.threads;
            }
            if !ctx.sync() {
                return;
            }
        }
    });
}

/// Blocked level-parallel backward substitution, each plane bit-identical
/// to [`upper_solve`] / [`upper_solve_block`].
pub fn upper_solve_par_block(
    lu: &Csc,
    sched: &RowSched,
    pool: &WorkerPool,
    xb: &mut [f64],
    nrhs: usize,
) {
    let n = lu.ncols();
    assert_eq!(xb.len(), n * nrhs);
    assert_eq!(sched.ptr.len(), n + 1);
    assert_eq!(sched.diag.len(), n, "upper schedule required");
    let vals = lu.values();
    let xp = SharedPtr(xb.as_mut_ptr());
    pool.run(&|ctx: &PoolCtx<'_>| {
        for level in &sched.levels {
            let mut idx = ctx.id;
            while idx < level.len() {
                let i = level[idx] as usize;
                let ibase = i * nrhs;
                let dj = vals[sched.diag[i]];
                // SAFETY: as in the blocked lower solve.
                for e in (sched.ptr[i]..sched.ptr[i + 1]).rev() {
                    let jbase = sched.cols[e] as usize * nrhs;
                    let uij = vals[sched.vidx[e]];
                    for p in 0..nrhs {
                        let xj = unsafe { *xp.0.add(jbase + p) };
                        if xj != 0.0 {
                            unsafe { *xp.0.add(ibase + p) -= uij * xj };
                        }
                    }
                }
                for p in 0..nrhs {
                    unsafe { *xp.0.add(ibase + p) /= dj };
                }
                idx += ctx.threads;
            }
            if !ctx.sync() {
                return;
            }
        }
    });
}

/// Self-scheduling sync-free forward substitution (arXiv:1710.04985):
/// workers claim rows in ascending order from a shared counter — a
/// topological order for `L`, since row `i` only reads rows `< i` — and
/// spin on per-row ready flags instead of a per-level barrier. Per-row
/// term order matches the sequential solve, so the result is
/// bit-identical at any thread count.
pub fn lower_unit_solve_syncfree(
    lu: &Csc,
    sched: &RowSched,
    pool: &WorkerPool,
    flags: &mut ReadyFlags,
    x: &mut [f64],
) {
    let n = lu.ncols();
    assert_eq!(x.len(), n);
    assert_eq!(sched.ptr.len(), n + 1);
    let vals = lu.values();
    let done = flags.prepare(n);
    let next = AtomicUsize::new(0);
    let xp = SharedPtr(x.as_mut_ptr());
    pool.run(&|_ctx: &PoolCtx<'_>| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            return;
        }
        // SAFETY: row i is exclusively owned by its claimant; every entry
        // read belongs to a row with a strictly smaller claim index, and
        // the acquire spin on its ready flag publishes its final value.
        let mut acc = unsafe { *xp.0.add(i) };
        for e in sched.ptr[i]..sched.ptr[i + 1] {
            let j = sched.cols[e] as usize;
            while done[j].load(Ordering::Acquire) == 0 {
                std::hint::spin_loop();
            }
            let xj = unsafe { *xp.0.add(j) };
            if xj != 0.0 {
                acc -= vals[sched.vidx[e]] * xj;
            }
        }
        unsafe { *xp.0.add(i) = acc };
        done[i].store(1, Ordering::Release);
    });
}

/// Self-scheduling sync-free backward substitution: rows are claimed in
/// descending order (`n-1-k`), the topological order for `U`, where row
/// `i` only reads rows `> i`. Bit-identical to [`upper_solve`].
pub fn upper_solve_syncfree(
    lu: &Csc,
    sched: &RowSched,
    pool: &WorkerPool,
    flags: &mut ReadyFlags,
    x: &mut [f64],
) {
    let n = lu.ncols();
    assert_eq!(x.len(), n);
    assert_eq!(sched.ptr.len(), n + 1);
    assert_eq!(sched.diag.len(), n, "upper schedule required");
    let vals = lu.values();
    let done = flags.prepare(n);
    let next = AtomicUsize::new(0);
    let xp = SharedPtr(x.as_mut_ptr());
    pool.run(&|_ctx: &PoolCtx<'_>| loop {
        let k = next.fetch_add(1, Ordering::Relaxed);
        if k >= n {
            return;
        }
        let i = n - 1 - k;
        // SAFETY: as in the sync-free lower solve.
        let mut acc = unsafe { *xp.0.add(i) };
        for e in (sched.ptr[i]..sched.ptr[i + 1]).rev() {
            let j = sched.cols[e] as usize;
            while done[j].load(Ordering::Acquire) == 0 {
                std::hint::spin_loop();
            }
            let xj = unsafe { *xp.0.add(j) };
            if xj != 0.0 {
                acc -= vals[sched.vidx[e]] * xj;
            }
        }
        unsafe { *xp.0.add(i) = acc / vals[sched.diag[i]] };
        done[i].store(1, Ordering::Release);
    });
}

/// Blocked sync-free forward substitution: `nrhs` interleaved planes per
/// claimed row, each plane bit-identical to the single-plane solves.
pub fn lower_unit_solve_syncfree_block(
    lu: &Csc,
    sched: &RowSched,
    pool: &WorkerPool,
    flags: &mut ReadyFlags,
    xb: &mut [f64],
    nrhs: usize,
) {
    let n = lu.ncols();
    assert_eq!(xb.len(), n * nrhs);
    assert_eq!(sched.ptr.len(), n + 1);
    let vals = lu.values();
    let done = flags.prepare(n);
    let next = AtomicUsize::new(0);
    let xp = SharedPtr(xb.as_mut_ptr());
    pool.run(&|_ctx: &PoolCtx<'_>| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            return;
        }
        let ibase = i * nrhs;
        // SAFETY: as in the single-plane sync-free solve; all planes of a
        // row share its ready flag.
        for e in sched.ptr[i]..sched.ptr[i + 1] {
            let j = sched.cols[e] as usize;
            while done[j].load(Ordering::Acquire) == 0 {
                std::hint::spin_loop();
            }
            let jbase = j * nrhs;
            let lij = vals[sched.vidx[e]];
            for p in 0..nrhs {
                let xj = unsafe { *xp.0.add(jbase + p) };
                if xj != 0.0 {
                    unsafe { *xp.0.add(ibase + p) -= lij * xj };
                }
            }
        }
        done[i].store(1, Ordering::Release);
    });
}

/// Blocked sync-free backward substitution.
pub fn upper_solve_syncfree_block(
    lu: &Csc,
    sched: &RowSched,
    pool: &WorkerPool,
    flags: &mut ReadyFlags,
    xb: &mut [f64],
    nrhs: usize,
) {
    let n = lu.ncols();
    assert_eq!(xb.len(), n * nrhs);
    assert_eq!(sched.ptr.len(), n + 1);
    assert_eq!(sched.diag.len(), n, "upper schedule required");
    let vals = lu.values();
    let done = flags.prepare(n);
    let next = AtomicUsize::new(0);
    let xp = SharedPtr(xb.as_mut_ptr());
    pool.run(&|_ctx: &PoolCtx<'_>| loop {
        let k = next.fetch_add(1, Ordering::Relaxed);
        if k >= n {
            return;
        }
        let i = n - 1 - k;
        let ibase = i * nrhs;
        let dj = vals[sched.diag[i]];
        // SAFETY: as in the single-plane sync-free solve.
        for e in (sched.ptr[i]..sched.ptr[i + 1]).rev() {
            let j = sched.cols[e] as usize;
            while done[j].load(Ordering::Acquire) == 0 {
                std::hint::spin_loop();
            }
            let jbase = j * nrhs;
            let uij = vals[sched.vidx[e]];
            for p in 0..nrhs {
                let xj = unsafe { *xp.0.add(jbase + p) };
                if xj != 0.0 {
                    unsafe { *xp.0.add(ibase + p) -= uij * xj };
                }
            }
        }
        for p in 0..nrhs {
            unsafe { *xp.0.add(ibase + p) /= dj };
        }
        done[i].store(1, Ordering::Release);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{leftlook, residual};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    #[test]
    fn solve_and_transpose_solve() {
        let a = gen::netlist(60, 5, 8, 0.1, 1, 0.2, 21);
        let f = symbolic_fill(&a).unwrap();
        let lu = leftlook::factor(&f).unwrap();
        let b: Vec<f64> = (0..60).map(|i| ((i * 13) % 11) as f64 - 5.0).collect();

        let x = lu.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-12);

        let xt = super::transpose_solve(&lu.lu, &b);
        let at = a.transpose();
        assert!(residual(&at, &xt, &b) < 1e-12);
    }

    #[test]
    fn multiple_rhs_reuse_factors() {
        let a = gen::grid2d(7, 7, 2);
        let f = symbolic_fill(&a).unwrap();
        let lu = leftlook::factor(&f).unwrap();
        for s in 0..5 {
            let b: Vec<f64> = (0..49).map(|i| ((i + s) % 5) as f64).collect();
            let x = lu.solve(&b);
            assert!(residual(&a, &x, &b) < 1e-12);
        }
    }

    /// Random diagonally dominant matrix (the pivot-free GLU regime) with
    /// `extra` random off-diagonal pairs.
    fn random_dd(n: usize, extra: usize, rng: &mut Rng) -> crate::sparse::Csc {
        use crate::sparse::Coo;
        let mut coo = Coo::new(n, n);
        let mut rowsum = vec![0.0f64; n];
        for _ in 0..extra {
            let i = rng.below(n);
            let j = rng.below(n);
            if i == j {
                continue;
            }
            let v = rng.range_f64(-1.0, 1.0);
            let w = rng.range_f64(-1.0, 1.0);
            coo.push(i, j, v);
            coo.push(j, i, w);
            rowsum[i] += v.abs();
            rowsum[j] += w.abs();
        }
        for i in 0..n {
            coo.push(i, i, rowsum[i] + 1.0 + rng.f64());
        }
        coo.to_csc()
    }

    #[test]
    fn parallel_trisolve_bit_identical_to_sequential() {
        let mut rng = Rng::new(0x7215);
        for trial in 0..6 {
            let n = rng.range(40, 250);
            let a = random_dd(n, n * 3, &mut rng);
            let f = symbolic_fill(&a).unwrap();
            let lu = leftlook::factor(&f).unwrap();
            let sched = TriangularSchedule::build(&lu.lu);
            let b: Vec<f64> = (0..n).map(|i| ((i * 31 + trial) % 17) as f64 - 8.0).collect();

            let mut seq = b.clone();
            super::lower_unit_solve(&lu.lu, &mut seq);
            let mut seq_lower = seq.clone();
            super::upper_solve(&lu.lu, &mut seq);

            for threads in [1, 2, 4] {
                let pool = crate::numeric::pool::WorkerPool::new(threads);
                let mut par = b.clone();
                lower_unit_solve_par(&lu.lu, &sched.lower, &pool, &mut par);
                assert_eq!(par, seq_lower, "trial {trial} threads {threads}: lower");
                upper_solve_par(&lu.lu, &sched.upper, &pool, &mut par);
                assert_eq!(par, seq, "trial {trial} threads {threads}: upper");
            }
            // sanity: the parallel pipeline actually solves the system
            std::mem::swap(&mut seq_lower, &mut seq);
            assert!(residual(&a, &seq_lower, &b) < 1e-10);
        }
    }

    #[test]
    fn syncfree_trisolve_bit_identical_to_sequential_and_levelset() {
        let mut rng = Rng::new(0x5F5F);
        for trial in 0..6 {
            let n = rng.range(40, 250);
            let a = random_dd(n, n * 3, &mut rng);
            let f = symbolic_fill(&a).unwrap();
            let lu = leftlook::factor(&f).unwrap();
            let sched = TriangularSchedule::build(&lu.lu);
            let b: Vec<f64> = (0..n).map(|i| ((i * 29 + trial) % 19) as f64 - 9.0).collect();

            let mut seq = b.clone();
            super::lower_unit_solve(&lu.lu, &mut seq);
            let seq_lower = seq.clone();
            super::upper_solve(&lu.lu, &mut seq);

            for threads in [1, 2, 4] {
                let pool = WorkerPool::new(threads);
                let mut flags = ReadyFlags::new();
                let mut sf = b.clone();
                lower_unit_solve_syncfree(&lu.lu, &sched.lower, &pool, &mut flags, &mut sf);
                assert_eq!(sf, seq_lower, "trial {trial} threads {threads}: lower");
                upper_solve_syncfree(&lu.lu, &sched.upper, &pool, &mut flags, &mut sf);
                assert_eq!(sf, seq, "trial {trial} threads {threads}: upper");
            }
        }
    }

    #[test]
    fn blocked_trisolves_match_looped_single_solves() {
        let mut rng = Rng::new(0xB10C);
        let n = 150;
        let a = random_dd(n, n * 3, &mut rng);
        let f = symbolic_fill(&a).unwrap();
        let lu = leftlook::factor(&f).unwrap();
        let sched = TriangularSchedule::build(&lu.lu);
        for nrhs in [1usize, 3, 8] {
            // looped reference: one full solve per plane
            let planes: Vec<Vec<f64>> = (0..nrhs)
                .map(|p| (0..n).map(|i| ((i * 7 + p * 13) % 23) as f64 - 11.0).collect())
                .collect();
            let mut refs = planes.clone();
            for r in &mut refs {
                super::lower_unit_solve(&lu.lu, r);
                super::upper_solve(&lu.lu, r);
            }
            let interleave = |ps: &[Vec<f64>]| -> Vec<f64> {
                let mut xb = vec![0.0; n * nrhs];
                for (p, plane) in ps.iter().enumerate() {
                    for i in 0..n {
                        xb[i * nrhs + p] = plane[i];
                    }
                }
                xb
            };
            let check = |xb: &[f64], what: &str| {
                for (p, r) in refs.iter().enumerate() {
                    for i in 0..n {
                        assert_eq!(xb[i * nrhs + p], r[i], "{what}: nrhs {nrhs} plane {p} row {i}");
                    }
                }
            };

            let mut xb = interleave(&planes);
            lower_unit_solve_block(&lu.lu, &mut xb, nrhs);
            upper_solve_block(&lu.lu, &mut xb, nrhs);
            check(&xb, "sequential block");

            for threads in [1, 2, 4] {
                let pool = WorkerPool::new(threads);
                let mut xb = interleave(&planes);
                lower_unit_solve_par_block(&lu.lu, &sched.lower, &pool, &mut xb, nrhs);
                upper_solve_par_block(&lu.lu, &sched.upper, &pool, &mut xb, nrhs);
                check(&xb, "level-set block");

                let mut flags = ReadyFlags::new();
                let mut xb = interleave(&planes);
                lower_unit_solve_syncfree_block(
                    &lu.lu,
                    &sched.lower,
                    &pool,
                    &mut flags,
                    &mut xb,
                    nrhs,
                );
                upper_solve_syncfree_block(
                    &lu.lu,
                    &sched.upper,
                    &pool,
                    &mut flags,
                    &mut xb,
                    nrhs,
                );
                check(&xb, "sync-free block");
            }
        }
    }

    #[test]
    fn variant_choice_follows_level_stats() {
        // wide, shallow: dense-ish random matrix → level-set
        let mut rng = Rng::new(0xA11A);
        let a = random_dd(200, 600, &mut rng);
        let f = symbolic_fill(&a).unwrap();
        let lu = leftlook::factor(&f).unwrap();
        let sched = TriangularSchedule::build(&lu.lu);
        if sched.parallel_worthwhile() {
            assert_ne!(sched.choose_variant(), TrisolveVariant::Sequential);
        } else {
            assert_eq!(sched.choose_variant(), TrisolveVariant::Sequential);
        }

        // a chain (bidiagonal) levelizes to width 1 → sequential
        use crate::sparse::Coo;
        let n = 64;
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, 2.0);
            if i + 1 < n {
                coo.push(i + 1, i, -1.0);
            }
        }
        let chain = coo.to_csc();
        let sched = TriangularSchedule::build(&chain);
        assert_eq!(sched.choose_variant(), TrisolveVariant::Sequential);
    }

    #[test]
    fn schedule_levels_partition_rows_and_respect_dependencies() {
        let a = gen::netlist(120, 6, 10, 0.08, 2, 0.2, 55);
        let f = symbolic_fill(&a).unwrap();
        let lu = leftlook::factor(&f).unwrap();
        let sched = TriangularSchedule::build(&lu.lu);
        for rs in [&sched.lower, &sched.upper] {
            let total: usize = rs.levels.iter().map(|l| l.len()).sum();
            assert_eq!(total, 120, "levels partition the rows");
            assert!(rs.num_levels() >= 1);
            let width = rs.mean_level_width();
            assert!((width - 120.0 / rs.num_levels() as f64).abs() < 1e-12);
            // every row's entries point at rows in strictly earlier levels
            let mut level_of = vec![0u32; 120];
            for (l, rows) in rs.levels.iter().enumerate() {
                for &r in rows {
                    level_of[r as usize] = l as u32;
                }
            }
            for i in 0..120 {
                for &j in &rs.cols[rs.ptr[i]..rs.ptr[i + 1]] {
                    assert!(
                        level_of[j as usize] < level_of[i],
                        "row {i} depends on row {j} in the same/later level"
                    );
                }
            }
        }
    }
}
