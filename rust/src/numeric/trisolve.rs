//! Sparse triangular solves over compact LU factors.
//!
//! Completes `Ax = b` after factorization (the `L y = b`, `U x = y` halves
//! of the paper's SPICE use-case); also exercised standalone by the
//! coordinator's repeated-solve path (same factors, many right-hand sides —
//! the Newton–Raphson pattern).

use crate::sparse::Csc;

/// In-place forward substitution with the unit-lower factor stored in the
/// strictly-lower triangle of `lu`: `x ← L⁻¹ x`.
pub fn lower_unit_solve(lu: &Csc, x: &mut [f64]) {
    let n = lu.ncols();
    assert_eq!(x.len(), n);
    for j in 0..n {
        let xj = x[j];
        if xj == 0.0 {
            continue;
        }
        let (rows, vals) = lu.col(j);
        let start = rows.partition_point(|&r| r <= j);
        for (&i, &lij) in rows[start..].iter().zip(&vals[start..]) {
            x[i] -= lij * xj;
        }
    }
}

/// In-place backward substitution with the upper factor (diagonal included):
/// `x ← U⁻¹ x`.
pub fn upper_solve(lu: &Csc, x: &mut [f64]) {
    let n = lu.ncols();
    assert_eq!(x.len(), n);
    for j in (0..n).rev() {
        let (rows, vals) = lu.col(j);
        let dpos = rows.partition_point(|&r| r < j);
        debug_assert!(rows[dpos] == j, "missing diagonal");
        let xj = x[j] / vals[dpos];
        x[j] = xj;
        if xj == 0.0 {
            continue;
        }
        for (&i, &uij) in rows[..dpos].iter().zip(&vals[..dpos]) {
            x[i] -= uij * xj;
        }
    }
}

/// Transpose solve `Aᵀ x = b` over the same factors (`Uᵀ y = b`, `Lᵀ x = y`)
/// — used by adjoint/sensitivity analysis in circuit simulators.
pub fn transpose_solve(lu: &Csc, b: &[f64]) -> Vec<f64> {
    let n = lu.ncols();
    let mut x = b.to_vec();
    // U^T is lower triangular (non-unit): forward substitution by columns.
    for j in 0..n {
        let (rows, vals) = lu.col(j);
        let dpos = rows.partition_point(|&r| r < j);
        let mut acc = x[j];
        for (&i, &uij) in rows[..dpos].iter().zip(&vals[..dpos]) {
            acc -= uij * x[i];
        }
        x[j] = acc / vals[dpos];
    }
    // L^T is unit upper: backward substitution.
    for j in (0..n).rev() {
        let (rows, vals) = lu.col(j);
        let start = rows.partition_point(|&r| r <= j);
        let mut acc = x[j];
        for (&i, &lij) in rows[start..].iter().zip(&vals[start..]) {
            acc -= lij * x[i];
        }
        x[j] = acc;
    }
    x
}

#[cfg(test)]
mod tests {
    use crate::numeric::{leftlook, residual};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;

    #[test]
    fn solve_and_transpose_solve() {
        let a = gen::netlist(60, 5, 8, 0.1, 1, 0.2, 21);
        let f = symbolic_fill(&a).unwrap();
        let lu = leftlook::factor(&f).unwrap();
        let b: Vec<f64> = (0..60).map(|i| ((i * 13) % 11) as f64 - 5.0).collect();

        let x = lu.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-12);

        let xt = super::transpose_solve(&lu.lu, &b);
        let at = a.transpose();
        assert!(residual(&at, &xt, &b) < 1e-12);
    }

    #[test]
    fn multiple_rhs_reuse_factors() {
        let a = gen::grid2d(7, 7, 2);
        let f = symbolic_fill(&a).unwrap();
        let lu = leftlook::factor(&f).unwrap();
        for s in 0..5 {
            let b: Vec<f64> = (0..49).map(|i| ((i + s) % 5) as f64).collect();
            let x = lu.solve(&b);
            assert!(residual(&a, &x, &b) < 1e-12);
        }
    }
}
