//! Numeric factorization engines and triangular solves.
//!
//! All engines factor the *same* statically-filled pattern `As = L + U`
//! (from [`crate::symbolic::symbolic_fill`]) without pivoting — the GLU
//! regime — and produce a compact [`LuFactors`]: `L`'s unit diagonal is
//! implicit, `U` includes the diagonal, both share `As`'s storage.
//!
//! - [`leftlook`] — Algorithm 1, the sequential Gilbert–Peierls oracle.
//! - [`rightlook`] — Algorithm 2, the sequential hybrid right-looking
//!   reference: *bit-identical* op order to one GPU column pipeline, used to
//!   cross-check the simulator's numerics.
//! - [`parlu`] — NICSLU-style multithreaded left-looking CPU baseline
//!   (level-scheduled, Table I's CPU comparison column), running on the
//!   persistent [`pool::WorkerPool`].
//! - [`parrl`] — parallel hybrid right-looking on the hazard-free
//!   GLU2.0/GLU3.0 schedule: the paper's execution model with real CPU
//!   threads (wall-clock, not simulated cycles). Its 1-thread run is one
//!   corner of the conformance triangle with
//!   [`crate::gpusim::executor::simulate_refactorization`] and the
//!   schedule executor ([`crate::runtime::executor::VirtualDevice`]) —
//!   see `rust/tests/conformance.rs`.
//! - [`pool`] — the spawn-once worker pool + spin barrier all the
//!   real-parallel paths (including the parallel triangular solves) share.
//! - [`trisolve`] — sparse forward/backward substitution over the factors,
//!   sequential and level-scheduled parallel.
//! - [`dense`] — dense LU with partial pivoting: the small-scale oracle the
//!   property tests compare everything against.

pub mod dense;
pub mod leftlook;
pub mod parlu;
pub mod parrl;
pub mod pool;
pub mod rightlook;
pub mod trisolve;

pub use pool::WorkerPool;

use crate::sparse::Csc;

/// Compact LU factors over a filled pattern.
///
/// Entry `(i, j)` of the underlying CSC holds `U(i,j)` for `i <= j` and
/// `L(i,j)` for `i > j`; `L`'s diagonal is implicitly 1.
#[derive(Debug, Clone)]
pub struct LuFactors {
    /// Factored matrix (same pattern as the symbolic fill).
    pub lu: Csc,
}

impl LuFactors {
    /// Dimension.
    pub fn n(&self) -> usize {
        self.lu.ncols()
    }

    /// Solve `LUx = b` (forward + backward substitution).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        trisolve::lower_unit_solve(&self.lu, &mut x);
        trisolve::upper_solve(&self.lu, &mut x);
        x
    }

    /// Reconstruct `L*U` densely (test helper, small n only).
    pub fn reconstruct_dense(&self) -> Vec<f64> {
        let n = self.n();
        let mut l = vec![0.0; n * n];
        let mut u = vec![0.0; n * n];
        for i in 0..n {
            l[i * n + i] = 1.0;
        }
        for c in 0..n {
            let (rows, vals) = self.lu.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                if r > c {
                    l[r * n + c] = v;
                } else {
                    u[r * n + c] = v;
                }
            }
        }
        let mut prod = vec![0.0; n * n];
        for i in 0..n {
            for k in 0..n {
                let lik = l[i * n + k];
                if lik != 0.0 {
                    for j in 0..n {
                        prod[i * n + j] += lik * u[k * n + j];
                    }
                }
            }
        }
        prod
    }
}

/// Maximum relative residual `‖Ax − b‖∞ / (‖A‖_F ‖x‖∞ + ‖b‖∞)` — the
/// acceptance metric used across the numeric tests.
pub fn residual(a: &Csc, x: &[f64], b: &[f64]) -> f64 {
    let ax = a.matvec(x);
    let num = ax
        .iter()
        .zip(b)
        .map(|(p, q)| (p - q).abs())
        .fold(0.0, f64::max);
    let xn = x.iter().map(|v| v.abs()).fold(0.0, f64::max);
    let bn = b.iter().map(|v| v.abs()).fold(0.0, f64::max);
    num / (a.fro_norm() * xn + bn + f64::MIN_POSITIVE)
}
