//! Numeric factorization engines and triangular solves.
//!
//! All engines factor the *same* statically-filled pattern `As = L + U`
//! (from [`crate::symbolic::symbolic_fill`]) without pivoting — the GLU
//! regime — and produce a compact [`LuFactors`]: `L`'s unit diagonal is
//! implicit, `U` includes the diagonal, both share `As`'s storage.
//!
//! - [`leftlook`] — Algorithm 1, the sequential Gilbert–Peierls oracle.
//! - [`rightlook`] — Algorithm 2, the sequential hybrid right-looking
//!   reference: *bit-identical* op order to one GPU column pipeline, used to
//!   cross-check the simulator's numerics.
//! - [`parlu`] — NICSLU-style multithreaded left-looking CPU baseline
//!   (level-scheduled, Table I's CPU comparison column), running on the
//!   persistent [`pool::WorkerPool`].
//! - [`parrl`] — parallel hybrid right-looking on the hazard-free
//!   GLU2.0/GLU3.0 schedule: the paper's execution model with real CPU
//!   threads (wall-clock, not simulated cycles). Its 1-thread run is one
//!   corner of the conformance triangle with
//!   [`crate::gpusim::executor::simulate_refactorization`] and the
//!   schedule executor ([`crate::runtime::executor::VirtualDevice`]) —
//!   see `rust/tests/conformance.rs`.
//! - [`pivlu`] — Gilbert–Peierls left-looking LU **with threshold partial
//!   pivoting**: the rung-5 rescue for matrices whose fixed pivot order is
//!   numerically unsalvageable (discovers fill on the fly, emits the new
//!   row permutation; see the robustness ladder in [`crate::glu`]).
//! - [`pool`] — the spawn-once worker pool + spin barrier all the
//!   real-parallel paths (including the parallel triangular solves) share.
//! - [`trisolve`] — sparse forward/backward substitution over the factors,
//!   sequential and level-scheduled parallel.
//! - [`dense`] — dense LU with partial pivoting: the small-scale oracle the
//!   property tests compare everything against.

pub mod dense;
pub mod leftlook;
pub mod parlu;
pub mod parrl;
pub mod pivlu;
pub mod pool;
pub mod rightlook;
pub mod trisolve;

pub use pool::WorkerPool;

use crate::sparse::Csc;

/// Typed failure classification, carried as the payload of the
/// `anyhow::Error` the solver stack raises (recover it with
/// `err.downcast_ref::<GluError>()`). The robustness ladder and the
/// [`crate::coordinator::SolverPool`] use it to tell a *values*-level
/// singularity (repairable: the symbolic state is still viable, retry with
/// perturbation/re-equilibration or fresh values) from a structural
/// failure (not repairable on this pattern); the serving layer
/// ([`crate::coordinator::serve`]) extends the same payload mechanism to
/// admission, deadline, and worker-lifecycle failures, and uses
/// [`GluError::is_transient`] to decide what retry-with-backoff may touch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GluError {
    /// The factorization hit a zero / non-finite pivot at column `col`:
    /// the *values* are singular under the static pivot order, the
    /// pattern and schedule remain valid. Raised only after the repair
    /// ladder is exhausted — **terminal** for the request that stamped
    /// these values (retrying the same values climbs the same ladder to
    /// the same dead end), though the cached pattern stays serviceable.
    NumericallySingular { col: usize },
    /// Admission control rejected the request: the bounded queue is at
    /// `depth` of `capacity` (or past the submitting tenant's
    /// priority-scaled share of it). **Transient** — the caller may back
    /// off and resubmit once the queue drains.
    Overloaded { depth: usize, capacity: usize },
    /// The request's deadline expired before an answer was produced;
    /// `budget_ms` is the deadline it was admitted with. **Terminal** for
    /// this request — the serving loop already spent the time budget.
    DeadlineExceeded { budget_ms: u64 },
    /// A service worker thread died (panic or lost channel) while the
    /// request was in flight. **Terminal**: the request's state is gone.
    WorkerPanicked,
    /// A deterministically injected transient fault (the chaos harness's
    /// poisoned-checkout action). **Transient** by construction — the
    /// retry path must absorb it.
    TransientFault,
}

impl GluError {
    /// Whether a retry (with backoff) can plausibly succeed. The ladder's
    /// in-place repairs never surface here — a repaired refactor returns
    /// `Ok` — so the only transient failures are load-level
    /// ([`GluError::Overloaded`]) and injected ([`GluError::TransientFault`])
    /// ones; [`GluError::NumericallySingular`] exhaustion is terminal and
    /// must never be retried with the same values.
    pub fn is_transient(&self) -> bool {
        matches!(self, GluError::Overloaded { .. } | GluError::TransientFault)
    }
}

impl std::fmt::Display for GluError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GluError::NumericallySingular { col } => {
                write!(f, "zero/non-finite pivot at column {col}")
            }
            GluError::Overloaded { depth, capacity } => {
                write!(f, "admission queue overloaded ({depth}/{capacity})")
            }
            GluError::DeadlineExceeded { budget_ms } => {
                write!(f, "deadline exceeded ({budget_ms} ms budget)")
            }
            GluError::WorkerPanicked => write!(f, "service worker thread died"),
            GluError::TransientFault => write!(f, "injected transient fault"),
        }
    }
}

/// The error every engine raises on a zero / non-finite pivot: the
/// classic message (so diagnostics — and anything matching on "pivot" —
/// stay unchanged) with a typed [`GluError::NumericallySingular`] payload
/// underneath.
pub(crate) fn singular_pivot(col: usize) -> anyhow::Error {
    let e = GluError::NumericallySingular { col };
    anyhow::Error::with_payload(e, e)
}

/// Wrap a [`GluError`] as an `anyhow::Error` whose Display is the error's
/// own message and whose typed payload is recoverable with
/// `downcast_ref::<GluError>()` — the serving layer's counterpart of
/// [`singular_pivot`].
pub fn service_error(e: GluError) -> anyhow::Error {
    anyhow::Error::with_payload(e, e)
}

/// Transient-vs-terminal classification of an error chain: `true` iff the
/// chain carries a typed [`GluError`] payload whose
/// [`GluError::is_transient`] says a backoff-retry may succeed. Untyped
/// errors are conservatively terminal (structural failures, I/O, bugs).
pub fn is_transient(err: &anyhow::Error) -> bool {
    err.downcast_ref::<GluError>()
        .is_some_and(GluError::is_transient)
}

/// Cheap pivot-growth monitor threaded through every factorization
/// kernel: a running max/min of `|pivot|` across the columns the kernel
/// divides by. Two scalar compares per column — nothing on the MAC hot
/// loop — yet enough for the robustness ladder's two estimates:
///
/// - **pivot growth** `max|pivot| / max|A_s|` (against the stamped-value
///   max the caller measures at scatter time): the classic element-growth
///   proxy — explosive growth means the static pivot order is numerically
///   degrading even when no pivot is exactly zero;
/// - **condition estimate** `max|pivot| / min|pivot|`: the diagonal-ratio
///   lower bound on `κ(U)`.
#[derive(Debug, Clone, Copy)]
pub struct PivotMonitor {
    /// Largest `|pivot|` seen (0.0 until a column is factored).
    pub max_abs_pivot: f64,
    /// Smallest `|pivot|` seen (`+inf` until a column is factored).
    pub min_abs_pivot: f64,
}

impl Default for PivotMonitor {
    fn default() -> Self {
        Self::new()
    }
}

impl PivotMonitor {
    /// A monitor that has observed nothing.
    pub fn new() -> Self {
        PivotMonitor {
            max_abs_pivot: 0.0,
            min_abs_pivot: f64::INFINITY,
        }
    }

    /// Observe one column's pivot (called once per divide phase).
    #[inline]
    pub fn observe(&mut self, pivot: f64) {
        let p = pivot.abs();
        if p > self.max_abs_pivot {
            self.max_abs_pivot = p;
        }
        if p < self.min_abs_pivot {
            self.min_abs_pivot = p;
        }
    }

    /// Merge another monitor's extrema (parallel engines merge per-worker
    /// locals through this).
    pub fn merge(&mut self, other: &PivotMonitor) {
        self.max_abs_pivot = self.max_abs_pivot.max(other.max_abs_pivot);
        self.min_abs_pivot = self.min_abs_pivot.min(other.min_abs_pivot);
    }

    /// Pivot growth against the largest stamped input value (0.0 when
    /// nothing was observed or the stamp max is degenerate).
    pub fn growth(&self, max_abs_stamp: f64) -> f64 {
        if max_abs_stamp > 0.0 && self.max_abs_pivot > 0.0 {
            self.max_abs_pivot / max_abs_stamp
        } else {
            0.0
        }
    }

    /// Diagonal-ratio condition estimate `max|pivot| / min|pivot|`
    /// (`+inf` for a zero pivot, 0.0 when nothing was observed).
    pub fn condition_estimate(&self) -> f64 {
        if self.min_abs_pivot.is_finite() && self.max_abs_pivot > 0.0 {
            self.max_abs_pivot / self.min_abs_pivot
        } else {
            0.0
        }
    }
}

/// A batch of value planes over one shared sparsity pattern, stored
/// interleaved: plane `p`'s value for pattern position `idx` lives at
/// `data[idx * planes + p]`, so the plane dimension is contiguous and the
/// batched kernels' innermost loops (`for p in 0..planes`) vectorize.
///
/// This is the batched-refactor layout of ROADMAP item 5: circuit
/// transient analysis re-runs the *same* levelized schedule with new
/// values every Newton step, so B value planes ride one schedule walk —
/// the per-task index gather/scatter (shared across planes through the
/// [`crate::plan::ScatterMap`]) is paid once instead of B times.
#[derive(Debug, Clone)]
pub struct ValuePlanes {
    planes: usize,
    nnz: usize,
    data: Vec<f64>,
}

impl ValuePlanes {
    /// Zero-initialized batch of `planes` planes over `nnz` positions.
    pub fn new(planes: usize, nnz: usize) -> Self {
        assert!(planes > 0, "a batch needs at least one plane");
        ValuePlanes {
            planes,
            nnz,
            data: vec![0.0; planes * nnz],
        }
    }

    /// Number of planes (the batch dimension B).
    pub fn planes(&self) -> usize {
        self.planes
    }

    /// Pattern positions per plane.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Overwrite plane `p` from a flat per-pattern value slice.
    pub fn set_plane(&mut self, p: usize, vals: &[f64]) {
        assert!(p < self.planes && vals.len() == self.nnz);
        for (idx, &v) in vals.iter().enumerate() {
            self.data[idx * self.planes + p] = v;
        }
    }

    /// Copy plane `p` out into a flat per-pattern value slice.
    pub fn copy_plane(&self, p: usize, out: &mut [f64]) {
        assert!(p < self.planes && out.len() == self.nnz);
        for (idx, slot) in out.iter_mut().enumerate() {
            *slot = self.data[idx * self.planes + p];
        }
    }

    /// Plane `p` as a freshly allocated vector (test/convenience path; the
    /// hot paths use [`ValuePlanes::copy_plane`] into reused storage).
    pub fn plane(&self, p: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.nnz];
        self.copy_plane(p, &mut out);
        out
    }

    /// The interleaved backing storage.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable interleaved backing storage (the batched kernels' view).
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }
}

/// Compact LU factors over a filled pattern.
///
/// Entry `(i, j)` of the underlying CSC holds `U(i,j)` for `i <= j` and
/// `L(i,j)` for `i > j`; `L`'s diagonal is implicitly 1.
#[derive(Debug, Clone)]
pub struct LuFactors {
    /// Factored matrix (same pattern as the symbolic fill).
    pub lu: Csc,
}

impl LuFactors {
    /// Dimension.
    pub fn n(&self) -> usize {
        self.lu.ncols()
    }

    /// Solve `LUx = b` (forward + backward substitution).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        trisolve::lower_unit_solve(&self.lu, &mut x);
        trisolve::upper_solve(&self.lu, &mut x);
        x
    }

    /// Reconstruct `L*U` densely (test helper, small n only).
    pub fn reconstruct_dense(&self) -> Vec<f64> {
        let n = self.n();
        let mut l = vec![0.0; n * n];
        let mut u = vec![0.0; n * n];
        for i in 0..n {
            l[i * n + i] = 1.0;
        }
        for c in 0..n {
            let (rows, vals) = self.lu.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                if r > c {
                    l[r * n + c] = v;
                } else {
                    u[r * n + c] = v;
                }
            }
        }
        let mut prod = vec![0.0; n * n];
        for i in 0..n {
            for k in 0..n {
                let lik = l[i * n + k];
                if lik != 0.0 {
                    for j in 0..n {
                        prod[i * n + j] += lik * u[k * n + j];
                    }
                }
            }
        }
        prod
    }
}

/// Maximum relative residual `‖Ax − b‖∞ / (‖A‖_F ‖x‖∞ + ‖b‖∞)` — the
/// acceptance metric used across the numeric tests.
pub fn residual(a: &Csc, x: &[f64], b: &[f64]) -> f64 {
    let ax = a.matvec(x);
    let num = ax
        .iter()
        .zip(b)
        .map(|(p, q)| (p - q).abs())
        .fold(0.0, f64::max);
    let xn = x.iter().map(|v| v.abs()).fold(0.0, f64::max);
    let bn = b.iter().map(|v| v.abs()).fold(0.0, f64::max);
    num / (a.fro_norm() * xn + bn + f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_vs_terminal_classification() {
        // Terminal: singular exhaustion, deadlines, dead workers.
        assert!(!GluError::NumericallySingular { col: 3 }.is_transient());
        assert!(!GluError::DeadlineExceeded { budget_ms: 50 }.is_transient());
        assert!(!GluError::WorkerPanicked.is_transient());
        // Transient: load shedding and injected faults.
        let over = GluError::Overloaded {
            depth: 8,
            capacity: 8,
        };
        assert!(over.is_transient());
        assert!(GluError::TransientFault.is_transient());
    }

    #[test]
    fn chain_classification_requires_typed_payload() {
        // Untyped errors are conservatively terminal.
        assert!(!is_transient(&anyhow::anyhow!("structural failure")));
        // Typed payloads classify through context frames.
        let e = service_error(GluError::Overloaded {
            depth: 9,
            capacity: 8,
        })
        .context("while submitting");
        assert!(is_transient(&e));
        let e = singular_pivot(7).context("while refactoring");
        assert!(!is_transient(&e));
    }

    #[test]
    fn service_error_payload_and_display() {
        let e = service_error(GluError::DeadlineExceeded { budget_ms: 250 });
        assert_eq!(format!("{e}"), "deadline exceeded (250 ms budget)");
        assert_eq!(
            e.downcast_ref::<GluError>(),
            Some(&GluError::DeadlineExceeded { budget_ms: 250 })
        );
    }
}
