//! NICSLU-style multithreaded left-looking factorization — the CPU-parallel
//! baseline of Table I ("NICSLU (CPU)" column).
//!
//! Column tasks are level-scheduled exactly like NICSLU's cluster/pipeline
//! modes: the U-pattern dependency graph (sufficient for *left*-looking —
//! the double-U hazard is a right-looking artifact) is levelized, and each
//! level's columns are factored by a pool of worker threads with a barrier
//! between levels.
//!
//! Safety model: within a level, thread `t` writes only the value ranges of
//! the columns assigned to it, and reads only columns from *earlier* levels
//! (guaranteed by the dependency analysis) plus its own workspace. The
//! barrier between levels publishes all writes (thread join/spawn in
//! `std::thread::scope` provides the needed synchronization).

use crate::depend::{glu1, levelize};
use crate::symbolic::SymbolicFill;

use super::LuFactors;

/// Raw shared-values handle. See module docs for the aliasing discipline.
struct SharedVals(*mut f64);
unsafe impl Send for SharedVals {}
unsafe impl Sync for SharedVals {}

/// Factor with `nthreads` workers (values identical to the sequential
/// left-looking oracle; scheduling identical in spirit to NICSLU).
pub fn factor(sym: &SymbolicFill, nthreads: usize) -> anyhow::Result<LuFactors> {
    let n = sym.filled.ncols();
    let nthreads = nthreads.max(1);
    let levels = levelize(&glu1::detect(&sym.filled));

    let mut lu = sym.filled.clone();
    let colptr: Vec<usize> = lu.colptr().to_vec();
    let rowidx: Vec<usize> = lu.rowidx().to_vec();
    let shared = SharedVals(lu.values_mut().as_mut_ptr());
    let shared_ref = &shared;
    let colptr_ref = &colptr;
    let rowidx_ref = &rowidx;

    let failed = std::sync::atomic::AtomicUsize::new(usize::MAX);
    let failed_ref = &failed;

    for level in &levels.levels {
        std::thread::scope(|scope| {
            let chunk = level.len().div_ceil(nthreads);
            for cols in level.chunks(chunk.max(1)) {
                scope.spawn(move || {
                    let mut work = vec![0.0f64; n];
                    for &j in cols {
                        let j = j as usize;
                        // SAFETY: see module docs — this thread owns column
                        // j's range; all reads target earlier levels.
                        let vals = shared_ref.0;
                        let (s, e) = (colptr_ref[j], colptr_ref[j + 1]);
                        let rows_j = &rowidx_ref[s..e];
                        for (idx, &r) in rows_j.iter().enumerate() {
                            work[r] = unsafe { *vals.add(s + idx) };
                        }
                        for &k in rows_j.iter().take_while(|&&k| k < j) {
                            let xk = work[k];
                            if xk != 0.0 {
                                let (ks, ke) = (colptr_ref[k], colptr_ref[k + 1]);
                                let rows_k = &rowidx_ref[ks..ke];
                                let start = rows_k.partition_point(|&r| r <= k);
                                for (off, &i) in rows_k[start..].iter().enumerate() {
                                    let lik = unsafe { *vals.add(ks + start + off) };
                                    work[i] -= lik * xk;
                                }
                            }
                        }
                        let pivot = work[j];
                        if pivot == 0.0 || !pivot.is_finite() {
                            failed_ref.store(j, std::sync::atomic::Ordering::Relaxed);
                            return;
                        }
                        for (idx, &r) in rows_j.iter().enumerate() {
                            let v = if r > j { work[r] / pivot } else { work[r] };
                            unsafe { *vals.add(s + idx) = v };
                            work[r] = 0.0;
                        }
                    }
                });
            }
        });
        let f = failed.load(std::sync::atomic::Ordering::Relaxed);
        anyhow::ensure!(f == usize::MAX, "zero/non-finite pivot at column {f}");
    }
    Ok(LuFactors { lu })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::leftlook;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;

    #[test]
    fn matches_sequential_oracle() {
        for nthreads in [1, 2, 4] {
            let a = gen::netlist(300, 6, 12, 0.05, 3, 0.2, 77);
            let f = symbolic_fill(&a).unwrap();
            let seq = leftlook::factor(&f).unwrap();
            let par = factor(&f, nthreads).unwrap();
            for (p, q) in par.lu.values().iter().zip(seq.lu.values()) {
                assert_eq!(p, q, "parallel left-looking must be bit-identical");
            }
        }
    }

    #[test]
    fn solves_correctly() {
        let a = gen::grid2d(12, 12, 6);
        let f = symbolic_fill(&a).unwrap();
        let lu = factor(&f, 4).unwrap();
        let b = vec![2.0; 144];
        let x = lu.solve(&b);
        assert!(crate::numeric::residual(&a, &x, &b) < 1e-12);
    }

    #[test]
    fn reports_singularity() {
        use crate::sparse::Coo;
        // Make a matrix whose (1,1) pivot cancels exactly during updates:
        // [[1, 1], [1, 1]] -> U(1,1) = 0.
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 1.0);
        let f = symbolic_fill(&coo.to_csc()).unwrap();
        assert!(factor(&f, 2).is_err());
    }
}
