//! NICSLU-style multithreaded left-looking factorization — the CPU-parallel
//! baseline of Table I ("NICSLU (CPU)" column).
//!
//! Column tasks are level-scheduled exactly like NICSLU's cluster/pipeline
//! modes: the U-pattern dependency graph (sufficient for *left*-looking —
//! the double-U hazard is a right-looking artifact) is levelized, and each
//! level's columns are factored by a **persistent** [`WorkerPool`]: the
//! workers are spawned once and meet at a spin barrier between levels, and
//! columns within a level are dealt round-robin (interleaved) across
//! workers for load balance. The seed implementation respawned OS threads
//! at every level ([`factor_spawn_per_level`], kept as the wall-clock
//! baseline for the bench harness); on circuit matrices with thousands of
//! shallow levels that spawn/join cost dwarfs the arithmetic.
//!
//! Safety model: within a level, a worker writes only the value ranges of
//! the columns assigned to it, and reads only columns from *earlier* levels
//! (guaranteed by the dependency analysis) plus its own workspace. The
//! inter-level barrier publishes all writes ([`PoolCtx::sync`]'s AcqRel
//! rendezvous; thread join/spawn provides the same in the legacy baseline).
//!
//! Failure handling: a zero/non-finite pivot records the failing column in
//! a shared abort flag that every worker re-checks between columns, so the
//! rest of the level stops early instead of computing doomed columns; the
//! error is reported after the level rendezvous.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::depend::{glu1, levelize, Levels};
use crate::numeric::pool::{PoolCtx, SharedPtr, WorkerPool};
use crate::symbolic::SymbolicFill;

use super::LuFactors;

/// Compute the left-looking level schedule (U-pattern dependency graph).
/// Callers that refactor repeatedly should compute this once and reuse it
/// via [`factor_with`] / [`refactor_in_place`].
pub fn leftlook_levels(sym: &SymbolicFill) -> Levels {
    levelize(&glu1::detect(&sym.filled))
}

/// Factor with `nthreads` workers (values identical to the sequential
/// left-looking oracle; scheduling identical in spirit to NICSLU).
///
/// Convenience wrapper: computes the level schedule and spawns a transient
/// [`WorkerPool`] (one spawn per *factorization*, not per level). Hot
/// loops (Newton refactorization) should hold a persistent pool and
/// schedule and call [`factor_with`] / [`refactor_in_place`] instead.
pub fn factor(sym: &SymbolicFill, nthreads: usize) -> anyhow::Result<LuFactors> {
    let levels = leftlook_levels(sym);
    let pool = WorkerPool::new(nthreads);
    let mut works = vec![vec![0.0f64; sym.filled.ncols()]; pool.threads()];
    factor_with(sym, &levels, &pool, &mut works)
}

/// Factor on a caller-provided pool and precomputed U-pattern level
/// schedule. `works` must hold one zeroed length-`n` dense workspace per
/// pool thread (it is returned zeroed, even on the error path).
pub fn factor_with(
    sym: &SymbolicFill,
    levels: &Levels,
    pool: &WorkerPool,
    works: &mut [Vec<f64>],
) -> anyhow::Result<LuFactors> {
    let mut lu = sym.filled.clone();
    refactor_in_place(&mut lu, levels, pool, works)?;
    Ok(LuFactors { lu })
}

/// Factor in place: `lu` holds the filled pattern with `A`'s values
/// stamped in and is overwritten with the factors. This is the
/// allocation-free refactorization hot path.
pub fn refactor_in_place(
    lu: &mut crate::sparse::Csc,
    levels: &Levels,
    pool: &WorkerPool,
    works: &mut [Vec<f64>],
) -> anyhow::Result<()> {
    let n = lu.ncols();
    anyhow::ensure!(
        works.len() >= pool.threads(),
        "need one workspace per pool thread"
    );
    for w in works.iter() {
        // hard check: `factor_col` addresses the workspace unchecked
        anyhow::ensure!(w.len() == n, "each workspace must have length n");
        debug_assert!(w.iter().all(|&v| v == 0.0));
    }
    let (colptr, rowidx, values) = lu.split_mut();
    let shared = SharedPtr(values.as_mut_ptr());
    let works_ptr = WorksPtr(works.as_mut_ptr());
    let failed = AtomicUsize::new(usize::MAX);

    pool.run(&|ctx: &PoolCtx<'_>| {
        // SAFETY: worker `id` touches only `works[id]`; ids are distinct.
        let work: &mut Vec<f64> = unsafe { &mut *works_ptr.0.add(ctx.id) };
        for level in &levels.levels {
            if failed.load(Ordering::Relaxed) == usize::MAX {
                // Interleaved (round-robin) column assignment: adjacent
                // columns tend to have similar cost, so dealing them out
                // one at a time balances better than contiguous chunks.
                let mut idx = ctx.id;
                while idx < level.len() {
                    let j = level[idx] as usize;
                    if !factor_col(j, colptr, rowidx, &shared, work, &failed) {
                        break;
                    }
                    // Abort check between columns: another worker may have
                    // hit a bad pivot — stop computing doomed columns.
                    if failed.load(Ordering::Relaxed) != usize::MAX {
                        break;
                    }
                    idx += ctx.threads;
                }
            }
            // Per-level rendezvous (even when aborting, to stay in step).
            if !ctx.sync() {
                return;
            }
        }
    });

    let f = failed.load(Ordering::Relaxed);
    if f != usize::MAX {
        return Err(super::singular_pivot(f));
    }
    Ok(())
}

/// Raw pointer to the per-worker workspace array (disjoint indexing only).
struct WorksPtr(*mut Vec<f64>);
unsafe impl Send for WorksPtr {}
unsafe impl Sync for WorksPtr {}

/// Factor one column left-looking against the shared values buffer.
/// Returns `false` after recording the column in `failed` on a
/// zero/non-finite pivot (the workspace is scrubbed before returning so
/// the buffers stay reusable).
///
/// The dense workspace is addressed through a raw pointer: every index
/// into it is a row index taken from `rowidx` (bounded by `n` — a [`Csc`]
/// invariant), and `work.len() == n` is checked by the callers. This keeps
/// the kernel's cost the same in debug and release profiles, which the
/// pool-vs-spawn wall-clock comparison in the bench smoke test relies on.
///
/// [`Csc`]: crate::sparse::Csc
#[inline]
fn factor_col(
    j: usize,
    colptr: &[usize],
    rowidx: &[usize],
    shared: &SharedPtr,
    work: &mut [f64],
    failed: &AtomicUsize,
) -> bool {
    // SAFETY: see module docs — this thread owns column j's value range;
    // all cross-column reads target columns from earlier levels. `wp`
    // indices are row indices < n == work.len().
    let vals = shared.0;
    let wp = work.as_mut_ptr();
    let (s, e) = (colptr[j], colptr[j + 1]);
    let rows_j = &rowidx[s..e];
    for (idx, &r) in rows_j.iter().enumerate() {
        unsafe { *wp.add(r) = *vals.add(s + idx) };
    }
    for &k in rows_j.iter().take_while(|&&k| k < j) {
        let xk = unsafe { *wp.add(k) };
        if xk != 0.0 {
            let (ks, ke) = (colptr[k], colptr[k + 1]);
            let rows_k = &rowidx[ks..ke];
            let start = rows_k.partition_point(|&r| r <= k);
            for (off, &i) in rows_k[start..].iter().enumerate() {
                let lik = unsafe { *vals.add(ks + start + off) };
                unsafe { *wp.add(i) -= lik * xk };
            }
        }
    }
    let pivot = unsafe { *wp.add(j) };
    if pivot == 0.0 || !pivot.is_finite() {
        failed.fetch_min(j, Ordering::Relaxed);
        for &r in rows_j {
            unsafe { *wp.add(r) = 0.0 };
        }
        return false;
    }
    for (idx, &r) in rows_j.iter().enumerate() {
        let wr = unsafe { *wp.add(r) };
        let v = if r > j { wr / pivot } else { wr };
        unsafe { *vals.add(s + idx) = v };
        unsafe { *wp.add(r) = 0.0 };
    }
    true
}

/// The seed implementation: spawn `nthreads` OS threads at **every level**
/// via `std::thread::scope`, with contiguous chunked column assignment.
///
/// Kept verbatim (plus the shared abort flag) as the wall-clock baseline
/// the bench harness and the smoke test compare [`factor`] against — the
/// per-level spawn/join cost is exactly what the persistent pool removes.
pub fn factor_spawn_per_level(sym: &SymbolicFill, nthreads: usize) -> anyhow::Result<LuFactors> {
    let levels = leftlook_levels(sym);
    factor_spawn_per_level_with(sym, &levels, nthreads)
}

/// [`factor_spawn_per_level`] on a precomputed schedule (so head-to-head
/// timings against [`factor_with`] isolate the worker orchestration cost).
pub fn factor_spawn_per_level_with(
    sym: &SymbolicFill,
    levels: &Levels,
    nthreads: usize,
) -> anyhow::Result<LuFactors> {
    let n = sym.filled.ncols();
    let nthreads = nthreads.max(1);

    let mut lu = sym.filled.clone();
    let colptr: Vec<usize> = lu.colptr().to_vec();
    let rowidx: Vec<usize> = lu.rowidx().to_vec();
    let shared = SharedPtr(lu.values_mut().as_mut_ptr());
    let shared_ref = &shared;
    let colptr_ref = &colptr;
    let rowidx_ref = &rowidx;

    let failed = AtomicUsize::new(usize::MAX);
    let failed_ref = &failed;

    for level in &levels.levels {
        std::thread::scope(|scope| {
            let chunk = level.len().div_ceil(nthreads);
            for cols in level.chunks(chunk.max(1)) {
                scope.spawn(move || {
                    let mut work = vec![0.0f64; n];
                    for &j in cols {
                        let j = j as usize;
                        if !factor_col(j, colptr_ref, rowidx_ref, shared_ref, &mut work, failed_ref)
                            || failed_ref.load(Ordering::Relaxed) != usize::MAX
                        {
                            return;
                        }
                    }
                });
            }
        });
        let f = failed.load(Ordering::Relaxed);
        if f != usize::MAX {
            return Err(super::singular_pivot(f));
        }
    }
    Ok(LuFactors { lu })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::leftlook;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;

    #[test]
    fn matches_sequential_oracle() {
        for nthreads in [1, 2, 4] {
            let a = gen::netlist(300, 6, 12, 0.05, 3, 0.2, 77);
            let f = symbolic_fill(&a).unwrap();
            let seq = leftlook::factor(&f).unwrap();
            let par = factor(&f, nthreads).unwrap();
            for (p, q) in par.lu.values().iter().zip(seq.lu.values()) {
                assert_eq!(p, q, "parallel left-looking must be bit-identical");
            }
        }
    }

    #[test]
    fn spawn_baseline_matches_pool_implementation() {
        let a = gen::netlist(250, 6, 12, 0.05, 2, 0.2, 31);
        let f = symbolic_fill(&a).unwrap();
        let pooled = factor(&f, 3).unwrap();
        let spawned = factor_spawn_per_level(&f, 3).unwrap();
        for (p, q) in pooled.lu.values().iter().zip(spawned.lu.values()) {
            assert_eq!(p, q, "both schedulers run the same arithmetic");
        }
    }

    #[test]
    fn persistent_pool_reuse_is_deterministic() {
        // Two factorizations over one pool + workspace set: identical
        // values, and the workspaces come back clean in between.
        let a = gen::netlist(200, 6, 10, 0.06, 2, 0.2, 13);
        let f = symbolic_fill(&a).unwrap();
        let levels = leftlook_levels(&f);
        let pool = WorkerPool::new(4);
        let mut works = vec![vec![0.0f64; 200]; pool.threads()];
        let one = factor_with(&f, &levels, &pool, &mut works).unwrap();
        let two = factor_with(&f, &levels, &pool, &mut works).unwrap();
        assert_eq!(one.lu.values(), two.lu.values());
    }

    #[test]
    fn solves_correctly() {
        let a = gen::grid2d(12, 12, 6);
        let f = symbolic_fill(&a).unwrap();
        let lu = factor(&f, 4).unwrap();
        let b = vec![2.0; 144];
        let x = lu.solve(&b);
        assert!(crate::numeric::residual(&a, &x, &b) < 1e-12);
    }

    #[test]
    fn reports_singularity() {
        use crate::sparse::Coo;
        // Make a matrix whose (1,1) pivot cancels exactly during updates:
        // [[1, 1], [1, 1]] -> U(1,1) = 0.
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 1.0);
        let f = symbolic_fill(&coo.to_csc()).unwrap();
        assert!(factor(&f, 2).is_err());
        assert!(factor_spawn_per_level(&f, 2).is_err());
    }

    #[test]
    fn abort_flag_reports_failure_and_scrubs_workspace() {
        // A singular block embedded in a larger matrix: the failure column
        // aborts the factorization, the error names a column, and reusing
        // the same pool + workspaces afterward still yields oracle-exact
        // results (i.e. the failure path left the workspaces clean).
        use crate::sparse::Coo;
        let n = 40;
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, 4.0);
            if i + 1 < n {
                coo.push(i + 1, i, 1.0);
                coo.push(i, i + 1, 1.0);
            }
        }
        // Overwrite a 2x2 corner into exact cancellation: rows/cols 10, 11.
        // U(11,11) becomes 4 - (1*4)... instead, force a zero pivot by
        // zeroing the diagonal entry the updates cannot repair.
        let mut bad = coo.to_csc();
        let idx = bad.entry_index(0, 0).unwrap();
        bad.values_mut()[idx] = 0.0;

        let f = symbolic_fill(&bad).unwrap();
        let levels = leftlook_levels(&f);
        let pool = WorkerPool::new(4);
        let mut works = vec![vec![0.0f64; n]; pool.threads()];
        let err = factor_with(&f, &levels, &pool, &mut works).unwrap_err();
        assert!(err.to_string().contains("pivot"), "{err}");
        for w in &works {
            assert!(w.iter().all(|&v| v == 0.0), "workspace scrubbed on abort");
        }

        // Same pool/workspaces, good matrix: still bit-identical to oracle.
        let good = gen::netlist(n, 5, 8, 0.1, 1, 0.2, 9);
        let fg = symbolic_fill(&good).unwrap();
        let lg = leftlook_levels(&fg);
        let par = factor_with(&fg, &lg, &pool, &mut works).unwrap();
        let seq = leftlook::factor(&fg).unwrap();
        assert_eq!(par.lu.values(), seq.lu.values());
    }
}
