//! Algorithm 1: sequential Gilbert–Peierls left-looking factorization over a
//! static filled pattern. The crate's sparse correctness oracle.

use super::LuFactors;
use crate::symbolic::SymbolicFill;

/// Factor `As` (filled pattern with original values) left-looking.
///
/// For each column `j`: scatter `As(:,j)` into a dense workspace, apply the
/// triangular-solve updates from every factored column `k < j` in the
/// column's pattern (ascending order — the pattern is the reach set, so
/// every such `k` is fully factored), then divide the subdiagonal by the
/// pivot. Gather back into the compact factor storage.
pub fn factor(sym: &SymbolicFill) -> anyhow::Result<LuFactors> {
    let n = sym.filled.ncols();
    let mut lu = sym.filled.clone();
    let mut work = vec![0.0f64; n];

    for j in 0..n {
        // Split: copy out column j's (rows, values) to avoid aliasing while
        // we read earlier columns of `lu`.
        let (rows_j, _) = lu.col(j);
        let rows_j: Vec<usize> = rows_j.to_vec();
        {
            let (_, vals_j) = lu.col(j);
            for (&r, &v) in rows_j.iter().zip(vals_j) {
                work[r] = v;
            }
        }

        // Triangular solve: for every pattern index k < j (ascending).
        for &k in rows_j.iter().take_while(|&&k| k < j) {
            let xk = work[k];
            if xk != 0.0 {
                let (rows_k, vals_k) = lu.col(k);
                // L entries of column k: rows > k.
                let start = rows_k.partition_point(|&r| r <= k);
                for (&i, &lik) in rows_k[start..].iter().zip(&vals_k[start..]) {
                    work[i] -= lik * xk;
                }
            }
        }

        // Pivot and gather.
        let pivot = work[j];
        anyhow::ensure!(
            pivot != 0.0 && pivot.is_finite(),
            "zero/non-finite pivot at column {j}"
        );
        let colptr_j = lu.colptr()[j];
        let vals = lu.values_mut();
        for (idx, &r) in rows_j.iter().enumerate() {
            let v = if r > j { work[r] / pivot } else { work[r] };
            vals[colptr_j + idx] = v;
            work[r] = 0.0; // clear workspace
        }
    }
    Ok(LuFactors { lu })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::residual;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    #[test]
    fn reconstructs_a_on_paper_example() {
        let a = crate::bench_support::paper_example();
        let f = symbolic_fill(&a).unwrap();
        let lu = factor(&f).unwrap();
        let prod = lu.reconstruct_dense();
        let dense = a.to_dense();
        for (p, q) in prod.iter().zip(&dense) {
            assert!((p - q).abs() < 1e-12, "{p} vs {q}");
        }
    }

    #[test]
    fn property_lu_equals_a_random_circuits() {
        let mut rng = Rng::new(0x77);
        for trial in 0..25 {
            let n = rng.range(10, 60);
            let a = gen::netlist(n.max(8), 5, 6, 0.1, 1, 0.2, 500 + trial);
            let f = symbolic_fill(&a).unwrap();
            let lu = factor(&f).unwrap();
            let prod = lu.reconstruct_dense();
            let dense = a.to_dense();
            for (idx, (p, q)) in prod.iter().zip(&dense).enumerate() {
                assert!(
                    (p - q).abs() < 1e-9 * (1.0 + q.abs()),
                    "trial {trial} idx {idx}: {p} vs {q}"
                );
            }
        }
    }

    #[test]
    fn solve_residual_small_on_meshes() {
        for (nx, ny) in [(8, 8), (15, 11)] {
            let a = gen::grid2d(nx, ny, 3);
            let f = symbolic_fill(&a).unwrap();
            let lu = factor(&f).unwrap();
            let n = a.nrows();
            let b: Vec<f64> = (0..n).map(|i| (i % 7) as f64 - 3.0).collect();
            let x = lu.solve(&b);
            assert!(residual(&a, &x, &b) < 1e-12);
        }
    }

    #[test]
    fn matches_dense_solve() {
        let a = gen::netlist(40, 5, 8, 0.1, 1, 0.2, 9);
        let f = symbolic_fill(&a).unwrap();
        let lu = factor(&f).unwrap();
        let b: Vec<f64> = (0..40).map(|i| 1.0 + (i as f64) * 0.1).collect();
        let xs = lu.solve(&b);
        let xd = crate::numeric::dense::solve(&a.to_dense(), 40, &b).unwrap();
        for (p, q) in xs.iter().zip(&xd) {
            assert!((p - q).abs() < 1e-8, "{p} vs {q}");
        }
    }
}
