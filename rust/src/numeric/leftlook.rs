//! Algorithm 1: sequential Gilbert–Peierls left-looking factorization over a
//! static filled pattern. The crate's sparse correctness oracle.

use super::{LuFactors, PivotMonitor};
use crate::symbolic::SymbolicFill;

/// Factor `As` (filled pattern with original values) left-looking.
///
/// For each column `j`: scatter `As(:,j)` into a dense workspace, apply the
/// triangular-solve updates from every factored column `k < j` in the
/// column's pattern (ascending order — the pattern is the reach set, so
/// every such `k` is fully factored), then divide the subdiagonal by the
/// pivot. Gather back into the compact factor storage.
pub fn factor(sym: &SymbolicFill) -> anyhow::Result<LuFactors> {
    let mut lu = sym.filled.clone();
    let mut work = vec![0.0f64; sym.filled.ncols()];
    factor_in_place(&mut lu, &mut work, &mut PivotMonitor::new())?;
    Ok(LuFactors { lu })
}

/// Factor in place: `lu` holds the filled pattern with `A`'s values stamped
/// in and is overwritten with the factors. `work` is a zeroed length-`n`
/// dense workspace, returned zeroed (even on the error path) so callers can
/// keep it hot across refactorizations — the Newton-loop fast path
/// allocates nothing. `mon` records the pivot extrema for the robustness
/// ladder's growth/condition estimates.
pub fn factor_in_place(
    lu: &mut crate::sparse::Csc,
    work: &mut [f64],
    mon: &mut PivotMonitor,
) -> anyhow::Result<()> {
    let n = lu.ncols();
    anyhow::ensure!(work.len() == n, "workspace must have length n");
    let (colptr, rowidx, values) = lu.split_mut();

    for j in 0..n {
        let (s, e) = (colptr[j], colptr[j + 1]);
        let rows_j = &rowidx[s..e];
        for (idx, &r) in rows_j.iter().enumerate() {
            work[r] = values[s + idx];
        }

        // Triangular solve: for every pattern index k < j (ascending).
        for &k in rows_j.iter().take_while(|&&k| k < j) {
            let xk = work[k];
            if xk != 0.0 {
                let (ks, ke) = (colptr[k], colptr[k + 1]);
                let rows_k = &rowidx[ks..ke];
                // L entries of column k: rows > k.
                let start = rows_k.partition_point(|&r| r <= k);
                for (off, &i) in rows_k[start..].iter().enumerate() {
                    work[i] -= values[ks + start + off] * xk;
                }
            }
        }

        // Pivot and gather.
        let pivot = work[j];
        if pivot == 0.0 || !pivot.is_finite() {
            for &r in rows_j {
                work[r] = 0.0;
            }
            return Err(super::singular_pivot(j));
        }
        mon.observe(pivot);
        for (idx, &r) in rows_j.iter().enumerate() {
            let v = if r > j { work[r] / pivot } else { work[r] };
            values[s + idx] = v;
            work[r] = 0.0; // clear workspace
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::residual;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    #[test]
    fn reconstructs_a_on_paper_example() {
        let a = crate::bench_support::paper_example();
        let f = symbolic_fill(&a).unwrap();
        let lu = factor(&f).unwrap();
        let prod = lu.reconstruct_dense();
        let dense = a.to_dense();
        for (p, q) in prod.iter().zip(&dense) {
            assert!((p - q).abs() < 1e-12, "{p} vs {q}");
        }
    }

    #[test]
    fn property_lu_equals_a_random_circuits() {
        let mut rng = Rng::new(0x77);
        for trial in 0..25 {
            let n = rng.range(10, 60);
            let a = gen::netlist(n.max(8), 5, 6, 0.1, 1, 0.2, 500 + trial);
            let f = symbolic_fill(&a).unwrap();
            let lu = factor(&f).unwrap();
            let prod = lu.reconstruct_dense();
            let dense = a.to_dense();
            for (idx, (p, q)) in prod.iter().zip(&dense).enumerate() {
                assert!(
                    (p - q).abs() < 1e-9 * (1.0 + q.abs()),
                    "trial {trial} idx {idx}: {p} vs {q}"
                );
            }
        }
    }

    #[test]
    fn solve_residual_small_on_meshes() {
        for (nx, ny) in [(8, 8), (15, 11)] {
            let a = gen::grid2d(nx, ny, 3);
            let f = symbolic_fill(&a).unwrap();
            let lu = factor(&f).unwrap();
            let n = a.nrows();
            let b: Vec<f64> = (0..n).map(|i| (i % 7) as f64 - 3.0).collect();
            let x = lu.solve(&b);
            assert!(residual(&a, &x, &b) < 1e-12);
        }
    }

    #[test]
    fn matches_dense_solve() {
        let a = gen::netlist(40, 5, 8, 0.1, 1, 0.2, 9);
        let f = symbolic_fill(&a).unwrap();
        let lu = factor(&f).unwrap();
        let b: Vec<f64> = (0..40).map(|i| 1.0 + (i as f64) * 0.1).collect();
        let xs = lu.solve(&b);
        let xd = crate::numeric::dense::solve(&a.to_dense(), 40, &b).unwrap();
        for (p, q) in xs.iter().zip(&xd) {
            assert!((p - q).abs() < 1e-8, "{p} vs {q}");
        }
    }
}
