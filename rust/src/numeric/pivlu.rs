//! Gilbert–Peierls left-looking sparse LU **with threshold partial
//! pivoting** — the rung-5 rescue factorization of the numeric robustness
//! ladder.
//!
//! Every other engine in this crate factors a *statically filled* pattern
//! without pivoting (the GLU regime): preprocessing is trusted to keep the
//! fixed pivot order viable, and the ladder in [`crate::glu::GluSolver`]
//! can only bend values on that pattern. This module is the CKTSO/NICSLU
//! style last resort for the matrices the fixed order genuinely cannot
//! factor: a classic Gilbert–Peierls left-looking elimination that
//!
//! - discovers fill **on the fly** into growable per-column buffers (no
//!   precomputed symbolic phase — the reach DFS runs against the partial
//!   row permutation as it is being chosen),
//! - picks each pivot by **threshold partial pivoting**: the static
//!   (diagonal) candidate is kept whenever it is within `tol` of the
//!   column's largest eligible magnitude; otherwise the admissible
//!   candidate with the smallest input row degree wins (a Markowitz-style
//!   sparsity tie-break, smallest row index on equal degree),
//! - emits the new row [`Permutation`] and the factors' merged fill
//!   pattern **in pivoted row indices**.
//!
//! The returned pattern is exactly the fill pattern the *no-pivot*
//! elimination of the row-permuted matrix produces (the Gilbert–Peierls
//! reach argument, the same property KLU's `refactor` relies on), so the
//! caller can rebuild the normal static pipeline — `SymbolicFill` →
//! detection → levelization → `FactorPlan` — on the rescued ordering and
//! every existing engine keeps refactoring it without pivoting.

use super::{singular_pivot, PivotMonitor};
use crate::sparse::{Csc, Permutation};

/// Default pivot threshold: a candidate within `1e-3 ×` the column max is
/// admissible, and the static diagonal is preferred whenever admissible —
/// loose enough to keep most of the preprocessing's pivot order (small
/// permutation drift, bounded fill), tight enough to cap element growth at
/// `(1 + 1/tol)` per step.
pub const DEFAULT_PIVOT_TOL: f64 = 1e-3;

/// Result of a successful rescue factorization.
#[derive(Debug, Clone)]
pub struct RescuedLu {
    /// Row permutation in scatter form over the *input's* row space:
    /// `row_perm.as_scatter()[input_row] = pivoted_row`.
    pub row_perm: Permutation,
    /// Columns whose chosen pivot differs from the static diagonal row —
    /// the permutation-drift count the robustness stats record.
    pub swapped_pivots: usize,
    /// The factors in compact L\U layout over the **pivoted** row indices:
    /// `U` on/above the diagonal, unit-lower `L` strictly below (same
    /// convention as [`crate::numeric::LuFactors`]). The sparsity pattern
    /// of this matrix is the merged fill pattern of the rescued ordering.
    pub lu: Csc,
    /// Entries of `lu` that are fill (not structural in the input).
    pub fill_count: usize,
}

/// Factor `a` (square, any viable row order) with threshold partial
/// pivoting. `tol` is the admissibility threshold in `(0, 1]`; `mon`
/// observes every chosen pivot so the caller's growth/condition gates work
/// unchanged. Fails with a typed
/// [`crate::numeric::GluError::NumericallySingular`] when some column has
/// no admissible pivot — i.e. the matrix is singular (or so close that
/// every candidate underflowed), which no row order can repair.
pub fn factor(a: &Csc, tol: f64, mon: &mut PivotMonitor) -> anyhow::Result<RescuedLu> {
    let n = a.ncols();
    anyhow::ensure!(a.nrows() == n, "pivot rescue requires a square matrix");
    anyhow::ensure!(tol > 0.0 && tol <= 1.0, "pivot threshold must be in (0, 1]");

    // Markowitz-style tie-break data: input row degrees (cheaper than live
    // degrees, and stable — the tie-break only has to *bias* toward
    // sparsity, not optimize it).
    let mut row_degree = vec![0usize; n];
    for &r in a.rowidx() {
        row_degree[r] += 1;
    }

    // pinv[input_row] = pivot position (usize::MAX while non-pivotal);
    // pos[k] = input row chosen as pivot of column k.
    let mut pinv = vec![usize::MAX; n];
    let mut pos = vec![usize::MAX; n];

    // Growable factor columns. L is kept in *input* row indices while the
    // permutation is still partial (its rows are non-pivotal when stored
    // and get their final index later); U rows are pivot positions, final
    // at emission time.
    let mut l_rows: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut l_vals: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut u_rows: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut u_vals: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut diag = vec![0.0f64; n];

    // Dense accumulator + DFS scratch, indexed by input row.
    let mut x = vec![0.0f64; n];
    let mut mark = vec![usize::MAX; n];
    let mut stack: Vec<(usize, usize)> = Vec::new();
    let mut topo: Vec<usize> = Vec::with_capacity(n);

    for j in 0..n {
        // Symbolic step: reach of A(:,j) through the already-pivotal L
        // columns, in DFS post-order (reversed below = topological).
        topo.clear();
        let (arows, avals) = a.col(j);
        for &r0 in arows {
            if mark[r0] == j {
                continue;
            }
            mark[r0] = j;
            stack.push((r0, 0));
            while let Some(&(node, child)) = stack.last() {
                let k = pinv[node];
                let nchild = if k == usize::MAX { 0 } else { l_rows[k].len() };
                if child < nchild {
                    stack.last_mut().unwrap().1 += 1;
                    let next = l_rows[k][child];
                    if mark[next] != j {
                        mark[next] = j;
                        stack.push((next, 0));
                    }
                } else {
                    topo.push(node);
                    stack.pop();
                }
            }
        }

        // Numeric step: scatter A(:,j), then apply the pivotal updates in
        // topological order (left-looking MAC against finished L columns).
        for (&r, &v) in arows.iter().zip(avals) {
            x[r] = v;
        }
        for &r in topo.iter().rev() {
            let k = pinv[r];
            if k == usize::MAX {
                continue;
            }
            let xk = x[r];
            if xk != 0.0 {
                for (&lr, &lv) in l_rows[k].iter().zip(&l_vals[k]) {
                    x[lr] -= xk * lv;
                }
            }
        }

        // Pivot search over the non-pivotal reach rows: threshold partial
        // pivoting with the static diagonal preferred, Markowitz-biased
        // otherwise.
        let mut maxabs = 0.0f64;
        for &r in &topo {
            if pinv[r] == usize::MAX {
                let v = x[r].abs();
                if !v.is_finite() {
                    clear(&mut x, &topo);
                    return Err(singular_pivot(j).context(format!(
                        "pivot rescue: non-finite candidate in column {j}"
                    )));
                }
                if v > maxabs {
                    maxabs = v;
                }
            }
        }
        if maxabs == 0.0 {
            clear(&mut x, &topo);
            return Err(singular_pivot(j).context(format!(
                "pivot rescue: no admissible pivot in column {j} — \
                 the matrix is singular under every row order"
            )));
        }
        let admissible = tol * maxabs;
        let mut pivot_row = usize::MAX;
        // The static candidate: input row `j` sits on the diagonal of the
        // caller's (already permuted) matrix.
        if pinv[j] == usize::MAX && mark[j] == j && x[j].abs() >= admissible {
            pivot_row = j;
        } else {
            let mut best_deg = usize::MAX;
            for &r in &topo {
                if pinv[r] == usize::MAX && x[r].abs() >= admissible {
                    let deg = row_degree[r];
                    if deg < best_deg || (deg == best_deg && r < pivot_row) {
                        best_deg = deg;
                        pivot_row = r;
                    }
                }
            }
        }
        let pivot = x[pivot_row];
        mon.observe(pivot);
        pinv[pivot_row] = j;
        pos[j] = pivot_row;

        // Emit the column: pivotal reach rows are U entries (final row
        // index = their pivot position), the rest join L scaled by the
        // pivot. Reach rows are kept even when numerically zero — the
        // pattern must stay the closed no-pivot fill of the rescued order.
        diag[j] = pivot;
        for &r in &topo {
            let k = pinv[r];
            if r == pivot_row {
                continue;
            }
            if k == usize::MAX {
                l_rows[j].push(r);
                l_vals[j].push(x[r] / pivot);
            } else {
                u_rows[j].push(k);
                u_vals[j].push(x[r]);
            }
        }
        clear(&mut x, &topo);
    }

    // Every row is pivotal now; `pinv` is a complete scatter permutation.
    let swapped_pivots = pos.iter().enumerate().filter(|&(k, &r)| r != k).count();
    let row_perm = Permutation::from_scatter(pinv.clone())
        .expect("pivot assignment yields a complete permutation");

    // Assemble the compact L\U matrix in pivoted row indices, per-column
    // sorted as the Csc invariants require.
    let mut colptr = Vec::with_capacity(n + 1);
    colptr.push(0usize);
    let mut rowidx = Vec::new();
    let mut values = Vec::new();
    let mut entries: Vec<(usize, f64)> = Vec::new();
    for j in 0..n {
        entries.clear();
        entries.extend(u_rows[j].iter().copied().zip(u_vals[j].iter().copied()));
        entries.push((j, diag[j]));
        entries.extend(
            l_rows[j]
                .iter()
                .map(|&r| pinv[r])
                .zip(l_vals[j].iter().copied()),
        );
        entries.sort_unstable_by_key(|&(r, _)| r);
        for &(r, v) in entries.iter() {
            rowidx.push(r);
            values.push(v);
        }
        colptr.push(rowidx.len());
    }
    let lu = Csc::from_raw_parts(n, n, colptr, rowidx, values)?;
    let fill_count = lu.nnz() - a.nnz();
    Ok(RescuedLu {
        row_perm,
        swapped_pivots,
        lu,
        fill_count,
    })
}

/// Zero the accumulator at exactly the touched positions.
fn clear(x: &mut [f64], touched: &[usize]) {
    for &r in touched {
        x[r] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{dense, residual, GluError, LuFactors};
    use crate::util::Rng;

    /// Random sparse nonsingular matrix with some zero diagonals — needs
    /// pivoting, solvable with it.
    fn needs_pivoting(n: usize, seed: u64) -> Csc {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0f64; n * n];
        for i in 0..n {
            // cyclic shift: row i holds its dominant entry at column (i+1)%n
            dense[i * n + (i + 1) % n] = 4.0 + rng.f64();
            for _ in 0..3 {
                let c = rng.below(n);
                dense[i * n + c] += rng.range_f64(-1.0, 1.0);
            }
        }
        Csc::from_dense(n, n, &dense)
    }

    /// Apply the rescued permutation and compare `L·U` against `P·A`
    /// densely.
    fn check_reconstruction(a: &Csc, r: &RescuedLu, tol: f64) {
        let n = a.ncols();
        let pa = a.permute(r.row_perm.as_scatter(), Permutation::identity(n).as_scatter());
        let want = pa.to_dense();
        let got = LuFactors { lu: r.lu.clone() }.reconstruct_dense();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= tol,
                "L·U disagrees with P·A at flat index {i}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn factors_permutation_heavy_matrices_the_static_order_cannot() {
        for seed in [1u64, 7, 42] {
            let a = needs_pivoting(24, seed);
            let mut mon = PivotMonitor::new();
            let r = factor(&a, DEFAULT_PIVOT_TOL, &mut mon).unwrap();
            assert!(r.swapped_pivots > 0, "cyclic matrix must force swaps");
            assert!(mon.min_abs_pivot > 0.0);
            check_reconstruction(&a, &r, 1e-10);
        }
    }

    #[test]
    fn solve_through_rescued_factors_matches_dense_oracle() {
        let n = 20;
        let a = needs_pivoting(n, 3);
        let mut mon = PivotMonitor::new();
        let r = factor(&a, DEFAULT_PIVOT_TOL, &mut mon).unwrap();
        let b = vec![1.0; n];
        // Solve P·A·x = P·b through the sparse factors…
        let pb = r.row_perm.apply(&b);
        let x = LuFactors { lu: r.lu.clone() }.solve(&pb);
        // …and check against the dense partial-pivoting oracle on A.
        let want = dense::solve(&a.to_dense(), n, &b).unwrap();
        for (g, w) in x.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
        assert!(residual(&a, &x, &b) <= 1e-12);
    }

    #[test]
    fn static_order_is_kept_when_admissible() {
        // Diagonally dominant: every static pivot is the column max, so
        // threshold pivoting must not drift the order at all.
        let a = crate::sparse::gen::grid2d(5, 5, 0);
        let mut mon = PivotMonitor::new();
        let r = factor(&a, DEFAULT_PIVOT_TOL, &mut mon).unwrap();
        assert_eq!(r.swapped_pivots, 0, "dominant diagonal must not drift");
        assert_eq!(
            r.row_perm.as_scatter(),
            Permutation::identity(a.ncols()).as_scatter()
        );
        check_reconstruction(&a, &r, 1e-12);
    }

    #[test]
    fn pattern_is_closed_under_nopivot_refactorization() {
        // The rescued pattern must be exactly reusable by the static
        // pipeline: symbolic fill of P·A may not exceed it.
        let a = needs_pivoting(30, 11);
        let mut mon = PivotMonitor::new();
        let r = factor(&a, DEFAULT_PIVOT_TOL, &mut mon).unwrap();
        let n = a.ncols();
        let pa = a.permute(r.row_perm.as_scatter(), Permutation::identity(n).as_scatter());
        let f = crate::symbolic::symbolic_fill(&pa).unwrap();
        for c in 0..n {
            let (rows, _) = f.filled.col(c);
            for &row in rows {
                assert!(
                    r.lu.has_entry(row, c),
                    "fill entry ({row},{c}) of the rescued order missing \
                     from the discovered pattern"
                );
            }
        }
    }

    #[test]
    fn truly_singular_is_typed_and_names_the_column() {
        // Rank-deficient: column 2 = column 0, so elimination runs dry.
        let mut d = vec![0.0f64; 9];
        d[0] = 1.0; // (0,0)
        d[1] = 2.0; // (0,1)
        d[2] = 1.0; // (0,2) == column 0
        d[3] = 3.0; // (1,0)
        d[4] = 1.0; // (1,1)
        d[5] = 3.0; // (1,2)
        d[6] = 2.0; // (2,0)
        d[7] = 4.0; // (2,1)
        d[8] = 2.0; // (2,2)
        let a = Csc::from_dense(3, 3, &d);
        let mut mon = PivotMonitor::new();
        let e = factor(&a, DEFAULT_PIVOT_TOL, &mut mon).unwrap_err();
        assert_eq!(
            e.downcast_ref::<GluError>(),
            Some(&GluError::NumericallySingular { col: 2 })
        );
        assert!(format!("{e:#}").contains("no admissible pivot"), "{e:#}");
    }

    #[test]
    fn all_zero_values_fail_on_the_first_column() {
        let mut a = crate::sparse::gen::grid2d(4, 4, 9);
        for v in a.values_mut() {
            *v = 0.0;
        }
        let mut mon = PivotMonitor::new();
        let e = factor(&a, DEFAULT_PIVOT_TOL, &mut mon).unwrap_err();
        assert_eq!(
            e.downcast_ref::<GluError>(),
            Some(&GluError::NumericallySingular { col: 0 })
        );
    }

    #[test]
    fn matches_dense_oracle_pivot_for_pivot_at_tol_one() {
        // With tol = 1.0 the threshold rule *is* partial pivoting (largest
        // magnitude wins; degree only breaks exact-magnitude ties, which a
        // random matrix does not produce). Pin the permutation and factor
        // values against `dense::lu_inplace`.
        let n = 12;
        let a = needs_pivoting(n, 5);
        let mut mon = PivotMonitor::new();
        let r = factor(&a, 1.0, &mut mon).unwrap();
        let mut lu = a.to_dense();
        let piv = dense::lu_inplace(&mut lu, n).unwrap();
        // dense piv is gather form (piv[k] = input row at step k).
        let want = Permutation::from_order(&piv).unwrap();
        assert_eq!(r.row_perm.as_scatter(), want.as_scatter());
        for i in 0..n {
            for j in 0..n {
                let g = r.lu.get(r.row_perm.as_scatter()[i], j);
                // dense lu holds the factors in pivoted rows already
                let k = want.as_scatter()[i];
                let w = lu[k * n + j];
                if g != 0.0 || w != 0.0 {
                    assert!((g - w).abs() < 1e-12, "({i},{j}): {g} vs {w}");
                }
            }
        }
    }
}
