//! Algorithm 2: the hybrid column right-looking factorization of GLU —
//! sequential reference implementation.
//!
//! Identical arithmetic to the GPU kernel pipelines (same MAC ordering per
//! subcolumn), so the simulator's numerics are checked against this engine
//! bit-for-bit, and this engine against the left-looking oracle to fp
//! tolerance.

use super::LuFactors;
use crate::symbolic::SymbolicFill;

/// Row-wise view of the strictly-upper pattern: for each row `j`, the
/// columns `k > j` with `As(j,k) ≠ 0` — column `j`'s *subcolumns* in the
/// paper's terminology (Fig. 3).
pub fn upper_rows(sym: &SymbolicFill) -> Vec<Vec<u32>> {
    let n = sym.filled.ncols();
    let mut urow: Vec<Vec<u32>> = vec![Vec::new(); n];
    for k in 0..n {
        let (rows, _) = sym.filled.col(k);
        for &j in rows.iter().take_while(|&&j| j < k) {
            urow[j].push(k as u32);
        }
    }
    urow
}

/// Factor `As` with the hybrid right-looking algorithm (Algorithm 2).
pub fn factor(sym: &SymbolicFill) -> anyhow::Result<LuFactors> {
    let n = sym.filled.ncols();
    let mut lu = sym.filled.clone();
    let urow = upper_rows(sym);

    for j in 0..n {
        // --- Step 1: compute L part of column j (divide by pivot). ---
        let (rows_j, vals_j) = lu.col(j);
        let diag_pos = rows_j
            .binary_search(&j)
            .map_err(|_| anyhow::anyhow!("missing diagonal at {j}"))?;
        let pivot = vals_j[diag_pos];
        anyhow::ensure!(
            pivot != 0.0 && pivot.is_finite(),
            "zero/non-finite pivot at column {j}"
        );
        let colptr_j = lu.colptr()[j];
        let col_len = rows_j.len();
        // Copy L rows/values for the update step (avoid aliasing).
        let lrows: Vec<usize> = rows_j[diag_pos + 1..].to_vec();
        {
            let vals = lu.values_mut();
            for idx in diag_pos + 1..col_len {
                vals[colptr_j + idx] /= pivot;
            }
        }
        let lvals: Vec<f64> = {
            let (_, vals_j) = lu.col(j);
            vals_j[diag_pos + 1..].to_vec()
        };

        // --- Step 2: submatrix update — for each subcolumn k (As(j,k)≠0,
        // k > j), apply the rank-1 column update (Eq. 3). ---
        for &k in &urow[j] {
            let k = k as usize;
            let multiplier = lu.get(j, k); // As(j, k)
            if multiplier == 0.0 {
                continue;
            }
            let colptr_k = lu.colptr()[k];
            let (rows_k, _) = lu.col(k);
            // Walk the L rows of column j and the pattern of column k in
            // lock-step (both sorted): every L row of column j is
            // guaranteed present in column k's pattern by the symbolic
            // analysis (fill-in closure).
            let mut pos = rows_k.partition_point(|&r| r <= j);
            let rows_k: Vec<usize> = rows_k[pos..].to_vec();
            let base = pos;
            pos = 0;
            let vals = lu.values_mut();
            for (&i, &lij) in lrows.iter().zip(&lvals) {
                while rows_k[pos] != i {
                    pos += 1;
                }
                vals[colptr_k + base + pos] -= lij * multiplier;
            }
        }
    }
    Ok(LuFactors { lu })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::leftlook;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    #[test]
    fn subcolumns_match_paper_fig3() {
        // Fig. 3: j = 3 (1-based) has subcolumns 5 and 8 because As(3,5)
        // and As(3,8) are nonzero. Our fixture encodes the same idea via
        // its upper row patterns; check on the fixture: row 3 (0-based)
        // has subcolumn 6 (As(3,6) != 0).
        let a = crate::bench_support::paper_example();
        let f = symbolic_fill(&a).unwrap();
        let urow = upper_rows(&f);
        assert!(urow[3].contains(&6));
    }

    #[test]
    fn matches_leftlooking_oracle_exactly_enough() {
        let mut rng = Rng::new(0x1717);
        for trial in 0..20 {
            let n = rng.range(10, 80);
            let a = gen::netlist(n.max(8), 6, 8, 0.1, 2, 0.25, 900 + trial);
            let f = symbolic_fill(&a).unwrap();
            let l = leftlook::factor(&f).unwrap();
            let r = factor(&f).unwrap();
            for (p, q) in l.lu.values().iter().zip(r.lu.values()) {
                assert!(
                    (p - q).abs() < 1e-10 * (1.0 + q.abs()),
                    "trial {trial}: {p} vs {q}"
                );
            }
        }
    }

    #[test]
    fn factor_solves_correctly() {
        let a = gen::grid2d(9, 9, 11);
        let f = symbolic_fill(&a).unwrap();
        let lu = factor(&f).unwrap();
        let b = vec![1.0; 81];
        let x = lu.solve(&b);
        assert!(crate::numeric::residual(&a, &x, &b) < 1e-12);
    }

    #[test]
    fn paper_example_update_order() {
        // Fig. 2 vs right-looking timing: the (a) update happens while
        // j = 4 and (b) while j = 6 (1-based). After factoring, both
        // engines agree on column 7's final values.
        let a = crate::bench_support::paper_example();
        let f = symbolic_fill(&a).unwrap();
        let l = leftlook::factor(&f).unwrap();
        let r = factor(&f).unwrap();
        let (_, lv) = l.lu.col(6);
        let (_, rv) = r.lu.col(6);
        for (p, q) in lv.iter().zip(rv) {
            assert!((p - q).abs() < 1e-14);
        }
    }
}
