//! Algorithm 2: the hybrid column right-looking factorization of GLU —
//! sequential reference implementation.
//!
//! Identical arithmetic to the GPU kernel pipelines (same MAC ordering per
//! subcolumn), so the simulator's numerics are checked against this engine
//! bit-for-bit, and this engine against the left-looking oracle to fp
//! tolerance.

use super::{LuFactors, PivotMonitor};
use crate::symbolic::SymbolicFill;

/// Row-wise view of the strictly-upper pattern: for each row `j`, the
/// columns `k > j` with `As(j,k) ≠ 0` — column `j`'s *subcolumns* in the
/// paper's terminology (Fig. 3).
pub fn upper_rows(sym: &SymbolicFill) -> Vec<Vec<u32>> {
    let n = sym.filled.ncols();
    let mut urow: Vec<Vec<u32>> = vec![Vec::new(); n];
    for k in 0..n {
        let (rows, _) = sym.filled.col(k);
        for &j in rows.iter().take_while(|&&j| j < k) {
            urow[j].push(k as u32);
        }
    }
    urow
}

/// Factor `As` with the hybrid right-looking algorithm (Algorithm 2).
pub fn factor(sym: &SymbolicFill) -> anyhow::Result<LuFactors> {
    let mut lu = sym.filled.clone();
    let urow = upper_rows(sym);
    let mut lvals = Vec::new();
    factor_in_place(&mut lu, &urow, &mut lvals, &mut PivotMonitor::new())?;
    Ok(LuFactors { lu })
}

/// Factor in place, column by column in ascending order: `lu` holds the
/// filled pattern with `A`'s values stamped in and is overwritten with the
/// factors. `urow` is the [`upper_rows`] view of the same pattern; `lvals`
/// is a reusable divide-phase scratch; `mon` records the pivot extrema for
/// the robustness ladder. Allocation-free — the refactorization fast path.
pub fn factor_in_place(
    lu: &mut crate::sparse::Csc,
    urow: &[Vec<u32>],
    lvals: &mut Vec<f64>,
    mon: &mut PivotMonitor,
) -> anyhow::Result<()> {
    anyhow::ensure!(urow.len() == lu.ncols(), "subcolumn view dimension mismatch");
    for j in 0..lu.ncols() {
        factor_column(lu, &urow[j], j, lvals, mon)?;
    }
    Ok(())
}

/// Factor one column: divide phase + submatrix (subcolumn) updates — the
/// single-column pipeline of Algorithm 2, shared verbatim with the
/// simulated-GPU executor (so the two engines are bit-identical by
/// construction).
///
/// Allocation-free on the hot path: the pattern is walked through the
/// split borrow of [`crate::sparse::Csc::split_mut`]; only the column's L
/// values are staged into the caller-provided scratch buffer (they are
/// read while other columns' values are written).
pub(crate) fn factor_column(
    lu: &mut crate::sparse::Csc,
    subcols: &[u32],
    j: usize,
    lvals: &mut Vec<f64>,
    mon: &mut PivotMonitor,
) -> anyhow::Result<()> {
    let (colptr, rowidx, values) = lu.split_mut();
    let (s_j, e_j) = (colptr[j], colptr[j + 1]);
    let rows_j = &rowidx[s_j..e_j];
    let diag_pos = rows_j
        .binary_search(&j)
        .map_err(|_| anyhow::anyhow!("missing diagonal at {j}"))?;
    let pivot = values[s_j + diag_pos];
    if pivot == 0.0 || !pivot.is_finite() {
        return Err(super::singular_pivot(j));
    }
    mon.observe(pivot);
    // Divide phase, staging L values into the scratch buffer.
    let lrows = &rows_j[diag_pos + 1..];
    lvals.clear();
    for idx in diag_pos + 1..rows_j.len() {
        let v = values[s_j + idx] / pivot;
        values[s_j + idx] = v;
        lvals.push(v);
    }

    // Submatrix update — for each subcolumn k (As(j,k)≠0, k > j), apply
    // the rank-1 column update (Eq. 3).
    for &k in subcols {
        let k = k as usize;
        let (s_k, e_k) = (colptr[k], colptr[k + 1]);
        let rows_k = &rowidx[s_k..e_k];
        let multiplier = match rows_k.binary_search(&j) {
            Ok(p) => values[s_k + p],
            Err(_) => continue,
        };
        if multiplier == 0.0 {
            continue;
        }
        let start = rows_k.partition_point(|&r| r <= j);
        // Walk L rows of column j and column k's pattern in lock-step:
        // symbolic fill guarantees every L row is present in column k.
        let mut pos = start;
        for (&i, &lij) in lrows.iter().zip(lvals.iter()) {
            while rows_k[pos] != i {
                pos += 1;
            }
            values[s_k + pos] -= lij * multiplier;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::leftlook;
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    #[test]
    fn subcolumns_match_paper_fig3() {
        // Fig. 3: j = 3 (1-based) has subcolumns 5 and 8 because As(3,5)
        // and As(3,8) are nonzero. Our fixture encodes the same idea via
        // its upper row patterns; check on the fixture: row 3 (0-based)
        // has subcolumn 6 (As(3,6) != 0).
        let a = crate::bench_support::paper_example();
        let f = symbolic_fill(&a).unwrap();
        let urow = upper_rows(&f);
        assert!(urow[3].contains(&6));
    }

    #[test]
    fn matches_leftlooking_oracle_exactly_enough() {
        let mut rng = Rng::new(0x1717);
        for trial in 0..20 {
            let n = rng.range(10, 80);
            let a = gen::netlist(n.max(8), 6, 8, 0.1, 2, 0.25, 900 + trial);
            let f = symbolic_fill(&a).unwrap();
            let l = leftlook::factor(&f).unwrap();
            let r = factor(&f).unwrap();
            for (p, q) in l.lu.values().iter().zip(r.lu.values()) {
                assert!(
                    (p - q).abs() < 1e-10 * (1.0 + q.abs()),
                    "trial {trial}: {p} vs {q}"
                );
            }
        }
    }

    #[test]
    fn factor_solves_correctly() {
        let a = gen::grid2d(9, 9, 11);
        let f = symbolic_fill(&a).unwrap();
        let lu = factor(&f).unwrap();
        let b = vec![1.0; 81];
        let x = lu.solve(&b);
        assert!(crate::numeric::residual(&a, &x, &b) < 1e-12);
    }

    #[test]
    fn paper_example_update_order() {
        // Fig. 2 vs right-looking timing: the (a) update happens while
        // j = 4 and (b) while j = 6 (1-based). After factoring, both
        // engines agree on column 7's final values.
        let a = crate::bench_support::paper_example();
        let f = symbolic_fill(&a).unwrap();
        let l = leftlook::factor(&f).unwrap();
        let r = factor(&f).unwrap();
        let (_, lv) = l.lu.col(6);
        let (_, rv) = r.lu.col(6);
        for (p, q) in lv.iter().zip(rv) {
            assert!((p - q).abs() < 1e-14);
        }
    }
}
