//! Parallel hybrid right-looking factorization on a hazard-free level
//! schedule — the GLU3.0 execution model with **real CPU threads** instead
//! of simulated GPU warps, executing the mode-annotated
//! [`crate::plan::FactorPlan`].
//!
//! ## The indexed hot loop
//!
//! Refactorization runs the same pattern thousands of times, so every
//! position the MAC loop needs is resolved **once per pattern** into the
//! plan's [`ScatterMap`]: the multiplier's value index and a flat run of
//! destination value indices per `(source, destination)` task. The numeric
//! inner loop is therefore pure `vals[dst[i]] -= l[i] * mult` — no
//! `binary_search`, no `partition_point`, no row-match scan, ever. The
//! pre-map implementation is retained as [`refactor_in_place_search`] /
//! [`factor_with_search`] — the head-to-head baseline the
//! `BENCH_numeric.json` `refactor_loop` block measures against.
//!
//! This engine holds no assignment policy of its own: every level's
//! worker-pool strategy comes from the plan's [`CpuAssignment`], decided
//! once at plan-build time alongside the GPU geometry:
//!
//! - [`CpuAssignment::InterleavedColumns`] (small-mode levels — wide, many
//!   independent columns): columns are dealt round-robin across the pool,
//!   each worker runs the full Algorithm 2 column pipeline; MAC commits
//!   into later-level columns are CAS (two sources may share targets).
//! - [`CpuAssignment::OwnedDestinations`] (narrow sliced levels, the
//!   default): two sub-phases per level. All divide phases run
//!   column-interleaved, a barrier publishes the normalized L values, then
//!   the level's MAC tasks — grouped by **destination column** at plan
//!   time ([`crate::plan::DestGroups`]) — are dealt to workers one whole
//!   group at a time. One owner per destination column means **plain
//!   (non-atomic) stores**, and since each group keeps ascending source
//!   order, the result is bit-identical to the simulator's serialization
//!   at *every* thread count.
//! - [`CpuAssignment::SubcolumnSlices`] (sliced levels where one
//!   destination group dominates): the flat `(column, subcolumn)` task
//!   list is dealt round-robin source-major instead, spreading the
//!   dominant destination's work across the pool at the price of CAS
//!   commits.
//! - [`CpuAssignment::ChainBatch`] (stream-mode singleton tails): a run of
//!   consecutive size-1 levels executes as one sequential chain on worker
//!   0 with a *single* end-of-run rendezvous — plain stores, since nothing
//!   else runs during the chain.
//!
//! ## Safety model (why the schedule makes this sound)
//!
//! A hazard-free schedule (GLU2.0 exact or GLU3.0 relaxed detection —
//! validated by [`crate::depend::levelize::validate_hazard_free`])
//! guarantees, for columns in the *same* level:
//!
//! - **No update lands in the current level.** Any column `i` with update
//!   work (`L(:,i)` non-empty) is ordered strictly before every column `k`
//!   with `As(i,k) != 0`, so all MAC targets live in later levels. The
//!   divide phase therefore writes its own column without interference,
//!   with plain accesses — and MAC tasks may *read* any same-level
//!   column's L values plainly after the intra-level barrier, since no one
//!   writes them. The same argument shows a same-level multiplier element
//!   `As(j,k)` is never itself a same-level MAC target.
//! - **No read/write hazard on multipliers or L values** (the double-U
//!   condition). What remains possible is two same-level columns
//!   *accumulating* into the same element of a later column. The
//!   interleaved and source-major strategies resolve that the GPU way —
//!   CAS commits, relaxed-atomic multiplier loads — while the ownership
//!   strategy removes the collision entirely: all tasks targeting one
//!   destination column run on one worker, so its reads and writes are
//!   plain, published by the end-of-level barrier.
//!
//! Accumulation order into a shared element is nondeterministic only in
//! the CAS strategies — results match the simulated-GPU engine (which
//! commits same-level columns in ascending order) to rounding, and are
//! *identical* to it when the pool has one thread, in **every** assignment
//! mode; ownership and chain levels are bit-identical at any thread count.
//!
//! GLU1.0's U-pattern schedule does **not** provide these guarantees
//! (paper Fig. 9's counterexample); [`crate::glu::GluSolver`] refuses to
//! combine it with this engine.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::numeric::pool::{PoolCtx, SharedPtr, WorkerPool};
use crate::plan::{CpuAssignment, FactorPlan, ScatterMap};
use crate::symbolic::SymbolicFill;

use super::{LuFactors, PivotMonitor, ValuePlanes};

/// Shared pivot-extrema accumulator for the worker pool: `|pivot|` is
/// non-negative, and for non-negative IEEE-754 doubles the bit pattern
/// orders exactly like the value — so a lock-free `fetch_max`/`fetch_min`
/// on the bits is a correct floating-point max/min. Two relaxed RMWs per
/// *column* (never on the MAC hot loop).
struct AtomicMonitor {
    max_bits: AtomicU64,
    min_bits: AtomicU64,
}

impl AtomicMonitor {
    fn new() -> Self {
        AtomicMonitor {
            max_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
        }
    }

    #[inline]
    fn observe(&self, pivot: f64) {
        let b = pivot.abs().to_bits();
        self.max_bits.fetch_max(b, Ordering::Relaxed);
        self.min_bits.fetch_min(b, Ordering::Relaxed);
    }

    fn merge_into(&self, mon: &mut PivotMonitor) {
        mon.merge(&PivotMonitor {
            max_abs_pivot: f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
            min_abs_pivot: f64::from_bits(self.min_bits.load(Ordering::Relaxed)),
        });
    }
}

/// Relaxed atomic load of `vals[idx]` (the multiplier read in the CAS
/// strategies: the schedule proves no concurrent *semantic* writer, but
/// sibling columns may be CAS-updating neighbouring elements of the same
/// column, so the access stays atomic).
#[inline]
fn atomic_load(vals: *mut f64, idx: usize) -> f64 {
    // SAFETY: `vals` points into a live, 8-aligned f64 buffer; every
    // concurrent access to this element during the parallel phase is
    // atomic (see module docs).
    let a = unsafe { &*(vals.add(idx) as *const AtomicU64) };
    f64::from_bits(a.load(Ordering::Relaxed))
}

/// Atomic `vals[idx] -= delta` via a CAS loop — the MAC-update commit of
/// the CAS strategies, the CPU analogue of the GPU kernel's atomic add.
#[inline]
fn atomic_sub(vals: *mut f64, idx: usize, delta: f64) {
    // SAFETY: as in `atomic_load`.
    let a = unsafe { &*(vals.add(idx) as *const AtomicU64) };
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) - delta).to_bits();
        match a.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Factor `As` on `pool` under a **hazard-free** plan (GLU2.0 or GLU3.0
/// detection; never GLU1.0 — see module docs), through the indexed
/// scatter-mapped hot loop.
pub fn factor_with(
    sym: &SymbolicFill,
    plan: &FactorPlan,
    pool: &WorkerPool,
) -> anyhow::Result<LuFactors> {
    let mut lu = sym.filled.clone();
    refactor_in_place(&mut lu, plan, pool, &mut PivotMonitor::new())?;
    Ok(LuFactors { lu })
}

/// Search-based twin of [`factor_with`] (the pre-[`ScatterMap`] engine,
/// kept as the bench baseline).
pub fn factor_with_search(
    sym: &SymbolicFill,
    plan: &FactorPlan,
    pool: &WorkerPool,
) -> anyhow::Result<LuFactors> {
    let mut lu = sym.filled.clone();
    refactor_in_place_search(&mut lu, plan, pool, &mut PivotMonitor::new())?;
    Ok(LuFactors { lu })
}

/// Factor in place through the indexed hot loop: `lu` holds the filled
/// pattern with `A`'s values stamped in and is overwritten with the
/// factors, level by level in the plan's [`CpuAssignment`] strategies.
/// Allocation-free — every position comes from the plan's cached
/// [`ScatterMap`] (built on first call, validated once in debug builds).
pub fn refactor_in_place(
    lu: &mut crate::sparse::Csc,
    plan: &FactorPlan,
    pool: &WorkerPool,
    mon: &mut PivotMonitor,
) -> anyhow::Result<()> {
    let n = lu.ncols();
    anyhow::ensure!(plan.n() == n, "plan dimension mismatch");
    let sm = plan.scatter(&*lu);
    anyhow::ensure!(
        sm.nnz == lu.nnz(),
        "scatter map does not match this pattern"
    );
    let levels = plan.levels();
    let steps = plan.cpu_steps();
    let (_, _, values) = lu.split_mut();
    let shared = SharedPtr(values.as_mut_ptr());
    let failed = AtomicUsize::new(usize::MAX);
    let amon = AtomicMonitor::new();

    pool.run(&|ctx: &PoolCtx<'_>| {
        let ok = || failed.load(Ordering::Relaxed) == usize::MAX;
        for step in steps {
            match step.assignment {
                CpuAssignment::InterleavedColumns => {
                    let level = &levels.levels[step.first_level];
                    if ok() {
                        let mut idx = ctx.id;
                        while idx < level.len() {
                            let j = level[idx] as usize;
                            if !factor_column_indexed(j, sm, &shared, &failed, &amon) || !ok() {
                                break;
                            }
                            idx += ctx.threads;
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
                CpuAssignment::SubcolumnSlices | CpuAssignment::OwnedDestinations => {
                    let level = &levels.levels[step.first_level];
                    // Sub-phase 1: divide phases, column-interleaved (the
                    // abort flag is re-checked between columns).
                    if ok() {
                        let mut idx = ctx.id;
                        while idx < level.len() {
                            if !divide_indexed(level[idx] as usize, sm, &shared, &failed, &amon)
                                || !ok()
                            {
                                break;
                            }
                            idx += ctx.threads;
                        }
                    }
                    // Publish the normalized L values to every worker.
                    if !ctx.sync() {
                        return;
                    }
                    // Sub-phase 2: the level's MAC tasks.
                    if ok() {
                        if step.assignment == CpuAssignment::OwnedDestinations {
                            // Whole destination groups per worker: plain
                            // stores, no collisions by construction.
                            let groups = plan.dest_groups(step.first_level);
                            let mut g = ctx.id;
                            while g < groups.num_groups() {
                                for t in groups.group(g) {
                                    mac_task_plain(t.src as usize, t.task as usize, sm, &shared);
                                }
                                g += ctx.threads;
                            }
                        } else {
                            // Source-major round-robin over the flat task
                            // list: CAS commits.
                            let mut base = 0usize;
                            for &j in level.iter() {
                                let j = j as usize;
                                let (t0, t1) =
                                    (sm.task_ptr[j] as usize, sm.task_ptr[j + 1] as usize);
                                for t in t0..t1 {
                                    if (base + (t - t0)) % ctx.threads == ctx.id {
                                        mac_task_atomic(j, t, sm, &shared);
                                    }
                                }
                                base += t1 - t0;
                            }
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
                CpuAssignment::ChainBatch => {
                    // A sequential singleton chain: worker 0 walks the whole
                    // run with plain stores; everyone meets once at the end.
                    if ctx.id == 0 && ok() {
                        'run: for li in step.first_level..step.first_level + step.level_count {
                            for &j in &levels.levels[li] {
                                if !factor_column_chain(j as usize, sm, &shared, &failed, &amon) {
                                    break 'run;
                                }
                            }
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
            }
        }
    });

    let f = failed.load(Ordering::Relaxed);
    amon.merge_into(mon);
    if f != usize::MAX {
        return Err(super::singular_pivot(f));
    }
    Ok(())
}

/// The divide phase of column `j` through the map: normalize the L run
/// (contiguous after the precomputed diagonal index) by the pivot. Plain
/// accesses — this worker owns the column until the next barrier.
#[inline]
fn divide_indexed(
    j: usize,
    sm: &ScatterMap,
    shared: &SharedPtr,
    failed: &AtomicUsize,
    amon: &AtomicMonitor,
) -> bool {
    let vals = shared.0;
    let d = sm.diag_idx[j] as usize;
    // SAFETY: only this worker touches column j's value range during this
    // level; earlier-level values were published by the inter-level
    // barrier (see module docs).
    let pivot = unsafe { *vals.add(d) };
    if pivot == 0.0 || !pivot.is_finite() {
        failed.fetch_min(j, Ordering::Relaxed);
        return false;
    }
    amon.observe(pivot);
    for idx in d + 1..=d + sm.l_len[j] as usize {
        let v = unsafe { *vals.add(idx) } / pivot;
        unsafe { *vals.add(idx) = v };
    }
    true
}

/// One MAC task with atomic commits (interleaved / source-major sliced
/// strategies): `vals[dst[i]] -= l[i] * mult` over the precomputed
/// destination run. Column `j`'s L values are read plainly (own writes, or
/// published by the intra-level barrier).
#[inline]
fn mac_task_atomic(j: usize, t: usize, sm: &ScatterMap, shared: &SharedPtr) {
    let vals = shared.0;
    let mult = atomic_load(vals, sm.mult_idx[t] as usize);
    if mult == 0.0 {
        return;
    }
    let ls = sm.diag_idx[j] as usize + 1;
    let off = sm.dst_off[t] as usize;
    let run = &sm.dst[off..off + sm.l_len[j] as usize];
    for (i, &d) in run.iter().enumerate() {
        // SAFETY: see module docs — L reads are race-free, commits atomic.
        let lij = unsafe { *vals.add(ls + i) };
        atomic_sub(vals, d as usize, lij * mult);
    }
}

/// One MAC task with plain stores (ownership / chain strategies): this
/// worker is the only one touching the destination column this level.
#[inline]
fn mac_task_plain(j: usize, t: usize, sm: &ScatterMap, shared: &SharedPtr) {
    let vals = shared.0;
    // SAFETY: the destination column — multiplier included — is owned by
    // this worker for the sub-phase (module docs), so plain accesses are
    // race-free; the end-of-level barrier publishes them.
    let mult = unsafe { *vals.add(sm.mult_idx[t] as usize) };
    if mult == 0.0 {
        return;
    }
    let ls = sm.diag_idx[j] as usize + 1;
    let off = sm.dst_off[t] as usize;
    let run = &sm.dst[off..off + sm.l_len[j] as usize];
    for (i, &d) in run.iter().enumerate() {
        let lij = unsafe { *vals.add(ls + i) };
        unsafe { *vals.add(d as usize) -= lij * mult };
    }
}

/// Full column pipeline for interleaved levels: indexed divide, then the
/// column's MAC tasks with atomic commits.
#[inline]
fn factor_column_indexed(
    j: usize,
    sm: &ScatterMap,
    shared: &SharedPtr,
    failed: &AtomicUsize,
    amon: &AtomicMonitor,
) -> bool {
    if !divide_indexed(j, sm, shared, failed, amon) {
        return false;
    }
    for t in sm.task_ptr[j] as usize..sm.task_ptr[j + 1] as usize {
        mac_task_atomic(j, t, sm, shared);
    }
    true
}

/// Full column pipeline for chain batches: single worker, plain stores.
#[inline]
fn factor_column_chain(
    j: usize,
    sm: &ScatterMap,
    shared: &SharedPtr,
    failed: &AtomicUsize,
    amon: &AtomicMonitor,
) -> bool {
    if !divide_indexed(j, sm, shared, failed, amon) {
        return false;
    }
    for t in sm.task_ptr[j] as usize..sm.task_ptr[j + 1] as usize {
        mac_task_plain(j, t, sm, shared);
    }
    true
}

// ---------------------------------------------------------------------------
// The batched value-plane refactor: B planes of values over one shared
// pattern ride a single schedule walk. The ScatterMap indices are shared
// across planes, so the per-task index gather is paid once; the innermost
// loops run over the contiguous plane dimension (`data[idx * B + p]`) and
// vectorize. Per plane, the operation order is exactly the single-plane
// engine's, so a 1-thread batched refactor is bit-identical to B looped
// single-plane refactors.
// ---------------------------------------------------------------------------

/// Batched [`refactor_in_place`]: factor every plane of `planes` (stamped
/// values over `pattern`'s positions) in one walk of the plan's schedule.
/// On a zero/non-finite pivot in *any* plane the whole batch aborts with
/// the failing column's typed error — callers fall back to looped
/// single-plane refactors (which run the full repair ladder per plane).
pub fn refactor_planes(
    pattern: &crate::sparse::Csc,
    planes: &mut ValuePlanes,
    plan: &FactorPlan,
    pool: &WorkerPool,
    mon: &mut PivotMonitor,
) -> anyhow::Result<()> {
    let n = pattern.ncols();
    anyhow::ensure!(plan.n() == n, "plan dimension mismatch");
    let sm = plan.scatter(pattern);
    anyhow::ensure!(
        sm.nnz == pattern.nnz() && sm.nnz == planes.nnz(),
        "scatter map does not match this pattern/batch"
    );
    let b = planes.planes();
    let levels = plan.levels();
    let steps = plan.cpu_steps();
    let shared = SharedPtr(planes.data_mut().as_mut_ptr());
    let failed = AtomicUsize::new(usize::MAX);
    let amon = AtomicMonitor::new();

    pool.run(&|ctx: &PoolCtx<'_>| {
        let ok = || failed.load(Ordering::Relaxed) == usize::MAX;
        for step in steps {
            match step.assignment {
                CpuAssignment::InterleavedColumns => {
                    let level = &levels.levels[step.first_level];
                    if ok() {
                        let mut idx = ctx.id;
                        while idx < level.len() {
                            let j = level[idx] as usize;
                            if !factor_column_indexed_batch(j, b, sm, &shared, &failed, &amon)
                                || !ok()
                            {
                                break;
                            }
                            idx += ctx.threads;
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
                CpuAssignment::SubcolumnSlices | CpuAssignment::OwnedDestinations => {
                    let level = &levels.levels[step.first_level];
                    if ok() {
                        let mut idx = ctx.id;
                        while idx < level.len() {
                            if !divide_indexed_batch(
                                level[idx] as usize,
                                b,
                                sm,
                                &shared,
                                &failed,
                                &amon,
                            ) || !ok()
                            {
                                break;
                            }
                            idx += ctx.threads;
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                    if ok() {
                        if step.assignment == CpuAssignment::OwnedDestinations {
                            let groups = plan.dest_groups(step.first_level);
                            let mut g = ctx.id;
                            while g < groups.num_groups() {
                                for t in groups.group(g) {
                                    mac_task_plain_batch(
                                        t.src as usize,
                                        t.task as usize,
                                        b,
                                        sm,
                                        &shared,
                                    );
                                }
                                g += ctx.threads;
                            }
                        } else {
                            let mut base = 0usize;
                            for &j in level.iter() {
                                let j = j as usize;
                                let (t0, t1) =
                                    (sm.task_ptr[j] as usize, sm.task_ptr[j + 1] as usize);
                                for t in t0..t1 {
                                    if (base + (t - t0)) % ctx.threads == ctx.id {
                                        mac_task_atomic_batch(j, t, b, sm, &shared);
                                    }
                                }
                                base += t1 - t0;
                            }
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
                CpuAssignment::ChainBatch => {
                    if ctx.id == 0 && ok() {
                        'run: for li in step.first_level..step.first_level + step.level_count {
                            for &j in &levels.levels[li] {
                                if !factor_column_chain_batch(
                                    j as usize, b, sm, &shared, &failed, &amon,
                                ) {
                                    break 'run;
                                }
                            }
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
            }
        }
    });

    let f = failed.load(Ordering::Relaxed);
    amon.merge_into(mon);
    if f != usize::MAX {
        return Err(super::singular_pivot(f));
    }
    Ok(())
}

/// Batched divide phase: per plane the pivot check and the L-run
/// normalization of [`divide_indexed`], with the plane loop innermost over
/// the contiguous plane run.
#[inline]
fn divide_indexed_batch(
    j: usize,
    b: usize,
    sm: &ScatterMap,
    shared: &SharedPtr,
    failed: &AtomicUsize,
    amon: &AtomicMonitor,
) -> bool {
    let vals = shared.0;
    let d = sm.diag_idx[j] as usize;
    // SAFETY: only this worker touches column j's value range (all planes)
    // during this level; see `divide_indexed`.
    for p in 0..b {
        let pivot = unsafe { *vals.add(d * b + p) };
        if pivot == 0.0 || !pivot.is_finite() {
            failed.fetch_min(j, Ordering::Relaxed);
            return false;
        }
        amon.observe(pivot);
    }
    for idx in d + 1..=d + sm.l_len[j] as usize {
        let lbase = idx * b;
        let dbase = d * b;
        for p in 0..b {
            let v = unsafe { *vals.add(lbase + p) } / unsafe { *vals.add(dbase + p) };
            unsafe { *vals.add(lbase + p) = v };
        }
    }
    true
}

/// Batched MAC task with atomic commits: for each destination element the
/// plane loop runs over the contiguous run, skipping planes whose
/// multiplier is zero (matching the single-plane task-level skip).
#[inline]
fn mac_task_atomic_batch(j: usize, t: usize, b: usize, sm: &ScatterMap, shared: &SharedPtr) {
    let vals = shared.0;
    let mbase = sm.mult_idx[t] as usize * b;
    let ls = sm.diag_idx[j] as usize + 1;
    let off = sm.dst_off[t] as usize;
    let run = &sm.dst[off..off + sm.l_len[j] as usize];
    for (i, &d) in run.iter().enumerate() {
        let lbase = (ls + i) * b;
        let dbase = d as usize * b;
        for p in 0..b {
            // The multiplier element is never a destination of its own
            // task (destinations sit strictly below the pivot row), so
            // re-reading it per element sees one stable value.
            let mult = atomic_load(vals, mbase + p);
            if mult == 0.0 {
                continue;
            }
            // SAFETY: see module docs — L reads race-free, commits atomic.
            let lij = unsafe { *vals.add(lbase + p) };
            atomic_sub(vals, dbase + p, lij * mult);
        }
    }
}

/// Batched MAC task with plain stores (ownership / chain strategies).
#[inline]
fn mac_task_plain_batch(j: usize, t: usize, b: usize, sm: &ScatterMap, shared: &SharedPtr) {
    let vals = shared.0;
    let mbase = sm.mult_idx[t] as usize * b;
    let ls = sm.diag_idx[j] as usize + 1;
    let off = sm.dst_off[t] as usize;
    let run = &sm.dst[off..off + sm.l_len[j] as usize];
    for (i, &d) in run.iter().enumerate() {
        let lbase = (ls + i) * b;
        let dbase = d as usize * b;
        for p in 0..b {
            // SAFETY: destination column owned by this worker (module docs).
            let mult = unsafe { *vals.add(mbase + p) };
            if mult == 0.0 {
                continue;
            }
            let lij = unsafe { *vals.add(lbase + p) };
            unsafe { *vals.add(dbase + p) -= lij * mult };
        }
    }
}

/// Batched full column pipeline for interleaved levels.
#[inline]
fn factor_column_indexed_batch(
    j: usize,
    b: usize,
    sm: &ScatterMap,
    shared: &SharedPtr,
    failed: &AtomicUsize,
    amon: &AtomicMonitor,
) -> bool {
    if !divide_indexed_batch(j, b, sm, shared, failed, amon) {
        return false;
    }
    for t in sm.task_ptr[j] as usize..sm.task_ptr[j + 1] as usize {
        mac_task_atomic_batch(j, t, b, sm, shared);
    }
    true
}

/// Batched full column pipeline for chain batches.
#[inline]
fn factor_column_chain_batch(
    j: usize,
    b: usize,
    sm: &ScatterMap,
    shared: &SharedPtr,
    failed: &AtomicUsize,
    amon: &AtomicMonitor,
) -> bool {
    if !divide_indexed_batch(j, b, sm, shared, failed, amon) {
        return false;
    }
    for t in sm.task_ptr[j] as usize..sm.task_ptr[j + 1] as usize {
        mac_task_plain_batch(j, t, b, sm, shared);
    }
    true
}

// ---------------------------------------------------------------------------
// The search-based baseline: the pre-ScatterMap engine, preserved verbatim
// so the indexed win stays measurable (`glu3 bench` refactor_loop) and the
// property tests can pin both paths to the simulator. It treats ownership
// levels as source-major slices — exactly the old execution.
// ---------------------------------------------------------------------------

/// Factor in place re-deriving every position numerically (binary search
/// per multiplier, `partition_point` + row-match scan per destination run,
/// CAS everywhere) — the baseline [`refactor_in_place`] is measured
/// against.
pub fn refactor_in_place_search(
    lu: &mut crate::sparse::Csc,
    plan: &FactorPlan,
    pool: &WorkerPool,
    mon: &mut PivotMonitor,
) -> anyhow::Result<()> {
    let n = lu.ncols();
    anyhow::ensure!(plan.n() == n, "plan dimension mismatch");
    let urow = plan.urow();
    let levels = plan.levels();
    let steps = plan.cpu_steps();
    let (colptr, rowidx, values) = lu.split_mut();
    let shared = SharedPtr(values.as_mut_ptr());
    let failed = AtomicUsize::new(usize::MAX);
    let amon = AtomicMonitor::new();

    pool.run(&|ctx: &PoolCtx<'_>| {
        let ok = || failed.load(Ordering::Relaxed) == usize::MAX;
        let mut lvals: Vec<f64> = Vec::new();
        for step in steps {
            match step.assignment {
                CpuAssignment::InterleavedColumns => {
                    let level = &levels.levels[step.first_level];
                    if ok() {
                        let mut idx = ctx.id;
                        while idx < level.len() {
                            let j = level[idx] as usize;
                            if !factor_column_search(
                                j, colptr, rowidx, &shared, &urow[j], &mut lvals, &failed, &amon,
                            ) || !ok()
                            {
                                break;
                            }
                            idx += ctx.threads;
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
                CpuAssignment::SubcolumnSlices | CpuAssignment::OwnedDestinations => {
                    let level = &levels.levels[step.first_level];
                    if ok() {
                        let mut idx = ctx.id;
                        while idx < level.len() {
                            if !divide_column_search(
                                level[idx] as usize,
                                colptr,
                                rowidx,
                                &shared,
                                &failed,
                                &amon,
                            ) || !ok()
                            {
                                break;
                            }
                            idx += ctx.threads;
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                    if ok() {
                        let mut base = 0usize;
                        for &j in level.iter() {
                            let j = j as usize;
                            let subs = &urow[j];
                            for (s, &k) in subs.iter().enumerate() {
                                if (base + s) % ctx.threads == ctx.id {
                                    mac_task_search(j, k as usize, colptr, rowidx, &shared);
                                }
                            }
                            base += subs.len();
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
                CpuAssignment::ChainBatch => {
                    if ctx.id == 0 && ok() {
                        'run: for li in step.first_level..step.first_level + step.level_count {
                            for &j in &levels.levels[li] {
                                let j = j as usize;
                                if !factor_column_search(
                                    j, colptr, rowidx, &shared, &urow[j], &mut lvals, &failed,
                                    &amon,
                                ) {
                                    break 'run;
                                }
                            }
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
            }
        }
    });

    let f = failed.load(Ordering::Relaxed);
    amon.merge_into(mon);
    if f != usize::MAX {
        return Err(super::singular_pivot(f));
    }
    Ok(())
}

/// One column of the Algorithm 2 pipeline, search-based: divide phase
/// (plain accesses — the column is owned by this worker for the level),
/// then the subcolumn MAC updates (atomic commits into later-level
/// columns).
#[inline]
#[allow(clippy::too_many_arguments)]
fn factor_column_search(
    j: usize,
    colptr: &[usize],
    rowidx: &[usize],
    shared: &SharedPtr,
    subcols: &[u32],
    lvals: &mut Vec<f64>,
    failed: &AtomicUsize,
    amon: &AtomicMonitor,
) -> bool {
    let vals = shared.0;
    let (s_j, e_j) = (colptr[j], colptr[j + 1]);
    let rows_j = &rowidx[s_j..e_j];
    let diag_pos = match rows_j.binary_search(&j) {
        Ok(p) => p,
        Err(_) => {
            failed.fetch_min(j, Ordering::Relaxed);
            return false;
        }
    };
    // SAFETY (divide phase): only this worker touches column j's value
    // range during this level; earlier-level values it reads were
    // published by the inter-level barrier.
    let pivot = unsafe { *vals.add(s_j + diag_pos) };
    if pivot == 0.0 || !pivot.is_finite() {
        failed.fetch_min(j, Ordering::Relaxed);
        return false;
    }
    amon.observe(pivot);
    let lrows = &rows_j[diag_pos + 1..];
    lvals.clear();
    for idx in diag_pos + 1..rows_j.len() {
        let v = unsafe { *vals.add(s_j + idx) } / pivot;
        unsafe { *vals.add(s_j + idx) = v };
        lvals.push(v);
    }

    for &k in subcols {
        let k = k as usize;
        let (s_k, e_k) = (colptr[k], colptr[k + 1]);
        let rows_k = &rowidx[s_k..e_k];
        let multiplier = match rows_k.binary_search(&j) {
            Ok(p) => atomic_load(vals, s_k + p),
            Err(_) => continue,
        };
        if multiplier == 0.0 {
            continue;
        }
        // Walk L rows of column j and column k's pattern in lock-step
        // (both sorted; the fill closure guarantees containment).
        let mut pos = rows_k.partition_point(|&r| r <= j);
        for (&i, &lij) in lrows.iter().zip(lvals.iter()) {
            while rows_k[pos] != i {
                pos += 1;
            }
            atomic_sub(vals, s_k + pos, lij * multiplier);
        }
    }
    true
}

/// The search-based divide phase alone (sub-phase 1 of a sliced level).
#[inline]
fn divide_column_search(
    j: usize,
    colptr: &[usize],
    rowidx: &[usize],
    shared: &SharedPtr,
    failed: &AtomicUsize,
    amon: &AtomicMonitor,
) -> bool {
    let vals = shared.0;
    let (s_j, e_j) = (colptr[j], colptr[j + 1]);
    let rows_j = &rowidx[s_j..e_j];
    let diag_pos = match rows_j.binary_search(&j) {
        Ok(p) => p,
        Err(_) => {
            failed.fetch_min(j, Ordering::Relaxed);
            return false;
        }
    };
    // SAFETY: as in `factor_column_search`'s divide phase.
    let pivot = unsafe { *vals.add(s_j + diag_pos) };
    if pivot == 0.0 || !pivot.is_finite() {
        failed.fetch_min(j, Ordering::Relaxed);
        return false;
    }
    amon.observe(pivot);
    for idx in diag_pos + 1..rows_j.len() {
        let v = unsafe { *vals.add(s_j + idx) } / pivot;
        unsafe { *vals.add(s_j + idx) = v };
    }
    true
}

/// One `(column j, subcolumn k)` MAC task, search-based (sub-phase 2 of a
/// sliced level): re-derives the multiplier position and every destination
/// position, commits with CAS.
#[inline]
fn mac_task_search(j: usize, k: usize, colptr: &[usize], rowidx: &[usize], shared: &SharedPtr) {
    let vals = shared.0;
    let (s_j, e_j) = (colptr[j], colptr[j + 1]);
    let rows_j = &rowidx[s_j..e_j];
    let diag_pos = match rows_j.binary_search(&j) {
        Ok(p) => p,
        // A missing diagonal was already recorded by the divide sub-phase;
        // the level aborts after the barrier.
        Err(_) => return,
    };
    let lrows = &rows_j[diag_pos + 1..];
    if lrows.is_empty() {
        return;
    }
    let (s_k, e_k) = (colptr[k], colptr[k + 1]);
    let rows_k = &rowidx[s_k..e_k];
    let multiplier = match rows_k.binary_search(&j) {
        Ok(p) => atomic_load(vals, s_k + p),
        Err(_) => return,
    };
    if multiplier == 0.0 {
        return;
    }
    let mut pos = rows_k.partition_point(|&r| r <= j);
    for (off, &i) in lrows.iter().enumerate() {
        // SAFETY: column j is read-only during this sub-phase (module docs).
        let lij = unsafe { *vals.add(s_j + diag_pos + 1 + off) };
        while rows_k[pos] != i {
            pos += 1;
        }
        atomic_sub(vals, s_k + pos, lij * multiplier);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::{glu2, glu3, levelize, Levels};
    use crate::gpusim::{simulate_factorization, DeviceConfig, Policy};
    use crate::numeric::{leftlook, residual};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    fn plan_for(f: &SymbolicFill, lv: &Levels) -> FactorPlan {
        FactorPlan::from_levels(f, lv.clone(), &Policy::glu3(), &DeviceConfig::titan_x())
    }

    #[test]
    fn matches_simulated_gpu_engine() {
        let mut rng = Rng::new(0x9A11);
        for trial in 0..8 {
            let n = rng.range(50, 220);
            let a = gen::netlist(n, 6, 10, 0.08, 2, 0.2, 6200 + trial);
            let f = symbolic_fill(&a).unwrap();
            let lv = levelize(&glu3::detect(&f.filled));
            let plan = plan_for(&f, &lv);
            let d = DeviceConfig::titan_x();
            let (sim, _) = simulate_factorization(&f, &lv, &Policy::glu3(), &d).unwrap();
            for threads in [1, 2, 4] {
                let pool = WorkerPool::new(threads);
                let par = factor_with(&f, &plan, &pool).unwrap();
                let search = factor_with_search(&f, &plan, &pool).unwrap();
                for ((p, s), q) in par
                    .lu
                    .values()
                    .iter()
                    .zip(search.lu.values())
                    .zip(sim.lu.values())
                {
                    assert!(
                        (p - q).abs() < 1e-9 * (1.0 + q.abs()),
                        "trial {trial} threads {threads}: indexed {p} vs sim {q}"
                    );
                    assert!(
                        (s - q).abs() < 1e-9 * (1.0 + q.abs()),
                        "trial {trial} threads {threads}: search {s} vs sim {q}"
                    );
                }
                if threads == 1 {
                    // one thread == the simulator's ascending serialization,
                    // in every assignment mode, on both paths
                    assert_eq!(par.lu.values(), sim.lu.values());
                    assert_eq!(search.lu.values(), sim.lu.values());
                }
            }
        }
    }

    #[test]
    fn glu2_exact_schedule_also_works() {
        let a = gen::netlist(150, 6, 10, 0.08, 2, 0.2, 404);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu2::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        let pool = WorkerPool::new(4);
        let lu = factor_with(&f, &plan, &pool).unwrap();
        let oracle = leftlook::factor(&f).unwrap();
        for (p, q) in lu.lu.values().iter().zip(oracle.lu.values()) {
            assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
        }
    }

    #[test]
    fn solves_correctly_on_mesh() {
        let g = gen::grid2d(20, 20, 5);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        // the mesh plan must exercise the ownership strategy
        assert!(plan
            .cpu_steps()
            .iter()
            .any(|s| s.assignment == CpuAssignment::OwnedDestinations));
        let pool = WorkerPool::new(4);
        let lu = factor_with(&f, &plan, &pool).unwrap();
        let b = vec![1.5; 400];
        let x = lu.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-10);
    }

    /// The arrow fixture forces the dominant-destination CAS path
    /// (source-major slicing) and both engines still agree with the
    /// oracle.
    #[test]
    fn dominant_destination_cas_path_is_correct() {
        use crate::sparse::Coo;
        let m = 8usize;
        let mut coo = Coo::new(m + 1, m + 1);
        for j in 0..=m {
            coo.push(j, j, 4.0);
        }
        for j in 0..m {
            coo.push(m, j, -1.0);
            coo.push(j, m, -1.0);
        }
        let a = coo.to_csc();
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        assert!(plan
            .cpu_steps()
            .iter()
            .any(|s| s.assignment == CpuAssignment::SubcolumnSlices));
        let oracle = leftlook::factor(&f).unwrap();
        for threads in [1, 3] {
            let pool = WorkerPool::new(threads);
            let lu = factor_with(&f, &plan, &pool).unwrap();
            for (p, q) in lu.lu.values().iter().zip(oracle.lu.values()) {
                assert!((p - q).abs() < 1e-12 * (1.0 + q.abs()), "threads {threads}");
            }
        }
    }

    /// Cheap stability invariants: 1-thread runs are bit-stable across
    /// repeats, and a 4-thread run (ownership levels deterministic, CAS
    /// levels reordered) agrees with 1 thread to rounding.
    #[test]
    fn repeated_runs_are_stable() {
        let g = gen::grid2d(16, 16, 2);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        let pool1 = WorkerPool::new(1);
        let x = factor_with(&f, &plan, &pool1).unwrap();
        let y = factor_with(&f, &plan, &pool1).unwrap();
        assert_eq!(x.lu.values(), y.lu.values());
        let pool4 = WorkerPool::new(4);
        let u = factor_with(&f, &plan, &pool4).unwrap();
        for (p, q) in u.lu.values().iter().zip(x.lu.values()) {
            assert!((p - q).abs() < 1e-11 * (1.0 + q.abs()));
        }
    }

    /// Every assignment strategy is exercised on an AMD mesh (wide small
    /// levels, narrow sliced levels, chain-batched singleton tail) under a
    /// fixed-allocation policy too: the engine executes whatever the plan
    /// says, with identical numerics.
    #[test]
    fn fixed_policy_plan_changes_strategies_not_values() {
        let g = gen::grid2d(18, 18, 9);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let d = DeviceConfig::titan_x();
        let adaptive = FactorPlan::from_levels(&f, lv.clone(), &Policy::glu3(), &d);
        let fixed = FactorPlan::from_levels(&f, lv.clone(), &Policy::glu2_fixed(), &d);
        // the two plans disagree on strategy somewhere...
        assert_ne!(
            adaptive
                .level_plans()
                .iter()
                .map(|lp| lp.assignment)
                .collect::<Vec<_>>(),
            fixed
                .level_plans()
                .iter()
                .map(|lp| lp.assignment)
                .collect::<Vec<_>>()
        );
        // ...but factor to the same values on the same schedule
        let pool = WorkerPool::new(3);
        let x = factor_with(&f, &adaptive, &pool).unwrap();
        let y = factor_with(&f, &fixed, &pool).unwrap();
        for (p, q) in x.lu.values().iter().zip(y.lu.values()) {
            assert!((p - q).abs() < 1e-11 * (1.0 + q.abs()), "{p} vs {q}");
        }
    }

    /// The batched value-plane refactor against B looped single-plane
    /// refactors: bit-identical at 1 thread, ≤ 1e-12 relative otherwise
    /// (the CAS levels' commit order differs across walks).
    #[test]
    fn batched_planes_match_looped_refactors() {
        let g = gen::grid2d(18, 18, 3);
        let ord = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(ord.as_scatter(), ord.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        let base = f.filled.values().to_vec();
        for bsz in [1usize, 4, 16] {
            for threads in [1, 2, 4] {
                let pool = WorkerPool::new(threads);
                let mut vp = ValuePlanes::new(bsz, f.filled.nnz());
                let mut looped = Vec::with_capacity(bsz);
                for p in 0..bsz {
                    let scale = 1.0 + 0.01 * p as f64;
                    let vals: Vec<f64> = base.iter().map(|v| v * scale).collect();
                    vp.set_plane(p, &vals);
                    let mut lu = f.filled.clone();
                    lu.values_mut().copy_from_slice(&vals);
                    refactor_in_place(&mut lu, &plan, &pool, &mut PivotMonitor::new()).unwrap();
                    looped.push(lu.values().to_vec());
                }
                refactor_planes(&f.filled, &mut vp, &plan, &pool, &mut PivotMonitor::new())
                    .unwrap();
                for p in 0..bsz {
                    let got = vp.plane(p);
                    if threads == 1 {
                        assert_eq!(got, looped[p], "B {bsz} plane {p}: 1-thread bit-identity");
                    } else {
                        for (x, y) in got.iter().zip(&looped[p]) {
                            assert!(
                                (x - y).abs() <= 1e-12 * (1.0 + y.abs()),
                                "B {bsz} threads {threads} plane {p}: {x} vs {y}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// A zero pivot in any plane aborts the whole batch with the failing
    /// column's typed error.
    #[test]
    fn batched_planes_report_zero_pivot() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 1.0); // U(1,1) cancels to zero
        let f = symbolic_fill(&coo.to_csc()).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        let pool = WorkerPool::new(2);
        let mut vp = ValuePlanes::new(3, f.filled.nnz());
        // column-major stamped values: [a00, a10, a01, a11]
        vp.set_plane(0, &[1.0, 1.0, 1.0, 3.0]); // healthy: U(1,1) = 2
        vp.set_plane(1, &[1.0, 1.0, 1.0, 1.0]); // singular: U(1,1) = 0
        vp.set_plane(2, &[2.0, 1.0, 1.0, 3.0]); // healthy: U(1,1) = 2.5
        let err = refactor_planes(&f.filled, &mut vp, &plan, &pool, &mut PivotMonitor::new())
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<crate::numeric::GluError>(),
                Some(crate::numeric::GluError::NumericallySingular { .. })
            ),
            "{err}"
        );
    }

    #[test]
    fn reports_zero_pivot() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 1.0); // U(1,1) cancels to zero
        let f = symbolic_fill(&coo.to_csc()).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        let pool = WorkerPool::new(2);
        let err = factor_with(&f, &plan, &pool).unwrap_err();
        // the failure is typed, not just worded
        assert_eq!(
            err.downcast_ref::<crate::numeric::GluError>(),
            Some(&crate::numeric::GluError::NumericallySingular { col: 1 }),
            "{err}"
        );
    }

    /// Pivot failure inside a *sliced* level (divide sub-phase) is caught
    /// and the MAC sub-phase skipped — on both MAC strategies.
    #[test]
    fn reports_zero_pivot_in_sliced_level() {
        let a = gen::netlist(120, 6, 10, 0.08, 2, 0.2, 515);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        // force a zero pivot in a level that the plan slices
        let sliced = plan.level_plans().iter().find(|lp| {
            matches!(
                lp.assignment,
                CpuAssignment::SubcolumnSlices | CpuAssignment::OwnedDestinations
            )
        });
        let Some(sliced) = sliced else {
            return; // fixture produced no sliced level; nothing to test
        };
        let victim = plan.levels().levels[sliced.index][0] as usize;
        let mut lu = f.filled.clone();
        let (colptr, rowidx, values) = lu.split_mut();
        let (s, e) = (colptr[victim], colptr[victim + 1]);
        let dpos = rowidx[s..e].binary_search(&victim).unwrap();
        values[s + dpos] = 0.0;
        // also zero the column's U entries so no earlier update revives it
        for idx in s..s + dpos {
            values[idx] = 0.0;
        }
        let pool = WorkerPool::new(3);
        let err =
            refactor_in_place(&mut lu, &plan, &pool, &mut PivotMonitor::new()).unwrap_err();
        match err.downcast_ref::<crate::numeric::GluError>() {
            Some(crate::numeric::GluError::NumericallySingular { col }) => {
                assert_eq!(*col, victim, "{err}")
            }
            _ => panic!("expected a typed NumericallySingular error: {err}"),
        }
    }
}
