//! Parallel hybrid right-looking factorization on a hazard-free level
//! schedule — the GLU3.0 execution model with **real CPU threads** instead
//! of simulated GPU warps.
//!
//! This is the first engine where the extra parallelism exposed by the
//! relaxed dependency detection ([`crate::depend::glu3`], Algorithm 4) is
//! measured in *wall-clock*, not simulated cycles: columns of one level are
//! dealt round-robin across a persistent [`WorkerPool`], each worker runs
//! the Algorithm 2 column pipeline (divide phase + subcolumn MAC updates),
//! and levels meet at a spin barrier.
//!
//! ## Safety model (why the schedule makes this sound)
//!
//! A hazard-free schedule (GLU2.0 exact or GLU3.0 relaxed detection —
//! validated by [`crate::depend::levelize::validate_hazard_free`])
//! guarantees, for columns in the *same* level:
//!
//! - **No update lands in the current level.** Any column `i` with update
//!   work (`L(:,i)` non-empty) is ordered strictly before every column `k`
//!   with `As(i,k) != 0`, so all MAC targets live in later levels. The
//!   divide phase therefore writes its own column without interference,
//!   with plain (non-atomic) accesses.
//! - **No read/write hazard on multipliers or L values** (the double-U
//!   condition). What remains possible is two same-level columns
//!   *accumulating* into the same element of a later column — the GPU
//!   resolves that with atomics, and so do we: MAC updates go through a
//!   compare-and-swap `f64` subtract, and multiplier loads are relaxed
//!   atomic loads.
//!
//! Accumulation order into a shared element is therefore nondeterministic
//! across threads — results match the simulated-GPU engine (which commits
//! same-level columns in ascending order) to rounding, and are *identical*
//! to it when the pool has one thread.
//!
//! GLU1.0's U-pattern schedule does **not** provide these guarantees
//! (paper Fig. 9's counterexample); [`crate::glu::GluSolver`] refuses to
//! combine it with this engine.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::depend::Levels;
use crate::numeric::pool::{PoolCtx, SharedPtr, WorkerPool};
use crate::symbolic::SymbolicFill;

use super::LuFactors;

/// Relaxed atomic load of `vals[idx]` (the multiplier read: the schedule
/// proves no concurrent *semantic* writer, but sibling columns may be
/// CAS-updating neighbouring elements of the same column, so the access
/// must be atomic to be race-free).
#[inline]
fn atomic_load(vals: *mut f64, idx: usize) -> f64 {
    // SAFETY: `vals` points into a live, 8-aligned f64 buffer; every
    // concurrent access to this element during the parallel phase is
    // atomic (see module docs).
    let a = unsafe { &*(vals.add(idx) as *const AtomicU64) };
    f64::from_bits(a.load(Ordering::Relaxed))
}

/// Atomic `vals[idx] -= delta` via a CAS loop — the MAC-update commit, the
/// CPU analogue of the GPU kernel's atomic add.
#[inline]
fn atomic_sub(vals: *mut f64, idx: usize, delta: f64) {
    // SAFETY: as in `atomic_load`.
    let a = unsafe { &*(vals.add(idx) as *const AtomicU64) };
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) - delta).to_bits();
        match a.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Factor `As` on `pool` under a **hazard-free** level schedule (GLU2.0 or
/// GLU3.0 detection; never GLU1.0 — see module docs). `urow` is the
/// subcolumn view from [`crate::numeric::rightlook::upper_rows`].
pub fn factor_with(
    sym: &SymbolicFill,
    urow: &[Vec<u32>],
    levels: &Levels,
    pool: &WorkerPool,
) -> anyhow::Result<LuFactors> {
    let mut lu = sym.filled.clone();
    refactor_in_place(&mut lu, urow, levels, pool)?;
    Ok(LuFactors { lu })
}

/// Factor in place: `lu` holds the filled pattern with `A`'s values
/// stamped in and is overwritten with the factors. Allocation-free apart
/// from each worker's small divide-phase scratch (grown once, reused
/// across levels).
pub fn refactor_in_place(
    lu: &mut crate::sparse::Csc,
    urow: &[Vec<u32>],
    levels: &Levels,
    pool: &WorkerPool,
) -> anyhow::Result<()> {
    let n = lu.ncols();
    anyhow::ensure!(urow.len() == n, "subcolumn view dimension mismatch");
    let (colptr, rowidx, values) = lu.split_mut();
    let shared = SharedPtr(values.as_mut_ptr());
    let failed = AtomicUsize::new(usize::MAX);

    pool.run(&|ctx: &PoolCtx<'_>| {
        let mut lvals: Vec<f64> = Vec::new();
        for level in &levels.levels {
            if failed.load(Ordering::Relaxed) == usize::MAX {
                let mut idx = ctx.id;
                while idx < level.len() {
                    let j = level[idx] as usize;
                    if !factor_column_par(j, colptr, rowidx, &shared, &urow[j], &mut lvals, &failed)
                        || failed.load(Ordering::Relaxed) != usize::MAX
                    {
                        break;
                    }
                    idx += ctx.threads;
                }
            }
            if !ctx.sync() {
                return;
            }
        }
    });

    let f = failed.load(Ordering::Relaxed);
    anyhow::ensure!(f == usize::MAX, "zero/non-finite pivot at column {f}");
    Ok(())
}

/// One column of the Algorithm 2 pipeline: divide phase (plain accesses —
/// the column is owned by this worker for the level), then the subcolumn
/// MAC updates (atomic commits into later-level columns).
#[inline]
fn factor_column_par(
    j: usize,
    colptr: &[usize],
    rowidx: &[usize],
    shared: &SharedPtr,
    subcols: &[u32],
    lvals: &mut Vec<f64>,
    failed: &AtomicUsize,
) -> bool {
    let vals = shared.0;
    let (s_j, e_j) = (colptr[j], colptr[j + 1]);
    let rows_j = &rowidx[s_j..e_j];
    let diag_pos = match rows_j.binary_search(&j) {
        Ok(p) => p,
        Err(_) => {
            failed.fetch_min(j, Ordering::Relaxed);
            return false;
        }
    };
    // SAFETY (divide phase): only this worker touches column j's value
    // range during this level; earlier-level values it reads were
    // published by the inter-level barrier.
    let pivot = unsafe { *vals.add(s_j + diag_pos) };
    if pivot == 0.0 || !pivot.is_finite() {
        failed.fetch_min(j, Ordering::Relaxed);
        return false;
    }
    let lrows = &rows_j[diag_pos + 1..];
    lvals.clear();
    for idx in diag_pos + 1..rows_j.len() {
        let v = unsafe { *vals.add(s_j + idx) } / pivot;
        unsafe { *vals.add(s_j + idx) = v };
        lvals.push(v);
    }

    for &k in subcols {
        let k = k as usize;
        let (s_k, e_k) = (colptr[k], colptr[k + 1]);
        let rows_k = &rowidx[s_k..e_k];
        let multiplier = match rows_k.binary_search(&j) {
            Ok(p) => atomic_load(vals, s_k + p),
            Err(_) => continue,
        };
        if multiplier == 0.0 {
            continue;
        }
        // Walk L rows of column j and column k's pattern in lock-step
        // (both sorted; the fill closure guarantees containment).
        let mut pos = rows_k.partition_point(|&r| r <= j);
        for (&i, &lij) in lrows.iter().zip(lvals.iter()) {
            while rows_k[pos] != i {
                pos += 1;
            }
            atomic_sub(vals, s_k + pos, lij * multiplier);
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::{glu2, glu3, levelize};
    use crate::gpusim::{simulate_factorization, DeviceConfig, Policy};
    use crate::numeric::rightlook::upper_rows;
    use crate::numeric::{leftlook, residual};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    #[test]
    fn matches_simulated_gpu_engine() {
        let mut rng = Rng::new(0x9A11);
        for trial in 0..8 {
            let n = rng.range(50, 220);
            let a = gen::netlist(n, 6, 10, 0.08, 2, 0.2, 6200 + trial);
            let f = symbolic_fill(&a).unwrap();
            let lv = levelize(&glu3::detect(&f.filled));
            let urow = upper_rows(&f);
            let d = DeviceConfig::titan_x();
            let (sim, _) = simulate_factorization(&f, &lv, &Policy::glu3(), &d).unwrap();
            for threads in [1, 2, 4] {
                let pool = WorkerPool::new(threads);
                let par = factor_with(&f, &urow, &lv, &pool).unwrap();
                for (p, q) in par.lu.values().iter().zip(sim.lu.values()) {
                    assert!(
                        (p - q).abs() < 1e-9 * (1.0 + q.abs()),
                        "trial {trial} threads {threads}: {p} vs {q}"
                    );
                }
                if threads == 1 {
                    // one thread == the simulator's ascending serialization
                    assert_eq!(par.lu.values(), sim.lu.values());
                }
            }
        }
    }

    #[test]
    fn glu2_exact_schedule_also_works() {
        let a = gen::netlist(150, 6, 10, 0.08, 2, 0.2, 404);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu2::detect(&f.filled));
        let urow = upper_rows(&f);
        let pool = WorkerPool::new(4);
        let lu = factor_with(&f, &urow, &lv, &pool).unwrap();
        let oracle = leftlook::factor(&f).unwrap();
        for (p, q) in lu.lu.values().iter().zip(oracle.lu.values()) {
            assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
        }
    }

    #[test]
    fn solves_correctly_on_mesh() {
        let g = gen::grid2d(20, 20, 5);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let urow = upper_rows(&f);
        let pool = WorkerPool::new(4);
        let lu = factor_with(&f, &urow, &lv, &pool).unwrap();
        let b = vec![1.5; 400];
        let x = lu.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-10);
    }

    #[test]
    fn reports_zero_pivot() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 1.0); // U(1,1) cancels to zero
        let f = symbolic_fill(&coo.to_csc()).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let urow = upper_rows(&f);
        let pool = WorkerPool::new(2);
        let err = factor_with(&f, &urow, &lv, &pool).unwrap_err();
        assert!(err.to_string().contains("pivot"), "{err}");
    }
}
