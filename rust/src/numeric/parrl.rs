//! Parallel hybrid right-looking factorization on a hazard-free level
//! schedule — the GLU3.0 execution model with **real CPU threads** instead
//! of simulated GPU warps, executing the mode-annotated
//! [`crate::plan::FactorPlan`].
//!
//! This engine holds no assignment policy of its own: every level's
//! worker-pool strategy comes from the plan's [`CpuAssignment`] — the CPU
//! analogue of the paper's three adaptive kernel modes, decided once at
//! plan-build time alongside the GPU geometry:
//!
//! - [`CpuAssignment::InterleavedColumns`] (small-mode levels — wide, many
//!   independent columns): columns are dealt round-robin across the pool,
//!   each worker runs the full Algorithm 2 column pipeline.
//! - [`CpuAssignment::SubcolumnSlices`] (large-mode levels — too few
//!   columns to feed every worker): two sub-phases per level. All divide
//!   phases run column-interleaved, a barrier publishes the normalized L
//!   values, then the level's flat `(column, subcolumn)` MAC task list is
//!   dealt round-robin — the thread-chunk analogue of the GPU kernel
//!   splitting a column's subcolumn tasks across warps.
//! - [`CpuAssignment::ChainBatch`] (stream-mode singleton tails): a run of
//!   consecutive size-1 levels executes as one sequential chain on worker
//!   0 with a *single* end-of-run rendezvous, instead of paying one
//!   barrier per level on a schedule with no parallelism to exploit.
//!
//! ## Safety model (why the schedule makes this sound)
//!
//! A hazard-free schedule (GLU2.0 exact or GLU3.0 relaxed detection —
//! validated by [`crate::depend::levelize::validate_hazard_free`])
//! guarantees, for columns in the *same* level:
//!
//! - **No update lands in the current level.** Any column `i` with update
//!   work (`L(:,i)` non-empty) is ordered strictly before every column `k`
//!   with `As(i,k) != 0`, so all MAC targets live in later levels. The
//!   divide phase therefore writes its own column without interference,
//!   with plain (non-atomic) accesses — and in the sliced sub-phase the
//!   MAC tasks may *read* any same-level column's L values plainly, since
//!   no one writes them after the intra-level barrier.
//! - **No read/write hazard on multipliers or L values** (the double-U
//!   condition). What remains possible is two same-level columns
//!   *accumulating* into the same element of a later column — the GPU
//!   resolves that with atomics, and so do we: MAC updates go through a
//!   compare-and-swap `f64` subtract, and multiplier loads are relaxed
//!   atomic loads.
//!
//! Accumulation order into a shared element is therefore nondeterministic
//! across threads — results match the simulated-GPU engine (which commits
//! same-level columns in ascending order) to rounding, and are *identical*
//! to it when the pool has one thread, in **every** assignment mode: at
//! one thread each strategy degenerates to ascending column order with
//! divide-before-MAC per level, and reordering divides ahead of MACs
//! within a level touches disjoint state (see the first bullet).
//!
//! GLU1.0's U-pattern schedule does **not** provide these guarantees
//! (paper Fig. 9's counterexample); [`crate::glu::GluSolver`] refuses to
//! combine it with this engine.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::numeric::pool::{PoolCtx, SharedPtr, WorkerPool};
use crate::plan::{CpuAssignment, FactorPlan};
use crate::symbolic::SymbolicFill;

use super::LuFactors;

/// Relaxed atomic load of `vals[idx]` (the multiplier read: the schedule
/// proves no concurrent *semantic* writer, but sibling columns may be
/// CAS-updating neighbouring elements of the same column, so the access
/// must be atomic to be race-free).
#[inline]
fn atomic_load(vals: *mut f64, idx: usize) -> f64 {
    // SAFETY: `vals` points into a live, 8-aligned f64 buffer; every
    // concurrent access to this element during the parallel phase is
    // atomic (see module docs).
    let a = unsafe { &*(vals.add(idx) as *const AtomicU64) };
    f64::from_bits(a.load(Ordering::Relaxed))
}

/// Atomic `vals[idx] -= delta` via a CAS loop — the MAC-update commit, the
/// CPU analogue of the GPU kernel's atomic add.
#[inline]
fn atomic_sub(vals: *mut f64, idx: usize, delta: f64) {
    // SAFETY: as in `atomic_load`.
    let a = unsafe { &*(vals.add(idx) as *const AtomicU64) };
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) - delta).to_bits();
        match a.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Factor `As` on `pool` under a **hazard-free** plan (GLU2.0 or GLU3.0
/// detection; never GLU1.0 — see module docs).
pub fn factor_with(
    sym: &SymbolicFill,
    plan: &FactorPlan,
    pool: &WorkerPool,
) -> anyhow::Result<LuFactors> {
    let mut lu = sym.filled.clone();
    refactor_in_place(&mut lu, plan, pool)?;
    Ok(LuFactors { lu })
}

/// Factor in place: `lu` holds the filled pattern with `A`'s values
/// stamped in and is overwritten with the factors, level by level in the
/// plan's [`CpuAssignment`] strategies. Allocation-free apart from each
/// worker's small divide-phase scratch (grown once, reused across levels).
pub fn refactor_in_place(
    lu: &mut crate::sparse::Csc,
    plan: &FactorPlan,
    pool: &WorkerPool,
) -> anyhow::Result<()> {
    let n = lu.ncols();
    anyhow::ensure!(plan.n() == n, "plan dimension mismatch");
    let urow = plan.urow();
    let levels = plan.levels();
    let steps = plan.cpu_steps();
    let (colptr, rowidx, values) = lu.split_mut();
    let shared = SharedPtr(values.as_mut_ptr());
    let failed = AtomicUsize::new(usize::MAX);

    pool.run(&|ctx: &PoolCtx<'_>| {
        let ok = || failed.load(Ordering::Relaxed) == usize::MAX;
        let mut lvals: Vec<f64> = Vec::new();
        for step in steps {
            match step.assignment {
                CpuAssignment::InterleavedColumns => {
                    let level = &levels.levels[step.first_level];
                    if ok() {
                        let mut idx = ctx.id;
                        while idx < level.len() {
                            let j = level[idx] as usize;
                            if !factor_column_par(
                                j, colptr, rowidx, &shared, &urow[j], &mut lvals, &failed,
                            ) || !ok()
                            {
                                break;
                            }
                            idx += ctx.threads;
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
                CpuAssignment::SubcolumnSlices => {
                    let level = &levels.levels[step.first_level];
                    // Sub-phase 1: divide phases, column-interleaved (the
                    // abort flag is re-checked between columns, as in the
                    // interleaved strategy).
                    if ok() {
                        let mut idx = ctx.id;
                        while idx < level.len() {
                            if !divide_column_par(level[idx] as usize, colptr, rowidx, &shared, &failed)
                                || !ok()
                            {
                                break;
                            }
                            idx += ctx.threads;
                        }
                    }
                    // Publish the normalized L values to every worker.
                    if !ctx.sync() {
                        return;
                    }
                    // Sub-phase 2: the flat (column, subcolumn) MAC task
                    // list, dealt round-robin across workers.
                    if ok() {
                        let mut base = 0usize;
                        for &j in level.iter() {
                            let j = j as usize;
                            let subs = &urow[j];
                            for (s, &k) in subs.iter().enumerate() {
                                if (base + s) % ctx.threads == ctx.id {
                                    mac_task(j, k as usize, colptr, rowidx, &shared);
                                }
                            }
                            base += subs.len();
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
                CpuAssignment::ChainBatch => {
                    // A sequential singleton chain: worker 0 walks the whole
                    // run; everyone meets once at the end of the run.
                    if ctx.id == 0 && ok() {
                        'run: for li in step.first_level..step.first_level + step.level_count {
                            for &j in &levels.levels[li] {
                                let j = j as usize;
                                if !factor_column_par(
                                    j, colptr, rowidx, &shared, &urow[j], &mut lvals, &failed,
                                ) {
                                    break 'run;
                                }
                            }
                        }
                    }
                    if !ctx.sync() {
                        return;
                    }
                }
            }
        }
    });

    let f = failed.load(Ordering::Relaxed);
    anyhow::ensure!(f == usize::MAX, "zero/non-finite pivot at column {f}");
    Ok(())
}

/// One column of the Algorithm 2 pipeline: divide phase (plain accesses —
/// the column is owned by this worker for the level), then the subcolumn
/// MAC updates (atomic commits into later-level columns).
#[inline]
fn factor_column_par(
    j: usize,
    colptr: &[usize],
    rowidx: &[usize],
    shared: &SharedPtr,
    subcols: &[u32],
    lvals: &mut Vec<f64>,
    failed: &AtomicUsize,
) -> bool {
    let vals = shared.0;
    let (s_j, e_j) = (colptr[j], colptr[j + 1]);
    let rows_j = &rowidx[s_j..e_j];
    let diag_pos = match rows_j.binary_search(&j) {
        Ok(p) => p,
        Err(_) => {
            failed.fetch_min(j, Ordering::Relaxed);
            return false;
        }
    };
    // SAFETY (divide phase): only this worker touches column j's value
    // range during this level; earlier-level values it reads were
    // published by the inter-level barrier.
    let pivot = unsafe { *vals.add(s_j + diag_pos) };
    if pivot == 0.0 || !pivot.is_finite() {
        failed.fetch_min(j, Ordering::Relaxed);
        return false;
    }
    let lrows = &rows_j[diag_pos + 1..];
    lvals.clear();
    for idx in diag_pos + 1..rows_j.len() {
        let v = unsafe { *vals.add(s_j + idx) } / pivot;
        unsafe { *vals.add(s_j + idx) = v };
        lvals.push(v);
    }

    for &k in subcols {
        let k = k as usize;
        let (s_k, e_k) = (colptr[k], colptr[k + 1]);
        let rows_k = &rowidx[s_k..e_k];
        let multiplier = match rows_k.binary_search(&j) {
            Ok(p) => atomic_load(vals, s_k + p),
            Err(_) => continue,
        };
        if multiplier == 0.0 {
            continue;
        }
        // Walk L rows of column j and column k's pattern in lock-step
        // (both sorted; the fill closure guarantees containment).
        let mut pos = rows_k.partition_point(|&r| r <= j);
        for (&i, &lij) in lrows.iter().zip(lvals.iter()) {
            while rows_k[pos] != i {
                pos += 1;
            }
            atomic_sub(vals, s_k + pos, lij * multiplier);
        }
    }
    true
}

/// The divide phase alone (sub-phase 1 of a sliced level): normalize
/// column `j`'s L entries by the pivot, in place. Plain accesses — this
/// worker owns the column until the intra-level barrier.
#[inline]
fn divide_column_par(
    j: usize,
    colptr: &[usize],
    rowidx: &[usize],
    shared: &SharedPtr,
    failed: &AtomicUsize,
) -> bool {
    let vals = shared.0;
    let (s_j, e_j) = (colptr[j], colptr[j + 1]);
    let rows_j = &rowidx[s_j..e_j];
    let diag_pos = match rows_j.binary_search(&j) {
        Ok(p) => p,
        Err(_) => {
            failed.fetch_min(j, Ordering::Relaxed);
            return false;
        }
    };
    // SAFETY: as in `factor_column_par`'s divide phase.
    let pivot = unsafe { *vals.add(s_j + diag_pos) };
    if pivot == 0.0 || !pivot.is_finite() {
        failed.fetch_min(j, Ordering::Relaxed);
        return false;
    }
    for idx in diag_pos + 1..rows_j.len() {
        let v = unsafe { *vals.add(s_j + idx) } / pivot;
        unsafe { *vals.add(s_j + idx) = v };
    }
    true
}

/// One `(column j, subcolumn k)` MAC task of a sliced level (sub-phase 2):
/// apply the Eq. 3 rank-1 update of column `j` onto column `k`. Column
/// `j`'s normalized L values are read plainly (published by the
/// intra-level barrier, and no same-level MAC ever targets column `j`);
/// commits into column `k` are atomic.
#[inline]
fn mac_task(j: usize, k: usize, colptr: &[usize], rowidx: &[usize], shared: &SharedPtr) {
    let vals = shared.0;
    let (s_j, e_j) = (colptr[j], colptr[j + 1]);
    let rows_j = &rowidx[s_j..e_j];
    let diag_pos = match rows_j.binary_search(&j) {
        Ok(p) => p,
        // A missing diagonal was already recorded by the divide sub-phase;
        // the level aborts after the barrier.
        Err(_) => return,
    };
    let lrows = &rows_j[diag_pos + 1..];
    if lrows.is_empty() {
        return;
    }
    let (s_k, e_k) = (colptr[k], colptr[k + 1]);
    let rows_k = &rowidx[s_k..e_k];
    let multiplier = match rows_k.binary_search(&j) {
        Ok(p) => atomic_load(vals, s_k + p),
        Err(_) => return,
    };
    if multiplier == 0.0 {
        return;
    }
    let mut pos = rows_k.partition_point(|&r| r <= j);
    for (off, &i) in lrows.iter().enumerate() {
        // SAFETY: column j is read-only during this sub-phase (module docs).
        let lij = unsafe { *vals.add(s_j + diag_pos + 1 + off) };
        while rows_k[pos] != i {
            pos += 1;
        }
        atomic_sub(vals, s_k + pos, lij * multiplier);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::{glu2, glu3, levelize, Levels};
    use crate::gpusim::{simulate_factorization, DeviceConfig, Policy};
    use crate::numeric::{leftlook, residual};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    fn plan_for(f: &SymbolicFill, lv: &Levels) -> FactorPlan {
        FactorPlan::from_levels(f, lv.clone(), &Policy::glu3(), &DeviceConfig::titan_x())
    }

    #[test]
    fn matches_simulated_gpu_engine() {
        let mut rng = Rng::new(0x9A11);
        for trial in 0..8 {
            let n = rng.range(50, 220);
            let a = gen::netlist(n, 6, 10, 0.08, 2, 0.2, 6200 + trial);
            let f = symbolic_fill(&a).unwrap();
            let lv = levelize(&glu3::detect(&f.filled));
            let plan = plan_for(&f, &lv);
            let d = DeviceConfig::titan_x();
            let (sim, _) = simulate_factorization(&f, &lv, &Policy::glu3(), &d).unwrap();
            for threads in [1, 2, 4] {
                let pool = WorkerPool::new(threads);
                let par = factor_with(&f, &plan, &pool).unwrap();
                for (p, q) in par.lu.values().iter().zip(sim.lu.values()) {
                    assert!(
                        (p - q).abs() < 1e-9 * (1.0 + q.abs()),
                        "trial {trial} threads {threads}: {p} vs {q}"
                    );
                }
                if threads == 1 {
                    // one thread == the simulator's ascending serialization,
                    // in every assignment mode
                    assert_eq!(par.lu.values(), sim.lu.values());
                }
            }
        }
    }

    #[test]
    fn glu2_exact_schedule_also_works() {
        let a = gen::netlist(150, 6, 10, 0.08, 2, 0.2, 404);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu2::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        let pool = WorkerPool::new(4);
        let lu = factor_with(&f, &plan, &pool).unwrap();
        let oracle = leftlook::factor(&f).unwrap();
        for (p, q) in lu.lu.values().iter().zip(oracle.lu.values()) {
            assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
        }
    }

    #[test]
    fn solves_correctly_on_mesh() {
        let g = gen::grid2d(20, 20, 5);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        let pool = WorkerPool::new(4);
        let lu = factor_with(&f, &plan, &pool).unwrap();
        let b = vec![1.5; 400];
        let x = lu.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-10);
    }

    /// Every assignment strategy is exercised on an AMD mesh (wide small
    /// levels, narrow sliced levels, chain-batched singleton tail) under a
    /// fixed-allocation policy too: the engine executes whatever the plan
    /// says, with identical numerics.
    #[test]
    fn fixed_policy_plan_changes_strategies_not_values() {
        let g = gen::grid2d(18, 18, 9);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let d = DeviceConfig::titan_x();
        let adaptive = FactorPlan::from_levels(&f, lv.clone(), &Policy::glu3(), &d);
        let fixed = FactorPlan::from_levels(&f, lv.clone(), &Policy::glu2_fixed(), &d);
        // the two plans disagree on strategy somewhere...
        assert_ne!(
            adaptive
                .level_plans()
                .iter()
                .map(|lp| lp.assignment)
                .collect::<Vec<_>>(),
            fixed
                .level_plans()
                .iter()
                .map(|lp| lp.assignment)
                .collect::<Vec<_>>()
        );
        // ...but factor to the same values on the same schedule
        let pool = WorkerPool::new(3);
        let x = factor_with(&f, &adaptive, &pool).unwrap();
        let y = factor_with(&f, &fixed, &pool).unwrap();
        for (p, q) in x.lu.values().iter().zip(y.lu.values()) {
            assert!((p - q).abs() < 1e-11 * (1.0 + q.abs()), "{p} vs {q}");
        }
    }

    #[test]
    fn reports_zero_pivot() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 1.0); // U(1,1) cancels to zero
        let f = symbolic_fill(&coo.to_csc()).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        let pool = WorkerPool::new(2);
        let err = factor_with(&f, &plan, &pool).unwrap_err();
        assert!(err.to_string().contains("pivot"), "{err}");
    }

    /// Pivot failure inside a *sliced* level (divide sub-phase) is caught
    /// and the MAC sub-phase skipped.
    #[test]
    fn reports_zero_pivot_in_sliced_level() {
        let a = gen::netlist(120, 6, 10, 0.08, 2, 0.2, 515);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let plan = plan_for(&f, &lv);
        // force a zero pivot in a level that the plan slices
        let sliced = plan
            .level_plans()
            .iter()
            .find(|lp| lp.assignment == CpuAssignment::SubcolumnSlices);
        let Some(sliced) = sliced else {
            return; // fixture produced no sliced level; nothing to test
        };
        let victim = plan.levels().levels[sliced.index][0] as usize;
        let mut lu = f.filled.clone();
        let (colptr, rowidx, values) = lu.split_mut();
        let (s, e) = (colptr[victim], colptr[victim + 1]);
        let dpos = rowidx[s..e].binary_search(&victim).unwrap();
        values[s + dpos] = 0.0;
        // also zero the column's U entries so no earlier update revives it
        for idx in s..s + dpos {
            values[idx] = 0.0;
        }
        let pool = WorkerPool::new(3);
        let err = refactor_in_place(&mut lu, &plan, &pool).unwrap_err();
        assert!(err.to_string().contains("pivot"), "{err}");
    }
}
