//! Dense LU with partial pivoting — the ground-truth oracle for all sparse
//! engines (and the dense-tail kernel's reference on the Rust side; the
//! Pallas dense-LU kernel is checked against `python/compile/kernels/ref.py`
//! on the Python side).

/// Dense LU factorization with partial pivoting, row-major in place.
/// Returns the pivot row permutation (`piv[k]` = row swapped into step `k`).
pub fn lu_inplace(a: &mut [f64], n: usize) -> anyhow::Result<Vec<usize>> {
    anyhow::ensure!(a.len() == n * n, "bad dimensions");
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // partial pivot
        let mut p = k;
        let mut best = a[k * n + k].abs();
        for i in k + 1..n {
            let v = a[i * n + k].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        anyhow::ensure!(best > 0.0, "singular at step {k}");
        if p != k {
            for j in 0..n {
                a.swap(k * n + j, p * n + j);
            }
            piv.swap(k, p);
        }
        let pivot = a[k * n + k];
        for i in k + 1..n {
            let m = a[i * n + k] / pivot;
            a[i * n + k] = m;
            if m != 0.0 {
                for j in k + 1..n {
                    a[i * n + j] -= m * a[k * n + j];
                }
            }
        }
    }
    Ok(piv)
}

/// Dense LU *without* pivoting — mirrors the GLU regime exactly (and the
/// Pallas `dense_lu` kernel). Fails on a zero pivot.
pub fn lu_nopivot_inplace(a: &mut [f64], n: usize) -> anyhow::Result<()> {
    anyhow::ensure!(a.len() == n * n, "bad dimensions");
    for k in 0..n {
        let pivot = a[k * n + k];
        anyhow::ensure!(pivot != 0.0, "zero pivot at step {k}");
        for i in k + 1..n {
            let m = a[i * n + k] / pivot;
            a[i * n + k] = m;
            if m != 0.0 {
                for j in k + 1..n {
                    a[i * n + j] -= m * a[k * n + j];
                }
            }
        }
    }
    Ok(())
}

/// Solve `Ax = b` densely via `lu_inplace` (copies `a`).
pub fn solve(a: &[f64], n: usize, b: &[f64]) -> anyhow::Result<Vec<f64>> {
    let mut lu = a.to_vec();
    let piv = lu_inplace(&mut lu, n)?;
    let mut x: Vec<f64> = piv.iter().map(|&p| b[p]).collect();
    // forward (unit lower)
    for i in 0..n {
        for j in 0..i {
            x[i] = x[i] - lu[i * n + j] * x[j];
        }
    }
    // backward
    for i in (0..n).rev() {
        for j in i + 1..n {
            x[i] = x[i] - lu[i * n + j] * x[j];
        }
        x[i] /= lu[i * n + i];
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_dd(n: usize, seed: u64) -> Vec<f64> {
        // diagonally dominant => no-pivot LU is defined
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            let mut row = 0.0;
            for j in 0..n {
                if i != j {
                    let v = rng.range_f64(-1.0, 1.0);
                    a[i * n + j] = v;
                    row += v.abs();
                }
            }
            a[i * n + i] = row + 1.0;
        }
        a
    }

    #[test]
    fn solve_recovers_known_x() {
        for n in [1, 2, 3, 7, 16, 33] {
            let a = random_dd(n, n as u64);
            let xs: Vec<f64> = (0..n).map(|i| (i as f64) - 1.5).collect();
            // b = A * xs
            let mut b = vec![0.0; n];
            for i in 0..n {
                for j in 0..n {
                    b[i] += a[i * n + j] * xs[j];
                }
            }
            let x = solve(&a, n, &b).unwrap();
            for (g, w) in x.iter().zip(&xs) {
                assert!((g - w).abs() < 1e-9, "n={n}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn nopivot_matches_pivot_on_dd() {
        let n = 12;
        let a = random_dd(n, 5);
        let mut lu1 = a.clone();
        let piv = lu_inplace(&mut lu1, n).unwrap();
        // diagonally dominant columns => partial pivoting may still swap;
        // compare via solve instead of factor entries.
        assert_eq!(piv.len(), n);
        let mut lu2 = a.clone();
        lu_nopivot_inplace(&mut lu2, n).unwrap();
        let b = vec![1.0; n];
        let x1 = solve(&a, n, &b).unwrap();
        // manual solve with nopivot factors
        let mut x2 = b.clone();
        for i in 0..n {
            for j in 0..i {
                x2[i] = x2[i] - lu2[i * n + j] * x2[j];
            }
        }
        for i in (0..n).rev() {
            for j in i + 1..n {
                x2[i] = x2[i] - lu2[i * n + j] * x2[j];
            }
            x2[i] /= lu2[i * n + i];
        }
        for (p, q) in x1.iter().zip(&x2) {
            assert!((p - q).abs() < 1e-9);
        }
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        // [[0,1],[1,0]] needs a swap.
        let a = vec![0.0, 1.0, 1.0, 0.0];
        let x = solve(&a, 2, &[2.0, 3.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
        let mut lu = a.clone();
        assert!(lu_nopivot_inplace(&mut lu, 2).is_err());
    }

    #[test]
    fn singular_detected() {
        let a = vec![1.0, 2.0, 2.0, 4.0];
        assert!(solve(&a, 2, &[1.0, 2.0]).is_err());
    }
}
