//! SPICE-like netlist representation and parser.
//!
//! Supported cards (case-insensitive, `*`/`;` comments, `.end` optional):
//!
//! ```text
//! R<name> a b <ohms>        resistor
//! C<name> a b <farads>      capacitor
//! I<name> a b <amps>        DC current source (flows a -> b)
//! V<name> a b <volts>       DC voltage source (MNA branch variable)
//! D<name> a b [is=..] [n=..]  diode (Shockley, linearized by NR)
//! G<name> a b c d <siemens> VCCS: i(a->b) = g * (v(c) - v(d))
//! ```
//!
//! Node `0` (or `gnd`) is ground. Values accept SPICE suffixes
//! (`k M meg u n p f`).

use std::collections::HashMap;

/// A circuit element.
#[derive(Debug, Clone, PartialEq)]
pub enum Element {
    Resistor { a: usize, b: usize, ohms: f64 },
    Capacitor { a: usize, b: usize, farads: f64 },
    CurrentSource { a: usize, b: usize, amps: f64 },
    VoltageSource { a: usize, b: usize, volts: f64 },
    Diode { a: usize, b: usize, isat: f64, nvt: f64 },
    Vccs { a: usize, b: usize, c: usize, d: usize, gm: f64 },
}

/// A parsed netlist. Node 0 is ground; nodes are compacted to `0..n_nodes`.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    pub elements: Vec<Element>,
    pub node_names: Vec<String>,
}

impl Netlist {
    /// Number of nodes including ground.
    pub fn n_nodes(&self) -> usize {
        self.node_names.len()
    }

    /// Number of voltage sources (MNA branch variables).
    pub fn n_vsources(&self) -> usize {
        self.elements
            .iter()
            .filter(|e| matches!(e, Element::VoltageSource { .. }))
            .count()
    }

    /// Node id by name, if present.
    pub fn node(&self, name: &str) -> Option<usize> {
        let name = normalize_node(name);
        self.node_names.iter().position(|n| *n == name)
    }
}

fn normalize_node(name: &str) -> String {
    let lower = name.to_ascii_lowercase();
    if lower == "gnd" {
        "0".to_string()
    } else {
        lower
    }
}

/// Parse a SPICE-ish value with suffix (`1k`, `2.2u`, `3meg`, `10`).
pub fn parse_value(tok: &str) -> anyhow::Result<f64> {
    let t = tok.trim().to_ascii_lowercase();
    let (num, mult) = if let Some(p) = t.strip_suffix("meg") {
        (p, 1e6)
    } else if let Some(p) = t.strip_suffix('k') {
        (p, 1e3)
    } else if let Some(p) = t.strip_suffix('m') {
        (p, 1e-3)
    } else if let Some(p) = t.strip_suffix('u') {
        (p, 1e-6)
    } else if let Some(p) = t.strip_suffix('n') {
        (p, 1e-9)
    } else if let Some(p) = t.strip_suffix('p') {
        (p, 1e-12)
    } else if let Some(p) = t.strip_suffix('f') {
        (p, 1e-15)
    } else if let Some(p) = t.strip_suffix('g') {
        (p, 1e9)
    } else {
        (t.as_str(), 1.0)
    };
    num.parse::<f64>()
        .map(|v| v * mult)
        .map_err(|_| anyhow::anyhow!("bad value {tok}"))
}

/// Parse a netlist from text.
pub fn parse_netlist(text: &str) -> anyhow::Result<Netlist> {
    let mut node_ids: HashMap<String, usize> = HashMap::new();
    let mut node_names: Vec<String> = Vec::new();
    // ground is always id 0
    node_ids.insert("0".into(), 0);
    node_names.push("0".into());

    let intern = |name: &str, ids: &mut HashMap<String, usize>, names: &mut Vec<String>| {
        let key = normalize_node(name);
        *ids.entry(key.clone()).or_insert_with(|| {
            names.push(key);
            names.len() - 1
        })
    };

    let mut elements = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split(['*', ';']).next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        let card = toks[0].to_ascii_lowercase();
        if card.starts_with('.') {
            if card == ".end" {
                break;
            }
            continue; // directives ignored in this subset
        }
        let err = |m: &str| anyhow::anyhow!("line {}: {m}: {line}", lineno + 1);
        let kind = card.chars().next().unwrap();
        match kind {
            'r' | 'c' | 'i' | 'v' => {
                if toks.len() < 4 {
                    return Err(err("expected: X a b value"));
                }
                let a = intern(toks[1], &mut node_ids, &mut node_names);
                let b = intern(toks[2], &mut node_ids, &mut node_names);
                let v = parse_value(toks[3])?;
                elements.push(match kind {
                    'r' => {
                        anyhow::ensure!(v > 0.0, err("resistance must be positive"));
                        Element::Resistor { a, b, ohms: v }
                    }
                    'c' => Element::Capacitor { a, b, farads: v },
                    'i' => Element::CurrentSource { a, b, amps: v },
                    _ => Element::VoltageSource { a, b, volts: v },
                });
            }
            'd' => {
                if toks.len() < 3 {
                    return Err(err("expected: D a b [is=..] [n=..]"));
                }
                let a = intern(toks[1], &mut node_ids, &mut node_names);
                let b = intern(toks[2], &mut node_ids, &mut node_names);
                let mut isat = 1e-14;
                let mut nvt = 0.02585;
                for t in &toks[3..] {
                    let tl = t.to_ascii_lowercase();
                    if let Some(v) = tl.strip_prefix("is=") {
                        isat = parse_value(v)?;
                    } else if let Some(v) = tl.strip_prefix("n=") {
                        nvt = 0.02585 * parse_value(v)?;
                    }
                }
                elements.push(Element::Diode { a, b, isat, nvt });
            }
            'g' => {
                if toks.len() < 6 {
                    return Err(err("expected: G a b c d gm"));
                }
                let a = intern(toks[1], &mut node_ids, &mut node_names);
                let b = intern(toks[2], &mut node_ids, &mut node_names);
                let c = intern(toks[3], &mut node_ids, &mut node_names);
                let d = intern(toks[4], &mut node_ids, &mut node_names);
                elements.push(Element::Vccs {
                    a,
                    b,
                    c,
                    d,
                    gm: parse_value(toks[5])?,
                });
            }
            _ => return Err(err("unknown card")),
        }
    }
    Ok(Netlist {
        elements,
        node_names,
    })
}

/// Programmatic builder: an `n`-stage RC ladder driven by a step source —
/// the classic SPICE benchmark topology (also used by the end-to-end
/// example).
pub fn rc_ladder(stages: usize, r: f64, c: f64, vin: f64) -> Netlist {
    let mut text = String::new();
    text.push_str(&format!("V1 in 0 {vin}\n"));
    let mut prev = "in".to_string();
    for i in 0..stages {
        let node = format!("n{i}");
        text.push_str(&format!("R{i} {prev} {node} {r}\n"));
        text.push_str(&format!("C{i} {node} 0 {c}\n"));
        prev = node;
    }
    parse_netlist(&text).expect("rc_ladder is well-formed")
}

/// Programmatic builder: a grid power network with diode clamps at random
/// nodes — a nonlinear workload with a big, sparse Jacobian.
pub fn diode_grid(nx: usize, ny: usize, vdd: f64, n_diodes: usize, seed: u64) -> Netlist {
    use crate::util::Rng;
    let mut rng = Rng::new(seed);
    let mut text = String::new();
    text.push_str(&format!("V1 vdd 0 {vdd}\n"));
    let node = |x: usize, y: usize| format!("g{x}_{y}");
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                text.push_str(&format!(
                    "Rh{x}_{y} {} {} {}\n",
                    node(x, y),
                    node(x + 1, y),
                    1.0 + rng.f64()
                ));
            }
            if y + 1 < ny {
                text.push_str(&format!(
                    "Rv{x}_{y} {} {} {}\n",
                    node(x, y),
                    node(x, y + 1),
                    1.0 + rng.f64()
                ));
            }
            // weak leak to ground keeps the matrix nonsingular
            text.push_str(&format!("Rl{x}_{y} {} 0 1e5\n", node(x, y)));
            // node decap: gives the transient real dynamics
            text.push_str(&format!("Cd{x}_{y} {} 0 1n\n", node(x, y)));
        }
    }
    // feed corners from vdd
    text.push_str(&format!("Rf0 vdd {} 0.1\n", node(0, 0)));
    text.push_str(&format!("Rf1 vdd {} 0.1\n", node(nx - 1, ny - 1)));
    for i in 0..n_diodes {
        let x = rng.below(nx);
        let y = rng.below(ny);
        text.push_str(&format!("Dd{i} {} 0 is=1e-12\n", node(x, y)));
    }
    parse_netlist(&text).expect("diode_grid is well-formed")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_values_with_suffixes() {
        assert_eq!(parse_value("1k").unwrap(), 1e3);
        assert!((parse_value("2.5u").unwrap() - 2.5e-6).abs() < 1e-18);
        assert_eq!(parse_value("3meg").unwrap(), 3e6);
        assert_eq!(parse_value("10").unwrap(), 10.0);
        assert!(parse_value("abc").is_err());
    }

    #[test]
    fn parse_basic_netlist() {
        let nl = parse_netlist(
            "* voltage divider\n\
             V1 in 0 5\n\
             R1 in out 1k\n\
             R2 out 0 1k ; load\n\
             .end\n",
        )
        .unwrap();
        assert_eq!(nl.elements.len(), 3);
        assert_eq!(nl.n_nodes(), 3);
        assert_eq!(nl.n_vsources(), 1);
        assert!(nl.node("out").is_some());
        assert_eq!(nl.node("gnd"), Some(0));
    }

    #[test]
    fn parse_diode_params() {
        let nl = parse_netlist("D1 a 0 is=1e-12 n=2\n").unwrap();
        match &nl.elements[0] {
            Element::Diode { isat, nvt, .. } => {
                assert_eq!(*isat, 1e-12);
                assert!((nvt - 0.0517).abs() < 1e-4);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn builders_are_well_formed() {
        let rc = rc_ladder(10, 1e3, 1e-6, 5.0);
        assert_eq!(rc.n_vsources(), 1);
        assert_eq!(rc.n_nodes(), 12); // gnd + in + 10 stages
        let dg = diode_grid(4, 4, 1.8, 3, 1);
        assert!(dg.n_nodes() > 16);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_netlist("R1 a b\n").is_err());
        assert!(parse_netlist("X1 a b 5\n").is_err());
        assert!(parse_netlist("R1 a b -5\n").is_err());
    }
}
