//! Backward-Euler transient analysis over the GLU solver.
//!
//! The SPICE inner loop the paper optimizes: at each time step, Newton
//! iterations restamp the Jacobian *values* (companion models move, diode
//! operating points move) while the *pattern* is fixed, so the solver's
//! preprocessing + symbolic state (the expensive CPU phases of Fig. 5) are
//! computed exactly once for the whole simulation and only the numeric
//! kernel reruns — this is where GLU3.0's fast refactorization pays off.

use super::mna::MnaSystem;
use super::netlist::Netlist;
use crate::coordinator::nr::NonlinearSystem;
use crate::coordinator::pool::{Checkout, SolverPool};
use crate::glu::GluOptions;

/// Transient options.
#[derive(Debug, Clone)]
pub struct TranOptions {
    pub dt: f64,
    pub steps: usize,
    pub nr_abstol: f64,
    pub nr_max_iters: usize,
    pub glu: GluOptions,
}

impl Default for TranOptions {
    fn default() -> Self {
        TranOptions {
            dt: 1e-6,
            steps: 100,
            nr_abstol: 1e-9,
            nr_max_iters: 50,
            glu: GluOptions::default(),
        }
    }
}

/// Transient result: the full waveform matrix plus solver statistics.
#[derive(Debug, Clone)]
pub struct TranResult {
    /// Time points (`steps + 1` including t = 0).
    pub times: Vec<f64>,
    /// `x` per time point (node voltages + branch currents).
    pub waveforms: Vec<Vec<f64>>,
    /// Total NR iterations across all steps.
    pub nr_iterations: usize,
    /// Total numeric refactorizations (== NR iterations; symbolic reused).
    pub refactorizations: usize,
    /// Sum of numeric-kernel time, ms (simulated-GPU kernel ms when the
    /// GPU engine is configured).
    pub numeric_ms_total: f64,
    /// One-time CPU preprocessing + symbolic + levelization time, ms
    /// (0 when the simulation ran against an already-warm [`SolverPool`]
    /// and never factored).
    pub cpu_ms_once: f64,
}

impl TranResult {
    /// Waveform of one unknown (node index - 1, or branch index).
    pub fn trace(&self, idx: usize) -> Vec<f64> {
        self.waveforms.iter().map(|x| x[idx]).collect()
    }
}

/// Run a backward-Euler transient from the DC operating point `x0` with a
/// private, single-pattern pool. See [`transient_in`] to share a
/// [`SolverPool`] across simulations (Monte-Carlo corners, concurrent
/// sessions): the pattern cache then carries the symbolic state from one
/// run to the next and even the first Newton solve refactors.
pub fn transient(netlist: &Netlist, x0: &[f64], opts: &TranOptions) -> anyhow::Result<TranResult> {
    let pool = SolverPool::with_config(opts.glu.clone(), 1, 1);
    transient_in(netlist, x0, opts, &pool)
}

/// Run a backward-Euler transient, solving every Newton step through
/// `pool`. The Jacobian pattern is fixed for the whole simulation, so the
/// pool factors at most once (not at all when already warm) and every other
/// solve takes the numeric-only refactor fast path.
pub fn transient_in(
    netlist: &Netlist,
    x0: &[f64],
    opts: &TranOptions,
    pool: &SolverPool,
) -> anyhow::Result<TranResult> {
    let mut sys = MnaSystem::dc(netlist.clone());
    sys.dt = Some(opts.dt);
    sys.x_prev = x0.to_vec();
    let dim = sys.dim();
    anyhow::ensure!(x0.len() == dim, "x0 dimension mismatch");

    let mut x = x0.to_vec();
    let mut cpu_ms_once = 0.0f64;
    let mut numeric_ms_total = 0.0f64;
    let mut nr_iterations = 0usize;
    let mut refactorizations = 0usize;

    let mut times = vec![0.0];
    let mut waveforms = vec![x.clone()];

    for step in 0..opts.steps {
        sys.x_prev = x.clone();
        // Newton loop for this time point.
        let mut converged = false;
        for _it in 0..opts.nr_max_iters {
            let f = sys.residual(&x);
            let norm = f.iter().map(|v| v.abs()).fold(0.0, f64::max);
            if norm < opts.nr_abstol {
                converged = true;
                break;
            }
            let j = sys.jacobian(&x);
            let mut guard = pool.checkout(&j)?;
            if guard.outcome() == Checkout::Factored {
                // CPU cost (preprocess + symbolic + levelization) of each
                // factorization this simulation paid — normally exactly one,
                // but accumulated in case a shared pool evicted the pattern
                // mid-run and it had to be re-analyzed.
                cpu_ms_once += guard.stats().cpu_ms();
            }
            refactorizations += 1;
            numeric_ms_total += guard.stats().numeric_ms;
            let dx = guard.solve(&f)?;
            drop(guard);
            for (xi, di) in x.iter_mut().zip(&dx) {
                *xi -= di;
            }
            nr_iterations += 1;
        }
        anyhow::ensure!(converged, "NR failed to converge at step {step}");
        times.push((step + 1) as f64 * opts.dt);
        waveforms.push(x.clone());
    }

    Ok(TranResult {
        times,
        waveforms,
        nr_iterations,
        refactorizations,
        numeric_ms_total,
        cpu_ms_once,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::netlist::parse_netlist;
    use crate::coordinator::nr::{newton_raphson, NrOptions};

    #[test]
    fn rc_step_response_matches_analytic() {
        // Single RC: v(t) = V (1 - exp(-t/RC)), R = 1k, C = 1u, tau = 1ms.
        let nl = parse_netlist(
            "V1 in 0 1\n\
             R1 in out 1k\n\
             C1 out 0 1u\n",
        )
        .unwrap();
        let sys = MnaSystem::dc(nl.clone());
        let dim = sys.dim();
        // start from v=0 everywhere but with the source consistent: use DC
        // solution with capacitor voltage forced by x0 = 0 (cap initially
        // discharged, BE companion handles it).
        let mut x0 = vec![0.0; dim];
        // the source branch equation needs v(in)=1 at t=0+; solve one NR on
        // the resistive network with the cap as short to ground at t=0 is
        // approximated well enough by starting transient from 0 directly.
        x0[nl.node("in").unwrap() - 1] = 1.0;
        let opts = TranOptions {
            dt: 5e-5, // tau/20
            steps: 60, // 3 tau
            ..Default::default()
        };
        let res = transient(&nl, &x0, &opts).unwrap();
        let out = nl.node("out").unwrap() - 1;
        let trace = res.trace(out);
        let tau = 1e-3;
        for (k, &t) in res.times.iter().enumerate().skip(5) {
            let want = 1.0 - (-t / tau).exp();
            // BE is first-order: a few percent at dt = tau/20
            assert!(
                (trace[k] - want).abs() < 0.05,
                "t={t}: {} vs {}",
                trace[k],
                want
            );
        }
        // monotone rise toward 1.0
        assert!(trace.last().unwrap() > &0.9);
        // one refactor per NR solve (the initial factor covers step 0/it 0)
        assert_eq!(res.refactorizations, res.nr_iterations);
    }

    #[test]
    fn diode_grid_transient_runs() {
        let nl = crate::circuit::netlist::diode_grid(4, 4, 1.8, 2, 3);
        let sys = MnaSystem::dc(nl.clone());
        let dc = newton_raphson(
            &sys,
            &vec![0.0; sys.dim()],
            &NrOptions {
                max_iters: 100,
                damping: 0.7,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(dc.converged);
        let res = transient(
            &nl,
            &dc.x,
            &TranOptions {
                dt: 1e-7,
                steps: 10,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(res.waveforms.len(), 11);
        // purely resistive+diode grid at steady state: waveform flat
        let first = &res.waveforms[0];
        let last = res.waveforms.last().unwrap();
        for (p, q) in first.iter().zip(last) {
            assert!((p - q).abs() < 1e-6);
        }
    }

    #[test]
    fn transient_with_parallel_engine_matches_default() {
        use crate::glu::{GluOptions, NumericEngine};

        let nl = parse_netlist(
            "V1 in 0 1\n\
             R1 in out 1k\n\
             C1 out 0 1u\n",
        )
        .unwrap();
        let sys = MnaSystem::dc(nl.clone());
        let dim = sys.dim();
        let mut x0 = vec![0.0; dim];
        x0[nl.node("in").unwrap() - 1] = 1.0;
        let opts = TranOptions {
            dt: 1e-4,
            steps: 8,
            ..Default::default()
        };
        let base = transient(&nl, &x0, &opts).unwrap();

        // Thread plumbing: TranOptions -> GluOptions -> SolverPool ->
        // pool-backed engine, for the whole Newton/transient loop.
        let par_opts = TranOptions {
            glu: GluOptions {
                engine: NumericEngine::ParallelRightLooking { threads: 2 },
                ..Default::default()
            },
            ..opts
        };
        let par = transient(&nl, &x0, &par_opts).unwrap();
        // one refactor per executed NR solve, whatever the engine
        assert_eq!(par.refactorizations, par.nr_iterations);
        assert_eq!(par.waveforms.len(), base.waveforms.len());
        for (a, b) in base.waveforms.iter().zip(&par.waveforms) {
            for (p, q) in a.iter().zip(b) {
                assert!((p - q).abs() < 1e-9 * (1.0 + q.abs()));
            }
        }
    }

    #[test]
    fn warm_pool_transient_never_factors() {
        use crate::coordinator::pool::SolverPool;
        use crate::glu::GluOptions;

        let nl = parse_netlist(
            "V1 in 0 1\n\
             R1 in out 1k\n\
             C1 out 0 1u\n",
        )
        .unwrap();
        let sys = MnaSystem::dc(nl.clone());
        let dim = sys.dim();
        let mut x0 = vec![0.0; dim];
        x0[nl.node("in").unwrap() - 1] = 1.0;
        let opts = TranOptions {
            dt: 1e-4,
            steps: 5,
            ..Default::default()
        };
        let pool = SolverPool::new(GluOptions::default());

        let r1 = transient_in(&nl, &x0, &opts, &pool).unwrap();
        assert!(r1.cpu_ms_once >= 0.0);
        assert_eq!(pool.stats().factors, 1);

        // Second run with the warm pool: zero factorizations, all hits.
        let r2 = transient_in(&nl, &x0, &opts, &pool).unwrap();
        assert_eq!(pool.stats().factors, 1);
        assert_eq!(r2.cpu_ms_once, 0.0);
        assert_eq!(
            pool.stats().hits as usize,
            r1.nr_iterations + r2.nr_iterations - 1
        );
        // identical waveforms
        for (a, b) in r1.waveforms.iter().zip(&r2.waveforms) {
            for (p, q) in a.iter().zip(b) {
                assert!((p - q).abs() < 1e-12);
            }
        }
    }
}
