//! Modified nodal analysis: stamping the netlist into `J x = ...` systems.
//!
//! Unknown vector `x` = node voltages (ground eliminated) followed by one
//! branch current per voltage source. The Jacobian sparsity pattern is
//! *identical on every call* — nonlinear elements (diodes) stamp a
//! conductance whose value changes but whose position does not — which is
//! what lets the GLU solver reuse its symbolic state across all NR
//! iterations and time steps.

use super::netlist::{Element, Netlist};
use crate::coordinator::nr::NonlinearSystem;
use crate::sparse::{Coo, Csc};

/// Minimum conductance to ground on every node (SPICE's GMIN).
pub const GMIN: f64 = 1e-12;

/// An MNA view of a netlist, optionally with capacitor companion models
/// (backward Euler) for transient analysis.
#[derive(Debug, Clone)]
pub struct MnaSystem {
    pub netlist: Netlist,
    /// Backward-Euler step; `None` for DC (capacitors open).
    pub dt: Option<f64>,
    /// Previous-step solution for companion models (transient only).
    pub x_prev: Vec<f64>,
}

impl MnaSystem {
    /// DC system (capacitors open-circuit).
    pub fn dc(netlist: Netlist) -> Self {
        let dim = netlist.n_nodes() - 1 + netlist.n_vsources();
        MnaSystem {
            netlist,
            dt: None,
            x_prev: vec![0.0; dim],
        }
    }

    /// Index of node `n` in `x` (ground has no index).
    fn ni(&self, n: usize) -> Option<usize> {
        (n > 0).then(|| n - 1)
    }

    /// Voltage of node `n` under `x`.
    fn v(&self, x: &[f64], n: usize) -> f64 {
        self.ni(n).map_or(0.0, |i| x[i])
    }

    /// Diode current and conductance with overflow-safe linearization.
    fn diode_iv(vd: f64, isat: f64, nvt: f64) -> (f64, f64) {
        let t = (vd / nvt).min(40.0);
        let e = t.exp();
        let i = isat * (e - 1.0);
        let g = isat / nvt * e;
        if vd / nvt > 40.0 {
            // linear extension beyond the clamp keeps NR stable
            (i + g * (vd - 40.0 * nvt), g)
        } else {
            (i, g)
        }
    }
}

impl NonlinearSystem for MnaSystem {
    fn dim(&self) -> usize {
        self.netlist.n_nodes() - 1 + self.netlist.n_vsources()
    }

    /// KCL residual at every non-ground node + branch equations.
    fn residual(&self, x: &[f64]) -> Vec<f64> {
        let nn = self.netlist.n_nodes() - 1;
        let mut f = vec![0.0; self.dim()];
        // GMIN leak
        for (i, fi) in f.iter_mut().take(nn).enumerate() {
            *fi += GMIN * x[i];
        }
        let mut vs_idx = 0usize;
        for e in &self.netlist.elements {
            match *e {
                Element::Resistor { a, b, ohms } => {
                    let i = (self.v(x, a) - self.v(x, b)) / ohms;
                    if let Some(ia) = self.ni(a) {
                        f[ia] += i;
                    }
                    if let Some(ib) = self.ni(b) {
                        f[ib] -= i;
                    }
                }
                Element::Capacitor { a, b, farads } => {
                    if let Some(dt) = self.dt {
                        let g = farads / dt;
                        let vd = self.v(x, a) - self.v(x, b);
                        let vd_prev = self.v(&self.x_prev, a) - self.v(&self.x_prev, b);
                        let i = g * (vd - vd_prev);
                        if let Some(ia) = self.ni(a) {
                            f[ia] += i;
                        }
                        if let Some(ib) = self.ni(b) {
                            f[ib] -= i;
                        }
                    }
                }
                Element::CurrentSource { a, b, amps } => {
                    if let Some(ia) = self.ni(a) {
                        f[ia] += amps;
                    }
                    if let Some(ib) = self.ni(b) {
                        f[ib] -= amps;
                    }
                }
                Element::VoltageSource { a, b, volts } => {
                    let ij = x[nn + vs_idx];
                    if let Some(ia) = self.ni(a) {
                        f[ia] += ij;
                    }
                    if let Some(ib) = self.ni(b) {
                        f[ib] -= ij;
                    }
                    f[nn + vs_idx] = self.v(x, a) - self.v(x, b) - volts;
                    vs_idx += 1;
                }
                Element::Diode { a, b, isat, nvt } => {
                    let vd = self.v(x, a) - self.v(x, b);
                    let (i, _) = Self::diode_iv(vd, isat, nvt);
                    if let Some(ia) = self.ni(a) {
                        f[ia] += i;
                    }
                    if let Some(ib) = self.ni(b) {
                        f[ib] -= i;
                    }
                }
                Element::Vccs { a, b, c, d, gm } => {
                    let i = gm * (self.v(x, c) - self.v(x, d));
                    if let Some(ia) = self.ni(a) {
                        f[ia] += i;
                    }
                    if let Some(ib) = self.ni(b) {
                        f[ib] -= i;
                    }
                }
            }
        }
        f
    }

    /// Jacobian with a call-invariant sparsity pattern.
    fn jacobian(&self, x: &[f64]) -> Csc {
        let nn = self.netlist.n_nodes() - 1;
        let dim = self.dim();
        let mut coo = Coo::new(dim, dim);
        // GMIN keeps every node diagonal structurally present.
        for i in 0..nn {
            coo.push(i, i, GMIN);
        }
        let stamp_g = |coo: &mut Coo, a: Option<usize>, b: Option<usize>, g: f64| {
            if let Some(ia) = a {
                coo.push(ia, ia, g);
            }
            if let Some(ib) = b {
                coo.push(ib, ib, g);
            }
            if let (Some(ia), Some(ib)) = (a, b) {
                coo.push(ia, ib, -g);
                coo.push(ib, ia, -g);
            }
        };
        let mut vs_idx = 0usize;
        for e in &self.netlist.elements {
            match *e {
                Element::Resistor { a, b, ohms } => {
                    stamp_g(&mut coo, self.ni(a), self.ni(b), 1.0 / ohms);
                }
                Element::Capacitor { a, b, farads } => {
                    // DC: stamp 0-valued entries so the pattern is identical
                    // between DC and transient runs of the same netlist.
                    let g = self.dt.map_or(0.0, |dt| farads / dt);
                    stamp_g(&mut coo, self.ni(a), self.ni(b), g);
                }
                Element::CurrentSource { .. } => {}
                Element::VoltageSource { a, b, .. } => {
                    let j = nn + vs_idx;
                    if let Some(ia) = self.ni(a) {
                        coo.push(ia, j, 1.0);
                        coo.push(j, ia, 1.0);
                    }
                    if let Some(ib) = self.ni(b) {
                        coo.push(ib, j, -1.0);
                        coo.push(j, ib, -1.0);
                    }
                    // No structural diagonal on the branch row: its pivot
                    // would be numerically zero. The MC64 matching step
                    // pairs the branch row with one of its ±1 entries
                    // instead (static pivoting, as real GLU deployments do
                    // for MNA systems).
                    vs_idx += 1;
                }
                Element::Diode { a, b, isat, nvt } => {
                    let vd = self.v(x, a) - self.v(x, b);
                    let (_, g) = Self::diode_iv(vd, isat, nvt);
                    stamp_g(&mut coo, self.ni(a), self.ni(b), g);
                }
                Element::Vccs { a, b, c, d, gm } => {
                    for (row, sign) in [(self.ni(a), 1.0), (self.ni(b), -1.0)] {
                        if let Some(r) = row {
                            if let Some(ic) = self.ni(c) {
                                coo.push(r, ic, sign * gm);
                            }
                            if let Some(id) = self.ni(d) {
                                coo.push(r, id, -sign * gm);
                            }
                        }
                    }
                }
            }
        }
        coo.to_csc()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::netlist::parse_netlist;
    use crate::coordinator::nr::{newton_raphson, NrOptions};

    #[test]
    fn voltage_divider_dc() {
        let nl = parse_netlist(
            "V1 in 0 6\n\
             R1 in out 1k\n\
             R2 out 0 2k\n",
        )
        .unwrap();
        let sys = MnaSystem::dc(nl.clone());
        let res = newton_raphson(&sys, &vec![0.0; sys.dim()], &NrOptions::default()).unwrap();
        assert!(res.converged);
        let out = nl.node("out").unwrap() - 1;
        assert!((res.x[out] - 4.0).abs() < 1e-6, "v(out) = {}", res.x[out]);
        // vsource current = 6V / 3k = 2 mA (flowing in->0 through branch)
        let i = res.x[sys.dim() - 1];
        assert!((i + 2e-3).abs() < 1e-7, "i = {i}");
    }

    #[test]
    fn diode_clamp_dc() {
        // 5V through 1k into a diode: v(d) ≈ 0.6-0.8V forward drop.
        let nl = parse_netlist(
            "V1 in 0 5\n\
             R1 in d 1k\n\
             D1 d 0 is=1e-14\n",
        )
        .unwrap();
        let sys = MnaSystem::dc(nl.clone());
        let res = newton_raphson(
            &sys,
            &vec![0.0; sys.dim()],
            &NrOptions {
                max_iters: 200,
                damping: 0.5,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(res.converged, "norms {:?}", &res.residual_norms[..5.min(res.residual_norms.len())]);
        let vd = res.x[nl.node("d").unwrap() - 1];
        assert!((0.5..0.9).contains(&vd), "diode drop {vd}");
    }

    #[test]
    fn jacobian_pattern_invariant() {
        let nl = super::super::netlist::diode_grid(5, 5, 1.8, 4, 2);
        let sys = MnaSystem::dc(nl);
        let j0 = sys.jacobian(&vec![0.0; sys.dim()]);
        let x1: Vec<f64> = (0..sys.dim()).map(|i| (i % 3) as f64 * 0.3).collect();
        let j1 = sys.jacobian(&x1);
        assert_eq!(j0.colptr(), j1.colptr());
        assert_eq!(j0.rowidx(), j1.rowidx());
        // but values differ (diode operating point moved)
        assert_ne!(j0.values(), j1.values());
    }

    #[test]
    fn vccs_stamps() {
        // V1 sets v(c)=1; G converts it to 2A into node out through 1 ohm.
        let nl = parse_netlist(
            "V1 c 0 1\n\
             R1 out 0 1\n\
             G1 0 out c 0 2\n",
        )
        .unwrap();
        let sys = MnaSystem::dc(nl.clone());
        let res = newton_raphson(&sys, &vec![0.0; sys.dim()], &NrOptions::default()).unwrap();
        assert!(res.converged);
        let v_out = res.x[nl.node("out").unwrap() - 1];
        assert!((v_out - 2.0).abs() < 1e-6, "v(out) = {v_out}");
    }
}
