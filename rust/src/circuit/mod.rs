//! SPICE-lite circuit simulator — the end-to-end workload that motivates
//! the paper ("for circuit simulation application such as the widely used
//! SPICE program, the core of the computing is to solve Ax = b").
//!
//! Modified nodal analysis over a [`netlist`], DC operating point via
//! Newton–Raphson ([`crate::coordinator::nr`]) and backward-Euler transient
//! analysis — all solving through [`crate::glu::GluSolver`], with the
//! symbolic state reused across every NR iteration and time step exactly as
//! the paper's flow (Fig. 5) intends.

pub mod mna;
pub mod netlist;
pub mod transient;

pub use mna::MnaSystem;
pub use netlist::{parse_netlist, Element, Netlist};
pub use transient::{transient, transient_in, TranOptions, TranResult};
