//! Small shared utilities: deterministic PRNG, timers, stats helpers.
//!
//! The build environment is offline (no `rand`, no `criterion`), so the crate
//! carries its own tiny, well-tested PRNG and measurement helpers.

pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
