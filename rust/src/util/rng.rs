//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through `splitmix64`, the standard construction from
//! Blackman & Vigna. Deterministic across platforms so matrix generators and
//! property tests are reproducible from a seed recorded in the bench logs.

/// A `xoshiro256**` PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough reduction; bias is
        // negligible for our n << 2^64 use-cases, but we keep the rejection
        // loop for exactness since generators feed property tests.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Floyd's algorithm: O(k) expected when k << n.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        self.shuffle(&mut out);
        out
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_uniformity_rough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 8];
        let trials = 80_000;
        for _ in 0..trials {
            counts[r.below(8)] += 1;
        }
        let expect = trials / 8;
        for &c in &counts {
            assert!((c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64);
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let n = r.range(5, 40);
            let k = r.range(1, n + 1);
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments_rough() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
