//! Aggregate statistics used by the bench tables (the paper reports both
//! arithmetic and geometric means of speedup ratios).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn arith_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean over strictly-positive values; 0.0 for an empty slice.
pub fn geo_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Relative L∞ error between two vectors, `max |a-b| / (1 + |b|)`.
pub fn rel_linf(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / (1.0 + y.abs()))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_basic() {
        assert_eq!(arith_mean(&[1.0, 3.0]), 2.0);
        assert!((geo_mean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(arith_mean(&[]), 0.0);
        assert_eq!(geo_mean(&[]), 0.0);
    }

    #[test]
    fn geo_mean_matches_paper_style() {
        // geometric mean of {2, 8} is 4; of {10, 1000} is 100.
        assert!((geo_mean(&[10.0, 1000.0]) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn rel_linf_zero_for_equal() {
        let v = vec![1.0, -2.0, 3.5];
        assert_eq!(rel_linf(&v, &v), 0.0);
        assert!(rel_linf(&[1.0], &[1.1]) > 0.0);
    }
}
