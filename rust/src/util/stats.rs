//! Aggregate statistics used by the bench tables (the paper reports both
//! arithmetic and geometric means of speedup ratios) and by the solver
//! service's latency accounting (p50/p99 per-request solve times).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn arith_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean over strictly-positive values; 0.0 for an empty slice.
pub fn geo_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Nearest-rank percentile of an *unsorted* sample slice; `p` in `[0, 100]`.
/// Returns 0.0 for an empty slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// A streaming latency recorder: keeps a bounded ring of recent per-request
/// samples (ms) and summarizes them as count / mean / p50 / p99 — the
/// service-facing numbers. Bounding the window keeps a long-lived serving
/// pool at constant memory no matter how many requests it handles;
/// [`LatencyRecorder::count`] still reports the all-time total.
#[derive(Debug, Clone)]
pub struct LatencyRecorder {
    /// Sample window (ring once `cap` is reached).
    samples: Vec<f64>,
    /// Next ring slot to overwrite once full.
    next: usize,
    /// All-time number of recorded samples.
    total: usize,
    cap: usize,
}

/// Default sample-window size.
const LATENCY_WINDOW: usize = 4096;

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::with_window(LATENCY_WINDOW)
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// A recorder keeping at most `window` recent samples (`window >= 1`).
    pub fn with_window(window: usize) -> Self {
        assert!(window >= 1);
        LatencyRecorder {
            samples: Vec::new(),
            next: 0,
            total: 0,
            cap: window,
        }
    }

    /// Record one request latency in milliseconds. Non-finite samples are
    /// dropped: `total_cmp` sorts NaN after every finite value, so a single
    /// NaN admitted to the window would poison `p99_ms` (and `mean_ms`)
    /// for as long as it stays resident.
    pub fn record(&mut self, ms: f64) {
        if !ms.is_finite() {
            return;
        }
        self.total += 1;
        if self.samples.len() < self.cap {
            self.samples.push(ms);
        } else {
            self.samples[self.next] = ms;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Merge another recorder (shard aggregation): its window samples enter
    /// this window, its all-time total carries over.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        for &s in &other.samples {
            self.record(s);
        }
        self.total += other.total - other.samples.len();
    }

    /// All-time number of recorded samples.
    pub fn count(&self) -> usize {
        self.total
    }

    /// Mean over the current window, ms.
    pub fn mean_ms(&self) -> f64 {
        arith_mean(&self.samples)
    }

    /// Median over the current window, ms.
    pub fn p50_ms(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    /// 99th percentile over the current window, ms.
    pub fn p99_ms(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    /// 99.9th percentile over the current window, ms — the serving tier's
    /// tail-latency gate. With fewer than 1000 window samples the nearest
    /// rank is the window maximum, which is the conservative reading a
    /// tail gate wants.
    pub fn p999_ms(&self) -> f64 {
        percentile(&self.samples, 99.9)
    }

    /// The sample window (insertion order until the ring wraps).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// A ring-buffered queue-depth gauge: records the admission queue's depth
/// at every transition (submit / dispatch), keeping the instantaneous
/// value, the all-time high-water mark, and a bounded window of recent
/// observations for mean/percentile summaries. Like [`LatencyRecorder`],
/// the ring keeps a long-lived server at constant memory.
#[derive(Debug, Clone)]
pub struct DepthGauge {
    /// Observation window (ring once `cap` is reached).
    samples: Vec<f64>,
    /// Next ring slot to overwrite once full.
    next: usize,
    /// All-time number of observations.
    total: usize,
    cap: usize,
    /// Depth at the most recent observation.
    current: usize,
    /// All-time high-water mark (not windowed — a saturation spike must
    /// stay visible even after its samples rotate out).
    max: usize,
}

/// Default depth-observation window.
const DEPTH_WINDOW: usize = 4096;

impl Default for DepthGauge {
    fn default() -> Self {
        Self::with_window(DEPTH_WINDOW)
    }
}

impl DepthGauge {
    pub fn new() -> Self {
        Self::default()
    }

    /// A gauge keeping at most `window` recent observations (`window >= 1`).
    pub fn with_window(window: usize) -> Self {
        assert!(window >= 1);
        DepthGauge {
            samples: Vec::new(),
            next: 0,
            total: 0,
            cap: window,
            current: 0,
            max: 0,
        }
    }

    /// Record the queue depth after a transition.
    pub fn record(&mut self, depth: usize) {
        self.current = depth;
        self.max = self.max.max(depth);
        self.total += 1;
        let d = depth as f64;
        if self.samples.len() < self.cap {
            self.samples.push(d);
        } else {
            self.samples[self.next] = d;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Depth at the most recent observation.
    pub fn current(&self) -> usize {
        self.current
    }

    /// All-time high-water mark.
    pub fn max_depth(&self) -> usize {
        self.max
    }

    /// All-time number of observations.
    pub fn count(&self) -> usize {
        self.total
    }

    /// Mean depth over the current window.
    pub fn mean(&self) -> f64 {
        arith_mean(&self.samples)
    }

    /// 99th-percentile depth over the current window.
    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    /// The observation window (insertion order until the ring wraps).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Relative L∞ error between two vectors, `max |a-b| / (1 + |b|)`.
pub fn rel_linf(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / (1.0 + y.abs()))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_basic() {
        assert_eq!(arith_mean(&[1.0, 3.0]), 2.0);
        assert!((geo_mean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(arith_mean(&[]), 0.0);
        assert_eq!(geo_mean(&[]), 0.0);
    }

    #[test]
    fn geo_mean_matches_paper_style() {
        // geometric mean of {2, 8} is 4; of {10, 1000} is 100.
        assert!((geo_mean(&[10.0, 1000.0]) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // order-independent
        let mut rev = xs.clone();
        rev.reverse();
        assert_eq!(percentile(&rev, 50.0), 50.0);
    }

    #[test]
    fn latency_recorder_summary() {
        let mut r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 10);
        assert_eq!(r.p50_ms(), 5.0);
        assert_eq!(r.p99_ms(), 10.0);
        assert!((r.mean_ms() - 5.5).abs() < 1e-12);

        let mut other = LatencyRecorder::new();
        other.record(100.0);
        r.merge(&other);
        assert_eq!(r.count(), 11);
        assert_eq!(r.p99_ms(), 100.0);
    }

    #[test]
    fn latency_recorder_window_is_bounded() {
        let mut r = LatencyRecorder::with_window(4);
        for i in 1..=10 {
            r.record(i as f64);
        }
        // window holds the last 4 samples (7, 8, 9, 10); total is all-time
        assert_eq!(r.count(), 10);
        assert_eq!(r.samples().len(), 4);
        assert_eq!(r.p99_ms(), 10.0);
        assert!((r.mean_ms() - 8.5).abs() < 1e-12);

        // merging keeps totals and respects the receiver's window
        let mut big = LatencyRecorder::with_window(2);
        big.merge(&r);
        assert_eq!(big.count(), 10);
        assert_eq!(big.samples().len(), 2);
    }

    #[test]
    fn latency_recorder_rejects_non_finite() {
        let mut r = LatencyRecorder::new();
        r.record(1.0);
        r.record(f64::NAN);
        r.record(f64::INFINITY);
        r.record(f64::NEG_INFINITY);
        r.record(3.0);
        // Only the finite samples count — a NaN in the window would sort
        // last under total_cmp and be reported as the p99.
        assert_eq!(r.count(), 2);
        assert_eq!(r.samples(), &[1.0, 3.0]);
        assert_eq!(r.p99_ms(), 3.0);
        assert!((r.mean_ms() - 2.0).abs() < 1e-12);

        // merge stays coherent (window samples are always finite).
        let mut agg = LatencyRecorder::new();
        agg.merge(&r);
        assert_eq!(agg.count(), 2);
        assert_eq!(agg.p99_ms(), 3.0);
    }

    #[test]
    fn percentile_edge_cases() {
        // Empty: every percentile is 0.0 by definition.
        for p in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(percentile(&[], p), 0.0);
        }
        // Single sample: every percentile is that sample.
        for p in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(percentile(&[7.25], p), 7.25);
        }
        // All-equal samples: every percentile is the common value.
        let same = vec![3.5; 64];
        for p in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(percentile(&same, p), 3.5);
        }
    }

    #[test]
    fn p999_tracks_the_extreme_tail() {
        let mut r = LatencyRecorder::new();
        // 998 fast samples and two 500 ms outliers: p99 must not see the
        // outliers (nearest rank 990), p999 must (nearest rank >= 999).
        for _ in 0..998 {
            r.record(1.0);
        }
        r.record(500.0);
        r.record(500.0);
        assert_eq!(r.p99_ms(), 1.0);
        assert_eq!(r.p999_ms(), 500.0);
        // Below 1000 samples the p999 nearest rank is the window max —
        // the conservative tail reading.
        let mut small = LatencyRecorder::new();
        small.record(1.0);
        small.record(9.0);
        assert_eq!(small.p999_ms(), 9.0);
    }

    #[test]
    fn depth_gauge_tracks_current_max_and_window() {
        let mut g = DepthGauge::with_window(4);
        assert_eq!(g.current(), 0);
        assert_eq!(g.max_depth(), 0);
        assert_eq!(g.mean(), 0.0);
        for d in [1usize, 3, 9, 2, 2, 2] {
            g.record(d);
        }
        assert_eq!(g.current(), 2);
        assert_eq!(g.count(), 6);
        // The window holds the last 4 observations (9, 2, 2, 2)...
        assert_eq!(g.samples().len(), 4);
        assert!((g.mean() - 3.75).abs() < 1e-12);
        assert_eq!(g.p99(), 9.0);
        // ...and once the spike rotates out, the high-water mark persists.
        for _ in 0..8 {
            g.record(1);
        }
        assert_eq!(g.max_depth(), 9);
        assert_eq!(g.p99(), 1.0);
    }

    #[test]
    fn rel_linf_zero_for_equal() {
        let v = vec![1.0, -2.0, 3.5];
        assert_eq!(rel_linf(&v, &v), 0.0);
        assert!(rel_linf(&[1.0], &[1.1]) > 0.0);
    }
}
