//! Wall-clock measurement helpers (criterion is unavailable offline; the
//! bench harnesses in `rust/benches/` are built on these).

use std::time::{Duration, Instant};

/// A simple stopwatch accumulating named phases.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    phases: Vec<(String, Duration)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, record it under `name`, and return its value.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.phases.push((name.to_string(), t0.elapsed()));
        out
    }

    /// Total across all recorded phases.
    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }

    /// Duration of the (last-recorded) phase with this name, if any.
    pub fn get(&self, name: &str) -> Option<Duration> {
        self.phases
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
    }

    /// All recorded `(name, duration)` pairs in insertion order.
    pub fn phases(&self) -> &[(String, Duration)] {
        &self.phases
    }
}

/// Measure `f` repeatedly: `warmup` unrecorded runs, then `iters` recorded
/// runs; returns (min, median, mean) in seconds. The bench harness's
/// replacement for criterion's sampling.
pub fn measure<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> MeasureStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    MeasureStats::from_samples(samples)
}

/// Summary statistics over timing samples (seconds).
#[derive(Debug, Clone)]
pub struct MeasureStats {
    pub samples: Vec<f64>,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
}

impl MeasureStats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.total_cmp(b));
        let min = samples[0];
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        MeasureStats {
            samples,
            min,
            median,
            mean,
        }
    }

    /// Median in milliseconds — the headline number the tables print.
    pub fn median_ms(&self) -> f64 {
        self.median * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_records_phases() {
        let mut sw = Stopwatch::new();
        let x = sw.time("work", || (0..1000).sum::<usize>());
        assert_eq!(x, 499_500);
        assert!(sw.get("work").is_some());
        assert!(sw.get("missing").is_none());
        assert_eq!(sw.phases().len(), 1);
        assert!(sw.total() >= sw.get("work").unwrap());
    }

    #[test]
    fn measure_returns_ordered_stats() {
        let stats = measure(1, 9, || std::thread::sleep(Duration::from_micros(50)));
        assert_eq!(stats.samples.len(), 9);
        assert!(stats.min <= stats.median);
        assert!(stats.min > 0.0);
    }
}
