//! Coordinate-format sparse matrix: the assembly/interchange format.
//!
//! COO is what the Matrix Market reader and the circuit MNA stamper produce;
//! duplicate entries are summed on conversion (exactly the stamping semantics
//! circuit simulators rely on).

use super::csc::Csc;

/// A coordinate-format sparse matrix with `f64` values.
#[derive(Debug, Clone, PartialEq)]
pub struct Coo {
    nrows: usize,
    ncols: usize,
    /// `(row, col, value)` triples, in arbitrary order, duplicates allowed.
    entries: Vec<(usize, usize, f64)>,
}

impl Coo {
    /// An empty `nrows x ncols` matrix.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo {
            nrows,
            ncols,
            entries: Vec::new(),
        }
    }

    /// Build from triples, validating indices.
    pub fn from_entries(
        nrows: usize,
        ncols: usize,
        entries: Vec<(usize, usize, f64)>,
    ) -> anyhow::Result<Self> {
        for &(r, c, _) in &entries {
            anyhow::ensure!(
                r < nrows && c < ncols,
                "entry ({r},{c}) outside {nrows}x{ncols}"
            );
        }
        Ok(Coo {
            nrows,
            ncols,
            entries,
        })
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Number of stored triples (duplicates counted).
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    pub fn entries(&self) -> &[(usize, usize, f64)] {
        &self.entries
    }

    /// Add `v` at `(r, c)` (duplicates are summed at conversion time —
    /// MNA stamping semantics).
    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.nrows && c < self.ncols);
        self.entries.push((r, c, v));
    }

    /// Convert to CSC, summing duplicates and dropping exact zeros produced
    /// *only* by duplicate cancellation (explicit zero entries are kept:
    /// circuit matrices use them as structural placeholders).
    pub fn to_csc(&self) -> Csc {
        // Counting sort by column, then by row within column.
        let mut colcount = vec![0usize; self.ncols + 1];
        for &(_, c, _) in &self.entries {
            colcount[c + 1] += 1;
        }
        for c in 0..self.ncols {
            colcount[c + 1] += colcount[c];
        }
        let mut rows = vec![0usize; self.entries.len()];
        let mut vals = vec![0f64; self.entries.len()];
        let mut next = colcount.clone();
        for &(r, c, v) in &self.entries {
            let p = next[c];
            rows[p] = r;
            vals[p] = v;
            next[c] += 1;
        }
        // Sort within each column and merge duplicates.
        let mut colptr = vec![0usize; self.ncols + 1];
        let mut out_rows = Vec::with_capacity(self.entries.len());
        let mut out_vals = Vec::with_capacity(self.entries.len());
        for c in 0..self.ncols {
            let (s, e) = (colcount[c], colcount[c + 1]);
            let mut col: Vec<(usize, f64)> = rows[s..e]
                .iter()
                .copied()
                .zip(vals[s..e].iter().copied())
                .collect();
            col.sort_unstable_by_key(|&(r, _)| r);
            let mut i = 0;
            while i < col.len() {
                let r = col[i].0;
                let mut v = col[i].1;
                let mut j = i + 1;
                let mut merged = false;
                while j < col.len() && col[j].0 == r {
                    v += col[j].1;
                    j += 1;
                    merged = true;
                }
                // Keep explicit singleton zeros; drop only merged cancellations.
                if !(merged && v == 0.0) {
                    out_rows.push(r);
                    out_vals.push(v);
                }
                i = j;
            }
            colptr[c + 1] = out_rows.len();
        }
        Csc::from_raw_parts(self.nrows, self.ncols, colptr, out_rows, out_vals)
            .expect("COO->CSC produced invalid CSC")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_convert() {
        let mut a = Coo::new(3, 3);
        a.push(0, 0, 1.0);
        a.push(2, 1, 3.0);
        a.push(1, 1, 2.0);
        let csc = a.to_csc();
        assert_eq!(csc.nnz(), 3);
        assert_eq!(csc.get(0, 0), 1.0);
        assert_eq!(csc.get(1, 1), 2.0);
        assert_eq!(csc.get(2, 1), 3.0);
        assert_eq!(csc.get(2, 2), 0.0);
    }

    #[test]
    fn duplicates_are_summed() {
        let mut a = Coo::new(2, 2);
        a.push(0, 0, 1.0);
        a.push(0, 0, 2.5);
        let csc = a.to_csc();
        assert_eq!(csc.nnz(), 1);
        assert_eq!(csc.get(0, 0), 3.5);
    }

    #[test]
    fn duplicate_cancellation_dropped_but_explicit_zero_kept() {
        let mut a = Coo::new(2, 2);
        a.push(0, 0, 1.0);
        a.push(0, 0, -1.0);
        a.push(1, 1, 0.0); // explicit structural zero
        let csc = a.to_csc();
        assert_eq!(csc.nnz(), 1);
        assert!(csc.has_entry(1, 1));
        assert!(!csc.has_entry(0, 0));
    }

    #[test]
    fn rows_sorted_within_columns() {
        let mut a = Coo::new(4, 2);
        a.push(3, 0, 1.0);
        a.push(0, 0, 2.0);
        a.push(2, 0, 3.0);
        let csc = a.to_csc();
        let (rows, _) = csc.col(0);
        assert_eq!(rows, &[0, 2, 3]);
    }

    #[test]
    fn from_entries_validates() {
        assert!(Coo::from_entries(2, 2, vec![(2, 0, 1.0)]).is_err());
        assert!(Coo::from_entries(2, 2, vec![(1, 1, 1.0)]).is_ok());
    }
}
