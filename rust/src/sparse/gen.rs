//! Synthetic circuit-matrix generators — the offline stand-in for the UFL
//! (SuiteSparse) matrices of the paper's evaluation.
//!
//! The UFL collection is not reachable from this environment, so every bench
//! runs on generated matrices whose *structure* mirrors the corresponding UFL
//! matrix class (see `DESIGN.md` §2):
//!
//! - [`GenSpec::Netlist`] — random transistor-netlist graphs with strong
//!   index locality, a few long-range nets and high-degree hub nodes (power
//!   rails): the `rajat*`, `circuit_*`, `hcircuit` class.
//! - [`GenSpec::Grid2d`] — 5-point mesh Laplacians: the `G3_circuit` class
//!   (power-grid / substrate meshes).
//! - [`GenSpec::Ladder`] — memory-array ladders with bit/word-line rails:
//!   the `memplus` class.
//! - [`GenSpec::AsicMesh`] — mesh plus random parasitic couplings and rails:
//!   the `ASIC_*ks` class (post-layout parasitic networks).
//!
//! All generators produce diagonally dominant matrices (as MC64-style static
//! pivoting would), so LU without numerical pivoting — the GLU regime — is
//! stable. Row counts are the paper's, scaled down where the original is too
//! large for a cycle-accounting simulator (scaling documented per entry in
//! [`SuiteMatrix::spec`]).

use super::coo::Coo;
use super::csc::Csc;
use crate::util::Rng;

/// Specification of a synthetic circuit matrix.
#[derive(Debug, Clone, PartialEq)]
pub enum GenSpec {
    /// Random transistor netlist: `n` nodes, average structural degree `deg`,
    /// locality window `window` (neighbors are mostly within ±window),
    /// `p_long` fraction of long-range nets, `hubs` power-rail nodes,
    /// `asym` fraction of one-directional (controlled-source) couplings.
    Netlist {
        n: usize,
        deg: usize,
        window: usize,
        p_long: f64,
        hubs: usize,
        asym: f64,
        seed: u64,
    },
    /// 5-point 2-D mesh Laplacian (`nx * ny` nodes) with leak to ground.
    Grid2d { nx: usize, ny: usize, seed: u64 },
    /// Memory-array ladder: `n` cells in chains of length `chain`, plus
    /// word/bit-line rails every `rail_every` cells.
    Ladder {
        n: usize,
        chain: usize,
        rail_every: usize,
        seed: u64,
    },
    /// Post-layout parasitic mesh: 2-D grid plus `parasitic_per_node`
    /// random medium-range couplings and `hubs` rails.
    AsicMesh {
        nx: usize,
        ny: usize,
        parasitic_per_node: f64,
        hubs: usize,
        seed: u64,
    },
}

impl GenSpec {
    /// Number of rows the spec will generate.
    pub fn n(&self) -> usize {
        match *self {
            GenSpec::Netlist { n, .. } => n,
            GenSpec::Grid2d { nx, ny, .. } => nx * ny,
            GenSpec::Ladder { n, .. } => n,
            GenSpec::AsicMesh { nx, ny, .. } => nx * ny,
        }
    }
}

/// Generate the matrix for a spec.
pub fn generate(spec: &GenSpec) -> Csc {
    match *spec {
        GenSpec::Netlist {
            n,
            deg,
            window,
            p_long,
            hubs,
            asym,
            seed,
        } => netlist(n, deg, window, p_long, hubs, asym, seed),
        GenSpec::Grid2d { nx, ny, seed } => grid2d(nx, ny, seed),
        GenSpec::Ladder {
            n,
            chain,
            rail_every,
            seed,
        } => ladder(n, chain, rail_every, seed),
        GenSpec::AsicMesh {
            nx,
            ny,
            parasitic_per_node,
            hubs,
            seed,
        } => asic_mesh(nx, ny, parasitic_per_node, hubs, seed),
    }
}

/// Log-uniform conductance in `[0.1, 10]` — typical circuit stamp range.
fn conductance(rng: &mut Rng) -> f64 {
    10f64.powf(rng.range_f64(-1.0, 1.0))
}

/// Assemble a structurally (mostly) symmetric conductance matrix from a set
/// of two-terminal couplings; makes the diagonal strictly *column*
/// diagonally dominant — the property that guarantees pivot-free LU is
/// stable (partial pivoting would never swap), matching the GLU regime.
fn assemble(n: usize, couplings: &[(usize, usize, f64, bool)], seed: u64) -> Csc {
    let mut rng = Rng::new(seed ^ 0xD1A6);
    // diag[c] accumulates the |offdiagonal| mass of *column* c.
    let mut diag = vec![0.0f64; n];
    let mut coo = Coo::new(n, n);
    for &(a, b, g, bidir) in couplings {
        if a == b {
            continue;
        }
        coo.push(a, b, -g); // entry in column b
        diag[b] += g;
        if bidir {
            coo.push(b, a, -g); // entry in column a
            diag[a] += g;
        }
    }
    for (i, &d) in diag.iter().enumerate() {
        // ground leak keeps every node's diagonal nonzero and dominant.
        let leak = 0.05 + 0.1 * rng.f64();
        coo.push(i, i, d * 1.05 + leak);
    }
    coo.to_csc()
}

/// Random transistor-netlist graph (rajat/circuit class).
pub fn netlist(
    n: usize,
    deg: usize,
    window: usize,
    p_long: f64,
    hubs: usize,
    asym: f64,
    seed: u64,
) -> Csc {
    assert!(n >= 8, "netlist needs n >= 8");
    let mut rng = Rng::new(seed);
    let hub_ids: Vec<usize> = (0..hubs.min(n / 8)).map(|_| rng.below(n)).collect();
    let mut couplings: Vec<(usize, usize, f64, bool)> = Vec::with_capacity(n * deg / 2 + n);
    // Each node sprouts ~deg/2 edges so average degree ≈ deg. Circuit
    // netlists are strongly local after netlist ordering: neighbor distance
    // is geometric (most nets span a handful of adjacent nodes), with a
    // small fraction of long-range nets (clock/reset/bus) — the knob that
    // controls fill-in, which is what distinguishes the low-fill `rajat12`
    // class (1.1x) from the high-fill `onetone2` class (5.7x).
    let halfdeg = deg.div_ceil(2).max(1);
    for a in 0..n {
        for _ in 0..halfdeg {
            let b = if rng.chance(p_long) {
                rng.below(n)
            } else {
                // geometric hop distance, capped at the window
                let mut d = 1usize;
                while d < window.max(1) && rng.chance(0.45) {
                    d += 1;
                }
                if rng.chance(0.5) {
                    a.saturating_sub(d)
                } else {
                    (a + d).min(n - 1)
                }
            };
            if b != a {
                couplings.push((a, b, conductance(&mut rng), !rng.chance(asym)));
            }
        }
    }
    // Power rails: each hub couples to a modest spread of nodes.
    for &h in &hub_ids {
        let fan = (n / 256).clamp(8, 64);
        for _ in 0..fan {
            let b = rng.below(n);
            if b != h {
                couplings.push((h, b, conductance(&mut rng), true));
            }
        }
    }
    assemble(n, &couplings, seed)
}

/// "Newton restamp": same sparsity pattern, fresh values. Scales every
/// column by an independent random factor in `[0.5, 2)`, which preserves
/// the column diagonal dominance the pivot-free GLU regime relies on —
/// the value churn a solver service sees between refactor requests. Used
/// by the service demo, the `serve` CLI command, and the service/property
/// tests.
pub fn restamp_columns(a: &Csc, rng: &mut Rng) -> Csc {
    let mut m = a.clone();
    let colptr = m.colptr().to_vec();
    let vals = m.values_mut();
    for c in 0..colptr.len() - 1 {
        let s = rng.range_f64(0.5, 2.0);
        for v in &mut vals[colptr[c]..colptr[c + 1]] {
            *v *= s;
        }
    }
    m
}

/// One-entry structural delta: `a` plus a single `(row, col)` stamp of
/// value `g` — the pattern-delta fixture for the incremental-symbolic path
/// (a device added between two existing nodes; the Jacobian gains one
/// coupling entry). If `(row, col)` is already structural, the value is
/// merged and the pattern is unchanged — callers wanting a guaranteed
/// structural change should pick an absent coordinate.
pub fn with_entry(a: &Csc, row: usize, col: usize, g: f64) -> Csc {
    assert!(row < a.nrows() && col < a.ncols());
    let mut coo = Coo::new(a.nrows(), a.ncols());
    for c in 0..a.ncols() {
        let (rows, vals) = a.col(c);
        for (&r, &v) in rows.iter().zip(vals) {
            coo.push(r, c, v);
        }
    }
    coo.push(row, col, g);
    coo.to_csc()
}

// ---------------------------------------------------------------------------
// Adversarial restamps — the numeric-robustness-ladder test fixtures.
//
// Each transformer keeps the sparsity pattern *bit-identical* (so the result
// is a legal [`crate::glu::GluSolver::refactor`] input for a solver factored
// on the healthy original) while making the values hostile to the no-pivot
// regime in a specific, documented way. This mirrors what a Newton iteration
// actually hands a cached solver when the operating point goes bad: the same
// Jacobian pattern with degenerate values.
// ---------------------------------------------------------------------------

/// Near-singular restamp: scale the diagonal entry of every `every`-th
/// column by `factor` (use `0.0` for exact zero pivots, `~1e-13` for the
/// tiny-pivot / condition-gate regime). Off-diagonals are untouched, so the
/// matrix usually stays nonsingular — it is the *static pivot order* that
/// breaks, which is exactly what the ladder's diagonal perturbation repairs.
pub fn weaken_diagonal(a: &Csc, every: usize, factor: f64) -> Csc {
    assert!(every >= 1);
    let mut m = a.clone();
    let n = m.ncols();
    for j in (0..n).step_by(every) {
        if let Some(idx) = m.entry_index(j, j) {
            let vals = m.values_mut();
            vals[idx] *= factor;
        }
    }
    m
}

/// Mis-scaled restamp: multiply every `every`-th *row* by `factor` (think
/// `1e100`: a device model blowing up in one equation). Pivots stay
/// nonzero but the diagonal ratio explodes past any condition gate, and a
/// relative diagonal perturbation drowns the healthy rows — the fixture
/// that forces the ladder past rung 1 into re-equilibration.
pub fn misscale_rows(a: &Csc, every: usize, factor: f64) -> Csc {
    assert!(every >= 1);
    let mut m = a.clone();
    let colptr = m.colptr().to_vec();
    let rowidx = m.rowidx().to_vec();
    let vals = m.values_mut();
    for c in 0..colptr.len() - 1 {
        for p in colptr[c]..colptr[c + 1] {
            if rowidx[p] % every == 0 {
                vals[p] *= factor;
            }
        }
    }
    m
}

/// Highly-unsymmetric restamp: stretch strictly-upper entries up and
/// strictly-lower entries down by per-entry log-uniform factors up to
/// `10^decades`, destroying the value symmetry (and much of the diagonal
/// dominance) the generators otherwise guarantee. Exercises the ladder's
/// growth monitoring on matrices where `A` and `Aᵀ` look nothing alike.
pub fn skew_unsymmetric(a: &Csc, decades: f64, seed: u64) -> Csc {
    let mut rng = Rng::new(seed ^ 0x5EED);
    let mut m = a.clone();
    let colptr = m.colptr().to_vec();
    let rowidx = m.rowidx().to_vec();
    let vals = m.values_mut();
    for c in 0..colptr.len() - 1 {
        for p in colptr[c]..colptr[c + 1] {
            let r = rowidx[p];
            if r < c {
                vals[p] *= 10f64.powf(rng.range_f64(0.0, decades));
            } else if r > c {
                vals[p] *= 10f64.powf(rng.range_f64(-decades, 0.0));
            }
        }
    }
    m
}

// ---------------------------------------------------------------------------
// Pivot-order killers — the rung-5 rescue test fixtures.
//
// Unlike the restamps above, these *construct* matrices (pattern and values)
// that no fixed-order repair can save: the static pivot sequence hits an
// exact zero that diagonal perturbation turns into a 1/eps elimination
// cascade, overflowing to a non-finite pivot long before the last column —
// under the original values, under perturbation, and under Ruiz rescaling
// alike. Only a factorization that *changes the row order*
// ([`crate::numeric::pivlu`]) factors them; threshold partial pivoting then
// finds unit-magnitude pivots and growth ~1. Both matrices are exactly
// nonsingular, and [`dominant_restamp`] produces a diagonally-dominant
// "healthy twin" on the identical pattern so a solver can be factored
// cleanly first and fed the hostile values through `refactor`.
// ---------------------------------------------------------------------------

/// A band of explicit-zero diagonals backed by a unit subdiagonal chain.
///
/// Columns `0..band` carry an explicit `0.0` diagonal and a unit
/// subdiagonal `(j+1, j)`; entry `(0, band)` closes the chain so the matrix
/// stays exactly nonsingular (determinant `±1` times the healthy block).
/// Columns `band..n` get a dominant random diagonal plus a sparse seeded
/// background strictly inside the healthy block. The fixed-order ladder
/// dies deterministically: rung 0 hits the exact zero at column 0, and the
/// perturbed reruns (rungs 1–4) push `1/eps ≈ 1e8` multipliers down the
/// chain, overflowing into column `band` after ~40 steps — so keep
/// `band >= 44`. Partial pivoting instead walks the unit subdiagonals and
/// swaps exactly `band + 1` pivots.
pub fn zero_diagonal_band(n: usize, band: usize, seed: u64) -> Csc {
    assert!(band >= 44 && band + 2 < n, "need 44 <= band < n - 2");
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    for j in 0..band {
        coo.push(j, j, 0.0); // explicit zero diagonal: pattern, no value
        coo.push(j + 1, j, 1.0); // unit subdiagonal chain
    }
    coo.push(0, band, 1.0); // closes the chain: keeps the matrix nonsingular
    for j in band..n {
        coo.push(j, j, 4.0 + rng.f64());
    }
    // Sparse background strictly inside the healthy block, off-diagonal, so
    // it can neither revive the dead band nor feed column `band` early.
    for _ in 0..n {
        let r = rng.range(band + 1, n);
        let c = rng.range(band + 1, n);
        if r != c {
            coo.push(r, c, 0.01 * rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csc()
}

/// Rows of an upper-bidiagonal matrix shuffled block-cyclically.
///
/// Builds a well-behaved upper-bidiagonal `B` (diagonal `±[3,5]`,
/// superdiagonal `±[0.5,1]`), then shifts every row up by one inside each
/// `block`-sized group (the top row wraps to the bottom) — the classic
/// "rows arrived in the wrong order" failure MC64 would normally undo at
/// preprocessing time, landing mid-stream on a solver whose permutations
/// are already frozen. Every diagonal of the shuffled matrix is
/// structurally zero (stored explicitly), so the ladder's perturbed reruns
/// cascade `1/eps` multipliers down each block and overflow before the
/// block ends — keep `block >= 44`. Threshold partial pivoting simply
/// rediscovers the un-shuffled order: all `n` pivots swap, growth ~1.
pub fn shuffle_rows(n: usize, block: usize, seed: u64) -> Csc {
    assert!(block >= 44 && n % block == 0, "need block >= 44 dividing n");
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n, n);
    let shifted = |r: usize| {
        let b = (r / block) * block;
        b + (r + block - b - 1) % block
    };
    for i in 0..n {
        let s = if rng.chance(0.5) { 1.0 } else { -1.0 };
        coo.push(shifted(i), i, s * rng.range_f64(3.0, 5.0));
        if i + 1 < n {
            let s = if rng.chance(0.5) { 1.0 } else { -1.0 };
            coo.push(shifted(i), i + 1, s * rng.range_f64(0.5, 1.0));
        }
    }
    for i in 0..n {
        coo.push(i, i, 0.0); // explicit zero diagonal at every column
    }
    coo.to_csc()
}

/// Diagonally-dominant healthy twin on an identical pattern: every
/// off-diagonal value is redrawn in `[-1, 1]` and every diagonal is then
/// stamped to `1 + margin` above its row's off-diagonal mass — so the
/// greedy matching is the identity, the no-pivot factorization is clean,
/// and the result is a legal `factor` precursor for a later `refactor`
/// with the adversarial values (same pattern, hostile stamps).
pub fn dominant_restamp(a: &Csc, seed: u64) -> Csc {
    let mut rng = Rng::new(seed ^ 0xD0_0D);
    let mut m = a.clone();
    let n = m.ncols();
    let colptr = m.colptr().to_vec();
    let rowidx = m.rowidx().to_vec();
    let vals = m.values_mut();
    let mut offmass = vec![0.0f64; n];
    for c in 0..n {
        for p in colptr[c]..colptr[c + 1] {
            if rowidx[p] != c {
                vals[p] = rng.range_f64(-1.0, 1.0);
                offmass[rowidx[p]] += vals[p].abs();
            }
        }
    }
    for c in 0..n {
        for p in colptr[c]..colptr[c + 1] {
            if rowidx[p] == c {
                vals[p] = offmass[c] + 1.0 + rng.f64();
            }
        }
    }
    m
}

/// 5-point 2-D mesh Laplacian (G3_circuit class).
pub fn grid2d(nx: usize, ny: usize, seed: u64) -> Csc {
    let n = nx * ny;
    let mut rng = Rng::new(seed);
    let idx = |x: usize, y: usize| y * nx + x;
    let mut couplings = Vec::with_capacity(2 * n);
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                couplings.push((idx(x, y), idx(x + 1, y), conductance(&mut rng), true));
            }
            if y + 1 < ny {
                couplings.push((idx(x, y), idx(x, y + 1), conductance(&mut rng), true));
            }
        }
    }
    assemble(n, &couplings, seed)
}

/// Memory-array ladder (memplus class): chains with periodic rails.
pub fn ladder(n: usize, chain: usize, rail_every: usize, seed: u64) -> Csc {
    assert!(chain >= 2);
    let mut rng = Rng::new(seed);
    let mut couplings = Vec::with_capacity(n * 2);
    for a in 0..n {
        // chain link
        if (a + 1) % chain != 0 && a + 1 < n {
            couplings.push((a, a + 1, conductance(&mut rng), true));
        }
        // rail couplings: every cell connects to its rail node
        if rail_every > 0 {
            let rail = (a / rail_every) * rail_every;
            if rail != a {
                couplings.push((a, rail, conductance(&mut rng), true));
            }
        }
    }
    assemble(n, &couplings, seed)
}

/// Post-layout parasitic mesh (ASIC_*ks class).
pub fn asic_mesh(nx: usize, ny: usize, parasitic_per_node: f64, hubs: usize, seed: u64) -> Csc {
    let n = nx * ny;
    let mut rng = Rng::new(seed);
    let idx = |x: usize, y: usize| y * nx + x;
    let mut couplings = Vec::with_capacity((n as f64 * (2.0 + parasitic_per_node)) as usize);
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                couplings.push((idx(x, y), idx(x + 1, y), conductance(&mut rng), true));
            }
            if y + 1 < ny {
                couplings.push((idx(x, y), idx(x, y + 1), conductance(&mut rng), true));
            }
        }
    }
    // Short-range parasitics: post-layout coupling capacitances reach a few
    // tracks away, not across the die — sample a (dx, dy) offset within a
    // small physical neighborhood (long-range edges would both be
    // unphysical and blow fill far beyond the ASIC_*ks matrices' 2–6x).
    let expected = (n as f64 * parasitic_per_node) as usize;
    for _ in 0..expected {
        let a = rng.below(n);
        let (ax, ay) = (a % nx, a / nx);
        let dx = rng.range(0, 17) as isize - 8; // ±8 tracks
        let dy = rng.range(0, 5) as isize - 2; // ±2 rows
        let bx = ax as isize + dx;
        let by = ay as isize + dy;
        if bx < 0 || by < 0 || bx >= nx as isize || by >= ny as isize {
            continue;
        }
        let b = by as usize * nx + bx as usize;
        if a != b {
            couplings.push((a, b, conductance(&mut rng), true));
        }
    }
    // Power rails: modest regional fan-out (a rail serves its die region).
    for hi in 0..hubs {
        let h = rng.below(n);
        let fan = (n / 512).clamp(8, 64);
        let region = n / hubs.max(1);
        let base = hi * region;
        for _ in 0..fan {
            let b = base + rng.below(region.max(1));
            if b != h && b < n {
                couplings.push((h, b, conductance(&mut rng), true));
            }
        }
    }
    assemble(n, &couplings, seed)
}

/// The benchmark suite: one entry per matrix in the paper's Tables I–III,
/// with the UFL name it substitutes for and the paper's published row/nnz
/// counts (kept for the EXPERIMENTS.md comparison columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SuiteMatrix {
    Rajat12,
    Circuit2,
    Memplus,
    Rajat27,
    Onetone2,
    Rajat15,
    Rajat26,
    Circuit4,
    Rajat20,
    Asic100ks,
    Hcircuit,
    Raj1,
    Asic320ks,
    Asic680ks,
    G3Circuit,
}

impl SuiteMatrix {
    /// All suite matrices in the paper's Table I order.
    pub const ALL: [SuiteMatrix; 15] = [
        SuiteMatrix::Rajat12,
        SuiteMatrix::Circuit2,
        SuiteMatrix::Memplus,
        SuiteMatrix::Rajat27,
        SuiteMatrix::Onetone2,
        SuiteMatrix::Rajat15,
        SuiteMatrix::Rajat26,
        SuiteMatrix::Circuit4,
        SuiteMatrix::Rajat20,
        SuiteMatrix::Asic100ks,
        SuiteMatrix::Hcircuit,
        SuiteMatrix::Raj1,
        SuiteMatrix::Asic320ks,
        SuiteMatrix::Asic680ks,
        SuiteMatrix::G3Circuit,
    ];

    /// A fast subset (n ≤ ~40k) for tests and smoke benches.
    pub const SMALL: [SuiteMatrix; 5] = [
        SuiteMatrix::Rajat12,
        SuiteMatrix::Circuit2,
        SuiteMatrix::Memplus,
        SuiteMatrix::Rajat27,
        SuiteMatrix::Onetone2,
    ];

    /// UFL name this entry substitutes for.
    pub fn ufl_name(self) -> &'static str {
        match self {
            SuiteMatrix::Rajat12 => "rajat12",
            SuiteMatrix::Circuit2 => "circuit_2",
            SuiteMatrix::Memplus => "memplus",
            SuiteMatrix::Rajat27 => "rajat27",
            SuiteMatrix::Onetone2 => "onetone2",
            SuiteMatrix::Rajat15 => "rajat15",
            SuiteMatrix::Rajat26 => "rajat26",
            SuiteMatrix::Circuit4 => "circuit_4",
            SuiteMatrix::Rajat20 => "rajat20",
            SuiteMatrix::Asic100ks => "ASIC_100ks",
            SuiteMatrix::Hcircuit => "hcircuit",
            SuiteMatrix::Raj1 => "Raj1",
            SuiteMatrix::Asic320ks => "ASIC_320ks",
            SuiteMatrix::Asic680ks => "ASIC_680ks",
            SuiteMatrix::G3Circuit => "G3_circuit",
        }
    }

    /// `(rows, nz)` as published in the paper's Table I.
    pub fn paper_stats(self) -> (usize, usize) {
        match self {
            SuiteMatrix::Rajat12 => (1879, 12926),
            SuiteMatrix::Circuit2 => (4510, 21199),
            SuiteMatrix::Memplus => (17758, 126150),
            SuiteMatrix::Rajat27 => (20640, 99777),
            SuiteMatrix::Onetone2 => (36057, 227628),
            SuiteMatrix::Rajat15 => (37261, 443573),
            SuiteMatrix::Rajat26 => (51032, 249302),
            SuiteMatrix::Circuit4 => (80209, 307604),
            SuiteMatrix::Rajat20 => (86916, 605045),
            SuiteMatrix::Asic100ks => (99190, 578890),
            SuiteMatrix::Hcircuit => (105676, 513072),
            SuiteMatrix::Raj1 => (263743, 1302464),
            SuiteMatrix::Asic320ks => (321671, 1827807),
            SuiteMatrix::Asic680ks => (682712, 2329176),
            SuiteMatrix::G3Circuit => (1585478, 4623152),
        }
    }

    /// The generator spec. Row counts follow the paper; the four largest
    /// matrices are scaled down (noted inline) so the cycle-accounting
    /// simulator completes the full suite in bench time.
    pub fn spec(self) -> GenSpec {
        match self {
            SuiteMatrix::Rajat12 => GenSpec::Netlist {
                n: 1879,
                deg: 7,
                window: 12,
                p_long: 0.004,
                hubs: 2,
                asym: 0.15,
                seed: 0x12,
            },
            SuiteMatrix::Circuit2 => GenSpec::Netlist {
                n: 4510,
                deg: 5,
                window: 12,
                p_long: 0.006,
                hubs: 3,
                asym: 0.2,
                seed: 0x02,
            },
            SuiteMatrix::Memplus => GenSpec::Ladder {
                n: 17758,
                chain: 64,
                rail_every: 128,
                seed: 0x03,
            },
            SuiteMatrix::Rajat27 => GenSpec::Netlist {
                n: 20640,
                deg: 5,
                window: 12,
                p_long: 0.004,
                hubs: 4,
                asym: 0.15,
                seed: 0x27,
            },
            SuiteMatrix::Onetone2 => GenSpec::Netlist {
                n: 36057,
                deg: 6,
                window: 28,
                p_long: 0.008,
                hubs: 6,
                asym: 0.3,
                seed: 0x04,
            },
            SuiteMatrix::Rajat15 => GenSpec::Netlist {
                n: 37261,
                deg: 8,
                window: 20,
                p_long: 0.005,
                hubs: 6,
                asym: 0.2,
                seed: 0x15,
            },
            SuiteMatrix::Rajat26 => GenSpec::Netlist {
                n: 51032,
                deg: 5,
                window: 14,
                p_long: 0.003,
                hubs: 6,
                asym: 0.15,
                seed: 0x26,
            },
            SuiteMatrix::Circuit4 => GenSpec::Netlist {
                n: 80209,
                deg: 4,
                window: 10,
                p_long: 0.003,
                hubs: 8,
                asym: 0.2,
                seed: 0x44,
            },
            SuiteMatrix::Rajat20 => GenSpec::Netlist {
                n: 86916,
                deg: 6,
                window: 18,
                p_long: 0.004,
                hubs: 8,
                asym: 0.2,
                seed: 0x20,
            },
            // ASIC post-layout parasitic networks are chain-dominated
            // (fill 2–6x in the paper), so the netlist generator with tight
            // locality models them better than a mesh would.
            SuiteMatrix::Asic100ks => GenSpec::Netlist {
                n: 99190,
                deg: 5,
                window: 14,
                p_long: 0.004,
                hubs: 10,
                asym: 0.1,
                seed: 0x100,
            },
            SuiteMatrix::Hcircuit => GenSpec::Netlist {
                n: 105676,
                deg: 4,
                window: 10,
                p_long: 0.002,
                hubs: 8,
                asym: 0.15,
                seed: 0x05,
            },
            // Scaled from 263743 rows (×0.5): simulator budget.
            SuiteMatrix::Raj1 => GenSpec::Netlist {
                n: 131072,
                deg: 7,
                window: 20,
                p_long: 0.003,
                hubs: 12,
                asym: 0.2,
                seed: 0x06,
            },
            // Scaled from 321671 rows (×0.5).
            SuiteMatrix::Asic320ks => GenSpec::Netlist {
                n: 160000,
                deg: 5,
                window: 10,
                p_long: 0.002,
                hubs: 12,
                asym: 0.1,
                seed: 0x320,
            },
            // Scaled from 682712 rows (×0.3).
            SuiteMatrix::Asic680ks => GenSpec::Netlist {
                n: 200704,
                deg: 4,
                window: 8,
                p_long: 0.0015,
                hubs: 12,
                asym: 0.1,
                seed: 0x680,
            },
            // Scaled from 1585478 rows (×0.077): 350x350 power-grid mesh
            // (2-D mesh fill under AMD grows superlinearly; 350² keeps the
            // cycle-accounting simulator inside the bench budget while
            // preserving the mesh structure that makes G3_circuit special
            // in Tables II/III).
            SuiteMatrix::G3Circuit => GenSpec::Grid2d {
                nx: 350,
                ny: 350,
                seed: 0x07,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_circuit_matrix(a: &Csc) {
        assert_eq!(a.nrows(), a.ncols());
        assert!(a.has_full_diagonal(), "diagonal must be structurally full");
        // Column diagonal dominance — required for pivot-free LU stability.
        for c in 0..a.ncols() {
            let (rows, vals) = a.col(c);
            let mut diag = 0.0;
            let mut off = 0.0;
            for (&r, &v) in rows.iter().zip(vals) {
                if r == c {
                    diag = v.abs();
                } else {
                    off += v.abs();
                }
            }
            assert!(diag > off * 0.99, "col {c}: diag {diag} vs off {off}");
        }
    }

    #[test]
    fn netlist_well_formed() {
        let a = netlist(512, 6, 16, 0.05, 4, 0.2, 1);
        check_circuit_matrix(&a);
        let avg = a.nnz() as f64 / 512.0;
        assert!(avg > 3.0 && avg < 20.0, "avg nnz/row {avg}");
    }

    #[test]
    fn netlist_deterministic() {
        let a = netlist(256, 6, 16, 0.05, 2, 0.2, 7);
        let b = netlist(256, 6, 16, 0.05, 2, 0.2, 7);
        assert_eq!(a, b);
        let c = netlist(256, 6, 16, 0.05, 2, 0.2, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn grid2d_structure() {
        let a = grid2d(8, 8, 3);
        check_circuit_matrix(&a);
        // interior node has 4 neighbors + diagonal = 5 entries in its column
        let (rows, _) = a.col(8 * 4 + 4);
        assert_eq!(rows.len(), 5);
        // corner has 2 neighbors + diag
        let (rows, _) = a.col(0);
        assert_eq!(rows.len(), 3);
    }

    #[test]
    fn ladder_structure() {
        let a = ladder(1024, 32, 64, 5);
        check_circuit_matrix(&a);
        assert!(a.nnz() < 1024 * 8);
    }

    #[test]
    fn asic_mesh_structure() {
        let a = asic_mesh(24, 24, 0.5, 2, 9);
        check_circuit_matrix(&a);
        let grid_only = grid2d(24, 24, 9);
        assert!(a.nnz() > grid_only.nnz(), "parasitics must add entries");
    }

    #[test]
    fn suite_specs_have_expected_sizes() {
        for m in SuiteMatrix::SMALL {
            let spec = m.spec();
            let (paper_rows, _) = m.paper_stats();
            // SMALL subset uses unscaled paper row counts.
            assert_eq!(spec.n(), paper_rows, "{}", m.ufl_name());
        }
        assert_eq!(SuiteMatrix::G3Circuit.spec().n(), 122_500);
    }

    #[test]
    fn small_suite_generates_valid() {
        for m in [SuiteMatrix::Rajat12, SuiteMatrix::Circuit2] {
            let a = generate(&m.spec());
            check_circuit_matrix(&a);
        }
    }

    #[test]
    fn with_entry_adds_exactly_one_structural_entry() {
        let a = grid2d(6, 6, 2);
        assert_eq!(a.get(17, 3), 0.0, "fixture needs an absent coordinate");
        let b = with_entry(&a, 17, 3, -0.25);
        assert_eq!(b.nnz(), a.nnz() + 1);
        assert_eq!(b.get(17, 3), -0.25);
        for c in 0..a.ncols() {
            let (rows, vals) = a.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                assert_eq!(b.get(r, c), v, "({r},{c}) must be untouched");
            }
        }
        // merging onto an existing coordinate keeps the pattern
        let m = with_entry(&a, 0, 0, 1.0);
        assert_eq!(m.nnz(), a.nnz());
        assert_eq!(m.get(0, 0), a.get(0, 0) + 1.0);
    }

    #[test]
    fn adversarial_restamps_preserve_pattern() {
        let a = netlist(200, 6, 12, 0.05, 2, 0.2, 31);
        for bad in [
            weaken_diagonal(&a, 3, 0.0),
            weaken_diagonal(&a, 5, 1e-13),
            misscale_rows(&a, 7, 1e100),
            skew_unsymmetric(&a, 6.0, 31),
        ] {
            assert_eq!(bad.colptr(), a.colptr());
            assert_eq!(bad.rowidx(), a.rowidx());
            assert_eq!(bad.nnz(), a.nnz());
        }
    }

    #[test]
    fn weaken_diagonal_hits_exactly_the_stride() {
        let a = grid2d(10, 10, 3);
        let bad = weaken_diagonal(&a, 4, 0.0);
        for j in 0..a.ncols() {
            let (orig, got) = (a.get(j, j), bad.get(j, j));
            if j % 4 == 0 {
                assert_eq!(got, 0.0, "col {j} must be zeroed");
            } else {
                assert_eq!(got, orig, "col {j} must be untouched");
            }
        }
    }

    #[test]
    fn misscale_rows_scales_whole_rows() {
        let a = grid2d(6, 6, 1);
        let bad = misscale_rows(&a, 3, 1e10);
        for c in 0..a.ncols() {
            let (rows, vals) = a.col(c);
            let (_, bvals) = bad.col(c);
            for ((&r, &v), &bv) in rows.iter().zip(vals).zip(bvals) {
                let want = if r % 3 == 0 { v * 1e10 } else { v };
                assert_eq!(bv, want, "({r},{c})");
            }
        }
    }

    #[test]
    fn zero_diagonal_band_structure() {
        let a = zero_diagonal_band(96, 48, 1);
        assert_eq!((a.nrows(), a.ncols()), (96, 96));
        assert!(a.has_full_diagonal(), "explicit zeros must be structural");
        for j in 0..48 {
            assert_eq!(a.get(j, j), 0.0, "col {j} diagonal must be zero");
            assert!(a.has_entry(j, j), "col {j} diagonal must be stored");
            assert_eq!(a.get(j + 1, j), 1.0, "col {j} unit subdiagonal");
        }
        assert_eq!(a.get(0, 48), 1.0, "chain-closing entry");
        for j in 48..96 {
            assert!(a.get(j, j) >= 4.0, "col {j} healthy diagonal");
        }
        // deterministic, and seed-sensitive in the healthy block
        assert_eq!(zero_diagonal_band(96, 48, 1), a);
        assert_ne!(zero_diagonal_band(96, 48, 2).get(50, 50), a.get(50, 50));
    }

    #[test]
    fn shuffle_rows_structure() {
        let a = shuffle_rows(96, 48, 9);
        // 2n-1 shifted bidiagonal entries + n explicit zero diagonals, and
        // none of the shifted coordinates lands on the diagonal.
        assert_eq!(a.nnz(), 3 * 96 - 1);
        assert!(a.has_full_diagonal());
        for i in 0..96 {
            assert_eq!(a.get(i, i), 0.0, "diagonal {i} must be zero");
        }
        // every column keeps exactly one large entry (the shuffled pivot)
        for c in 0..96 {
            let (_, vals) = a.col(c);
            let big = vals.iter().filter(|v| v.abs() >= 3.0).count();
            assert_eq!(big, 1, "col {c} must keep exactly one pivot entry");
        }
        assert_eq!(shuffle_rows(96, 48, 9), a);
    }

    #[test]
    fn dominant_restamp_is_a_healthy_twin() {
        for a in [zero_diagonal_band(96, 48, 3), shuffle_rows(96, 48, 3)] {
            let t = dominant_restamp(&a, 17);
            assert_eq!(t.colptr(), a.colptr());
            assert_eq!(t.rowidx(), a.rowidx());
            // row-dominant (stable no-pivot LU) and column-dominant (the
            // greedy matching keeps the natural row order)
            let mut offrow = vec![0.0f64; 96];
            for c in 0..96 {
                let (rows, vals) = t.col(c);
                for (&r, &v) in rows.iter().zip(vals) {
                    if r != c {
                        offrow[r] += v.abs();
                        assert!(v.abs() <= 1.0);
                    }
                }
            }
            for c in 0..96 {
                assert!(t.get(c, c) >= offrow[c] + 1.0, "row {c} not dominant");
            }
        }
    }

    #[test]
    fn skew_unsymmetric_breaks_value_symmetry() {
        let a = grid2d(8, 8, 5);
        let bad = skew_unsymmetric(&a, 6.0, 5);
        // diagonal untouched, and at least one mirrored pair now differs by
        // orders of magnitude
        let mut max_ratio = 0.0f64;
        for c in 0..a.ncols() {
            assert_eq!(bad.get(c, c), a.get(c, c));
            let (rows, _) = a.col(c);
            for &r in rows {
                if r > c {
                    let (lo, hi) = (bad.get(r, c).abs(), bad.get(c, r).abs());
                    if lo > 0.0 {
                        max_ratio = max_ratio.max(hi / lo);
                    }
                }
            }
        }
        assert!(max_ratio > 1e3, "skew too mild: {max_ratio}");
    }
}
